"""Structure equivalences + model-level checks for the JAX (L2) side."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import model, structures

jax.config.update("jax_platform_name", "cpu")


def test_apply_matches_dense_reconstruction():
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(jax.random.PRNGKey(1), (5, 12))
    inits = [
        structures.init_dense(key, 8, 12),
        structures.init_low_rank(key, 8, 12, 3),
        structures.init_blast(key, 8, 12, 2, 3),
        structures.init_monarch(key, 8, 12, 2, 2),
        structures.init_block_diag(key, 8, 12, 2, 2),
    ]
    for p in inits:
        y = structures.apply_linear(p, x, use_pallas=False)
        w = structures.to_dense(p)
        np.testing.assert_allclose(y, x @ w.T, rtol=1e-4, atol=1e-4,
                                   err_msg=structures.structure_kind(p))


def test_blast_pallas_and_einsum_agree_in_layer():
    key = jax.random.PRNGKey(2)
    p = structures.init_blast(key, 8, 12, 2, 3)
    x = jax.random.normal(jax.random.PRNGKey(3), (5, 12))
    y1 = structures.apply_linear(p, x, use_pallas=True)
    y2 = structures.apply_linear(p, x, use_pallas=False)
    np.testing.assert_allclose(y1, y2, rtol=1e-4, atol=1e-4)


@settings(max_examples=10, deadline=None)
@given(b=st.sampled_from([1, 2, 4]), r=st.integers(1, 8),
       seed=st.integers(0, 1000))
def test_blast_param_count(b, r, seed):
    key = jax.random.PRNGKey(seed)
    p = structures.init_blast(key, 16, 16, b, r)
    expected = r * (16 + 16) + r * b * b
    assert structures.num_params(p) == expected


def test_model_forward_shape_and_loss():
    cfg = model.make_config(structure=("blast", 2, 4))
    params = model.init_params(jax.random.PRNGKey(0), cfg)
    tokens = jnp.arange(16, dtype=jnp.int32) % cfg["vocab"]
    logits = model.forward(params, tokens, cfg)
    assert logits.shape == (16, cfg["vocab"])
    loss = model.loss_fn(params, tokens, cfg)
    # Random init ~ uniform -> loss near log(vocab).
    assert abs(float(loss) - np.log(cfg["vocab"])) < 1.0


def test_train_step_reduces_loss():
    cfg = model.make_config(structure=("blast", 2, 4), max_seq=16)
    params = model.init_params(jax.random.PRNGKey(0), cfg)
    opt = model.init_opt_state(params)
    batch = (jnp.arange(4 * 16, dtype=jnp.int32) % cfg["vocab"]).reshape(4, 16)
    step = jax.jit(lambda p, o, b: model.train_step(p, o, b, 1e-2, cfg))
    _, _, loss0 = step(params, opt, batch)
    for _ in range(20):
        params, opt, loss = step(params, opt, batch)
    assert float(loss) < float(loss0) * 0.8, (float(loss0), float(loss))


def test_structures_all_train():
    """Every structure's model must be end-to-end differentiable."""
    for s in [("dense",), ("lowrank", 8), ("blast", 2, 4),
              ("monarch", 2, 2), ("blockdiag", 2, 8)]:
        cfg = model.make_config(structure=s, max_seq=8, n_layers=1)
        params = model.init_params(jax.random.PRNGKey(0), cfg)
        tokens = jnp.arange(8, dtype=jnp.int32) % cfg["vocab"]
        g = jax.grad(model.loss_fn)(params, tokens, cfg)
        leaves = jax.tree.leaves(g)
        assert any(float(jnp.abs(l).max()) > 0 for l in leaves), s
