"""JAX mirror of Algorithm 2 cross-validates the Rust implementation's
behavior (convergence ordering, preconditioning benefit)."""

import jax
import jax.numpy as jnp
import numpy as np

from compile.factorize import blast_loss, factorize_gd, factorize_precgd
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")


def low_rank_target(key, n, r_star):
    k1, k2 = jax.random.split(key)
    u = jax.random.normal(k1, (n, r_star))
    v = jax.random.normal(k2, (n, r_star))
    return (u @ v.T) / np.sqrt(r_star)


def test_gd_converges_exact_rank():
    a = low_rank_target(jax.random.PRNGKey(0), 48, 4)
    u, v, s, trace = factorize_gd(a, b=4, r=4, iters=60, seed=1)
    rel = float(jnp.linalg.norm(ref.blast_dense(u, v, s) - a) / jnp.linalg.norm(a))
    assert rel < 0.1, rel


def test_precgd_beats_gd_overparameterized():
    """Fig. 3-right: PrecGD converges where GD stalls for r > r*."""
    a = low_rank_target(jax.random.PRNGKey(1), 48, 4)
    _, _, _, trace_gd = factorize_gd(a, b=4, r=16, iters=50, seed=2)
    _, _, _, trace_pgd = factorize_precgd(a, b=4, r=16, iters=50, seed=2)
    assert trace_pgd[-1] < 0.5 * trace_gd[-1], (trace_gd[-1], trace_pgd[-1])


def test_losses_decrease():
    a = low_rank_target(jax.random.PRNGKey(2), 32, 2)
    _, _, _, trace = factorize_precgd(a, b=2, r=4, iters=40, seed=3)
    assert trace[-1] < 1e-2 * trace[0]


def test_loss_fn_zero_at_exact():
    key = jax.random.PRNGKey(3)
    b, p, r = 2, 8, 3
    u = jax.random.normal(key, (b, p, r))
    v = jax.random.normal(jax.random.fold_in(key, 1), (b, p, r))
    s = jax.random.uniform(jax.random.fold_in(key, 2), (b, b, r))
    a = ref.blast_dense(u, v, s)
    assert float(blast_loss(a, u, v, s)) < 1e-8
