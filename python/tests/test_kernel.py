"""L1 correctness: Pallas BLAST kernel vs pure-jnp oracle.

Hypothesis sweeps shapes/b/r/dtype; every case asserts allclose against
both the einsum form of Algorithm 1 and the dense reconstruction.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.blast_matmul import (blast_matmul,
                                          mxu_utilization_estimate,
                                          vmem_footprint_bytes)
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")


def random_factors(key, b, p, q, r):
    k1, k2, k3 = jax.random.split(key, 3)
    u = jax.random.normal(k1, (b, p, r))
    v = jax.random.normal(k2, (b, q, r))
    s = jax.random.uniform(k3, (b, b, r), minval=-1.0, maxval=1.0)
    return u, v, s


def test_kernel_matches_ref_basic():
    key = jax.random.PRNGKey(0)
    u, v, s = random_factors(key, 4, 8, 8, 3)
    x = jax.random.normal(jax.random.PRNGKey(1), (5, 32))
    y_kernel = blast_matmul(x, u, v, s)
    y_ref = ref.blast_matmul_ref(x, u, v, s)
    np.testing.assert_allclose(y_kernel, y_ref, rtol=1e-4, atol=1e-4)


def test_kernel_matches_dense():
    key = jax.random.PRNGKey(2)
    u, v, s = random_factors(key, 2, 6, 4, 5)
    x = jax.random.normal(jax.random.PRNGKey(3), (7, 8))
    dense = ref.blast_dense(u, v, s)
    y_kernel = blast_matmul(x, u, v, s)
    np.testing.assert_allclose(y_kernel, x @ dense.T, rtol=1e-4, atol=1e-4)


@settings(max_examples=25, deadline=None)
@given(
    b=st.sampled_from([1, 2, 4]),
    p=st.integers(1, 12),
    q=st.integers(1, 12),
    r=st.integers(1, 16),
    batch=st.integers(1, 9),
    seed=st.integers(0, 2**31 - 1),
)
def test_kernel_matches_ref_sweep(b, p, q, r, batch, seed):
    key = jax.random.PRNGKey(seed)
    u, v, s = random_factors(key, b, p, q, r)
    x = jax.random.normal(jax.random.fold_in(key, 1), (batch, b * q))
    y_kernel = blast_matmul(x, u, v, s)
    y_ref = ref.blast_matmul_ref(x, u, v, s)
    np.testing.assert_allclose(y_kernel, y_ref, rtol=2e-4, atol=2e-4)


def test_low_rank_special_case():
    """All-ones couplings make BLAST a global low-rank product (§2)."""
    key = jax.random.PRNGKey(4)
    b, p, q, r = 3, 4, 5, 2
    u, v, _ = random_factors(key, b, p, q, r)
    s = jnp.ones((b, b, r))
    big_u = u.reshape(b * p, r)
    big_v = v.reshape(b * q, r)
    dense = ref.blast_dense(u, v, s)
    np.testing.assert_allclose(dense, big_u @ big_v.T, rtol=1e-4, atol=1e-5)


def test_block_diag_special_case():
    """One-hot diagonal couplings zero the off-diagonal blocks (§A.1)."""
    key = jax.random.PRNGKey(5)
    b, p, q, r = 3, 4, 4, 4
    u, v, _ = random_factors(key, b, p, q, r)
    s = jnp.zeros((b, b, r))
    for i in range(b):
        s = s.at[i, i].set(1.0)
    dense = np.asarray(ref.blast_dense(u, v, s))
    for i in range(b):
        for j in range(b):
            blk = dense[i * p:(i + 1) * p, j * q:(j + 1) * q]
            if i != j:
                assert np.abs(blk).max() < 1e-5, f"block {i},{j} nonzero"
            else:
                expected = np.asarray(u[i]) @ np.asarray(v[i]).T
                np.testing.assert_allclose(blk, expected, rtol=1e-4, atol=1e-5)


def test_flop_and_param_formulas():
    assert ref.blast_matvec_flops(256, 256, 16, 8) == (256 + 256 + 256) * 8
    assert ref.blast_num_params(256, 256, 16, 8) == 8 * 512 + 8 * 256


def test_kernel_grad_flows():
    """The kernel must be differentiable (paper §3.1: trainable by SGD)."""
    key = jax.random.PRNGKey(6)
    u, v, s = random_factors(key, 2, 4, 4, 3)
    x = jax.random.normal(jax.random.PRNGKey(7), (3, 8))

    def loss(u, v, s):
        return (blast_matmul(x, u, v, s) ** 2).sum()

    gu, gv, gs = jax.grad(loss, argnums=(0, 1, 2))(u, v, s)
    # Compare against grads through the einsum reference.
    def loss_ref(u, v, s):
        return (ref.blast_matmul_ref(x, u, v, s) ** 2).sum()

    ru, rv, rs = jax.grad(loss_ref, argnums=(0, 1, 2))(u, v, s)
    np.testing.assert_allclose(gu, ru, rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(gv, rv, rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(gs, rs, rtol=1e-3, atol=1e-3)


def test_vmem_footprint_within_budget():
    """DESIGN.md §8: per-grid-step VMEM stays under 16 MB at Llama-like
    shapes (b=16, p=q=256, r=992, decode batch 8)."""
    assert vmem_footprint_bytes(8, 16, 256, 256, 992) <= 16 * 1024 * 1024


def test_mxu_share_dominates():
    share = mxu_utilization_estimate(8, 16, 256, 256, 992)
    assert share > 0.9, share


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_dtypes(dtype):
    key = jax.random.PRNGKey(8)
    u, v, s = random_factors(key, 2, 4, 4, 3)
    u, v, s = u.astype(dtype), v.astype(dtype), s.astype(dtype)
    x = jax.random.normal(jax.random.PRNGKey(9), (4, 8)).astype(dtype)
    y = blast_matmul(x, u, v, s)
    y_ref = ref.blast_matmul_ref(x, u, v, s)
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(
        np.asarray(y, np.float32), np.asarray(y_ref, np.float32),
        rtol=tol, atol=tol)
