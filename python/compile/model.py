"""L2 — JAX transformer LM with pluggable structured linears.

The same TinyLM architecture as the Rust side (token + positional
embeddings, pre-LN blocks with stacked-QKV attention, GELU MLP, dense
head), expressed functionally so it lowers to a single HLO module. Three
entrypoints get AOT-exported per structure:

* ``forward``       — full-sequence logits (prefill / scoring);
* ``train_step``    — fused fwd + bwd + AdamW update (the E2E example's
  hot loop; Rust feeds batches, all math is inside the artifact);
* ``loss_only``     — mean next-token loss (perplexity eval).

BLAST layers route through the Pallas kernel (interpret=True), so the
exported HLO contains the Algorithm-1 dataflow.
"""

import functools

import jax
import jax.numpy as jnp

from . import structures


# ---------------------------------------------------------------------
# Config / init
# ---------------------------------------------------------------------

def make_config(vocab=64, d_model=64, n_layers=2, n_heads=4, d_ff=128,
                max_seq=32, structure=("dense",)):
    return dict(vocab=vocab, d_model=d_model, n_layers=n_layers,
                n_heads=n_heads, d_ff=d_ff, max_seq=max_seq,
                structure=tuple(structure))


def _init_structured(key, out_dim, in_dim, structure):
    kind = structure[0]
    if kind == "dense":
        return structures.init_dense(key, out_dim, in_dim)
    if kind == "lowrank":
        return structures.init_low_rank(key, out_dim, in_dim, structure[1])
    if kind == "blast":
        return structures.init_blast(key, out_dim, in_dim, structure[1], structure[2])
    if kind == "monarch":
        return structures.init_monarch(key, out_dim, in_dim, structure[1], structure[2])
    if kind == "blockdiag":
        return structures.init_block_diag(key, out_dim, in_dim, structure[1], structure[2])
    raise ValueError(kind)


def init_params(key, cfg):
    """Initialize all model parameters as a pytree."""
    keys = jax.random.split(key, 4 + 4 * cfg["n_layers"])
    d, v, ff = cfg["d_model"], cfg["vocab"], cfg["d_ff"]
    s = cfg["structure"]
    params = {
        "tok_embed": jax.random.normal(keys[0], (v, d)) * 0.02,
        "pos_embed": jax.random.normal(keys[1], (cfg["max_seq"], d)) * 0.02,
        "head": structures.init_dense(keys[2], v, d),
        "ln_f": {"g": jnp.ones(d), "b": jnp.zeros(d)},
        "blocks": [],
    }
    for i in range(cfg["n_layers"]):
        k = keys[4 + 4 * i: 8 + 4 * i]
        params["blocks"].append({
            "ln1": {"g": jnp.ones(d), "b": jnp.zeros(d)},
            "wqkv": _init_structured(k[0], 3 * d, d, s),
            "wo": _init_structured(k[1], d, d, s),
            "ln2": {"g": jnp.ones(d), "b": jnp.zeros(d)},
            "fc1": _init_structured(k[2], ff, d, s),
            "fc2": _init_structured(k[3], d, ff, s),
        })
    return params


# ---------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------

def _layernorm(p, x):
    mean = x.mean(-1, keepdims=True)
    var = ((x - mean) ** 2).mean(-1, keepdims=True)
    return (x - mean) / jnp.sqrt(var + 1e-5) * p["g"] + p["b"]


def _attention(blk, x, n_heads, causal=True):
    seq, d = x.shape
    hd = d // n_heads
    qkv = structures.apply_linear(blk["wqkv"], x)  # (seq, 3d)
    q, k, v = jnp.split(qkv, 3, axis=-1)
    q = q.reshape(seq, n_heads, hd).transpose(1, 0, 2)
    k = k.reshape(seq, n_heads, hd).transpose(1, 0, 2)
    v = v.reshape(seq, n_heads, hd).transpose(1, 0, 2)
    scores = jnp.einsum("hsd,htd->hst", q, k) / jnp.sqrt(hd)
    if causal:
        mask = jnp.tril(jnp.ones((seq, seq), dtype=bool))
        scores = jnp.where(mask[None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    ctx = jnp.einsum("hst,htd->hsd", probs, v)
    ctx = ctx.transpose(1, 0, 2).reshape(seq, d)
    return structures.apply_linear(blk["wo"], ctx)


def _block(blk, x, n_heads):
    x = x + _attention(blk, _layernorm(blk["ln1"], x), n_heads)
    h = structures.apply_linear(blk["fc1"], _layernorm(blk["ln2"], x))
    h = jax.nn.gelu(h, approximate=True)
    return x + structures.apply_linear(blk["fc2"], h)


def forward(params, tokens, cfg):
    """Full-sequence logits: tokens (seq,) int32 -> (seq, vocab)."""
    seq = tokens.shape[0]
    x = params["tok_embed"][tokens] + params["pos_embed"][:seq]
    for blk in params["blocks"]:
        x = _block(blk, x, cfg["n_heads"])
    x = _layernorm(params["ln_f"], x)
    return structures.apply_linear(params["head"], x)


def loss_fn(params, tokens, cfg):
    """Mean next-token cross-entropy over one sequence."""
    logits = forward(params, tokens, cfg)
    targets = tokens[1:]
    lp = jax.nn.log_softmax(logits[:-1], axis=-1)
    return -jnp.take_along_axis(lp, targets[:, None], axis=-1).mean()


def batch_loss(params, batch, cfg):
    """Mean loss over a (B, seq) batch of token sequences."""
    return jax.vmap(lambda t: loss_fn(params, t, cfg))(batch).mean()


# ---------------------------------------------------------------------
# Fused AdamW train step (AOT entrypoint)
# ---------------------------------------------------------------------

def init_opt_state(params):
    zeros = jax.tree.map(jnp.zeros_like, params)
    return {"m": zeros, "v": jax.tree.map(jnp.zeros_like, params),
            "t": jnp.zeros((), jnp.float32)}


def train_step(params, opt_state, batch, lr, cfg,
               beta1=0.9, beta2=0.999, eps=1e-8, weight_decay=0.01):
    """One fused AdamW step on a (B, seq) batch; returns
    (new_params, new_opt_state, loss). This whole function is one HLO
    artifact — Python never runs at training time."""
    loss, grads = jax.value_and_grad(batch_loss)(params, batch, cfg)
    t = opt_state["t"] + 1.0
    b1t = 1.0 - beta1 ** t
    b2t = 1.0 - beta2 ** t

    def upd(p, g, m, v):
        m2 = beta1 * m + (1 - beta1) * g
        v2 = beta2 * v + (1 - beta2) * g * g
        step = lr * ((m2 / b1t) / (jnp.sqrt(v2 / b2t) + eps) + weight_decay * p)
        return p - step, m2, v2

    flat_p, tree = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(opt_state["m"])
    flat_v = jax.tree.leaves(opt_state["v"])
    new_p, new_m, new_v = [], [], []
    for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v):
        if jnp.issubdtype(p.dtype, jnp.floating):
            p2, m2, v2 = upd(p, g, m, v)
        else:
            p2, m2, v2 = p, m, v
        new_p.append(p2)
        new_m.append(m2)
        new_v.append(v2)
    params2 = jax.tree.unflatten(tree, new_p)
    opt2 = {"m": jax.tree.unflatten(tree, new_m),
            "v": jax.tree.unflatten(tree, new_v), "t": t}
    return params2, opt2, loss


# ---------------------------------------------------------------------
# AOT-friendly flattened entrypoints
# ---------------------------------------------------------------------

def make_entrypoints(cfg):
    """Build (fn, example_args) pairs for AOT lowering. Parameters are
    flattened into a positional list so the Rust side can feed plain
    buffers in a documented order."""
    key = jax.random.PRNGKey(0)
    params = init_params(key, cfg)
    flat, tree = jax.tree.flatten(params)
    opt = init_opt_state(params)
    opt_flat, opt_tree = jax.tree.flatten(opt)

    seq = cfg["max_seq"]
    tokens = jnp.zeros((seq,), jnp.int32)
    batch = jnp.zeros((4, seq), jnp.int32)
    lr = jnp.float32(1e-3)

    def fwd_flat(*args):
        p = jax.tree.unflatten(tree, args[:-1])
        return (forward(p, args[-1], cfg),)

    def loss_flat(*args):
        p = jax.tree.unflatten(tree, args[:-1])
        return (loss_fn(p, args[-1], cfg),)

    n_p = len(flat)
    n_o = len(opt_flat)

    def train_flat(*args):
        p = jax.tree.unflatten(tree, args[:n_p])
        o = jax.tree.unflatten(opt_tree, args[n_p:n_p + n_o])
        b = args[n_p + n_o]
        lr_ = args[n_p + n_o + 1]
        p2, o2, loss = train_step(p, o, b, lr_, cfg)
        return tuple(jax.tree.leaves(p2)) + tuple(jax.tree.leaves(o2)) + (loss,)

    return {
        "forward": (fwd_flat, tuple(flat) + (tokens,)),
        "loss": (loss_flat, tuple(flat) + (tokens,)),
        "train_step": (train_flat, tuple(flat) + tuple(opt_flat) + (batch, lr)),
    }, params, tree


@functools.lru_cache(maxsize=None)
def param_order_doc():
    """Human-readable note on the flattened parameter order (jax tree
    order: dict keys sorted alphabetically, lists in order)."""
    return ("jax.tree.flatten order: blocks[0..L-1] "
            "(fc1, fc2, ln1, ln2, wo, wqkv — each dict alphabetical), "
            "then head, ln_f, pos_embed, tok_embed")
