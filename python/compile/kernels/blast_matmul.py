"""L1 — Pallas kernel for the BLAST matmul (Algorithm 1).

The kernel expresses the paper's three-stage product with the TPU mapping
described in DESIGN.md §Hardware-Adaptation:

* grid over output block rows ``i`` (one grid step per block row);
* stage 1 (``z_j = X_j V_j``) is hoisted OUT of the grid into a batched
  contraction computed once — the "computed once and shared" property the
  paper gets from ``torch.bmm``;
* each grid step loads only ``U_i`` (p×r tile) and the coupling row
  ``S[i]`` into VMEM, couples the resident ``z`` intermediate on the VPU
  (elementwise multiply + reduce over j — no gathers, no zero padding),
  and hits the MXU with a dense ``(B,r)×(r,p)`` tile.

Per-grid-step VMEM footprint: ``B·r + p·r + b·r`` floats — versus
``B·n + p·n`` for a dense row tile — which is the memory-traffic
reduction the paper exploits on A100 (Table 4 is bandwidth-bound).

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; numerics are validated against ``ref.py`` by pytest and
the same HLO runs under the Rust PJRT client.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _stage1_kernel(x_ref, v_ref, z_ref):
    """Stage 1: z_j = X_j @ V_j for one block column j (grid over j)."""
    z_ref[...] = x_ref[...] @ v_ref[...]


def _stage23_kernel(z_ref, s_ref, u_ref, y_ref):
    """Stages 2+3 for one output block row i (grid over i).

    z: (b, B, r) resident intermediate; s: (b, r) coupling row S[i];
    u: (p, r) left factor U_i; y: (B, p) output slice.
    """
    z = z_ref[...]            # (b, B, r)
    s = s_ref[...]            # (b, r)
    # Stage 2 (VPU): w = sum_j s[j] * z[j]  -> (B, r).
    w = jnp.sum(z * s[:, None, :], axis=0)
    # Stage 3 (MXU): y = w @ U_i^T -> (B, p).
    y_ref[...] = w @ u_ref[...].T


@jax.custom_vjp
def blast_matmul(x, u, v, s):
    """Y = X @ A^T with A in BLAST form (Algorithm 1), via two
    pallas_calls.

    Shapes: x (B, n=b*q), u (b, p, r), v (b, q, r), s (b, b, r);
    returns (B, m=b*p).

    Forward runs the Pallas kernel; the VJP re-derives the three-stage
    dataflow in einsum form (Pallas interpret-mode has no reverse-mode
    rule), which is algebraically the same computation — validated by
    ``test_kernel_grad_flows``.
    """
    return _blast_matmul_impl(x, u, v, s)


@functools.partial(jax.jit, static_argnames=("interpret",))
def _blast_matmul_impl(x, u, v, s, interpret=True):
    b, p, r = u.shape
    _, q, _ = v.shape
    batch = x.shape[0]
    assert x.shape[1] == b * q, f"x cols {x.shape[1]} != b*q {b * q}"

    xb = x.reshape(batch, b, q).transpose(1, 0, 2)  # (b, B, q)

    # Stage 1: grid over block columns; each step is a dense (B,q)x(q,r)
    # MXU tile. z has shape (b, B, r).
    z = pl.pallas_call(
        _stage1_kernel,
        grid=(b,),
        in_specs=[
            pl.BlockSpec((None, batch, q), lambda j: (j, 0, 0)),
            pl.BlockSpec((None, q, r), lambda j: (j, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, batch, r), lambda j: (j, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, batch, r), x.dtype),
        interpret=interpret,
    )(xb, v)

    # Stages 2+3: grid over block rows; z stays VMEM-resident across the
    # coupling reduction, U_i streams in per step.
    y = pl.pallas_call(
        _stage23_kernel,
        grid=(b,),
        in_specs=[
            pl.BlockSpec((b, batch, r), lambda i: (0, 0, 0)),
            pl.BlockSpec((None, b, r), lambda i: (i, 0, 0)),
            pl.BlockSpec((None, p, r), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((batch, None, p), lambda i: (0, i, 0)),
        out_shape=jax.ShapeDtypeStruct((batch, b, p), x.dtype),
        interpret=interpret,
    )(z, s, u)

    return y.reshape(batch, b * p)


def _blast_fwd(x, u, v, s):
    y = _blast_matmul_impl(x, u, v, s)
    return y, (x, u, v, s)


def _blast_bwd(res, dy):
    """Backward through the three stages (einsum form):
    z_j = X_j V_j ; w_i = Σ_j s_{ij} ⊙ z_j ; y_i = w_i U_i^T."""
    import jax.numpy as jnp
    x, u, v, s = res
    b, p, r = u.shape
    q = v.shape[1]
    batch = x.shape[0]
    xb = x.reshape(batch, b, q)
    z = jnp.einsum("Bjq,jqr->Bjr", xb, v)
    w = jnp.einsum("Bjr,ijr->Bir", z, s)
    dyb = dy.reshape(batch, b, p)
    # y_i = w_i U_i^T -> dU_i = dy_i^T w_i ; dw_i = dy_i U_i.
    du = jnp.einsum("Bip,Bir->ipr", dyb, w)
    dw = jnp.einsum("Bip,ipr->Bir", dyb, u)
    # w_i = Σ_j s_{ij} ⊙ z_j -> ds_{ij} = Σ_B dw_i ⊙ z_j ; dz_j = Σ_i dw_i ⊙ s_{ij}.
    ds = jnp.einsum("Bir,Bjr->ijr", dw, z)
    dz = jnp.einsum("Bir,ijr->Bjr", dw, s)
    # z_j = X_j V_j -> dV_j = X_j^T dz_j ; dX_j = dz_j V_j^T.
    dv = jnp.einsum("Bjq,Bjr->jqr", xb, dz)
    dx = jnp.einsum("Bjr,jqr->Bjq", dz, v).reshape(batch, b * q)
    return dx, du, dv, ds


blast_matmul.defvjp(_blast_fwd, _blast_bwd)


def vmem_footprint_bytes(batch, b, p, q, r, dtype_bytes=4):
    """Per-grid-step VMEM estimate for the stage-2/3 kernel (see module
    docstring): the resident z (b·B·r), one coupling row (b·r), one left
    factor tile (p·r), and the output slice (B·p)."""
    floats = b * batch * r + b * r + p * r + batch * p
    return floats * dtype_bytes


def mxu_utilization_estimate(batch, b, p, q, r):
    """Fraction of kernel FLOPs that land on the MXU as dense tiles
    (stages 1 and 3) versus the VPU coupling (stage 2). The paper's
    efficiency claim rests on the MXU share dominating."""
    mxu = b * (batch * q * r) + b * (batch * r * p)  # stages 1+3
    vpu = b * b * batch * r                          # stage 2
    return mxu / (mxu + vpu)
