"""Pure-jnp correctness oracle for the BLAST matmul (Algorithm 1).

Two reference implementations:
* ``blast_dense``: materialize the dense matrix from the factors
  (Eq. 2 block assembly) and multiply — the ground truth.
* ``blast_matmul_ref``: the einsum form of Algorithm 1 (stage 1 batched
  right-factor product, stage 2 diagonal coupling + aggregation, stage 3
  batched left-factor product), used to check the Pallas kernel stage by
  stage.

Conventions (matching the Rust side and Appendix A's ``blast_matmul``):
  U: (b, p, r)   left factors, block row i
  V: (b, q, r)   right factors, block column j
  S: (b, b, r)   couplings, S[i, j] = s_{i,j}
  X: (B, n)      activations, n = b*q; output Y: (B, m), m = b*p
and the layer computes ``Y = X @ A.T`` (PyTorch Linear convention).
"""

import jax.numpy as jnp


def blast_dense(u, v, s):
    """Assemble the dense (m, n) matrix: A[i,j] = U_i diag(s_ij) V_j^T."""
    b, p, r = u.shape
    _, q, _ = v.shape
    # blocks[i, j] = U_i @ diag(S[i,j]) @ V_j^T  -> (b, b, p, q)
    blocks = jnp.einsum("ipr,ijr,jqr->ijpq", u, s, v)
    # Stitch into (m, n).
    return blocks.transpose(0, 2, 1, 3).reshape(b * p, b * q)


def blast_matmul_ref(x, u, v, s):
    """Algorithm 1 as einsums: Y = X @ A^T for X of shape (B, n)."""
    b, p, r = u.shape
    _, q, _ = v.shape
    batch = x.shape[0]
    xb = x.reshape(batch, b, q)  # split columns into block chunks
    # Stage 1: z[j] = X_j @ V_j  -> (B, b, r); shared across block rows.
    z = jnp.einsum("Bjq,jqr->Bjr", xb, v)
    # Stage 2: w[i] = sum_j s_{i,j} * z[j]  -> (B, b, r).
    w = jnp.einsum("Bjr,ijr->Bir", z, s)
    # Stage 3: y[i] = w[i] @ U_i^T -> (B, b, p) -> (B, m).
    y = jnp.einsum("Bir,ipr->Bip", w, u)
    return y.reshape(batch, b * p)


def blast_matvec_flops(m, n, b, r):
    """Multiplication count of Algorithm 1 (paper §2): (m + n + b²)·r."""
    return (m + n + b * b) * r


def blast_num_params(m, n, b, r):
    """Parameter count (paper §2): r(m+n) + r·b²."""
    return r * (m + n) + r * b * b
