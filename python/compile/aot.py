"""AOT export: lower the L2 model (with the L1 Pallas kernel inlined) to
HLO **text** artifacts the Rust PJRT runtime loads.

HLO text — NOT ``.serialize()`` — is the interchange format: jax >= 0.5
emits HloModuleProto with 64-bit instruction ids which xla_extension
0.5.1 rejects; the text parser reassigns ids and round-trips cleanly.

Outputs (per configured variant) under ``--out-dir``:
  <name>.hlo.txt          the lowered module
  <name>.params.bmx       initial parameters in the shared BMX1 format
  manifest.json           shapes + flattened-argument order for Rust

Run once at build time: ``make artifacts``. Python never runs on the
request path.
"""

import argparse
import json
import os
import struct
import sys

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model, structures
from .kernels.blast_matmul import (mxu_utilization_estimate,
                                   vmem_footprint_bytes)


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def write_bmx(path, named_arrays):
    """Write the Rust `TensorBundle` BMX1 format (name -> 2D f32)."""
    with open(path, "wb") as f:
        f.write(b"BMX1")
        f.write(struct.pack("<I", len(named_arrays)))
        for name, arr in named_arrays.items():
            a = np.asarray(arr, dtype=np.float32)
            if a.ndim == 1:
                a = a.reshape(1, -1)
            elif a.ndim != 2:
                a = a.reshape(a.shape[0], -1)
            nb = name.encode()
            f.write(struct.pack("<I", len(nb)))
            f.write(nb)
            f.write(struct.pack("<II", a.shape[0], a.shape[1]))
            f.write(a.tobytes())


# The artifact catalogue: one TinyLM per structure, matched roughly in
# parameter budget (see DESIGN.md §5). Keep shapes small — every variant
# lowers 3 entrypoints.
VARIANTS = {
    "tinylm_dense": model.make_config(structure=("dense",)),
    "tinylm_blast": model.make_config(structure=("blast", 4, 8)),
    "tinylm_lowrank": model.make_config(structure=("lowrank", 12)),
    "tinylm_monarch": model.make_config(structure=("monarch", 4, 3)),
    "tinylm_blockdiag": model.make_config(structure=("blockdiag", 4, 12)),
}


def flatten_name(path):
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return ".".join(parts)


def export_variant(name, cfg, out_dir, entries):
    eps, params, _tree = model.make_entrypoints(cfg)
    flat_with_paths, _ = jax.tree_util.tree_flatten_with_path(params)
    param_names = [flatten_name(path) for path, _ in flat_with_paths]

    # Parameters bundle (shared across entrypoints).
    named = {n: v for n, v in zip(param_names, [v for _, v in flat_with_paths])}
    write_bmx(os.path.join(out_dir, f"{name}.params.bmx"), named)

    for ep_name, (fn, example_args) in eps.items():
        lowered = jax.jit(fn).lower(*example_args)
        text = to_hlo_text(lowered)
        fname = f"{name}.{ep_name}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        entries.append({
            "name": f"{name}.{ep_name}",
            "variant": name,
            "entrypoint": ep_name,
            "file": fname,
            "params_file": f"{name}.params.bmx",
            "param_names": param_names,
            "arg_shapes": [list(np.shape(a)) for a in example_args],
            "arg_dtypes": [str(np.asarray(a).dtype) for a in example_args],
            "num_outputs": len(fn(*example_args)),
            "config": {k: (list(v) if isinstance(v, tuple) else v)
                       for k, v in cfg.items()},
        })
        print(f"  wrote {fname} ({len(text)} chars)")


def kernel_analysis():
    """§Perf L1 structural analysis (DESIGN.md §8): VMEM footprint and
    MXU-share estimates for representative shapes."""
    rows = []
    for (batch, b, p, q, r) in [(32, 4, 16, 16, 8), (8, 16, 256, 256, 992),
                                (1, 16, 256, 256, 992), (8, 2, 2048, 2048, 1024)]:
        rows.append({
            "batch": batch, "b": b, "p": p, "q": q, "r": r,
            "vmem_bytes": vmem_footprint_bytes(batch, b, p, q, r),
            "mxu_share": round(mxu_utilization_estimate(batch, b, p, q, r), 6),
        })
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--variants", default="tinylm_dense,tinylm_blast")
    ap.add_argument("--analyze", action="store_true")
    args = ap.parse_args()

    os.makedirs(args.out_dir, exist_ok=True)
    entries = []
    wanted = [v for v in args.variants.split(",") if v]
    for name in wanted:
        if name not in VARIANTS:
            print(f"unknown variant {name}; have {list(VARIANTS)}", file=sys.stderr)
            sys.exit(1)
        print(f"lowering {name} ...")
        export_variant(name, VARIANTS[name], args.out_dir, entries)

    manifest = {
        "format": 1,
        "interchange": "hlo-text",
        "artifacts": entries,
        "kernel_analysis": kernel_analysis(),
    }
    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"manifest: {len(entries)} artifacts")


if __name__ == "__main__":
    main()
