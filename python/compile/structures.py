"""Structured linear layers in JAX: init + apply for the five weight
families the paper evaluates (dense, low-rank, Monarch, block-diagonal,
BLAST). Mirrors the Rust `nn::linear` module; the BLAST apply routes
through the Pallas kernel so the whole model lowers into one HLO.
"""

import jax
import jax.numpy as jnp

from .kernels.blast_matmul import blast_matmul
from .kernels import ref


def init_dense(key, out_dim, in_dim, std=0.02):
    return {"w": jax.random.normal(key, (out_dim, in_dim)) * std}


def init_low_rank(key, out_dim, in_dim, r, std=0.02):
    k1, k2 = jax.random.split(key)
    return {
        "p": jax.random.normal(k1, (out_dim, r)) * std,
        "q": jax.random.normal(k2, (in_dim, r)) * std,
    }


def init_blast(key, out_dim, in_dim, b, r, std=0.02):
    """Appendix C.2 init: U,V ~ N(0, std), s ~ Unif(0, 2)."""
    assert out_dim % b == 0 and in_dim % b == 0
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "u": jax.random.normal(k1, (b, out_dim // b, r)) * std,
        "v": jax.random.normal(k2, (b, in_dim // b, r)) * std,
        "s": jax.random.uniform(k3, (b, b, r), minval=0.0, maxval=2.0),
    }


def init_monarch(key, out_dim, in_dim, b, t, std=0.02):
    assert out_dim % b == 0 and in_dim % b == 0
    k1, k2 = jax.random.split(key)
    return {
        # Shared right bases per block column: (b, t, q).
        "rb": jax.random.normal(k1, (b, t, in_dim // b)) * std,
        # Couplings: (b, b, p, t) indexed [i, j].
        "l": jax.random.normal(k2, (b, b, out_dim // b, t)) * std,
    }


def init_block_diag(key, out_dim, in_dim, b, t, std=0.02):
    assert out_dim % b == 0 and in_dim % b == 0
    k1, k2 = jax.random.split(key)
    return {
        "pd": jax.random.normal(k1, (b, out_dim // b, t)) * std,
        "qd": jax.random.normal(k2, (b, in_dim // b, t)) * std,
    }


def apply_linear(params, x, use_pallas=True):
    """y = x @ W^T for any structure. x: (tokens, in_dim)."""
    kind = structure_kind(params)
    if kind == "dense":
        return x @ params["w"].T
    if kind == "lowrank":
        return (x @ params["q"]) @ params["p"].T
    if kind == "blast":
        if use_pallas:
            return blast_matmul(x, params["u"], params["v"], params["s"])
        return ref.blast_matmul_ref(x, params["u"], params["v"], params["s"])
    if kind == "monarch":
        b, t, q = params["rb"].shape
        batch = x.shape[0]
        xb = x.reshape(batch, b, q)
        # z[j] = X_j @ R_j^T -> (B, b, t)
        z = jnp.einsum("Bjq,jtq->Bjt", xb, params["rb"])
        # y[i] = sum_j z[j] @ L_ij^T -> (B, b, p)
        y = jnp.einsum("Bjt,ijpt->Bip", z, params["l"])
        return y.reshape(batch, -1)
    if kind == "blockdiag":
        b, p, t = params["pd"].shape
        q = params["qd"].shape[1]
        batch = x.shape[0]
        xb = x.reshape(batch, b, q)
        z = jnp.einsum("Biq,iqt->Bit", xb, params["qd"])
        y = jnp.einsum("Bit,ipt->Bip", z, params["pd"])
        return y.reshape(batch, -1)
    raise ValueError(f"unknown structure kind {kind}")


def structure_kind(params):
    """Infer the structure family from the parameter keys (the pytree
    holds only arrays, so AOT flattening and optimizers stay clean)."""
    keys = set(params)
    if "w" in keys:
        return "dense"
    if {"p", "q"} <= keys:
        return "lowrank"
    if {"u", "v", "s"} <= keys:
        return "blast"
    if {"rb", "l"} <= keys:
        return "monarch"
    if {"pd", "qd"} <= keys:
        return "blockdiag"
    raise ValueError(f"unrecognized structure keys {sorted(keys)}")


def num_params(params):
    return sum(int(v.size) for v in params.values())


def to_dense(params):
    """Dense (out, in) reconstruction of any structure."""
    kind = structure_kind(params)
    if kind == "dense":
        return params["w"]
    if kind == "lowrank":
        return params["p"] @ params["q"].T
    if kind == "blast":
        return ref.blast_dense(params["u"], params["v"], params["s"])
    if kind == "monarch":
        b, _, q = params["rb"].shape
        p = params["l"].shape[2]
        blocks = jnp.einsum("ijpt,jtq->ijpq", params["l"], params["rb"])
        return blocks.transpose(0, 2, 1, 3).reshape(b * p, b * q)
    if kind == "blockdiag":
        b, p, _ = params["pd"].shape
        q = params["qd"].shape[1]
        blocks = jnp.einsum("ipt,iqt->ipq", params["pd"], params["qd"])
        out = jnp.zeros((b * p, b * q))
        for i in range(b):
            out = out.at[i * p:(i + 1) * p, i * q:(i + 1) * q].set(blocks[i])
        return out
    raise ValueError(kind)
