"""JAX mirror of Algorithm 2 (preconditioned BLAST factorization).

Cross-validates the Rust implementation: same update equations (Eqs. 5-9),
same delta schedule (Eq. 19), same linearly-decaying step size. Used only
by tests — the production compression path is the Rust `factorize` module.
"""

import jax
import jax.numpy as jnp

from .kernels import ref


def blast_loss(a, u, v, s):
    """Eq. 4: 0.5 * ||A - BLAST(u, v, s)||_F^2."""
    return 0.5 * jnp.sum((a - ref.blast_dense(u, v, s)) ** 2)


def _init(key, m, n, b, r, eps=1e-2):
    k1, k2, k3 = jax.random.split(key, 3)
    u = jax.random.normal(k1, (b, m // b, r)) * eps
    v = jax.random.normal(k2, (b, n // b, r)) * eps
    s = jax.random.uniform(k3, (b, b, r))
    return u, v, s


def _v_bar(v, s, i):
    """V̄_i: (n, r) concatenation of S_{i,j} V_j^T blocks."""
    b, q, r = v.shape
    return (v * s[i][:, None, :]).reshape(b * q, r)


def _u_bar(u, s, j):
    b, p, r = u.shape
    return (u * s[:, j][:, None, :]).reshape(b * p, r)


def factorize_gd(a, b, r, iters, seed=0):
    """Plain alternating GD (Eqs. 5-7) with sigma_max step sizes."""
    m, n = a.shape
    u, v, s = _init(jax.random.PRNGKey(seed), m, n, b, r)
    p, q = m // b, n // b
    trace = []
    for k in range(iters):
        eta = 1.0 - k / iters
        # U sweep.
        for i in range(b):
            vb = _v_bar(v, s, i)
            lip = jnp.linalg.norm(vb.T @ vb, 2)
            resid = u[i] @ vb.T - a[i * p:(i + 1) * p]
            u = u.at[i].add(-(eta / jnp.maximum(lip, 1e-12)) * (resid @ vb))
        # V sweep.
        for j in range(b):
            ub = _u_bar(u, s, j)
            lip = jnp.linalg.norm(ub.T @ ub, 2)
            resid = ub @ v[j].T - a[:, j * q:(j + 1) * q]
            v = v.at[j].add(-(eta / jnp.maximum(lip, 1e-12)) * (resid.T @ ub))
        # s sweep.
        for i in range(b):
            for j in range(b):
                w = (u[i].T @ u[i]) * (v[j].T @ v[j])
                lip = jnp.linalg.norm(w, 2)
                rhs = jnp.diag(u[i].T @ a[i * p:(i + 1) * p, j * q:(j + 1) * q] @ v[j])
                g = w @ s[i, j] - rhs
                s = s.at[i, j].add(-(eta / jnp.maximum(lip, 1e-12)) * g)
        trace.append(float(blast_loss(a, u, v, s)))
    return u, v, s, trace


def factorize_precgd(a, b, r, iters, seed=0, delta0=0.1):
    """Algorithm 2: preconditioned updates (Eqs. 8-9, delta per Eq. 19)."""
    m, n = a.shape
    u, v, s = _init(jax.random.PRNGKey(seed), m, n, b, r)
    p, q = m // b, n // b
    eye = jnp.eye(r)
    trace = []
    for k in range(iters):
        eta = 1.0 - k / iters
        delta = delta0 * jnp.sqrt(blast_loss(a, u, v, s)) + 1e-10
        for i in range(b):
            vb = _v_bar(v, s, i)
            grad = (u[i] @ vb.T - a[i * p:(i + 1) * p]) @ vb
            pre = jnp.linalg.solve(vb.T @ vb + delta * eye, grad.T).T
            u = u.at[i].add(-eta * pre)
        for j in range(b):
            ub = _u_bar(u, s, j)
            grad = (ub @ v[j].T - a[:, j * q:(j + 1) * q]).T @ ub
            pre = jnp.linalg.solve(ub.T @ ub + delta * eye, grad.T).T
            v = v.at[j].add(-eta * pre)
        for i in range(b):
            for j in range(b):
                w = (u[i].T @ u[i]) * (v[j].T @ v[j])
                rhs = jnp.diag(u[i].T @ a[i * p:(i + 1) * p, j * q:(j + 1) * q] @ v[j])
                g = w @ s[i, j] - rhs
                s = s.at[i, j].add(-eta * jnp.linalg.solve(w + delta * eye, g))
        trace.append(float(blast_loss(a, u, v, s)))
    return u, v, s, trace
