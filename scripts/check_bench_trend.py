#!/usr/bin/env python3
"""Bench-trend gate: compare the current BENCH_*.json files against the
previous CI run's artifacts and fail on a >20% throughput regression.

Usage: check_bench_trend.py [--prev DIR] [--curr DIR]

* Missing previous artifacts (first run, expired retention) just set the
  baseline — never a failure.
* Under BLAST_BENCH_FAST=1 (the CI smoke setting: tiny workloads on noisy
  shared runners) regressions are reported but warn-only, matching the
  in-bench gate policy.
"""

import json
import os
import sys

# (file, path-into-json, human label) — higher is better for all of them.
METRICS = [
    ("BENCH_serving.json", ("continuous", "tokens_per_sec"), "serving tokens/sec"),
    # Shared-system-prompt scenario through the paged KV block manager:
    # throughput with the radix prefix cache absorbing the shared span.
    ("BENCH_serving.json", ("prefix", "tokens_per_sec"), "prefix-cache serving tokens/sec"),
    # Self-speculative decoding scenario: draft proposals + one batched
    # multi-token verify per step. Throughput regression here means the
    # verify batching or the rollback path got slower.
    ("BENCH_serving.json", ("spec", "tokens_per_sec_spec"), "speculative serving tokens/sec"),
    ("BENCH_factorize.json", ("precgd", "iters_per_sec"), "factorize PrecGD iters/sec"),
    ("BENCH_kernels.json", ("dense", "autotuned_gflops"), "dense GEMM GFLOP/s"),
    # Per-structure plan-path throughput (the structure-plan execution
    # layer: every weight structure lowered to packed microkernel stages).
    ("BENCH_kernels.json", ("structures", "lowrank", "plan_gflops"), "lowrank plan GFLOP/s"),
    ("BENCH_kernels.json", ("structures", "monarch", "plan_gflops"), "monarch plan GFLOP/s"),
    ("BENCH_kernels.json", ("structures", "blockdiag", "plan_gflops"), "blockdiag plan GFLOP/s"),
    # Int8 quantized packed path (weight-only; activations quantize on
    # the fly). The dense/blast shapes are the bench's ≥1.5× gate pair.
    ("BENCH_kernels.json", ("quantized", "dense", "i8_gflops"), "int8 dense GFLOP/s"),
    ("BENCH_kernels.json", ("quantized", "blast", "i8_gflops"), "int8 blast GFLOP/s"),
]
THRESHOLD = 0.20

# Int8 pack footprint in bytes per weight (values + per-row scales +
# tile padding). Lower is better, and it is layout-determined rather
# than timing-determined, so growth is warn-only: a jump means the
# panel layout or scale storage changed, not that a runner was noisy.
BYTES_PER_WEIGHT = [
    ("BENCH_kernels.json", ("quantized", "dense", "bytes_per_weight"), "int8 dense bytes/weight"),
    ("BENCH_kernels.json", ("quantized", "blast", "bytes_per_weight"), "int8 blast bytes/weight"),
]
BYTES_GROWTH_THRESHOLD = 0.10

# Observability ratios carried in the benches' "obs" snapshot section.
# Compared as absolute deltas (they're already in [0, 1]) and always
# warn-only: cache behaviour on tiny smoke workloads is advisory, but a
# large drop is an early smell of a pack-keying or eviction regression.
OBS_RATIOS = [
    ("BENCH_kernels.json", ("obs", "pack_cache", "hit_rate"), "kernels pack-cache hit rate"),
    ("BENCH_serving.json", ("obs", "pack_cache", "hit_rate"), "serving pack-cache hit rate"),
    # Fraction of prompt tokens served from cached KV blocks in the
    # shared-prefix scenario. A drop means requests are re-prefilling
    # spans the radix cache should absorb (eviction or keying bug).
    ("BENCH_serving.json", ("prefix", "hit_rate"), "serving prefix-cache hit rate"),
    # Fraction of draft proposals the target's verify pass accepted. A
    # drop means speculation is burning draft compute without committing
    # tokens (draft/target drift, or a verify/acceptance bug) — the
    # self-draft baseline should sit at 1.0.
    ("BENCH_serving.json", ("spec", "acceptance_rate"), "speculative acceptance rate"),
]
OBS_DROP_THRESHOLD = 0.10

# Robustness ratios from the overload scenario (burst past the pending
# bound into an undersized KV arena). Always warn-only and compared as
# absolute deltas: shed rate on a timing-sensitive burst is advisory,
# but a large *swing in either direction* is an early smell — up means
# admission got slower or the drain loop regressed, down means the
# bound stopped being enforced.
ROBUSTNESS_RATIOS = [
    ("BENCH_serving.json", ("overload", "shed_rate"), "overload shed rate"),
]
ROBUSTNESS_SWING_THRESHOLD = 0.25


def load_metric(path, keys):
    try:
        with open(path) as f:
            node = json.load(f)
        for k in keys:
            node = node[k]
        return float(node)
    except (OSError, KeyError, TypeError, ValueError):
        return None


def arg_value(flag, default):
    if flag in sys.argv:
        return sys.argv[sys.argv.index(flag) + 1]
    return default


def main():
    prev_dir = arg_value("--prev", "prev-bench")
    curr_dir = arg_value("--curr", ".")
    warn_only = os.environ.get("BLAST_BENCH_FAST") == "1"
    failures = []
    for fname, keys, label in METRICS:
        curr = load_metric(os.path.join(curr_dir, fname), keys)
        prev = load_metric(os.path.join(prev_dir, fname), keys)
        if curr is None:
            print(f"[trend] {label}: no current measurement in {fname} — skipped")
            continue
        if prev is None:
            print(f"[trend] {label}: no previous artifact — baseline set at {curr:.2f}")
            continue
        change = (curr - prev) / prev if prev > 0 else 0.0
        print(f"[trend] {label}: prev {prev:.2f} -> curr {curr:.2f} ({change:+.1%})")
        if change < -THRESHOLD:
            failures.append(
                f"{label} regressed {-change:.1%} (threshold {THRESHOLD:.0%})"
            )
    for fname, keys, label in BYTES_PER_WEIGHT:
        curr = load_metric(os.path.join(curr_dir, fname), keys)
        prev = load_metric(os.path.join(prev_dir, fname), keys)
        if curr is None or prev is None or prev <= 0:
            continue
        growth = (curr - prev) / prev
        print(f"[trend] {label}: prev {prev:.3f} -> curr {curr:.3f} ({growth:+.1%})")
        if growth > BYTES_GROWTH_THRESHOLD:
            print(
                f"[trend] WARNING: {label} grew {growth:.1%} "
                f"(> {BYTES_GROWTH_THRESHOLD:.0%}) — check panel/scale layout"
            )
    for fname, keys, label in OBS_RATIOS:
        curr = load_metric(os.path.join(curr_dir, fname), keys)
        prev = load_metric(os.path.join(prev_dir, fname), keys)
        if curr is None or prev is None:
            continue
        drop = prev - curr
        print(f"[trend] {label}: prev {prev:.3f} -> curr {curr:.3f}")
        if drop > OBS_DROP_THRESHOLD:
            print(
                f"[trend] WARNING: {label} dropped {drop:.2f} "
                f"(> {OBS_DROP_THRESHOLD:.2f} absolute) — check pack keying/eviction"
            )
    for fname, keys, label in ROBUSTNESS_RATIOS:
        curr = load_metric(os.path.join(curr_dir, fname), keys)
        prev = load_metric(os.path.join(prev_dir, fname), keys)
        if curr is None or prev is None:
            continue
        swing = curr - prev
        print(f"[trend] {label}: prev {prev:.3f} -> curr {curr:.3f}")
        if abs(swing) > ROBUSTNESS_SWING_THRESHOLD:
            print(
                f"[trend] WARNING: {label} swung {swing:+.2f} "
                f"(> {ROBUSTNESS_SWING_THRESHOLD:.2f} absolute) — check "
                f"admission drain/shed policy"
            )
    if failures:
        for f in failures:
            print(f"[trend] REGRESSION: {f}")
        if warn_only:
            print("[trend] BLAST_BENCH_FAST=1 — warn-only, job passes")
            return 0
        return 1
    print("[trend] OK — no regression beyond threshold")
    return 0


if __name__ == "__main__":
    sys.exit(main())
