//! Serving demo: start the L3 coordinator with dense + BLAST-compressed
//! variants, fire a batched request load from client threads, and report
//! latency/throughput per variant — the serving-system view of Table 4.
//!
//! Run: `cargo run --release --example serve`

use blast_repro::coordinator::{BatcherConfig, Coordinator, CoordinatorConfig};
use blast_repro::nn::attention::StructureKind;
use blast_repro::nn::gpt::{LmConfig, TinyLM};
use blast_repro::tensor::Rng;
use std::sync::Arc;
use std::time::Instant;

fn main() {
    let mut rng = Rng::new(7);
    let mut cfg = LmConfig::tiny(StructureKind::Dense);
    cfg.max_seq = 96;
    let dense = TinyLM::new(cfg, &mut rng);
    let mut cfg_b = LmConfig::tiny(StructureKind::Blast { b: 4, r: 10 });
    cfg_b.max_seq = 96;
    let blast = TinyLM::new(cfg_b, &mut rng);
    println!(
        "dense params: {}, blast params: {} ({:.0}% fewer)",
        dense.num_params(),
        blast.num_params(),
        100.0 * (1.0 - blast.num_params() as f64 / dense.num_params() as f64)
    );

    let coord = Arc::new(Coordinator::new(
        vec![("dense".into(), dense), ("blast".into(), blast)],
        CoordinatorConfig {
            batcher: BatcherConfig { max_batch: 8, ..Default::default() },
        },
    ));

    for variant in ["dense", "blast"] {
        let t0 = Instant::now();
        let n_clients = 4;
        let per_client = 8;
        let new_tokens = 48;
        let mut handles = Vec::new();
        for c in 0..n_clients {
            let coord = Arc::clone(&coord);
            let variant = variant.to_string();
            handles.push(std::thread::spawn(move || {
                let mut total_compute = std::time::Duration::ZERO;
                for i in 0..per_client {
                    let resp = coord
                        .generate(&variant, vec![1 + (c + i) % 8, 2, 3], new_tokens)
                        .expect("request");
                    total_compute += resp.compute_time;
                }
                total_compute
            }));
        }
        let mut compute = std::time::Duration::ZERO;
        for h in handles {
            compute += h.join().unwrap();
        }
        let wall = t0.elapsed();
        let tokens = n_clients * per_client * new_tokens;
        println!(
            "{variant:<6}: {tokens} tokens in {wall:?} wall ({:.0} tok/s), compute sum {compute:?}",
            tokens as f64 / wall.as_secs_f64()
        );
    }
    println!("\nmetrics: {}", coord.metrics.report());
}
