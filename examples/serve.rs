//! Serving demo: start the L3 coordinator with dense + BLAST-compressed
//! variants, fire a request load from client threads through the
//! continuous-batching workers, and report latency/throughput per
//! variant — the serving-system view of Table 4. Ends with a streaming
//! request consumed token by token.
//!
//! Run: `cargo run --release --example serve`

use blast_repro::coordinator::{
    BatcherConfig, Coordinator, CoordinatorConfig, EngineConfig, ResponseEvent,
};
use blast_repro::nn::attention::StructureKind;
use blast_repro::nn::gpt::{LmConfig, TinyLM};
use blast_repro::tensor::Rng;
use std::sync::Arc;
use std::time::Instant;

fn main() {
    let mut rng = Rng::new(7);
    let mut cfg = LmConfig::tiny(StructureKind::Dense);
    cfg.max_seq = 96;
    let dense = TinyLM::new(cfg, &mut rng);
    let mut cfg_b = LmConfig::tiny(StructureKind::Blast { b: 4, r: 10 });
    cfg_b.max_seq = 96;
    let blast = TinyLM::new(cfg_b, &mut rng);
    println!(
        "dense params: {}, blast params: {} ({:.0}% fewer)",
        dense.num_params(),
        blast.num_params(),
        100.0 * (1.0 - blast.num_params() as f64 / dense.num_params() as f64)
    );

    let coord = Arc::new(Coordinator::new(
        vec![("dense".into(), dense), ("blast".into(), blast)],
        CoordinatorConfig {
            batcher: BatcherConfig { max_batch: 8, ..Default::default() },
            engine: EngineConfig { max_seqs: 8, ..EngineConfig::global().clone() },
        },
    ));

    for variant in ["dense", "blast"] {
        let t0 = Instant::now();
        let n_clients = 4;
        let per_client = 8;
        let new_tokens = 48;
        let mut handles = Vec::new();
        for c in 0..n_clients {
            let coord = Arc::clone(&coord);
            let variant = variant.to_string();
            handles.push(std::thread::spawn(move || {
                // Per-request latencies: under continuous batching the
                // decode work is shared across live sequences, so
                // client-side TTFT / end-to-end are the meaningful
                // per-request numbers (a sum of compute_time would
                // count each batched iteration up to `max_seqs` times).
                let mut ttft_sum = std::time::Duration::ZERO;
                let mut e2e_sum = std::time::Duration::ZERO;
                for i in 0..per_client {
                    let resp = coord
                        .generate(&variant, vec![1 + (c + i) % 8, 2, 3], new_tokens)
                        .expect("request");
                    ttft_sum += resp.ttft.unwrap_or_default();
                    e2e_sum += resp.queue_time + resp.compute_time;
                }
                (ttft_sum, e2e_sum)
            }));
        }
        let mut ttft = std::time::Duration::ZERO;
        let mut e2e = std::time::Duration::ZERO;
        for h in handles {
            let (t, e) = h.join().unwrap();
            ttft += t;
            e2e += e;
        }
        let wall = t0.elapsed();
        let n_requests = (n_clients * per_client) as u32;
        let tokens = n_clients * per_client * new_tokens;
        println!(
            "{variant:<6}: {tokens} tokens in {wall:?} wall ({:.0} tok/s), \
             mean ttft {:?}, mean e2e {:?}",
            tokens as f64 / wall.as_secs_f64(),
            ttft / n_requests,
            e2e / n_requests,
        );
    }
    // Streaming API: consume tokens as they are sampled. stdout is
    // line-buffered, so flush per token to actually see the stream.
    use std::io::Write as _;
    let (_, handle) = coord.submit("blast", vec![1, 2, 3], 12).expect("submit");
    print!("streamed:");
    for ev in handle.events() {
        match ev {
            ResponseEvent::Token { token, .. } => {
                print!(" {token}");
                let _ = std::io::stdout().flush();
            }
            ResponseEvent::Done(resp) => {
                println!(
                    "  [done: {} tokens, ttft {:?}]",
                    resp.generated,
                    resp.ttft.unwrap_or_default()
                );
            }
        }
    }

    println!("\nmetrics: {}", coord.metrics.report());
}
