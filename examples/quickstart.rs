//! Quickstart: the BLAST matrix in five minutes.
//!
//! 1. Build a BLAST matrix and multiply with Algorithm 1.
//! 2. Show the special cases (low-rank / block-diagonal embeddings).
//! 3. Compress a synthetic weight with Algorithm 2 (PrecGD) and compare
//!    against the SVD low-rank baseline at the same parameter budget.
//!
//! Run: `cargo run --release --example quickstart`

use blast_repro::blast::{blast_rank_for_ratio, BlastMatrix};
use blast_repro::factorize::baselines::LowRankWeight;
use blast_repro::factorize::{factorize_precgd, PrecGdOptions};
use blast_repro::tensor::{matmul_nt, Rng};

fn main() {
    let mut rng = Rng::new(0);

    // --- 1. A BLAST matrix and Algorithm 1 ---------------------------
    let (m, n, b, r) = (64, 64, 4, 8);
    let a = BlastMatrix::random_init(m, n, b, r, 0.2, &mut rng);
    println!(
        "BLAST {m}x{n} (b={b}, r={r}): {} params vs {} dense ({:.1}% compression), \
         {} mults/matvec vs {}",
        a.num_params(),
        a.dense_params(),
        a.compression_ratio() * 100.0,
        a.matvec_flops(),
        m * n
    );
    let x: Vec<f32> = (0..n).map(|i| (i as f32 * 0.37).sin()).collect();
    let y = a.matvec(&x); // Algorithm 1: 3 stages, z shared across rows
    let y_dense = blast_repro::tensor::gemv(&a.to_dense(), &x);
    let err: f32 = y.iter().zip(&y_dense).map(|(p, q)| (p - q).abs()).sum();
    println!("Algorithm 1 vs dense reconstruction: total |err| = {err:.2e}");

    // --- 2. Special cases (paper §2 / §A.1) --------------------------
    let u = rng.gaussian_matrix(m, 4, 1.0);
    let v = rng.gaussian_matrix(n, 4, 1.0);
    let low_rank = matmul_nt(&u, &v);
    let embedded = BlastMatrix::from_low_rank(&u, &v, b);
    println!(
        "low-rank embedding exact? rel err = {:.2e}",
        embedded.to_dense().sub(&low_rank).fro_norm() / low_rank.fro_norm()
    );

    // --- 3. Compression: Algorithm 2 vs SVD --------------------------
    // A weight whose true structure is BLAST-like (heterogeneous block
    // ranks) — the setting where the paper's flexibility claim bites.
    let truth = BlastMatrix::random_init(m, n, b, 6, 0.3, &mut rng);
    let target = truth.to_dense();
    let ratio = 0.5;
    let r_fit = blast_rank_for_ratio(m, n, b, ratio).unwrap();
    let fit = factorize_precgd(
        &target,
        &PrecGdOptions { b, r: r_fit, iters: 120, ..Default::default() },
    );
    let r_lr = blast_repro::blast::lowrank_rank_for_ratio(m, n, ratio).unwrap();
    let lr = LowRankWeight::compress(&target, r_lr);
    let lr_err = lr.to_dense().sub(&target).fro_norm() as f64 / target.fro_norm() as f64;
    println!(
        "compress at {:.0}% budget: BLAST (Algorithm 2) rel err {:.4} vs SVD low-rank {:.4}",
        ratio * 100.0,
        fit.rel_error,
        lr_err
    );
    println!("=> BLAST adapts to the heterogeneous block structure; a global low-rank cannot.");
}
