//! End-to-end validation (DESIGN.md §6): train a transformer LM through
//! the full three-layer stack.
//!
//! * L1: the Pallas BLAST kernel is inlined in the lowered HLO
//!   (`tinylm_blast.*`);
//! * L2: the fused fwd+bwd+AdamW `train_step` was exported once by
//!   `make artifacts`;
//! * L3: this Rust driver streams synthetic-corpus batches through the
//!   PJRT executable for a few hundred steps, logs the loss curve, then
//!   runs generation through the serving coordinator.
//!
//! Python never runs here — only the artifacts do.
//!
//! Run: `make artifacts && cargo run --release --example train_e2e`

use blast_repro::coordinator::{Coordinator, CoordinatorConfig};
use blast_repro::data::corpus::SyntheticCorpus;
use blast_repro::nn::attention::StructureKind;
use blast_repro::nn::gpt::{LmConfig, TinyLM};
use blast_repro::runtime::executor::{load_params_ordered, TensorValue};
use blast_repro::runtime::{Manifest, PjrtEngine};
use blast_repro::tensor::Rng;

fn main() -> anyhow::Result<()> {
    let variant = std::env::args().nth(1).unwrap_or_else(|| "tinylm_blast".into());
    let steps: usize = std::env::args()
        .nth(2)
        .and_then(|s| s.parse().ok())
        .unwrap_or(300);

    let manifest = Manifest::load("artifacts")
        .map_err(|e| anyhow::anyhow!("{e}\nrun `make artifacts` first"))?;
    let entry = manifest.find(&format!("{variant}.train_step"))?;
    let mut engine = PjrtEngine::cpu()?;
    println!("PJRT platform: {}", engine.platform());
    let exe = engine.load(entry)?;
    println!(
        "loaded {} ({} args, {} outputs)",
        entry.name,
        entry.arg_shapes.len(),
        entry.num_outputs
    );

    // --- assemble the initial argument list in manifest order ---------
    let n_params = entry.param_names.len();
    let mut args: Vec<TensorValue> = load_params_ordered(entry)?;
    for i in 0..2 * n_params + 1 {
        // opt state zeros: m-leaves, scalar t, v-leaves (jax tree order)
        let shape = entry.arg_shapes[n_params + i].clone();
        let numel: usize = shape.iter().product::<usize>().max(1);
        args.push(TensorValue::F32 { shape, data: vec![0.0; numel] });
    }
    let batch_idx = 3 * n_params + 1;
    let batch_shape = entry.arg_shapes[batch_idx].clone();
    let (bsz, seq) = (batch_shape[0], batch_shape[1]);
    args.push(TensorValue::I32 {
        shape: batch_shape.clone(),
        data: vec![0; bsz * seq],
    });
    args.push(TensorValue::scalar_f32(3e-3)); // lr

    // --- data pipeline -------------------------------------------------
    let corpus = SyntheticCorpus::generate(64, 50_000, 2048);
    let data = corpus.train_dataset();
    let mut batcher = data.batcher(seq, 0);

    // --- training loop: all math inside the artifact -------------------
    println!("training {variant} for {steps} steps (batch {bsz} x seq {seq})...");
    let t0 = std::time::Instant::now();
    let mut first_loss = None;
    let mut loss_curve = Vec::new();
    for step in 0..steps {
        // Fresh batch.
        let mut flat = Vec::with_capacity(bsz * seq);
        for _ in 0..bsz {
            flat.extend(batcher.next_sequence().iter().map(|&t| t as i32));
        }
        args[batch_idx] = TensorValue::I32 { shape: batch_shape.clone(), data: flat };

        let out = exe.run(&args)?;
        let loss = out.last().unwrap().as_f32()?[0];
        if first_loss.is_none() {
            first_loss = Some(loss);
        }
        // Feed back params + optimizer state.
        for (i, v) in out.into_iter().enumerate().take(3 * n_params + 1) {
            args[i] = v;
        }
        if step % 25 == 0 || step + 1 == steps {
            println!("  step {step:>4}: loss {loss:.4}");
            loss_curve.push((step, loss));
        }
    }
    let train_time = t0.elapsed();
    let first = first_loss.unwrap();
    let last = loss_curve.last().unwrap().1;
    println!(
        "loss {first:.4} -> {last:.4} in {train_time:?} \
         ({:.1} steps/s; vocab-uniform baseline {:.4})",
        steps as f64 / train_time.as_secs_f64(),
        (64f32).ln()
    );
    anyhow::ensure!(last < first * 0.8, "training through PJRT did not learn");

    // --- serve the (independently trained) Rust-native model ----------
    // The artifact owns the trained parameters above; the coordinator
    // demo uses the native stack to show the L3 path composing.
    let mut rng = Rng::new(3);
    let mut lm = TinyLM::new(LmConfig::tiny(StructureKind::Blast { b: 4, r: 8 }), &mut rng);
    blast_repro::train::train_lm(
        &mut lm,
        &data,
        &blast_repro::train::LmTrainConfig { steps: 100, ..Default::default() },
    );
    let coord = Coordinator::new(vec![("blast".into(), lm)], CoordinatorConfig::default());
    let resp = coord.generate("blast", vec![1, 2, 3], 16)?;
    println!(
        "coordinator generation: {:?} (queue {:?}, compute {:?})",
        resp.tokens, resp.queue_time, resp.compute_time
    );
    println!("metrics: {}", coord.metrics.report());
    coord.shutdown();
    println!("E2E OK: data -> PJRT train_step (L1 Pallas + L2 JAX inside) -> serving (L3)");
    Ok(())
}
