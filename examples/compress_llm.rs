//! Full LLM compression pipeline (the Table 3 workflow end to end):
//! train dense TinyLM → compress at 50 % with BLAST (Algorithm 2) and
//! every baseline → evaluate perplexity + zero-shot → re-train → re-eval.
//!
//! Run: `cargo run --release --example compress_llm`

use blast_repro::data::corpus::SyntheticCorpus;
use blast_repro::data::zeroshot::build_suites;
use blast_repro::eval::{eval_suites, perplexity};
use blast_repro::factorize::{Compressor, Structure};
use blast_repro::nn::attention::StructureKind;
use blast_repro::nn::gpt::{LmConfig, TinyLM};
use blast_repro::tensor::Rng;
use blast_repro::train::{compress_lm, retrain_lm, train_lm, LmTrainConfig};

fn main() {
    let corpus = SyntheticCorpus::generate(64, 30_000, 2048);
    let suites = build_suites(&corpus, 25);

    println!("== stage 1: train the dense reference ==");
    let mut rng = Rng::new(0);
    let mut dense = TinyLM::new(LmConfig::tiny(StructureKind::Dense), &mut rng);
    train_lm(
        &mut dense,
        &corpus.train_dataset(),
        &LmTrainConfig { steps: 400, log_every: 100, ..Default::default() },
    );
    let ppl0 = perplexity(&dense, &corpus.valid_dataset(), 32, 12);
    let (_, acc0) = eval_suites(&dense, &suites);
    println!("dense: ppl {ppl0:.2}, avg 0-shot {acc0:.1}%  ({} params)", dense.num_params());

    println!("\n== stage 2: compress at 50% + re-train (0.49B-token analogue) ==");
    let comp = Compressor { blast_iters: 120, ..Default::default() };
    println!(
        "{:<24} {:>10} {:>10} {:>10} {:>12} {:>12}",
        "structure", "params", "ppl", "0-shot", "ppl(retr)", "0-shot(retr)"
    );
    for s in [
        Structure::LowRank,
        Structure::Monarch { b: 4 },
        Structure::BlockDiag { b: 4 },
        Structure::Blast { b: 4 },
    ] {
        let mut m = dense.clone();
        let report = compress_lm(&mut m, s, 0.5, &comp);
        let ppl = perplexity(&m, &corpus.valid_dataset(), 32, 12);
        let (_, acc) = eval_suites(&m, &suites);
        retrain_lm(&mut m, &corpus.train_dataset(), 150);
        let ppl_r = perplexity(&m, &corpus.valid_dataset(), 32, 12);
        let (_, acc_r) = eval_suites(&m, &suites);
        println!(
            "{:<24} {:>10} {:>10.2} {:>9.1}% {:>12.2} {:>11.1}%",
            report.structure, report.params_after, ppl, acc, ppl_r, acc_r
        );
    }
    println!("\npaper shape: BLAST keeps the lowest degradation; Monarch/Block-Diagonal collapse.");
}
