//! Bench: serving throughput — request-granularity sequential decode
//! (the pre-continuous-batching worker) vs iteration-level continuous
//! batching, under a Poisson-ish arrival process with mixed prompt and
//! output lengths, plus a prefix-cache scenario and an overload
//! scenario (burst past the pending bound into an undersized KV arena:
//! shed rate, preemptions, survivor TTFT). Reports tokens/sec and TTFT
//! and writes the machine-readable `BENCH_serving.json` so later PRs
//! can track the trajectory.
//!
//! Acceptance gate: continuous batching must reach ≥ 1.5× the
//! sequential tokens/sec at concurrency ≥ 4 on the tiny serving model.
//!
//! `BLAST_BENCH_FAST=1` shrinks the workload for CI smoke runs;
//! `BLAST_SERVING_BENCH_OUT` overrides the JSON output path.

use blast_repro::coordinator::{BatcherConfig, Coordinator, CoordinatorConfig, EngineConfig};
use blast_repro::nn::attention::StructureKind;
use blast_repro::nn::gpt::{argmax, LmConfig, TinyLM};
use blast_repro::tensor::Rng;
use blast_repro::util::json::{obj, Json};
use std::time::{Duration, Instant};

/// One request of the arrival trace.
struct Arrival {
    at: Duration,
    prompt: Vec<usize>,
    max_new: usize,
}

/// Poisson-ish trace: exponential inter-arrival gaps, mixed prompt
/// lengths (2..=8) and output lengths (new_tokens/2 ..= new_tokens).
fn build_workload(
    rng: &mut Rng,
    n: usize,
    mean_gap_us: f64,
    vocab: usize,
    new_tokens: usize,
) -> Vec<Arrival> {
    let mut t = 0.0f64;
    (0..n)
        .map(|_| {
            let u = f64::from(rng.uniform_range(0.0, 1.0)).clamp(1e-6, 1.0 - 1e-6);
            t += -mean_gap_us * (1.0 - u).ln();
            let plen = 2 + rng.below(7);
            Arrival {
                at: Duration::from_micros(t as u64),
                prompt: (0..plen).map(|_| rng.below(vocab)).collect(),
                max_new: new_tokens / 2 + rng.below(new_tokens / 2 + 1),
            }
        })
        .collect()
}

fn busy_wait_until(t0: Instant, deadline: Duration) {
    while t0.elapsed() < deadline {
        std::hint::spin_loop();
    }
}

/// The pre-refactor serving path: requests processed one at a time in
/// arrival order, each with a private KV cache (batched prefill, then
/// the per-token decode loop). Returns (tokens/sec, ttft samples,
/// total generated).
fn run_sequential(model: &TinyLM, workload: &[Arrival]) -> (f64, Vec<Duration>, usize) {
    let t0 = Instant::now();
    let mut ttfts = Vec::with_capacity(workload.len());
    let mut total = 0usize;
    for req in workload {
        busy_wait_until(t0, req.at);
        let mut kv = model.new_kv_cache();
        let mut tokens = req.prompt.clone();
        let mut logits = model.prefill(&req.prompt, &mut kv);
        let mut generated = 0usize;
        for _ in 0..req.max_new {
            let Some(l) = &logits else { break };
            let next = argmax(l.row(0));
            tokens.push(next);
            generated += 1;
            if generated == 1 {
                ttfts.push(t0.elapsed() - req.at);
            }
            let pos = tokens.len() - 1;
            if pos + 1 >= model.cfg.max_seq {
                break;
            }
            logits = Some(model.decode_step(next, pos, &mut kv));
        }
        total += generated;
    }
    (total as f64 / t0.elapsed().as_secs_f64(), ttfts, total)
}

fn coord_config(max_seqs: usize) -> CoordinatorConfig {
    CoordinatorConfig {
        batcher: BatcherConfig::default(),
        engine: EngineConfig { max_seqs, ..EngineConfig::global().clone() },
    }
}

/// The continuous-batching path: same trace submitted to a coordinator
/// with `max_seqs` concurrent sequences. The last tuple element is the
/// coordinator's serving-metrics snapshot (captured before shutdown),
/// embedded under `obs.serving` in the bench JSON.
fn run_continuous(
    model: TinyLM,
    workload: &[Arrival],
    max_seqs: usize,
) -> (f64, Vec<Duration>, usize, Json) {
    let coord = Coordinator::new(vec![("m".into(), model)], coord_config(max_seqs)).unwrap();
    // Warm the worker (pretune runs on its thread) before the clock.
    let _ = coord.generate("m", vec![1, 2, 3], 4).unwrap();
    let t0 = Instant::now();
    let mut handles = Vec::with_capacity(workload.len());
    for req in workload {
        busy_wait_until(t0, req.at);
        handles.push(coord.submit("m", req.prompt.clone(), req.max_new).unwrap().1);
    }
    let mut ttfts = Vec::new();
    let mut total = 0usize;
    for h in handles {
        let resp = h.recv().unwrap();
        total += resp.generated;
        if let Some(t) = resp.ttft {
            ttfts.push(t);
        }
    }
    let tps = total as f64 / t0.elapsed().as_secs_f64();
    // Diagnostic only: includes the one pre-clock warm-up request, so
    // its counts are the timed workload + 1 (the JSON uses the
    // client-side samples above, which exclude it).
    println!("continuous metrics (incl. 1 warm-up request): {}", coord.metrics.report());
    let serving = coord.metrics.snapshot_json();
    coord.shutdown();
    (tps, ttfts, total, serving)
}

/// Prefix-caching scenario: `n` requests sharing one long system
/// prompt with distinct short user tails, submitted through a
/// 4-sequence coordinator with churn (more requests than capacity).
/// Requests after the first hit the radix prefix cache and skip
/// prefill over the shared span. Returns (tokens/sec, hit_rate,
/// tokens_saved, kv_bytes_per_live_token) — hit_rate computed from the
/// engine-wide obs counter deltas across the run, so it reflects
/// exactly what the worker did.
fn run_prefix(
    model: TinyLM,
    n: usize,
    system_len: usize,
    new_tokens: usize,
) -> (f64, f64, u64, f64) {
    use blast_repro::obs::well_known as wk;
    let vocab = model.cfg.vocab;
    let coord = Coordinator::new(vec![("m".into(), model)], coord_config(4)).unwrap();
    // Warm the worker (pretune runs on its thread) before the clock.
    let _ = coord.generate("m", vec![1, 2, 3], 4).unwrap();
    let system: Vec<usize> = (0..system_len).map(|i| (i * 11 + 3) % vocab).collect();
    let hits0 = wk::kv_prefix_hit_tokens().get();
    let prefilled0 = wk::kv_prefilled_tokens().get();
    let t0 = Instant::now();
    let mut handles = Vec::with_capacity(n);
    for i in 0..n {
        let mut prompt = system.clone();
        prompt.extend([(i * 13 + 1) % vocab, (i * 7 + 2) % vocab]);
        handles.push(coord.submit("m", prompt, new_tokens).unwrap().1);
    }
    let mut total = 0usize;
    for h in handles {
        total += h.recv().unwrap().generated;
    }
    let tps = total as f64 / t0.elapsed().as_secs_f64();
    let hits = wk::kv_prefix_hit_tokens().get() - hits0;
    let prefilled = wk::kv_prefilled_tokens().get() - prefilled0;
    let hit_rate = hits as f64 / (hits + prefilled).max(1) as f64;
    // Gauge set by the manager at its live-token high-water mark: KV
    // arena bytes held per live token — sharing prefix blocks pulls
    // this below the private-cache cost.
    let bytes_per_tok = wk::kv_bytes_per_live_token().get();
    coord.shutdown();
    (tps, hit_rate, hits, bytes_per_tok)
}

/// Overload/robustness scenario: a no-gap burst far beyond the pending
/// bound into a deliberately undersized KV arena with preemption
/// enabled. This measures the fault-tolerance tier rather than raw
/// throughput: how much load was shed at admission, how often KV
/// pressure preempted an active sequence, and the TTFT of the requests
/// that survived. Every handle must terminate with `Done` or a typed
/// error — a hang here is the class of bug `tests/chaos.rs` guards.
fn run_overload(model: TinyLM, n: usize, new_tokens: usize) -> Json {
    use blast_repro::coordinator::ServeError;
    let max_pending = 8usize;
    let vocab = model.cfg.vocab;
    let mut engine = EngineConfig { max_seqs: 4, ..EngineConfig::global().clone() };
    engine.kv_block_size = 4;
    // ~2 worst-case sequences' worth of blocks (budget ≤ 8 for the
    // prompts below): admission starves while 4 slots are configured,
    // so the preemption path actually runs.
    engine.kv_total_blocks = Some(20);
    engine.max_pending = max_pending;
    engine.preempt_after = 2;
    let coord = Coordinator::new(
        vec![("m".into(), model)],
        CoordinatorConfig { batcher: BatcherConfig::default(), engine },
    )
    .unwrap();
    // Warm the worker (pretune runs on its thread) before the clock.
    let _ = coord.generate("m", vec![1, 2, 3], 4).unwrap();
    let t0 = Instant::now();
    let mut handles = Vec::with_capacity(n);
    for i in 0..n {
        let prompt: Vec<usize> =
            (0..(2 + i % 7)).map(|k| (i * 5 + k * 3 + 1) % vocab).collect();
        handles.push(coord.submit("m", prompt, new_tokens).unwrap().1);
    }
    let mut served = 0usize;
    let mut shed = 0usize;
    let mut ttfts = Vec::new();
    for h in handles {
        match h.recv() {
            Ok(resp) => {
                served += 1;
                if let Some(t) = resp.ttft {
                    ttfts.push(t);
                }
            }
            Err(ServeError::Overloaded { .. }) => shed += 1,
            Err(e) => panic!("unexpected serve error under overload: {e}"),
        }
    }
    let elapsed = t0.elapsed();
    let snap = coord.metrics.snapshot();
    coord.shutdown();
    let (ttft_mean, ttft_p95) = latency_stats_ms(&ttfts);
    let shed_rate = shed as f64 / n as f64;
    println!(
        "overload   : {served}/{n} served, {shed} shed ({:.1}%), {} preemptions, \
         survivor ttft mean {ttft_mean:.2}ms p95 {ttft_p95:.2}ms in {:.1}ms",
        shed_rate * 100.0,
        snap.preempted,
        elapsed.as_secs_f64() * 1e3
    );
    obj(vec![
        ("n_requests", Json::from(n)),
        ("max_pending", Json::from(max_pending)),
        ("requests_served", Json::from(served)),
        ("requests_shed", Json::from(shed)),
        ("shed_rate", Json::from(shed_rate)),
        ("preemptions", Json::from(snap.preempted as usize)),
        ("ttft_ms_mean_survivors", Json::from(ttft_mean)),
        ("ttft_ms_p95_survivors", Json::from(ttft_p95)),
    ])
}

/// Speculative-decoding scenario: one burst served twice through
/// otherwise-identical coordinators — speculation off, then
/// self-draft speculation at depth `gamma`. With a self-draft every
/// proposal matches the target argmax, so the upside is pure
/// batching: each verify commits up to γ+1 positions in one batched
/// weight pass instead of γ+1 weight-bound single-row decode steps
/// (the draft's proposal rows ride the same amortization). Acceptance
/// rate comes from the engine-wide obs counter deltas, so it reflects
/// exactly what the worker's verify loop did. Returns the JSON
/// section and the spec-vs-plain speedup for the gate.
fn run_spec(n: usize, new_tokens: usize, gamma: usize) -> (Json, f64) {
    use blast_repro::obs::well_known as wk;
    let mut rng = Rng::new(4245);
    let mut cfg = LmConfig::tiny(StructureKind::Blast { b: 4, r: 8 });
    cfg.max_seq = 96;
    let model = TinyLM::new(cfg, &mut rng);
    let vocab = model.cfg.vocab;
    let run = |g: usize| -> (f64, usize) {
        let mut engine = EngineConfig { max_seqs: 4, ..EngineConfig::global().clone() };
        engine.spec_gamma = g;
        engine.spec_draft = if g > 0 { Some("self".into()) } else { None };
        let coord = Coordinator::new(
            vec![("m".into(), model.clone())],
            CoordinatorConfig { batcher: BatcherConfig::default(), engine },
        )
        .unwrap();
        // Warm the worker (pretune runs on its thread) before the clock.
        let _ = coord.generate("m", vec![1, 2, 3], 4).unwrap();
        let t0 = Instant::now();
        let mut handles = Vec::with_capacity(n);
        for i in 0..n {
            let prompt: Vec<usize> =
                (0..(2 + i % 5)).map(|k| (i * 3 + k + 1) % vocab).collect();
            handles.push(coord.submit("m", prompt, new_tokens).unwrap().1);
        }
        let mut total = 0usize;
        for h in handles {
            total += h.recv().unwrap().generated;
        }
        let tps = total as f64 / t0.elapsed().as_secs_f64();
        coord.shutdown();
        (tps, total)
    };
    let (plain_tps, plain_tokens) = run(0);
    let proposed0 = wk::spec_tokens_proposed().get();
    let accepted0 = wk::spec_tokens_accepted().get();
    let (spec_tps, spec_tokens) = run(gamma);
    let proposed = wk::spec_tokens_proposed().get() - proposed0;
    let accepted = wk::spec_tokens_accepted().get() - accepted0;
    assert_eq!(
        plain_tokens, spec_tokens,
        "speculative decoding is bit-identical: token counts must match"
    );
    let acceptance = accepted as f64 / proposed.max(1) as f64;
    let speedup = spec_tps / plain_tps;
    println!(
        "spec (γ={gamma}): {spec_tps:>9.1} tok/s vs plain {plain_tps:.1} tok/s \
         ({speedup:.2}x), acceptance {:.1}% ({accepted}/{proposed})",
        acceptance * 100.0
    );
    (
        obj(vec![
            ("n_requests", Json::from(n)),
            ("gamma", Json::from(gamma)),
            ("tokens_per_sec_plain", Json::from(plain_tps)),
            ("tokens_per_sec_spec", Json::from(spec_tps)),
            ("speedup", Json::from(speedup)),
            ("tokens_proposed", Json::from(proposed as usize)),
            ("tokens_accepted", Json::from(accepted as usize)),
            ("acceptance_rate", Json::from(acceptance)),
            ("tokens_generated", Json::from(spec_tokens)),
        ]),
        speedup,
    )
}

/// (mean ms, p95 ms) of a latency sample set.
fn latency_stats_ms(samples: &[Duration]) -> (f64, f64) {
    if samples.is_empty() {
        return (0.0, 0.0);
    }
    let mut s = samples.to_vec();
    s.sort();
    let mean = s.iter().sum::<Duration>().as_secs_f64() * 1e3 / s.len() as f64;
    let p95 = s[((s.len() as f64 - 1.0) * 0.95).round() as usize].as_secs_f64() * 1e3;
    (mean, p95)
}

fn side_json(tps: f64, ttft: &[Duration], tokens: usize) -> Json {
    let (ttft_mean, ttft_p95) = latency_stats_ms(ttft);
    obj(vec![
        ("tokens_per_sec", Json::from(tps)),
        ("ttft_ms_mean", Json::from(ttft_mean)),
        ("ttft_ms_p95", Json::from(ttft_p95)),
        ("tokens_generated", Json::from(tokens)),
    ])
}

fn main() {
    let fast = std::env::var("BLAST_BENCH_FAST").is_ok_and(|v| v == "1");
    let mut rng = Rng::new(4242);
    let mut cfg = LmConfig::tiny(StructureKind::Blast { b: 4, r: 8 });
    cfg.max_seq = 96;
    let model = TinyLM::new(cfg, &mut rng);

    let n_requests = if fast { 16 } else { 32 };
    let new_tokens = if fast { 24 } else { 48 };
    let slots = 8usize;
    let mean_gap_us = 300.0;
    let workload = build_workload(&mut rng, n_requests, mean_gap_us, cfg.vocab, new_tokens);
    let offered: usize = workload.iter().map(|a| a.max_new).sum();
    println!(
        "=== bench: serving_throughput — {n_requests} requests, ≤{offered} tokens, \
         {slots} slots, Poisson mean gap {mean_gap_us}µs{} ===",
        if fast { " (fast)" } else { "" }
    );

    // Warm the process-global autotuner for both decode (batch bucket
    // 1) and prefill (bucket 8: prompts are 2..=8 tokens) shapes, plus
    // a decode pass, so neither side pays tuning probes inside its
    // timed region.
    model.pretune(&[1, 8]);
    let _ = model.generate(&[1, 2, 3], 4);

    let (seq_tps, seq_ttft, seq_tokens) = run_sequential(&model, &workload);
    let (seq_mean, seq_p95) = latency_stats_ms(&seq_ttft);
    println!(
        "sequential : {seq_tps:>9.1} tok/s  ttft mean {seq_mean:.2}ms p95 {seq_p95:.2}ms  \
         ({seq_tokens} tokens)"
    );

    let (cont_tps, cont_ttft, cont_tokens, serving_snapshot) =
        run_continuous(model, &workload, slots);
    let (cont_mean, cont_p95) = latency_stats_ms(&cont_ttft);
    println!(
        "continuous : {cont_tps:>9.1} tok/s  ttft mean {cont_mean:.2}ms p95 {cont_p95:.2}ms  \
         ({cont_tokens} tokens)"
    );

    assert_eq!(
        seq_tokens, cont_tokens,
        "greedy decode parity: both paths must generate identical token counts"
    );

    let speedup = cont_tps / seq_tps;
    println!("--> continuous batching is {speedup:.2}x sequential decode");

    // Prefix-cache scenario: shared system prompt + distinct tails.
    let prefix_requests = if fast { 8 } else { 16 };
    let system_len = if fast { 32 } else { 48 };
    let mut rng_p = Rng::new(4243);
    let mut cfg_p = LmConfig::tiny(StructureKind::Blast { b: 4, r: 8 });
    cfg_p.max_seq = 96;
    let model_p = TinyLM::new(cfg_p, &mut rng_p);
    let (px_tps, px_hit_rate, px_saved, px_bytes_per_tok) =
        run_prefix(model_p, prefix_requests, system_len, new_tokens / 2);
    println!(
        "prefix     : {px_tps:>9.1} tok/s  hit rate {:.1}% ({px_saved} prompt tokens \
         served from cache), {px_bytes_per_tok:.0} KV bytes/live token",
        px_hit_rate * 100.0
    );

    // Overload scenario: burst far beyond pending + KV capacity.
    let ov_requests = if fast { 32 } else { 64 };
    let mut rng_o = Rng::new(4244);
    let mut cfg_o = LmConfig::tiny(StructureKind::Blast { b: 4, r: 8 });
    cfg_o.max_seq = 96;
    let model_o = TinyLM::new(cfg_o, &mut rng_o);
    let overload = run_overload(model_o, ov_requests, new_tokens / 2);

    // Speculative-decoding scenario: self-draft burst, spec vs plain.
    let spec_requests = if fast { 12 } else { 24 };
    let spec_gamma = 4usize;
    let (spec_json, spec_speedup) = run_spec(spec_requests, new_tokens / 2, spec_gamma);

    let out_path = std::env::var("BLAST_SERVING_BENCH_OUT")
        .unwrap_or_else(|_| concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_serving.json").into());
    let root = obj(vec![
        ("bench", Json::from("serving_throughput")),
        (
            "config",
            obj(vec![
                ("n_requests", Json::from(n_requests)),
                ("slots", Json::from(slots)),
                ("new_tokens_max", Json::from(new_tokens)),
                ("mean_gap_us", Json::from(mean_gap_us)),
                ("fast_mode", Json::from(fast)),
            ]),
        ),
        ("sequential", side_json(seq_tps, &seq_ttft, seq_tokens)),
        ("continuous", side_json(cont_tps, &cont_ttft, cont_tokens)),
        (
            "prefix",
            obj(vec![
                ("n_requests", Json::from(prefix_requests)),
                ("system_prompt_len", Json::from(system_len)),
                ("tokens_per_sec", Json::from(px_tps)),
                ("hit_rate", Json::from(px_hit_rate)),
                ("prompt_tokens_saved", Json::from(px_saved as usize)),
                ("kv_bytes_per_live_token", Json::from(px_bytes_per_tok)),
            ]),
        ),
        ("overload", overload),
        ("spec", spec_json),
        ("speedup", Json::from(speedup)),
        (
            "gate",
            obj(vec![
                ("min_speedup", Json::from(1.5)),
                ("pass", Json::from(speedup >= 1.5)),
                ("spec_min_speedup", Json::from(1.3)),
                ("spec_pass", Json::from(spec_speedup >= 1.3)),
            ]),
        ),
        // Full observability snapshot (pack-cache hit rate, per-plan
        // GFLOP/s, KV occupancy) with the continuous run's serving
        // section — check_bench_trend.py gates on obs.pack_cache.hit_rate.
        (
            "obs",
            blast_repro::obs::MetricsSnapshot::collect()
                .with_serving(serving_snapshot)
                .into_json(),
        ),
    ]);
    match std::fs::write(&out_path, root.to_string_pretty()) {
        Ok(()) => println!("wrote {out_path}"),
        Err(e) => println!("could not write {out_path}: {e}"),
    }

    // Acceptance gate: continuous batching must be >= 1.5x sequential
    // tokens/sec at concurrency >= 4. Under BLAST_BENCH_FAST=1 (the CI
    // smoke setting: tiny workload on noisy shared runners) a miss is
    // reported but not fatal — the gate is enforced on real bench runs,
    // matching the blast_matmul gate policy.
    if speedup < 1.5 {
        let msg = format!(
            "continuous batching ({cont_tps:.1} tok/s) must be >= 1.5x sequential \
             decode ({seq_tps:.1} tok/s) at concurrency >= 4, got {speedup:.2}x"
        );
        assert!(fast, "acceptance gate: {msg}");
        println!("WARNING (not fatal in BLAST_BENCH_FAST mode): {msg}");
    } else {
        println!("gate: continuous >= 1.5x sequential — OK");
    }

    // Speculative gate: a self-draft at γ=4 must buy >= 1.3x the plain
    // continuous tokens/sec — the batched verify has to amortize enough
    // weight traffic to pay for the draft's proposal rows with room to
    // spare. Same fast-mode policy as the batching gate.
    if spec_speedup < 1.3 {
        let msg = format!(
            "speculative decoding must be >= 1.3x plain continuous decode, \
             got {spec_speedup:.2}x"
        );
        assert!(fast, "acceptance gate: {msg}");
        println!("WARNING (not fatal in BLAST_BENCH_FAST mode): {msg}");
    } else {
        println!("gate: speculative >= 1.3x plain — OK");
    }
}
