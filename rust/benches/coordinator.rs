//! Bench: coordinator overhead — batcher formation cost and end-to-end
//! request latency vs direct model calls (DESIGN.md §8 L3 target:
//! coordinator adds < 5 % at batch 8).

use blast_repro::coordinator::{BatcherConfig, Coordinator, CoordinatorConfig, EngineConfig};
use blast_repro::nn::attention::StructureKind;
use blast_repro::nn::gpt::{LmConfig, TinyLM};
use blast_repro::tensor::Rng;
use blast_repro::util::bench::BenchSuite;

fn main() {
    let mut suite = BenchSuite::new("coordinator — dispatch overhead");
    let mut rng = Rng::new(2);
    let model = TinyLM::new(LmConfig::tiny(StructureKind::Blast { b: 4, r: 8 }), &mut rng);
    let direct_model = model.clone();

    // Direct generation (no coordinator).
    let l = 16;
    suite.bench_throughput("direct generate L=16", l as f64, "tok", || {
        std::hint::black_box(direct_model.generate(&[1, 2, 3], l));
    });

    // Through the coordinator, single request at a time.
    let coord = Coordinator::new(
        vec![("m".into(), model)],
        CoordinatorConfig {
            batcher: BatcherConfig {
                max_batch: 8,
                max_wait: std::time::Duration::from_micros(200),
            },
            engine: EngineConfig { max_seqs: 8, ..EngineConfig::default() },
        },
    )
    .unwrap();
    suite.bench_throughput("coordinator generate L=16", l as f64, "tok", || {
        std::hint::black_box(coord.generate("m", vec![1, 2, 3], l).unwrap());
    });
    suite.report_speedup("direct generate L=16", "coordinator generate L=16");

    // Batch of 8 submitted concurrently.
    suite.bench_throughput("coordinator 8-way batch L=16", (8 * l) as f64, "tok", || {
        let rxs: Vec<_> = (0..8)
            .map(|i| coord.submit("m", vec![1 + i % 4, 2, 3], l).unwrap().1)
            .collect();
        for rx in rxs {
            std::hint::black_box(rx.recv().unwrap());
        }
    });
    println!("metrics: {}", coord.metrics.report());
    coord.shutdown();
}
