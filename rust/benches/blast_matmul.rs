//! Bench: BLAST kernel engine vs the naive reference, plus Algorithm 1
//! vs dense — the kernel-level basis of every FLOPs column in the paper
//! and of Table 4's speedups.
//!
//! Sections:
//!   1. Kernel shoot-out on the acceptance shape (1024×1024 BLAST,
//!      b=8, r=32): naive reference vs every registered kernel vs the
//!      autotuned engine dispatch, at decode (batch 1) and prefill
//!      (batch 8) shapes.
//!   2. Algorithm 1 vs dense matvec across sizes at 50% compression.
//!   3. Activation-batch matmul at the transformer layer shape.
//!
//! Set `BLAST_AUTOTUNE_CACHE=<path>` to regenerate a persisted plan
//! file: the run prints where the plan table was written.

use blast_repro::blast::{blast_rank_for_ratio, BlastMatrix};
use blast_repro::kernels::{engine, BlastView, KernelOp, PlanKey};
use blast_repro::tensor::{gemv, Matrix, Rng};
use blast_repro::util::bench::BenchSuite;

fn main() {
    let mut suite = BenchSuite::new("blast_matmul — kernel engine + Algorithm 1 vs dense");
    let mut rng = Rng::new(0);

    // ------------------------------------------------------------------
    // 1. Kernel shoot-out on the acceptance shape: 1024×1024, b=8, r=32.
    // ------------------------------------------------------------------
    let (n, b, r) = (1024usize, 8usize, 32usize);
    let a = BlastMatrix::random_init(n, n, b, r, 0.02, &mut rng);
    let flops = a.matvec_flops() as f64;
    for &batch in &[1usize, 8] {
        let x = rng.gaussian_matrix(batch, n, 1.0);
        let naive_name = format!("blast {n}x{n} b={b} r={r} batch={batch} [naive]");
        {
            let kernel = engine().kernel_named("naive").expect("naive registered");
            suite.bench_throughput(&naive_name, flops * batch as f64, "mult", || {
                let op = KernelOp::Blast(BlastView::from_matrix(&a));
                std::hint::black_box(kernel.run(&x, &op));
            });
        }
        for name in ["blast_fused", "blast_fused_par"] {
            let kernel = engine().kernel_named(name).expect("kernel registered");
            let case = format!("blast {n}x{n} b={b} r={r} batch={batch} [{name}]");
            suite.bench_throughput(&case, flops * batch as f64, "mult", || {
                let op = KernelOp::Blast(BlastView::from_matrix(&a));
                std::hint::black_box(kernel.run(&x, &op));
            });
            suite.report_speedup(&naive_name, &case);
        }
        // The autotuned engine path (what the serving stack actually runs).
        let tuned_name = format!("blast {n}x{n} b={b} r={r} batch={batch} [autotuned]");
        suite.bench_throughput(&tuned_name, flops * batch as f64, "mult", || {
            std::hint::black_box(engine().blast_act(&x, &a));
        });
        suite.report_speedup(&naive_name, &tuned_name);
        let key = PlanKey::for_op(&KernelOp::Blast(BlastView::from_matrix(&a)), batch);
        println!(
            "    plan[{}, m={}, n={}, batch-bucket={}] -> {}",
            key.op.to_tag_string(),
            key.m,
            key.n,
            key.batch,
            engine().plan_for(&key).unwrap_or_else(|| "<untuned>".into())
        );

        // Acceptance gate: the autotuned path must be >= 2x the naive
        // reference kernel on this shape. Under BLAST_BENCH_FAST=1 (the
        // CI smoke setting: few samples on noisy shared runners) a miss
        // is reported but not fatal — the gate is enforced on real
        // bench runs.
        let naive_t = suite.mean_of(&naive_name).unwrap().as_secs_f64();
        let tuned_t = suite.mean_of(&tuned_name).unwrap().as_secs_f64();
        let speedup = naive_t / tuned_t;
        println!("    acceptance: autotuned is {speedup:.2}x naive at batch={batch}");
        let fast_mode = std::env::var("BLAST_BENCH_FAST").is_ok_and(|v| v == "1");
        if speedup < 2.0 {
            let msg = format!(
                "autotuned kernel must be >= 2x naive on {n}x{n} b={b} r={r} batch={batch}, got {speedup:.2}x"
            );
            assert!(fast_mode, "{msg}");
            println!("    WARNING (not fatal in BLAST_BENCH_FAST mode): {msg}");
        }
    }

    // Correctness spot check under bench conditions.
    let xb = rng.gaussian_matrix(8, n, 1.0);
    let y_ref = blast_repro::tensor::matmul_nt(&xb, &a.to_dense());
    let y = a.matmul_act(&xb);
    let err = y.sub(&y_ref).fro_norm() / (1.0 + y_ref.fro_norm());
    assert!(err < 1e-3, "bench-path numerics drifted: {err}");

    // ------------------------------------------------------------------
    // 2. Matvec sweep over sizes at 50% compression.
    // ------------------------------------------------------------------
    for &size in &[512usize, 1024, 2048, 4096] {
        let dense = rng.gaussian_matrix(size, size, 0.02);
        let x: Vec<f32> = (0..size).map(|i| (i as f32 * 0.01).sin()).collect();
        let dense_name = format!("dense matvec {size}x{size}");
        suite.bench_throughput(&dense_name, (size * size) as f64, "mult", || {
            std::hint::black_box(gemv(&dense, &x));
        });
        for &bb in &[2usize, 16] {
            if let Some(rr) = blast_rank_for_ratio(size, size, bb, 0.5) {
                let am = BlastMatrix::random_init(size, size, bb, rr, 0.02, &mut rng);
                let name = format!("blast matvec {size}x{size} b={bb} r={rr}");
                suite.bench_throughput(&name, am.matvec_flops() as f64, "mult", || {
                    std::hint::black_box(am.matvec(&x));
                });
                suite.report_speedup(&dense_name, &name);
            }
        }
    }

    // ------------------------------------------------------------------
    // 3. Activation-batch matmul (the transformer layer shape).
    // ------------------------------------------------------------------
    let size = 1024;
    let batch = 8;
    let dense = rng.gaussian_matrix(size, size, 0.02);
    let x = rng.gaussian_matrix(batch, size, 1.0);
    suite.bench("dense matmul_act 8x1024 [tensor::matmul_nt]", || {
        std::hint::black_box(blast_repro::tensor::matmul_nt(&x, &dense));
    });
    suite.bench("dense matmul_act 8x1024 [engine]", || {
        std::hint::black_box(engine().matmul_nt(&x, &dense));
    });
    let rr = blast_rank_for_ratio(size, size, 16, 0.5).unwrap();
    let am = BlastMatrix::random_init(size, size, 16, rr, 0.02, &mut rng);
    suite.bench("blast matmul_act 8x1024 b=16 [engine]", || {
        std::hint::black_box(am.matmul_act(&x));
    });
    suite.report_speedup(
        "dense matmul_act 8x1024 [engine]",
        "blast matmul_act 8x1024 b=16 [engine]",
    );

    if let Ok(path) = std::env::var("BLAST_AUTOTUNE_CACHE") {
        // Every tuning decision is persisted as it is made; report where.
        println!("autotune plans persisted to {path}");
    }
    let _ = Matrix::zeros(1, 1);
}
