//! Bench: the kernel engine — packed SIMD dense GEMM vs the PR-3 scalar
//! loop, BLAST kernels vs the naive reference, and Algorithm 1 vs dense
//! — the kernel-level basis of every FLOPs column in the paper and of
//! Table 4's speedups.
//!
//! Sections:
//!   1. Dense GEMM shoot-out at prefill/decode shapes: the pre-SIMD
//!      scalar tiled loop (kept as a baseline) vs the packed
//!      microkernel (single-thread and autotuned). Acceptance gate:
//!      autotuned ≥ 2× scalar at the prefill shape on ≥8-lane FMA
//!      hardware (warn-only under `BLAST_BENCH_FAST` or without AVX2).
//!   2. Kernel shoot-out on the acceptance shape (1024×1024 BLAST,
//!      b=8, r=32): naive reference vs every registered kernel vs the
//!      autotuned engine dispatch, at decode (batch 1) and prefill
//!      (batch 8) shapes, with the ≥2× autotuned-vs-naive gate.
//!   3. Algorithm 1 vs dense matvec across sizes at 50% compression.
//!   4. Activation-batch matmul at the transformer layer shape.
//!
//! Always writes the machine-readable `BENCH_kernels.json` (repo root;
//! override with `BLAST_KERNELS_BENCH_OUT`) so `scripts/
//! check_bench_trend.py` can track the trajectory. Set
//! `BLAST_AUTOTUNE_CACHE=<path>` to also persist the plan table.

use blast_repro::blast::{blast_rank_for_ratio, BlastMatrix};
use blast_repro::kernels::{engine, micro, tiled, BlastView, KernelOp, PlanKey};
use blast_repro::tensor::{gemv, Matrix, Rng};
use blast_repro::util::bench::BenchSuite;
use blast_repro::util::json::{obj, Json};

fn main() {
    let fast_mode = std::env::var("BLAST_BENCH_FAST").is_ok_and(|v| v == "1");
    let avx2 = micro::avx2_detected();
    let mut suite = BenchSuite::new("blast_matmul — packed SIMD engine + Algorithm 1 vs dense");
    let mut rng = Rng::new(0);
    println!(
        "simd: mode={:?} avx2_detected={avx2} (BLAST_SIMD overrides; contract is bit-identical)",
        micro::simd_mode()
    );

    // ------------------------------------------------------------------
    // 1. Dense GEMM: scalar baseline vs packed microkernel.
    // ------------------------------------------------------------------
    let (dk, dn) = (1024usize, 1024usize);
    let dense_w = rng.gaussian_matrix(dn, dk, 0.02);
    let mut dense_gflops = (0.0f64, 0.0f64); // (scalar, autotuned) at prefill
    let mut dense_speedup = 0.0f64;
    for &batch in &[1usize, 8] {
        let x = rng.gaussian_matrix(batch, dk, 1.0);
        let flops = (2 * batch * dk * dn) as f64;
        let scalar_name = format!("dense {dk}x{dn} batch={batch} [scalar PR-3 baseline]");
        suite.bench_throughput(&scalar_name, flops, "flop", || {
            let mut out = vec![0.0f32; batch * dn];
            tiled::dense_nt_rows_scalar_baseline(&x, &dense_w, 0, batch, &mut out);
            std::hint::black_box(out);
        });
        let packed_name = format!("dense {dk}x{dn} batch={batch} [packed 1-thread]");
        {
            let kernel = engine().kernel_named("dense_tiled").expect("registered");
            suite.bench_throughput(&packed_name, flops, "flop", || {
                std::hint::black_box(kernel.run(&x, &KernelOp::DenseNt { w: &dense_w }));
            });
            suite.report_speedup(&scalar_name, &packed_name);
        }
        let tuned_name = format!("dense {dk}x{dn} batch={batch} [autotuned]");
        suite.bench_throughput(&tuned_name, flops, "flop", || {
            std::hint::black_box(engine().matmul_nt(&x, &dense_w));
        });
        suite.report_speedup(&scalar_name, &tuned_name);

        let scalar_t = suite.mean_of(&scalar_name).unwrap().as_secs_f64();
        let tuned_t = suite.mean_of(&tuned_name).unwrap().as_secs_f64();
        if batch == 8 {
            dense_gflops = (flops / scalar_t / 1e9, flops / tuned_t / 1e9);
            dense_speedup = scalar_t / tuned_t;
            println!(
                "    acceptance: autotuned dense GEMM is {dense_speedup:.2}x the scalar \
                 baseline at batch={batch}"
            );
            // Gate: ≥2× on ≥8-lane FMA hardware; warn-only in fast mode
            // or when the machine has no AVX2 to vectorize onto.
            if dense_speedup < 2.0 {
                let msg = format!(
                    "autotuned dense GEMM must be >= 2x the PR-3 scalar tiled loop at \
                     {dk}x{dn} batch={batch}, got {dense_speedup:.2}x"
                );
                assert!(fast_mode || !avx2, "{msg}");
                println!("    WARNING (not fatal: fast-mode/no-AVX2): {msg}");
            }
        }
    }

    // ------------------------------------------------------------------
    // 2. BLAST kernel shoot-out on the acceptance shape.
    // ------------------------------------------------------------------
    let (n, b, r) = (1024usize, 8usize, 32usize);
    let a = BlastMatrix::random_init(n, n, b, r, 0.02, &mut rng);
    let flops = a.matvec_flops() as f64;
    let mut blast_speedups = Vec::new();
    for &batch in &[1usize, 8] {
        let x = rng.gaussian_matrix(batch, n, 1.0);
        let naive_name = format!("blast {n}x{n} b={b} r={r} batch={batch} [naive]");
        {
            let kernel = engine().kernel_named("naive").expect("naive registered");
            suite.bench_throughput(&naive_name, flops * batch as f64, "mult", || {
                let op = KernelOp::Blast(BlastView::from_matrix(&a));
                std::hint::black_box(kernel.run(&x, &op));
            });
        }
        for name in ["blast_fused", "blast_fused_par"] {
            let kernel = engine().kernel_named(name).expect("kernel registered");
            let case = format!("blast {n}x{n} b={b} r={r} batch={batch} [{name}]");
            suite.bench_throughput(&case, flops * batch as f64, "mult", || {
                let op = KernelOp::Blast(BlastView::from_matrix(&a));
                std::hint::black_box(kernel.run(&x, &op));
            });
            suite.report_speedup(&naive_name, &case);
        }
        // The autotuned engine path (what the serving stack actually runs).
        let tuned_name = format!("blast {n}x{n} b={b} r={r} batch={batch} [autotuned]");
        suite.bench_throughput(&tuned_name, flops * batch as f64, "mult", || {
            std::hint::black_box(engine().blast_act(&x, &a));
        });
        suite.report_speedup(&naive_name, &tuned_name);
        let key = PlanKey::for_op(&KernelOp::Blast(BlastView::from_matrix(&a)), batch);
        println!(
            "    plan[{}, m={}, n={}, batch-bucket={}] -> {}",
            key.op.to_tag_string(),
            key.m,
            key.n,
            key.batch,
            engine().plan_for(&key).unwrap_or_else(|| "<untuned>".into())
        );

        // Acceptance gate: the autotuned path must be >= 2x the naive
        // reference kernel on this shape. Under BLAST_BENCH_FAST=1 (the
        // CI smoke setting: few samples on noisy shared runners) a miss
        // is reported but not fatal — the gate is enforced on real
        // bench runs.
        let naive_t = suite.mean_of(&naive_name).unwrap().as_secs_f64();
        let tuned_t = suite.mean_of(&tuned_name).unwrap().as_secs_f64();
        let speedup = naive_t / tuned_t;
        blast_speedups.push((batch, speedup));
        println!("    acceptance: autotuned is {speedup:.2}x naive at batch={batch}");
        if speedup < 2.0 {
            let msg = format!(
                "autotuned kernel must be >= 2x naive on {n}x{n} b={b} r={r} batch={batch}, got {speedup:.2}x"
            );
            assert!(fast_mode, "{msg}");
            println!("    WARNING (not fatal in BLAST_BENCH_FAST mode): {msg}");
        }
    }

    // Correctness spot check under bench conditions.
    let xb = rng.gaussian_matrix(8, n, 1.0);
    let y_ref = blast_repro::tensor::matmul_nt(&xb, &a.to_dense());
    let y = a.matmul_act(&xb);
    let err = y.sub(&y_ref).fro_norm() / (1.0 + y_ref.fro_norm());
    assert!(err < 1e-3, "bench-path numerics drifted: {err}");

    // ------------------------------------------------------------------
    // 3. Matvec sweep over sizes at 50% compression.
    // ------------------------------------------------------------------
    for &size in &[512usize, 1024, 2048, 4096] {
        let dense = rng.gaussian_matrix(size, size, 0.02);
        let x: Vec<f32> = (0..size).map(|i| (i as f32 * 0.01).sin()).collect();
        let dense_name = format!("dense matvec {size}x{size}");
        suite.bench_throughput(&dense_name, (size * size) as f64, "mult", || {
            std::hint::black_box(gemv(&dense, &x));
        });
        for &bb in &[2usize, 16] {
            if let Some(rr) = blast_rank_for_ratio(size, size, bb, 0.5) {
                let am = BlastMatrix::random_init(size, size, bb, rr, 0.02, &mut rng);
                let name = format!("blast matvec {size}x{size} b={bb} r={rr}");
                suite.bench_throughput(&name, am.matvec_flops() as f64, "mult", || {
                    std::hint::black_box(am.matvec(&x));
                });
                suite.report_speedup(&dense_name, &name);
            }
        }
    }

    // ------------------------------------------------------------------
    // 4. Activation-batch matmul (the transformer layer shape).
    // ------------------------------------------------------------------
    let size = 1024;
    let batch = 8;
    let dense = rng.gaussian_matrix(size, size, 0.02);
    let x = rng.gaussian_matrix(batch, size, 1.0);
    suite.bench("dense matmul_act 8x1024 [tensor::matmul_nt]", || {
        std::hint::black_box(blast_repro::tensor::matmul_nt(&x, &dense));
    });
    suite.bench("dense matmul_act 8x1024 [engine]", || {
        std::hint::black_box(engine().matmul_nt(&x, &dense));
    });
    let rr = blast_rank_for_ratio(size, size, 16, 0.5).unwrap();
    let am = BlastMatrix::random_init(size, size, 16, rr, 0.02, &mut rng);
    suite.bench("blast matmul_act 8x1024 b=16 [engine]", || {
        std::hint::black_box(am.matmul_act(&x));
    });
    suite.report_speedup(
        "dense matmul_act 8x1024 [engine]",
        "blast matmul_act 8x1024 b=16 [engine]",
    );

    // ------------------------------------------------------------------
    // Machine-readable output for the bench-trend gate.
    // ------------------------------------------------------------------
    let out_path = std::env::var("BLAST_KERNELS_BENCH_OUT")
        .unwrap_or_else(|_| concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_kernels.json").into());
    let blast_json: Vec<Json> = blast_speedups
        .iter()
        .map(|(bsz, s)| {
            obj(vec![("batch", Json::from(*bsz)), ("speedup_vs_naive", Json::from(*s))])
        })
        .collect();
    let root = obj(vec![
        ("bench", Json::from("blast_matmul")),
        ("provenance", Json::from("bench run")),
        (
            "simd",
            obj(vec![
                ("mode", Json::from(format!("{:?}", micro::simd_mode()))),
                ("avx2_detected", Json::from(avx2)),
            ]),
        ),
        (
            "dense",
            obj(vec![
                ("batch", Json::from(8usize)),
                ("k", Json::from(dk)),
                ("n", Json::from(dn)),
                ("scalar_gflops", Json::from(dense_gflops.0)),
                ("autotuned_gflops", Json::from(dense_gflops.1)),
                ("speedup_vs_scalar", Json::from(dense_speedup)),
            ]),
        ),
        ("blast", Json::Arr(blast_json)),
        (
            "gate",
            obj(vec![
                ("min_dense_speedup", Json::from(2.0)),
                ("min_blast_speedup", Json::from(2.0)),
                ("enforced", Json::from(!fast_mode && avx2)),
                ("fast_mode", Json::from(fast_mode)),
            ]),
        ),
    ]);
    match std::fs::write(&out_path, root.to_string_pretty()) {
        Ok(()) => println!("wrote {out_path}"),
        Err(e) => println!("could not write {out_path}: {e}"),
    }

    if let Ok(path) = std::env::var("BLAST_AUTOTUNE_CACHE") {
        // Every tuning decision is persisted as it is made; report where.
        println!("autotune plans persisted to {path}");
    }
    let _ = Matrix::zeros(1, 1);
}
