//! Bench: Algorithm 1 vs dense matmul across (n, b, r) — the kernel-level
//! basis of every FLOPs column in the paper and of Table 4's speedups.

use blast_repro::blast::{blast_rank_for_ratio, BlastMatrix};
use blast_repro::tensor::{gemv, Matrix, Rng};
use blast_repro::util::bench::BenchSuite;

fn main() {
    let mut suite = BenchSuite::new("blast_matmul — Algorithm 1 vs dense");
    let mut rng = Rng::new(0);

    // Matvec sweep over sizes at 50% compression.
    for &n in &[512usize, 1024, 2048, 4096] {
        let dense = rng.gaussian_matrix(n, n, 0.02);
        let x: Vec<f32> = (0..n).map(|i| (i as f32 * 0.01).sin()).collect();
        let dense_name = format!("dense matvec {n}x{n}");
        suite.bench_throughput(&dense_name, (n * n) as f64, "mult", || {
            std::hint::black_box(gemv(&dense, &x));
        });
        for &b in &[2usize, 16] {
            if let Some(r) = blast_rank_for_ratio(n, n, b, 0.5) {
                let a = BlastMatrix::random_init(n, n, b, r, 0.02, &mut rng);
                let name = format!("blast matvec {n}x{n} b={b} r={r}");
                suite.bench_throughput(&name, a.matvec_flops() as f64, "mult", || {
                    std::hint::black_box(a.matvec(&x));
                });
                suite.report_speedup(&dense_name, &name);
            }
        }
    }

    // Activation-batch matmul (the transformer layer shape).
    let n = 1024;
    let batch = 8;
    let dense = rng.gaussian_matrix(n, n, 0.02);
    let x = rng.gaussian_matrix(batch, n, 1.0);
    suite.bench("dense matmul_act 8x1024", || {
        std::hint::black_box(blast_repro::tensor::matmul_nt(&x, &dense));
    });
    let r = blast_rank_for_ratio(n, n, 16, 0.5).unwrap();
    let a = BlastMatrix::random_init(n, n, 16, r, 0.02, &mut rng);
    suite.bench("blast matmul_act 8x1024 b=16", || {
        std::hint::black_box(a.matmul_act(&x));
    });
    suite.report_speedup("dense matmul_act 8x1024", "blast matmul_act 8x1024 b=16");

    // Correctness spot check under bench conditions.
    let y_ref = blast_repro::tensor::matmul_nt(&x, &a.to_dense());
    let y = a.matmul_act(&x);
    let err = y.sub(&y_ref).fro_norm() / (1.0 + y_ref.fro_norm());
    assert!(err < 1e-3, "bench-path numerics drifted: {err}");
    let _ = Matrix::zeros(1, 1);
}
