//! Bench: the kernel engine — packed SIMD dense GEMM vs the PR-3 scalar
//! loop, BLAST kernels vs the naive reference, and Algorithm 1 vs dense
//! — the kernel-level basis of every FLOPs column in the paper and of
//! Table 4's speedups.
//!
//! Sections:
//!   1. Dense GEMM shoot-out at prefill/decode shapes: the pre-SIMD
//!      scalar tiled loop (kept as a baseline) vs the packed
//!      microkernel (single-thread and autotuned). Acceptance gate:
//!      autotuned ≥ 2× scalar at the prefill shape on ≥8-lane FMA
//!      hardware (warn-only under `BLAST_BENCH_FAST` or without AVX2).
//!   2. Kernel shoot-out on the acceptance shape (1024×1024 BLAST,
//!      b=8, r=32): naive reference vs the plan executors vs the
//!      autotuned engine dispatch, at decode (batch 1) and prefill
//!      (batch 8) shapes, with the ≥2× autotuned-vs-naive gate.
//!   3. Algorithm 1 vs dense matvec across sizes at 50% compression.
//!   4. Activation-batch matmul at the transformer layer shape.
//!   5. Per-structure shoot-out: the structure-plan path vs the
//!      pre-refactor per-structure loop nests (submatrix copies +
//!      per-block engine dispatches) for Low-Rank, Monarch, and
//!      Block-Diagonal, with GFLOP/s per structure recorded in
//!      `BENCH_kernels.json`.
//!   6. Quantized shoot-out: int8 packed panels (`plan_seq_i8`) vs the
//!      f32 packed path (`plan_seq`) through the same plan programs,
//!      per structure, plus the int8 bytes-per-weight footprint and
//!      resident pack-cache bytes. Acceptance gate: int8 ≥ 1.5× f32 at
//!      8×1024×1024 dense and on the BLAST plan (warn-only under
//!      `BLAST_BENCH_FAST` or without AVX2).
//!
//! Always writes the machine-readable `BENCH_kernels.json` (repo root;
//! override with `BLAST_KERNELS_BENCH_OUT`) so `scripts/
//! check_bench_trend.py` can track the trajectory. Set
//! `BLAST_AUTOTUNE_CACHE=<path>` to also persist the plan table.

use blast_repro::blast::{blast_rank_for_ratio, BlastMatrix};
use blast_repro::kernels::{
    engine, micro, pack_cache, plan_cache, tiled, Factors, KernelOp, PlanKey, PlanOperands,
    QuantPanels, StructPlan,
};
use blast_repro::tensor::{gemv, Matrix, Rng};
use blast_repro::util::bench::BenchSuite;
use blast_repro::util::json::{obj, Json};

fn main() {
    let fast_mode = std::env::var("BLAST_BENCH_FAST").is_ok_and(|v| v == "1");
    let avx2 = micro::avx2_detected();
    let mut suite = BenchSuite::new("blast_matmul — packed SIMD engine + Algorithm 1 vs dense");
    let mut rng = Rng::new(0);
    println!(
        "simd: mode={:?} avx2_detected={avx2} (BLAST_SIMD overrides; contract is bit-identical)",
        micro::simd_mode()
    );

    // ------------------------------------------------------------------
    // 1. Dense GEMM: scalar baseline vs packed microkernel.
    // ------------------------------------------------------------------
    let (dk, dn) = (1024usize, 1024usize);
    let dense_w = rng.gaussian_matrix(dn, dk, 0.02);
    let mut dense_gflops = (0.0f64, 0.0f64); // (scalar, autotuned) at prefill
    let mut dense_speedup = 0.0f64;
    for &batch in &[1usize, 8] {
        let x = rng.gaussian_matrix(batch, dk, 1.0);
        let flops = (2 * batch * dk * dn) as f64;
        let scalar_name = format!("dense {dk}x{dn} batch={batch} [scalar PR-3 baseline]");
        suite.bench_throughput(&scalar_name, flops, "flop", || {
            let mut out = vec![0.0f32; batch * dn];
            tiled::dense_nt_rows_scalar_baseline(&x, &dense_w, 0, batch, &mut out);
            std::hint::black_box(out);
        });
        let packed_name = format!("dense {dk}x{dn} batch={batch} [packed 1-thread]");
        {
            let kernel = engine().kernel_named("dense_tiled").expect("registered");
            suite.bench_throughput(&packed_name, flops, "flop", || {
                std::hint::black_box(kernel.run(&x, &KernelOp::DenseNt { w: &dense_w }));
            });
            suite.report_speedup(&scalar_name, &packed_name);
        }
        let tuned_name = format!("dense {dk}x{dn} batch={batch} [autotuned]");
        suite.bench_throughput(&tuned_name, flops, "flop", || {
            std::hint::black_box(engine().matmul_nt(&x, &dense_w));
        });
        suite.report_speedup(&scalar_name, &tuned_name);

        let scalar_t = suite.mean_of(&scalar_name).unwrap().as_secs_f64();
        let tuned_t = suite.mean_of(&tuned_name).unwrap().as_secs_f64();
        if batch == 8 {
            dense_gflops = (flops / scalar_t / 1e9, flops / tuned_t / 1e9);
            dense_speedup = scalar_t / tuned_t;
            println!(
                "    acceptance: autotuned dense GEMM is {dense_speedup:.2}x the scalar \
                 baseline at batch={batch}"
            );
            // Gate: ≥2× on ≥8-lane FMA hardware; warn-only in fast mode
            // or when the machine has no AVX2 to vectorize onto.
            if dense_speedup < 2.0 {
                let msg = format!(
                    "autotuned dense GEMM must be >= 2x the PR-3 scalar tiled loop at \
                     {dk}x{dn} batch={batch}, got {dense_speedup:.2}x"
                );
                assert!(fast_mode || !avx2, "{msg}");
                println!("    WARNING (not fatal: fast-mode/no-AVX2): {msg}");
            }
        }
    }

    // ------------------------------------------------------------------
    // 2. BLAST kernel shoot-out on the acceptance shape.
    // ------------------------------------------------------------------
    let (n, b, r) = (1024usize, 8usize, 32usize);
    let a = BlastMatrix::random_init(n, n, b, r, 0.02, &mut rng);
    let a_plan = a.plan();
    let flops = a.matvec_flops() as f64;
    let mut blast_speedups = Vec::new();
    for &batch in &[1usize, 8] {
        let x = rng.gaussian_matrix(batch, n, 1.0);
        let naive_name = format!("blast {n}x{n} b={b} r={r} batch={batch} [naive]");
        {
            let kernel = engine().kernel_named("naive").expect("naive registered");
            suite.bench_throughput(&naive_name, flops * batch as f64, "mult", || {
                let op = KernelOp::Plan { plan: &a_plan, ops: a.plan_operands() };
                std::hint::black_box(kernel.run(&x, &op));
            });
        }
        for name in ["plan_seq", "plan_par"] {
            let kernel = engine().kernel_named(name).expect("kernel registered");
            let case = format!("blast {n}x{n} b={b} r={r} batch={batch} [{name}]");
            suite.bench_throughput(&case, flops * batch as f64, "mult", || {
                let op = KernelOp::Plan { plan: &a_plan, ops: a.plan_operands() };
                std::hint::black_box(kernel.run(&x, &op));
            });
            suite.report_speedup(&naive_name, &case);
        }
        // The autotuned engine path (what the serving stack actually runs).
        let tuned_name = format!("blast {n}x{n} b={b} r={r} batch={batch} [autotuned]");
        suite.bench_throughput(&tuned_name, flops * batch as f64, "mult", || {
            std::hint::black_box(engine().blast_act(&x, &a));
        });
        suite.report_speedup(&naive_name, &tuned_name);
        let key = PlanKey::for_op(
            &KernelOp::Plan { plan: &a_plan, ops: a.plan_operands() },
            batch,
        );
        println!(
            "    plan[{}, m={}, n={}, batch-bucket={}] -> {}",
            key.op.to_tag_string(),
            key.m,
            key.n,
            key.batch,
            engine().plan_for(&key).unwrap_or_else(|| "<untuned>".into())
        );

        // Acceptance gate: the autotuned path must be >= 2x the naive
        // reference kernel on this shape. Under BLAST_BENCH_FAST=1 (the
        // CI smoke setting: few samples on noisy shared runners) a miss
        // is reported but not fatal — the gate is enforced on real
        // bench runs.
        let naive_t = suite.mean_of(&naive_name).unwrap().as_secs_f64();
        let tuned_t = suite.mean_of(&tuned_name).unwrap().as_secs_f64();
        let speedup = naive_t / tuned_t;
        blast_speedups.push((batch, speedup));
        println!("    acceptance: autotuned is {speedup:.2}x naive at batch={batch}");
        if speedup < 2.0 {
            let msg = format!(
                "autotuned kernel must be >= 2x naive on {n}x{n} b={b} r={r} batch={batch}, got {speedup:.2}x"
            );
            assert!(fast_mode, "{msg}");
            println!("    WARNING (not fatal in BLAST_BENCH_FAST mode): {msg}");
        }
    }

    // Correctness spot check under bench conditions.
    let xb = rng.gaussian_matrix(8, n, 1.0);
    let y_ref = blast_repro::tensor::matmul_nt(&xb, &a.to_dense());
    let y = a.matmul_act(&xb);
    let err = y.sub(&y_ref).fro_norm() / (1.0 + y_ref.fro_norm());
    assert!(err < 1e-3, "bench-path numerics drifted: {err}");

    // ------------------------------------------------------------------
    // 3. Matvec sweep over sizes at 50% compression.
    // ------------------------------------------------------------------
    for &size in &[512usize, 1024, 2048, 4096] {
        let dense = rng.gaussian_matrix(size, size, 0.02);
        let x: Vec<f32> = (0..size).map(|i| (i as f32 * 0.01).sin()).collect();
        let dense_name = format!("dense matvec {size}x{size}");
        suite.bench_throughput(&dense_name, (size * size) as f64, "mult", || {
            std::hint::black_box(gemv(&dense, &x));
        });
        for &bb in &[2usize, 16] {
            if let Some(rr) = blast_rank_for_ratio(size, size, bb, 0.5) {
                let am = BlastMatrix::random_init(size, size, bb, rr, 0.02, &mut rng);
                let name = format!("blast matvec {size}x{size} b={bb} r={rr}");
                suite.bench_throughput(&name, am.matvec_flops() as f64, "mult", || {
                    std::hint::black_box(am.matvec(&x));
                });
                suite.report_speedup(&dense_name, &name);
            }
        }
    }

    // ------------------------------------------------------------------
    // 4. Activation-batch matmul (the transformer layer shape).
    // ------------------------------------------------------------------
    let size = 1024;
    let batch = 8;
    let dense = rng.gaussian_matrix(size, size, 0.02);
    let x = rng.gaussian_matrix(batch, size, 1.0);
    suite.bench("dense matmul_act 8x1024 [tensor::matmul_nt]", || {
        std::hint::black_box(blast_repro::tensor::matmul_nt(&x, &dense));
    });
    suite.bench("dense matmul_act 8x1024 [engine]", || {
        std::hint::black_box(engine().matmul_nt(&x, &dense));
    });
    let rr = blast_rank_for_ratio(size, size, 16, 0.5).unwrap();
    let am = BlastMatrix::random_init(size, size, 16, rr, 0.02, &mut rng);
    suite.bench("blast matmul_act 8x1024 b=16 [engine]", || {
        std::hint::black_box(am.matmul_act(&x));
    });
    suite.report_speedup(
        "dense matmul_act 8x1024 [engine]",
        "blast matmul_act 8x1024 b=16 [engine]",
    );

    // ------------------------------------------------------------------
    // 5. Per-structure shoot-out: structure plans vs the pre-refactor
    //    per-structure loop nests.
    // ------------------------------------------------------------------
    //
    // The baselines reproduce what `nn::linear::forward` used to run
    // before the plan refactor: per-block submatrix copies + one engine
    // dense dispatch per factor product (LowRank additionally paid a
    // tensor-level stage-1 GEMM). The plan path runs the same math as
    // one autotuned stage program over packed factor panels.
    let (sm, sb) = (1024usize, 8usize);
    let mut structure_json: Vec<(&'static str, Json)> = Vec::new();
    for &batch in &[8usize] {
        let x = rng.gaussian_matrix(batch, sm, 1.0);

        // --- Low-Rank (r = 256, the 50% compression rank) ---
        let lr_r = 256usize;
        let lp = rng.gaussian_matrix(sm, lr_r, 0.02);
        let lq = rng.gaussian_matrix(sm, lr_r, 0.02);
        // 2·(multiply-adds), matching the dense section's FLOP units so
        // the JSON gflops fields are comparable across sections.
        let lr_flops = (2 * (sm + sm) * lr_r * batch) as f64;
        let base_name = format!("lowrank {sm}x{sm} r={lr_r} batch={batch} [pre-plan loops]");
        suite.bench_throughput(&base_name, lr_flops, "flop", || {
            let z = blast_repro::tensor::matmul(&x, &lq);
            std::hint::black_box(engine().matmul_nt(&z, &lp));
        });
        let plan = StructPlan::low_rank(sm, sm, lr_r);
        let ops = PlanOperands {
            g0: Factors::Mats(std::slice::from_ref(&lq)),
            g1: Factors::Mats(std::slice::from_ref(&lp)),
            s: None,
        };
        let plan_name = format!("lowrank {sm}x{sm} r={lr_r} batch={batch} [plan]");
        suite.bench_throughput(&plan_name, lr_flops, "flop", || {
            std::hint::black_box(engine().plan_act(&x, &plan, &ops));
        });
        suite.report_speedup(&base_name, &plan_name);
        let base_t = suite.mean_of(&base_name).unwrap().as_secs_f64();
        let plan_t = suite.mean_of(&plan_name).unwrap().as_secs_f64();
        structure_json.push((
            "lowrank",
            obj(vec![
                ("baseline_gflops", Json::from(lr_flops / base_t / 1e9)),
                ("plan_gflops", Json::from(lr_flops / plan_t / 1e9)),
                ("speedup_vs_baseline", Json::from(base_t / plan_t)),
            ]),
        ));

        // --- Monarch (b = 8, t = 64) ---
        let (mb, mt) = (sb, 64usize);
        let (mp, mq) = (sm / mb, sm / mb);
        let rb: Vec<Matrix> = (0..mb).map(|_| rng.gaussian_matrix(mt, mq, 0.02)).collect();
        let ml: Vec<Matrix> =
            (0..mb * mb).map(|_| rng.gaussian_matrix(mp, mt, 0.02)).collect();
        let mo_flops = (2 * (sm * mt + sm * mb * mt) * batch) as f64;
        let base_name = format!("monarch {sm}x{sm} b={mb} t={mt} batch={batch} [pre-plan loops]");
        suite.bench_throughput(&base_name, mo_flops, "flop", || {
            let z: Vec<Matrix> = (0..mb)
                .map(|j| {
                    let xj = x.submatrix(0, batch, j * mq, (j + 1) * mq);
                    engine().matmul_nt(&xj, &rb[j])
                })
                .collect();
            let mut y = Matrix::zeros(batch, sm);
            for i in 0..mb {
                for j in 0..mb {
                    let contrib = engine().matmul_nt(&z[j], &ml[i * mb + j]);
                    for t in 0..batch {
                        let yrow = &mut y.row_mut(t)[i * mp..(i + 1) * mp];
                        for (yv, cv) in yrow.iter_mut().zip(contrib.row(t)) {
                            *yv += cv;
                        }
                    }
                }
            }
            std::hint::black_box(y);
        });
        let plan = StructPlan::monarch(sm, sm, mb, mt);
        let ops = PlanOperands { g0: Factors::Mats(&rb), g1: Factors::Mats(&ml), s: None };
        let plan_name = format!("monarch {sm}x{sm} b={mb} t={mt} batch={batch} [plan]");
        suite.bench_throughput(&plan_name, mo_flops, "flop", || {
            std::hint::black_box(engine().plan_act(&x, &plan, &ops));
        });
        suite.report_speedup(&base_name, &plan_name);
        let base_t = suite.mean_of(&base_name).unwrap().as_secs_f64();
        let plan_t = suite.mean_of(&plan_name).unwrap().as_secs_f64();
        structure_json.push((
            "monarch",
            obj(vec![
                ("baseline_gflops", Json::from(mo_flops / base_t / 1e9)),
                ("plan_gflops", Json::from(mo_flops / plan_t / 1e9)),
                ("speedup_vs_baseline", Json::from(base_t / plan_t)),
            ]),
        ));

        // --- Block-Diagonal (b = 8, t = 64) ---
        let (db, dt) = (sb, 64usize);
        let (dp, dq) = (sm / db, sm / db);
        let pd: Vec<Matrix> = (0..db).map(|_| rng.gaussian_matrix(dp, dt, 0.02)).collect();
        let qd: Vec<Matrix> = (0..db).map(|_| rng.gaussian_matrix(dq, dt, 0.02)).collect();
        let bd_flops = (2 * (sm + sm) * dt * batch) as f64;
        let base_name =
            format!("blockdiag {sm}x{sm} b={db} t={dt} batch={batch} [pre-plan loops]");
        suite.bench_throughput(&base_name, bd_flops, "flop", || {
            let mut y = Matrix::zeros(batch, sm);
            for i in 0..db {
                let xi = x.submatrix(0, batch, i * dq, (i + 1) * dq);
                let z = blast_repro::tensor::matmul(&xi, &qd[i]);
                let yi = engine().matmul_nt(&z, &pd[i]);
                for t in 0..batch {
                    y.row_mut(t)[i * dp..(i + 1) * dp].copy_from_slice(yi.row(t));
                }
            }
            std::hint::black_box(y);
        });
        let plan = StructPlan::block_diag(sm, sm, db, dt);
        let ops = PlanOperands { g0: Factors::Mats(&qd), g1: Factors::Mats(&pd), s: None };
        let plan_name = format!("blockdiag {sm}x{sm} b={db} t={dt} batch={batch} [plan]");
        suite.bench_throughput(&plan_name, bd_flops, "flop", || {
            std::hint::black_box(engine().plan_act(&x, &plan, &ops));
        });
        suite.report_speedup(&base_name, &plan_name);
        let base_t = suite.mean_of(&base_name).unwrap().as_secs_f64();
        let plan_t = suite.mean_of(&plan_name).unwrap().as_secs_f64();
        structure_json.push((
            "blockdiag",
            obj(vec![
                ("baseline_gflops", Json::from(bd_flops / base_t / 1e9)),
                ("plan_gflops", Json::from(bd_flops / plan_t / 1e9)),
                ("speedup_vs_baseline", Json::from(base_t / plan_t)),
            ]),
        ));
    }

    // ------------------------------------------------------------------
    // 6. Quantized shoot-out: int8 packed panels vs f32 packed panels
    //    through the same plan programs. Weight-only quantization —
    //    activations quantize per-row on the fly inside the i8 kernels
    //    and the BLAST coupling stage stays f32, so the comparison is
    //    plan-for-plan on identical math. Single-thread executors
    //    (`plan_seq` vs `plan_seq_i8`) keep the ratio a clean
    //    per-kernel number, free of thread-spawn noise.
    // ------------------------------------------------------------------
    let mut quant_json: Vec<(&'static str, Json)> = Vec::new();
    let qbatch = 8usize;

    // Footprint side of the shoot-out: int8 pack bytes (values + scales
    // + tile padding) over the raw weight count. Couplings are never
    // packed (they stay f32), so only GEMM-stage factors count.
    let bytes_per_weight = |mats: &[&Matrix]| -> f64 {
        let (mut bytes, mut weights) = (0usize, 0usize);
        for m in mats {
            bytes += QuantPanels::pack_rows(m).bytes();
            weights += m.rows * m.cols;
        }
        bytes as f64 / weights.max(1) as f64
    };

    // --- dense 8×1024×1024 (the acceptance shape) ---
    let qx = rng.gaussian_matrix(qbatch, dk, 1.0);
    let dplan = StructPlan::dense(dn, dk);
    let dplan_q = plan_cache().get(dplan.sig.quantized(), dplan.m, dplan.n);
    let dflops = (2 * qbatch * dk * dn) as f64;
    let (dq_f32, dq_i8, dq_speedup) = quant_shootout(
        &mut suite,
        &format!("quant dense {dk}x{dn} batch={qbatch}"),
        &qx,
        &dplan,
        &dplan_q,
        PlanOperands::single(&dense_w),
        dflops,
    );
    // Numerics sanity under bench conditions: int8 must stay inside the
    // documented bounded-error envelope of the f32 reference (the
    // strict per-structure ≤1e-2 contract is asserted by
    // tests/quant_parity.rs on uniform data; gaussian bench weights get
    // a looser 2e-2 guard).
    {
        let i8_kernel = engine().kernel_named("plan_seq_i8").expect("registered");
        let op = KernelOp::Plan { plan: &dplan_q, ops: PlanOperands::single(&dense_w) };
        let y_q = i8_kernel.run(&qx, &op);
        let y_f = engine().matmul_nt(&qx, &dense_w);
        let rel = y_q.sub(&y_f).fro_norm() / y_f.fro_norm().max(f32::MIN_POSITIVE);
        assert!(rel < 2e-2, "int8 bench numerics drifted: rel={rel}");
    }
    quant_json.push((
        "dense",
        obj(vec![
            ("f32_gflops", Json::from(dq_f32)),
            ("i8_gflops", Json::from(dq_i8)),
            ("speedup", Json::from(dq_speedup)),
            ("bytes_per_weight", Json::from(bytes_per_weight(&[&dense_w]))),
        ]),
    ));
    println!("    acceptance: int8 dense plan is {dq_speedup:.2}x the f32 packed path");
    if dq_speedup < 1.5 {
        let msg = format!(
            "int8 packed path must be >= 1.5x the f32 packed path at {dk}x{dn} \
             batch={qbatch}, got {dq_speedup:.2}x"
        );
        assert!(fast_mode || !avx2, "{msg}");
        println!("    WARNING (not fatal: fast-mode/no-AVX2): {msg}");
    }

    // --- BLAST (the section-2 acceptance shape) ---
    let qxb = rng.gaussian_matrix(qbatch, n, 1.0);
    let a_plan_q = plan_cache().get(a_plan.sig.quantized(), a_plan.m, a_plan.n);
    let bflops = 2.0 * a.matvec_flops() as f64 * qbatch as f64;
    let (bq_f32, bq_i8, bq_speedup) = quant_shootout(
        &mut suite,
        &format!("quant blast {n}x{n} b={b} r={r} batch={qbatch}"),
        &qxb,
        &a_plan,
        &a_plan_q,
        a.plan_operands(),
        bflops,
    );
    let blast_factors: Vec<&Matrix> = a.u.iter().chain(a.v.iter()).collect();
    quant_json.push((
        "blast",
        obj(vec![
            ("f32_gflops", Json::from(bq_f32)),
            ("i8_gflops", Json::from(bq_i8)),
            ("speedup", Json::from(bq_speedup)),
            ("bytes_per_weight", Json::from(bytes_per_weight(&blast_factors))),
        ]),
    ));
    println!("    acceptance: int8 BLAST plan is {bq_speedup:.2}x the f32 packed path");
    if bq_speedup < 1.5 {
        let msg = format!(
            "int8 packed path must be >= 1.5x the f32 packed path on blast {n}x{n} \
             b={b} r={r} batch={qbatch}, got {bq_speedup:.2}x"
        );
        assert!(fast_mode || !avx2, "{msg}");
        println!("    WARNING (not fatal: fast-mode/no-AVX2): {msg}");
    }

    // --- Low-Rank / Monarch / Block-Diagonal (recorded, no gate: the
    //     small per-stage GEMMs amortize the activation-quant pass less
    //     than the two gated shapes do) ---
    {
        let lr_r = 256usize;
        let lp = rng.gaussian_matrix(sm, lr_r, 0.02);
        let lq = rng.gaussian_matrix(sm, lr_r, 0.02);
        let lx = rng.gaussian_matrix(qbatch, sm, 1.0);
        let lplan = StructPlan::low_rank(sm, sm, lr_r);
        let lplan_q = plan_cache().get(lplan.sig.quantized(), lplan.m, lplan.n);
        let lr_flops = (2 * (sm + sm) * lr_r * qbatch) as f64;
        let lops = PlanOperands {
            g0: Factors::Mats(std::slice::from_ref(&lq)),
            g1: Factors::Mats(std::slice::from_ref(&lp)),
            s: None,
        };
        let (g_f32, g_i8, sp) = quant_shootout(
            &mut suite,
            &format!("quant lowrank {sm}x{sm} r={lr_r} batch={qbatch}"),
            &lx,
            &lplan,
            &lplan_q,
            lops,
            lr_flops,
        );
        quant_json.push((
            "lowrank",
            obj(vec![
                ("f32_gflops", Json::from(g_f32)),
                ("i8_gflops", Json::from(g_i8)),
                ("speedup", Json::from(sp)),
                ("bytes_per_weight", Json::from(bytes_per_weight(&[&lp, &lq]))),
            ]),
        ));
    }
    {
        let (mb, mt) = (sb, 64usize);
        let (mp, mq) = (sm / mb, sm / mb);
        let rb: Vec<Matrix> = (0..mb).map(|_| rng.gaussian_matrix(mt, mq, 0.02)).collect();
        let ml: Vec<Matrix> =
            (0..mb * mb).map(|_| rng.gaussian_matrix(mp, mt, 0.02)).collect();
        let mx = rng.gaussian_matrix(qbatch, sm, 1.0);
        let mplan = StructPlan::monarch(sm, sm, mb, mt);
        let mplan_q = plan_cache().get(mplan.sig.quantized(), mplan.m, mplan.n);
        let mo_flops = (2 * (sm * mt + sm * mb * mt) * qbatch) as f64;
        let mops = PlanOperands { g0: Factors::Mats(&rb), g1: Factors::Mats(&ml), s: None };
        let (g_f32, g_i8, sp) = quant_shootout(
            &mut suite,
            &format!("quant monarch {sm}x{sm} b={mb} t={mt} batch={qbatch}"),
            &mx,
            &mplan,
            &mplan_q,
            mops,
            mo_flops,
        );
        let factors: Vec<&Matrix> = rb.iter().chain(ml.iter()).collect();
        quant_json.push((
            "monarch",
            obj(vec![
                ("f32_gflops", Json::from(g_f32)),
                ("i8_gflops", Json::from(g_i8)),
                ("speedup", Json::from(sp)),
                ("bytes_per_weight", Json::from(bytes_per_weight(&factors))),
            ]),
        ));
    }
    {
        let (db, dt) = (sb, 64usize);
        let (dp, dq) = (sm / db, sm / db);
        let pd: Vec<Matrix> = (0..db).map(|_| rng.gaussian_matrix(dp, dt, 0.02)).collect();
        let qd: Vec<Matrix> = (0..db).map(|_| rng.gaussian_matrix(dq, dt, 0.02)).collect();
        let bx = rng.gaussian_matrix(qbatch, sm, 1.0);
        let bplan = StructPlan::block_diag(sm, sm, db, dt);
        let bplan_q = plan_cache().get(bplan.sig.quantized(), bplan.m, bplan.n);
        let bd_flops = (2 * (sm + sm) * dt * qbatch) as f64;
        let bops = PlanOperands { g0: Factors::Mats(&qd), g1: Factors::Mats(&pd), s: None };
        let (g_f32, g_i8, sp) = quant_shootout(
            &mut suite,
            &format!("quant blockdiag {sm}x{sm} b={db} t={dt} batch={qbatch}"),
            &bx,
            &bplan,
            &bplan_q,
            bops,
            bd_flops,
        );
        let factors: Vec<&Matrix> = pd.iter().chain(qd.iter()).collect();
        quant_json.push((
            "blockdiag",
            obj(vec![
                ("f32_gflops", Json::from(g_f32)),
                ("i8_gflops", Json::from(g_i8)),
                ("speedup", Json::from(sp)),
                ("bytes_per_weight", Json::from(bytes_per_weight(&factors))),
            ]),
        ));
    }
    // Resident packed bytes (f32 + int8 entries, scales included in the
    // LRU budget) after the full shoot-out.
    quant_json.push(("pack_cache_resident_bytes", Json::from(pack_cache().bytes())));

    // ------------------------------------------------------------------
    // Machine-readable output for the bench-trend gate.
    // ------------------------------------------------------------------
    let out_path = std::env::var("BLAST_KERNELS_BENCH_OUT")
        .unwrap_or_else(|_| concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_kernels.json").into());
    let blast_json: Vec<Json> = blast_speedups
        .iter()
        .map(|(bsz, s)| {
            obj(vec![("batch", Json::from(*bsz)), ("speedup_vs_naive", Json::from(*s))])
        })
        .collect();
    let root = obj(vec![
        ("bench", Json::from("blast_matmul")),
        ("provenance", Json::from("bench run")),
        (
            "simd",
            obj(vec![
                ("mode", Json::from(format!("{:?}", micro::simd_mode()))),
                ("avx2_detected", Json::from(avx2)),
            ]),
        ),
        (
            "dense",
            obj(vec![
                ("batch", Json::from(8usize)),
                ("k", Json::from(dk)),
                ("n", Json::from(dn)),
                ("scalar_gflops", Json::from(dense_gflops.0)),
                ("autotuned_gflops", Json::from(dense_gflops.1)),
                ("speedup_vs_scalar", Json::from(dense_speedup)),
            ]),
        ),
        ("blast", Json::Arr(blast_json)),
        ("structures", obj(structure_json)),
        // Section 6: f32-packed vs int8-packed GFLOP/s per structure,
        // the int8 bytes-per-weight footprint, and the resident
        // pack-cache bytes after the shoot-out.
        ("quantized", obj(quant_json)),
        (
            "gate",
            obj(vec![
                ("min_dense_speedup", Json::from(2.0)),
                ("min_blast_speedup", Json::from(2.0)),
                ("min_quant_speedup", Json::from(1.5)),
                ("enforced", Json::from(!fast_mode && avx2)),
                ("fast_mode", Json::from(fast_mode)),
            ]),
        ),
        // Observability snapshot of the whole bench run: pack-cache
        // hit rate and per-plan-signature GFLOP/s feed the trend gate.
        ("obs", blast_repro::obs::MetricsSnapshot::collect().into_json()),
    ]);
    match std::fs::write(&out_path, root.to_string_pretty()) {
        Ok(()) => println!("wrote {out_path}"),
        Err(e) => println!("could not write {out_path}: {e}"),
    }

    if let Ok(path) = std::env::var("BLAST_AUTOTUNE_CACHE") {
        // Every tuning decision is persisted as it is made; report where.
        println!("autotune plans persisted to {path}");
    }
    let _ = Matrix::zeros(1, 1);
}

/// Bench one plan program both ways — f32 packed panels via `plan_seq`
/// against int8 packed panels via `plan_seq_i8` — and return
/// `(f32_gflops, i8_gflops, speedup)`. Both runs share `ops` (the i8
/// kernel quantizes the same f32 factors at pack time) and the
/// single-thread executors, so the ratio isolates the int8 microkernel
/// + halved panel traffic from threading effects.
fn quant_shootout(
    suite: &mut BenchSuite,
    label: &str,
    x: &Matrix,
    plan_f32: &StructPlan,
    plan_i8: &StructPlan,
    ops: PlanOperands<'_>,
    flops: f64,
) -> (f64, f64, f64) {
    let f32_kernel = engine().kernel_named("plan_seq").expect("plan_seq registered");
    let i8_kernel = engine().kernel_named("plan_seq_i8").expect("plan_seq_i8 registered");
    let f32_name = format!("{label} [f32 packed 1-thread]");
    suite.bench_throughput(&f32_name, flops, "flop", || {
        let op = KernelOp::Plan { plan: plan_f32, ops };
        std::hint::black_box(f32_kernel.run(x, &op));
    });
    let i8_name = format!("{label} [int8 packed 1-thread]");
    suite.bench_throughput(&i8_name, flops, "flop", || {
        let op = KernelOp::Plan { plan: plan_i8, ops };
        std::hint::black_box(i8_kernel.run(x, &op));
    });
    suite.report_speedup(&f32_name, &i8_name);
    let f32_t = suite.mean_of(&f32_name).unwrap().as_secs_f64();
    let i8_t = suite.mean_of(&i8_name).unwrap().as_secs_f64();
    (flops / f32_t / 1e9, flops / i8_t / 1e9, f32_t / i8_t)
}
