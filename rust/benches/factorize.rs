//! Bench: compression-pipeline throughput — single-thread vs
//! block-parallel PrecGD (Algorithm 2), plus per-structure compressor
//! cost at a fixed budget. Writes the machine-readable
//! `BENCH_factorize.json` at the repo root so the CI bench-trend job can
//! track the trajectory.
//!
//! Acceptance gate: block-parallel PrecGD must reach ≥ 2× the
//! single-thread wall clock on ≥ 4 cores, at **bit-identical** loss
//! trajectories (asserted here and in `tests/factorize_parity.rs`).
//! On < 4 cores or under `BLAST_BENCH_FAST=1` (the CI smoke setting) a
//! miss is reported but not fatal, matching the other bench gates.
//!
//! `BLAST_FACTORIZE_BENCH_OUT` overrides the JSON output path.

use blast_repro::factorize::{factorize_precgd, Compressor, PrecGdOptions, Structure};
use blast_repro::tensor::{matmul_nt, Rng};
use blast_repro::util::bench::BenchSuite;
use blast_repro::util::json::{obj, Json};
use blast_repro::util::par;

fn main() {
    let fast = std::env::var("BLAST_BENCH_FAST").is_ok_and(|v| v == "1");
    let threads = par::num_threads();
    let (n, iters) = if fast { (128usize, 8usize) } else { (256, 20) };
    let (b, r, r_star) = (8usize, 16usize, 8usize);

    let mut suite = BenchSuite::new("factorize — parallel compression pipeline");
    let mut rng = Rng::new(0);
    let u = rng.gaussian_matrix(n, r_star, 1.0);
    let v = rng.gaussian_matrix(n, r_star, 1.0);
    let target = matmul_nt(&u, &v).scale(1.0 / (r_star as f32).sqrt());
    println!(
        "target {n}x{n} rank-{r_star}; PrecGD b={b} r={r} iters={iters}; {threads} threads{}",
        if fast { " (fast)" } else { "" }
    );

    // --- Single-thread vs block-parallel PrecGD (the tentpole gate). ---
    let single_name = format!("PrecGD single-thread n={n} b={b} r={r}");
    let parallel_name = format!("PrecGD block-parallel n={n} b={b} r={r}");
    suite.bench(&single_name, || {
        std::hint::black_box(factorize_precgd(
            &target,
            &PrecGdOptions {
                b,
                r,
                iters,
                trace_every: 0,
                parallel: false,
                ..Default::default()
            },
        ));
    });
    suite.bench(&parallel_name, || {
        std::hint::black_box(factorize_precgd(
            &target,
            &PrecGdOptions { b, r, iters, trace_every: 0, parallel: true, ..Default::default() },
        ));
    });
    suite.report_speedup(&single_name, &parallel_name);

    // Parity: the parallel schedule is bit-identical, not just close.
    let seq = factorize_precgd(
        &target,
        &PrecGdOptions { b, r, iters, trace_every: 0, parallel: false, ..Default::default() },
    );
    let par = factorize_precgd(
        &target,
        &PrecGdOptions { b, r, iters, trace_every: 0, parallel: true, ..Default::default() },
    );
    assert_eq!(
        seq.rel_error, par.rel_error,
        "block-parallel PrecGD must be bit-identical to single-thread"
    );
    println!("parity: single vs parallel rel-err {:.3e} (bit-identical)", par.rel_error);

    let single_ms = suite.mean_of(&single_name).unwrap().as_secs_f64() * 1e3;
    let parallel_ms = suite.mean_of(&parallel_name).unwrap().as_secs_f64() * 1e3;
    let speedup = single_ms / parallel_ms.max(1e-9);
    // Throughput metric the bench-trend job tracks: PrecGD iterations/s
    // through the parallel path.
    let iters_per_sec = iters as f64 / (parallel_ms / 1e3);

    // --- Per-structure compressor cost at a 50% budget. ---
    let m = if fast { 64 } else { 96 };
    let layer = rng.gaussian_matrix(m, m, 1.0);
    let comp = Compressor { blast_iters: if fast { 10 } else { 30 }, ..Default::default() };
    let mut per_structure = Vec::new();
    for s in [
        Structure::LowRank,
        Structure::Monarch { b: 4 },
        Structure::BlockDiag { b: 4 },
        Structure::Blast { b: 4 },
    ] {
        let name = format!("compress {m}x{m} {}", s.name());
        suite.bench(&name, || {
            std::hint::black_box(comp.compress(&layer, s, 0.5));
        });
        let mean_ms = suite.mean_of(&name).unwrap().as_secs_f64() * 1e3;
        let rel_error = comp
            .compress(&layer, s, 0.5)
            .map(|w| w.rel_error(&layer))
            .unwrap_or(f64::NAN);
        per_structure.push(obj(vec![
            ("structure", Json::from(s.name())),
            ("mean_ms", Json::from(mean_ms)),
            ("rel_error", Json::from(rel_error)),
        ]));
    }

    // --- Machine-readable report. ---
    let enforced = threads >= 4 && !fast;
    let pass = speedup >= 2.0;
    let out_path = std::env::var("BLAST_FACTORIZE_BENCH_OUT")
        .unwrap_or_else(|_| concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_factorize.json").into());
    let root = obj(vec![
        ("bench", Json::from("factorize")),
        (
            "config",
            obj(vec![
                ("n", Json::from(n)),
                ("b", Json::from(b)),
                ("r", Json::from(r)),
                ("iters", Json::from(iters)),
                ("threads", Json::from(threads)),
                ("fast_mode", Json::from(fast)),
            ]),
        ),
        (
            "precgd",
            obj(vec![
                ("single_thread_ms", Json::from(single_ms)),
                ("parallel_ms", Json::from(parallel_ms)),
                ("speedup", Json::from(speedup)),
                ("iters_per_sec", Json::from(iters_per_sec)),
                ("rel_error", Json::from(par.rel_error)),
            ]),
        ),
        ("per_structure", Json::Arr(per_structure)),
        (
            "gate",
            obj(vec![
                ("min_speedup", Json::from(2.0)),
                ("enforced", Json::from(enforced)),
                ("pass", Json::from(pass)),
            ]),
        ),
    ]);
    match std::fs::write(&out_path, root.to_string_pretty()) {
        Ok(()) => println!("wrote {out_path}"),
        Err(e) => println!("could not write {out_path}: {e}"),
    }

    // Acceptance gate: parallel PrecGD >= 2x single-thread on >= 4 cores.
    if !pass {
        let msg = format!(
            "block-parallel PrecGD ({parallel_ms:.1}ms) must be >= 2x single-thread \
             ({single_ms:.1}ms) on >= 4 cores, got {speedup:.2}x on {threads} threads"
        );
        assert!(!enforced, "acceptance gate: {msg}");
        println!("WARNING (not fatal: {} threads, fast={fast}): {msg}", threads);
    } else {
        println!("gate: parallel PrecGD >= 2x single-thread — OK ({speedup:.2}x)");
    }
}
