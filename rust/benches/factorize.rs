//! Bench: factorization step cost (Fig. 3 / Fig. 9 infrastructure) —
//! GD vs PrecGD per-iteration cost and full-solve cost across b and r.

use blast_repro::factorize::{factorize_gd, factorize_precgd, GdOptions, PrecGdOptions};
use blast_repro::tensor::{matmul_nt, Rng};
use blast_repro::util::bench::BenchSuite;

fn main() {
    let mut suite = BenchSuite::new("factorize — Algorithm 2 cost");
    let mut rng = Rng::new(0);
    let n = 128;
    let u = rng.gaussian_matrix(n, 8, 1.0);
    let v = rng.gaussian_matrix(n, 8, 1.0);
    let target = matmul_nt(&u, &v).scale(1.0 / 8f32.sqrt());

    for &(b, r) in &[(4usize, 8usize), (8, 8), (8, 32), (16, 32)] {
        suite.bench(&format!("GD 10 iters n={n} b={b} r={r}"), || {
            std::hint::black_box(factorize_gd(
                &target,
                &GdOptions { b, r, iters: 10, trace_every: 0, ..Default::default() },
            ));
        });
        suite.bench(&format!("PrecGD 10 iters n={n} b={b} r={r}"), || {
            std::hint::black_box(factorize_precgd(
                &target,
                &PrecGdOptions { b, r, iters: 10, trace_every: 0, ..Default::default() },
            ));
        });
    }

    // Convergence-to-tolerance comparison (the Fig. 3 story in one line):
    // iterations are equal; PrecGD reaches far lower error.
    let gd = factorize_gd(
        &target,
        &GdOptions { b: 8, r: 32, iters: 40, trace_every: 0, ..Default::default() },
    );
    let pgd = factorize_precgd(
        &target,
        &PrecGdOptions { b: 8, r: 32, iters: 40, trace_every: 0, ..Default::default() },
    );
    println!(
        "--> after 40 iters (r=4r*): GD rel-err {:.3e} vs PrecGD {:.3e} ({:.0}x better)",
        gd.rel_error,
        pgd.rel_error,
        gd.rel_error / pgd.rel_error.max(1e-12)
    );
}
