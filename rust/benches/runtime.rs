//! Bench: Table 4 — decode runtime vs compression ratio and block count,
//! both at real Llama-7B layer shapes (matvec) and end-to-end TinyLM
//! generation (L ∈ {10, 100}).

use blast_repro::blast::blast_rank_for_ratio;
use blast_repro::nn::attention::StructureKind;
use blast_repro::nn::gpt::{LmConfig, TinyLM};
use blast_repro::tensor::Rng;
use blast_repro::util::bench::BenchSuite;

fn main() {
    let mut suite = BenchSuite::new("runtime — Table 4 decode");

    // End-to-end generation at TinyLM scale.
    let make = |s: StructureKind| {
        let mut rng = Rng::new(1);
        let mut cfg = LmConfig::tiny(s);
        cfg.max_seq = 128;
        TinyLM::new(cfg, &mut rng)
    };
    let rows: Vec<(String, TinyLM)> = vec![
        ("CR0 dense".into(), make(StructureKind::Dense)),
        (
            "CR20 b=2".into(),
            make(StructureKind::Blast { b: 2, r: blast_rank_for_ratio(128, 64, 2, 0.2).unwrap() }),
        ),
        (
            "CR20 b=4".into(),
            make(StructureKind::Blast { b: 4, r: blast_rank_for_ratio(128, 64, 4, 0.2).unwrap() }),
        ),
        (
            "CR50 b=4".into(),
            make(StructureKind::Blast { b: 4, r: blast_rank_for_ratio(128, 64, 4, 0.5).unwrap() }),
        ),
    ];
    for &l in &[10usize, 100] {
        let dense_name = format!("generate L={l} CR0 dense");
        for (label, model) in &rows {
            let name = format!("generate L={l} {label}");
            suite.bench_throughput(&name, l as f64, "tok", || {
                std::hint::black_box(model.generate(&[1, 2, 3], l));
            });
        }
        for (label, _) in &rows[1..] {
            suite.report_speedup(&dense_name, &format!("generate L={l} {label}"));
        }
    }

    // Matvec at true Llama shapes (the Table 4 mechanism).
    println!("\n-- raw matvec at Llama-7B shapes --");
    blast_repro::experiments::runtime_exp::print_matvec_sweep(3);
}
