//! Bench: PJRT artifact execution — compile-once cost and per-call
//! latency of the forward / loss / train_step artifacts (the L2 hot path
//! of the E2E example). Skips when artifacts are missing.

use blast_repro::runtime::executor::{load_params_ordered, TensorValue};
use blast_repro::runtime::{Manifest, PjrtEngine};
use blast_repro::util::bench::BenchSuite;

fn main() {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        println!("pjrt_exec bench skipped: run `make artifacts` first");
        return;
    }
    let manifest = Manifest::load("artifacts").expect("manifest");
    let mut engine = PjrtEngine::cpu().expect("client");
    let mut suite = BenchSuite::new("pjrt_exec — artifact execution");

    for variant in ["tinylm_dense", "tinylm_blast"] {
        let Ok(entry) = manifest.find(&format!("{variant}.forward")) else {
            continue;
        };
        // Compile time (one-shot, reported not benched).
        let t0 = std::time::Instant::now();
        let exe = engine.load(entry).expect("compile");
        println!("compile {}: {:?}", entry.name, t0.elapsed());
        let mut args = load_params_ordered(entry).expect("params");
        let seq = entry.arg_shapes.last().unwrap()[0];
        args.push(TensorValue::I32 {
            shape: vec![seq],
            data: (0..seq as i32).map(|i| i % 9).collect(),
        });
        suite.bench_throughput(
            &format!("{variant}.forward seq={seq}"),
            seq as f64,
            "tok",
            || {
                std::hint::black_box(exe.run(&args).unwrap());
            },
        );
    }

    // train_step per-call cost.
    if let Ok(entry) = manifest.find("tinylm_blast.train_step") {
        let exe = engine.load(entry).expect("compile train");
        let n = entry.param_names.len();
        let mut args = load_params_ordered(entry).expect("params");
        for i in 0..2 * n + 1 {
            let shape = entry.arg_shapes[n + i].clone();
            let numel: usize = shape.iter().product::<usize>().max(1);
            args.push(TensorValue::F32 { shape, data: vec![0.0; numel] });
        }
        let bshape = entry.arg_shapes[3 * n + 1].clone();
        let count: usize = bshape.iter().product();
        args.push(TensorValue::I32 {
            shape: bshape,
            data: (0..count as i32).map(|i| i % 11).collect(),
        });
        args.push(TensorValue::scalar_f32(1e-3));
        suite.bench("tinylm_blast.train_step", || {
            std::hint::black_box(exe.run(&args).unwrap());
        });
    }
    println!("pjrt platform: {}", engine.platform());
}
