//! Training drivers: from-scratch training (§3.1 / §4.1) and the
//! compression + re-training pipeline (§3.2 / §4.2), built on the manual
//! backprop in [`crate::nn`].

pub mod lm_trainer;
pub mod vit_trainer;
pub mod compress_model;

pub use compress_model::{
    compress_lm, linear_weight_from_compressed, retrain_lm, CompressReport,
};
pub use lm_trainer::{train_lm, LmTrainConfig, TrainLog};
pub use vit_trainer::{train_vit, VitTrainConfig};
