//! From-scratch LM training loop (paper §3.1): standard AdamW + cosine
//! schedule over the structured model, no special treatment of the BLAST
//! factors — exactly the paper's point that BLAST trains with vanilla
//! optimizers.

use crate::data::corpus::LmDataset;
use crate::nn::gpt::TinyLM;
use crate::nn::param::{AdamW, CosineSchedule};

/// Training configuration.
#[derive(Clone, Copy, Debug)]
pub struct LmTrainConfig {
    pub steps: usize,
    pub seq_len: usize,
    pub batch: usize,
    pub lr: f32,
    pub weight_decay: f32,
    pub warmup_steps: usize,
    pub log_every: usize,
    pub seed: u64,
}

impl Default for LmTrainConfig {
    fn default() -> Self {
        LmTrainConfig {
            steps: 200,
            seq_len: 32,
            batch: 4,
            lr: 3e-3,
            weight_decay: 0.01,
            warmup_steps: 10,
            log_every: 0,
            seed: 0,
        }
    }
}

/// Loss trace of a run.
#[derive(Clone, Debug, Default)]
pub struct TrainLog {
    /// (step, train loss) samples.
    pub losses: Vec<(usize, f64)>,
    pub final_loss: f64,
}

/// Train `model` in place on `data`; returns the loss trace.
pub fn train_lm(model: &mut TinyLM, data: &LmDataset, cfg: &LmTrainConfig) -> TrainLog {
    let mut opt = AdamW::new(cfg.lr, cfg.weight_decay);
    let sched = CosineSchedule {
        base_lr: cfg.lr,
        min_lr: cfg.lr * 0.01,
        warmup_steps: cfg.warmup_steps,
        total_steps: cfg.steps,
        warmup_start: cfg.lr * 0.01,
    };
    let mut log = TrainLog::default();
    let mut batcher = data.batcher(cfg.seq_len, cfg.seed);

    let mut running = 0.0f64;
    let mut running_n = 0usize;
    for step in 0..cfg.steps {
        model.zero_grads();
        let mut loss_sum = 0.0f64;
        for _ in 0..cfg.batch {
            let seq = batcher.next_sequence();
            let (loss, cache, dlogits) = model.loss_t(&seq);
            model.backward(&cache, &dlogits);
            loss_sum += loss;
        }
        // Mean over the batch: scale grads.
        let scale = 1.0 / cfg.batch as f32;
        for p in model.params_mut() {
            p.g.scale_inplace(scale);
        }
        let lr_now = sched.lr_at(step);
        opt.step(&mut model.params_mut(), lr_now);

        let loss = loss_sum / cfg.batch as f64;
        running += loss;
        running_n += 1;
        if cfg.log_every > 0 && (step % cfg.log_every == 0 || step + 1 == cfg.steps) {
            println!(
                "step {step:>5}  loss {:.4}  lr {lr_now:.2e}",
                running / running_n as f64
            );
            running = 0.0;
            running_n = 0;
        }
        if step % 10 == 0 || step + 1 == cfg.steps {
            log.losses.push((step, loss));
        }
        log.final_loss = loss;
    }
    log
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::corpus::SyntheticCorpus;
    use crate::nn::attention::StructureKind;
    use crate::nn::gpt::LmConfig;
    use crate::tensor::Rng;

    #[test]
    fn training_reduces_loss_dense_and_blast() {
        let corpus = SyntheticCorpus::generate(64, 4000, 500);
        let data = corpus.train_dataset();
        for s in [StructureKind::Dense, StructureKind::Blast { b: 4, r: 6 }] {
            let mut rng = Rng::new(500);
            let mut lm = TinyLM::new(LmConfig::tiny(s), &mut rng);
            let cfg = LmTrainConfig { steps: 80, ..Default::default() };
            let log = train_lm(&mut lm, &data, &cfg);
            let first = log.losses.first().unwrap().1;
            let last = log.final_loss;
            assert!(
                last < first - 0.5,
                "{s:?}: loss {first} -> {last} did not improve"
            );
        }
    }

    #[test]
    fn log_has_entries() {
        let corpus = SyntheticCorpus::generate(64, 2000, 100);
        let data = corpus.train_dataset();
        let mut rng = Rng::new(501);
        let mut lm = TinyLM::new(LmConfig::tiny(StructureKind::Dense), &mut rng);
        let log = train_lm(&mut lm, &data, &LmTrainConfig { steps: 21, ..Default::default() });
        assert!(log.losses.len() >= 3);
    }
}
