//! Whole-model compression + re-training pipeline (paper §3.2 / §4.2).
//!
//! Walks every structured-eligible linear in a trained dense model,
//! compresses each weight with the chosen structure at a target ratio
//! (BLAST via Algorithm 2 PrecGD, baselines via SVD constructions), swaps
//! the compressed representation back into the model, and optionally
//! re-trains — the "re-training recovers performance with only 0.49B
//! tokens" workflow of Table 3 / Fig. 7.

use crate::factorize::{Compressor, Structure};
use crate::nn::gpt::TinyLM;
use crate::nn::linear::{Linear, LinearWeight};
use crate::nn::param::PTensor;
use crate::tensor::Matrix;

/// Summary of a compression run.
#[derive(Clone, Debug)]
pub struct CompressReport {
    pub structure: String,
    pub ratio: f64,
    pub layers_compressed: usize,
    pub params_before: usize,
    pub params_after: usize,
    /// Mean per-layer relative reconstruction error.
    pub mean_rel_error: f64,
}

impl CompressReport {
    pub fn achieved_ratio(&self) -> f64 {
        1.0 - self.params_after as f64 / self.params_before.max(1) as f64
    }
}

/// Convert a factorization-side [`CompressedWeight`] into the trainable
/// [`LinearWeight`] representation for an `out×inp` layer. Shared by
/// [`compress_linear`] and the parallel compression pipeline
/// ([`crate::factorize::pipeline`]), which checkpoints compressed factors
/// to disk between the two.
///
/// [`CompressedWeight`]: crate::factorize::CompressedWeight
pub fn linear_weight_from_compressed(
    compressed: crate::factorize::CompressedWeight,
    out: usize,
    inp: usize,
) -> LinearWeight {
    match compressed {
        crate::factorize::CompressedWeight::Dense(m) => LinearWeight::Dense { w: PTensor::new(m) },
        crate::factorize::CompressedWeight::LowRank(w) => LinearWeight::LowRank {
            p: PTensor::new(w.p),
            q: PTensor::new(w.q),
        },
        crate::factorize::CompressedWeight::Blast(bm) => {
            let tmp = Linear::from_blast_matrix(&bm);
            tmp.weight
        }
        crate::factorize::CompressedWeight::Monarch(w) => {
            let b = w.b;
            LinearWeight::Monarch {
                b,
                t: w.t,
                out,
                inp,
                rb: w.r_bases.into_iter().map(PTensor::new).collect(),
                l: w.l.into_iter().flatten().map(PTensor::new).collect(),
            }
        }
        crate::factorize::CompressedWeight::BlockDiag(w) => {
            let b = w.b;
            let (pd, qd): (Vec<Matrix>, Vec<Matrix>) = w.blocks.into_iter().unzip();
            LinearWeight::BlockDiag {
                b,
                out,
                inp,
                pd: pd.into_iter().map(PTensor::new).collect(),
                qd: qd.into_iter().map(PTensor::new).collect(),
            }
        }
    }
}

/// Replace one dense linear's weight with a compressed structure (bias is
/// preserved). Returns the relative reconstruction error, or None if the
/// budget is infeasible for this layer.
pub fn compress_linear(
    layer: &mut Linear,
    compressor: &Compressor,
    structure: Structure,
    ratio: f64,
) -> Option<f64> {
    let dense = layer.dense_weight();
    let compressed = compressor.compress(&dense, structure, ratio)?;
    let rel = compressed.rel_error(&dense);
    layer.weight = linear_weight_from_compressed(compressed, dense.rows, dense.cols);
    // The weight structure changed in place: drop the cached execution
    // plan so the next dispatch lowers the new structure (Linear::plan
    // also self-validates, but resetting here keeps the layer-cache
    // hit path).
    layer.plan = Default::default();
    Some(rel)
}

/// Compress every transformer linear of a trained LM in place (embeddings
/// and head stay dense, as in the paper). Returns the report.
///
/// Layers run through the parallel work queue of
/// [`crate::factorize::pipeline`] with deterministic per-layer seeds, so
/// multi-layer compression scales with cores while staying reproducible.
pub fn compress_lm(
    model: &mut TinyLM,
    structure: Structure,
    ratio: f64,
    compressor: &Compressor,
) -> CompressReport {
    let params_before = model.num_params();
    let mut named: Vec<(String, &mut Linear)> = Vec::new();
    for (i, blk) in model.blocks.iter_mut().enumerate() {
        named.push((format!("block{i}.attn.wqkv"), &mut blk.attn.wqkv));
        named.push((format!("block{i}.attn.wo"), &mut blk.attn.wo));
        named.push((format!("block{i}.fc1"), &mut blk.fc1));
        named.push((format!("block{i}.fc2"), &mut blk.fc2));
    }
    let errs = crate::factorize::compress_linears_parallel(named, compressor, structure, ratio);
    let layers = errs.iter().flatten().count();
    let err_sum: f64 = errs.iter().flatten().sum();
    let params_after = model.num_params();
    CompressReport {
        structure: structure.name(),
        ratio,
        layers_compressed: layers,
        params_before,
        params_after,
        mean_rel_error: if layers > 0 { err_sum / layers as f64 } else { 0.0 },
    }
}

/// Re-train a compressed LM (the paper's "re-training" stage): a short
/// AdamW run on the training corpus starting from the compressed factors.
pub fn retrain_lm(
    model: &mut TinyLM,
    data: &crate::data::corpus::LmDataset,
    steps: usize,
) -> crate::train::TrainLog {
    let cfg = crate::train::LmTrainConfig {
        steps,
        lr: 1e-3, // lower LR than from-scratch, as in Table 6
        warmup_steps: steps / 20,
        ..Default::default()
    };
    crate::train::train_lm(model, data, &cfg)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::corpus::SyntheticCorpus;
    use crate::nn::attention::StructureKind;
    use crate::nn::gpt::LmConfig;
    use crate::tensor::Rng;

    fn trained_dense_lm(corpus: &SyntheticCorpus, steps: usize) -> TinyLM {
        let mut rng = Rng::new(730);
        let mut lm = TinyLM::new(LmConfig::tiny(StructureKind::Dense), &mut rng);
        let cfg = crate::train::LmTrainConfig { steps, ..Default::default() };
        crate::train::train_lm(&mut lm, &corpus.train_dataset(), &cfg);
        lm
    }

    #[test]
    fn compression_reduces_params() {
        let corpus = SyntheticCorpus::generate(64, 4000, 640);
        let lm = trained_dense_lm(&corpus, 30);
        for s in [
            Structure::LowRank,
            Structure::Blast { b: 4 },
            Structure::Monarch { b: 4 },
            Structure::BlockDiag { b: 4 },
        ] {
            let mut m = lm.clone();
            let comp = Compressor { blast_iters: 25, ..Default::default() };
            let report = compress_lm(&mut m, s, 0.5, &comp);
            assert!(report.layers_compressed > 0, "{s:?}");
            assert!(
                report.params_after < report.params_before,
                "{s:?}: {} -> {}",
                report.params_before,
                report.params_after
            );
            // Model still runs.
            let ppl = crate::eval::perplexity(&m, &corpus.valid_dataset(), 32, 4);
            assert!(ppl.is_finite() && ppl > 1.0, "{s:?} ppl {ppl}");
        }
    }

    #[test]
    fn blast_compression_lower_error_than_blockdiag() {
        // The flexibility claim at the model level: BLAST's mean layer
        // reconstruction error at 50% CR is below block-diagonal's.
        let corpus = SyntheticCorpus::generate(64, 4000, 640);
        let lm = trained_dense_lm(&corpus, 30);
        let comp = Compressor { blast_iters: 60, ..Default::default() };
        let mut m1 = lm.clone();
        let r_blast = compress_lm(&mut m1, Structure::Blast { b: 4 }, 0.5, &comp);
        let mut m2 = lm.clone();
        let r_bd = compress_lm(&mut m2, Structure::BlockDiag { b: 4 }, 0.5, &comp);
        assert!(
            r_blast.mean_rel_error < r_bd.mean_rel_error,
            "blast {} vs blockdiag {}",
            r_blast.mean_rel_error,
            r_bd.mean_rel_error
        );
    }

    #[test]
    fn retraining_recovers_perplexity() {
        let corpus = SyntheticCorpus::generate(64, 8000, 640);
        let lm = trained_dense_lm(&corpus, 120);
        let ppl_orig = crate::eval::perplexity(&lm, &corpus.valid_dataset(), 32, 6);
        let mut m = lm.clone();
        let comp = Compressor { blast_iters: 40, ..Default::default() };
        compress_lm(&mut m, Structure::Blast { b: 4 }, 0.5, &comp);
        let ppl_comp = crate::eval::perplexity(&m, &corpus.valid_dataset(), 32, 6);
        retrain_lm(&mut m, &corpus.train_dataset(), 60);
        let ppl_retrained = crate::eval::perplexity(&m, &corpus.valid_dataset(), 32, 6);
        assert!(
            ppl_retrained < ppl_comp,
            "retraining must help: {ppl_comp} -> {ppl_retrained} (orig {ppl_orig})"
        );
    }
}
