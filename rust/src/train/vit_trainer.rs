//! ViT training loop over the synthetic texture dataset (Fig. 4 / Table 1
//! from-scratch experiments and Fig. 6 re-training).

use crate::data::images::TextureDataset;
use crate::nn::param::{AdamW, CosineSchedule};
use crate::nn::vit::TinyViT;
use crate::tensor::Rng;

/// Training configuration.
#[derive(Clone, Copy, Debug)]
pub struct VitTrainConfig {
    pub steps: usize,
    pub batch: usize,
    pub lr: f32,
    pub weight_decay: f32,
    pub seed: u64,
    pub log_every: usize,
}

impl Default for VitTrainConfig {
    fn default() -> Self {
        VitTrainConfig {
            steps: 150,
            batch: 8,
            lr: 2e-3,
            weight_decay: 0.01,
            seed: 0,
            log_every: 0,
        }
    }
}

/// Train in place; returns the final running training loss.
pub fn train_vit(model: &mut TinyViT, data: &TextureDataset, cfg: &VitTrainConfig) -> f64 {
    let mut opt = AdamW::new(cfg.lr, cfg.weight_decay);
    let sched = CosineSchedule {
        base_lr: cfg.lr,
        min_lr: cfg.lr * 0.01,
        warmup_steps: cfg.steps / 20,
        total_steps: cfg.steps,
        warmup_start: cfg.lr * 0.01,
    };
    let mut rng = Rng::new(cfg.seed ^ 0xDA7A);
    let mut last = f64::NAN;
    for step in 0..cfg.steps {
        model.zero_grads();
        let mut loss_sum = 0.0f64;
        for _ in 0..cfg.batch {
            let label = rng.below(data.n_classes);
            let img = data.sample(label, &mut rng);
            loss_sum += model.train_example(&img.pixels, img.label);
        }
        let scale = 1.0 / cfg.batch as f32;
        for p in model.params_mut() {
            p.g.scale_inplace(scale);
        }
        opt.step(&mut model.params_mut(), sched.lr_at(step));
        last = loss_sum / cfg.batch as f64;
        if cfg.log_every > 0 && step % cfg.log_every == 0 {
            println!("vit step {step:>5} loss {last:.4}");
        }
    }
    last
}

/// Held-out accuracy over freshly sampled images.
pub fn eval_vit_accuracy(
    model: &TinyViT,
    data: &TextureDataset,
    per_class: usize,
    seed: u64,
) -> f64 {
    let mut rng = Rng::new(seed ^ 0xE7A1);
    let mut correct = 0usize;
    let mut total = 0usize;
    for c in 0..data.n_classes {
        for _ in 0..per_class {
            let img = data.sample(c, &mut rng);
            if model.predict(&img.pixels) == c {
                correct += 1;
            }
            total += 1;
        }
    }
    100.0 * correct as f64 / total as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::attention::StructureKind;
    use crate::nn::vit::VitConfig;

    #[test]
    fn vit_learns_textures() {
        let data = TextureDataset::new(16, 4);
        let mut rng = Rng::new(720);
        let mut vit = TinyViT::new(
            VitConfig { n_classes: 4, ..VitConfig::tiny(StructureKind::Dense) },
            &mut rng,
        );
        let before = eval_vit_accuracy(&vit, &data, 10, 1);
        train_vit(&mut vit, &data, &VitTrainConfig { steps: 120, ..Default::default() });
        let after = eval_vit_accuracy(&vit, &data, 10, 1);
        assert!(
            after > before + 20.0 || after > 70.0,
            "accuracy {before} -> {after}"
        );
    }
}
