//! Kernel engine: packed SIMD microkernel GEMM + structure-plan
//! execution with a per-(plan, shape) autotuner.
//!
//! Every inference-time matrix product in the repo — the structured
//! `Y = X · Wᵀ` of `nn::linear` (Dense, Low-Rank, Monarch,
//! Block-Diagonal, and BLAST weights alike), the attention score/context
//! products, and the BLAST Algorithm-1 product of `blast::matmul` —
//! dispatches through this subsystem instead of calling a fixed loop
//! nest. The pieces:
//!
//! * [`micro`] — the BLIS-style packed microkernel and the engine's
//!   **fixed-lane accumulation contract**: every contraction is an
//!   8-lane strided partial sum over ascending k-chunks (zero-padded
//!   tail) reduced in a fixed tree order, identical across every
//!   kernel, batch size, thread schedule, and SIMD path. Runtime
//!   dispatch between a portable auto-vectorizing path and an
//!   `std::arch` AVX2 path is controlled by `BLAST_SIMD=auto|avx2|
//!   portable` (default `auto`; both paths are bit-identical).
//! * [`pack`] — B-panel packing: weights are repacked once per
//!   (weights, shape) into microkernel panels and cached process-wide
//!   with sampled-fingerprint invalidation on in-place mutation and
//!   byte-bounded LRU eviction (`BLAST_PACK_CACHE_MB`).
//! * [`plan`] — the **structure-plan IR**: every weight structure
//!   lowers to a short sequence of packed-microkernel stages
//!   (block-windowed `Gemm` over cached factor panels, the BLAST
//!   coupling stage) with thread-local inter-stage scratch. One tuned
//!   execution path serves all five structures — the paper's
//!   one-abstraction claim (§3, Table 1) realized at the execution
//!   layer.
//! * [`naive::NaiveKernel`] — the contract reference (no blocking, no
//!   packing, no SIMD dispatch, no threads; plans run per element with
//!   gathered columns). Every other kernel must match it **bit for
//!   bit** (`tests/kernel_parity.rs`).
//! * [`tiled::TiledKernel`] / [`parallel::ParallelKernel`] — the packed
//!   dense kernels for raw `DenseNt` ops (attention activations and
//!   other weight-agnostic products).
//! * [`plan::PlanKernel`] — the packed structure-plan executor,
//!   registered in sequential (`plan_seq`) and batch-row-parallel
//!   (`plan_par`) variants; the autotuner picks per (plan signature,
//!   shape, batch-bucket), so Monarch/BlockDiag/LowRank shapes get
//!   tuned execution instead of hardcoded loops. Int8 variants
//!   (`plan_seq_i8` / `plan_par_i8`) run quantized weight panels
//!   through the i32-exact int8 microkernels; they only support
//!   `q=i8` plan signatures, while the f32 variants support every
//!   plan — so a quantized signature gets a genuine f32-vs-int8
//!   shoot-out at tuning time.
//! * [`autotune::Autotuner`] — benchmarks the candidate kernels the
//!   first time each `(structure, shape, batch-bucket)` key is seen,
//!   caches the winner in-process, and (optionally) persists the plan
//!   table as JSON via `util::json` so later processes skip the probe.
//!   Because of the fixed-lane contract its choice can never change a
//!   result bit.
//!
//! ## Dispatch
//!
//! [`engine()`] returns the process-wide [`KernelEngine`]. Hot paths
//! call [`KernelEngine::matmul_nt`] / [`KernelEngine::plan_act`] (or
//! their allocation-free `*_into` variants, which write into a
//! caller-owned output matrix and are what the zero-allocation decode
//! path uses); the engine resolves the plan (tuning on a miss) and runs
//! the chosen kernel. Layers resolve their [`plan::StructPlan`] from a
//! per-layer [`plan::PlanCell`] (built at model load by
//! `TinyLM::pretune`), so a steady-state structured `forward_into` is
//! allocation-free for **every** structure.
//!
//! Environment knobs:
//!
//! * `BLAST_KERNEL=<name>` — force one kernel (e.g. `naive`,
//!   `dense_tiled`, `dense_parallel`, `plan_seq`, `plan_par`,
//!   `plan_seq_i8`, `plan_par_i8`) for every op it supports; used by
//!   the benches to compare kernels.
//! * `BLAST_SIMD=auto|avx2|portable` — SIMD path selection (see above).
//! * `BLAST_PACK_CACHE_MB=<mib>` — packed-panel cache budget.
//! * `BLAST_AUTOTUNE_CACHE=<path>` — load the plan table from `<path>`
//!   at startup and re-persist it after each new tuning decision.
//!
//! ## Plan-table format
//!
//! ```json
//! {
//!   "version": 1,
//!   "plans": [
//!     {"op": "plan:blast(b=8,r=32)", "m": 1024, "n": 1024, "batch": 8,
//!      "kernel": "plan_par"}
//!   ]
//! }
//! ```
//!
//! `op` is the structure-plan signature (`"dense"` for raw dense ops;
//! `"plan:dense"`, `"plan:lowrank(r=…)"`, `"plan:monarch(b=…,t=…)"`,
//! `"plan:blockdiag(b=…,t=…)"`, `"plan:blast(b=…,r=…)"` for plan ops;
//! int8-quantized plans carry a `q=i8` suffix inside the parens, e.g.
//! `"plan:blast(b=…,r=…,q=i8)"` / `"plan:dense(q=i8)"`, and tune
//! independently of their f32 twins), and `batch` is the bucket ceiling
//! (1, 8, 64, 4096), so decode (batch=1) and prefill (batch≫1) tune
//! independently. Entries with unknown tags or kernel names (e.g. the
//! pre-plan `"blast(b=…)"` tags) are skipped and simply re-tuned.
//! Regenerate a plan file with
//! `BLAST_AUTOTUNE_CACHE=plans.json cargo bench --bench blast_matmul`.

pub mod autotune;
pub mod micro;
pub mod naive;
pub mod pack;
pub mod parallel;
pub mod plan;
pub mod tiled;

pub use autotune::{Autotuner, PlanKey};
pub use micro::{SimdMode, LANES, MR, NR};
pub use naive::NaiveKernel;
pub use pack::{pack_cache, PackCache, PackedPanels, QuantPanels};
pub use parallel::ParallelKernel;
pub use plan::{
    plan_cache, PlanCache, PlanCell, PlanKernel, PlanKind, PlanOperands, PlanSig, QuantMode,
    StructPlan,
};
pub use tiled::TiledKernel;

use crate::blast::BlastMatrix;
use crate::nn::param::PTensor;
use crate::tensor::Matrix;
use crate::util::par;
use std::sync::OnceLock;

/// Where a [`PlanOperands`] factor group's matrices live. Borrowed, so
/// building operands is allocation-free — this runs on every decode
/// dispatch.
#[derive(Clone, Copy)]
pub enum Factors<'a> {
    /// Plain matrices (`BlastMatrix::u` / `::v`, transient factors).
    Mats(&'a [Matrix]),
    /// Trainable parameters (`nn::linear::LinearWeight` factor lists).
    Params(&'a [PTensor]),
}

impl<'a> Factors<'a> {
    #[inline]
    fn get(&self, i: usize) -> &'a Matrix {
        match self {
            Factors::Mats(m) => &m[i],
            Factors::Params(p) => &p[i].v,
        }
    }
}

/// Where a plan's coupling table lives (also borrowed).
#[derive(Clone, Copy)]
pub enum Couplings<'a> {
    /// `BlastMatrix::s` — nested `[i][j] -> Vec<f32>` of length `r`.
    Nested(&'a [Vec<Vec<f32>>]),
    /// A packed `(b·b) × r` matrix, row `i·b + j` (the trainable layout).
    Packed(&'a Matrix),
}

/// One dispatchable operation over a row-major activation batch
/// `X (batch × in_features)`.
pub enum KernelOp<'a> {
    /// `Y = X · Wᵀ` with a raw dense weight `W (out × in)` — the
    /// attention-score / weight-agnostic primitive.
    DenseNt { w: &'a Matrix },
    /// A lowered weight structure: `Y = X · Aᵀ` executed as the plan's
    /// packed-microkernel stages over `ops`' factor storage.
    Plan { plan: &'a StructPlan, ops: PlanOperands<'a> },
}

/// Allocation-free structure identity of an op — the hot-path half of a
/// plan key (`PlanKey::for_op` runs on every dispatch, so this must not
/// build a `String`).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum OpTag {
    Dense,
    Plan(PlanSig),
}

impl OpTag {
    /// Stable textual form used in the JSON plan file
    /// (`"dense"` / `"plan:blast(b=8,r=32)"`).
    pub fn to_tag_string(self) -> String {
        match self {
            OpTag::Dense => "dense".to_string(),
            OpTag::Plan(sig) => sig.to_tag_string(),
        }
    }

    /// Inverse of [`to_tag_string`]; `None` on unknown tags (old or
    /// hand-edited plan files — including the pre-plan `"blast(b=…)"`
    /// form, which is deliberately retired and re-tunes).
    ///
    /// [`to_tag_string`]: OpTag::to_tag_string
    pub fn parse(tag: &str) -> Option<Self> {
        if tag == "dense" {
            return Some(OpTag::Dense);
        }
        PlanSig::parse(tag).map(OpTag::Plan)
    }
}

impl KernelOp<'_> {
    /// Output features of the op.
    pub fn out_features(&self) -> usize {
        match self {
            KernelOp::DenseNt { w } => w.rows,
            KernelOp::Plan { plan, .. } => plan.m,
        }
    }

    /// Input features of the op.
    pub fn in_features(&self) -> usize {
        match self {
            KernelOp::DenseNt { w } => w.cols,
            KernelOp::Plan { plan, .. } => plan.n,
        }
    }

    /// Structure identity for plan keys (allocation-free).
    pub fn tag(&self) -> OpTag {
        match self {
            KernelOp::DenseNt { .. } => OpTag::Dense,
            KernelOp::Plan { plan, .. } => OpTag::Plan(plan.sig),
        }
    }
}

/// A matmul kernel. Implementations must be pure functions of their
/// inputs (no observable internal state), `Send + Sync`, and
/// exact-shape-agnostic within the ops they support, and must follow
/// the engine's fixed-lane accumulation contract (see [`micro`]).
pub trait MatmulKernel: Send + Sync {
    /// Stable name (plan files store it).
    fn name(&self) -> &'static str;

    /// Whether this kernel can run `op` at the given batch size.
    fn supports(&self, op: &KernelOp<'_>, batch: usize) -> bool;

    /// Compute the op. `x` is `(batch × in_features)`; the result is
    /// `(batch × out_features)`.
    fn run(&self, x: &Matrix, op: &KernelOp<'_>) -> Matrix;

    /// Compute the op into a caller-owned output (shape is reset by the
    /// kernel; an adequately-sized buffer is reused without touching
    /// the allocator). The default falls back to [`run`] and therefore
    /// allocates — the optimized kernels override it; the decode hot
    /// path relies on those overrides for its zero-allocation
    /// guarantee.
    ///
    /// [`run`]: MatmulKernel::run
    fn run_into(&self, x: &Matrix, op: &KernelOp<'_>, out: &mut Matrix) {
        *out = self.run(x, op);
    }
}

/// The process-wide engine: registered kernels + the autotuner that maps
/// `(structure, shape, batch-bucket)` keys onto them.
pub struct KernelEngine {
    kernels: Vec<Box<dyn MatmulKernel>>,
    tuner: Autotuner,
    forced: Option<usize>,
}

impl KernelEngine {
    /// Engine with the standard kernel set and env-configured tuner.
    pub fn with_default_kernels() -> Self {
        let kernels: Vec<Box<dyn MatmulKernel>> = vec![
            Box::new(NaiveKernel),
            Box::new(TiledKernel),
            Box::new(ParallelKernel),
            Box::new(PlanKernel::sequential()),
            Box::new(PlanKernel::row_parallel()),
            Box::new(PlanKernel::sequential_i8()),
            Box::new(PlanKernel::row_parallel_i8()),
        ];
        let tuner = Autotuner::from_env();
        let forced = crate::util::config::EngineConfig::global()
            .kernel_force
            .as_deref()
            .and_then(|name| kernels.iter().position(|k| k.name() == name));
        KernelEngine { kernels, tuner, forced }
    }

    /// `Y = X · Wᵀ` through the tuned kernel for this shape.
    pub fn matmul_nt(&self, x: &Matrix, w: &Matrix) -> Matrix {
        assert_eq!(x.cols, w.cols, "matmul_nt shape mismatch: {:?} vs {:?}", x.shape(), w.shape());
        self.dispatch(x, &KernelOp::DenseNt { w })
    }

    /// [`matmul_nt`] into a caller-owned output (allocation-free once
    /// the buffer and the plan are warm).
    ///
    /// [`matmul_nt`]: KernelEngine::matmul_nt
    pub fn matmul_nt_into(&self, x: &Matrix, w: &Matrix, out: &mut Matrix) {
        assert_eq!(x.cols, w.cols, "matmul_nt shape mismatch: {:?} vs {:?}", x.shape(), w.shape());
        self.dispatch_into(x, &KernelOp::DenseNt { w }, out);
    }

    /// `Y = X · Wᵀ` with a *statically* chosen execution (serial below a
    /// work threshold, row-parallel above), bypassing both the autotuner
    /// and the pack cache.
    ///
    /// Use this for activation×activation products whose shapes vary per
    /// input (e.g. attention scores, where one operand dimension is the
    /// sequence length): tuning those would create a throwaway plan
    /// entry — and a probe run — for every distinct length, and packing
    /// them would churn the weight-panel cache. Like
    /// [`matmul_nt_serial`], this path deliberately ignores
    /// `BLAST_KERNEL` forcing: a forced packing kernel would insert
    /// every transient activation operand into the pack cache,
    /// evicting real layer-weight panels — and the fixed-lane contract
    /// makes the unpacked choice bit-identical to any forced kernel
    /// anyway, so forcing could never change results here.
    ///
    /// [`matmul_nt_serial`]: KernelEngine::matmul_nt_serial
    pub fn matmul_nt_static(&self, x: &Matrix, w: &Matrix) -> Matrix {
        assert_eq!(x.cols, w.cols, "matmul_nt shape mismatch: {:?} vs {:?}", x.shape(), w.shape());
        if x.rows == 0 {
            return Matrix::zeros(0, w.rows);
        }
        let mode = micro::simd_mode();
        let mut y = Matrix::zeros(x.rows, w.rows);
        // Same work threshold the tensor-level GEMMs use to decide
        // whether threads pay for themselves.
        if x.rows * w.rows * w.cols >= 64 * 64 * 64 && x.rows >= 2 {
            let n = w.rows;
            let chunk_rows = x.rows.div_ceil(par::num_threads()).max(1);
            par::par_chunks_mut(&mut y.data, chunk_rows * n, |ci, chunk| {
                let rows = chunk.len() / n;
                tiled::dense_nt_rows_unpacked(mode, x, w, ci * chunk_rows, rows, chunk);
            });
        } else {
            tiled::dense_nt_rows_unpacked(mode, x, w, 0, x.rows, &mut y.data);
        }
        y
    }

    /// `Y = X · Wᵀ` through the fixed serial unpacked path, guaranteed
    /// never to spawn worker threads. For callers that already own the
    /// thread-level parallelism — the factorization sweeps fan the `b×b`
    /// factor grid across the pool and the compression pipeline fans
    /// whole layers — where a nested `dense_parallel` dispatch would
    /// multiply live threads (workers × pool) and oversubscribe the
    /// machine. Deliberately ignores `BLAST_KERNEL` forcing: "no nested
    /// threads" is a correctness-of-scheduling contract, not a tuning
    /// preference. Bit-identical to every other dense path by the
    /// engine's fixed-lane contract.
    pub fn matmul_nt_serial(&self, x: &Matrix, w: &Matrix) -> Matrix {
        assert_eq!(
            x.cols,
            w.cols,
            "matmul_nt shape mismatch: {:?} vs {:?}",
            x.shape(),
            w.shape()
        );
        if x.rows == 0 {
            return Matrix::zeros(0, w.rows);
        }
        let mut y = Matrix::zeros(x.rows, w.rows);
        tiled::dense_nt_rows_unpacked(micro::simd_mode(), x, w, 0, x.rows, &mut y.data);
        y
    }

    /// `C = A · B` as a [`StructPlan::dense_t`] plan on the serial
    /// reference executor: `B`'s columns are gathered once per call
    /// into reused thread-local scratch (never allocating a `Bᵀ` — the
    /// pre-plan implementation transposed `B` afresh on every call),
    /// dispatched with the same fixed-lane contract, so the result is
    /// bit-identical to `matmul_nt_serial(a, Bᵀ)`. Serial and
    /// pack-cache-free like [`matmul_nt_serial`], for the same
    /// scheduling reasons.
    ///
    /// [`matmul_nt_serial`]: KernelEngine::matmul_nt_serial
    pub fn matmul_serial(&self, a: &Matrix, b: &Matrix) -> Matrix {
        assert_eq!(
            a.cols,
            b.rows,
            "matmul_serial shape mismatch: {:?} x {:?}",
            a.shape(),
            b.shape()
        );
        let plan = plan_cache().dense_t(b.cols, b.rows);
        self.plan_act_serial(a, &plan, &PlanOperands::single(b))
    }

    /// A structured product `Y = X · Aᵀ` through the tuned kernel for
    /// this (plan signature, shape, batch-bucket).
    pub fn plan_act(&self, x: &Matrix, plan: &StructPlan, ops: &PlanOperands<'_>) -> Matrix {
        self.dispatch(x, &KernelOp::Plan { plan, ops: *ops })
    }

    /// [`plan_act`] into a caller-owned output — the allocation-free
    /// structured hot path.
    ///
    /// [`plan_act`]: KernelEngine::plan_act
    pub fn plan_act_into(
        &self,
        x: &Matrix,
        plan: &StructPlan,
        ops: &PlanOperands<'_>,
        out: &mut Matrix,
    ) {
        self.dispatch_into(x, &KernelOp::Plan { plan, ops: *ops }, out);
    }

    /// Execute a plan on the serial reference path: per-element
    /// contract arithmetic, no worker threads, no autotuner probe, and
    /// — critically for the factorization sweeps, whose factors mutate
    /// every iteration — **no pack-cache traffic**. Bit-identical to
    /// the tuned [`plan_act`] by the fixed-lane contract.
    ///
    /// [`plan_act`]: KernelEngine::plan_act
    pub fn plan_act_serial(
        &self,
        x: &Matrix,
        plan: &StructPlan,
        ops: &PlanOperands<'_>,
    ) -> Matrix {
        ops.validate(plan, x);
        let mut y = Matrix::zeros(x.rows, plan.m);
        if x.rows > 0 {
            plan::execute_reference(micro::simd_mode(), x, plan, ops, &mut y.data);
        }
        y
    }

    /// BLAST Algorithm-1 activation product through the tuned kernel
    /// (the `BlastMatrix` convenience wrapper over [`plan_act`]).
    ///
    /// [`plan_act`]: KernelEngine::plan_act
    pub fn blast_act(&self, x: &Matrix, a: &BlastMatrix) -> Matrix {
        let plan = a.plan();
        self.plan_act(x, &plan, &a.plan_operands())
    }

    /// [`blast_act`] into a caller-owned output.
    ///
    /// [`blast_act`]: KernelEngine::blast_act
    pub fn blast_act_into(&self, x: &Matrix, a: &BlastMatrix, out: &mut Matrix) {
        let plan = a.plan();
        self.plan_act_into(x, &plan, &a.plan_operands(), out);
    }

    /// Dispatch an op, tuning on a plan miss.
    pub fn dispatch(&self, x: &Matrix, op: &KernelOp<'_>) -> Matrix {
        let idx = match self.resolve(x, op) {
            Some(i) => i,
            None => return Matrix::zeros(0, op.out_features()),
        };
        self.kernels[idx].run(x, op)
    }

    /// [`dispatch`] into a caller-owned output — the allocation-free hot
    /// path (plan lookup, packed panels, and kernel scratch are all
    /// cache hits in the steady state).
    ///
    /// [`dispatch`]: KernelEngine::dispatch
    pub fn dispatch_into(&self, x: &Matrix, op: &KernelOp<'_>, out: &mut Matrix) {
        let idx = match self.resolve(x, op) {
            Some(i) => i,
            None => {
                out.reset(0, op.out_features());
                return;
            }
        };
        self.kernels[idx].run_into(x, op, out);
    }

    /// Shared plan resolution: validate, short-circuit empty batches
    /// (`None`), apply `BLAST_KERNEL` forcing, tune on a miss. A cached
    /// choice that does not support the op (a stale or hand-edited plan
    /// file) is re-tuned rather than trusted.
    fn resolve(&self, x: &Matrix, op: &KernelOp<'_>) -> Option<usize> {
        if let KernelOp::Plan { plan, ops } = op {
            ops.validate(plan, x);
        }
        if x.rows == 0 {
            return None;
        }
        if let Some(i) = self.forced {
            if self.kernels[i].supports(op, x.rows) {
                return Some(i);
            }
        }
        let key = PlanKey::for_op(op, x.rows);
        Some(match self.tuner.lookup(&key, &self.kernels) {
            Some(i) if self.kernels[i].supports(op, x.rows) => i,
            _ => self.tuner.tune(&key, x, op, &self.kernels),
        })
    }

    /// Kernel by stable name (benches and tests compare specific kernels).
    pub fn kernel_named(&self, name: &str) -> Option<&dyn MatmulKernel> {
        self.kernels.iter().find(|k| k.name() == name).map(|k| k.as_ref())
    }

    /// Names of all registered kernels.
    pub fn kernel_names(&self) -> Vec<&'static str> {
        self.kernels.iter().map(|k| k.name()).collect()
    }

    /// The chosen kernel name for a key, if already planned.
    pub fn plan_for(&self, key: &PlanKey) -> Option<String> {
        self.tuner.plan_name(key)
    }

    /// Persist the current plan table as JSON.
    pub fn save_plans(&self, path: &std::path::Path) -> anyhow::Result<()> {
        self.tuner.save(path)
    }

    /// Load a plan table, merging over the in-process cache.
    pub fn load_plans(&self, path: &std::path::Path) -> anyhow::Result<usize> {
        self.tuner.load(path)
    }
}

static ENGINE: OnceLock<KernelEngine> = OnceLock::new();

/// The process-wide [`KernelEngine`] (constructed on first use).
pub fn engine() -> &'static KernelEngine {
    ENGINE.get_or_init(KernelEngine::with_default_kernels)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Rng;

    #[test]
    fn engine_matches_tensor_matmul_nt() {
        let mut rng = Rng::new(800);
        let x = rng.gaussian_matrix(5, 24, 1.0);
        let w = rng.gaussian_matrix(10, 24, 1.0);
        let y = engine().matmul_nt(&x, &w);
        let y_ref = crate::tensor::matmul_nt(&x, &w);
        assert_eq!(y.shape(), (5, 10));
        assert!(y.sub(&y_ref).fro_norm() < 1e-4 * (1.0 + y_ref.fro_norm()));
    }

    #[test]
    fn engine_matches_blast_dense_reconstruction() {
        let mut rng = Rng::new(801);
        let a = BlastMatrix::random_init(12, 18, 3, 4, 1.0, &mut rng);
        let x = rng.gaussian_matrix(6, 18, 1.0);
        let y = engine().blast_act(&x, &a);
        let y_ref = crate::tensor::matmul_nt(&x, &a.to_dense());
        assert_eq!(y.shape(), (6, 12));
        assert!(y.sub(&y_ref).fro_norm() < 1e-3 * (1.0 + y_ref.fro_norm()));
    }

    #[test]
    fn into_variants_bit_match_allocating_variants() {
        let mut rng = Rng::new(805);
        let x = rng.gaussian_matrix(4, 18, 1.0);
        let w = rng.gaussian_matrix(9, 18, 1.0);
        let y = engine().matmul_nt(&x, &w);
        let mut out = Matrix::zeros(1, 1);
        engine().matmul_nt_into(&x, &w, &mut out);
        assert_eq!(out.shape(), y.shape());
        assert_eq!(out.data, y.data);

        let a = BlastMatrix::random_init(12, 18, 3, 4, 1.0, &mut rng);
        let yb = engine().blast_act(&x, &a);
        let mut outb = Matrix::zeros(0, 0);
        engine().blast_act_into(&x, &a, &mut outb);
        assert_eq!(outb.shape(), yb.shape());
        assert_eq!(outb.data, yb.data);
    }

    #[test]
    fn static_and_serial_paths_bit_match_tuned_dispatch() {
        let mut rng = Rng::new(806);
        let x = rng.gaussian_matrix(5, 33, 1.0);
        let w = rng.gaussian_matrix(11, 33, 1.0);
        let tuned = engine().matmul_nt(&x, &w);
        let stat = engine().matmul_nt_static(&x, &w);
        let serial = engine().matmul_nt_serial(&x, &w);
        assert_eq!(tuned.data, stat.data, "static path diverged from tuned");
        assert_eq!(tuned.data, serial.data, "serial path diverged from tuned");
    }

    #[test]
    fn matmul_serial_matches_tensor_matmul() {
        let mut rng = Rng::new(804);
        let a = rng.gaussian_matrix(7, 12, 1.0);
        let b = rng.gaussian_matrix(12, 9, 1.0);
        let y = engine().matmul_serial(&a, &b);
        let y_ref = crate::tensor::matmul(&a, &b);
        assert_eq!(y.shape(), (7, 9));
        assert!(y.sub(&y_ref).fro_norm() < 1e-4 * (1.0 + y_ref.fro_norm()));
        // Bit-identical to the transpose-then-NT form it replaced.
        let nt = engine().matmul_nt_serial(&a, &b.transpose());
        assert_eq!(y.data, nt.data, "col-gathered plan diverged from transpose-then-NT");
    }

    #[test]
    fn empty_batch_short_circuits() {
        let w = Matrix::zeros(4, 6);
        let x = Matrix::zeros(0, 6);
        let y = engine().matmul_nt(&x, &w);
        assert_eq!(y.shape(), (0, 4));
        let mut out = Matrix::zeros(3, 3);
        engine().matmul_nt_into(&x, &w, &mut out);
        assert_eq!(out.shape(), (0, 4));

        let plan = StructPlan::dense(4, 6);
        let y = engine().plan_act(&x, &plan, &PlanOperands::single(&w));
        assert_eq!(y.shape(), (0, 4));
    }

    #[test]
    fn plan_is_cached_after_dispatch() {
        let mut rng = Rng::new(802);
        let x = rng.gaussian_matrix(3, 16, 1.0);
        let w = rng.gaussian_matrix(8, 16, 1.0);
        let _ = engine().matmul_nt(&x, &w);
        let key = PlanKey::for_op(&KernelOp::DenseNt { w: &w }, 3);
        let plan = engine().plan_for(&key).expect("plan cached after dispatch");
        assert!(engine().kernel_named(&plan).is_some());
    }

    #[test]
    fn plan_ops_tune_separately_from_raw_dense() {
        // A dense *plan* op and a raw DenseNt op at the same shape use
        // distinct tuner keys (distinct tags), so a cached choice for
        // one can never be applied to the other.
        let mut rng = Rng::new(803);
        let x = rng.gaussian_matrix(2, 16, 1.0);
        let w = rng.gaussian_matrix(8, 16, 1.0);
        let plan = plan_cache().dense(8, 16);
        let y_plan = engine().plan_act(&x, &plan, &PlanOperands::single(&w));
        let y_raw = engine().matmul_nt(&x, &w);
        assert_eq!(y_plan.data, y_raw.data, "both tags share the contract bits");
        let raw_key = PlanKey::for_op(&KernelOp::DenseNt { w: &w }, 2);
        let plan_key =
            PlanKey::for_op(&KernelOp::Plan { plan: &plan, ops: PlanOperands::single(&w) }, 2);
        assert_ne!(raw_key, plan_key);
        assert!(engine().plan_for(&plan_key).is_some(), "plan op tuned under its own key");
    }

    #[test]
    fn plan_act_serial_bit_matches_tuned_plan_act() {
        let mut rng = Rng::new(807);
        let a = BlastMatrix::random_init(12, 12, 3, 4, 1.0, &mut rng);
        let x = rng.gaussian_matrix(4, 12, 1.0);
        let plan = a.plan();
        let ops = a.plan_operands();
        let tuned = engine().plan_act(&x, &plan, &ops);
        let serial = engine().plan_act_serial(&x, &plan, &ops);
        assert_eq!(tuned.data, serial.data, "serial plan path diverged from tuned");
    }
}
