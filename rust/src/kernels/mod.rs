//! Kernel engine: tiled + parallel BLAST kernels with a per-shape autotuner.
//!
//! Every inference-time matrix product in the repo — the dense
//! `Y = X · Wᵀ` of `nn::linear`, the attention score/context products,
//! and the BLAST Algorithm-1 product of `blast::matmul` — dispatches
//! through this subsystem instead of calling a fixed loop nest. The
//! pieces:
//!
//! * [`MatmulKernel`] — the kernel trait. A kernel advertises which
//!   [`KernelOp`]s it supports and computes `Y = X · Wᵀ` (dense) or the
//!   Algorithm-1 product `Y = X · Aᵀ` (BLAST) for row-major activation
//!   batches.
//! * [`naive::NaiveKernel`] — the scalar triple-loop reference. Every
//!   other kernel is property-tested element-wise against it
//!   (`tests/kernel_parity.rs`).
//! * [`tiled::TiledKernel`] — cache-blocked dense kernel: 8-wide
//!   output-column register tiles over contiguous rows, weight tile held
//!   cache-hot across the activation batch. All kernels share a
//!   **bit-stability invariant** — each output element is one sequential
//!   ascending-k sum — so the autotuner's choice never changes results
//!   by a bit (the prefill/decode identity depends on this).
//! * [`parallel::ParallelKernel`] — the tiled row kernel fanned out over
//!   `util::par`'s scoped-thread pool, one disjoint output-row chunk per
//!   worker.
//! * [`fused::FusedBlastKernel`] — Algorithm 1 with stage 1
//!   (`V_jᵀ x_j`) and stage 3 (`U_i w_i`) batched across *all* blocks in
//!   contiguous buffers: no per-block submatrix copies, no per-block
//!   allocations, one pass over the input per token. Sequential and
//!   row-parallel variants are registered.
//! * [`autotune::Autotuner`] — benchmarks the candidate kernels the
//!   first time each `(structure, shape, batch-bucket)` key is seen,
//!   caches the winner in-process, and (optionally) persists the plan
//!   table as JSON via `util::json` so later processes skip the probe.
//!
//! ## Dispatch
//!
//! [`engine()`] returns the process-wide [`KernelEngine`]. Hot paths call
//! [`KernelEngine::matmul_nt`] / [`KernelEngine::blast_act`]; the engine
//! resolves the plan (tuning on a miss) and runs the chosen kernel.
//!
//! Environment knobs:
//!
//! * `BLAST_KERNEL=<name>` — force one kernel (e.g. `naive`,
//!   `dense_tiled`, `dense_parallel`, `blast_fused`, `blast_fused_par`)
//!   for every op it supports; used by the benches to compare kernels.
//! * `BLAST_AUTOTUNE_CACHE=<path>` — load the plan table from `<path>`
//!   at startup and re-persist it after each new tuning decision.
//!
//! ## Plan format
//!
//! ```json
//! {
//!   "version": 1,
//!   "plans": [
//!     {"op": "blast(b=8,r=32)", "m": 1024, "n": 1024, "batch": 8,
//!      "kernel": "blast_fused_par"}
//!   ]
//! }
//! ```
//!
//! `batch` is the bucket ceiling (1, 8, 64, 4096), so decode (batch=1)
//! and prefill (batch≫1) tune independently. Regenerate a plan file with
//! `BLAST_AUTOTUNE_CACHE=plans.json cargo bench --bench blast_matmul`.

pub mod autotune;
pub mod fused;
pub mod naive;
pub mod parallel;
pub mod tiled;

pub use autotune::{Autotuner, PlanKey};
pub use fused::FusedBlastKernel;
pub use naive::NaiveKernel;
pub use parallel::ParallelKernel;
pub use tiled::TiledKernel;

use crate::blast::BlastMatrix;
use crate::tensor::Matrix;
use std::sync::OnceLock;

/// Borrowed view of a BLAST weight, shared by `BlastMatrix` and the
/// trainable `nn::linear::LinearWeight::Blast` layout so kernels are
/// agnostic to where the factors live.
pub struct BlastView<'a> {
    /// Logical output features (rows of the represented matrix).
    pub m: usize,
    /// Logical input features (cols of the represented matrix).
    pub n: usize,
    /// Blocks per side.
    pub b: usize,
    /// Rank parameter.
    pub r: usize,
    /// Left factors, `b` entries of shape `p×r` (`p = m/b`).
    pub u: Vec<&'a Matrix>,
    /// Right factors, `b` entries of shape `q×r` (`q = n/b`).
    pub v: Vec<&'a Matrix>,
    /// Diagonal couplings, `b·b` slices of length `r`, row-major by
    /// `(i, j) → i·b + j`.
    pub s: Vec<&'a [f32]>,
}

impl<'a> BlastView<'a> {
    /// Block height `p = m/b`.
    #[inline]
    pub fn p(&self) -> usize {
        self.m / self.b
    }

    /// Block width `q = n/b`.
    #[inline]
    pub fn q(&self) -> usize {
        self.n / self.b
    }

    /// Coupling vector `s_{i,j}`.
    #[inline]
    pub fn s_row(&self, i: usize, j: usize) -> &'a [f32] {
        self.s[i * self.b + j]
    }

    /// View over a `BlastMatrix`.
    pub fn from_matrix(a: &'a BlastMatrix) -> Self {
        BlastView {
            m: a.m,
            n: a.n,
            b: a.b,
            r: a.r,
            u: a.u.iter().collect(),
            v: a.v.iter().collect(),
            s: a
                .s
                .iter()
                .flat_map(|row| row.iter().map(|sij| sij.as_slice()))
                .collect(),
        }
    }

    fn validate(&self, x: &Matrix) {
        assert_eq!(x.cols, self.n, "blast_act input mismatch: x cols {} vs n {}", x.cols, self.n);
        assert_eq!(
            self.u.len(),
            self.b,
            "blast view: {} left factors for b={}",
            self.u.len(),
            self.b
        );
        assert_eq!(
            self.v.len(),
            self.b,
            "blast view: {} right factors for b={}",
            self.v.len(),
            self.b
        );
        assert_eq!(self.s.len(), self.b * self.b, "blast view: coupling table size");
    }
}

/// One dispatchable operation over a row-major activation batch
/// `X (batch × in_features)`.
pub enum KernelOp<'a> {
    /// `Y = X · Wᵀ` with a dense weight `W (out × in)` — the linear-layer
    /// and attention-score primitive.
    DenseNt { w: &'a Matrix },
    /// `Y = X · Aᵀ` via BLAST Algorithm 1.
    Blast(BlastView<'a>),
}

/// Allocation-free structure identity of an op — the hot-path half of a
/// plan key (`PlanKey::for_op` runs on every dispatch, so this must not
/// build a `String`).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum OpTag {
    Dense,
    Blast { b: u32, r: u32 },
}

impl OpTag {
    /// Stable textual form used in the JSON plan file
    /// (`"dense"` / `"blast(b=8,r=32)"`).
    pub fn to_tag_string(self) -> String {
        match self {
            OpTag::Dense => "dense".to_string(),
            OpTag::Blast { b, r } => format!("blast(b={b},r={r})"),
        }
    }

    /// Inverse of [`to_tag_string`]; `None` on unknown tags (old or
    /// hand-edited plan files).
    ///
    /// [`to_tag_string`]: OpTag::to_tag_string
    pub fn parse(tag: &str) -> Option<Self> {
        if tag == "dense" {
            return Some(OpTag::Dense);
        }
        let inner = tag.strip_prefix("blast(b=")?.strip_suffix(')')?;
        let (b, r) = inner.split_once(",r=")?;
        Some(OpTag::Blast { b: b.parse().ok()?, r: r.parse().ok()? })
    }
}

impl KernelOp<'_> {
    /// Output features of the op.
    pub fn out_features(&self) -> usize {
        match self {
            KernelOp::DenseNt { w } => w.rows,
            KernelOp::Blast(a) => a.m,
        }
    }

    /// Input features of the op.
    pub fn in_features(&self) -> usize {
        match self {
            KernelOp::DenseNt { w } => w.cols,
            KernelOp::Blast(a) => a.n,
        }
    }

    /// Structure identity for plan keys (allocation-free).
    pub fn tag(&self) -> OpTag {
        match self {
            KernelOp::DenseNt { .. } => OpTag::Dense,
            KernelOp::Blast(a) => OpTag::Blast { b: a.b as u32, r: a.r as u32 },
        }
    }
}

/// A matmul kernel. Implementations must be pure functions of their
/// inputs (no internal state), `Send + Sync`, and exact-shape-agnostic
/// within the ops they support.
pub trait MatmulKernel: Send + Sync {
    /// Stable name (plan files store it).
    fn name(&self) -> &'static str;

    /// Whether this kernel can run `op` at the given batch size.
    fn supports(&self, op: &KernelOp<'_>, batch: usize) -> bool;

    /// Compute the op. `x` is `(batch × in_features)`; the result is
    /// `(batch × out_features)`.
    fn run(&self, x: &Matrix, op: &KernelOp<'_>) -> Matrix;
}

/// The process-wide engine: registered kernels + the autotuner that maps
/// `(structure, shape, batch-bucket)` keys onto them.
pub struct KernelEngine {
    kernels: Vec<Box<dyn MatmulKernel>>,
    tuner: Autotuner,
    forced: Option<usize>,
}

impl KernelEngine {
    /// Engine with the standard kernel set and env-configured tuner.
    pub fn with_default_kernels() -> Self {
        let kernels: Vec<Box<dyn MatmulKernel>> = vec![
            Box::new(NaiveKernel),
            Box::new(TiledKernel),
            Box::new(ParallelKernel),
            Box::new(FusedBlastKernel::sequential()),
            Box::new(FusedBlastKernel::row_parallel()),
        ];
        let tuner = Autotuner::from_env();
        let forced = std::env::var("BLAST_KERNEL")
            .ok()
            .and_then(|name| kernels.iter().position(|k| k.name() == name));
        KernelEngine { kernels, tuner, forced }
    }

    /// `Y = X · Wᵀ` through the tuned kernel for this shape.
    pub fn matmul_nt(&self, x: &Matrix, w: &Matrix) -> Matrix {
        assert_eq!(x.cols, w.cols, "matmul_nt shape mismatch: {:?} vs {:?}", x.shape(), w.shape());
        self.dispatch(x, &KernelOp::DenseNt { w })
    }

    /// `Y = X · Wᵀ` with a *statically* chosen dense kernel (tiled below
    /// a work threshold, row-parallel above), bypassing the autotuner.
    ///
    /// Use this for activation×activation products whose shapes vary per
    /// input (e.g. attention scores, where one operand dimension is the
    /// sequence length): tuning those would create a throwaway plan
    /// entry — and a probe run — for every distinct length. Thanks to
    /// the kernels' shared bit-stability invariant the static choice is
    /// numerically identical to the tuned one.
    pub fn matmul_nt_static(&self, x: &Matrix, w: &Matrix) -> Matrix {
        assert_eq!(x.cols, w.cols, "matmul_nt shape mismatch: {:?} vs {:?}", x.shape(), w.shape());
        if x.rows == 0 {
            return Matrix::zeros(0, w.rows);
        }
        let op = KernelOp::DenseNt { w };
        if let Some(i) = self.forced {
            if self.kernels[i].supports(&op, x.rows) {
                return self.kernels[i].run(x, &op);
            }
        }
        // Same work threshold the tensor-level GEMMs use to decide
        // whether threads pay for themselves.
        let name = if x.rows * w.rows * w.cols >= 64 * 64 * 64 && x.rows >= 2 {
            "dense_parallel"
        } else {
            "dense_tiled"
        };
        self.kernel_named(name).expect("built-in dense kernel").run(x, &op)
    }

    /// `Y = X · Wᵀ` through the fixed serial dense kernel
    /// (`dense_tiled`), guaranteed never to spawn worker threads. For
    /// callers that already own the thread-level parallelism — the
    /// factorization sweeps fan the `b×b` factor grid across the pool
    /// and the compression pipeline fans whole layers — where a nested
    /// `dense_parallel` dispatch would multiply live threads
    /// (workers × pool) and oversubscribe the machine. Deliberately
    /// ignores `BLAST_KERNEL` forcing: "no nested threads" is a
    /// correctness-of-scheduling contract, not a tuning preference.
    /// Bit-identical to every other dense kernel by the engine's
    /// bit-stability invariant.
    pub fn matmul_nt_serial(&self, x: &Matrix, w: &Matrix) -> Matrix {
        assert_eq!(
            x.cols,
            w.cols,
            "matmul_nt shape mismatch: {:?} vs {:?}",
            x.shape(),
            w.shape()
        );
        if x.rows == 0 {
            return Matrix::zeros(0, w.rows);
        }
        let op = KernelOp::DenseNt { w };
        self.kernel_named("dense_tiled").expect("built-in dense kernel").run(x, &op)
    }

    /// `C = A · B` via [`matmul_nt_serial`]: `B` is transposed once
    /// (O(rows·cols), a ≤1/r fraction of the O(m·n·r) product for the
    /// tall-thin factor shapes this serves) and dispatched as
    /// `A · (Bᵀ)ᵀ`.
    ///
    /// [`matmul_nt_serial`]: KernelEngine::matmul_nt_serial
    pub fn matmul_serial(&self, a: &Matrix, b: &Matrix) -> Matrix {
        assert_eq!(
            a.cols,
            b.rows,
            "matmul_serial shape mismatch: {:?} x {:?}",
            a.shape(),
            b.shape()
        );
        self.matmul_nt_serial(a, &b.transpose())
    }

    /// BLAST Algorithm-1 activation product through the tuned kernel.
    pub fn blast_act(&self, x: &Matrix, a: &BlastMatrix) -> Matrix {
        self.dispatch(x, &KernelOp::Blast(BlastView::from_matrix(a)))
    }

    /// Dispatch an op, tuning on a plan miss.
    pub fn dispatch(&self, x: &Matrix, op: &KernelOp<'_>) -> Matrix {
        if let KernelOp::Blast(view) = op {
            view.validate(x);
        }
        if x.rows == 0 {
            return Matrix::zeros(0, op.out_features());
        }
        if let Some(i) = self.forced {
            if self.kernels[i].supports(op, x.rows) {
                return self.kernels[i].run(x, op);
            }
        }
        let key = PlanKey::for_op(op, x.rows);
        let idx = match self.tuner.lookup(&key, &self.kernels) {
            Some(i) => i,
            None => self.tuner.tune(&key, x, op, &self.kernels),
        };
        self.kernels[idx].run(x, op)
    }

    /// Kernel by stable name (benches and tests compare specific kernels).
    pub fn kernel_named(&self, name: &str) -> Option<&dyn MatmulKernel> {
        self.kernels.iter().find(|k| k.name() == name).map(|k| k.as_ref())
    }

    /// Names of all registered kernels.
    pub fn kernel_names(&self) -> Vec<&'static str> {
        self.kernels.iter().map(|k| k.name()).collect()
    }

    /// The chosen kernel name for a key, if already planned.
    pub fn plan_for(&self, key: &PlanKey) -> Option<String> {
        self.tuner.plan_name(key)
    }

    /// Persist the current plan table as JSON.
    pub fn save_plans(&self, path: &std::path::Path) -> anyhow::Result<()> {
        self.tuner.save(path)
    }

    /// Load a plan table, merging over the in-process cache.
    pub fn load_plans(&self, path: &std::path::Path) -> anyhow::Result<usize> {
        self.tuner.load(path)
    }
}

static ENGINE: OnceLock<KernelEngine> = OnceLock::new();

/// The process-wide [`KernelEngine`] (constructed on first use).
pub fn engine() -> &'static KernelEngine {
    ENGINE.get_or_init(KernelEngine::with_default_kernels)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Rng;

    #[test]
    fn engine_matches_tensor_matmul_nt() {
        let mut rng = Rng::new(800);
        let x = rng.gaussian_matrix(5, 24, 1.0);
        let w = rng.gaussian_matrix(10, 24, 1.0);
        let y = engine().matmul_nt(&x, &w);
        let y_ref = crate::tensor::matmul_nt(&x, &w);
        assert_eq!(y.shape(), (5, 10));
        assert!(y.sub(&y_ref).fro_norm() < 1e-4 * (1.0 + y_ref.fro_norm()));
    }

    #[test]
    fn engine_matches_blast_dense_reconstruction() {
        let mut rng = Rng::new(801);
        let a = BlastMatrix::random_init(12, 18, 3, 4, 1.0, &mut rng);
        let x = rng.gaussian_matrix(6, 18, 1.0);
        let y = engine().blast_act(&x, &a);
        let y_ref = crate::tensor::matmul_nt(&x, &a.to_dense());
        assert_eq!(y.shape(), (6, 12));
        assert!(y.sub(&y_ref).fro_norm() < 1e-3 * (1.0 + y_ref.fro_norm()));
    }

    #[test]
    fn matmul_serial_matches_tensor_matmul() {
        let mut rng = Rng::new(804);
        let a = rng.gaussian_matrix(7, 12, 1.0);
        let b = rng.gaussian_matrix(12, 9, 1.0);
        let y = engine().matmul_serial(&a, &b);
        let y_ref = crate::tensor::matmul(&a, &b);
        assert_eq!(y.shape(), (7, 9));
        assert!(y.sub(&y_ref).fro_norm() < 1e-4 * (1.0 + y_ref.fro_norm()));

        let nt = engine().matmul_nt_serial(&a, &rng.gaussian_matrix(5, 12, 1.0));
        assert_eq!(nt.shape(), (7, 5));
    }

    #[test]
    fn empty_batch_short_circuits() {
        let w = Matrix::zeros(4, 6);
        let x = Matrix::zeros(0, 6);
        let y = engine().matmul_nt(&x, &w);
        assert_eq!(y.shape(), (0, 4));
    }

    #[test]
    fn plan_is_cached_after_dispatch() {
        let mut rng = Rng::new(802);
        let x = rng.gaussian_matrix(3, 16, 1.0);
        let w = rng.gaussian_matrix(8, 16, 1.0);
        let _ = engine().matmul_nt(&x, &w);
        let key = PlanKey::for_op(&KernelOp::DenseNt { w: &w }, 3);
        let plan = engine().plan_for(&key).expect("plan cached after dispatch");
        assert!(engine().kernel_named(&plan).is_some());
    }

    #[test]
    fn view_from_matrix_is_consistent() {
        let mut rng = Rng::new(803);
        let a = BlastMatrix::random_init(8, 8, 2, 3, 1.0, &mut rng);
        let view = BlastView::from_matrix(&a);
        assert_eq!(view.p(), 4);
        assert_eq!(view.q(), 4);
        assert_eq!(view.s_row(1, 0), a.s[1][0].as_slice());
        assert_eq!(view.u[1].shape(), (4, 3));
    }
}
