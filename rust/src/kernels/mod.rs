//! Kernel engine: packed SIMD microkernel GEMM + fused BLAST kernels
//! with a per-shape autotuner.
//!
//! Every inference-time matrix product in the repo — the dense
//! `Y = X · Wᵀ` of `nn::linear`, the attention score/context products,
//! and the BLAST Algorithm-1 product of `blast::matmul` — dispatches
//! through this subsystem instead of calling a fixed loop nest. The
//! pieces:
//!
//! * [`micro`] — the BLIS-style packed microkernel and the engine's
//!   **fixed-lane accumulation contract**: every contraction is an
//!   8-lane strided partial sum over ascending k-chunks (zero-padded
//!   tail) reduced in a fixed tree order, identical across every
//!   kernel, batch size, thread schedule, and SIMD path. Runtime
//!   dispatch between a portable auto-vectorizing path and an
//!   `std::arch` AVX2 path is controlled by `BLAST_SIMD=auto|avx2|
//!   portable` (default `auto`; both paths are bit-identical).
//! * [`pack`] — B-panel packing: weights are repacked once per
//!   (weights, shape) into microkernel panels and cached process-wide,
//!   with sampled-fingerprint invalidation on in-place mutation.
//! * [`naive::NaiveKernel`] — the contract reference (no blocking, no
//!   packing, no SIMD dispatch, no threads). Every other kernel must
//!   match it **bit for bit** (`tests/kernel_parity.rs`).
//! * [`tiled::TiledKernel`] — single-threaded packed-microkernel dense
//!   kernel.
//! * [`parallel::ParallelKernel`] — the same microkernel fanned out over
//!   `util::par`'s scoped-thread pool, one disjoint output-row chunk per
//!   worker.
//! * [`fused::FusedBlastKernel`] — Algorithm 1 with stages 1 and 3 as
//!   microkernel calls over the packed `V`/`U` factor panels and
//!   thread-local stage scratch. Sequential and row-parallel variants
//!   are registered.
//! * [`autotune::Autotuner`] — benchmarks the candidate kernels the
//!   first time each `(structure, shape, batch-bucket)` key is seen,
//!   caches the winner in-process, and (optionally) persists the plan
//!   table as JSON via `util::json` so later processes skip the probe.
//!   Because of the fixed-lane contract its choice can never change a
//!   result bit.
//!
//! ## Dispatch
//!
//! [`engine()`] returns the process-wide [`KernelEngine`]. Hot paths
//! call [`KernelEngine::matmul_nt`] / [`KernelEngine::blast_act`] (or
//! their allocation-free `*_into` variants, which write into a
//! caller-owned output matrix and are what the zero-allocation decode
//! path uses); the engine resolves the plan (tuning on a miss) and runs
//! the chosen kernel.
//!
//! Environment knobs:
//!
//! * `BLAST_KERNEL=<name>` — force one kernel (e.g. `naive`,
//!   `dense_tiled`, `dense_parallel`, `blast_fused`, `blast_fused_par`)
//!   for every op it supports; used by the benches to compare kernels.
//! * `BLAST_SIMD=auto|avx2|portable` — SIMD path selection (see above).
//! * `BLAST_AUTOTUNE_CACHE=<path>` — load the plan table from `<path>`
//!   at startup and re-persist it after each new tuning decision.
//!
//! ## Plan format
//!
//! ```json
//! {
//!   "version": 1,
//!   "plans": [
//!     {"op": "blast(b=8,r=32)", "m": 1024, "n": 1024, "batch": 8,
//!      "kernel": "blast_fused_par"}
//!   ]
//! }
//! ```
//!
//! `batch` is the bucket ceiling (1, 8, 64, 4096), so decode (batch=1)
//! and prefill (batch≫1) tune independently. Regenerate a plan file with
//! `BLAST_AUTOTUNE_CACHE=plans.json cargo bench --bench blast_matmul`.

pub mod autotune;
pub mod fused;
pub mod micro;
pub mod naive;
pub mod pack;
pub mod parallel;
pub mod tiled;

pub use autotune::{Autotuner, PlanKey};
pub use fused::FusedBlastKernel;
pub use micro::{SimdMode, LANES, MR, NR};
pub use naive::NaiveKernel;
pub use pack::{PackCache, PackedPanels};
pub use parallel::ParallelKernel;
pub use tiled::TiledKernel;

use crate::blast::BlastMatrix;
use crate::nn::param::PTensor;
use crate::tensor::Matrix;
use crate::util::par;
use std::sync::OnceLock;

/// Where a [`BlastView`]'s factor matrices live. Borrowed, so building
/// a view is allocation-free — this runs on every decode dispatch.
#[derive(Clone, Copy)]
pub enum Factors<'a> {
    /// Plain matrices (`BlastMatrix::u` / `::v`).
    Mats(&'a [Matrix]),
    /// Trainable parameters (`nn::linear::LinearWeight::Blast`).
    Params(&'a [PTensor]),
}

impl<'a> Factors<'a> {
    #[inline]
    fn get(&self, i: usize) -> &'a Matrix {
        match self {
            Factors::Mats(m) => &m[i],
            Factors::Params(p) => &p[i].v,
        }
    }

    #[inline]
    fn len(&self) -> usize {
        match self {
            Factors::Mats(m) => m.len(),
            Factors::Params(p) => p.len(),
        }
    }
}

/// Where a [`BlastView`]'s coupling table lives (also borrowed).
#[derive(Clone, Copy)]
pub enum Couplings<'a> {
    /// `BlastMatrix::s` — nested `[i][j] -> Vec<f32>` of length `r`.
    Nested(&'a [Vec<Vec<f32>>]),
    /// A packed `(b·b) × r` matrix, row `i·b + j` (the trainable layout).
    Packed(&'a Matrix),
}

/// Borrowed view of a BLAST weight, shared by `BlastMatrix` and the
/// trainable `nn::linear::LinearWeight::Blast` layout so kernels are
/// agnostic to where the factors live. Construction never allocates.
pub struct BlastView<'a> {
    /// Logical output features (rows of the represented matrix).
    pub m: usize,
    /// Logical input features (cols of the represented matrix).
    pub n: usize,
    /// Blocks per side.
    pub b: usize,
    /// Rank parameter.
    pub r: usize,
    u: Factors<'a>,
    v: Factors<'a>,
    s: Couplings<'a>,
}

impl<'a> BlastView<'a> {
    /// View over explicit factor/coupling storage.
    pub fn new(
        m: usize,
        n: usize,
        b: usize,
        r: usize,
        u: Factors<'a>,
        v: Factors<'a>,
        s: Couplings<'a>,
    ) -> Self {
        BlastView { m, n, b, r, u, v, s }
    }

    /// View over a `BlastMatrix`.
    pub fn from_matrix(a: &'a BlastMatrix) -> Self {
        BlastView {
            m: a.m,
            n: a.n,
            b: a.b,
            r: a.r,
            u: Factors::Mats(&a.u),
            v: Factors::Mats(&a.v),
            s: Couplings::Nested(&a.s),
        }
    }

    /// Block height `p = m/b`.
    #[inline]
    pub fn p(&self) -> usize {
        self.m / self.b
    }

    /// Block width `q = n/b`.
    #[inline]
    pub fn q(&self) -> usize {
        self.n / self.b
    }

    /// Left factor `U_i` (`p × r`).
    #[inline]
    pub fn u(&self, i: usize) -> &'a Matrix {
        self.u.get(i)
    }

    /// Right factor `V_j` (`q × r`).
    #[inline]
    pub fn v(&self, j: usize) -> &'a Matrix {
        self.v.get(j)
    }

    /// Coupling vector `s_{i,j}` (length `r`).
    #[inline]
    pub fn s_row(&self, i: usize, j: usize) -> &'a [f32] {
        match self.s {
            Couplings::Nested(s) => &s[i][j],
            Couplings::Packed(s) => s.row(i * self.b + j),
        }
    }

    fn validate(&self, x: &Matrix) {
        assert_eq!(x.cols, self.n, "blast_act input mismatch: x cols {} vs n {}", x.cols, self.n);
        assert_eq!(
            self.u.len(),
            self.b,
            "blast view: {} left factors for b={}",
            self.u.len(),
            self.b
        );
        assert_eq!(
            self.v.len(),
            self.b,
            "blast view: {} right factors for b={}",
            self.v.len(),
            self.b
        );
        match self.s {
            Couplings::Nested(s) => {
                assert_eq!(s.len(), self.b, "blast view: coupling rows");
                for (i, row) in s.iter().enumerate() {
                    assert_eq!(
                        row.len(),
                        self.b,
                        "blast view: coupling row {i} has {} entries for b={}",
                        row.len(),
                        self.b
                    );
                }
            }
            Couplings::Packed(s) => {
                assert_eq!(s.rows, self.b * self.b, "blast view: coupling table size");
                assert_eq!(s.cols, self.r, "blast view: coupling width");
            }
        }
    }
}

/// One dispatchable operation over a row-major activation batch
/// `X (batch × in_features)`.
pub enum KernelOp<'a> {
    /// `Y = X · Wᵀ` with a dense weight `W (out × in)` — the linear-layer
    /// and attention-score primitive.
    DenseNt { w: &'a Matrix },
    /// `Y = X · Aᵀ` via BLAST Algorithm 1.
    Blast(BlastView<'a>),
}

/// Allocation-free structure identity of an op — the hot-path half of a
/// plan key (`PlanKey::for_op` runs on every dispatch, so this must not
/// build a `String`).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum OpTag {
    Dense,
    Blast { b: u32, r: u32 },
}

impl OpTag {
    /// Stable textual form used in the JSON plan file
    /// (`"dense"` / `"blast(b=8,r=32)"`).
    pub fn to_tag_string(self) -> String {
        match self {
            OpTag::Dense => "dense".to_string(),
            OpTag::Blast { b, r } => format!("blast(b={b},r={r})"),
        }
    }

    /// Inverse of [`to_tag_string`]; `None` on unknown tags (old or
    /// hand-edited plan files).
    ///
    /// [`to_tag_string`]: OpTag::to_tag_string
    pub fn parse(tag: &str) -> Option<Self> {
        if tag == "dense" {
            return Some(OpTag::Dense);
        }
        let inner = tag.strip_prefix("blast(b=")?.strip_suffix(')')?;
        let (b, r) = inner.split_once(",r=")?;
        Some(OpTag::Blast { b: b.parse().ok()?, r: r.parse().ok()? })
    }
}

impl KernelOp<'_> {
    /// Output features of the op.
    pub fn out_features(&self) -> usize {
        match self {
            KernelOp::DenseNt { w } => w.rows,
            KernelOp::Blast(a) => a.m,
        }
    }

    /// Input features of the op.
    pub fn in_features(&self) -> usize {
        match self {
            KernelOp::DenseNt { w } => w.cols,
            KernelOp::Blast(a) => a.n,
        }
    }

    /// Structure identity for plan keys (allocation-free).
    pub fn tag(&self) -> OpTag {
        match self {
            KernelOp::DenseNt { .. } => OpTag::Dense,
            KernelOp::Blast(a) => OpTag::Blast { b: a.b as u32, r: a.r as u32 },
        }
    }
}

/// A matmul kernel. Implementations must be pure functions of their
/// inputs (no observable internal state), `Send + Sync`, and
/// exact-shape-agnostic within the ops they support, and must follow
/// the engine's fixed-lane accumulation contract (see [`micro`]).
pub trait MatmulKernel: Send + Sync {
    /// Stable name (plan files store it).
    fn name(&self) -> &'static str;

    /// Whether this kernel can run `op` at the given batch size.
    fn supports(&self, op: &KernelOp<'_>, batch: usize) -> bool;

    /// Compute the op. `x` is `(batch × in_features)`; the result is
    /// `(batch × out_features)`.
    fn run(&self, x: &Matrix, op: &KernelOp<'_>) -> Matrix;

    /// Compute the op into a caller-owned output (shape is reset by the
    /// kernel; an adequately-sized buffer is reused without touching
    /// the allocator). The default falls back to [`run`] and therefore
    /// allocates — the optimized kernels override it; the decode hot
    /// path relies on those overrides for its zero-allocation
    /// guarantee.
    ///
    /// [`run`]: MatmulKernel::run
    fn run_into(&self, x: &Matrix, op: &KernelOp<'_>, out: &mut Matrix) {
        *out = self.run(x, op);
    }
}

/// The process-wide engine: registered kernels + the autotuner that maps
/// `(structure, shape, batch-bucket)` keys onto them.
pub struct KernelEngine {
    kernels: Vec<Box<dyn MatmulKernel>>,
    tuner: Autotuner,
    forced: Option<usize>,
}

impl KernelEngine {
    /// Engine with the standard kernel set and env-configured tuner.
    pub fn with_default_kernels() -> Self {
        let kernels: Vec<Box<dyn MatmulKernel>> = vec![
            Box::new(NaiveKernel),
            Box::new(TiledKernel),
            Box::new(ParallelKernel),
            Box::new(FusedBlastKernel::sequential()),
            Box::new(FusedBlastKernel::row_parallel()),
        ];
        let tuner = Autotuner::from_env();
        let forced = std::env::var("BLAST_KERNEL")
            .ok()
            .and_then(|name| kernels.iter().position(|k| k.name() == name));
        KernelEngine { kernels, tuner, forced }
    }

    /// `Y = X · Wᵀ` through the tuned kernel for this shape.
    pub fn matmul_nt(&self, x: &Matrix, w: &Matrix) -> Matrix {
        assert_eq!(x.cols, w.cols, "matmul_nt shape mismatch: {:?} vs {:?}", x.shape(), w.shape());
        self.dispatch(x, &KernelOp::DenseNt { w })
    }

    /// [`matmul_nt`] into a caller-owned output (allocation-free once
    /// the buffer and the plan are warm).
    ///
    /// [`matmul_nt`]: KernelEngine::matmul_nt
    pub fn matmul_nt_into(&self, x: &Matrix, w: &Matrix, out: &mut Matrix) {
        assert_eq!(x.cols, w.cols, "matmul_nt shape mismatch: {:?} vs {:?}", x.shape(), w.shape());
        self.dispatch_into(x, &KernelOp::DenseNt { w }, out);
    }

    /// `Y = X · Wᵀ` with a *statically* chosen execution (serial below a
    /// work threshold, row-parallel above), bypassing both the autotuner
    /// and the pack cache.
    ///
    /// Use this for activation×activation products whose shapes vary per
    /// input (e.g. attention scores, where one operand dimension is the
    /// sequence length): tuning those would create a throwaway plan
    /// entry — and a probe run — for every distinct length, and packing
    /// them would churn the weight-panel cache. Like
    /// [`matmul_nt_serial`], this path deliberately ignores
    /// `BLAST_KERNEL` forcing: a forced packing kernel would insert
    /// every transient activation operand into the pack cache,
    /// evicting real layer-weight panels — and the fixed-lane contract
    /// makes the unpacked choice bit-identical to any forced kernel
    /// anyway, so forcing could never change results here.
    ///
    /// [`matmul_nt_serial`]: KernelEngine::matmul_nt_serial
    pub fn matmul_nt_static(&self, x: &Matrix, w: &Matrix) -> Matrix {
        assert_eq!(x.cols, w.cols, "matmul_nt shape mismatch: {:?} vs {:?}", x.shape(), w.shape());
        if x.rows == 0 {
            return Matrix::zeros(0, w.rows);
        }
        let mode = micro::simd_mode();
        let mut y = Matrix::zeros(x.rows, w.rows);
        // Same work threshold the tensor-level GEMMs use to decide
        // whether threads pay for themselves.
        if x.rows * w.rows * w.cols >= 64 * 64 * 64 && x.rows >= 2 {
            let n = w.rows;
            let chunk_rows = x.rows.div_ceil(par::num_threads()).max(1);
            par::par_chunks_mut(&mut y.data, chunk_rows * n, |ci, chunk| {
                let rows = chunk.len() / n;
                tiled::dense_nt_rows_unpacked(mode, x, w, ci * chunk_rows, rows, chunk);
            });
        } else {
            tiled::dense_nt_rows_unpacked(mode, x, w, 0, x.rows, &mut y.data);
        }
        y
    }

    /// `Y = X · Wᵀ` through the fixed serial unpacked path, guaranteed
    /// never to spawn worker threads. For callers that already own the
    /// thread-level parallelism — the factorization sweeps fan the `b×b`
    /// factor grid across the pool and the compression pipeline fans
    /// whole layers — where a nested `dense_parallel` dispatch would
    /// multiply live threads (workers × pool) and oversubscribe the
    /// machine. Deliberately ignores `BLAST_KERNEL` forcing: "no nested
    /// threads" is a correctness-of-scheduling contract, not a tuning
    /// preference. Bit-identical to every other dense path by the
    /// engine's fixed-lane contract.
    pub fn matmul_nt_serial(&self, x: &Matrix, w: &Matrix) -> Matrix {
        assert_eq!(
            x.cols,
            w.cols,
            "matmul_nt shape mismatch: {:?} vs {:?}",
            x.shape(),
            w.shape()
        );
        if x.rows == 0 {
            return Matrix::zeros(0, w.rows);
        }
        let mut y = Matrix::zeros(x.rows, w.rows);
        tiled::dense_nt_rows_unpacked(micro::simd_mode(), x, w, 0, x.rows, &mut y.data);
        y
    }

    /// `C = A · B` via [`matmul_nt_serial`]: `B` is transposed once
    /// (O(rows·cols), a ≤1/r fraction of the O(m·n·r) product for the
    /// tall-thin factor shapes this serves) and dispatched as
    /// `A · (Bᵀ)ᵀ`.
    ///
    /// [`matmul_nt_serial`]: KernelEngine::matmul_nt_serial
    pub fn matmul_serial(&self, a: &Matrix, b: &Matrix) -> Matrix {
        assert_eq!(
            a.cols,
            b.rows,
            "matmul_serial shape mismatch: {:?} x {:?}",
            a.shape(),
            b.shape()
        );
        self.matmul_nt_serial(a, &b.transpose())
    }

    /// BLAST Algorithm-1 activation product through the tuned kernel.
    pub fn blast_act(&self, x: &Matrix, a: &BlastMatrix) -> Matrix {
        self.dispatch(x, &KernelOp::Blast(BlastView::from_matrix(a)))
    }

    /// [`blast_act`] into a caller-owned output.
    ///
    /// [`blast_act`]: KernelEngine::blast_act
    pub fn blast_act_into(&self, x: &Matrix, a: &BlastMatrix, out: &mut Matrix) {
        self.dispatch_into(x, &KernelOp::Blast(BlastView::from_matrix(a)), out);
    }

    /// Dispatch an op, tuning on a plan miss.
    pub fn dispatch(&self, x: &Matrix, op: &KernelOp<'_>) -> Matrix {
        let idx = match self.resolve(x, op) {
            Some(i) => i,
            None => return Matrix::zeros(0, op.out_features()),
        };
        self.kernels[idx].run(x, op)
    }

    /// [`dispatch`] into a caller-owned output — the allocation-free hot
    /// path (plan lookup, packed panels, and kernel scratch are all
    /// cache hits in the steady state).
    ///
    /// [`dispatch`]: KernelEngine::dispatch
    pub fn dispatch_into(&self, x: &Matrix, op: &KernelOp<'_>, out: &mut Matrix) {
        let idx = match self.resolve(x, op) {
            Some(i) => i,
            None => {
                out.reset(0, op.out_features());
                return;
            }
        };
        self.kernels[idx].run_into(x, op, out);
    }

    /// Shared plan resolution: validate, short-circuit empty batches
    /// (`None`), apply `BLAST_KERNEL` forcing, tune on a miss.
    fn resolve(&self, x: &Matrix, op: &KernelOp<'_>) -> Option<usize> {
        if let KernelOp::Blast(view) = op {
            view.validate(x);
        }
        if x.rows == 0 {
            return None;
        }
        if let Some(i) = self.forced {
            if self.kernels[i].supports(op, x.rows) {
                return Some(i);
            }
        }
        let key = PlanKey::for_op(op, x.rows);
        Some(match self.tuner.lookup(&key, &self.kernels) {
            Some(i) => i,
            None => self.tuner.tune(&key, x, op, &self.kernels),
        })
    }

    /// Kernel by stable name (benches and tests compare specific kernels).
    pub fn kernel_named(&self, name: &str) -> Option<&dyn MatmulKernel> {
        self.kernels.iter().find(|k| k.name() == name).map(|k| k.as_ref())
    }

    /// Names of all registered kernels.
    pub fn kernel_names(&self) -> Vec<&'static str> {
        self.kernels.iter().map(|k| k.name()).collect()
    }

    /// The chosen kernel name for a key, if already planned.
    pub fn plan_for(&self, key: &PlanKey) -> Option<String> {
        self.tuner.plan_name(key)
    }

    /// Persist the current plan table as JSON.
    pub fn save_plans(&self, path: &std::path::Path) -> anyhow::Result<()> {
        self.tuner.save(path)
    }

    /// Load a plan table, merging over the in-process cache.
    pub fn load_plans(&self, path: &std::path::Path) -> anyhow::Result<usize> {
        self.tuner.load(path)
    }
}

static ENGINE: OnceLock<KernelEngine> = OnceLock::new();

/// The process-wide [`KernelEngine`] (constructed on first use).
pub fn engine() -> &'static KernelEngine {
    ENGINE.get_or_init(KernelEngine::with_default_kernels)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Rng;

    #[test]
    fn engine_matches_tensor_matmul_nt() {
        let mut rng = Rng::new(800);
        let x = rng.gaussian_matrix(5, 24, 1.0);
        let w = rng.gaussian_matrix(10, 24, 1.0);
        let y = engine().matmul_nt(&x, &w);
        let y_ref = crate::tensor::matmul_nt(&x, &w);
        assert_eq!(y.shape(), (5, 10));
        assert!(y.sub(&y_ref).fro_norm() < 1e-4 * (1.0 + y_ref.fro_norm()));
    }

    #[test]
    fn engine_matches_blast_dense_reconstruction() {
        let mut rng = Rng::new(801);
        let a = BlastMatrix::random_init(12, 18, 3, 4, 1.0, &mut rng);
        let x = rng.gaussian_matrix(6, 18, 1.0);
        let y = engine().blast_act(&x, &a);
        let y_ref = crate::tensor::matmul_nt(&x, &a.to_dense());
        assert_eq!(y.shape(), (6, 12));
        assert!(y.sub(&y_ref).fro_norm() < 1e-3 * (1.0 + y_ref.fro_norm()));
    }

    #[test]
    fn into_variants_bit_match_allocating_variants() {
        let mut rng = Rng::new(805);
        let x = rng.gaussian_matrix(4, 18, 1.0);
        let w = rng.gaussian_matrix(9, 18, 1.0);
        let y = engine().matmul_nt(&x, &w);
        let mut out = Matrix::zeros(1, 1);
        engine().matmul_nt_into(&x, &w, &mut out);
        assert_eq!(out.shape(), y.shape());
        assert_eq!(out.data, y.data);

        let a = BlastMatrix::random_init(12, 18, 3, 4, 1.0, &mut rng);
        let yb = engine().blast_act(&x, &a);
        let mut outb = Matrix::zeros(0, 0);
        engine().blast_act_into(&x, &a, &mut outb);
        assert_eq!(outb.shape(), yb.shape());
        assert_eq!(outb.data, yb.data);
    }

    #[test]
    fn static_and_serial_paths_bit_match_tuned_dispatch() {
        let mut rng = Rng::new(806);
        let x = rng.gaussian_matrix(5, 33, 1.0);
        let w = rng.gaussian_matrix(11, 33, 1.0);
        let tuned = engine().matmul_nt(&x, &w);
        let stat = engine().matmul_nt_static(&x, &w);
        let serial = engine().matmul_nt_serial(&x, &w);
        assert_eq!(tuned.data, stat.data, "static path diverged from tuned");
        assert_eq!(tuned.data, serial.data, "serial path diverged from tuned");
    }

    #[test]
    fn matmul_serial_matches_tensor_matmul() {
        let mut rng = Rng::new(804);
        let a = rng.gaussian_matrix(7, 12, 1.0);
        let b = rng.gaussian_matrix(12, 9, 1.0);
        let y = engine().matmul_serial(&a, &b);
        let y_ref = crate::tensor::matmul(&a, &b);
        assert_eq!(y.shape(), (7, 9));
        assert!(y.sub(&y_ref).fro_norm() < 1e-4 * (1.0 + y_ref.fro_norm()));

        let nt = engine().matmul_nt_serial(&a, &rng.gaussian_matrix(5, 12, 1.0));
        assert_eq!(nt.shape(), (7, 5));
    }

    #[test]
    fn empty_batch_short_circuits() {
        let w = Matrix::zeros(4, 6);
        let x = Matrix::zeros(0, 6);
        let y = engine().matmul_nt(&x, &w);
        assert_eq!(y.shape(), (0, 4));
        let mut out = Matrix::zeros(3, 3);
        engine().matmul_nt_into(&x, &w, &mut out);
        assert_eq!(out.shape(), (0, 4));
    }

    #[test]
    fn plan_is_cached_after_dispatch() {
        let mut rng = Rng::new(802);
        let x = rng.gaussian_matrix(3, 16, 1.0);
        let w = rng.gaussian_matrix(8, 16, 1.0);
        let _ = engine().matmul_nt(&x, &w);
        let key = PlanKey::for_op(&KernelOp::DenseNt { w: &w }, 3);
        let plan = engine().plan_for(&key).expect("plan cached after dispatch");
        assert!(engine().kernel_named(&plan).is_some());
    }

    #[test]
    fn view_from_matrix_is_consistent() {
        let mut rng = Rng::new(803);
        let a = BlastMatrix::random_init(8, 8, 2, 3, 1.0, &mut rng);
        let view = BlastView::from_matrix(&a);
        assert_eq!(view.p(), 4);
        assert_eq!(view.q(), 4);
        assert_eq!(view.s_row(1, 0), a.s[1][0].as_slice());
        assert_eq!(view.u(1).shape(), (4, 3));
    }
}
