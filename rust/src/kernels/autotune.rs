//! Per-shape kernel autotuner.
//!
//! The first time the engine sees a `(structure, shape, batch-bucket)`
//! key it times every registered kernel that supports the op on the real
//! input and caches the winner; later dispatches for the same key are a
//! hash lookup. Plans key on a *bucketed* batch size so the decode path
//! (batch 1) and the prefill/training path (batch ≫ 1) tune
//! independently without fragmenting the table per exact batch.
//!
//! The table persists as JSON (written with `util::json`, no serde)
//! when `BLAST_AUTOTUNE_CACHE=<path>` is set: the file is loaded at
//! engine construction and re-written after every new tuning decision,
//! so a served model warms once per deployment instead of once per
//! process. Plans store kernel *names*; unknown names (an old file, a
//! renamed kernel) are ignored and simply re-tuned.

use super::{KernelOp, MatmulKernel, OpTag};
use crate::tensor::Matrix;
use crate::util::json::{obj, Json};
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::RwLock;
use std::time::Instant;

/// Identity of one tuning decision. `Copy` and allocation-free: one is
/// built on every engine dispatch.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct PlanKey {
    /// Structure tag (serialized as `"dense"` / `"plan:blast(b=8,r=32)"`).
    pub op: OpTag,
    /// Output features.
    pub m: usize,
    /// Input features.
    pub n: usize,
    /// Bucketed batch size (the bucket ceiling).
    pub batch: usize,
}

impl PlanKey {
    /// Key for an op at a concrete batch size.
    pub fn for_op(op: &KernelOp<'_>, batch: usize) -> Self {
        PlanKey {
            op: op.tag(),
            m: op.out_features(),
            n: op.in_features(),
            batch: batch_bucket(batch),
        }
    }
}

/// Bucket a concrete batch size: 1 (decode), ≤8 (small prefill /
/// micro-batch), ≤64 (prefill), everything else (training-scale).
pub fn batch_bucket(batch: usize) -> usize {
    match batch {
        0..=1 => 1,
        2..=8 => 8,
        9..=64 => 64,
        _ => 4096,
    }
}

/// Benchmark-and-cache kernel selection. The plan table is read-mostly
/// (every dispatch takes the read lock; only tuning and plan-file loads
/// take the write lock).
pub struct Autotuner {
    plans: RwLock<HashMap<PlanKey, String>>,
    persist_to: Option<PathBuf>,
}

impl Autotuner {
    /// Empty tuner with no persistence.
    pub fn new() -> Self {
        Autotuner { plans: RwLock::new(HashMap::new()), persist_to: None }
    }

    /// Tuner configured from `BLAST_AUTOTUNE_CACHE` (loads the file if it
    /// exists; tuning decisions are re-persisted to it).
    pub fn from_env() -> Self {
        let tuner = Autotuner::new();
        if let Some(path) = &crate::util::config::EngineConfig::global().autotune_cache {
            let path = PathBuf::from(path);
            let _ = tuner.load(&path); // best effort; absent file is fine
            // Safety note: persist_to is only read after construction.
            return Autotuner { persist_to: Some(path), ..tuner };
        }
        tuner
    }

    /// The cached kernel index for `key`, if present and still valid for
    /// this kernel set.
    pub fn lookup(&self, key: &PlanKey, kernels: &[Box<dyn MatmulKernel>]) -> Option<usize> {
        let plans = self.plans.read().unwrap();
        let name = plans.get(key)?;
        let idx = kernels.iter().position(|k| k.name() == name.as_str())?;
        // Cached handle → the table hit is one relaxed fetch_add (this
        // sits on every engine dispatch, including batch-1 decode).
        crate::obs::well_known::autotune_table_hits().inc();
        Some(idx)
    }

    /// The cached kernel name for `key` (diagnostics / benches).
    pub fn plan_name(&self, key: &PlanKey) -> Option<String> {
        self.plans.read().unwrap().get(key).cloned()
    }

    /// Time every supporting kernel on `x` and cache the fastest.
    /// Returns the winning kernel index (there is always at least one
    /// candidate: the naive reference supports every op).
    pub fn tune(
        &self,
        key: &PlanKey,
        x: &Matrix,
        op: &KernelOp<'_>,
        kernels: &[Box<dyn MatmulKernel>],
    ) -> usize {
        let mut best: Option<(f64, usize)> = None;
        for (i, kernel) in kernels.iter().enumerate() {
            if !kernel.supports(op, x.rows) {
                continue;
            }
            let secs = time_kernel(kernel.as_ref(), x, op);
            let improves = match best {
                None => true,
                Some((b, _)) => secs < b,
            };
            if improves {
                best = Some((secs, i));
            }
        }
        let (_, idx) = best.expect("no kernel supports this op (naive must)");
        // Tuning is rare (once per key per process) and already times
        // kernel runs, so the labelled bump's allocation is fine here.
        crate::obs::well_known::autotune_tune_events().inc();
        crate::obs::registry().bump_labeled(
            "autotune_selected",
            &format!("{}|{}", key.op.to_tag_string(), kernels[idx].name()),
        );
        {
            let mut plans = self.plans.write().unwrap();
            plans.insert(*key, kernels[idx].name().to_string());
        }
        if let Some(path) = &self.persist_to {
            let _ = self.save(path); // best effort
        }
        idx
    }

    /// Number of cached plans.
    pub fn len(&self) -> usize {
        self.plans.read().unwrap().len()
    }

    /// Serialize the plan table to `path` as JSON.
    pub fn save(&self, path: &Path) -> anyhow::Result<()> {
        let plans = self.plans.read().unwrap();
        let mut entries: Vec<(PlanKey, String)> =
            plans.iter().map(|(k, v)| (*k, v.clone())).collect();
        drop(plans);
        // Deterministic file contents regardless of hash order.
        entries.sort_by_key(|(k, _)| (k.op.to_tag_string(), k.m, k.n, k.batch));
        let arr: Vec<Json> = entries
            .into_iter()
            .map(|(k, name)| {
                obj(vec![
                    ("op", Json::from(k.op.to_tag_string())),
                    ("m", Json::from(k.m)),
                    ("n", Json::from(k.n)),
                    ("batch", Json::from(k.batch)),
                    ("kernel", Json::from(name)),
                ])
            })
            .collect();
        let root = obj(vec![("version", Json::from(1usize)), ("plans", Json::Arr(arr))]);
        std::fs::write(path, root.to_string_pretty())?;
        Ok(())
    }

    /// Merge plans from a JSON file into the table; returns how many
    /// entries were loaded. Malformed entries are skipped.
    pub fn load(&self, path: &Path) -> anyhow::Result<usize> {
        let text = std::fs::read_to_string(path)?;
        let root = Json::parse(&text)?;
        let mut loaded = 0usize;
        if let Ok(arr) = root.get("plans") {
            if let Some(items) = arr.as_arr() {
                let mut plans = self.plans.write().unwrap();
                for item in items {
                    let parsed = (|| -> Option<(PlanKey, String)> {
                        Some((
                            PlanKey {
                                op: OpTag::parse(item.get("op").ok()?.as_str()?)?,
                                m: item.get("m").ok()?.as_usize()?,
                                n: item.get("n").ok()?.as_usize()?,
                                batch: item.get("batch").ok()?.as_usize()?,
                            },
                            item.get("kernel").ok()?.as_str()?.to_string(),
                        ))
                    })();
                    if let Some((key, name)) = parsed {
                        plans.insert(key, name);
                        loaded += 1;
                    }
                }
            }
        }
        Ok(loaded)
    }
}

impl Default for Autotuner {
    fn default() -> Self {
        Self::new()
    }
}

/// Best-of-a-few wall-clock seconds for one kernel on one input. One
/// warmup run, then up to three timed runs with an early exit once the
/// probe has cost ~20 ms — model-load tuning must stay cheap.
fn time_kernel(kernel: &dyn MatmulKernel, x: &Matrix, op: &KernelOp<'_>) -> f64 {
    std::hint::black_box(kernel.run(x, op));
    let mut best = f64::INFINITY;
    let mut spent = 0.0f64;
    for _ in 0..3 {
        let t0 = Instant::now();
        std::hint::black_box(kernel.run(x, op));
        let dt = t0.elapsed().as_secs_f64();
        best = best.min(dt);
        spent += dt;
        if spent > 0.02 {
            break;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::{
        NaiveKernel, ParallelKernel, PlanKernel, PlanKind, PlanSig, QuantMode, TiledKernel,
    };
    use crate::tensor::Rng;

    fn kernel_set() -> Vec<Box<dyn MatmulKernel>> {
        vec![
            Box::new(NaiveKernel),
            Box::new(TiledKernel),
            Box::new(ParallelKernel),
            Box::new(PlanKernel::sequential()),
            Box::new(PlanKernel::row_parallel()),
            Box::new(PlanKernel::sequential_i8()),
            Box::new(PlanKernel::row_parallel_i8()),
        ]
    }

    #[test]
    fn op_tag_string_round_trip() {
        let f32_sig = |kind, b, r| PlanSig { kind, b, r, q: QuantMode::F32 };
        for tag in [
            OpTag::Dense,
            OpTag::Plan(f32_sig(PlanKind::Blast, 8, 32)),
            OpTag::Plan(f32_sig(PlanKind::Monarch, 2, 4)),
            OpTag::Plan(f32_sig(PlanKind::LowRank, 1, 16)),
            OpTag::Plan(f32_sig(PlanKind::Dense, 1, 0)),
            OpTag::Plan(f32_sig(PlanKind::Blast, 8, 32).quantized()),
            OpTag::Plan(f32_sig(PlanKind::Dense, 1, 0).quantized()),
        ] {
            assert_eq!(OpTag::parse(&tag.to_tag_string()), Some(tag));
        }
        assert_eq!(
            OpTag::parse("plan:blast(b=8,r=32)"),
            Some(OpTag::Plan(f32_sig(PlanKind::Blast, 8, 32)))
        );
        // Quantized signatures carry a `q=i8` suffix and tune separately.
        assert_eq!(
            OpTag::parse("plan:blast(b=8,r=32,q=i8)"),
            Some(OpTag::Plan(f32_sig(PlanKind::Blast, 8, 32).quantized()))
        );
        // The retired pre-plan tag form is rejected (old files re-tune).
        assert!(OpTag::parse("blast(b=8,r=32)").is_none());
        assert!(OpTag::parse("monarch(b=2)").is_none());
        assert!(OpTag::parse("plan:blast(b=x,r=2)").is_none());
    }

    #[test]
    fn batch_buckets() {
        assert_eq!(batch_bucket(0), 1);
        assert_eq!(batch_bucket(1), 1);
        assert_eq!(batch_bucket(2), 8);
        assert_eq!(batch_bucket(8), 8);
        assert_eq!(batch_bucket(9), 64);
        assert_eq!(batch_bucket(64), 64);
        assert_eq!(batch_bucket(65), 4096);
        assert_eq!(batch_bucket(100_000), 4096);
    }

    #[test]
    fn tune_then_lookup_round_trip() {
        let tuner = Autotuner::new();
        let kernels = kernel_set();
        let mut rng = Rng::new(850);
        let x = rng.gaussian_matrix(4, 32, 1.0);
        let w = rng.gaussian_matrix(16, 32, 1.0);
        let op = KernelOp::DenseNt { w: &w };
        let key = PlanKey::for_op(&op, x.rows);
        assert!(tuner.lookup(&key, &kernels).is_none());
        let idx = tuner.tune(&key, &x, &op, &kernels);
        assert_eq!(tuner.lookup(&key, &kernels), Some(idx));
        // Dense ops must never select a BLAST-only kernel.
        assert!(kernels[idx].supports(&op, x.rows));
    }

    #[test]
    fn save_load_round_trip() {
        let tuner = Autotuner::new();
        let kernels = kernel_set();
        let mut rng = Rng::new(851);
        let x = rng.gaussian_matrix(2, 16, 1.0);
        let w = rng.gaussian_matrix(8, 16, 1.0);
        let op = KernelOp::DenseNt { w: &w };
        let key = PlanKey::for_op(&op, x.rows);
        tuner.tune(&key, &x, &op, &kernels);

        let dir = std::env::temp_dir().join(format!("blast-tune-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("plans.json");
        tuner.save(&path).unwrap();

        let fresh = Autotuner::new();
        let n = fresh.load(&path).unwrap();
        assert_eq!(n, 1);
        assert_eq!(fresh.plan_name(&key), tuner.plan_name(&key));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn unknown_kernel_names_force_retune() {
        let tuner = Autotuner::new();
        let kernels = kernel_set();
        let key = PlanKey { op: OpTag::Dense, m: 8, n: 16, batch: 1 };
        tuner.plans.write().unwrap().insert(key, "no_such_kernel".into());
        assert!(tuner.lookup(&key, &kernels).is_none());
    }
}
