//! Thread-parallel dense kernel: the packed microkernel fanned out over
//! `util::par`'s scoped-thread pool.
//!
//! The weight panels come from the same process-wide pack cache as the
//! single-threaded kernel (packed once, shared read-only across
//! workers). The output is partitioned into contiguous row chunks (one
//! per worker); each worker runs `micro::nt_rows_packed` on its chunk,
//! so workers share read-only `x`/panels and own disjoint slices of `Y`
//! — no locks, no false sharing beyond chunk boundaries. Per-row
//! arithmetic is exactly the single-thread routine, so the fan-out is
//! bit-identical to `dense_tiled` (the engine's fixed-lane contract).

use super::micro;
use super::pack;
use super::{KernelOp, MatmulKernel};
use crate::tensor::Matrix;
use crate::util::par;

/// Row-parallel packed-microkernel dense kernel.
pub struct ParallelKernel;

impl MatmulKernel for ParallelKernel {
    fn name(&self) -> &'static str {
        "dense_parallel"
    }

    fn supports(&self, op: &KernelOp<'_>, _batch: usize) -> bool {
        matches!(op, KernelOp::DenseNt { .. })
    }

    fn run(&self, x: &Matrix, op: &KernelOp<'_>) -> Matrix {
        let mut y = Matrix::zeros(x.rows, op.out_features());
        self.run_into_buf(x, op, &mut y.data);
        y
    }

    fn run_into(&self, x: &Matrix, op: &KernelOp<'_>, out: &mut Matrix) {
        out.reset(x.rows, op.out_features());
        self.run_into_buf(x, op, &mut out.data);
    }
}

impl ParallelKernel {
    fn run_into_buf(&self, x: &Matrix, op: &KernelOp<'_>, out: &mut [f32]) {
        let KernelOp::DenseNt { w } = op else {
            unreachable!("ParallelKernel only supports DenseNt (checked via supports)")
        };
        let batch = x.rows;
        let n = w.rows;
        if batch == 0 || n == 0 {
            return;
        }
        let panels = pack::pack_cache().rows(w);
        let mode = micro::simd_mode();
        let chunk_rows = batch.div_ceil(par::num_threads()).max(1);
        par::par_chunks_mut(out, chunk_rows * n, |ci, chunk| {
            let t0 = ci * chunk_rows;
            let rows = chunk.len() / n;
            micro::nt_rows_packed(mode, x, &panels, t0, rows, chunk);
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::TiledKernel;
    use crate::tensor::Rng;

    #[test]
    fn matches_reference_above_and_below_thread_counts() {
        let mut rng = Rng::new(830);
        for &(batch, k, n) in &[(1, 32, 16), (3, 64, 64), (64, 128, 96), (130, 40, 25)] {
            let x = rng.gaussian_matrix(batch, k, 1.0);
            let w = rng.gaussian_matrix(n, k, 1.0);
            let y = ParallelKernel.run(&x, &KernelOp::DenseNt { w: &w });
            let y_ref = crate::tensor::matmul_nt(&x, &w);
            assert!(
                y.sub(&y_ref).fro_norm() < 1e-3 * (1.0 + y_ref.fro_norm()),
                "mismatch at batch={batch} k={k} n={n}"
            );
        }
    }

    #[test]
    fn bit_identical_to_single_thread() {
        let mut rng = Rng::new(831);
        for &(batch, k, n) in &[(1, 9, 5), (17, 33, 20), (64, 100, 31)] {
            let x = rng.gaussian_matrix(batch, k, 1.0);
            let w = rng.gaussian_matrix(n, k, 1.0);
            let y_par = ParallelKernel.run(&x, &KernelOp::DenseNt { w: &w });
            let y_one = TiledKernel.run(&x, &KernelOp::DenseNt { w: &w });
            for (i, (a, b)) in y_par.data.iter().zip(&y_one.data).enumerate() {
                assert_eq!(a.to_bits(), b.to_bits(), "batch={batch} k={k} n={n} elem {i}");
            }
        }
    }
}
