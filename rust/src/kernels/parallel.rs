//! Thread-parallel dense kernel: the tiled row micro-kernel fanned out
//! over `util::par`'s scoped-thread pool.
//!
//! The output is partitioned into contiguous row chunks (one per worker);
//! each worker runs `tiled::dense_nt_rows` on its chunk, so workers share
//! read-only `x`/`W` and own disjoint slices of `Y` — no locks, no false
//! sharing beyond chunk boundaries. For a single-row batch the chunking
//! degenerates to one chunk and `par_chunks_mut` runs it inline, so the
//! kernel is safe (if pointless) at decode shapes; the autotuner is what
//! keeps it off them.

use super::{tiled, KernelOp, MatmulKernel};
use crate::tensor::Matrix;
use crate::util::par;

/// Row-parallel tiled dense kernel.
pub struct ParallelKernel;

impl MatmulKernel for ParallelKernel {
    fn name(&self) -> &'static str {
        "dense_parallel"
    }

    fn supports(&self, op: &KernelOp<'_>, _batch: usize) -> bool {
        matches!(op, KernelOp::DenseNt { .. })
    }

    fn run(&self, x: &Matrix, op: &KernelOp<'_>) -> Matrix {
        let KernelOp::DenseNt { w } = op else {
            unreachable!("ParallelKernel only supports DenseNt (checked via supports)")
        };
        let batch = x.rows;
        let n = w.rows;
        let mut y = Matrix::zeros(batch, n);
        if batch == 0 || n == 0 {
            return y;
        }
        let chunk_rows = batch.div_ceil(par::num_threads()).max(1);
        par::par_chunks_mut(&mut y.data, chunk_rows * n, |ci, chunk| {
            let t0 = ci * chunk_rows;
            let rows = chunk.len() / n;
            tiled::dense_nt_rows(x, w, t0, rows, chunk);
        });
        y
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Rng;

    #[test]
    fn matches_reference_above_and_below_thread_counts() {
        let mut rng = Rng::new(830);
        for &(batch, k, n) in &[(1, 32, 16), (3, 64, 64), (64, 128, 96), (130, 40, 25)] {
            let x = rng.gaussian_matrix(batch, k, 1.0);
            let w = rng.gaussian_matrix(n, k, 1.0);
            let y = ParallelKernel.run(&x, &KernelOp::DenseNt { w: &w });
            let y_ref = crate::tensor::matmul_nt(&x, &w);
            assert!(
                y.sub(&y_ref).fro_norm() < 1e-3 * (1.0 + y_ref.fro_norm()),
                "mismatch at batch={batch} k={k} n={n}"
            );
        }
    }
}
