//! The reference kernel: the fixed-lane accumulation contract spelled
//! out with no blocking, no packing, no threading, and no SIMD.
//!
//! Deliberately the slowest correct implementation — it mirrors the
//! textbook structure of each op (dense `y_{t,o} = x_t · w_o`;
//! structure plans stage by stage, one row and one element at a time,
//! with col-packed factors explicitly gathered) while computing **every
//! contraction with [`micro::dot8`]**, the portable contract-defining
//! dot product. Under the engine-wide fixed-lane contract the optimized
//! kernels must reproduce this kernel's results *bit for bit* (not
//! approximately), which is what `tests/kernel_parity.rs` asserts; the
//! autotuner can therefore fall back to it for any op without changing
//! a single bit.
//!
//! [`micro::dot8`]: super::micro::dot8

use super::micro::dot8;
use super::plan;
use super::{KernelOp, MatmulKernel, SimdMode};
use crate::tensor::Matrix;

/// Contract-reference kernel (supports every op).
pub struct NaiveKernel;

impl MatmulKernel for NaiveKernel {
    fn name(&self) -> &'static str {
        "naive"
    }

    fn supports(&self, _op: &KernelOp<'_>, _batch: usize) -> bool {
        true
    }

    fn run(&self, x: &Matrix, op: &KernelOp<'_>) -> Matrix {
        let mut y = Matrix::zeros(x.rows, op.out_features());
        self.run_into(x, op, &mut y);
        y
    }

    fn run_into(&self, x: &Matrix, op: &KernelOp<'_>, out: &mut Matrix) {
        out.reset(x.rows, op.out_features());
        match op {
            KernelOp::DenseNt { w } => dense_nt(x, w, out),
            // The reference executor keeps its per-row scratch in
            // thread-locals, so this stays allocation-free when the
            // autotuner picks `naive` for a hot decode shape.
            KernelOp::Plan { plan, ops } => {
                plan::execute_reference(SimdMode::Portable, x, plan, ops, &mut out.data)
            }
        }
    }
}

fn dense_nt(x: &Matrix, w: &Matrix, y: &mut Matrix) {
    let (batch, _k) = x.shape();
    let out = w.rows;
    for t in 0..batch {
        for o in 0..out {
            y.set(t, o, dot8(x.row(t), w.row(o)));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::blast::BlastMatrix;
    use crate::tensor::Rng;

    #[test]
    fn dense_matches_tensor_ops() {
        let mut rng = Rng::new(810);
        let x = rng.gaussian_matrix(4, 9, 1.0);
        let w = rng.gaussian_matrix(7, 9, 1.0);
        let y = NaiveKernel.run(&x, &KernelOp::DenseNt { w: &w });
        let y_ref = crate::tensor::matmul_nt(&x, &w);
        assert!(y.sub(&y_ref).fro_norm() < 1e-4 * (1.0 + y_ref.fro_norm()));
    }

    #[test]
    fn blast_plan_matches_dense_reconstruction() {
        let mut rng = Rng::new(811);
        let a = BlastMatrix::random_init(10, 15, 5, 3, 1.0, &mut rng);
        let x = rng.gaussian_matrix(3, 15, 1.0);
        let plan = a.plan();
        let y = NaiveKernel.run(&x, &KernelOp::Plan { plan: &plan, ops: a.plan_operands() });
        let y_ref = crate::tensor::matmul_nt(&x, &a.to_dense());
        assert!(y.sub(&y_ref).fro_norm() < 1e-3 * (1.0 + y_ref.fro_norm()));
    }
}
