//! The reference kernel: the fixed-lane accumulation contract spelled
//! out with no blocking, no packing, no threading, and no SIMD.
//!
//! Deliberately the slowest correct implementation — it mirrors the
//! textbook structure of each op (dense `y_{t,o} = x_t · w_o`; BLAST
//! Algorithm 1 block by block) while computing **every contraction with
//! [`micro::dot8`]**, the portable contract-defining dot product. Under
//! the engine-wide fixed-lane contract the optimized kernels must
//! reproduce this kernel's results *bit for bit* (not approximately),
//! which is what `tests/kernel_parity.rs` asserts; the autotuner can
//! therefore fall back to it for any op without changing a single bit.
//!
//! [`micro::dot8`]: super::micro::dot8

use super::micro::dot8;
use super::{BlastView, KernelOp, MatmulKernel};
use crate::tensor::Matrix;
use std::cell::RefCell;

thread_local! {
    /// Per-thread (vcol, z, w) scratch for the BLAST reference path, so
    /// `run_into` stays allocation-free — the autotuner may legitimately
    /// pick `naive` for a hot decode shape, and the engine-wide
    /// zero-allocation guarantee must not depend on which kernel wins.
    static SCRATCH: RefCell<(Vec<f32>, Vec<f32>, Vec<f32>)> =
        const { RefCell::new((Vec::new(), Vec::new(), Vec::new())) };
}

/// Contract-reference kernel (supports every op).
pub struct NaiveKernel;

impl MatmulKernel for NaiveKernel {
    fn name(&self) -> &'static str {
        "naive"
    }

    fn supports(&self, _op: &KernelOp<'_>, _batch: usize) -> bool {
        true
    }

    fn run(&self, x: &Matrix, op: &KernelOp<'_>) -> Matrix {
        let mut y = Matrix::zeros(x.rows, op.out_features());
        self.run_into(x, op, &mut y);
        y
    }

    fn run_into(&self, x: &Matrix, op: &KernelOp<'_>, out: &mut Matrix) {
        out.reset(x.rows, op.out_features());
        match op {
            KernelOp::DenseNt { w } => dense_nt(x, w, out),
            KernelOp::Blast(a) => blast_act(x, a, out),
        }
    }
}

fn dense_nt(x: &Matrix, w: &Matrix, y: &mut Matrix) {
    let (batch, _k) = x.shape();
    let out = w.rows;
    for t in 0..batch {
        for o in 0..out {
            y.set(t, o, dot8(x.row(t), w.row(o)));
        }
    }
}

/// Algorithm 1, one block at a time, one token at a time. Stage 1 dots
/// run over an explicitly gathered `V_j` column so the contraction order
/// (ascending position within the block, 8-lane strided) matches the
/// packed fused kernel exactly.
fn blast_act(x: &Matrix, a: &BlastView<'_>, y: &mut Matrix) {
    let (p, q, b, r) = (a.p(), a.q(), a.b, a.r);
    let batch = x.rows;
    SCRATCH.with(|cell| {
        let mut scratch = cell.borrow_mut();
        let (vcol, z, w) = &mut *scratch;
        vcol.clear();
        vcol.resize(q, 0.0);
        z.clear();
        z.resize(b * r, 0.0);
        w.clear();
        w.resize(r, 0.0);
        for t in 0..batch {
            let xrow = x.row(t);
            // Stage 1: z_j[k] = dot8(x_j, V_j[:, k]).
            for j in 0..b {
                let vj = a.v(j);
                let xj = &xrow[j * q..(j + 1) * q];
                for k in 0..r {
                    for (c, slot) in vcol.iter_mut().enumerate() {
                        *slot = vj.at(c, k);
                    }
                    z[j * r + k] = dot8(xj, vcol);
                }
            }
            // Stage 2 (ascending j, per element) + stage 3 per block row.
            for i in 0..b {
                w.iter_mut().for_each(|v| *v = 0.0);
                for j in 0..b {
                    let s = a.s_row(i, j);
                    for k in 0..r {
                        w[k] += s[k] * z[j * r + k];
                    }
                }
                for c in 0..p {
                    y.set(t, i * p + c, dot8(a.u(i).row(c), w));
                }
            }
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::blast::BlastMatrix;
    use crate::tensor::Rng;

    #[test]
    fn dense_matches_tensor_ops() {
        let mut rng = Rng::new(810);
        let x = rng.gaussian_matrix(4, 9, 1.0);
        let w = rng.gaussian_matrix(7, 9, 1.0);
        let y = NaiveKernel.run(&x, &KernelOp::DenseNt { w: &w });
        let y_ref = crate::tensor::matmul_nt(&x, &w);
        assert!(y.sub(&y_ref).fro_norm() < 1e-4 * (1.0 + y_ref.fro_norm()));
    }

    #[test]
    fn blast_matches_dense_reconstruction() {
        let mut rng = Rng::new(811);
        let a = BlastMatrix::random_init(10, 15, 5, 3, 1.0, &mut rng);
        let x = rng.gaussian_matrix(3, 15, 1.0);
        let view = super::super::BlastView::from_matrix(&a);
        let y = NaiveKernel.run(&x, &KernelOp::Blast(view));
        let y_ref = crate::tensor::matmul_nt(&x, &a.to_dense());
        assert!(y.sub(&y_ref).fro_norm() < 1e-3 * (1.0 + y_ref.fro_norm()));
    }
}
