//! The reference kernel: scalar triple loops, no blocking, no threading.
//!
//! This is deliberately the slowest correct implementation — it mirrors
//! the textbook definition of each op (dense `y_{t,o} = Σ_k x_{t,k}
//! w_{o,k}`; BLAST Algorithm 1 block by block) so that every optimized
//! kernel has an unambiguous parity target, and so the autotuner always
//! has a universal fallback that supports every op.

use super::{BlastView, KernelOp, MatmulKernel};
use crate::tensor::Matrix;

/// Scalar reference kernel (supports every op).
pub struct NaiveKernel;

impl MatmulKernel for NaiveKernel {
    fn name(&self) -> &'static str {
        "naive"
    }

    fn supports(&self, _op: &KernelOp<'_>, _batch: usize) -> bool {
        true
    }

    fn run(&self, x: &Matrix, op: &KernelOp<'_>) -> Matrix {
        match op {
            KernelOp::DenseNt { w } => dense_nt(x, w),
            KernelOp::Blast(a) => blast_act(x, a),
        }
    }
}

fn dense_nt(x: &Matrix, w: &Matrix) -> Matrix {
    let (batch, k) = x.shape();
    let out = w.rows;
    let mut y = Matrix::zeros(batch, out);
    for t in 0..batch {
        for o in 0..out {
            let mut acc = 0.0f32;
            for c in 0..k {
                acc += x.at(t, c) * w.at(o, c);
            }
            y.set(t, o, acc);
        }
    }
    y
}

/// Algorithm 1, one block at a time, one token at a time.
fn blast_act(x: &Matrix, a: &BlastView<'_>) -> Matrix {
    let (p, q, b, r) = (a.p(), a.q(), a.b, a.r);
    let batch = x.rows;
    let mut y = Matrix::zeros(batch, a.m);
    for t in 0..batch {
        let xrow = x.row(t);
        // Stage 1: z_j = V_jᵀ x_j, column-major access into V (naive).
        let mut z = vec![0.0f32; b * r];
        for j in 0..b {
            for k in 0..r {
                let mut acc = 0.0f32;
                for c in 0..q {
                    acc += xrow[j * q + c] * a.v[j].at(c, k);
                }
                z[j * r + k] = acc;
            }
        }
        // Stages 2+3 per output block row.
        for i in 0..b {
            let mut w = vec![0.0f32; r];
            for j in 0..b {
                let s = a.s_row(i, j);
                for k in 0..r {
                    w[k] += s[k] * z[j * r + k];
                }
            }
            for c in 0..p {
                let mut acc = 0.0f32;
                for k in 0..r {
                    acc += a.u[i].at(c, k) * w[k];
                }
                y.set(t, i * p + c, acc);
            }
        }
    }
    y
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::blast::BlastMatrix;
    use crate::tensor::Rng;

    #[test]
    fn dense_matches_tensor_ops() {
        let mut rng = Rng::new(810);
        let x = rng.gaussian_matrix(4, 9, 1.0);
        let w = rng.gaussian_matrix(7, 9, 1.0);
        let y = NaiveKernel.run(&x, &KernelOp::DenseNt { w: &w });
        let y_ref = crate::tensor::matmul_nt(&x, &w);
        assert!(y.sub(&y_ref).fro_norm() < 1e-4 * (1.0 + y_ref.fro_norm()));
    }

    #[test]
    fn blast_matches_dense_reconstruction() {
        let mut rng = Rng::new(811);
        let a = BlastMatrix::random_init(10, 15, 5, 3, 1.0, &mut rng);
        let x = rng.gaussian_matrix(3, 15, 1.0);
        let view = super::super::BlastView::from_matrix(&a);
        let y = NaiveKernel.run(&x, &KernelOp::Blast(view));
        let y_ref = crate::tensor::matmul_nt(&x, &a.to_dense());
        assert!(y.sub(&y_ref).fro_norm() < 1e-3 * (1.0 + y_ref.fro_norm()));
    }
}
