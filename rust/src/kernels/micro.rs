//! The packed SIMD microkernel: the one place that defines the engine's
//! accumulation-order contract.
//!
//! ## The fixed-lane accumulation contract
//!
//! Every contraction the kernel engine performs — the dense
//! `y = x · wᵀ` dot products, BLAST stage 1 (`z_j = V_jᵀ x_j`) and
//! stage 3 (`y_i = U_i w_i`) — is computed as a **fixed 8-lane strided
//! partial sum reduced in a fixed tree order**:
//!
//! * element `c` of the shared dimension is accumulated into lane
//!   `c % LANES` (the input is consumed in ascending 8-element chunks);
//! * a short final chunk is zero-padded on *both* operands to a full
//!   `LANES`-wide step, so the tail performs the same eight
//!   multiply-adds as every other chunk;
//! * the eight lane sums are reduced as
//!   `((l0+l1)+(l2+l3)) + ((l4+l5)+(l6+l7))` — never left-to-right.
//!
//! Every kernel — including the naive reference — computes every output
//! element with exactly this recipe, so the autotuner's per-(shape,
//! batch) kernel choice, the pack cache, and the `BLAST_SIMD` runtime
//! dispatch can never change a result by a single bit. This replaces
//! the pre-SIMD engine contract ("one sequential ascending-k sum per
//! element"); the prefill/decode identity and the parallel-schedule
//! identity now rest on this definition instead.
//!
//! ## SIMD dispatch
//!
//! [`simd_mode`] resolves `BLAST_SIMD=auto|avx2|portable` (default
//! `auto`) once per process:
//!
//! * `portable` — `[f32; 8]` lane arrays over `chunks_exact(8)`; stable
//!   Rust, auto-vectorizes on any target.
//! * `avx2` — `std::arch` 256-bit intrinsics behind
//!   `is_x86_feature_detected!("avx2")` (+"fma" detection for the
//!   dispatch decision). Forcing `avx2` on hardware without it falls
//!   back to portable.
//!
//! The AVX2 path deliberately uses `mul` + `add` rather than `fmadd`:
//! fused multiply-add skips the intermediate rounding step, which would
//! produce different bits than the portable two-rounding sequence and
//! break the contract above. The throughput win comes from the 8-wide
//! lanes, register blocking, and packed panels — not from fusion.
//!
//! ## The microkernel
//!
//! [`nt_rows_packed`] computes an `MR×NR` register block of
//! `Y = X · Wᵀ` per inner iteration against a [`pack::PackedPanels`]
//! B-panel (`NR` weight rows interleaved per 8-wide k-chunk, so the
//! inner loop streams one contiguous panel). `MR=2` activation rows ×
//! `NR=4` panel rows keeps `MR·NR = 8` vector accumulators plus the
//! operand loads inside the 16 ymm registers of AVX2.
//!
//! ## The int8 path
//!
//! [`qnt_block_packed`] is the quantized sibling of [`nt_block_packed`]:
//! int8 weights from a [`pack::QuantPanels`] (per-packed-row symmetric
//! scales, same 8-wide k-chunk interleave) against int8 activations
//! produced per stage by [`quantize_row_i8`] (per-row symmetric,
//! always scalar, so activation bits never depend on the SIMD mode).
//! Its accumulation contract is *stronger* than the f32 one: products
//! and sums live in **i32, which is exact**, so the portable scalar
//! loop and the AVX2 `maddubs`/`madd` pipeline agree **bit-for-bit in
//! i32 by construction** — no fixed lane assignment or reduction tree
//! is needed. The only rounding happens in the shared scalar finish,
//! `acc as f32 * (x_scale * w_scale)`, which both paths execute
//! identically. Two guardrails make the AVX2 path exact rather than
//! saturating:
//!
//! * quantized values are clamped to `[-127, 127]` (never −128), so a
//!   `maddubs` pair sum is at most `2·127·127 = 32258 < i16::MAX` — the
//!   saturating instruction never saturates;
//! * the sign of each activation byte is transferred onto the weight
//!   byte (`sign_epi8`) so `maddubs`'s unsigned×signed operands are
//!   `|x| · (w·sign(x))`, whose i16/i32 totals equal the signed scalar
//!   products exactly. i32 accumulation overflows only past
//!   `k ≈ 2.6·10⁵·8` — far beyond any layer here.
//!
//! Quantized results carry a **bounded-error** guarantee against the
//! f32 contract (≤1e-2 relative, tested per structure), not a bit
//! guarantee; within the int8 family, portable vs AVX2 and sequential
//! vs row-parallel remain bit-identical.
//!
//! [`pack::PackedPanels`]: super::pack::PackedPanels
//! [`pack::QuantPanels`]: super::pack::QuantPanels

use super::pack::{PackedPanels, QuantPanels};
use std::sync::OnceLock;

/// SIMD vector width in f32 lanes. Fixed by the accumulation contract —
/// changing it changes every result bit.
pub const LANES: usize = 8;
/// Activation rows per microkernel block.
pub const MR: usize = 2;
/// Packed weight rows per microkernel block (panel tile height).
pub const NR: usize = 4;

/// Which instruction set the packed kernels run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SimdMode {
    /// Runtime-detected best (AVX2 when available, else portable).
    Auto,
    /// Force the `std::arch` AVX2 path (falls back if undetected).
    Avx2,
    /// Force the portable lane-array path.
    Portable,
}

impl SimdMode {
    /// Parse the `BLAST_SIMD` value; unknown strings mean `Auto`.
    pub fn parse(s: &str) -> SimdMode {
        match s.to_ascii_lowercase().as_str() {
            "avx2" => SimdMode::Avx2,
            "portable" | "scalar" => SimdMode::Portable,
            _ => SimdMode::Auto,
        }
    }

    /// Whether this mode runs the AVX2 path on the current machine.
    pub fn use_avx2(self) -> bool {
        match self {
            SimdMode::Portable => false,
            SimdMode::Auto | SimdMode::Avx2 => avx2_detected(),
        }
    }
}

/// True when the CPU supports the AVX2(+FMA) path.
pub fn avx2_detected() -> bool {
    #[cfg(target_arch = "x86_64")]
    {
        std::arch::is_x86_feature_detected!("avx2")
            && std::arch::is_x86_feature_detected!("fma")
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        false
    }
}

/// Process-wide dispatch mode from `BLAST_SIMD` (resolved once through
/// [`EngineConfig`](crate::util::config::EngineConfig)).
pub fn simd_mode() -> SimdMode {
    use crate::util::config::{EngineConfig, SimdPref};
    static MODE: OnceLock<SimdMode> = OnceLock::new();
    *MODE.get_or_init(|| match EngineConfig::global().simd {
        SimdPref::Auto => SimdMode::Auto,
        SimdPref::Avx2 => SimdMode::Avx2,
        SimdPref::Portable => SimdMode::Portable,
    })
}

/// The contract's fixed reduction tree over the eight lane sums.
#[inline(always)]
pub fn reduce_lanes(l: &[f32; LANES]) -> f32 {
    ((l[0] + l[1]) + (l[2] + l[3])) + ((l[4] + l[5]) + (l[6] + l[7]))
}

/// Contract-defining dot product (portable): 8-lane strided partials
/// over ascending chunks, zero-padded tail, fixed-tree reduction. This
/// is what the naive reference kernel runs; every packed/SIMD path must
/// reproduce its bits exactly.
pub fn dot8(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut lanes = [0.0f32; LANES];
    let mut ca = a.chunks_exact(LANES);
    let mut cb = b.chunks_exact(LANES);
    for (xa, xb) in (&mut ca).zip(&mut cb) {
        for l in 0..LANES {
            lanes[l] += xa[l] * xb[l];
        }
    }
    let (ra, rb) = (ca.remainder(), cb.remainder());
    if !ra.is_empty() {
        let mut pa = [0.0f32; LANES];
        let mut pb = [0.0f32; LANES];
        pa[..ra.len()].copy_from_slice(ra);
        pb[..rb.len()].copy_from_slice(rb);
        for l in 0..LANES {
            lanes[l] += pa[l] * pb[l];
        }
    }
    reduce_lanes(&lanes)
}

/// [`dot8`] with explicit SIMD dispatch — bit-identical to the portable
/// [`dot8`] in every mode (same lane assignment, same padded tail, same
/// reduction tree; AVX2 uses mul+add, see the module docs). Used by the
/// unpacked dense paths (`matmul_nt_static`/`matmul_nt_serial`), whose
/// operands are transient activations that would churn the pack cache.
#[cfg(target_arch = "x86_64")]
pub fn dot8_with(mode: SimdMode, a: &[f32], b: &[f32]) -> f32 {
    if mode.use_avx2() {
        // SAFETY: avx2 detected (checked by use_avx2).
        unsafe { dot8_avx2(a, b) }
    } else {
        dot8(a, b)
    }
}

#[cfg(not(target_arch = "x86_64"))]
pub fn dot8_with(_mode: SimdMode, a: &[f32], b: &[f32]) -> f32 {
    dot8(a, b)
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn dot8_avx2(a: &[f32], b: &[f32]) -> f32 {
    use std::arch::x86_64::*;
    debug_assert_eq!(a.len(), b.len());
    let k = a.len();
    let kc = k.div_ceil(LANES);
    let full = if has_ragged_tail(k, kc) { kc - 1 } else { kc };
    let mut vacc = _mm256_setzero_ps();
    let (pa, pb) = (a.as_ptr(), b.as_ptr());
    for c in 0..full {
        let va = _mm256_loadu_ps(pa.add(c * LANES));
        let vb = _mm256_loadu_ps(pb.add(c * LANES));
        vacc = _mm256_add_ps(vacc, _mm256_mul_ps(va, vb));
    }
    if full != kc {
        let ta = padded_tail(a, kc);
        let tb = padded_tail(b, kc);
        let va = _mm256_loadu_ps(ta.as_ptr());
        let vb = _mm256_loadu_ps(tb.as_ptr());
        vacc = _mm256_add_ps(vacc, _mm256_mul_ps(va, vb));
    }
    let mut lanes = [0.0f32; LANES];
    _mm256_storeu_ps(lanes.as_mut_ptr(), vacc);
    reduce_lanes(&lanes)
}

/// `y[o] = dot8(x, W.row(o))` for every packed output row, without
/// going through a `Matrix` — the single-row slice form of
/// [`nt_block_packed`] (`x` is one activation row or one
/// coupling-weighted stage vector). `y.len()` may be shorter than the
/// padded tile grid; extra (padding) rows are computed into the
/// register block and discarded.
pub fn nt_row_packed(mode: SimdMode, x: &[f32], panels: &PackedPanels, y: &mut [f32]) {
    debug_assert_eq!(x.len(), panels.k);
    debug_assert_eq!(y.len(), panels.n);
    let use_avx2 = mode.use_avx2();
    for tile in 0..panels.tiles() {
        let j0 = tile * NR;
        let jn = (j0 + NR).min(panels.n);
        if j0 >= panels.n {
            break;
        }
        let mut acc = [[0.0f32; LANES]; NR];
        let panel = panels.panel(tile);
        mk_1xnr(use_avx2, x, panel, panels.kc, &mut acc);
        for (jj, j) in (j0..jn).enumerate() {
            y[j] = reduce_lanes(&acc[jj]);
        }
    }
}

/// 1×NR microkernel dispatch (the cfg split keeps the call sites free
/// of per-statement `cfg` attributes).
#[cfg(target_arch = "x86_64")]
#[inline]
fn mk_1xnr(use_avx2: bool, x: &[f32], panel: &[f32], kc: usize, acc: &mut [[f32; LANES]; NR]) {
    if use_avx2 {
        // SAFETY: avx2 detected (checked by SimdMode::use_avx2).
        unsafe { mk_1xnr_avx2(x, panel, kc, acc) }
    } else {
        mk_1xnr_portable(x, panel, kc, acc)
    }
}

#[cfg(not(target_arch = "x86_64"))]
#[inline]
fn mk_1xnr(_use_avx2: bool, x: &[f32], panel: &[f32], kc: usize, acc: &mut [[f32; LANES]; NR]) {
    mk_1xnr_portable(x, panel, kc, acc)
}

/// MR×NR microkernel dispatch.
#[cfg(target_arch = "x86_64")]
#[inline]
fn mk_2xnr(
    use_avx2: bool,
    xa: &[f32],
    xb: &[f32],
    panel: &[f32],
    kc: usize,
    acc: &mut [[[f32; LANES]; NR]; MR],
) {
    if use_avx2 {
        // SAFETY: avx2 detected (checked by SimdMode::use_avx2).
        unsafe { mk_2xnr_avx2(xa, xb, panel, kc, acc) }
    } else {
        mk_2xnr_portable(xa, xb, panel, kc, acc)
    }
}

#[cfg(not(target_arch = "x86_64"))]
#[inline]
fn mk_2xnr(
    _use_avx2: bool,
    xa: &[f32],
    xb: &[f32],
    panel: &[f32],
    kc: usize,
    acc: &mut [[[f32; LANES]; NR]; MR],
) {
    mk_2xnr_portable(xa, xb, panel, kc, acc)
}

/// Rows `t0 .. t0+rows` of `Y = X · Wᵀ` against packed panels, written
/// into `out` (a `rows × panels.n` row-major slice). `MR`-row blocked;
/// this is the dense kernels' shared inner routine (the parallel kernel
/// hands each worker a disjoint `out` chunk).
pub fn nt_rows_packed(
    mode: SimdMode,
    x: &crate::tensor::Matrix,
    panels: &PackedPanels,
    t0: usize,
    rows: usize,
    out: &mut [f32],
) {
    debug_assert_eq!(x.cols, panels.k);
    debug_assert_eq!(out.len(), rows * panels.n);
    nt_block_packed(mode, &x.data, x.cols, t0, 0, panels, rows, out, panels.n, 0, false);
}

/// The structure-plan generalization of [`nt_rows_packed`]: a packed
/// `X · Wᵀ` over a *column window* of a strided source buffer, written
/// into a *column window* of a strided destination buffer, optionally
/// accumulating (`dst += …`, sequential adds in block-dispatch order —
/// the plan executor's Monarch aggregation stage relies on this order
/// being deterministic).
///
/// Source row `tt` is `src[(src_t0+tt)·src_stride + src_col ..][..k]`;
/// destination row `tt` is
/// `dst[tt·dst_stride + dst_col ..][..panels.n]` (destination rows are
/// chunk-local, matching the parallel kernels' disjoint-chunk
/// convention). Per-element arithmetic is the fixed-lane contract
/// exactly — `dst = / += reduce_lanes(8-lane strided partials)` — so
/// this routine is bit-identical to per-element [`dot8`] whatever the
/// windowing.
#[allow(clippy::too_many_arguments)]
pub fn nt_block_packed(
    mode: SimdMode,
    src: &[f32],
    src_stride: usize,
    src_t0: usize,
    src_col: usize,
    panels: &PackedPanels,
    rows: usize,
    dst: &mut [f32],
    dst_stride: usize,
    dst_col: usize,
    accumulate: bool,
) {
    let k = panels.k;
    let n = panels.n;
    debug_assert!(src_col + k <= src_stride.max(k));
    debug_assert!(dst_col + n <= dst_stride.max(n));
    let use_avx2 = mode.use_avx2();
    for tile in 0..panels.tiles() {
        let j0 = tile * NR;
        let jn = (j0 + NR).min(n);
        if j0 >= n {
            break;
        }
        let panel = panels.panel(tile);
        let mut t = 0usize;
        while t + MR <= rows {
            let xa = &src[(src_t0 + t) * src_stride + src_col..][..k];
            let xb = &src[(src_t0 + t + 1) * src_stride + src_col..][..k];
            let mut acc = [[[0.0f32; LANES]; NR]; MR];
            mk_2xnr(use_avx2, xa, xb, panel, panels.kc, &mut acc);
            for (tt, row_acc) in acc.iter().enumerate() {
                for (jj, j) in (j0..jn).enumerate() {
                    let slot = &mut dst[(t + tt) * dst_stride + dst_col + j];
                    let v = reduce_lanes(&row_acc[jj]);
                    if accumulate {
                        *slot += v;
                    } else {
                        *slot = v;
                    }
                }
            }
            t += MR;
        }
        while t < rows {
            let xa = &src[(src_t0 + t) * src_stride + src_col..][..k];
            let mut acc = [[0.0f32; LANES]; NR];
            mk_1xnr(use_avx2, xa, panel, panels.kc, &mut acc);
            for (jj, j) in (j0..jn).enumerate() {
                let slot = &mut dst[t * dst_stride + dst_col + j];
                let v = reduce_lanes(&acc[jj]);
                if accumulate {
                    *slot += v;
                } else {
                    *slot = v;
                }
            }
            t += 1;
        }
    }
}

/// Quantize one activation row to int8 with a symmetric per-row scale.
///
/// `out` must be at least `x.len()` long; every byte past `x.len()` is
/// zeroed, so callers can size `out` to a full k-chunk grid and let the
/// quantized kernels read whole chunks without a padded-tail special
/// case (padding bytes multiply against zero weight padding — exact
/// zero in i32). Returns the scale `max|x| / 127` (`0.0` for all-zero
/// or non-finite rows, which quantize to all zeros).
///
/// Always scalar: activation bits must not depend on `BLAST_SIMD`.
/// Values are clamped to `[-127, 127]` — never −128 — which is what
/// keeps the AVX2 `maddubs` path saturation-free (see module docs).
pub fn quantize_row_i8(x: &[f32], out: &mut [i8]) -> f32 {
    debug_assert!(out.len() >= x.len());
    let max_abs = x.iter().fold(0.0f32, |m, v| m.max(v.abs()));
    if max_abs == 0.0 || !max_abs.is_finite() {
        out.fill(0);
        return 0.0;
    }
    let inv = 127.0 / max_abs;
    for (o, &v) in out.iter_mut().zip(x) {
        *o = (v * inv).round().clamp(-127.0, 127.0) as i8;
    }
    out[x.len()..].fill(0);
    max_abs / 127.0
}

/// Quantized sibling of [`nt_block_packed`]: `dst = / += dequant(Xq · Wqᵀ)`
/// over a column window of a strided **int8** source buffer against a
/// [`QuantPanels`] weight, finishing each i32 accumulator with the
/// single shared scalar multiply `acc as f32 * (x_scale * w_scale)`.
///
/// Unlike the f32 routine there is no padded-tail path: source row `tt`
/// must expose `panels.kc * LANES` readable bytes starting at
/// `src[(src_t0+tt)·src_stride + src_col]`, i.e. the activation buffer
/// is padded past the widest window (see [`quantize_row_i8`]). Bytes at
/// k-positions ≥ `panels.k` meet zero weight padding and contribute
/// exact zero. `src_scales[src_t0 + tt]` is row `tt`'s activation
/// scale; windowing never re-scales, because the scale is a whole-row
/// property.
///
/// Portable and AVX2 agree bit-for-bit (i32 accumulation is exact; the
/// f32 finish is shared), so `BLAST_SIMD` never changes quantized
/// results either.
#[allow(clippy::too_many_arguments)]
pub fn qnt_block_packed(
    mode: SimdMode,
    src: &[i8],
    src_scales: &[f32],
    src_stride: usize,
    src_t0: usize,
    src_col: usize,
    panels: &QuantPanels,
    rows: usize,
    dst: &mut [f32],
    dst_stride: usize,
    dst_col: usize,
    accumulate: bool,
) {
    let n = panels.n;
    let kb = panels.kc * LANES;
    debug_assert!(
        src_col + kb <= src_stride.max(kb),
        "int8 source rows must be padded to a full k-chunk past the window"
    );
    debug_assert!(dst_col + n <= dst_stride.max(n));
    let use_avx2 = mode.use_avx2();
    for tile in 0..panels.tiles() {
        let j0 = tile * NR;
        let jn = (j0 + NR).min(n);
        if j0 >= n {
            break;
        }
        let panel = panels.panel(tile);
        let wscales = panels.tile_scales(tile);
        let mut t = 0usize;
        while t + MR <= rows {
            let xa = &src[(src_t0 + t) * src_stride + src_col..][..kb];
            let xb = &src[(src_t0 + t + 1) * src_stride + src_col..][..kb];
            let mut acc = [[0i32; NR]; MR];
            mk_q_2xnr(use_avx2, xa, xb, panel, panels.kc, &mut acc);
            for (tt, row_acc) in acc.iter().enumerate() {
                let xs = src_scales[src_t0 + t + tt];
                for (jj, j) in (j0..jn).enumerate() {
                    let slot = &mut dst[(t + tt) * dst_stride + dst_col + j];
                    let v = row_acc[jj] as f32 * (xs * wscales[jj]);
                    if accumulate {
                        *slot += v;
                    } else {
                        *slot = v;
                    }
                }
            }
            t += MR;
        }
        while t < rows {
            let xa = &src[(src_t0 + t) * src_stride + src_col..][..kb];
            let mut acc = [0i32; NR];
            mk_q_1xnr(use_avx2, xa, panel, panels.kc, &mut acc);
            let xs = src_scales[src_t0 + t];
            for (jj, j) in (j0..jn).enumerate() {
                let slot = &mut dst[t * dst_stride + dst_col + j];
                let v = acc[jj] as f32 * (xs * wscales[jj]);
                if accumulate {
                    *slot += v;
                } else {
                    *slot = v;
                }
            }
            t += 1;
        }
    }
}

/// 1×NR int8 microkernel dispatch.
#[cfg(target_arch = "x86_64")]
#[inline]
fn mk_q_1xnr(use_avx2: bool, x: &[i8], panel: &[i8], kc: usize, acc: &mut [i32; NR]) {
    if use_avx2 {
        // SAFETY: avx2 detected (checked by SimdMode::use_avx2).
        unsafe { mk_q_1xnr_avx2(x, panel, kc, acc) }
    } else {
        mk_q_1xnr_portable(x, panel, kc, acc)
    }
}

#[cfg(not(target_arch = "x86_64"))]
#[inline]
fn mk_q_1xnr(_use_avx2: bool, x: &[i8], panel: &[i8], kc: usize, acc: &mut [i32; NR]) {
    mk_q_1xnr_portable(x, panel, kc, acc)
}

/// MR×NR int8 microkernel dispatch.
#[cfg(target_arch = "x86_64")]
#[inline]
fn mk_q_2xnr(
    use_avx2: bool,
    xa: &[i8],
    xb: &[i8],
    panel: &[i8],
    kc: usize,
    acc: &mut [[i32; NR]; MR],
) {
    if use_avx2 {
        // SAFETY: avx2 detected (checked by SimdMode::use_avx2).
        unsafe { mk_q_2xnr_avx2(xa, xb, panel, kc, acc) }
    } else {
        mk_q_2xnr_portable(xa, xb, panel, kc, acc)
    }
}

#[cfg(not(target_arch = "x86_64"))]
#[inline]
fn mk_q_2xnr(
    _use_avx2: bool,
    xa: &[i8],
    xb: &[i8],
    panel: &[i8],
    kc: usize,
    acc: &mut [[i32; NR]; MR],
) {
    mk_q_2xnr_portable(xa, xb, panel, kc, acc)
}

fn mk_q_1xnr_portable(x: &[i8], panel: &[i8], kc: usize, acc: &mut [i32; NR]) {
    debug_assert!(x.len() >= kc * LANES);
    for c in 0..kc {
        let xc = &x[c * LANES..(c + 1) * LANES];
        let base = c * NR * LANES;
        for (j, aj) in acc.iter_mut().enumerate() {
            let pj = &panel[base + j * LANES..base + (j + 1) * LANES];
            let mut s = 0i32;
            for l in 0..LANES {
                s += xc[l] as i32 * pj[l] as i32;
            }
            *aj += s;
        }
    }
}

fn mk_q_2xnr_portable(xa: &[i8], xb: &[i8], panel: &[i8], kc: usize, acc: &mut [[i32; NR]; MR]) {
    let (a0, a1) = {
        let (h, t) = acc.split_at_mut(1);
        (&mut h[0], &mut t[0])
    };
    mk_q_1xnr_portable(xa, panel, kc, a0);
    mk_q_1xnr_portable(xb, panel, kc, a1);
}

// ----------------------------------------------------------------------
// AVX2 int8 microkernels
// ----------------------------------------------------------------------
//
// One k-chunk of one tile is exactly NR·LANES = 32 int8 values — one
// __m256i whose 64-bit lane j holds weight row j's 8 bytes. The 8
// activation bytes are broadcast across all four 64-bit lanes, the
// activation sign is transferred onto the weight bytes (`sign_epi8`),
// and `maddubs` (u8·i8 pair sums, non-saturating here — see module
// docs) + `madd`(·1) yield 8 i32 lanes where row j = lane 2j + 2j+1.
// Everything is exact in i32, so this matches the portable scalar loop
// bit-for-bit with no ordering discipline required.

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn mk_q_1xnr_avx2(x: &[i8], panel: &[i8], kc: usize, acc: &mut [i32; NR]) {
    use std::arch::x86_64::*;
    debug_assert!(x.len() >= kc * LANES);
    let ones = _mm256_set1_epi16(1);
    let mut vacc = _mm256_setzero_si256();
    let pp = panel.as_ptr();
    let xp = x.as_ptr();
    for c in 0..kc {
        let bits = (xp.add(c * LANES) as *const i64).read_unaligned();
        let vx = _mm256_set1_epi64x(bits);
        let vw = _mm256_loadu_si256(pp.add(c * NR * LANES) as *const __m256i);
        let ax = _mm256_sign_epi8(vx, vx);
        let sw = _mm256_sign_epi8(vw, vx);
        let p16 = _mm256_maddubs_epi16(ax, sw);
        vacc = _mm256_add_epi32(vacc, _mm256_madd_epi16(p16, ones));
    }
    let mut lanes = [0i32; LANES];
    _mm256_storeu_si256(lanes.as_mut_ptr() as *mut __m256i, vacc);
    for (j, aj) in acc.iter_mut().enumerate() {
        *aj += lanes[2 * j] + lanes[2 * j + 1];
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn mk_q_2xnr_avx2(
    xa: &[i8],
    xb: &[i8],
    panel: &[i8],
    kc: usize,
    acc: &mut [[i32; NR]; MR],
) {
    use std::arch::x86_64::*;
    debug_assert!(xa.len() >= kc * LANES && xb.len() >= kc * LANES);
    let ones = _mm256_set1_epi16(1);
    let mut vacc0 = _mm256_setzero_si256();
    let mut vacc1 = _mm256_setzero_si256();
    let pp = panel.as_ptr();
    let pa = xa.as_ptr();
    let pb = xb.as_ptr();
    for c in 0..kc {
        let vw = _mm256_loadu_si256(pp.add(c * NR * LANES) as *const __m256i);
        let va = _mm256_set1_epi64x((pa.add(c * LANES) as *const i64).read_unaligned());
        let vb = _mm256_set1_epi64x((pb.add(c * LANES) as *const i64).read_unaligned());
        let p16a = _mm256_maddubs_epi16(_mm256_sign_epi8(va, va), _mm256_sign_epi8(vw, va));
        let p16b = _mm256_maddubs_epi16(_mm256_sign_epi8(vb, vb), _mm256_sign_epi8(vw, vb));
        vacc0 = _mm256_add_epi32(vacc0, _mm256_madd_epi16(p16a, ones));
        vacc1 = _mm256_add_epi32(vacc1, _mm256_madd_epi16(p16b, ones));
    }
    let mut l0 = [0i32; LANES];
    let mut l1 = [0i32; LANES];
    _mm256_storeu_si256(l0.as_mut_ptr() as *mut __m256i, vacc0);
    _mm256_storeu_si256(l1.as_mut_ptr() as *mut __m256i, vacc1);
    for j in 0..NR {
        acc[0][j] += l0[2 * j] + l0[2 * j + 1];
        acc[1][j] += l1[2 * j] + l1[2 * j + 1];
    }
}

/// Copy the ≤8-element tail of `x` into a zero-padded chunk (the
/// contract's tail semantics: missing elements contribute `+0.0 · 0.0`).
#[inline(always)]
fn padded_tail(x: &[f32], kc: usize) -> [f32; LANES] {
    let mut pad = [0.0f32; LANES];
    let tail = &x[(kc - 1) * LANES..];
    pad[..tail.len()].copy_from_slice(tail);
    pad
}

/// Whether the final k-chunk of a length-`k` operand needs padding.
#[inline(always)]
fn has_ragged_tail(k: usize, kc: usize) -> bool {
    kc * LANES != k
}

// ----------------------------------------------------------------------
// Portable microkernels (auto-vectorizing lane arrays)
// ----------------------------------------------------------------------

fn mk_1xnr_portable(x: &[f32], panel: &[f32], kc: usize, acc: &mut [[f32; LANES]; NR]) {
    let full = if has_ragged_tail(x.len(), kc) { kc - 1 } else { kc };
    for c in 0..full {
        let xa: &[f32] = &x[c * LANES..(c + 1) * LANES];
        let base = c * NR * LANES;
        for j in 0..NR {
            let pj = &panel[base + j * LANES..base + (j + 1) * LANES];
            let aj = &mut acc[j];
            for l in 0..LANES {
                aj[l] += xa[l] * pj[l];
            }
        }
    }
    if full != kc {
        let pad = padded_tail(x, kc);
        let base = (kc - 1) * NR * LANES;
        for j in 0..NR {
            let pj = &panel[base + j * LANES..base + (j + 1) * LANES];
            let aj = &mut acc[j];
            for l in 0..LANES {
                aj[l] += pad[l] * pj[l];
            }
        }
    }
}

fn mk_2xnr_portable(
    xa: &[f32],
    xb: &[f32],
    panel: &[f32],
    kc: usize,
    acc: &mut [[[f32; LANES]; NR]; MR],
) {
    let full = if has_ragged_tail(xa.len(), kc) { kc - 1 } else { kc };
    for c in 0..full {
        let va: &[f32] = &xa[c * LANES..(c + 1) * LANES];
        let vb: &[f32] = &xb[c * LANES..(c + 1) * LANES];
        let base = c * NR * LANES;
        for j in 0..NR {
            let pj = &panel[base + j * LANES..base + (j + 1) * LANES];
            let (a0, a1) = {
                let (h, t) = acc.split_at_mut(1);
                (&mut h[0][j], &mut t[0][j])
            };
            for l in 0..LANES {
                a0[l] += va[l] * pj[l];
                a1[l] += vb[l] * pj[l];
            }
        }
    }
    if full != kc {
        let pa = padded_tail(xa, kc);
        let pb = padded_tail(xb, kc);
        let base = (kc - 1) * NR * LANES;
        for j in 0..NR {
            let pj = &panel[base + j * LANES..base + (j + 1) * LANES];
            let (a0, a1) = {
                let (h, t) = acc.split_at_mut(1);
                (&mut h[0][j], &mut t[0][j])
            };
            for l in 0..LANES {
                a0[l] += pa[l] * pj[l];
                a1[l] += pb[l] * pj[l];
            }
        }
    }
}

// ----------------------------------------------------------------------
// AVX2 microkernels
// ----------------------------------------------------------------------
//
// `mul` + `add` (NOT `fmadd`): bit-identity with the portable path
// requires the same two-rounding sequence per lane step (see the module
// docs). Lane assignment and reduction happen in the same order as the
// portable kernels, so the final stores are bit-identical.

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn mk_1xnr_avx2(x: &[f32], panel: &[f32], kc: usize, acc: &mut [[f32; LANES]; NR]) {
    use std::arch::x86_64::*;
    let full = if has_ragged_tail(x.len(), kc) { kc - 1 } else { kc };
    let mut va_acc = [_mm256_setzero_ps(); NR];
    let pp = panel.as_ptr();
    let xp = x.as_ptr();
    for c in 0..full {
        let vx = _mm256_loadu_ps(xp.add(c * LANES));
        let base = c * NR * LANES;
        for j in 0..NR {
            let pj = _mm256_loadu_ps(pp.add(base + j * LANES));
            va_acc[j] = _mm256_add_ps(va_acc[j], _mm256_mul_ps(vx, pj));
        }
    }
    if full != kc {
        let pad = padded_tail(x, kc);
        let vx = _mm256_loadu_ps(pad.as_ptr());
        let base = (kc - 1) * NR * LANES;
        for j in 0..NR {
            let pj = _mm256_loadu_ps(pp.add(base + j * LANES));
            va_acc[j] = _mm256_add_ps(va_acc[j], _mm256_mul_ps(vx, pj));
        }
    }
    for j in 0..NR {
        _mm256_storeu_ps(acc[j].as_mut_ptr(), va_acc[j]);
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn mk_2xnr_avx2(
    xa: &[f32],
    xb: &[f32],
    panel: &[f32],
    kc: usize,
    acc: &mut [[[f32; LANES]; NR]; MR],
) {
    use std::arch::x86_64::*;
    let full = if has_ragged_tail(xa.len(), kc) { kc - 1 } else { kc };
    let mut acc0 = [_mm256_setzero_ps(); NR];
    let mut acc1 = [_mm256_setzero_ps(); NR];
    let pp = panel.as_ptr();
    let pa = xa.as_ptr();
    let pb = xb.as_ptr();
    for c in 0..full {
        let va = _mm256_loadu_ps(pa.add(c * LANES));
        let vb = _mm256_loadu_ps(pb.add(c * LANES));
        let base = c * NR * LANES;
        for j in 0..NR {
            let pj = _mm256_loadu_ps(pp.add(base + j * LANES));
            acc0[j] = _mm256_add_ps(acc0[j], _mm256_mul_ps(va, pj));
            acc1[j] = _mm256_add_ps(acc1[j], _mm256_mul_ps(vb, pj));
        }
    }
    if full != kc {
        let ta = padded_tail(xa, kc);
        let tb = padded_tail(xb, kc);
        let va = _mm256_loadu_ps(ta.as_ptr());
        let vb = _mm256_loadu_ps(tb.as_ptr());
        let base = (kc - 1) * NR * LANES;
        for j in 0..NR {
            let pj = _mm256_loadu_ps(pp.add(base + j * LANES));
            acc0[j] = _mm256_add_ps(acc0[j], _mm256_mul_ps(va, pj));
            acc1[j] = _mm256_add_ps(acc1[j], _mm256_mul_ps(vb, pj));
        }
    }
    for j in 0..NR {
        _mm256_storeu_ps(acc[0][j].as_mut_ptr(), acc0[j]);
        _mm256_storeu_ps(acc[1][j].as_mut_ptr(), acc1[j]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::{Matrix, Rng};

    /// f64 reference dot (order-independent up to f64 rounding).
    fn dot_ref(a: &[f32], b: &[f32]) -> f32 {
        a.iter().zip(b).map(|(x, y)| (*x as f64) * (*y as f64)).sum::<f64>() as f32
    }

    #[test]
    fn dot8_close_to_reference_across_lengths() {
        let mut rng = Rng::new(860);
        for k in [1usize, 2, 7, 8, 9, 15, 16, 17, 64, 100, 257] {
            let a = rng.gaussian_matrix(1, k, 1.0);
            let b = rng.gaussian_matrix(1, k, 1.0);
            let got = dot8(a.row(0), b.row(0));
            let want = dot_ref(a.row(0), b.row(0));
            assert!(
                (got - want).abs() <= 1e-4 * (1.0 + want.abs()),
                "k={k}: {got} vs {want}"
            );
        }
    }

    #[test]
    fn reduce_tree_is_fixed_order() {
        let lanes = [1e8f32, 1.0, -1e8, 1.0, 3.0, 4.0, 5.0, 6.0];
        // ((1e8+1)+(-1e8+1)) + ((3+4)+(5+6)) with f32 rounding at each
        // step — NOT the left-to-right sum.
        let expect = ((1e8f32 + 1.0) + (-1e8f32 + 1.0)) + ((3.0f32 + 4.0) + (5.0f32 + 6.0));
        assert_eq!(reduce_lanes(&lanes), expect);
    }

    #[test]
    fn packed_row_matches_dot8_bitwise() {
        let mut rng = Rng::new(861);
        // Awkward shapes: k not a multiple of 8, n < NR, n straddling
        // tile edges.
        for &(n, k) in &[(1usize, 3usize), (3, 8), (4, 9), (5, 16), (13, 31), (17, 64)] {
            let w = rng.gaussian_matrix(n, k, 1.0);
            let x = rng.gaussian_matrix(1, k, 1.0);
            let panels = PackedPanels::pack_rows(&w);
            let mut y = vec![0.0f32; n];
            nt_row_packed(SimdMode::Portable, x.row(0), &panels, &mut y);
            for o in 0..n {
                let want = dot8(x.row(0), w.row(o));
                assert!(
                    y[o].to_bits() == want.to_bits(),
                    "n={n} k={k} o={o}: packed {} vs dot8 {want}",
                    y[o]
                );
            }
        }
    }

    #[test]
    fn packed_rows_match_dot8_bitwise_all_batch_shapes() {
        let mut rng = Rng::new(862);
        for &(batch, n, k) in &[(1usize, 5usize, 7usize), (2, 4, 8), (3, 9, 17), (5, 2, 40)] {
            let w = rng.gaussian_matrix(n, k, 1.0);
            let x = rng.gaussian_matrix(batch, k, 1.0);
            let panels = PackedPanels::pack_rows(&w);
            let mut out = vec![0.0f32; batch * n];
            nt_rows_packed(SimdMode::Portable, &x, &panels, 0, batch, &mut out);
            for t in 0..batch {
                for o in 0..n {
                    let want = dot8(x.row(t), w.row(o));
                    assert_eq!(
                        out[t * n + o].to_bits(),
                        want.to_bits(),
                        "batch={batch} n={n} k={k} t={t} o={o}"
                    );
                }
            }
        }
    }

    #[test]
    fn block_packed_windows_match_dot8_bitwise() {
        // Column-windowed source/destination with strides (the plan
        // executor's block-gather/scatter form) must match per-element
        // dot8 on the gathered slices exactly, in both write and
        // accumulate modes.
        let mut rng = Rng::new(865);
        let (rows, src_stride, src_col, k, n, dst_stride, dst_col) = (5usize, 20, 3, 9, 6, 15, 4);
        let src = rng.gaussian_matrix(rows, src_stride, 1.0);
        let w = rng.gaussian_matrix(n, k, 1.0);
        let panels = PackedPanels::pack_rows(&w);
        let mut dst = vec![0.0f32; rows * dst_stride];
        nt_block_packed(
            SimdMode::Portable,
            &src.data,
            src_stride,
            0,
            src_col,
            &panels,
            rows,
            &mut dst,
            dst_stride,
            dst_col,
            false,
        );
        for t in 0..rows {
            let xs = &src.row(t)[src_col..src_col + k];
            for o in 0..n {
                let want = dot8(xs, w.row(o));
                assert_eq!(
                    dst[t * dst_stride + dst_col + o].to_bits(),
                    want.to_bits(),
                    "write mode t={t} o={o}"
                );
            }
        }
        // Accumulate mode: run again, expect exactly the sequential sum
        // of the two identical contributions.
        let before = dst.clone();
        nt_block_packed(
            SimdMode::Portable,
            &src.data,
            src_stride,
            0,
            src_col,
            &panels,
            rows,
            &mut dst,
            dst_stride,
            dst_col,
            true,
        );
        for t in 0..rows {
            for o in 0..n {
                let idx = t * dst_stride + dst_col + o;
                let want = before[idx] + dot8(&src.row(t)[src_col..src_col + k], w.row(o));
                assert_eq!(dst[idx].to_bits(), want.to_bits(), "accumulate mode t={t} o={o}");
            }
        }
        // Untouched destination columns stay zero.
        for t in 0..rows {
            for c in 0..dst_col {
                assert_eq!(dst[t * dst_stride + c], 0.0);
            }
        }
    }

    #[test]
    fn block_packed_src_t0_offset_selects_rows() {
        let mut rng = Rng::new(866);
        let x = rng.gaussian_matrix(6, 10, 1.0);
        let w = rng.gaussian_matrix(4, 10, 1.0);
        let panels = PackedPanels::pack_rows(&w);
        // Compute rows 2..5 into a chunk-local buffer.
        let mut chunk = vec![0.0f32; 3 * 4];
        nt_block_packed(SimdMode::Portable, &x.data, 10, 2, 0, &panels, 3, &mut chunk, 4, 0, false);
        for tt in 0..3 {
            for o in 0..4 {
                let want = dot8(x.row(2 + tt), w.row(o));
                assert_eq!(chunk[tt * 4 + o].to_bits(), want.to_bits(), "tt={tt} o={o}");
            }
        }
    }

    #[test]
    fn avx2_bit_identical_to_portable_when_detected() {
        if !avx2_detected() {
            eprintln!("avx2 not detected; skipping SIMD bit-identity check");
            return;
        }
        let mut rng = Rng::new(863);
        for &(batch, n, k) in
            &[(1usize, 3usize, 9usize), (2, 8, 64), (5, 13, 31), (7, 40, 129), (4, 4, 8)]
        {
            let w = rng.gaussian_matrix(n, k, 1.0);
            let x = rng.gaussian_matrix(batch, k, 1.0);
            let panels = PackedPanels::pack_rows(&w);
            let mut a = vec![0.0f32; batch * n];
            let mut b = vec![0.0f32; batch * n];
            nt_rows_packed(SimdMode::Portable, &x, &panels, 0, batch, &mut a);
            nt_rows_packed(SimdMode::Avx2, &x, &panels, 0, batch, &mut b);
            for (i, (pa, pb)) in a.iter().zip(&b).enumerate() {
                assert_eq!(
                    pa.to_bits(),
                    pb.to_bits(),
                    "batch={batch} n={n} k={k} elem {i}: portable {pa} vs avx2 {pb}"
                );
            }
        }
    }

    #[test]
    fn dot8_with_modes_bit_identical() {
        if !avx2_detected() {
            eprintln!("avx2 not detected; skipping dot8_with identity check");
            return;
        }
        let mut rng = Rng::new(864);
        for k in [1usize, 7, 8, 9, 31, 64, 257] {
            let a = rng.gaussian_matrix(1, k, 1.0);
            let b = rng.gaussian_matrix(1, k, 1.0);
            let p = dot8_with(SimdMode::Portable, a.row(0), b.row(0));
            let v = dot8_with(SimdMode::Avx2, a.row(0), b.row(0));
            assert_eq!(p.to_bits(), v.to_bits(), "k={k}: portable {p} vs avx2 {v}");
            assert_eq!(p.to_bits(), dot8(a.row(0), b.row(0)).to_bits());
        }
    }

    #[test]
    fn simd_mode_parsing() {
        assert_eq!(SimdMode::parse("avx2"), SimdMode::Avx2);
        assert_eq!(SimdMode::parse("AVX2"), SimdMode::Avx2);
        assert_eq!(SimdMode::parse("portable"), SimdMode::Portable);
        assert_eq!(SimdMode::parse("scalar"), SimdMode::Portable);
        assert_eq!(SimdMode::parse("auto"), SimdMode::Auto);
        assert_eq!(SimdMode::parse("garbage"), SimdMode::Auto);
        assert!(!SimdMode::Portable.use_avx2());
    }

    /// Dequantized int8 value of weight row `o`, k-index `c`.
    fn qw_at(p: &QuantPanels, o: usize, c: usize) -> i32 {
        let panel = p.panel(o / NR);
        panel[(c / LANES) * NR * LANES + (o % NR) * LANES + (c % LANES)] as i32
    }

    #[test]
    fn quantize_row_i8_scale_padding_and_clamp() {
        let x = [1.0f32, -2.0, 0.5, -4.0];
        let mut q = [7i8; 8]; // oversized: padding must be zeroed
        let s = quantize_row_i8(&x, &mut q);
        assert_eq!(s, 4.0 / 127.0);
        assert_eq!(q[..4], [32, -64, 16, -127]);
        assert_eq!(q[4..], [0, 0, 0, 0]);
        // All-zero row: zero scale, zero bytes.
        let s0 = quantize_row_i8(&[0.0, -0.0], &mut q[..4]);
        assert_eq!(s0, 0.0);
        assert_eq!(q[..4], [0, 0, 0, 0]);
        // −128 is never produced, whatever the data.
        let mut rng = Rng::new(890);
        let row: Vec<f32> = (0..257).map(|_| rng.gaussian() * 10.0).collect();
        let mut qr = vec![0i8; 264];
        quantize_row_i8(&row, &mut qr);
        assert!(qr.iter().all(|&v| v != i8::MIN), "clamp must exclude -128");
    }

    #[test]
    fn qnt_block_packed_matches_integer_reference_bitwise() {
        // The quantized kernel's contract: an exact i32 dot of the
        // quantized operands, then one shared f32 finish. Check it
        // bitwise against a scalar reconstruction — portable mode.
        let mut rng = Rng::new(891);
        for &(rows, n, k) in &[(1usize, 3usize, 9usize), (2, 4, 8), (3, 5, 7), (5, 13, 31), (4, 2, 40)] {
            let w = rng.gaussian_matrix(n, k, 1.0);
            let x = rng.gaussian_matrix(rows, k, 1.0);
            let panels = QuantPanels::pack_rows(&w);
            let kb = panels.kc * LANES;
            let mut srcq = vec![0i8; rows * kb];
            let mut scales = vec![0.0f32; rows];
            for t in 0..rows {
                scales[t] = quantize_row_i8(x.row(t), &mut srcq[t * kb..(t + 1) * kb]);
            }
            let mut out = vec![0.0f32; rows * n];
            qnt_block_packed(
                SimdMode::Portable, &srcq, &scales, kb, 0, 0, &panels, rows, &mut out, n, 0, false,
            );
            for t in 0..rows {
                for o in 0..n {
                    let acc: i32 = (0..k)
                        .map(|c| srcq[t * kb + c] as i32 * qw_at(&panels, o, c))
                        .sum();
                    let want = acc as f32 * (scales[t] * panels.tile_scales(o / NR)[o % NR]);
                    assert_eq!(
                        out[t * n + o].to_bits(),
                        want.to_bits(),
                        "rows={rows} n={n} k={k} t={t} o={o}"
                    );
                }
            }
        }
    }

    #[test]
    fn qnt_block_packed_window_and_accumulate() {
        // Column-windowed strided source with src_t0 offset, plus
        // accumulate mode — the plan executor's gather/scatter form.
        let mut rng = Rng::new(892);
        let (rows, k, n, src_col, dst_col, dst_stride) = (5usize, 9usize, 6usize, 3, 4, 15);
        let w = rng.gaussian_matrix(n, k, 1.0);
        let panels = QuantPanels::pack_rows(&w);
        let kb = panels.kc * LANES;
        // Row payload is wider than the window; stride leaves a full
        // k-chunk readable past src_col (the padding requirement).
        let stride = src_col + kb + 2;
        let x = rng.gaussian_matrix(rows + 2, stride, 1.0);
        let mut srcq = vec![0i8; (rows + 2) * stride];
        let mut scales = vec![0.0f32; rows + 2];
        for t in 0..rows + 2 {
            scales[t] = quantize_row_i8(&x.row(t)[..stride], &mut srcq[t * stride..(t + 1) * stride]);
        }
        let mut dst = vec![0.0f32; rows * dst_stride];
        qnt_block_packed(
            SimdMode::Portable, &srcq, &scales, stride, 2, src_col, &panels, rows, &mut dst,
            dst_stride, dst_col, false,
        );
        let expect_one = |t: usize, o: usize| -> f32 {
            // Window bytes past k are real quantized activations, but
            // the weight padding there is zero — exact zero products.
            let acc: i32 = (0..k)
                .map(|c| srcq[(2 + t) * stride + src_col + c] as i32 * qw_at(&panels, o, c))
                .sum();
            acc as f32 * (scales[2 + t] * panels.tile_scales(o / NR)[o % NR])
        };
        for t in 0..rows {
            for o in 0..n {
                let got = dst[t * dst_stride + dst_col + o];
                assert_eq!(got.to_bits(), expect_one(t, o).to_bits(), "write t={t} o={o}");
            }
        }
        let before = dst.clone();
        qnt_block_packed(
            SimdMode::Portable, &srcq, &scales, stride, 2, src_col, &panels, rows, &mut dst,
            dst_stride, dst_col, true,
        );
        for t in 0..rows {
            for o in 0..n {
                let idx = t * dst_stride + dst_col + o;
                let want = before[idx] + expect_one(t, o);
                assert_eq!(dst[idx].to_bits(), want.to_bits(), "accumulate t={t} o={o}");
            }
        }
        for t in 0..rows {
            for c in 0..dst_col {
                assert_eq!(dst[t * dst_stride + c], 0.0, "untouched dst column");
            }
        }
    }

    #[test]
    fn qnt_avx2_bit_identical_to_portable_when_detected() {
        if !avx2_detected() {
            eprintln!("avx2 not detected; skipping int8 SIMD bit-identity check");
            return;
        }
        let mut rng = Rng::new(893);
        for &(rows, n, k) in
            &[(1usize, 3usize, 9usize), (2, 8, 64), (5, 13, 31), (7, 40, 129), (4, 4, 8)]
        {
            let w = rng.gaussian_matrix(n, k, 1.0);
            let x = rng.gaussian_matrix(rows, k, 1.0);
            let panels = QuantPanels::pack_rows(&w);
            let kb = panels.kc * LANES;
            let mut srcq = vec![0i8; rows * kb];
            let mut scales = vec![0.0f32; rows];
            for t in 0..rows {
                scales[t] = quantize_row_i8(x.row(t), &mut srcq[t * kb..(t + 1) * kb]);
            }
            let mut a = vec![0.0f32; rows * n];
            let mut b = vec![0.0f32; rows * n];
            qnt_block_packed(
                SimdMode::Portable, &srcq, &scales, kb, 0, 0, &panels, rows, &mut a, n, 0, false,
            );
            qnt_block_packed(
                SimdMode::Avx2, &srcq, &scales, kb, 0, 0, &panels, rows, &mut b, n, 0, false,
            );
            for (i, (pa, pb)) in a.iter().zip(&b).enumerate() {
                assert_eq!(
                    pa.to_bits(),
                    pb.to_bits(),
                    "rows={rows} n={n} k={k} elem {i}: portable {pa} vs avx2 {pb}"
                );
            }
        }
    }

    #[test]
    fn qnt_error_within_deterministic_quantization_bound() {
        // |y_q − y| ≤ (s_w/2)·‖x‖₁ + (s_x/2)·‖w‖₁ + k·s_x·s_w/4 — the
        // worst-case round-to-nearest bound, plus f32 rounding slop.
        let mut rng = Rng::new(894);
        for &(rows, n, k) in &[(3usize, 5usize, 17usize), (2, 8, 64), (1, 13, 100)] {
            let w = rng.gaussian_matrix(n, k, 1.0);
            let x = rng.gaussian_matrix(rows, k, 1.0);
            let panels = QuantPanels::pack_rows(&w);
            let kb = panels.kc * LANES;
            let mut srcq = vec![0i8; rows * kb];
            let mut scales = vec![0.0f32; rows];
            for t in 0..rows {
                scales[t] = quantize_row_i8(x.row(t), &mut srcq[t * kb..(t + 1) * kb]);
            }
            let mut out = vec![0.0f32; rows * n];
            qnt_block_packed(
                SimdMode::Portable, &srcq, &scales, kb, 0, 0, &panels, rows, &mut out, n, 0, false,
            );
            for t in 0..rows {
                let x1: f32 = x.row(t).iter().map(|v| v.abs()).sum();
                for o in 0..n {
                    let want = dot_ref(x.row(t), w.row(o));
                    let w1: f32 = w.row(o).iter().map(|v| v.abs()).sum();
                    let (sx, sw) = (scales[t], panels.tile_scales(o / NR)[o % NR]);
                    let tol =
                        0.5 * (sw * x1 + sx * w1) + 0.25 * k as f32 * sx * sw + 1e-4 * (1.0 + want.abs());
                    let got = out[t * n + o];
                    assert!(
                        (got - want).abs() <= tol,
                        "rows={rows} n={n} k={k} t={t} o={o}: {got} vs {want} (tol {tol})"
                    );
                }
            }
        }
    }

    #[test]
    fn zero_padding_tail_preserves_sign_semantics() {
        // The padded tail must behave exactly like dot8's padded tail —
        // including for negative zeros in the data.
        let w = Matrix::from_vec(1, 3, vec![-0.0, 2.0, -3.0]);
        let x = Matrix::from_vec(1, 3, vec![1.0, -0.0, 0.5]);
        let panels = PackedPanels::pack_rows(&w);
        let mut y = vec![0.0f32; 1];
        nt_row_packed(SimdMode::Portable, x.row(0), &panels, &mut y);
        assert_eq!(y[0].to_bits(), dot8(x.row(0), w.row(0)).to_bits());
    }
}
