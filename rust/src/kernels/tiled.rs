//! Cache-blocked dense `Y = X · Wᵀ` kernel.
//!
//! Blocking scheme (single-threaded; `parallel::ParallelKernel` reuses
//! the same row micro-kernel across threads):
//!
//! * output columns are processed in `NR`-wide register tiles: one pass
//!   over an activation row feeds `NR` simultaneous dot-product
//!   accumulators, so each loaded `x` value is reused `NR` times;
//! * within a column tile the batch loop is outermost per tile, so the
//!   `NR` weight rows stay cache-hot across the activation rows;
//! * every inner product is a single sequential ascending-`k` sum over
//!   contiguous slices — no shared-dimension panel splitting. This is
//!   the engine-wide **bit-stability invariant**: all dense kernels
//!   accumulate each output element in the same order, so the
//!   autotuner's per-(shape, batch) kernel choice can never change
//!   results by a single bit (the prefill/decode identity in
//!   `nn::gpt::prefill` depends on this).

use super::{KernelOp, MatmulKernel};
use crate::tensor::Matrix;

/// Output-column register-tile width.
const NR: usize = 8;

/// Cache-blocked dense kernel.
pub struct TiledKernel;

impl MatmulKernel for TiledKernel {
    fn name(&self) -> &'static str {
        "dense_tiled"
    }

    fn supports(&self, op: &KernelOp<'_>, _batch: usize) -> bool {
        matches!(op, KernelOp::DenseNt { .. })
    }

    fn run(&self, x: &Matrix, op: &KernelOp<'_>) -> Matrix {
        let KernelOp::DenseNt { w } = op else {
            unreachable!("TiledKernel only supports DenseNt (checked via supports)")
        };
        let mut y = Matrix::zeros(x.rows, w.rows);
        dense_nt_rows(x, w, 0, x.rows, &mut y.data);
        y
    }
}

/// Compute rows `t0 .. t0+rows` of `Y = X · Wᵀ` into `out` (a
/// `rows × w.rows` row-major slice). Shared with the parallel kernel,
/// which hands each worker a disjoint output-row chunk.
pub(crate) fn dense_nt_rows(x: &Matrix, w: &Matrix, t0: usize, rows: usize, out: &mut [f32]) {
    let k = x.cols;
    let n = w.rows;
    debug_assert_eq!(out.len(), rows * n);
    for j0 in (0..n).step_by(NR) {
        let j1 = (j0 + NR).min(n);
        let tile = j1 - j0;
        for tt in 0..rows {
            let xrow = x.row(t0 + tt);
            let mut acc = [0.0f32; NR];
            for (jj, j) in (j0..j1).enumerate() {
                let wrow = w.row(j);
                let mut s = 0.0f32;
                // Single sequential ascending-k pass over contiguous
                // slices (see the bit-stability invariant above).
                for c in 0..k {
                    s += xrow[c] * wrow[c];
                }
                acc[jj] = s;
            }
            let yrow = &mut out[tt * n + j0..tt * n + j1];
            yrow.copy_from_slice(&acc[..tile]);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Rng;

    #[test]
    fn matches_reference_across_awkward_shapes() {
        let mut rng = Rng::new(820);
        // Shapes straddling the NR register-tile boundary and large-k cases.
        for &(batch, k, n) in &[
            (1, 1, 1),
            (3, 7, 5),
            (2, 255, 9),
            (4, 256, 8),
            (5, 257, 17),
            (1, 300, 300),
            (9, 520, 33),
        ] {
            let x = rng.gaussian_matrix(batch, k, 1.0);
            let w = rng.gaussian_matrix(n, k, 1.0);
            let y = TiledKernel.run(&x, &KernelOp::DenseNt { w: &w });
            let y_ref = crate::tensor::matmul_nt(&x, &w);
            assert!(
                y.sub(&y_ref).fro_norm() < 1e-3 * (1.0 + y_ref.fro_norm()),
                "mismatch at batch={batch} k={k} n={n}"
            );
        }
    }

    #[test]
    fn declines_blast_ops() {
        let mut rng = Rng::new(821);
        let a = crate::blast::BlastMatrix::random_init(4, 4, 2, 2, 1.0, &mut rng);
        let view = super::super::BlastView::from_matrix(&a);
        assert!(!TiledKernel.supports(&KernelOp::Blast(view), 1));
    }
}
