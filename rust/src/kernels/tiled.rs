//! Packed-microkernel dense `Y = X · Wᵀ` kernel.
//!
//! The weight is repacked once into microkernel B-panels (cached
//! process-wide by `pack::PackCache`, so serving-path layer weights pay
//! the pack exactly once) and the product runs as `MR×NR` register
//! blocks of 8-lane SIMD partial sums (`micro::nt_rows_packed`).
//! `parallel::ParallelKernel` reuses the same routine across threads,
//! one disjoint output-row chunk per worker.
//!
//! Every output element follows the engine-wide **fixed-lane
//! accumulation contract** (see `micro`): 8-lane strided partials over
//! ascending k-chunks, zero-padded tail, fixed-tree reduction. The
//! packed, unpacked, portable, and AVX2 paths all produce identical
//! bits, so the autotuner's per-(shape, batch) kernel choice never
//! changes a result (the prefill/decode identity in `nn::gpt::prefill`
//! depends on this).
//!
//! [`dense_nt_rows_unpacked`] is the cache-free variant for transient
//! activation×activation products (attention scores, factorization
//! sweeps): same bits, no panel reuse to exploit, no pack-cache churn.

use super::micro::{self, SimdMode};
use super::pack;
use super::{KernelOp, MatmulKernel};
use crate::tensor::Matrix;

/// Packed-microkernel dense kernel.
pub struct TiledKernel;

impl MatmulKernel for TiledKernel {
    fn name(&self) -> &'static str {
        "dense_tiled"
    }

    fn supports(&self, op: &KernelOp<'_>, _batch: usize) -> bool {
        matches!(op, KernelOp::DenseNt { .. })
    }

    fn run(&self, x: &Matrix, op: &KernelOp<'_>) -> Matrix {
        let mut y = Matrix::zeros(x.rows, op.out_features());
        self.run_into_buf(x, op, &mut y.data);
        y
    }

    fn run_into(&self, x: &Matrix, op: &KernelOp<'_>, out: &mut Matrix) {
        out.reset(x.rows, op.out_features());
        self.run_into_buf(x, op, &mut out.data);
    }
}

impl TiledKernel {
    fn run_into_buf(&self, x: &Matrix, op: &KernelOp<'_>, out: &mut [f32]) {
        let KernelOp::DenseNt { w } = op else {
            unreachable!("TiledKernel only supports DenseNt (checked via supports)")
        };
        let panels = pack::pack_cache().rows(w);
        micro::nt_rows_packed(micro::simd_mode(), x, &panels, 0, x.rows, out);
    }
}

/// Rows `t0 .. t0+rows` of `Y = X · Wᵀ` into `out` without packing:
/// per-element `dot8_with` over the raw weight rows (weight row held
/// hot across the batch). Bit-identical to the packed path.
pub(crate) fn dense_nt_rows_unpacked(
    mode: SimdMode,
    x: &Matrix,
    w: &Matrix,
    t0: usize,
    rows: usize,
    out: &mut [f32],
) {
    let n = w.rows;
    debug_assert_eq!(out.len(), rows * n);
    for o in 0..n {
        let wrow = w.row(o);
        for tt in 0..rows {
            out[tt * n + o] = micro::dot8_with(mode, x.row(t0 + tt), wrow);
        }
    }
}

/// The pre-SIMD (PR-3) scalar inner loop: one sequential ascending-k
/// accumulator per output element, 8-wide output-column register tiles.
/// Kept **only** as the benchmark baseline for the microkernel speedup
/// gate (`benches/blast_matmul.rs`) — it does *not* satisfy the
/// fixed-lane contract and must never be registered as a kernel.
pub fn dense_nt_rows_scalar_baseline(
    x: &Matrix,
    w: &Matrix,
    t0: usize,
    rows: usize,
    out: &mut [f32],
) {
    const NR: usize = 8;
    let k = x.cols;
    let n = w.rows;
    debug_assert_eq!(out.len(), rows * n);
    for j0 in (0..n).step_by(NR) {
        let j1 = (j0 + NR).min(n);
        let tile = j1 - j0;
        for tt in 0..rows {
            let xrow = x.row(t0 + tt);
            let mut acc = [0.0f32; NR];
            for (jj, j) in (j0..j1).enumerate() {
                let wrow = w.row(j);
                let mut s = 0.0f32;
                for c in 0..k {
                    s += xrow[c] * wrow[c];
                }
                acc[jj] = s;
            }
            let yrow = &mut out[tt * n + j0..tt * n + j1];
            yrow.copy_from_slice(&acc[..tile]);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Rng;

    #[test]
    fn matches_reference_across_awkward_shapes() {
        let mut rng = Rng::new(820);
        // Shapes straddling the NR/MR register blocks, the 8-lane chunk
        // boundary, and large-k cases.
        for &(batch, k, n) in &[
            (1, 1, 1),
            (1, 9, 3),
            (2, 8, 4),
            (3, 7, 5),
            (2, 255, 9),
            (4, 256, 8),
            (5, 257, 17),
            (1, 300, 300),
            (9, 520, 33),
        ] {
            let x = rng.gaussian_matrix(batch, k, 1.0);
            let w = rng.gaussian_matrix(n, k, 1.0);
            let y = TiledKernel.run(&x, &KernelOp::DenseNt { w: &w });
            let y_ref = crate::tensor::matmul_nt(&x, &w);
            assert!(
                y.sub(&y_ref).fro_norm() < 1e-3 * (1.0 + y_ref.fro_norm()),
                "mismatch at batch={batch} k={k} n={n}"
            );
            // Bit-identical to the contract reference.
            let naive = super::super::NaiveKernel.run(&x, &KernelOp::DenseNt { w: &w });
            for (i, (a, b)) in y.data.iter().zip(&naive.data).enumerate() {
                assert_eq!(
                    a.to_bits(),
                    b.to_bits(),
                    "contract violation at batch={batch} k={k} n={n} elem {i}"
                );
            }
        }
    }

    #[test]
    fn unpacked_matches_packed_bitwise() {
        let mut rng = Rng::new(821);
        for &(batch, k, n) in &[(1, 5, 3), (3, 31, 9), (4, 64, 12), (2, 129, 7)] {
            let x = rng.gaussian_matrix(batch, k, 1.0);
            let w = rng.gaussian_matrix(n, k, 1.0);
            let packed = TiledKernel.run(&x, &KernelOp::DenseNt { w: &w });
            let mut unpacked = vec![0.0f32; batch * n];
            dense_nt_rows_unpacked(micro::simd_mode(), &x, &w, 0, batch, &mut unpacked);
            for (i, (a, b)) in packed.data.iter().zip(&unpacked).enumerate() {
                assert_eq!(a.to_bits(), b.to_bits(), "batch={batch} k={k} n={n} elem {i}");
            }
        }
    }

    #[test]
    fn run_into_reuses_buffer_and_matches_run() {
        let mut rng = Rng::new(822);
        let x = rng.gaussian_matrix(3, 20, 1.0);
        let w = rng.gaussian_matrix(7, 20, 1.0);
        let op = KernelOp::DenseNt { w: &w };
        let y = TiledKernel.run(&x, &op);
        let mut out = Matrix::zeros(3, 7);
        let cap = out.data.capacity();
        let ptr = out.data.as_ptr();
        TiledKernel.run_into(&x, &op, &mut out);
        assert_eq!(out.data, y.data);
        assert_eq!(out.data.capacity(), cap);
        assert_eq!(out.data.as_ptr(), ptr, "same-size run_into must not reallocate");
    }

    #[test]
    fn declines_plan_ops() {
        let mut rng = Rng::new(823);
        let a = crate::blast::BlastMatrix::random_init(4, 4, 2, 2, 1.0, &mut rng);
        let plan = a.plan();
        let op = KernelOp::Plan { plan: &plan, ops: a.plan_operands() };
        assert!(!TiledKernel.supports(&op, 1));
    }
}
