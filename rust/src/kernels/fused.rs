//! Fused BLAST Algorithm-1 kernel on the packed SIMD microkernel.
//!
//! Algorithm 1 per token:
//!
//! * **Stage 1 (microkernel)** — `z_j = V_jᵀ x_j` runs as packed
//!   `nt_row` products over the column-packed `V_j` panels (`V_jᵀ` rows
//!   become contiguous panel streams; packed once per factor and cached
//!   process-wide by `pack::PackCache`).
//! * **Stage 2** — the `b²` couplings scale-and-add `z` bands into a
//!   single `w = [w_1 | … | w_b]` buffer, ascending `j` (short: `b·r`
//!   multiply-adds per output block row).
//! * **Stage 3 (microkernel)** — `y_i = U_i w_i` as packed `nt_row`
//!   products over the row-packed `U_i` panels.
//!
//! Stages 1 and 3 follow the engine-wide fixed-lane accumulation
//! contract (see `micro`), so this kernel is bit-identical to the naive
//! reference for every op, batch size, and `BLAST_SIMD` mode.
//!
//! Scratch (`2·b·r` floats) lives in a thread-local pool reused across
//! calls — a steady-state decode dispatch performs **zero heap
//! allocations** through `run_into`. The row-parallel variant
//! (`blast_fused_par`) hands disjoint output-row chunks to `util::par`
//! workers, each with its own thread-local scratch; the sequential
//! variant wins at decode shapes (batch 1) where thread fan-out costs
//! more than the product itself. The autotuner picks.

use super::micro::{self, SimdMode};
use super::pack::{self, PackedPanels};
use super::{BlastView, KernelOp, MatmulKernel};
use crate::tensor::Matrix;
use crate::util::par;
use std::cell::RefCell;
use std::sync::Arc;

/// Per-thread fused-kernel scratch: the (z, w) stage buffers plus the
/// packed-panel handles for the call's `V`/`U` factors, all reused
/// across calls (capacities persist, so a warm call never allocates;
/// clearing the panel vecs only drops `Arc` refcounts).
#[derive(Default)]
struct FusedScratch {
    z: Vec<f32>,
    w: Vec<f32>,
    vpanels: Vec<Arc<PackedPanels>>,
    upanels: Vec<Arc<PackedPanels>>,
}

thread_local! {
    static SCRATCH: RefCell<FusedScratch> = RefCell::new(FusedScratch::default());
}

/// Fused Algorithm-1 kernel (sequential or row-parallel).
pub struct FusedBlastKernel {
    row_parallel: bool,
}

impl FusedBlastKernel {
    /// Single-threaded variant — the decode-path (batch 1) choice.
    pub fn sequential() -> Self {
        FusedBlastKernel { row_parallel: false }
    }

    /// Batch-row-parallel variant — the prefill/training-batch choice.
    pub fn row_parallel() -> Self {
        FusedBlastKernel { row_parallel: true }
    }
}

impl MatmulKernel for FusedBlastKernel {
    fn name(&self) -> &'static str {
        if self.row_parallel {
            "blast_fused_par"
        } else {
            "blast_fused"
        }
    }

    fn supports(&self, op: &KernelOp<'_>, _batch: usize) -> bool {
        matches!(op, KernelOp::Blast(_))
    }

    fn run(&self, x: &Matrix, op: &KernelOp<'_>) -> Matrix {
        let KernelOp::Blast(a) = op else {
            unreachable!("FusedBlastKernel only supports Blast (checked via supports)")
        };
        let mut y = Matrix::zeros(x.rows, a.m);
        self.run_into_buf(x, a, &mut y.data);
        y
    }

    fn run_into(&self, x: &Matrix, op: &KernelOp<'_>, out: &mut Matrix) {
        let KernelOp::Blast(a) = op else {
            unreachable!("FusedBlastKernel only supports Blast (checked via supports)")
        };
        out.reset(x.rows, a.m);
        self.run_into_buf(x, a, &mut out.data);
    }
}

impl FusedBlastKernel {
    fn run_into_buf(&self, x: &Matrix, a: &BlastView<'_>, out: &mut [f32]) {
        let batch = x.rows;
        if batch == 0 {
            return;
        }
        let mode = micro::simd_mode();
        if self.row_parallel && batch > 1 {
            let chunk_rows = batch.div_ceil(par::num_threads()).max(1);
            par::par_chunks_mut(out, chunk_rows * a.m, |ci, chunk| {
                let rows = chunk.len() / a.m;
                fused_rows(mode, x, a, ci * chunk_rows, rows, chunk);
            });
        } else {
            fused_rows(mode, x, a, 0, batch, out);
        }
    }
}

/// Compute output rows `t0 .. t0+rows` into `out` (`rows × a.m`,
/// row-major) with the thread-local `2·b·r` scratch reused across rows.
fn fused_rows(
    mode: SimdMode,
    x: &Matrix,
    a: &BlastView<'_>,
    t0: usize,
    rows: usize,
    out: &mut [f32],
) {
    let (p, q, b, r) = (a.p(), a.q(), a.b, a.r);
    let br = b * r;
    debug_assert_eq!(out.len(), rows * a.m);
    SCRATCH.with(|cell| {
        let mut scratch = cell.borrow_mut();
        let FusedScratch { z, w, vpanels, upanels } = &mut *scratch;
        z.clear();
        z.resize(br, 0.0);
        w.clear();
        w.resize(br, 0.0);
        // Fetch every factor's packed panels once per call (one cache
        // lookup + fingerprint each), not once per token row.
        let cache = pack::pack_cache();
        vpanels.clear();
        upanels.clear();
        for j in 0..b {
            vpanels.push(cache.cols(a.v(j)));
        }
        for i in 0..b {
            upanels.push(cache.rows(a.u(i)));
        }
        for tt in 0..rows {
            let xrow = x.row(t0 + tt);

            // Stage 1 (microkernel): z_j = V_jᵀ x_j over packed panels.
            for j in 0..b {
                let xj = &xrow[j * q..(j + 1) * q];
                micro::nt_row_packed(mode, xj, &vpanels[j], &mut z[j * r..(j + 1) * r]);
            }

            // Stage 2: w[i·r ..] = Σ_j s_{i,j} ⊙ z_j (ascending j).
            w.iter_mut().for_each(|v| *v = 0.0);
            for i in 0..b {
                let wi = &mut w[i * r..(i + 1) * r];
                for j in 0..b {
                    let s = a.s_row(i, j);
                    let zj = &z[j * r..(j + 1) * r];
                    for k in 0..r {
                        wi[k] += s[k] * zj[k];
                    }
                }
            }

            // Stage 3 (microkernel): y_i = U_i w_i over packed panels.
            let yrow = &mut out[tt * a.m..(tt + 1) * a.m];
            for i in 0..b {
                let wi = &w[i * r..(i + 1) * r];
                micro::nt_row_packed(mode, wi, &upanels[i], &mut yrow[i * p..(i + 1) * p]);
            }
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::blast::BlastMatrix;
    use crate::kernels::NaiveKernel;
    use crate::tensor::Rng;

    fn check(a: &BlastMatrix, x: &Matrix, kernel: &FusedBlastKernel) {
        let view = BlastView::from_matrix(a);
        let y = kernel.run(x, &KernelOp::Blast(view));
        let y_ref = crate::tensor::matmul_nt(x, &a.to_dense());
        assert!(
            y.sub(&y_ref).fro_norm() < 1e-3 * (1.0 + y_ref.fro_norm()),
            "fused mismatch (m={}, n={}, b={}, r={}, batch={}, par={})",
            a.m,
            a.n,
            a.b,
            a.r,
            x.rows,
            kernel.row_parallel,
        );
        // Bit-identity with the contract reference.
        let naive = NaiveKernel.run(x, &KernelOp::Blast(BlastView::from_matrix(a)));
        for (i, (got, want)) in y.data.iter().zip(&naive.data).enumerate() {
            assert_eq!(
                got.to_bits(),
                want.to_bits(),
                "contract violation (m={}, b={}, r={}, batch={}, par={}) elem {i}: {got} vs {want}",
                a.m,
                a.b,
                a.r,
                x.rows,
                kernel.row_parallel,
            );
        }
    }

    #[test]
    fn sequential_and_parallel_match_dense() {
        let mut rng = Rng::new(840);
        for &(m, n, b, r, batch) in &[
            (4, 4, 1, 2, 1),
            (8, 8, 2, 3, 1),
            (12, 6, 3, 2, 5),
            (16, 16, 4, 5, 8),
            (10, 15, 5, 4, 33),
            (18, 9, 3, 9, 2), // r > LANES, q not a lane multiple
        ] {
            let a = BlastMatrix::random_init(m, n, b, r, 1.0, &mut rng);
            let x = rng.gaussian_matrix(batch, n, 1.0);
            check(&a, &x, &FusedBlastKernel::sequential());
            check(&a, &x, &FusedBlastKernel::row_parallel());
        }
    }

    #[test]
    fn run_into_matches_run_without_reallocating() {
        let mut rng = Rng::new(842);
        let a = BlastMatrix::random_init(12, 12, 3, 4, 1.0, &mut rng);
        let x = rng.gaussian_matrix(2, 12, 1.0);
        let view = BlastView::from_matrix(&a);
        let y = FusedBlastKernel::sequential().run(&x, &KernelOp::Blast(view));
        let mut out = Matrix::zeros(2, 12);
        let ptr = out.data.as_ptr();
        let view2 = BlastView::from_matrix(&a);
        FusedBlastKernel::sequential().run_into(&x, &KernelOp::Blast(view2), &mut out);
        assert_eq!(out.data, y.data);
        assert_eq!(out.data.as_ptr(), ptr, "same-size run_into must not reallocate");
    }

    #[test]
    fn zero_couplings_give_exact_zero() {
        let mut rng = Rng::new(841);
        let mut a = BlastMatrix::random_init(8, 8, 2, 2, 1.0, &mut rng);
        for i in 0..2 {
            for j in 0..2 {
                a.s[i][j].fill(0.0);
            }
        }
        let x = rng.gaussian_matrix(3, 8, 1.0);
        let view = BlastView::from_matrix(&a);
        let y = FusedBlastKernel::sequential().run(&x, &KernelOp::Blast(view));
        assert!(y.data.iter().all(|&v| v == 0.0));
    }
}
