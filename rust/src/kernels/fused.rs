//! Fused BLAST Algorithm-1 kernel.
//!
//! The baseline (`naive` kernel / the pre-engine `matmul_act`) walks the
//! product block by block: it copies each input block column out with
//! `submatrix`, allocates a fresh `z_j` per block, a fresh `w` per output
//! block row, and a fresh `y_i` per stage-3 product. This kernel fuses
//! the three stages over contiguous buffers instead:
//!
//! * **Stage 1 batched across blocks** — one pass over the activation
//!   row accumulates `z = [z_1 | … | z_b]` (a single `b·r` buffer) via
//!   contiguous axpy over `V_j` rows; no block copies, no per-block
//!   allocation.
//! * **Stage 2** — the `b²` couplings scale-and-add `z` bands into a
//!   single `w = [w_1 | … | w_b]` buffer.
//! * **Stage 3 batched across blocks** — one sweep writes every output
//!   block `y_i = U_i w_i` as contiguous dot products over `U_i` rows.
//!
//! Total scratch per worker: `2·b·r` floats, reused across the whole
//! batch. The row-parallel variant (`blast_fused_par`) hands disjoint
//! output-row chunks to `util::par` workers, each with its own scratch;
//! the sequential variant wins at decode shapes (batch 1) where thread
//! fan-out costs more than the product itself. The autotuner picks.

use super::{BlastView, KernelOp, MatmulKernel};
use crate::tensor::Matrix;
use crate::util::par;

/// Fused Algorithm-1 kernel (sequential or row-parallel).
pub struct FusedBlastKernel {
    row_parallel: bool,
}

impl FusedBlastKernel {
    /// Single-threaded variant — the decode-path (batch 1) choice.
    pub fn sequential() -> Self {
        FusedBlastKernel { row_parallel: false }
    }

    /// Batch-row-parallel variant — the prefill/training-batch choice.
    pub fn row_parallel() -> Self {
        FusedBlastKernel { row_parallel: true }
    }
}

impl MatmulKernel for FusedBlastKernel {
    fn name(&self) -> &'static str {
        if self.row_parallel {
            "blast_fused_par"
        } else {
            "blast_fused"
        }
    }

    fn supports(&self, op: &KernelOp<'_>, _batch: usize) -> bool {
        matches!(op, KernelOp::Blast(_))
    }

    fn run(&self, x: &Matrix, op: &KernelOp<'_>) -> Matrix {
        let KernelOp::Blast(a) = op else {
            unreachable!("FusedBlastKernel only supports Blast (checked via supports)")
        };
        let batch = x.rows;
        let mut y = Matrix::zeros(batch, a.m);
        if batch == 0 {
            return y;
        }
        if self.row_parallel && batch > 1 {
            let chunk_rows = batch.div_ceil(par::num_threads()).max(1);
            par::par_chunks_mut(&mut y.data, chunk_rows * a.m, |ci, chunk| {
                let rows = chunk.len() / a.m;
                fused_rows(x, a, ci * chunk_rows, rows, chunk);
            });
        } else {
            fused_rows(x, a, 0, batch, &mut y.data);
        }
        y
    }
}

/// Compute output rows `t0 .. t0+rows` into `out` (`rows × a.m`,
/// row-major) with one `2·b·r` scratch reused across rows.
fn fused_rows(x: &Matrix, a: &BlastView<'_>, t0: usize, rows: usize, out: &mut [f32]) {
    let (p, q, b, r) = (a.p(), a.q(), a.b, a.r);
    let br = b * r;
    debug_assert_eq!(out.len(), rows * a.m);
    let mut z = vec![0.0f32; br];
    let mut w = vec![0.0f32; br];
    for tt in 0..rows {
        let xrow = x.row(t0 + tt);

        // Stage 1 (batched): z[j·r ..] += x_{j·q+c} · V_j[c, :].
        z.fill(0.0);
        for j in 0..b {
            let zj = &mut z[j * r..(j + 1) * r];
            let vj = a.v[j];
            let xj = &xrow[j * q..(j + 1) * q];
            for (c, &xv) in xj.iter().enumerate() {
                if xv == 0.0 {
                    continue;
                }
                let vrow = vj.row(c);
                // Contiguous axpy of width r — auto-vectorizes.
                for k in 0..r {
                    zj[k] += xv * vrow[k];
                }
            }
        }

        // Stage 2: w[i·r ..] = Σ_j s_{i,j} ⊙ z_j.
        w.fill(0.0);
        for i in 0..b {
            let wi = &mut w[i * r..(i + 1) * r];
            for j in 0..b {
                let s = a.s_row(i, j);
                let zj = &z[j * r..(j + 1) * r];
                for k in 0..r {
                    wi[k] += s[k] * zj[k];
                }
            }
        }

        // Stage 3 (batched): y[i·p + c] = U_i[c, :] · w_i.
        let yrow = &mut out[tt * a.m..(tt + 1) * a.m];
        for i in 0..b {
            let ui = a.u[i];
            let wi = &w[i * r..(i + 1) * r];
            let yi = &mut yrow[i * p..(i + 1) * p];
            for (c, ycell) in yi.iter_mut().enumerate() {
                let urow = ui.row(c);
                let mut acc = 0.0f32;
                for k in 0..r {
                    acc += urow[k] * wi[k];
                }
                *ycell = acc;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::blast::BlastMatrix;
    use crate::tensor::Rng;

    fn check(a: &BlastMatrix, x: &Matrix, kernel: &FusedBlastKernel) {
        let view = BlastView::from_matrix(a);
        let y = kernel.run(x, &KernelOp::Blast(view));
        let y_ref = crate::tensor::matmul_nt(x, &a.to_dense());
        assert!(
            y.sub(&y_ref).fro_norm() < 1e-3 * (1.0 + y_ref.fro_norm()),
            "fused mismatch (m={}, n={}, b={}, r={}, batch={}, par={})",
            a.m,
            a.n,
            a.b,
            a.r,
            x.rows,
            kernel.row_parallel,
        );
    }

    #[test]
    fn sequential_and_parallel_match_dense() {
        let mut rng = Rng::new(840);
        for &(m, n, b, r, batch) in &[
            (4, 4, 1, 2, 1),
            (8, 8, 2, 3, 1),
            (12, 6, 3, 2, 5),
            (16, 16, 4, 5, 8),
            (10, 15, 5, 4, 33),
        ] {
            let a = BlastMatrix::random_init(m, n, b, r, 1.0, &mut rng);
            let x = rng.gaussian_matrix(batch, n, 1.0);
            check(&a, &x, &FusedBlastKernel::sequential());
            check(&a, &x, &FusedBlastKernel::row_parallel());
        }
    }

    #[test]
    fn zero_couplings_give_exact_zero() {
        let mut rng = Rng::new(841);
        let mut a = BlastMatrix::random_init(8, 8, 2, 2, 1.0, &mut rng);
        for i in 0..2 {
            for j in 0..2 {
                a.s[i][j].fill(0.0);
            }
        }
        let x = rng.gaussian_matrix(3, 8, 1.0);
        let view = BlastView::from_matrix(&a);
        let y = FusedBlastKernel::sequential().run(&x, &KernelOp::Blast(view));
        assert!(y.data.iter().all(|&v| v == 0.0));
    }
}
