//! `StructPlan` — the structure-plan IR: every weight structure lowered
//! to a short sequence of packed-microkernel stages.
//!
//! The paper's core claim (§3, Table 1) is that one block-level
//! abstraction subsumes Low-Rank, Monarch, and Block-Diagonal weights.
//! This module is that claim realized at the execution layer: a
//! [`StructPlan`] lowers each structure into at most three stages over
//! four buffers (`Input`, two scratch buffers, `Output`):
//!
//! | structure | stages |
//! |---|---|
//! | Dense | `Gemm(Input→Output)` — one full-width row-packed panel |
//! | Low-Rank (`W = P Qᵀ`) | `Gemm(Input→S0)` over col-packed `Q`, `Gemm(S0→Output)` over row-packed `P` |
//! | Block-Diagonal | block-gathered `Gemm(Input→S0)` over col-packed `Q_i`, block-scattered `Gemm(S0→Output)` over row-packed `P_i` |
//! | Monarch | block-gathered `Gemm(Input→S0)` over row-packed `R_j`, *accumulating* `Gemm(S0→Output)` over row-packed `L_{i,j}` (ascending `j` within each `i`) |
//! | BLAST | block-gathered `Gemm(Input→S0)` over col-packed `V_j`, `Couple(S0→S1)` (the `s_{i,j}` scale-and-add), `Gemm(S1→Output)` over row-packed `U_i` |
//!
//! Every `Gemm` stage runs [`micro::nt_block_packed`] over
//! [`pack::PackCache`]d factor panels, so all five structures share one
//! tuned execution path — the same microkernel, the same packed-panel
//! cache, the same fixed-lane accumulation contract as the dense and
//! BLAST kernels of PR 4. [`execute_reference`] is the contract
//! spelled out per element with [`micro::dot8_with`] (gathered columns
//! for col-packed factors); the packed executor must reproduce it **bit
//! for bit** (`tests/kernel_parity.rs`).
//!
//! Plans are pure *structure*: they hold block offsets, widths, and
//! factor indices — never weight values — so a plan survives in-place
//! weight updates and is built **once per (structure, shape)**, cached
//! process-wide by [`PlanCache`] and per-layer by [`PlanCell`]
//! (`nn::linear` builds each layer's plan at model load via
//! `TinyLM::pretune`). Execution resolves the actual factor matrices
//! through a borrowed [`PlanOperands`] view (allocation-free to
//! construct, like the old `BlastView`), which is what keeps
//! `Linear::forward_into` allocation-free for **all** structures.

use super::micro::{self, SimdMode};
use super::pack::{self, PackedPanels, QuantPanels};
use super::{Couplings, Factors, KernelOp, MatmulKernel};
use crate::tensor::Matrix;
use crate::util::par;
use std::cell::RefCell;
use std::collections::HashMap;
use std::sync::{Arc, OnceLock, RwLock};

// ----------------------------------------------------------------------
// Plan signature (the autotuner-key half of a plan)
// ----------------------------------------------------------------------

/// Which structure a plan realizes.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum PlanKind {
    /// Dense `W (m×n)`, row-packed.
    Dense,
    /// Dense `A·B` form: the factor is `n×m` and col-packed (no
    /// transpose materialized) — the serial factorization paths use it.
    DenseT,
    /// `W = P Qᵀ` with rank `r`.
    LowRank,
    /// Monarch with `b` blocks and inner width `t`.
    Monarch,
    /// Block-diagonal with `b` blocks of rank `t`.
    BlockDiag,
    /// BLAST with `b` blocks and rank `r`.
    Blast,
}

/// Numeric precision of a plan's weight panels. Activations and
/// inter-stage scratch are always f32; `I8` means the *weights* are
/// int8-quantized at pack time and each stage dynamically quantizes its
/// f32 input rows (see `micro::quantize_row_i8`). `F32` is the default
/// and the accuracy reference: a quantized plan's results carry a
/// bounded-error guarantee (≤1e-2 relative per structure, tested)
/// against the same plan run in f32, not a bit guarantee.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub enum QuantMode {
    #[default]
    F32,
    I8,
}

impl QuantMode {
    /// CLI / manifest spelling (`"f32"` / `"int8"`).
    pub fn name(self) -> &'static str {
        match self {
            QuantMode::F32 => "f32",
            QuantMode::I8 => "int8",
        }
    }

    /// Inverse of [`name`]; also accepts `"i8"`. `None` on unknown
    /// spellings, so the CLI can reject rather than default.
    ///
    /// [`name`]: QuantMode::name
    pub fn parse(token: &str) -> Option<QuantMode> {
        match token {
            "f32" | "fp32" => Some(QuantMode::F32),
            "int8" | "i8" => Some(QuantMode::I8),
            _ => None,
        }
    }
}

/// Compact, allocation-free structure identity: the plan half of an
/// autotuner key, so Monarch/BlockDiag/LowRank shapes get their own
/// tuned kernel choice instead of hardcoded loops. `b` is blocks per
/// side (1 when the structure has no blocks), `r` the inner width
/// (rank `r`, Monarch/BlockDiag `t`; 0 for dense). `q` is the weight
/// precision — a first-class part of the identity, so the autotuner
/// races the f32 kernels against the int8 ones per quantized
/// (signature, shape, batch-bucket).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct PlanSig {
    pub kind: PlanKind,
    pub b: u32,
    pub r: u32,
    pub q: QuantMode,
}

impl PlanSig {
    /// This signature with int8 weight panels.
    pub fn quantized(self) -> PlanSig {
        PlanSig { q: QuantMode::I8, ..self }
    }

    /// Stable textual form used in the JSON plan file
    /// (`"plan:blast(b=8,r=32)"`, `"plan:blast(b=8,r=32,q=i8)"`,
    /// `"plan:dense"`, `"plan:dense(q=i8)"`, …). The `q=i8` suffix only
    /// appears for quantized plans, so every pre-quantization tag keeps
    /// its exact historical spelling.
    pub fn to_tag_string(self) -> String {
        let base = match self.kind {
            PlanKind::Dense => "plan:dense".to_string(),
            PlanKind::DenseT => "plan:dense_t".to_string(),
            PlanKind::LowRank => format!("plan:lowrank(r={})", self.r),
            PlanKind::Monarch => format!("plan:monarch(b={},t={})", self.b, self.r),
            PlanKind::BlockDiag => format!("plan:blockdiag(b={},t={})", self.b, self.r),
            PlanKind::Blast => format!("plan:blast(b={},r={})", self.b, self.r),
        };
        match self.q {
            QuantMode::F32 => base,
            QuantMode::I8 => match base.strip_suffix(')') {
                Some(open) => format!("{open},q=i8)"),
                None => format!("{base}(q=i8)"),
            },
        }
    }

    /// Inverse of [`to_tag_string`]; `None` on unknown tags.
    ///
    /// [`to_tag_string`]: PlanSig::to_tag_string
    pub fn parse(tag: &str) -> Option<Self> {
        let body = tag.strip_prefix("plan:")?;
        // Peel the quant suffix first, restoring the closing paren for
        // the parenthesized forms.
        let (body, q) = if let Some(core) = body.strip_suffix(",q=i8)") {
            (format!("{core})"), QuantMode::I8)
        } else if let Some(core) = body.strip_suffix("(q=i8)") {
            (core.to_string(), QuantMode::I8)
        } else {
            (body.to_string(), QuantMode::F32)
        };
        let body = body.as_str();
        let sig = if body == "dense" {
            Some(PlanSig { kind: PlanKind::Dense, b: 1, r: 0, q })
        } else if body == "dense_t" {
            Some(PlanSig { kind: PlanKind::DenseT, b: 1, r: 0, q })
        } else if let Some(inner) =
            body.strip_prefix("lowrank(r=").and_then(|s| s.strip_suffix(')'))
        {
            Some(PlanSig { kind: PlanKind::LowRank, b: 1, r: inner.parse().ok()?, q })
        } else {
            let two = |prefix: &str, kind: PlanKind, mid: &str| -> Option<PlanSig> {
                let inner = body.strip_prefix(prefix)?.strip_suffix(')')?;
                let (b, r) = inner.split_once(mid)?;
                Some(PlanSig { kind, b: b.parse().ok()?, r: r.parse().ok()?, q })
            };
            two("monarch(b=", PlanKind::Monarch, ",t=")
                .or_else(|| two("blockdiag(b=", PlanKind::BlockDiag, ",t="))
                .or_else(|| two("blast(b=", PlanKind::Blast, ",r="))
        };
        sig
    }
}

// ----------------------------------------------------------------------
// The IR
// ----------------------------------------------------------------------

/// One of the executor's four buffers.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BufRef {
    /// The activation batch `X (batch × n)`.
    Input,
    /// First inter-stage scratch (width [`StructPlan::s0`] per row).
    S0,
    /// Second inter-stage scratch (width [`StructPlan::s1`] per row).
    S1,
    /// The result `Y (batch × m)`.
    Output,
}

/// How a factor is packed into microkernel panels.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PackKind {
    /// Output `o` of the block is the factor's row `o` (`X · Fᵀ`).
    Rows,
    /// Output `o` is the factor's column `o` (`X · F`, gathered —
    /// no transpose is ever materialized).
    Cols,
}

/// One packed-microkernel product inside a [`PlanStage::Gemm`]:
/// `dst[:, dst_col..dst_col+n_out] (+)= src[:, src_col..src_col+k] ·
/// factorᵀ` (factor resolved through [`PlanOperands`]).
#[derive(Clone, Copy, Debug)]
pub struct GemmBlock {
    /// Operand group (0 or 1, see [`PlanOperands`]).
    pub group: u8,
    /// Factor index within the group.
    pub index: u32,
    /// Panel orientation.
    pub pack: PackKind,
    /// First source column of the block's input window.
    pub src_col: u32,
    /// Contraction width.
    pub k: u32,
    /// First destination column.
    pub dst_col: u32,
    /// Output width.
    pub n_out: u32,
}

/// One executable stage.
#[derive(Clone, Debug)]
pub enum PlanStage {
    /// A set of block-windowed packed products. With `accumulate`, the
    /// destination is zeroed once and the blocks add in declaration
    /// order (the Monarch ascending-`j` aggregation); without it the
    /// blocks must jointly cover the destination.
    Gemm { src: BufRef, dst: BufRef, accumulate: bool, blocks: Vec<GemmBlock> },
    /// BLAST stage 2: `dst[:, i·r..] = Σ_j s_{i,j} ⊙ src[:, j·r..]`,
    /// ascending `j` — the coupling scale-and-add.
    Couple { src: BufRef, dst: BufRef, b: u32, r: u32 },
}

/// A lowered structure: the full stage program plus scratch widths.
/// Pure structure — no weight values — so one plan serves every layer
/// of the same (structure, shape) and survives in-place weight updates.
#[derive(Clone, Debug)]
pub struct StructPlan {
    pub sig: PlanSig,
    /// Output features.
    pub m: usize,
    /// Input features.
    pub n: usize,
    /// Per-row width of scratch `S0` (0 = unused).
    pub s0: usize,
    /// Per-row width of scratch `S1` (0 = unused).
    pub s1: usize,
    pub stages: Vec<PlanStage>,
}

impl StructPlan {
    /// Dense `W (m×n)`: one full-width row-packed stage.
    pub fn dense(m: usize, n: usize) -> StructPlan {
        StructPlan {
            sig: PlanSig { kind: PlanKind::Dense, b: 1, r: 0, q: QuantMode::F32 },
            m,
            n,
            s0: 0,
            s1: 0,
            stages: vec![PlanStage::Gemm {
                src: BufRef::Input,
                dst: BufRef::Output,
                accumulate: false,
                blocks: vec![GemmBlock {
                    group: 0,
                    index: 0,
                    pack: PackKind::Rows,
                    src_col: 0,
                    k: n as u32,
                    dst_col: 0,
                    n_out: m as u32,
                }],
            }],
        }
    }

    /// Dense `A·B` form: the factor is an `n×m` matrix, col-packed, so
    /// `Y = X · F` without materializing `Fᵀ`.
    pub fn dense_t(m: usize, n: usize) -> StructPlan {
        let mut p = StructPlan::dense(m, n);
        p.sig = PlanSig { kind: PlanKind::DenseT, b: 1, r: 0, q: QuantMode::F32 };
        if let PlanStage::Gemm { blocks, .. } = &mut p.stages[0] {
            blocks[0].pack = PackKind::Cols;
        }
        p
    }

    /// Low-Rank `W = P Qᵀ` (`P: m×r`, `Q: n×r`): `S0 = X·Q` (cols),
    /// `Y = S0·Pᵀ` (rows). Group 0 is `Q`, group 1 is `P`.
    pub fn low_rank(m: usize, n: usize, r: usize) -> StructPlan {
        StructPlan {
            sig: PlanSig { kind: PlanKind::LowRank, b: 1, r: r as u32, q: QuantMode::F32 },
            m,
            n,
            s0: r,
            s1: 0,
            stages: vec![
                PlanStage::Gemm {
                    src: BufRef::Input,
                    dst: BufRef::S0,
                    accumulate: false,
                    blocks: vec![GemmBlock {
                        group: 0,
                        index: 0,
                        pack: PackKind::Cols,
                        src_col: 0,
                        k: n as u32,
                        dst_col: 0,
                        n_out: r as u32,
                    }],
                },
                PlanStage::Gemm {
                    src: BufRef::S0,
                    dst: BufRef::Output,
                    accumulate: false,
                    blocks: vec![GemmBlock {
                        group: 1,
                        index: 0,
                        pack: PackKind::Rows,
                        src_col: 0,
                        k: r as u32,
                        dst_col: 0,
                        n_out: m as u32,
                    }],
                },
            ],
        }
    }

    /// Block-diagonal (`P_i: p×t`, `Q_i: q×t` per diagonal block):
    /// block-gathered rank stage, block-scattered output stage. Group 0
    /// is the `Q_i` list, group 1 the `P_i` list.
    pub fn block_diag(m: usize, n: usize, b: usize, t: usize) -> StructPlan {
        assert!(b > 0 && m % b == 0 && n % b == 0, "block_diag plan: b={b} must divide {m}x{n}");
        let p = m / b;
        let q = n / b;
        let stage1 = (0..b)
            .map(|i| GemmBlock {
                group: 0,
                index: i as u32,
                pack: PackKind::Cols,
                src_col: (i * q) as u32,
                k: q as u32,
                dst_col: (i * t) as u32,
                n_out: t as u32,
            })
            .collect();
        let stage2 = (0..b)
            .map(|i| GemmBlock {
                group: 1,
                index: i as u32,
                pack: PackKind::Rows,
                src_col: (i * t) as u32,
                k: t as u32,
                dst_col: (i * p) as u32,
                n_out: p as u32,
            })
            .collect();
        StructPlan {
            sig: PlanSig { kind: PlanKind::BlockDiag, b: b as u32, r: t as u32, q: QuantMode::F32 },
            m,
            n,
            s0: b * t,
            s1: 0,
            stages: vec![
                PlanStage::Gemm {
                    src: BufRef::Input,
                    dst: BufRef::S0,
                    accumulate: false,
                    blocks: stage1,
                },
                PlanStage::Gemm {
                    src: BufRef::S0,
                    dst: BufRef::Output,
                    accumulate: false,
                    blocks: stage2,
                },
            ],
        }
    }

    /// Monarch (`R_j: t×q` shared right bases, `L_{i,j}: p×t` couplings
    /// stored row-major as `l[i·b+j]`): shared-basis stage, then an
    /// accumulating output stage ascending `j` within each block row
    /// `i`. Group 0 is the `R_j` list, group 1 the `L_{i,j}` list.
    pub fn monarch(m: usize, n: usize, b: usize, t: usize) -> StructPlan {
        assert!(b > 0 && m % b == 0 && n % b == 0, "monarch plan: b={b} must divide {m}x{n}");
        let p = m / b;
        let q = n / b;
        let stage1 = (0..b)
            .map(|j| GemmBlock {
                group: 0,
                index: j as u32,
                pack: PackKind::Rows,
                src_col: (j * q) as u32,
                k: q as u32,
                dst_col: (j * t) as u32,
                n_out: t as u32,
            })
            .collect();
        let mut stage2 = Vec::with_capacity(b * b);
        for i in 0..b {
            for j in 0..b {
                stage2.push(GemmBlock {
                    group: 1,
                    index: (i * b + j) as u32,
                    pack: PackKind::Rows,
                    src_col: (j * t) as u32,
                    k: t as u32,
                    dst_col: (i * p) as u32,
                    n_out: p as u32,
                });
            }
        }
        StructPlan {
            sig: PlanSig { kind: PlanKind::Monarch, b: b as u32, r: t as u32, q: QuantMode::F32 },
            m,
            n,
            s0: b * t,
            s1: 0,
            stages: vec![
                PlanStage::Gemm {
                    src: BufRef::Input,
                    dst: BufRef::S0,
                    accumulate: false,
                    blocks: stage1,
                },
                PlanStage::Gemm {
                    src: BufRef::S0,
                    dst: BufRef::Output,
                    accumulate: true,
                    blocks: stage2,
                },
            ],
        }
    }

    /// BLAST (`U_i: p×r`, `V_j: q×r`, couplings `s_{i,j}`): Algorithm 1
    /// as right-factor stage, coupling stage, left-factor stage. Group 0
    /// is the `V_j` list, group 1 the `U_i` list; the coupling table
    /// rides in [`PlanOperands::s`].
    pub fn blast(m: usize, n: usize, b: usize, r: usize) -> StructPlan {
        assert!(b > 0 && m % b == 0 && n % b == 0, "blast plan: b={b} must divide {m}x{n}");
        let p = m / b;
        let q = n / b;
        let stage1 = (0..b)
            .map(|j| GemmBlock {
                group: 0,
                index: j as u32,
                pack: PackKind::Cols,
                src_col: (j * q) as u32,
                k: q as u32,
                dst_col: (j * r) as u32,
                n_out: r as u32,
            })
            .collect();
        let stage3 = (0..b)
            .map(|i| GemmBlock {
                group: 1,
                index: i as u32,
                pack: PackKind::Rows,
                src_col: (i * r) as u32,
                k: r as u32,
                dst_col: (i * p) as u32,
                n_out: p as u32,
            })
            .collect();
        StructPlan {
            sig: PlanSig { kind: PlanKind::Blast, b: b as u32, r: r as u32, q: QuantMode::F32 },
            m,
            n,
            s0: b * r,
            s1: b * r,
            stages: vec![
                PlanStage::Gemm {
                    src: BufRef::Input,
                    dst: BufRef::S0,
                    accumulate: false,
                    blocks: stage1,
                },
                PlanStage::Couple {
                    src: BufRef::S0,
                    dst: BufRef::S1,
                    b: b as u32,
                    r: r as u32,
                },
                PlanStage::Gemm {
                    src: BufRef::S1,
                    dst: BufRef::Output,
                    accumulate: false,
                    blocks: stage3,
                },
            ],
        }
    }

    /// Rebuild a plan from its signature and shape (the [`PlanCache`]
    /// constructor — a signature plus `(m, n)` fully determines a plan).
    /// The quant mode rides on the signature only: the stage program is
    /// identical, and the executor picks f32 or int8 panels from it.
    pub fn build(sig: PlanSig, m: usize, n: usize) -> StructPlan {
        let mut p = match sig.kind {
            PlanKind::Dense => StructPlan::dense(m, n),
            PlanKind::DenseT => StructPlan::dense_t(m, n),
            PlanKind::LowRank => StructPlan::low_rank(m, n, sig.r as usize),
            PlanKind::Monarch => StructPlan::monarch(m, n, sig.b as usize, sig.r as usize),
            PlanKind::BlockDiag => StructPlan::block_diag(m, n, sig.b as usize, sig.r as usize),
            PlanKind::Blast => StructPlan::blast(m, n, sig.b as usize, sig.r as usize),
        };
        p.sig.q = sig.q;
        p
    }

    /// Total multiplies per activation row (the structure FLOPs the
    /// paper counts; benches use it for GFLOP/s).
    pub fn flops_per_row(&self) -> usize {
        self.stages
            .iter()
            .map(|st| match st {
                PlanStage::Gemm { blocks, .. } => {
                    blocks.iter().map(|b| (b.k * b.n_out) as usize).sum()
                }
                PlanStage::Couple { b, r, .. } => (*b as usize) * (*b as usize) * (*r as usize),
            })
            .sum()
    }
}

// ----------------------------------------------------------------------
// Operands
// ----------------------------------------------------------------------

/// Borrowed factor storage for one plan execution. Two factor groups
/// (each a [`Factors`] slice view over `Matrix` or `PTensor` storage)
/// plus the optional coupling table; construction never allocates —
/// this is built on every decode dispatch.
#[derive(Clone, Copy)]
pub struct PlanOperands<'a> {
    pub g0: Factors<'a>,
    pub g1: Factors<'a>,
    pub s: Option<Couplings<'a>>,
}

impl<'a> PlanOperands<'a> {
    /// Operands for a [`StructPlan::dense`] / [`StructPlan::dense_t`]
    /// plan over one plain matrix.
    pub fn single(w: &'a Matrix) -> Self {
        PlanOperands {
            g0: Factors::Mats(std::slice::from_ref(w)),
            g1: Factors::Mats(&[]),
            s: None,
        }
    }

    /// The factor behind a [`GemmBlock`].
    #[inline]
    pub fn factor(&self, group: u8, index: usize) -> &'a Matrix {
        match group {
            0 => self.g0.get(index),
            _ => self.g1.get(index),
        }
    }

    /// Coupling vector `s_{i,j}` (length `r`).
    #[inline]
    fn s_row(&self, i: usize, j: usize, b: usize) -> &'a [f32] {
        match self.s.expect("plan has a Couple stage but operands carry no couplings") {
            Couplings::Nested(s) => &s[i][j],
            Couplings::Packed(s) => s.row(i * b + j),
        }
    }

    /// Shape-check the operands against `plan` and the activation `x`.
    /// Called once per dispatch (the plan-op analogue of the old
    /// `BlastView::validate`).
    pub fn validate(&self, plan: &StructPlan, x: &Matrix) {
        assert_eq!(x.cols, plan.n, "plan input mismatch: x cols {} vs n {}", x.cols, plan.n);
        for stage in &plan.stages {
            match stage {
                PlanStage::Gemm { blocks, .. } => {
                    for blk in blocks {
                        let f = self.factor(blk.group, blk.index as usize);
                        let (rows, cols) = match blk.pack {
                            PackKind::Rows => (blk.n_out, blk.k),
                            PackKind::Cols => (blk.k, blk.n_out),
                        };
                        assert_eq!(
                            f.shape(),
                            (rows as usize, cols as usize),
                            "plan factor g{}[{}] shape mismatch",
                            blk.group,
                            blk.index
                        );
                    }
                }
                PlanStage::Couple { b, r, .. } => {
                    let (b, r) = (*b as usize, *r as usize);
                    match self.s.expect("Couple stage needs couplings") {
                        Couplings::Nested(s) => {
                            assert_eq!(s.len(), b, "plan coupling rows");
                            for row in s {
                                assert_eq!(row.len(), b, "plan coupling row width");
                            }
                        }
                        Couplings::Packed(s) => {
                            assert_eq!(s.rows, b * b, "plan coupling table size");
                            assert_eq!(s.cols, r, "plan coupling width");
                        }
                    }
                }
            }
        }
    }
}

// ----------------------------------------------------------------------
// Packed executor
// ----------------------------------------------------------------------

/// Per-thread plan-execution scratch: the two inter-stage buffers plus
/// the packed-panel handles for the call's factors, all reused across
/// calls (capacities persist, so a warm call never allocates; clearing
/// the panel vec only drops `Arc` refcounts).
#[derive(Default)]
struct PlanScratch {
    s0: Vec<f32>,
    s1: Vec<f32>,
    panels: Vec<Arc<PackedPanels>>,
}

thread_local! {
    static SCRATCH: RefCell<PlanScratch> = RefCell::new(PlanScratch::default());
}

/// Execute output rows `t0 .. t0+rows` of `plan` on the packed
/// microkernel path, writing into `out` (a chunk-local `rows × plan.m`
/// row-major slice). Inter-stage scratch is arena-owned per thread;
/// factor panels are fetched from the process-wide pack cache once per
/// call. Bit-identical to [`execute_reference`] by the fixed-lane
/// contract.
pub(crate) fn execute_packed(
    mode: SimdMode,
    x: &Matrix,
    plan: &StructPlan,
    ops: &PlanOperands<'_>,
    t0: usize,
    rows: usize,
    out: &mut [f32],
) {
    debug_assert_eq!(out.len(), rows * plan.m);
    SCRATCH.with(|cell| {
        let mut scratch = cell.borrow_mut();
        let PlanScratch { s0, s1, panels } = &mut *scratch;
        s0.clear();
        s0.resize(rows * plan.s0, 0.0);
        s1.clear();
        s1.resize(rows * plan.s1, 0.0);
        // Fetch every factor's packed panels once per call (one cache
        // lookup + fingerprint each), in stage-block order.
        let cache = pack::pack_cache();
        panels.clear();
        for stage in &plan.stages {
            if let PlanStage::Gemm { blocks, .. } = stage {
                for blk in blocks {
                    let f = ops.factor(blk.group, blk.index as usize);
                    panels.push(match blk.pack {
                        PackKind::Rows => cache.rows(f),
                        PackKind::Cols => cache.cols(f),
                    });
                }
            }
        }
        let mut pi = 0usize;
        for stage in &plan.stages {
            match stage {
                PlanStage::Gemm { src, dst, accumulate, blocks } => {
                    let stage_panels = &panels[pi..pi + blocks.len()];
                    pi += blocks.len();
                    // Resolve the (src, dst) buffer pair. Only the
                    // pairs the lowerings emit are supported; scratch
                    // reads are chunk-local (src_t0 = 0).
                    match (src, dst) {
                        (BufRef::Input, BufRef::Output) => gemm_stage(
                            mode, &x.data, x.cols, t0, out, plan.m, rows, *accumulate, blocks,
                            stage_panels,
                        ),
                        (BufRef::Input, BufRef::S0) => gemm_stage(
                            mode, &x.data, x.cols, t0, s0, plan.s0, rows, *accumulate, blocks,
                            stage_panels,
                        ),
                        (BufRef::S0, BufRef::Output) => gemm_stage(
                            mode, s0, plan.s0, 0, out, plan.m, rows, *accumulate, blocks,
                            stage_panels,
                        ),
                        (BufRef::S0, BufRef::S1) => gemm_stage(
                            mode, s0, plan.s0, 0, s1, plan.s1, rows, *accumulate, blocks,
                            stage_panels,
                        ),
                        (BufRef::S1, BufRef::Output) => gemm_stage(
                            mode, s1, plan.s1, 0, out, plan.m, rows, *accumulate, blocks,
                            stage_panels,
                        ),
                        _ => unreachable!("unsupported plan buffer pair {src:?} -> {dst:?}"),
                    }
                }
                PlanStage::Couple { src, dst, b, r } => match (src, dst) {
                    (BufRef::S0, BufRef::S1) => {
                        couple_stage(s0, plan.s0, s1, plan.s1, rows, *b as usize, *r as usize, ops)
                    }
                    _ => unreachable!("unsupported couple buffer pair {src:?} -> {dst:?}"),
                },
            }
        }
    });
}

/// One packed `Gemm` stage over `rows` chunk-local destination rows.
#[allow(clippy::too_many_arguments)]
fn gemm_stage(
    mode: SimdMode,
    src: &[f32],
    src_stride: usize,
    src_t0: usize,
    dst: &mut [f32],
    dst_stride: usize,
    rows: usize,
    accumulate: bool,
    blocks: &[GemmBlock],
    panels: &[Arc<PackedPanels>],
) {
    if accumulate {
        // Accumulating stages add into a zeroed destination (`Matrix::
        // reset` leaves buffer contents unspecified, and scratch carries
        // the previous call's values).
        dst[..rows * dst_stride].fill(0.0);
    }
    for (blk, p) in blocks.iter().zip(panels) {
        micro::nt_block_packed(
            mode,
            src,
            src_stride,
            src_t0,
            blk.src_col as usize,
            p,
            rows,
            dst,
            dst_stride,
            blk.dst_col as usize,
            accumulate,
        );
    }
}

/// The BLAST coupling stage over `rows` chunk-local rows: ascending-`j`
/// scale-and-add per block row `i`, exactly the fixed stage-2 recipe of
/// the reference executor.
#[allow(clippy::too_many_arguments)]
fn couple_stage(
    src: &[f32],
    src_stride: usize,
    dst: &mut [f32],
    dst_stride: usize,
    rows: usize,
    b: usize,
    r: usize,
    ops: &PlanOperands<'_>,
) {
    for t in 0..rows {
        let zrow = &src[t * src_stride..t * src_stride + b * r];
        let wrow = &mut dst[t * dst_stride..t * dst_stride + b * r];
        for i in 0..b {
            let wi = &mut wrow[i * r..(i + 1) * r];
            wi.fill(0.0);
            for j in 0..b {
                let s = ops.s_row(i, j, b);
                let zj = &zrow[j * r..(j + 1) * r];
                for k in 0..r {
                    wi[k] += s[k] * zj[k];
                }
            }
        }
    }
}

// ----------------------------------------------------------------------
// Quantized packed executor
// ----------------------------------------------------------------------

/// Per-thread scratch for the int8 executor: the f32 inter-stage
/// buffers (unchanged from the f32 path — quantization is stage-local),
/// one reusable int8 row buffer + per-row scale vector for the current
/// stage's dynamically quantized input, and the quant-panel handles.
/// Same reuse discipline as [`PlanScratch`]: capacities persist, so a
/// warm decode call never allocates.
#[derive(Default)]
struct QuantScratch {
    s0: Vec<f32>,
    s1: Vec<f32>,
    xq: Vec<i8>,
    xs: Vec<f32>,
    panels: Vec<Arc<QuantPanels>>,
}

thread_local! {
    static QSCRATCH: RefCell<QuantScratch> = RefCell::new(QuantScratch::default());
}

/// Int8 sibling of [`execute_packed`]: the same stage program with
/// [`micro::qnt_block_packed`] over [`pack::PackCache`] quant entries.
/// Each `Gemm` stage quantizes its f32 source rows (whole-row symmetric
/// scales, always scalar) into the reusable int8 buffer, runs the int8
/// microkernels, and writes f32 — so `Couple` stages and inter-stage
/// dataflow are untouched. Rows are quantized independently, which
/// makes the result invariant to row chunking: sequential and
/// row-parallel quantized execution are bit-identical (tested), while
/// accuracy versus the f32 plan is a bounded-error guarantee.
pub(crate) fn execute_packed_i8(
    mode: SimdMode,
    x: &Matrix,
    plan: &StructPlan,
    ops: &PlanOperands<'_>,
    t0: usize,
    rows: usize,
    out: &mut [f32],
) {
    debug_assert_eq!(out.len(), rows * plan.m);
    QSCRATCH.with(|cell| {
        let mut scratch = cell.borrow_mut();
        let QuantScratch { s0, s1, xq, xs, panels } = &mut *scratch;
        s0.clear();
        s0.resize(rows * plan.s0, 0.0);
        s1.clear();
        s1.resize(rows * plan.s1, 0.0);
        let cache = pack::pack_cache();
        panels.clear();
        for stage in &plan.stages {
            if let PlanStage::Gemm { blocks, .. } = stage {
                for blk in blocks {
                    let f = ops.factor(blk.group, blk.index as usize);
                    panels.push(match blk.pack {
                        PackKind::Rows => cache.rows_q(f),
                        PackKind::Cols => cache.cols_q(f),
                    });
                }
            }
        }
        let mut pi = 0usize;
        for stage in &plan.stages {
            match stage {
                PlanStage::Gemm { src, dst, accumulate, blocks } => {
                    let stage_panels = &panels[pi..pi + blocks.len()];
                    pi += blocks.len();
                    match (src, dst) {
                        (BufRef::Input, BufRef::Output) => gemm_stage_i8(
                            mode, &x.data, x.cols, t0, out, plan.m, rows, *accumulate, blocks,
                            stage_panels, xq, xs,
                        ),
                        (BufRef::Input, BufRef::S0) => gemm_stage_i8(
                            mode, &x.data, x.cols, t0, s0, plan.s0, rows, *accumulate, blocks,
                            stage_panels, xq, xs,
                        ),
                        (BufRef::S0, BufRef::Output) => gemm_stage_i8(
                            mode, s0, plan.s0, 0, out, plan.m, rows, *accumulate, blocks,
                            stage_panels, xq, xs,
                        ),
                        (BufRef::S0, BufRef::S1) => gemm_stage_i8(
                            mode, s0, plan.s0, 0, s1, plan.s1, rows, *accumulate, blocks,
                            stage_panels, xq, xs,
                        ),
                        (BufRef::S1, BufRef::Output) => gemm_stage_i8(
                            mode, s1, plan.s1, 0, out, plan.m, rows, *accumulate, blocks,
                            stage_panels, xq, xs,
                        ),
                        _ => unreachable!("unsupported plan buffer pair {src:?} -> {dst:?}"),
                    }
                }
                PlanStage::Couple { src, dst, b, r } => match (src, dst) {
                    (BufRef::S0, BufRef::S1) => {
                        couple_stage(s0, plan.s0, s1, plan.s1, rows, *b as usize, *r as usize, ops)
                    }
                    _ => unreachable!("unsupported couple buffer pair {src:?} -> {dst:?}"),
                },
            }
        }
    });
}

/// One int8 `Gemm` stage: dynamically quantize the stage's source rows,
/// then run every block's quantized microkernel product. Rows are laid
/// out at stride `src_stride + LANES` in the int8 buffer — the extra
/// zeroed chunk lets [`micro::qnt_block_packed`] read whole k-chunks
/// at any block window without a tail special case.
#[allow(clippy::too_many_arguments)]
fn gemm_stage_i8(
    mode: SimdMode,
    src: &[f32],
    src_stride: usize,
    src_t0: usize,
    dst: &mut [f32],
    dst_stride: usize,
    rows: usize,
    accumulate: bool,
    blocks: &[GemmBlock],
    panels: &[Arc<QuantPanels>],
    xq: &mut Vec<i8>,
    xs: &mut Vec<f32>,
) {
    let qstride = src_stride + micro::LANES;
    xq.clear();
    xq.resize(rows * qstride, 0);
    xs.clear();
    xs.resize(rows, 0.0);
    for t in 0..rows {
        let row = &src[(src_t0 + t) * src_stride..][..src_stride];
        xs[t] = micro::quantize_row_i8(row, &mut xq[t * qstride..(t + 1) * qstride]);
    }
    if accumulate {
        dst[..rows * dst_stride].fill(0.0);
    }
    for (blk, p) in blocks.iter().zip(panels) {
        micro::qnt_block_packed(
            mode,
            xq,
            xs,
            qstride,
            0,
            blk.src_col as usize,
            p,
            rows,
            dst,
            dst_stride,
            blk.dst_col as usize,
            accumulate,
        );
    }
}

// ----------------------------------------------------------------------
// Reference executor (the per-element contract)
// ----------------------------------------------------------------------

thread_local! {
    /// Per-thread (gathered-columns buffer, per-block gather offsets,
    /// s0, s1) scratch for the reference executor, so it stays
    /// allocation-free once warm — the autotuner may legitimately pick
    /// `naive` for a hot decode shape.
    static REF_SCRATCH: RefCell<(Vec<f32>, Vec<usize>, Vec<f32>, Vec<f32>)> =
        const { RefCell::new((Vec::new(), Vec::new(), Vec::new(), Vec::new())) };
}

/// Execute `plan` one row and one element at a time with
/// [`micro::dot8_with`]: the fixed-lane contract spelled out with no
/// packing, blocking, or threads. Col-packed factors are gathered into
/// row-major scratch **once per call** (not per row — the serial
/// factorization paths run multi-row batches through this executor),
/// which leaves the per-element bits identical to a per-row gather
/// since the gathered slice contents are the same. This is both the
/// parity reference (`NaiveKernel` runs it in `Portable` mode) and the
/// engine's never-thread-spawning, pack-cache-free serial path for
/// mutation-heavy factorization callers.
pub(crate) fn execute_reference(
    mode: SimdMode,
    x: &Matrix,
    plan: &StructPlan,
    ops: &PlanOperands<'_>,
    out: &mut [f32],
) {
    debug_assert_eq!(out.len(), x.rows * plan.m);
    REF_SCRATCH.with(|cell| {
        let mut scratch = cell.borrow_mut();
        let (gather, gofs, s0, s1) = &mut *scratch;
        s0.clear();
        s0.resize(plan.s0, 0.0);
        s1.clear();
        s1.resize(plan.s1, 0.0);
        // Gather every col-packed factor's columns once per call
        // (`gofs[block]` is the block's base offset into `gather`, or
        // `usize::MAX` for row-packed blocks, in stage-block order).
        gather.clear();
        gofs.clear();
        for stage in &plan.stages {
            if let PlanStage::Gemm { blocks, .. } = stage {
                for blk in blocks {
                    if blk.pack == PackKind::Cols {
                        let f = ops.factor(blk.group, blk.index as usize);
                        gofs.push(gather.len());
                        for o in 0..f.cols {
                            for c in 0..f.rows {
                                gather.push(f.at(c, o));
                            }
                        }
                    } else {
                        gofs.push(usize::MAX);
                    }
                }
            }
        }
        for t in 0..x.rows {
            let xrow = x.row(t);
            let orow_range = t * plan.m..(t + 1) * plan.m;
            let mut bi = 0usize;
            for stage in &plan.stages {
                match stage {
                    PlanStage::Gemm { src, dst, accumulate, blocks } => {
                        let stage_gofs = &gofs[bi..bi + blocks.len()];
                        bi += blocks.len();
                        match (src, dst) {
                            (BufRef::Input, BufRef::Output) => ref_gemm_row(
                                mode, xrow, &mut out[orow_range.clone()], *accumulate, blocks,
                                stage_gofs, gather, ops,
                            ),
                            (BufRef::Input, BufRef::S0) => ref_gemm_row(
                                mode, xrow, s0, *accumulate, blocks, stage_gofs, gather, ops,
                            ),
                            (BufRef::S0, BufRef::Output) => ref_gemm_row(
                                mode, s0, &mut out[orow_range.clone()], *accumulate, blocks,
                                stage_gofs, gather, ops,
                            ),
                            (BufRef::S0, BufRef::S1) => ref_gemm_row(
                                mode, s0, s1, *accumulate, blocks, stage_gofs, gather, ops,
                            ),
                            (BufRef::S1, BufRef::Output) => ref_gemm_row(
                                mode, s1, &mut out[orow_range.clone()], *accumulate, blocks,
                                stage_gofs, gather, ops,
                            ),
                            _ => unreachable!("unsupported plan buffer pair {src:?} -> {dst:?}"),
                        }
                    }
                    PlanStage::Couple { src, dst, b, r } => match (src, dst) {
                        (BufRef::S0, BufRef::S1) => {
                            couple_stage(s0, plan.s0, s1, plan.s1, 1, *b as usize, *r as usize, ops)
                        }
                        _ => unreachable!("unsupported couple buffer pair {src:?} -> {dst:?}"),
                    },
                }
            }
        }
    });
}

/// One reference `Gemm` stage over a single row. `gofs`/`gather` carry
/// the call-level column gathers (see [`execute_reference`]).
#[allow(clippy::too_many_arguments)]
fn ref_gemm_row(
    mode: SimdMode,
    src_row: &[f32],
    dst_row: &mut [f32],
    accumulate: bool,
    blocks: &[GemmBlock],
    gofs: &[usize],
    gather: &[f32],
    ops: &PlanOperands<'_>,
) {
    if accumulate {
        dst_row.fill(0.0);
    }
    for (blk, &gof) in blocks.iter().zip(gofs) {
        let k = blk.k as usize;
        let xs = &src_row[blk.src_col as usize..blk.src_col as usize + k];
        let f = ops.factor(blk.group, blk.index as usize);
        for o in 0..blk.n_out as usize {
            let val = match blk.pack {
                PackKind::Rows => micro::dot8_with(mode, xs, f.row(o)),
                PackKind::Cols => {
                    micro::dot8_with(mode, xs, &gather[gof + o * k..gof + (o + 1) * k])
                }
            };
            let slot = &mut dst_row[blk.dst_col as usize + o];
            if accumulate {
                *slot += val;
            } else {
                *slot = val;
            }
        }
    }
}

// ----------------------------------------------------------------------
// The registered plan kernels
// ----------------------------------------------------------------------

/// The packed structure-plan executor, registered with the engine in
/// sequential (`plan_seq`) and batch-row-parallel (`plan_par`)
/// variants. The sequential variant wins at decode shapes (batch 1)
/// where thread fan-out costs more than the product; the parallel
/// variant hands disjoint output-row chunks to `util::par` workers,
/// each running the full stage program over its rows with its own
/// thread-local scratch. The autotuner picks per (plan signature,
/// shape, batch-bucket); by the fixed-lane contract the choice never
/// changes a bit.
pub struct PlanKernel {
    row_parallel: bool,
    quant: bool,
}

impl PlanKernel {
    /// Single-threaded variant — the decode-path (batch 1) choice.
    pub fn sequential() -> Self {
        PlanKernel { row_parallel: false, quant: false }
    }

    /// Batch-row-parallel variant — the prefill/training-batch choice.
    pub fn row_parallel() -> Self {
        PlanKernel { row_parallel: true, quant: false }
    }

    /// Int8 single-threaded variant. Only supports `q=i8` plans; the
    /// f32 variants support those too (running them in full f32, which
    /// trivially satisfies the bounded-error guarantee), so the
    /// autotuner's f32-vs-int8 shoot-out is a genuine race.
    pub fn sequential_i8() -> Self {
        PlanKernel { row_parallel: false, quant: true }
    }

    /// Int8 batch-row-parallel variant.
    pub fn row_parallel_i8() -> Self {
        PlanKernel { row_parallel: true, quant: true }
    }
}

impl MatmulKernel for PlanKernel {
    fn name(&self) -> &'static str {
        match (self.quant, self.row_parallel) {
            (false, false) => "plan_seq",
            (false, true) => "plan_par",
            (true, false) => "plan_seq_i8",
            (true, true) => "plan_par_i8",
        }
    }

    fn supports(&self, op: &KernelOp<'_>, _batch: usize) -> bool {
        match op {
            // Int8 kernels require int8 panels; f32 kernels run any
            // plan (a q=i8 plan in f32 is the accuracy reference).
            KernelOp::Plan { plan, .. } => !self.quant || plan.sig.q == QuantMode::I8,
            _ => false,
        }
    }

    fn run(&self, x: &Matrix, op: &KernelOp<'_>) -> Matrix {
        let KernelOp::Plan { plan, ops } = op else {
            unreachable!("PlanKernel only supports Plan ops (checked via supports)")
        };
        let mut y = Matrix::zeros(x.rows, plan.m);
        self.run_into_buf(x, plan, ops, &mut y.data);
        y
    }

    fn run_into(&self, x: &Matrix, op: &KernelOp<'_>, out: &mut Matrix) {
        let KernelOp::Plan { plan, ops } = op else {
            unreachable!("PlanKernel only supports Plan ops (checked via supports)")
        };
        out.reset(x.rows, plan.m);
        self.run_into_buf(x, plan, ops, &mut out.data);
    }
}

impl PlanKernel {
    fn run_into_buf(&self, x: &Matrix, plan: &StructPlan, ops: &PlanOperands<'_>, out: &mut [f32]) {
        let batch = x.rows;
        if batch == 0 {
            return;
        }
        // Executor profile: count every call; time (and accumulate the
        // executed FLOPs of) one call in every `BLAST_PROF_SAMPLE` so
        // the snapshot can derive GFLOP/s per plan signature. The
        // profile entry is interned on the first call per signature
        // (model warmup), so on the steady-state decode path this is a
        // read-locked hash probe plus relaxed counter ops — no
        // allocation, honoring the zero-alloc decode contract.
        let prof = crate::obs::plan_profile(plan.sig);
        prof.calls.inc();
        let every = crate::obs::prof_sample_every();
        let t0 = (every > 0 && prof.calls.get() % every == 0).then(std::time::Instant::now);
        crate::obs::trace::all_enter(self.name(), 0);
        let mode = micro::simd_mode();
        let exec = if self.quant { execute_packed_i8 } else { execute_packed };
        if self.row_parallel && batch > 1 {
            let chunk_rows = batch.div_ceil(par::num_threads()).max(1);
            par::par_chunks_mut(out, chunk_rows * plan.m, |ci, chunk| {
                let rows = chunk.len() / plan.m;
                exec(mode, x, plan, ops, ci * chunk_rows, rows, chunk);
            });
        } else {
            exec(mode, x, plan, ops, 0, batch, out);
        }
        crate::obs::trace::all_exit(self.name(), 0);
        if let Some(t0) = t0 {
            prof.sampled.inc();
            prof.wall_ns.add(t0.elapsed().as_nanos().min(u128::from(u64::MAX)) as u64);
            prof.flops.add((plan.flops_per_row() * batch) as u64);
        }
    }
}

// ----------------------------------------------------------------------
// Process-wide plan cache + per-layer plan cell
// ----------------------------------------------------------------------

/// Process-wide structural plan cache keyed on `(signature, m, n)`. A
/// signature plus shape fully determines a plan, and plans hold no
/// weight values, so entries never invalidate — unlike the pack cache,
/// no fingerprint is needed.
pub struct PlanCache {
    plans: RwLock<HashMap<(PlanSig, usize, usize), Arc<StructPlan>>>,
}

impl PlanCache {
    pub fn new() -> Self {
        PlanCache { plans: RwLock::new(HashMap::new()) }
    }

    /// The plan for `(sig, m, n)`, built on first request.
    pub fn get(&self, sig: PlanSig, m: usize, n: usize) -> Arc<StructPlan> {
        {
            let plans = self.plans.read().unwrap();
            if let Some(p) = plans.get(&(sig, m, n)) {
                return Arc::clone(p);
            }
        }
        let built = Arc::new(StructPlan::build(sig, m, n));
        let mut plans = self.plans.write().unwrap();
        Arc::clone(plans.entry((sig, m, n)).or_insert(built))
    }

    /// Cached [`StructPlan::dense`] (the serial factorization paths'
    /// `X·Wᵀ` form).
    pub fn dense(&self, m: usize, n: usize) -> Arc<StructPlan> {
        self.get(PlanSig { kind: PlanKind::Dense, b: 1, r: 0, q: QuantMode::F32 }, m, n)
    }

    /// Cached [`StructPlan::dense_t`] (the `A·B` form).
    pub fn dense_t(&self, m: usize, n: usize) -> Arc<StructPlan> {
        self.get(PlanSig { kind: PlanKind::DenseT, b: 1, r: 0, q: QuantMode::F32 }, m, n)
    }

    /// Number of cached plans (diagnostics / tests).
    pub fn len(&self) -> usize {
        self.plans.read().unwrap().len()
    }

    /// True when no plans are cached.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

impl Default for PlanCache {
    fn default() -> Self {
        Self::new()
    }
}

/// The process-wide [`PlanCache`].
pub fn plan_cache() -> &'static PlanCache {
    static CACHE: OnceLock<PlanCache> = OnceLock::new();
    CACHE.get_or_init(PlanCache::new)
}

/// A layer-held plan slot: built once (at model load via
/// `TinyLM::pretune`, or lazily on first dispatch) and shared through
/// the process-wide cache. Lives directly on `nn::linear::Linear`, so a
/// steady-state `forward_into` resolves its plan with one atomic load —
/// no lock, no hash, no allocation.
#[derive(Clone, Debug, Default)]
pub struct PlanCell(OnceLock<Arc<StructPlan>>);

impl PlanCell {
    pub fn new() -> Self {
        PlanCell(OnceLock::new())
    }

    /// The cached plan, building it through [`plan_cache`] on first use.
    pub fn get_or_build(&self, sig: PlanSig, m: usize, n: usize) -> &Arc<StructPlan> {
        self.0.get_or_init(|| plan_cache().get(sig, m, n))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Rng;

    fn ref_vs_packed(plan: &StructPlan, ops: &PlanOperands<'_>, x: &Matrix) {
        ops.validate(plan, x);
        let mut reference = vec![0.0f32; x.rows * plan.m];
        execute_reference(SimdMode::Portable, x, plan, ops, &mut reference);
        let mut packed = vec![0.0f32; x.rows * plan.m];
        execute_packed(SimdMode::Portable, x, plan, ops, 0, x.rows, &mut packed);
        for (i, (a, b)) in packed.iter().zip(&reference).enumerate() {
            assert_eq!(
                a.to_bits(),
                b.to_bits(),
                "{:?} elem {i}: packed {a} vs reference {b}",
                plan.sig
            );
        }
    }

    #[test]
    fn sig_tag_round_trip() {
        for sig in [
            PlanSig { kind: PlanKind::Dense, b: 1, r: 0, q: QuantMode::F32 },
            PlanSig { kind: PlanKind::DenseT, b: 1, r: 0, q: QuantMode::F32 },
            PlanSig { kind: PlanKind::LowRank, b: 1, r: 7, q: QuantMode::F32 },
            PlanSig { kind: PlanKind::Monarch, b: 4, r: 2, q: QuantMode::F32 },
            PlanSig { kind: PlanKind::BlockDiag, b: 2, r: 3, q: QuantMode::F32 },
            PlanSig { kind: PlanKind::Blast, b: 8, r: 32, q: QuantMode::F32 },
        ] {
            assert_eq!(PlanSig::parse(&sig.to_tag_string()), Some(sig));
            // And the int8 flavor of each.
            let qsig = sig.quantized();
            assert_eq!(PlanSig::parse(&qsig.to_tag_string()), Some(qsig));
        }
        // Quantized tags append q=i8 inside (or create) the parens.
        assert_eq!(
            PlanSig { kind: PlanKind::Blast, b: 8, r: 32, q: QuantMode::I8 }.to_tag_string(),
            "plan:blast(b=8,r=32,q=i8)"
        );
        assert_eq!(
            PlanSig { kind: PlanKind::Dense, b: 1, r: 0, q: QuantMode::I8 }.to_tag_string(),
            "plan:dense(q=i8)"
        );
        assert!(PlanSig::parse("plan:dense(q=i4)").is_none());
        assert!(PlanSig::parse("dense").is_none(), "bare dense is the raw-op tag");
        assert!(PlanSig::parse("plan:nope(b=1)").is_none());
    }

    #[test]
    fn dense_plan_matches_tensor_matmul_nt() {
        let mut rng = Rng::new(900);
        for &(batch, m, n) in &[(1usize, 3usize, 9usize), (4, 16, 33), (2, 1, 1)] {
            let w = rng.gaussian_matrix(m, n, 1.0);
            let x = rng.gaussian_matrix(batch, n, 1.0);
            let plan = StructPlan::dense(m, n);
            let ops = PlanOperands::single(&w);
            ref_vs_packed(&plan, &ops, &x);
            let mut y = vec![0.0f32; batch * m];
            execute_packed(SimdMode::Portable, &x, &plan, &ops, 0, batch, &mut y);
            let y_ref = crate::tensor::matmul_nt(&x, &w);
            for (a, b) in y.iter().zip(&y_ref.data) {
                assert!((a - b).abs() < 1e-3 * (1.0 + b.abs()));
            }
        }
    }

    #[test]
    fn dense_t_plan_matches_tensor_matmul() {
        let mut rng = Rng::new(901);
        let a = rng.gaussian_matrix(5, 12, 1.0);
        let b = rng.gaussian_matrix(12, 9, 1.0);
        let plan = StructPlan::dense_t(9, 12);
        let ops = PlanOperands::single(&b);
        ref_vs_packed(&plan, &ops, &a);
        let mut y = vec![0.0f32; 5 * 9];
        execute_reference(SimdMode::Portable, &a, &plan, &ops, &mut y);
        let y_ref = crate::tensor::matmul(&a, &b);
        for (got, want) in y.iter().zip(&y_ref.data) {
            assert!((got - want).abs() < 1e-3 * (1.0 + want.abs()));
        }
    }

    #[test]
    fn low_rank_plan_matches_dense_reconstruction() {
        let mut rng = Rng::new(902);
        for &(batch, m, n, r) in &[(1usize, 6usize, 8usize, 3usize), (5, 9, 17, 2)] {
            let p = rng.gaussian_matrix(m, r, 1.0);
            let q = rng.gaussian_matrix(n, r, 1.0);
            let x = rng.gaussian_matrix(batch, n, 1.0);
            let plan = StructPlan::low_rank(m, n, r);
            let ops = PlanOperands {
                g0: Factors::Mats(std::slice::from_ref(&q)),
                g1: Factors::Mats(std::slice::from_ref(&p)),
                s: None,
            };
            ref_vs_packed(&plan, &ops, &x);
            let mut y = vec![0.0f32; batch * m];
            execute_packed(SimdMode::Portable, &x, &plan, &ops, 0, batch, &mut y);
            let y_ref = crate::tensor::matmul_nt(&x, &crate::tensor::matmul_nt(&p, &q));
            for (got, want) in y.iter().zip(&y_ref.data) {
                assert!((got - want).abs() < 1e-3 * (1.0 + want.abs()), "m={m} n={n} r={r}");
            }
        }
    }

    #[test]
    fn monarch_plan_matches_block_reconstruction() {
        let mut rng = Rng::new(903);
        for &(batch, b, p, q, t) in &[(1usize, 2usize, 3usize, 4usize, 2usize), (3, 3, 2, 3, 2)] {
            let (m, n) = (b * p, b * q);
            let rb: Vec<Matrix> = (0..b).map(|_| rng.gaussian_matrix(t, q, 1.0)).collect();
            let l: Vec<Matrix> = (0..b * b).map(|_| rng.gaussian_matrix(p, t, 1.0)).collect();
            let x = rng.gaussian_matrix(batch, n, 1.0);
            let plan = StructPlan::monarch(m, n, b, t);
            let ops = PlanOperands { g0: Factors::Mats(&rb), g1: Factors::Mats(&l), s: None };
            ref_vs_packed(&plan, &ops, &x);
            // Dense reconstruction: block (i,j) = L_{i,j} · R_j.
            let mut w = Matrix::zeros(m, n);
            for i in 0..b {
                for j in 0..b {
                    let blk = crate::tensor::matmul(&l[i * b + j], &rb[j]);
                    w.set_submatrix(i * p, j * q, &blk);
                }
            }
            let mut y = vec![0.0f32; batch * m];
            execute_packed(SimdMode::Portable, &x, &plan, &ops, 0, batch, &mut y);
            let y_ref = crate::tensor::matmul_nt(&x, &w);
            for (got, want) in y.iter().zip(&y_ref.data) {
                assert!((got - want).abs() < 1e-3 * (1.0 + want.abs()), "b={b} p={p} t={t}");
            }
        }
    }

    #[test]
    fn block_diag_plan_matches_block_reconstruction() {
        let mut rng = Rng::new(904);
        for &(batch, b, p, q, t) in &[(1usize, 1usize, 4usize, 3usize, 2usize), (4, 3, 2, 2, 1)] {
            let (m, n) = (b * p, b * q);
            let pd: Vec<Matrix> = (0..b).map(|_| rng.gaussian_matrix(p, t, 1.0)).collect();
            let qd: Vec<Matrix> = (0..b).map(|_| rng.gaussian_matrix(q, t, 1.0)).collect();
            let x = rng.gaussian_matrix(batch, n, 1.0);
            let plan = StructPlan::block_diag(m, n, b, t);
            let ops = PlanOperands { g0: Factors::Mats(&qd), g1: Factors::Mats(&pd), s: None };
            ref_vs_packed(&plan, &ops, &x);
            let mut w = Matrix::zeros(m, n);
            for i in 0..b {
                let blk = crate::tensor::matmul_nt(&pd[i], &qd[i]);
                w.set_submatrix(i * p, i * q, &blk);
            }
            let mut y = vec![0.0f32; batch * m];
            execute_packed(SimdMode::Portable, &x, &plan, &ops, 0, batch, &mut y);
            let y_ref = crate::tensor::matmul_nt(&x, &w);
            for (got, want) in y.iter().zip(&y_ref.data) {
                assert!((got - want).abs() < 1e-3 * (1.0 + want.abs()), "b={b} p={p} t={t}");
            }
        }
    }

    #[test]
    fn blast_plan_matches_dense_reconstruction() {
        let mut rng = Rng::new(905);
        let a = crate::blast::BlastMatrix::random_init(12, 18, 3, 4, 1.0, &mut rng);
        let x = rng.gaussian_matrix(5, 18, 1.0);
        let plan = StructPlan::blast(12, 18, 3, 4);
        let ops = PlanOperands {
            g0: Factors::Mats(&a.v),
            g1: Factors::Mats(&a.u),
            s: Some(Couplings::Nested(&a.s)),
        };
        ref_vs_packed(&plan, &ops, &x);
        let mut y = vec![0.0f32; 5 * 12];
        execute_packed(SimdMode::Portable, &x, &plan, &ops, 0, 5, &mut y);
        let y_ref = crate::tensor::matmul_nt(&x, &a.to_dense());
        for (got, want) in y.iter().zip(&y_ref.data) {
            assert!((got - want).abs() < 1e-3 * (1.0 + want.abs()));
        }
    }

    #[test]
    fn plan_cache_dedupes_and_cell_reuses() {
        let cache = PlanCache::new();
        let sig = PlanSig { kind: PlanKind::Blast, b: 2, r: 4, q: QuantMode::F32 };
        let p1 = cache.get(sig, 8, 8);
        let p2 = cache.get(sig, 8, 8);
        assert!(Arc::ptr_eq(&p1, &p2));
        assert_eq!(cache.len(), 1);
        let p3 = cache.get(sig, 16, 8);
        assert!(!Arc::ptr_eq(&p1, &p3));

        let cell = PlanCell::new();
        let a = Arc::clone(cell.get_or_build(sig, 8, 8));
        let b = Arc::clone(cell.get_or_build(sig, 8, 8));
        assert!(Arc::ptr_eq(&a, &b));
    }

    /// Uniform [-1, 1) entries: bounded max/rms ratio keeps the int8
    /// round-off comfortably inside the tested 1e-2 relative bound
    /// (gaussian tails push per-row scales, and with them the error,
    /// right up against it).
    fn uniform_matrix(rng: &mut Rng, rows: usize, cols: usize) -> Matrix {
        let data = (0..rows * cols).map(|_| rng.uniform_range(-1.0, 1.0)).collect();
        Matrix::from_vec(rows, cols, data)
    }

    fn frobenius_rel_err(got: &[f32], want: &[f32]) -> f32 {
        let err: f32 = got.iter().zip(want).map(|(a, b)| (a - b) * (a - b)).sum();
        let den: f32 = want.iter().map(|v| v * v).sum();
        (err / den.max(f32::MIN_POSITIVE)).sqrt()
    }

    #[test]
    fn quantized_blast_plan_bounded_error_and_chunk_invariant() {
        let mut rng = Rng::new(910);
        let (batch, m, n, b, r) = (5usize, 12usize, 18usize, 3usize, 4usize);
        let u: Vec<Matrix> = (0..b).map(|_| uniform_matrix(&mut rng, m / b, r)).collect();
        let v: Vec<Matrix> = (0..b).map(|_| uniform_matrix(&mut rng, n / b, r)).collect();
        let s: Vec<Vec<Vec<f32>>> = (0..b)
            .map(|_| (0..b).map(|_| (0..r).map(|_| rng.uniform_range(-1.0, 1.0)).collect()).collect())
            .collect();
        let x = uniform_matrix(&mut rng, batch, n);
        let plan = StructPlan::build(
            PlanSig { kind: PlanKind::Blast, b: b as u32, r: r as u32, q: QuantMode::I8 },
            m,
            n,
        );
        assert_eq!(plan.sig.q, QuantMode::I8, "build must propagate the quant mode");
        let ops = PlanOperands {
            g0: Factors::Mats(&v),
            g1: Factors::Mats(&u),
            s: Some(Couplings::Nested(&s)),
        };
        let mut f32_out = vec![0.0f32; batch * m];
        execute_packed(SimdMode::Portable, &x, &plan, &ops, 0, batch, &mut f32_out);
        let mut i8_out = vec![0.0f32; batch * m];
        execute_packed_i8(SimdMode::Portable, &x, &plan, &ops, 0, batch, &mut i8_out);
        let rel = frobenius_rel_err(&i8_out, &f32_out);
        assert!(rel <= 1e-2, "blast int8 vs f32 relative error {rel} > 1e-2");
        assert!(rel > 0.0, "int8 path suspiciously exact — is it running f32?");
        // Per-row quantization makes the result invariant to row
        // chunking: split execution is bit-identical to one call, which
        // is what makes plan_seq_i8 and plan_par_i8 interchangeable.
        let mut chunked = vec![0.0f32; batch * m];
        execute_packed_i8(SimdMode::Portable, &x, &plan, &ops, 0, 2, &mut chunked[..2 * m]);
        execute_packed_i8(SimdMode::Portable, &x, &plan, &ops, 2, 3, &mut chunked[2 * m..]);
        for (i, (a, c)) in i8_out.iter().zip(&chunked).enumerate() {
            assert_eq!(a.to_bits(), c.to_bits(), "elem {i}: whole {a} vs chunked {c}");
        }
    }

    #[test]
    fn quantized_low_rank_plan_bounded_error() {
        let mut rng = Rng::new(911);
        let (batch, m, n, r) = (3usize, 9usize, 17usize, 4usize);
        let p = uniform_matrix(&mut rng, m, r);
        let q = uniform_matrix(&mut rng, n, r);
        let x = uniform_matrix(&mut rng, batch, n);
        let plan = StructPlan::build(
            PlanSig { kind: PlanKind::LowRank, b: 1, r: r as u32, q: QuantMode::I8 },
            m,
            n,
        );
        let ops = PlanOperands {
            g0: Factors::Mats(std::slice::from_ref(&q)),
            g1: Factors::Mats(std::slice::from_ref(&p)),
            s: None,
        };
        let mut f32_out = vec![0.0f32; batch * m];
        execute_packed(SimdMode::Portable, &x, &plan, &ops, 0, batch, &mut f32_out);
        let mut i8_out = vec![0.0f32; batch * m];
        execute_packed_i8(SimdMode::Portable, &x, &plan, &ops, 0, batch, &mut i8_out);
        let rel = frobenius_rel_err(&i8_out, &f32_out);
        assert!(rel <= 1e-2, "lowrank int8 vs f32 relative error {rel} > 1e-2");
    }

    #[test]
    fn plan_kernel_i8_variants_support_only_quant_plans() {
        let f32_plan = StructPlan::dense(4, 8);
        let i8_plan =
            StructPlan::build(StructPlan::dense(4, 8).sig.quantized(), 4, 8);
        let w = Matrix::zeros(4, 8);
        let ops = PlanOperands::single(&w);
        let f32_op = KernelOp::Plan { plan: &f32_plan, ops };
        let i8_op = KernelOp::Plan { plan: &i8_plan, ops };
        for (k, f32_ok) in [
            (PlanKernel::sequential(), true),
            (PlanKernel::row_parallel(), true),
            (PlanKernel::sequential_i8(), false),
            (PlanKernel::row_parallel_i8(), false),
        ] {
            assert_eq!(k.supports(&f32_op, 1), f32_ok, "{} on f32 plan", k.name());
            assert!(k.supports(&i8_op, 1), "{} must support i8 plans", k.name());
        }
        assert_eq!(PlanKernel::sequential_i8().name(), "plan_seq_i8");
        assert_eq!(PlanKernel::row_parallel_i8().name(), "plan_par_i8");
    }

    #[test]
    fn flops_per_row_formulas() {
        // BLAST: (m + n + b²)·r — the paper's Algorithm-1 count.
        let p = StructPlan::blast(64, 64, 4, 8);
        assert_eq!(p.flops_per_row(), (64 + 64 + 16) * 8);
        // Monarch: n·t + m·b·t.
        let p = StructPlan::monarch(64, 64, 4, 8);
        assert_eq!(p.flops_per_row(), 64 * 8 + 64 * 4 * 8);
        // Dense: m·n.
        assert_eq!(StructPlan::dense(16, 24).flops_per_row(), 16 * 24);
    }
}
