//! B-panel packing for the SIMD microkernel, with a process-wide cache.
//!
//! The microkernel streams its weight operand from a **packed panel**:
//! for each tile of `NR` output rows, the rows' 8-wide k-chunks are
//! interleaved (`chunk 0` of rows `j0..j0+NR`, then `chunk 1`, …) so the
//! inner loop reads one forward-moving contiguous stream instead of `NR`
//! strided row cursors. Short tiles and ragged k-tails are zero-padded
//! to full `NR × LANES` groups — the zeros fall out of the fixed-lane
//! contract's padded-tail semantics, so padding never changes a bit.
//!
//! Weights are packed **once per (weights, shape)** and cached in the
//! process-wide [`PackCache`]: the key is the weight buffer's address +
//! shape, validated on every hit by a content fingerprint — full FNV
//! for buffers of ≤ [`FULL_HASH_LIMIT`] elements, head/tail/center/
//! strided sampling above that (see [`fingerprint`]'s docs for the
//! exact detection contract and its deliberate blind spot for surgical
//! single-element edits of large weights). Serving-path weights are
//! immutable after load; the fingerprint is a safety net for
//! whole-tensor in-place updates (optimizer steps, factor sweeps),
//! which always touch sampled elements.
//!
//! Alongside the f32 [`PackedPanels`], [`QuantPanels`] holds the same
//! panel layout quantized to **int8 with per-panel-row symmetric
//! scales** (one f32 scale per packed output row, stored grouped by
//! `NR`-row panel so the microkernel's dequant reads are as local as
//! its value reads). Quantized panels are first-class cache entries:
//! same key space (plus a quant bit), same fingerprint validation, and
//! their scale bytes count toward the LRU byte budget.
//!
//! The cache is **byte-bounded**: packed panels are evicted in
//! least-recently-used order whenever the total packed bytes exceed the
//! capacity (`BLAST_PACK_CACHE_MB`, default
//! [`DEFAULT_PACK_CACHE_MB`] MiB), so long-lived serving processes
//! holding many models cannot grow packed panels without limit. Hit
//! recency is a relaxed atomic tick (no lock, no allocation on the hot
//! path); eviction runs only on insert. An evicted weight simply
//! repacks on next use — eviction can never change results, only
//! re-pay the O(n·k) pack (asserted by the in-module eviction-parity
//! test).

use super::micro::{LANES, NR};
use crate::obs::{Counter, Gauge};
use crate::tensor::Matrix;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, OnceLock, RwLock};

/// Default [`PackCache`] capacity in MiB when `BLAST_PACK_CACHE_MB` is
/// unset.
pub const DEFAULT_PACK_CACHE_MB: usize = 512;

/// A weight matrix repacked into microkernel panels.
pub struct PackedPanels {
    /// Output rows represented (un-padded).
    pub n: usize,
    /// Shared (contraction) dimension (un-padded).
    pub k: usize,
    /// Number of 8-wide k-chunks (`k.div_ceil(LANES)`).
    pub kc: usize,
    /// Panel data: `tiles × kc × NR × LANES`, fully zero-padded.
    pub data: Vec<f32>,
}

impl PackedPanels {
    /// Number of `NR`-row tiles.
    #[inline]
    pub fn tiles(&self) -> usize {
        self.n.div_ceil(NR)
    }

    /// The packed panel for one tile (`kc · NR · LANES` floats).
    #[inline]
    pub fn panel(&self, tile: usize) -> &[f32] {
        let stride = self.kc * NR * LANES;
        &self.data[tile * stride..(tile + 1) * stride]
    }

    fn empty(n: usize, k: usize) -> PackedPanels {
        let kc = k.div_ceil(LANES);
        let tiles = n.div_ceil(NR);
        PackedPanels { n, k, kc, data: vec![0.0; tiles * kc * NR * LANES] }
    }

    /// Pack the rows of `w` (for `Y = X · Wᵀ`: output `o` is `w.row(o)`).
    pub fn pack_rows(w: &Matrix) -> PackedPanels {
        let mut p = PackedPanels::empty(w.rows, w.cols);
        for o in 0..w.rows {
            p.write_row(o, w.row(o));
        }
        p
    }

    /// Pack the **columns** of `v` (for `z = Vᵀ x`: output `o` is
    /// `v.col(o)`, gathered without materializing the transpose).
    pub fn pack_cols(v: &Matrix) -> PackedPanels {
        let mut p = PackedPanels::empty(v.cols, v.rows);
        let stride = p.kc * NR * LANES;
        for o in 0..v.cols {
            let tile = o / NR;
            let jj = o % NR;
            let base = tile * stride + jj * LANES;
            for c in 0..v.rows {
                p.data[base + (c / LANES) * NR * LANES + (c % LANES)] = v.at(c, o);
            }
        }
        p
    }

    fn write_row(&mut self, o: usize, row: &[f32]) {
        let stride = self.kc * NR * LANES;
        let tile = o / NR;
        let jj = o % NR;
        let base = tile * stride + jj * LANES;
        for (c, &v) in row.iter().enumerate() {
            self.data[base + (c / LANES) * NR * LANES + (c % LANES)] = v;
        }
    }

    /// Recover packed row `o` (tests / diagnostics).
    pub fn unpack_row(&self, o: usize) -> Vec<f32> {
        let stride = self.kc * NR * LANES;
        let tile = o / NR;
        let jj = o % NR;
        let base = tile * stride + jj * LANES;
        (0..self.k).map(|c| self.data[base + (c / LANES) * NR * LANES + (c % LANES)]).collect()
    }
}

/// A weight matrix repacked into int8 microkernel panels with
/// per-packed-row symmetric scales.
///
/// Same tile/k-chunk interleaving as [`PackedPanels`] (element
/// `(row o, k-index c)` lives at
/// `tile·stride + (c/LANES)·NR·LANES + (o%NR)·LANES + c%LANES`, with
/// `stride = kc·NR·LANES`), values quantized as
/// `q = round(v / s)` clamped to `[-127, 127]` with
/// `s = max|row| / 127` — the value `-128` is never produced, which is
/// what keeps the AVX2 `maddubs` pair-sums exact (see
/// `micro`'s int8 contract). Scales are stored panel-grouped:
/// `scales[tile·NR + jj]` is the scale of packed row `tile·NR + jj`;
/// padding rows carry scale `0.0` (their quantized values are all
/// zero, so they can never contribute).
pub struct QuantPanels {
    /// Output rows represented (un-padded).
    pub n: usize,
    /// Shared (contraction) dimension (un-padded).
    pub k: usize,
    /// Number of 8-wide k-chunks (`k.div_ceil(LANES)`).
    pub kc: usize,
    /// Panel data: `tiles × kc × NR × LANES` int8, fully zero-padded.
    pub data: Vec<i8>,
    /// Per-packed-row dequant scales, `tiles × NR` entries.
    pub scales: Vec<f32>,
}

impl QuantPanels {
    /// Number of `NR`-row tiles.
    #[inline]
    pub fn tiles(&self) -> usize {
        self.n.div_ceil(NR)
    }

    /// The packed panel for one tile (`kc · NR · LANES` int8 values).
    #[inline]
    pub fn panel(&self, tile: usize) -> &[i8] {
        let stride = self.kc * NR * LANES;
        &self.data[tile * stride..(tile + 1) * stride]
    }

    /// The `NR` dequant scales for one tile's packed rows.
    #[inline]
    pub fn tile_scales(&self, tile: usize) -> &[f32] {
        &self.scales[tile * NR..(tile + 1) * NR]
    }

    /// Resident bytes (values + scales) — what the cache budget counts.
    pub fn bytes(&self) -> usize {
        self.data.len() + self.scales.len() * std::mem::size_of::<f32>()
    }

    fn empty(n: usize, k: usize) -> QuantPanels {
        let kc = k.div_ceil(LANES);
        let tiles = n.div_ceil(NR);
        QuantPanels {
            n,
            k,
            kc,
            data: vec![0; tiles * kc * NR * LANES],
            scales: vec![0.0; tiles * NR],
        }
    }

    /// Quantize-and-pack the rows of `w` (for `Y = X · Wᵀ`).
    pub fn pack_rows(w: &Matrix) -> QuantPanels {
        let mut p = QuantPanels::empty(w.rows, w.cols);
        for o in 0..w.rows {
            p.write_row(o, w.row(o).iter().copied());
        }
        p
    }

    /// Quantize-and-pack the **columns** of `v` (for `z = Vᵀ x`,
    /// gathered without materializing the transpose).
    pub fn pack_cols(v: &Matrix) -> QuantPanels {
        let mut p = QuantPanels::empty(v.cols, v.rows);
        for o in 0..v.cols {
            p.write_row(o, (0..v.rows).map(|c| v.at(c, o)));
        }
        p
    }

    fn write_row(&mut self, o: usize, row: impl Iterator<Item = f32> + Clone) {
        let max_abs = row.clone().fold(0.0f32, |m, v| m.max(v.abs()));
        if max_abs == 0.0 || !max_abs.is_finite() {
            // All-zero (or non-finite) row: scale 0.0, values stay 0.
            return;
        }
        let scale = max_abs / 127.0;
        self.scales[o] = scale;
        let inv = 127.0 / max_abs;
        let stride = self.kc * NR * LANES;
        let tile = o / NR;
        let jj = o % NR;
        let base = tile * stride + jj * LANES;
        for (c, v) in row.enumerate() {
            let q = (v * inv).round().clamp(-127.0, 127.0) as i8;
            self.data[base + (c / LANES) * NR * LANES + (c % LANES)] = q;
        }
    }

    /// Dequantized packed row `o` (tests / diagnostics).
    pub fn unpack_row(&self, o: usize) -> Vec<f32> {
        let stride = self.kc * NR * LANES;
        let tile = o / NR;
        let jj = o % NR;
        let base = tile * stride + jj * LANES;
        let s = self.scales[o];
        (0..self.k)
            .map(|c| {
                self.data[base + (c / LANES) * NR * LANES + (c % LANES)] as f32 * s
            })
            .collect()
    }
}

/// Cache key: buffer identity + shape + pack orientation + precision.
#[derive(Clone, Copy, PartialEq, Eq, Hash)]
struct PackKey {
    ptr: usize,
    rows: usize,
    cols: usize,
    cols_packed: bool,
    quant: bool,
}

/// Either precision's panels — one map holds both, so f32 and int8
/// entries share the byte budget and the LRU order.
#[derive(Clone)]
enum PanelData {
    F32(Arc<PackedPanels>),
    I8(Arc<QuantPanels>),
}

struct PackEntry {
    fingerprint: u64,
    panels: PanelData,
    /// Packed bytes this entry holds (panel data, plus scale bytes for
    /// quantized entries).
    bytes: usize,
    /// Recency tick of the last hit (relaxed: approximate order is
    /// enough for eviction, and the hot path must stay lock-free).
    last_used: AtomicU64,
}

/// The map plus its running byte total (kept together so both are
/// guarded by one lock).
#[derive(Default)]
struct PackInner {
    map: HashMap<PackKey, PackEntry>,
    bytes: usize,
}

/// Per-cache observability counters (all relaxed atomics; the hit-path
/// increment adds one `fetch_add` inside the read-critical section and
/// nothing else). Surfaced process-wide through
/// `obs::MetricsSnapshot::collect`, which reads [`pack_cache()`]'s
/// instance.
#[derive(Debug, Default)]
pub struct PackStats {
    /// Lookups served from cache (fingerprint validated).
    pub hits: Counter,
    /// Lookups that packed fresh panels (cold, evicted, or mutated).
    pub misses: Counter,
    /// Entries removed by the LRU byte-budget sweep.
    pub evictions: Counter,
    /// Hits rejected because the content fingerprint changed (in-place
    /// weight mutation detected); each also counts as a miss.
    pub fingerprint_mismatches: Counter,
    /// High-water mark of resident packed bytes.
    pub bytes_high_water: Gauge,
}

/// Process-wide packed-weight cache (see the module docs).
pub struct PackCache {
    entries: RwLock<PackInner>,
    capacity_bytes: usize,
    tick: AtomicU64,
    stats: PackStats,
}

impl PackCache {
    /// Cache bounded by `BLAST_PACK_CACHE_MB` (default
    /// [`DEFAULT_PACK_CACHE_MB`]).
    pub fn new() -> Self {
        let mb = crate::util::config::EngineConfig::global()
            .pack_cache_mb
            .filter(|&mb| mb > 0)
            .unwrap_or(DEFAULT_PACK_CACHE_MB);
        Self::with_capacity_bytes(mb.saturating_mul(1024 * 1024))
    }

    /// Cache with an explicit byte budget (tests exercise eviction with
    /// tiny budgets; production uses [`new`]).
    ///
    /// [`new`]: PackCache::new
    pub fn with_capacity_bytes(capacity_bytes: usize) -> Self {
        PackCache {
            entries: RwLock::new(PackInner::default()),
            capacity_bytes: capacity_bytes.max(1),
            tick: AtomicU64::new(1),
            stats: PackStats::default(),
        }
    }

    /// This cache's observability counters.
    pub fn stats(&self) -> &PackStats {
        &self.stats
    }

    /// Packed rows of `w`, from cache when the fingerprint still matches.
    pub fn rows(&self, w: &Matrix) -> Arc<PackedPanels> {
        match self.get(w, false, false) {
            PanelData::F32(p) => p,
            PanelData::I8(_) => unreachable!("f32 lookup returned quant panels"),
        }
    }

    /// Packed columns of `v`, from cache when the fingerprint matches.
    pub fn cols(&self, v: &Matrix) -> Arc<PackedPanels> {
        match self.get(v, true, false) {
            PanelData::F32(p) => p,
            PanelData::I8(_) => unreachable!("f32 lookup returned quant panels"),
        }
    }

    /// Int8-quantized packed rows of `w` (distinct cache entry from the
    /// f32 packing of the same buffer).
    pub fn rows_q(&self, w: &Matrix) -> Arc<QuantPanels> {
        match self.get(w, false, true) {
            PanelData::I8(p) => p,
            PanelData::F32(_) => unreachable!("quant lookup returned f32 panels"),
        }
    }

    /// Int8-quantized packed columns of `v`.
    pub fn cols_q(&self, v: &Matrix) -> Arc<QuantPanels> {
        match self.get(v, true, true) {
            PanelData::I8(p) => p,
            PanelData::F32(_) => unreachable!("quant lookup returned f32 panels"),
        }
    }

    fn get(&self, w: &Matrix, cols_packed: bool, quant: bool) -> PanelData {
        let key = PackKey {
            ptr: w.data.as_ptr() as usize,
            rows: w.rows,
            cols: w.cols,
            cols_packed,
            quant,
        };
        let fp = fingerprint(&w.data);
        {
            let inner = self.entries.read().unwrap();
            if let Some(e) = inner.map.get(&key) {
                if e.fingerprint == fp {
                    e.last_used.store(self.tick.fetch_add(1, Ordering::Relaxed), Ordering::Relaxed);
                    self.stats.hits.inc();
                    return e.panels.clone();
                }
                self.stats.fingerprint_mismatches.inc();
            }
        }
        self.stats.misses.inc();
        let (panels, bytes) = if quant {
            let p = Arc::new(if cols_packed {
                QuantPanels::pack_cols(w)
            } else {
                QuantPanels::pack_rows(w)
            });
            let bytes = p.bytes();
            (PanelData::I8(p), bytes)
        } else {
            let p = Arc::new(if cols_packed {
                PackedPanels::pack_cols(w)
            } else {
                PackedPanels::pack_rows(w)
            });
            let bytes = p.data.len() * std::mem::size_of::<f32>();
            (PanelData::F32(p), bytes)
        };
        let mut inner = self.entries.write().unwrap();
        if let Some(old) = inner.map.remove(&key) {
            // Stale entry for a mutated weight: replace, reclaim bytes.
            inner.bytes -= old.bytes;
        }
        inner.map.insert(
            key,
            PackEntry {
                fingerprint: fp,
                panels: panels.clone(),
                bytes,
                last_used: AtomicU64::new(self.tick.fetch_add(1, Ordering::Relaxed)),
            },
        );
        inner.bytes += bytes;
        self.stats.bytes_high_water.set_max(inner.bytes as u64);
        // LRU eviction down to the byte budget. The entry just inserted
        // carries the freshest tick, so it survives unless it alone
        // exceeds the budget — in which case it is still returned to the
        // caller (Arc-owned) and simply not retained.
        while inner.bytes > self.capacity_bytes && !inner.map.is_empty() {
            let Some(victim) = inner
                .map
                .iter()
                .min_by_key(|(_, e)| e.last_used.load(Ordering::Relaxed))
                .map(|(k, _)| *k)
            else {
                break;
            };
            if let Some(e) = inner.map.remove(&victim) {
                inner.bytes -= e.bytes;
                self.stats.evictions.inc();
            }
        }
        panels
    }

    /// Number of cached weights (diagnostics / tests).
    pub fn len(&self) -> usize {
        self.entries.read().unwrap().map.len()
    }

    /// True when no weights are cached.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Total packed bytes currently held (diagnostics / tests).
    pub fn bytes(&self) -> usize {
        self.entries.read().unwrap().bytes
    }

    /// The configured byte budget.
    pub fn capacity_bytes(&self) -> usize {
        self.capacity_bytes
    }
}

impl Default for PackCache {
    fn default() -> Self {
        Self::new()
    }
}

/// The process-wide pack cache.
pub fn pack_cache() -> &'static PackCache {
    static CACHE: OnceLock<PackCache> = OnceLock::new();
    CACHE.get_or_init(PackCache::new)
}

/// Buffers up to this many elements are fully hashed by
/// [`fingerprint`]; larger ones are sampled. Every weight in the test
/// models (and any factor matrix up to 32×32) sits below it, so
/// single-element mutations of those are always detected.
pub const FULL_HASH_LIMIT: usize = 1024;

/// FNV-1a content fingerprint, run on every cache lookup.
///
/// Buffers of ≤ [`FULL_HASH_LIMIT`] elements are hashed in full —
/// **any** in-place mutation invalidates. Larger buffers hash the
/// first 64, the last 64, a contiguous 64-element window straddling
/// the buffer midpoint, and 128 evenly strided interior elements. The
/// strided sample has a fixed phase (it starts at index 64), so before
/// the center window was added a localized mid-buffer edit — one
/// rewritten block of a block-structured factor, say — could land
/// entirely between strides; the dense center window closes the most
/// likely such gap. Whole-tensor updates (optimizer steps, factor
/// sweeps, checkpoint loads) always touch sampled elements and are
/// detected, but a surgical edit of a single unsampled element of a
/// large cached weight still would not be. That trade keeps hit
/// validation O(1) at serving sizes; serving-path weights are
/// immutable after load, and the mutation-heavy paths (factorization,
/// attention scores) use the unpacked kernels, which never consult
/// this cache. Code that does fine-grained in-place edits of large
/// weights must route them through a fresh buffer (or the allocating
/// `Matrix` ops) rather than relying on sampled detection. Quantized
/// panel entries make silent stale reuse costlier — one stale scale
/// corrupts a whole panel — which is why the sample got denser rather
/// than sparser.
fn fingerprint(data: &[f32]) -> u64 {
    const FNV_OFFSET: u64 = 0xcbf29ce484222325;
    const FNV_PRIME: u64 = 0x100000001b3;
    let mut h = FNV_OFFSET ^ (data.len() as u64);
    // Word-wise FNV (one xor+mul per f32, not per byte): this runs on
    // every cache hit, so validation must stay a small fraction of the
    // product it guards even for batch-1 dispatches on small weights.
    let mut eat = |v: f32| {
        h ^= u64::from(v.to_bits());
        h = h.wrapping_mul(FNV_PRIME);
    };
    let n = data.len();
    if n <= FULL_HASH_LIMIT {
        for &v in data {
            eat(v);
        }
        return h;
    }
    for &v in &data[..64] {
        eat(v);
    }
    for &v in &data[n - 64..] {
        eat(v);
    }
    // Deterministic center window: 64 contiguous elements straddling
    // the midpoint (clamped inside the interior, so it never re-reads
    // the head/tail samples). n > FULL_HASH_LIMIT here, so the
    // interior is always at least 896 elements wide.
    let mid = (n / 2).saturating_sub(32).clamp(64, n - 128);
    for &v in &data[mid..mid + 64] {
        eat(v);
    }
    let stride = (n - 128).max(1) / 128 + 1;
    let mut i = 64;
    while i < n - 64 {
        eat(data[i]);
        i += stride;
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Rng;

    #[test]
    fn pack_rows_round_trip() {
        let mut rng = Rng::new(870);
        for &(n, k) in &[(1usize, 1usize), (3, 8), (4, 9), (5, 17), (13, 31)] {
            let w = rng.gaussian_matrix(n, k, 1.0);
            let p = PackedPanels::pack_rows(&w);
            assert_eq!(p.n, n);
            assert_eq!(p.k, k);
            for o in 0..n {
                assert_eq!(p.unpack_row(o), w.row(o), "n={n} k={k} row {o}");
            }
        }
    }

    #[test]
    fn pack_cols_round_trip() {
        let mut rng = Rng::new(871);
        for &(rows, cols) in &[(1usize, 1usize), (8, 3), (9, 4), (17, 5), (31, 13)] {
            let v = rng.gaussian_matrix(rows, cols, 1.0);
            let p = PackedPanels::pack_cols(&v);
            assert_eq!(p.n, cols);
            assert_eq!(p.k, rows);
            for o in 0..cols {
                assert_eq!(p.unpack_row(o), v.col(o), "rows={rows} cols={cols} col {o}");
            }
        }
    }

    #[test]
    fn padding_regions_are_zero() {
        let mut rng = Rng::new(872);
        let w = rng.gaussian_matrix(5, 9, 1.0); // ragged tile AND ragged k
        let p = PackedPanels::pack_rows(&w);
        // Tile 1 holds row 4 plus three padding rows; chunk 1 holds one
        // real k element plus seven padding lanes per row.
        let stride = p.kc * NR * LANES;
        for tile in 0..p.tiles() {
            for jj in 0..NR {
                let o = tile * NR + jj;
                for c in 0..p.kc * LANES {
                    let v = p.data[tile * stride + (c / LANES) * NR * LANES + jj * LANES + (c % LANES)];
                    if o >= p.n || c >= p.k {
                        assert_eq!(v, 0.0, "padding at tile={tile} jj={jj} c={c}");
                    }
                }
            }
        }
    }

    #[test]
    fn lru_eviction_stays_within_budget_and_preserves_parity() {
        // Budget fits roughly two packed 8x8 weights (panel data for an
        // 8x8: tiles(8)=2, kc(8)=1 -> 2*1*4*8 = 64 floats = 256 bytes).
        let cache = PackCache::with_capacity_bytes(600);
        let mut rng = Rng::new(875);
        let ws: Vec<crate::tensor::Matrix> =
            (0..5).map(|_| rng.gaussian_matrix(8, 8, 1.0)).collect();
        for w in &ws {
            let p = cache.rows(w);
            assert_eq!(p.unpack_row(0), w.row(0));
        }
        assert!(cache.bytes() <= cache.capacity_bytes(), "eviction must keep the byte budget");
        assert!(cache.len() < ws.len(), "five weights cannot all fit in a two-weight budget");
        // An evicted weight repacks correctly on demand (parity after
        // eviction), and the repack re-enters the cache.
        let p0 = cache.rows(&ws[0]);
        for o in 0..8 {
            assert_eq!(p0.unpack_row(o), ws[0].row(o), "evicted weight must repack identically");
        }
        assert!(cache.bytes() <= cache.capacity_bytes());
    }

    #[test]
    fn lru_evicts_least_recently_used_first() {
        let cache = PackCache::with_capacity_bytes(600);
        let mut rng = Rng::new(876);
        let a = rng.gaussian_matrix(8, 8, 1.0);
        let b = rng.gaussian_matrix(8, 8, 1.0);
        let pa = cache.rows(&a);
        let _pb = cache.rows(&b);
        // Touch `a` so `b` is the LRU entry, then insert a third weight
        // to force an eviction.
        let pa2 = cache.rows(&a);
        assert!(Arc::ptr_eq(&pa, &pa2));
        let c = rng.gaussian_matrix(8, 8, 1.0);
        let _pc = cache.rows(&c);
        // `a` must have survived (recently used): the next lookup is a
        // hit on the same Arc.
        let pa3 = cache.rows(&a);
        assert!(Arc::ptr_eq(&pa, &pa3), "recently-used entry must survive eviction");
        assert!(cache.bytes() <= cache.capacity_bytes());
    }

    #[test]
    fn stats_counters_match_eviction_parity_scenario() {
        // Mirror of `lru_eviction_stays_within_budget_and_preserves_parity`
        // on a fresh instance, asserting the observability counters:
        // a two-weight budget fed five 8x8 weights (256 packed bytes
        // each) — five cold misses, three evictions, no hits.
        let cache = PackCache::with_capacity_bytes(600);
        let mut rng = Rng::new(878);
        let ws: Vec<crate::tensor::Matrix> =
            (0..5).map(|_| rng.gaussian_matrix(8, 8, 1.0)).collect();
        for w in &ws {
            let _ = cache.rows(w);
        }
        let s = cache.stats();
        assert_eq!(s.misses.get(), 5, "five cold inserts");
        assert_eq!(s.hits.get(), 0);
        assert_eq!(s.fingerprint_mismatches.get(), 0);
        // Every insert beyond the two that fit evicted exactly one LRU
        // entry; in general evictions == inserts − resident entries.
        assert_eq!(s.evictions.get(), 5 - cache.len() as u64);
        // High-water saw the transient over-budget state right after an
        // insert, before the LRU sweep brought it back down.
        assert!(s.bytes_high_water.get() as usize > cache.capacity_bytes());
        assert!(cache.bytes() <= cache.capacity_bytes());

        // The most recent weight survived: a hit, no new packing.
        let _ = cache.rows(&ws[4]);
        assert_eq!(cache.stats().hits.get(), 1);
        assert_eq!(cache.stats().misses.get(), 5);

        // An evicted weight repacks: a miss (plus one more eviction to
        // make room), never a fingerprint mismatch.
        let _ = cache.rows(&ws[0]);
        let s = cache.stats();
        assert_eq!(s.misses.get(), 6);
        assert_eq!(s.evictions.get(), 6 - cache.len() as u64);
        assert_eq!(s.fingerprint_mismatches.get(), 0);

        // In-place mutation: detected as a mismatch AND counted a miss.
        let mut w = rng.gaussian_matrix(8, 8, 1.0);
        let _ = cache.rows(&w);
        w.set(0, 0, w.at(0, 0) + 1.0);
        let _ = cache.rows(&w);
        let s = cache.stats();
        assert_eq!(s.fingerprint_mismatches.get(), 1);
        assert_eq!(s.misses.get(), 8, "mutation repack counts as a miss");
    }

    #[test]
    fn oversized_entry_is_returned_but_not_retained() {
        let cache = PackCache::with_capacity_bytes(64);
        let mut rng = Rng::new(877);
        let w = rng.gaussian_matrix(16, 16, 1.0);
        let p = cache.rows(&w);
        assert_eq!(p.unpack_row(3), w.row(3), "caller still gets usable panels");
        assert!(cache.bytes() <= cache.capacity_bytes());
    }

    #[test]
    fn cache_hits_and_invalidates_on_mutation() {
        let cache = PackCache::new();
        let mut rng = Rng::new(873);
        let mut w = rng.gaussian_matrix(6, 10, 1.0);
        let p1 = cache.rows(&w);
        let p2 = cache.rows(&w);
        assert!(Arc::ptr_eq(&p1, &p2), "second lookup must hit the cache");
        assert_eq!(cache.len(), 1);

        // In-place weight mutation (the matrix is small enough that the
        // fingerprint hashes every element): the stale panel must not be
        // served.
        w.set(3, 7, w.at(3, 7) + 1.0);
        let p3 = cache.rows(&w);
        assert!(!Arc::ptr_eq(&p1, &p3), "mutated weight must repack");
        assert_eq!(p3.unpack_row(3), w.row(3));

        // Row-pack and col-pack of the same buffer are distinct entries.
        let pc = cache.cols(&w);
        assert_eq!(pc.n, w.cols);
        assert!(cache.len() >= 2);
    }

    #[test]
    fn fingerprint_sensitive_to_strided_interior_sample() {
        // Large buffer: only sampled elements are hashed; mutating a
        // sampled interior element must change the fingerprint.
        let mut data = vec![0.5f32; 10_000];
        let f0 = fingerprint(&data);
        data[0] = 1.0; // head sample
        let f1 = fingerprint(&data);
        assert_ne!(f0, f1);
        data[9_999] = 2.0; // tail sample
        let f2 = fingerprint(&data);
        assert_ne!(f1, f2);
        let mut other = vec![0.5f32; 10_001]; // length folds into the hash
        other[0] = 1.0;
        other[10_000] = 2.0;
        assert_ne!(fingerprint(&other), f2);
    }

    #[test]
    fn fingerprint_center_window_catches_phase_missed_edit() {
        // n = 10_000 -> stride = (10_000-128)/128 + 1 = 78, strided
        // samples at 64 + 78k. Index 4979 sits inside the center window
        // [4968, 5032) but is not 64 + 78k for any k (4978 is the
        // nearest strided sample) — before the center window, this edit
        // was invisible to the sampled fingerprint.
        let mut data = vec![0.5f32; 10_000];
        let f0 = fingerprint(&data);
        data[4979] = 9.0;
        assert_ne!(fingerprint(&data), f0, "center-window sample must see the edit");
    }

    #[test]
    fn quant_pack_rows_round_trip_within_scale_step() {
        let mut rng = Rng::new(880);
        for &(n, k) in &[(1usize, 1usize), (3, 8), (4, 9), (5, 17), (13, 31)] {
            let w = rng.gaussian_matrix(n, k, 1.0);
            let p = QuantPanels::pack_rows(&w);
            assert_eq!(p.n, n);
            assert_eq!(p.k, k);
            assert_eq!(p.scales.len(), p.tiles() * NR);
            for o in 0..n {
                let deq = p.unpack_row(o);
                let max_abs = w.row(o).iter().fold(0.0f32, |m, v| m.max(v.abs()));
                // Symmetric round-to-nearest: error per element is at
                // most half a quantization step.
                let tol = max_abs / 127.0 * 0.5 + 1e-7;
                for (c, (&got, &want)) in deq.iter().zip(w.row(o)).enumerate() {
                    assert!(
                        (got - want).abs() <= tol,
                        "n={n} k={k} row {o} col {c}: {got} vs {want} (tol {tol})"
                    );
                }
            }
        }
    }

    #[test]
    fn quant_pack_cols_matches_transposed_pack_rows() {
        let mut rng = Rng::new(881);
        let v = rng.gaussian_matrix(17, 5, 1.0);
        let pc = QuantPanels::pack_cols(&v);
        let pr = QuantPanels::pack_rows(&v.transpose());
        assert_eq!(pc.data, pr.data, "col-pack must equal row-pack of the transpose");
        assert_eq!(pc.scales, pr.scales);
    }

    #[test]
    fn quant_padding_rows_have_zero_scale_and_values() {
        let mut rng = Rng::new(882);
        let w = rng.gaussian_matrix(5, 9, 1.0); // ragged tile AND ragged k
        let p = QuantPanels::pack_rows(&w);
        let stride = p.kc * NR * LANES;
        for tile in 0..p.tiles() {
            for jj in 0..NR {
                let o = tile * NR + jj;
                if o >= p.n {
                    assert_eq!(p.scales[o], 0.0, "padding row {o} must have zero scale");
                }
                for c in 0..p.kc * LANES {
                    let v = p.data[tile * stride + (c / LANES) * NR * LANES + jj * LANES + (c % LANES)];
                    if o >= p.n || c >= p.k {
                        assert_eq!(v, 0, "padding at tile={tile} jj={jj} c={c}");
                    }
                }
            }
        }
    }

    #[test]
    fn quant_entries_are_distinct_and_budgeted() {
        let cache = PackCache::with_capacity_bytes(1 << 20);
        let mut rng = Rng::new(883);
        let mut w = rng.gaussian_matrix(8, 8, 1.0);
        let pf = cache.rows(&w);
        let pq = cache.rows_q(&w);
        assert_eq!(cache.len(), 2, "f32 and int8 packings are separate entries");
        // Scale bytes are part of the resident accounting: the quant
        // entry is values (64 i8) + scales (8 f32) = 96 bytes, the f32
        // entry 64 f32 = 256 bytes.
        assert_eq!(cache.bytes(), pq.bytes() + pf.data.len() * 4);
        // Both hit on re-lookup.
        assert!(Arc::ptr_eq(&pq, &cache.rows_q(&w)));
        assert_eq!(cache.stats().hits.get(), 1);
        // In-place mutation invalidates the quant entry too.
        w.set(2, 3, w.at(2, 3) + 1.0);
        let pq2 = cache.rows_q(&w);
        assert!(!Arc::ptr_eq(&pq, &pq2), "mutated weight must requantize");
        assert_eq!(cache.stats().fingerprint_mismatches.get(), 1);
        // Col-quant is yet another entry (the repack above replaced the
        // stale row-quant entry under its existing key).
        let _ = cache.cols_q(&w);
        assert_eq!(cache.len(), 3);
    }
}
