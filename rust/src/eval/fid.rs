//! Fréchet-distance metrics for the diffusion experiment (Table 2).
//!
//! The paper reports FID/sFID (Fréchet distance between Inception
//! features of generated vs. reference images) and IS. Our substitution
//! (DESIGN.md §3): fit Gaussians directly in pixel space (FID analogue)
//! and on second-order feature maps (sFID analogue), computed *exactly*
//! via the closed form
//! `d² = ‖μ₁ − μ₂‖² + Tr(Σ₁ + Σ₂ − 2(Σ₁ Σ₂)^{1/2})`,
//! with the matrix square root from the symmetric eigendecomposition of
//! the (symmetrized) product. The IS analogue uses the TinyViT-style
//! entropy formulation over a probe classifier's predictions.

use crate::linalg::svd::svd;
use crate::tensor::{matmul, matmul_tn, Matrix};

/// Gaussian fit of a sample set (rows = samples).
pub struct GaussianFit {
    pub mean: Vec<f64>,
    pub cov: Matrix,
}

/// Fit mean and covariance (with small ridge for stability).
pub fn fit_gaussian(samples: &Matrix) -> GaussianFit {
    let (n, d) = samples.shape();
    assert!(n >= 2, "need at least 2 samples");
    let mut mean = vec![0.0f64; d];
    for i in 0..n {
        for (m, v) in mean.iter_mut().zip(samples.row(i)) {
            *m += *v as f64;
        }
    }
    for m in mean.iter_mut() {
        *m /= n as f64;
    }
    let mut centered = samples.clone();
    for i in 0..n {
        let row = centered.row_mut(i);
        for (v, m) in row.iter_mut().zip(&mean) {
            *v -= *m as f32;
        }
    }
    let mut cov = matmul_tn(&centered, &centered);
    cov.scale_inplace(1.0 / (n - 1) as f32);
    for i in 0..d {
        *cov.at_mut(i, i) += 1e-5;
    }
    GaussianFit { mean, cov }
}

/// Symmetric PSD matrix square root via SVD (for symmetric PSD input the
/// SVD coincides with the eigendecomposition).
fn sqrtm_psd(a: &Matrix) -> Matrix {
    let d = svd(a);
    // A = U diag(s) V^T with U ≈ V for symmetric PSD; sqrt = U diag(√s) U^T.
    let n = a.rows;
    let mut us = d.u.clone();
    for i in 0..n {
        let row = us.row_mut(i);
        for (k, sv) in d.s.iter().enumerate() {
            row[k] *= sv.max(0.0).sqrt();
        }
    }
    crate::tensor::matmul_nt(&us, &d.u)
}

/// Exact Fréchet distance between two Gaussian fits.
pub fn frechet_distance(a: &GaussianFit, b: &GaussianFit) -> f64 {
    assert_eq!(a.mean.len(), b.mean.len());
    let mean_term: f64 = a
        .mean
        .iter()
        .zip(&b.mean)
        .map(|(x, y)| (x - y) * (x - y))
        .sum();
    let tr_a: f64 = a.cov.diagonal().iter().map(|&v| v as f64).sum();
    let tr_b: f64 = b.cov.diagonal().iter().map(|&v| v as f64).sum();
    // (Σ₁ Σ₂)^{1/2}: symmetrize the product before the PSD sqrt — for
    // commuting/near-commuting covariances this matches the exact value,
    // and the symmetrization bounds numerical asymmetry.
    let prod = matmul(&a.cov, &b.cov);
    let sym = prod.add(&prod.transpose()).scale(0.5);
    let sqrt = sqrtm_psd(&sym);
    let tr_sqrt: f64 = sqrt.diagonal().iter().map(|&v| v as f64).sum();
    (mean_term + tr_a + tr_b - 2.0 * tr_sqrt).max(0.0)
}

/// FID analogue between two pixel-sample sets.
pub fn fid_between(generated: &Matrix, reference: &Matrix) -> f64 {
    frechet_distance(&fit_gaussian(generated), &fit_gaussian(reference))
}

/// sFID analogue: Fréchet distance on second-order (spatial-gradient)
/// features, which weights structure over raw intensity — mirroring
/// sFID's use of intermediate spatial features.
pub fn sfid_analogue(generated: &Matrix, reference: &Matrix, img: usize) -> f64 {
    let feat = |m: &Matrix| -> Matrix {
        let n = m.rows;
        // Horizontal + vertical finite differences, downsampled 2x.
        let half = img / 2;
        let mut out = Matrix::zeros(n, 2 * half * half);
        for s in 0..n {
            let px = m.row(s);
            let orow = out.row_mut(s);
            for i in 0..half {
                for j in 0..half {
                    let (ii, jj) = (i * 2, j * 2);
                    let v = px[ii * img + jj];
                    let dh = if jj + 1 < img { px[ii * img + jj + 1] - v } else { 0.0 };
                    let dv = if ii + 1 < img { px[(ii + 1) * img + jj] - v } else { 0.0 };
                    orow[i * half + j] = dh;
                    orow[half * half + i * half + j] = dv;
                }
            }
        }
        out
    };
    fid_between(&feat(generated), &feat(reference))
}

/// Inception-Score analogue: `exp(E_x KL(p(y|x) || p(y)))` using a probe
/// classifier's class probabilities (rows of `probs`, one per sample).
pub fn inception_score_analogue(probs: &Matrix) -> f64 {
    let (n, k) = probs.shape();
    assert!(n > 0 && k > 1);
    // Marginal p(y).
    let mut marginal = vec![0.0f64; k];
    for i in 0..n {
        for (m, v) in marginal.iter_mut().zip(probs.row(i)) {
            *m += *v as f64;
        }
    }
    for m in marginal.iter_mut() {
        *m /= n as f64;
    }
    let mut kl_sum = 0.0f64;
    for i in 0..n {
        let row = probs.row(i);
        for (j, &p) in row.iter().enumerate() {
            let p = p as f64;
            if p > 1e-12 {
                kl_sum += p * (p / marginal[j].max(1e-12)).ln();
            }
        }
    }
    (kl_sum / n as f64).exp()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Rng;

    fn gaussian_samples(n: usize, d: usize, mean: f32, std: f32, seed: u64) -> Matrix {
        let mut rng = Rng::new(seed);
        let mut m = rng.gaussian_matrix(n, d, std);
        m.map_inplace(|v| v + mean);
        m
    }

    #[test]
    fn identical_distributions_near_zero() {
        let a = gaussian_samples(400, 8, 0.0, 1.0, 800);
        let b = gaussian_samples(400, 8, 0.0, 1.0, 801);
        let fid = fid_between(&a, &b);
        assert!(fid < 0.5, "fid {fid}");
    }

    #[test]
    fn mean_shift_detected_quadratically() {
        let a = gaussian_samples(400, 8, 0.0, 1.0, 802);
        let b1 = gaussian_samples(400, 8, 1.0, 1.0, 803);
        let b2 = gaussian_samples(400, 8, 2.0, 1.0, 804);
        let f1 = fid_between(&a, &b1);
        let f2 = fid_between(&a, &b2);
        // ‖Δμ‖² scales 4x: 8 vs 32 expected.
        assert!((f1 - 8.0).abs() < 2.0, "f1 {f1}");
        assert!((f2 - 32.0).abs() < 5.0, "f2 {f2}");
    }

    #[test]
    fn variance_mismatch_detected() {
        let a = gaussian_samples(500, 6, 0.0, 1.0, 805);
        let b = gaussian_samples(500, 6, 0.0, 2.0, 806);
        let fid = fid_between(&a, &b);
        // Per dim: 1 + 4 − 2·2 = 1 → total ≈ 6.
        assert!((fid - 6.0).abs() < 2.0, "fid {fid}");
    }

    #[test]
    fn frechet_symmetric() {
        let a = gaussian_samples(300, 5, 0.0, 1.0, 807);
        let b = gaussian_samples(300, 5, 0.7, 1.5, 808);
        let f1 = fid_between(&a, &b);
        let f2 = fid_between(&b, &a);
        assert!((f1 - f2).abs() < 0.05 * f1.max(1.0), "{f1} vs {f2}");
    }

    #[test]
    fn sfid_sensitive_to_structure_not_mean() {
        let mut rng = Rng::new(809);
        // Same mean, different spatial structure: stripes vs noise.
        let img = 8;
        let mut stripes = Matrix::zeros(200, 64);
        for s in 0..200 {
            let phase = rng.uniform_range(0.0, 6.28);
            let row = stripes.row_mut(s);
            for i in 0..8 {
                for j in 0..8 {
                    row[i * 8 + j] = ((i as f32) + phase).sin();
                }
            }
        }
        let noise = rng.gaussian_matrix(200, 64, 0.6);
        let s1 = sfid_analogue(&stripes, &noise, img);
        let stripes2 = {
            let mut m = Matrix::zeros(200, 64);
            for s in 0..200 {
                let phase = rng.uniform_range(0.0, 6.28);
                let row = m.row_mut(s);
                for i in 0..8 {
                    for j in 0..8 {
                        row[i * 8 + j] = ((i as f32) + phase).sin();
                    }
                }
            }
            m
        };
        let s2 = sfid_analogue(&stripes, &stripes2, img);
        assert!(s1 > 5.0 * s2.max(1e-6), "sfid structure blind: {s1} vs {s2}");
    }

    #[test]
    fn inception_score_bounds() {
        // Confident + diverse -> high IS; uniform -> 1.0.
        let k = 4;
        let mut confident = Matrix::zeros(8, k);
        for i in 0..8 {
            confident.set(i, i % k, 1.0);
        }
        let is_conf = inception_score_analogue(&confident);
        assert!((is_conf - k as f64).abs() < 0.1, "IS {is_conf}");

        let uniform = Matrix::from_fn(8, k, |_, _| 1.0 / k as f32);
        let is_unif = inception_score_analogue(&uniform);
        assert!((is_unif - 1.0).abs() < 1e-6);

        // Confident but mode-collapsed -> 1.0 as well.
        let mut collapsed = Matrix::zeros(8, k);
        for i in 0..8 {
            collapsed.set(i, 0, 1.0);
        }
        assert!((inception_score_analogue(&collapsed) - 1.0).abs() < 1e-6);
    }
}
