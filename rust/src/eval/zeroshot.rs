//! Zero-shot choice scoring: length-normalized log-likelihood argmax,
//! the lm-eval-harness protocol used in Tables 3/12/13.

use crate::data::zeroshot::{ChoiceTask, TaskSuite};
use crate::nn::gpt::TinyLM;

/// Per-suite accuracy.
#[derive(Clone, Debug)]
pub struct SuiteResult {
    pub name: String,
    pub accuracy: f64,
    pub n: usize,
}

/// Log-probability of `continuation` following `prompt` under the model,
/// normalized by continuation length.
pub fn choice_logprob(model: &TinyLM, prompt: &[usize], continuation: &[usize]) -> f64 {
    let mut seq = prompt.to_vec();
    seq.extend_from_slice(continuation);
    let logits = model.forward(&seq);
    // Log-softmax rows, sum logprob of continuation tokens.
    let mut lp = 0.0f64;
    for (k, &tok) in continuation.iter().enumerate() {
        // continuation token k is predicted at position prompt.len()+k-1.
        let row_idx = prompt.len() + k - 1;
        let row = logits.row(row_idx);
        let max = row.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
        let logz = (row.iter().map(|&v| ((v - max) as f64).exp()).sum::<f64>()).ln() + max as f64;
        lp += row[tok] as f64 - logz;
    }
    lp / continuation.len() as f64
}

/// Score one task.
pub fn score_task(model: &TinyLM, task: &ChoiceTask) -> bool {
    let mut best = 0usize;
    let mut best_lp = f64::NEG_INFINITY;
    for (i, c) in task.choices.iter().enumerate() {
        let lp = choice_logprob(model, &task.prompt, c);
        if lp > best_lp {
            best_lp = lp;
            best = i;
        }
    }
    best == task.answer
}

/// Evaluate one suite.
pub fn eval_suite(model: &TinyLM, suite: &TaskSuite) -> SuiteResult {
    let correct = suite.tasks.iter().filter(|t| score_task(model, t)).count();
    SuiteResult {
        name: suite.name.clone(),
        accuracy: 100.0 * correct as f64 / suite.tasks.len().max(1) as f64,
        n: suite.tasks.len(),
    }
}

/// Evaluate all suites; returns per-suite results plus the average.
pub fn eval_suites(model: &TinyLM, suites: &[TaskSuite]) -> (Vec<SuiteResult>, f64) {
    let results: Vec<SuiteResult> = suites.iter().map(|s| eval_suite(model, s)).collect();
    let avg = results.iter().map(|r| r.accuracy).sum::<f64>() / results.len().max(1) as f64;
    (results, avg)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::corpus::SyntheticCorpus;
    use crate::data::zeroshot::build_suites;
    use crate::nn::attention::StructureKind;
    use crate::nn::gpt::{LmConfig, TinyLM};
    use crate::tensor::Rng;

    #[test]
    fn random_model_near_chance() {
        let c = SyntheticCorpus::generate(64, 5000, 100);
        let suites = build_suites(&c, 30);
        let mut rng = Rng::new(710);
        let lm = TinyLM::new(LmConfig::tiny(StructureKind::Dense), &mut rng);
        let (_, avg) = eval_suites(&lm, &suites);
        // 6 suites are 2-way (50% chance), one is 4-way (25%).
        assert!(avg > 25.0 && avg < 75.0, "avg {avg}");
    }

    #[test]
    fn trained_model_beats_chance() {
        let c = SyntheticCorpus::generate(64, 12_000, 100);
        let suites = build_suites(&c, 25);
        let mut rng = Rng::new(711);
        let mut lm = TinyLM::new(LmConfig::tiny(StructureKind::Dense), &mut rng);
        let cfg = crate::train::LmTrainConfig { steps: 150, ..Default::default() };
        crate::train::train_lm(&mut lm, &c.train_dataset(), &cfg);
        let (results, avg) = eval_suites(&lm, &suites);
        assert_eq!(results.len(), 7);
        // Chance average = (6*50 + 25)/7 ≈ 46.4; trained should clear it.
        assert!(avg > 52.0, "trained avg {avg} not above chance");
    }

    #[test]
    fn logprob_prefers_repeated_pattern() {
        // Sanity on the scoring math itself: a model trained on one
        // sequence assigns it a higher normalized logprob than a random
        // continuation.
        let mut rng = Rng::new(712);
        let mut lm = TinyLM::new(LmConfig::tiny(StructureKind::Dense), &mut rng);
        let seq: Vec<usize> = vec![5, 9, 5, 9, 5, 9, 5, 9];
        let mut opt = crate::nn::param::AdamW::new(1e-2, 0.0);
        for _ in 0..40 {
            lm.zero_grads();
            let (_, cache, d) = lm.loss_t(&seq);
            lm.backward(&cache, &d);
            opt.step(&mut lm.params_mut(), 1e-2);
        }
        let lp_good = choice_logprob(&lm, &[5, 9, 5], &[9]);
        let lp_bad = choice_logprob(&lm, &[5, 9, 5], &[33]);
        assert!(lp_good > lp_bad, "{lp_good} vs {lp_bad}");
    }
}
