//! Evaluation metrics: the quantities the paper's tables report.
//!
//! * [`perplexity`] — WikiText-style token perplexity (Fig. 5, Table 3);
//! * [`zeroshot`] — length-normalized choice scoring accuracy
//!   (Tables 3/12/13, Fig. 7);
//! * [`fid`] — exact Fréchet distance between Gaussian fits + sFID and
//!   Inception-Score analogues (Table 2).

pub mod perplexity;
pub mod zeroshot;
pub mod fid;

pub use fid::{frechet_distance, inception_score_analogue, sfid_analogue};
pub use perplexity::perplexity;
pub use zeroshot::{eval_suite, eval_suites, SuiteResult};
