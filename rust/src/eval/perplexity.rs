//! Token perplexity over evaluation windows.

use crate::data::corpus::LmDataset;
use crate::nn::gpt::TinyLM;

/// Mean perplexity (exp of mean next-token NLL) over non-overlapping
/// windows of `seq_len` tokens. Caps the number of windows for runtime.
pub fn perplexity(model: &TinyLM, data: &LmDataset, seq_len: usize, max_windows: usize) -> f64 {
    let windows = data.eval_windows(seq_len);
    let take = windows.len().min(max_windows).max(1);
    let mut nll = 0.0f64;
    let mut n = 0usize;
    for w in windows.into_iter().take(take) {
        nll += model.loss(w);
        n += 1;
    }
    (nll / n as f64).exp()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::corpus::SyntheticCorpus;
    use crate::nn::attention::StructureKind;
    use crate::nn::gpt::{LmConfig, TinyLM};
    use crate::tensor::Rng;

    #[test]
    fn random_model_near_uniform() {
        let c = SyntheticCorpus::generate(64, 2000, 640);
        let mut rng = Rng::new(700);
        let lm = TinyLM::new(LmConfig::tiny(StructureKind::Dense), &mut rng);
        let ppl = perplexity(&lm, &c.valid_dataset(), 32, 8);
        // Random init ≈ uniform distribution → ppl ≈ vocab.
        assert!(ppl > 64.0 * 0.5 && ppl < 64.0 * 2.0, "ppl {ppl}");
    }

    #[test]
    fn trained_model_beats_random() {
        let c = SyntheticCorpus::generate(64, 8000, 640);
        let mut rng = Rng::new(701);
        let mut lm = TinyLM::new(LmConfig::tiny(StructureKind::Dense), &mut rng);
        let before = perplexity(&lm, &c.valid_dataset(), 32, 6);
        let cfg = crate::train::LmTrainConfig { steps: 80, ..Default::default() };
        crate::train::train_lm(&mut lm, &c.train_dataset(), &cfg);
        let after = perplexity(&lm, &c.valid_dataset(), 32, 6);
        assert!(after < before * 0.7, "ppl {before} -> {after}");
    }
}
