//! Per-worker scratch arena: pooled `f32` buffers for the decode hot
//! path.
//!
//! Every serving worker (and any other steady-state loop) owns one
//! `ScratchArena` and routes its per-step temporaries — embedded
//! activations, LayerNorm outputs, attention QKV/context/score buffers,
//! MLP intermediates — through it. Buffers are pooled by
//! power-of-two capacity class: `take`/`take_matrix` pop a buffer of
//! sufficient capacity (allocating only when the class is empty) and
//! `recycle`/`recycle_matrix` push it back. After one warm step at a
//! given batch shape, every take is a pop — a steady-state
//! `decode_step_batch` iteration performs **zero heap allocations**
//! (asserted by `tests/decode_alloc.rs` with a counting allocator).
//!
//! The arena is deliberately not `Sync`: it models *per-worker* scratch.
//! Cross-thread kernel scratch lives in the kernels' own thread-locals.

use crate::tensor::Matrix;

/// Pooled scratch buffers, bucketed by power-of-two capacity.
pub struct ScratchArena {
    /// `pools[c]` holds buffers with capacity exactly `1 << c`.
    pools: Vec<Vec<Vec<f32>>>,
    /// Buffers currently handed out (diagnostics; leak detection).
    outstanding: usize,
    /// Pool misses: `take` calls that had to allocate (warmup / shape
    /// growth — zero per steady-state step).
    misses: usize,
    /// Bytes currently parked in the pools.
    pooled_bytes: usize,
    /// High-water mark of `pooled_bytes` over this arena's lifetime.
    high_water_bytes: usize,
}

/// Capacity class of a request: buffers are allocated at the next
/// power of two so repeat takes of nearby sizes share a pool.
#[inline]
fn class_of(len: usize) -> usize {
    len.max(1).next_power_of_two().trailing_zeros() as usize
}

impl ScratchArena {
    pub fn new() -> Self {
        ScratchArena {
            pools: Vec::new(),
            outstanding: 0,
            misses: 0,
            pooled_bytes: 0,
            high_water_bytes: 0,
        }
    }

    /// A zeroed buffer of exactly `len` elements (capacity is the
    /// power-of-two class, so recycling round-trips losslessly).
    pub fn take(&mut self, len: usize) -> Vec<f32> {
        let class = class_of(len);
        if self.pools.len() <= class {
            self.pools.resize_with(class + 1, Vec::new);
        }
        self.outstanding += 1;
        match self.pools[class].pop() {
            Some(mut buf) => {
                self.pooled_bytes -= buf.capacity() * std::mem::size_of::<f32>();
                buf.clear();
                buf.resize(len, 0.0);
                buf
            }
            None => {
                // Cold path (warmup / shape growth): account the miss
                // locally and process-wide. The global counters are
                // relaxed atomics, but they only run here — a
                // steady-state take is always a pop.
                self.misses += 1;
                let bytes = (1usize << class) * std::mem::size_of::<f32>();
                crate::obs::well_known::arena_misses().inc();
                crate::obs::well_known::arena_allocated_bytes().add(bytes as u64);
                let mut buf = Vec::with_capacity(1 << class);
                buf.resize(len, 0.0);
                buf
            }
        }
    }

    /// Return a buffer to its capacity class. Only buffers whose
    /// capacity is exactly a power of two (i.e. buffers this arena
    /// handed out and that were not grown) are pooled; anything else is
    /// silently dropped — re-pooling it would make the next `take` of
    /// its class re-allocate on resize, defeating the steady-state
    /// guarantee. Callers that grow an arena buffer past its class
    /// should treat that as a warmup-path event, not steady state.
    pub fn recycle(&mut self, buf: Vec<f32>) {
        if buf.capacity() == 0 {
            return;
        }
        let class = class_of(buf.capacity());
        // Only pool buffers whose capacity is exactly a class size —
        // anything else would make `take` re-allocate on reuse.
        if buf.capacity() != (1 << class) {
            self.outstanding = self.outstanding.saturating_sub(1);
            return;
        }
        if self.pools.len() <= class {
            self.pools.resize_with(class + 1, Vec::new);
        }
        self.outstanding = self.outstanding.saturating_sub(1);
        self.pooled_bytes += buf.capacity() * std::mem::size_of::<f32>();
        if self.pooled_bytes > self.high_water_bytes {
            // High-water only moves during warmup/growth, so the global
            // gauge update stays off the steady-state recycle path.
            self.high_water_bytes = self.pooled_bytes;
            crate::obs::well_known::arena_pooled_bytes_high_water()
                .set_max(self.pooled_bytes as u64);
        }
        self.pools[class].push(buf);
    }

    /// A zeroed `rows × cols` matrix backed by a pooled buffer.
    pub fn take_matrix(&mut self, rows: usize, cols: usize) -> Matrix {
        Matrix::from_vec(rows, cols, self.take(rows * cols))
    }

    /// Return a matrix's buffer to the pool.
    pub fn recycle_matrix(&mut self, m: Matrix) {
        self.recycle(m.data);
    }

    /// Buffers currently checked out (should return to 0 between steps).
    pub fn outstanding(&self) -> usize {
        self.outstanding
    }

    /// Total pooled buffers across all classes.
    pub fn pooled(&self) -> usize {
        self.pools.iter().map(|p| p.len()).sum()
    }

    /// `take` calls that had to allocate (zero per steady-state step).
    pub fn misses(&self) -> usize {
        self.misses
    }

    /// Bytes currently parked in the pools.
    pub fn pooled_bytes(&self) -> usize {
        self.pooled_bytes
    }

    /// Most bytes this arena ever had pooled at once.
    pub fn high_water_bytes(&self) -> usize {
        self.high_water_bytes
    }
}

impl Default for ScratchArena {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn take_recycle_reuses_buffer() {
        let mut arena = ScratchArena::new();
        let buf = arena.take(100);
        assert_eq!(buf.len(), 100);
        assert_eq!(buf.capacity(), 128);
        let ptr = buf.as_ptr();
        arena.recycle(buf);
        assert_eq!(arena.pooled(), 1);
        // Same class (any len in 65..=128) reuses the same buffer.
        let again = arena.take(128);
        assert_eq!(again.as_ptr(), ptr, "same-class take must pop the pooled buffer");
        assert!(again.iter().all(|&v| v == 0.0), "recycled buffer must be re-zeroed");
        arena.recycle(again);
    }

    #[test]
    fn matrix_round_trip() {
        let mut arena = ScratchArena::new();
        let mut m = arena.take_matrix(3, 5);
        assert_eq!(m.shape(), (3, 5));
        m.set(2, 4, 7.0);
        let ptr = m.data.as_ptr();
        arena.recycle_matrix(m);
        let m2 = arena.take_matrix(4, 4);
        assert_eq!(m2.data.as_ptr(), ptr, "16-element class is shared");
        assert_eq!(m2.at(0, 0), 0.0);
        assert_eq!(arena.outstanding(), 1);
        arena.recycle_matrix(m2);
        assert_eq!(arena.outstanding(), 0);
    }

    #[test]
    fn distinct_classes_do_not_alias() {
        let mut arena = ScratchArena::new();
        let a = arena.take(10); // class 16
        let b = arena.take(100); // class 128
        arena.recycle(a);
        arena.recycle(b);
        let c = arena.take(100);
        assert_eq!(c.capacity(), 128);
        arena.recycle(c);
        assert_eq!(arena.pooled(), 2);
    }

    #[test]
    fn zero_len_take_is_fine() {
        let mut arena = ScratchArena::new();
        let buf = arena.take(0);
        assert!(buf.is_empty());
        arena.recycle(buf);
    }

    #[test]
    fn stats_track_misses_and_pooled_bytes() {
        let mut arena = ScratchArena::new();
        let a = arena.take(100); // class 128: cold -> miss
        assert_eq!(arena.misses(), 1);
        assert_eq!(arena.pooled_bytes(), 0);
        arena.recycle(a);
        assert_eq!(arena.pooled_bytes(), 128 * 4);
        assert_eq!(arena.high_water_bytes(), 128 * 4);
        // Steady state: the pop re-uses the buffer, no new miss, and
        // pooled bytes drop while the buffer is checked out.
        let b = arena.take(128);
        assert_eq!(arena.misses(), 1);
        assert_eq!(arena.pooled_bytes(), 0);
        arena.recycle(b);
        assert_eq!(arena.high_water_bytes(), 128 * 4, "high water is monotone");
    }

    #[test]
    fn foreign_capacity_buffers_are_dropped_not_pooled() {
        let mut arena = ScratchArena::new();
        let mut v = Vec::with_capacity(100); // not a power of two
        v.resize(100, 1.0f32);
        arena.recycle(v);
        assert_eq!(arena.pooled(), 0);
    }
}
