//! In-repo replacements for common ecosystem crates (the build is fully
//! offline, so everything beyond `xla`/`anyhow` is implemented here).
//!
//! * [`par`] — scoped-thread data parallelism (rayon-lite).
//! * [`arena`] — pooled per-worker scratch buffers (the decode hot
//!   path's zero-allocation backing store).
//! * [`json`] — minimal JSON value model + parser/serializer for the
//!   artifact manifest and experiment reports.
//! * [`cli`] — flag/positional argument parsing for the `blast` binary.
//! * [`bench`] — measurement harness used by `cargo bench` targets
//!   (criterion-lite: warmup, repeated timed runs, mean/p50/p95).
//! * [`check`] — seeded random-input property testing (proptest-lite).
//! * [`config`] — typed engine configuration (`EngineConfig`): one
//!   struct holding every `BLAST_*` knob, resolved once at startup.
//! * [`failpoint`] — deterministic fault injection (`fail_point!`
//!   sites, armed via `BLAST_FAILPOINTS`; no-op otherwise).

pub mod arena;
pub mod config;
pub mod failpoint;
pub mod par;
pub mod json;
pub mod cli;
pub mod bench;
pub mod check;
