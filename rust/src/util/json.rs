//! Minimal JSON value model, parser, and serializer.
//!
//! Used for the artifact manifest (`artifacts/manifest.json`, written by
//! `python/compile/aot.py`) and for machine-readable experiment reports.
//! Supports the full JSON grammar minus exotic escapes (\u is handled).

use anyhow::{anyhow, bail, Result};
use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(s: &str) -> Result<Json> {
        let mut p = Parser { bytes: s.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            bail!("trailing characters at byte {}", p.pos);
        }
        Ok(v)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// `obj[key]` with a useful error.
    pub fn get(&self, key: &str) -> Result<&Json> {
        self.as_obj()
            .ok_or_else(|| anyhow!("expected object while looking up `{key}`"))?
            .get(key)
            .ok_or_else(|| anyhow!("missing key `{key}`"))
    }

    /// Serialize compactly.
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, None, 0);
        out
    }

    /// Serialize with 2-space indentation.
    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(2), 0);
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline(out, indent, depth + 1);
                    v.write(out, indent, depth + 1);
                }
                if !a.is_empty() {
                    newline(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !o.is_empty() {
                    newline(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

/// Convenience builders.
impl From<&str> for Json {
    fn from(s: &str) -> Json {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Json {
        Json::Str(s)
    }
}
impl From<f64> for Json {
    fn from(n: f64) -> Json {
        Json::Num(n)
    }
}
impl From<usize> for Json {
    fn from(n: usize) -> Json {
        Json::Num(n as f64)
    }
}
impl From<bool> for Json {
    fn from(b: bool) -> Json {
        Json::Bool(b)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Json {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

/// Build an object from pairs.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

fn newline(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            bail!("expected `{}` at byte {}", b as char, self.pos)
        }
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek() {
            Some(b'n') => self.keyword("null", Json::Null),
            Some(b't') => self.keyword("true", Json::Bool(true)),
            Some(b'f') => self.keyword("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => bail!("unexpected {:?} at byte {}", other.map(|c| c as char), self.pos),
        }
    }

    fn keyword(&mut self, kw: &str, v: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(kw.as_bytes()) {
            self.pos += kw.len();
            Ok(v)
        } else {
            bail!("bad keyword at byte {}", self.pos)
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            let Some(c) = self.peek() else {
                bail!("unterminated string");
            };
            self.pos += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let Some(e) = self.peek() else {
                        bail!("unterminated escape");
                    };
                    self.pos += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            if self.pos + 4 > self.bytes.len() {
                                bail!("truncated \\u escape");
                            }
                            let hex = std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])?;
                            let code = u32::from_str_radix(hex, 16)?;
                            self.pos += 4;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        }
                        _ => bail!("bad escape \\{}", e as char),
                    }
                }
                c => {
                    // Re-decode UTF-8: collect continuation bytes.
                    if c < 0x80 {
                        out.push(c as char);
                    } else {
                        let start = self.pos - 1;
                        let width = match c {
                            0xC0..=0xDF => 2,
                            0xE0..=0xEF => 3,
                            _ => 4,
                        };
                        self.pos = start + width;
                        if self.pos > self.bytes.len() {
                            bail!("truncated utf-8");
                        }
                        out.push_str(std::str::from_utf8(&self.bytes[start..self.pos])?);
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.pos += 1;
            } else {
                break;
            }
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos])?;
        Ok(Json::Num(s.parse()?))
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(out));
                }
                _ => bail!("expected `,` or `]` at byte {}", self.pos),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            out.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(out));
                }
                _ => bail!("expected `,` or `}}` at byte {}", self.pos),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"hi\\n\"").unwrap(), Json::Str("hi\n".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[2].get("b").unwrap().as_str(),
            Some("c")
        );
    }

    #[test]
    fn round_trip() {
        let src = r#"{"name":"tinylm_blast","shapes":[[4,8],[8,16]],"rank":12,"ok":true}"#;
        let v = Json::parse(src).unwrap();
        let re = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, re);
        let re2 = Json::parse(&v.to_string_pretty()).unwrap();
        assert_eq!(v, re2);
    }

    #[test]
    fn unicode_and_escapes() {
        let v = Json::parse(r#""A\t π""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "A\t π");
        let round = Json::Str("quote\" slash\\ nl\n".into()).to_string();
        assert_eq!(Json::parse(&round).unwrap().as_str().unwrap(), "quote\" slash\\ nl\n");
    }

    #[test]
    fn errors_reported() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("tru").is_err());
        assert!(Json::parse("1 2").is_err());
    }

    #[test]
    fn builders() {
        let v = obj(vec![
            ("x", Json::from(1usize)),
            ("y", Json::from(vec!["a", "b"])),
        ]);
        assert_eq!(v.get("x").unwrap().as_usize(), Some(1));
        assert_eq!(v.get("y").unwrap().as_arr().unwrap().len(), 2);
    }
}
