//! Scoped-thread data parallelism (rayon-lite).
//!
//! The GEMM kernels and factorization sweeps parallelize over disjoint
//! output-row chunks. `std::thread::scope` gives us borrow-safe fork/join
//! without any external crate; for the chunk sizes we use (hundreds of
//! rows × hundreds of floats) thread-spawn overhead is well under 1 % of
//! kernel time.

use std::sync::atomic::{AtomicUsize, Ordering};

/// Number of worker threads to use (cached).
pub fn num_threads() -> usize {
    static CACHED: AtomicUsize = AtomicUsize::new(0);
    let v = CACHED.load(Ordering::Relaxed);
    if v != 0 {
        return v;
    }
    let cfg = super::config::EngineConfig::global();
    let n = match cfg.num_threads {
        0 => std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
        n => n,
    }
    .max(1);
    CACHED.store(n, Ordering::Relaxed);
    n
}

/// Run `f(chunk_index, chunk)` over contiguous mutable chunks of `data`,
/// in parallel. `chunk_len` is the length of each chunk (last may be
/// shorter). The closure must be `Sync` since it is shared across threads.
pub fn par_chunks_mut<T: Send, F>(data: &mut [T], chunk_len: usize, f: F)
where
    F: Fn(usize, &mut [T]) + Sync,
{
    assert!(chunk_len > 0);
    let n_chunks = data.len().div_ceil(chunk_len);
    let threads = num_threads().min(n_chunks);
    if threads <= 1 || n_chunks <= 1 {
        for (i, chunk) in data.chunks_mut(chunk_len).enumerate() {
            f(i, chunk);
        }
        return;
    }
    // Lock-free static partition: chunks are distributed round-robin to
    // `threads` buckets; each bucket moves into its own scoped thread.
    // (&mut [T] chunks are disjoint, so this is plain safe ownership —
    // no Mutex, no per-chunk allocation. §Perf: replacing the previous
    // Mutex-slot scheme cut gemv dispatch overhead ~6x at 4096 rows.)
    let mut buckets: Vec<Vec<(usize, &mut [T])>> =
        (0..threads).map(|_| Vec::with_capacity(n_chunks / threads + 1)).collect();
    for (i, chunk) in data.chunks_mut(chunk_len).enumerate() {
        buckets[i % threads].push((i, chunk));
    }
    let f = &f;
    std::thread::scope(|scope| {
        for bucket in buckets {
            scope.spawn(move || {
                for (i, chunk) in bucket {
                    f(i, chunk);
                }
            });
        }
    });
}

/// Parallel map over indices `0..n`, collecting results in order.
pub fn par_map<T: Send, F>(n: usize, f: F) -> Vec<T>
where
    F: Fn(usize) -> T + Sync,
{
    let threads = num_threads().min(n.max(1));
    if threads <= 1 || n <= 1 {
        return (0..n).map(f).collect();
    }
    let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
    let next = AtomicUsize::new(0);
    let slots: Vec<std::sync::Mutex<&mut Option<T>>> =
        out.iter_mut().map(std::sync::Mutex::new).collect();
    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let v = f(i);
                **slots[i].lock().unwrap() = Some(v);
            });
        }
    });
    out.into_iter().map(|o| o.expect("par_map slot unfilled")).collect()
}

/// [`par_map`] when `parallel` is true, plain sequential map otherwise.
///
/// The factorization sweeps use this so one code path serves both the
/// grid-parallel and the single-thread reference execution: per-index
/// arithmetic is identical and results are collected in index order, so
/// both modes produce bit-identical outputs.
pub fn par_map_if<T: Send, F>(parallel: bool, n: usize, f: F) -> Vec<T>
where
    F: Fn(usize) -> T + Sync,
{
    if parallel {
        par_map(n, f)
    } else {
        (0..n).map(f).collect()
    }
}

/// Parallel for over indices `0..n` (no results).
pub fn par_for<F>(n: usize, f: F)
where
    F: Fn(usize) + Sync,
{
    let threads = num_threads().min(n.max(1));
    if threads <= 1 || n <= 1 {
        for i in 0..n {
            f(i);
        }
        return;
    }
    let next = AtomicUsize::new(0);
    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                f(i);
            });
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chunks_cover_everything() {
        let mut data = vec![0u32; 1003];
        par_chunks_mut(&mut data, 17, |i, chunk| {
            for x in chunk.iter_mut() {
                *x = i as u32 + 1;
            }
        });
        assert!(data.iter().all(|&x| x > 0));
        // First chunk is all 1s, second all 2s, ...
        assert_eq!(data[0], 1);
        assert_eq!(data[17], 2);
        assert_eq!(data[1002], 1003u32.div_ceil(17));
    }

    #[test]
    fn map_preserves_order() {
        let out = par_map(100, |i| i * i);
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, i * i);
        }
    }

    #[test]
    fn par_for_runs_all() {
        let counter = AtomicUsize::new(0);
        par_for(1000, |_| {
            counter.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(counter.load(Ordering::Relaxed), 1000);
    }

    #[test]
    fn empty_and_single() {
        let out: Vec<usize> = par_map(0, |i| i);
        assert!(out.is_empty());
        let out = par_map(1, |i| i + 7);
        assert_eq!(out, vec![7]);
        let mut data: Vec<u8> = vec![];
        par_chunks_mut(&mut data, 4, |_, _| panic!("no chunks expected"));
    }

    #[test]
    fn num_threads_positive() {
        assert!(num_threads() >= 1);
    }

    #[test]
    fn par_map_if_modes_agree() {
        let seq = par_map_if(false, 37, |i| i * 3 + 1);
        let par = par_map_if(true, 37, |i| i * 3 + 1);
        assert_eq!(seq, par);
    }
}
