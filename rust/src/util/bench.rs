//! Measurement harness for the `cargo bench` targets (criterion-lite).
//!
//! Each bench binary builds a `BenchSuite`, registers closures, and calls
//! `run()`. For every case we warm up, then collect wall-clock samples
//! until either `max_samples` runs or `max_time` elapses, and report
//! mean / p50 / p95 / min plus derived throughput when the case declares
//! work-per-iteration.

use std::time::{Duration, Instant};

/// One timing result.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub samples: Vec<Duration>,
    /// Optional work per iteration (elements, tokens, FLOPs…) for
    /// throughput reporting.
    pub work_per_iter: Option<(f64, &'static str)>,
}

impl BenchResult {
    pub fn mean(&self) -> Duration {
        let total: Duration = self.samples.iter().sum();
        total / self.samples.len().max(1) as u32
    }

    pub fn percentile(&self, p: f64) -> Duration {
        let mut s = self.samples.clone();
        s.sort();
        let idx = ((s.len() as f64 - 1.0) * p / 100.0).round() as usize;
        s[idx.min(s.len() - 1)]
    }

    pub fn min(&self) -> Duration {
        self.samples.iter().min().copied().unwrap_or_default()
    }

    pub fn report_line(&self) -> String {
        let mean = self.mean();
        let p50 = self.percentile(50.0);
        let p95 = self.percentile(95.0);
        let mut line = format!(
            "{:<44} mean {:>11.3?}  p50 {:>11.3?}  p95 {:>11.3?}  min {:>11.3?}  (n={})",
            self.name,
            mean,
            p50,
            p95,
            self.min(),
            self.samples.len()
        );
        if let Some((work, unit)) = self.work_per_iter {
            let per_sec = work / mean.as_secs_f64();
            line.push_str(&format!("  [{per_sec:.3e} {unit}/s]"));
        }
        line
    }
}

/// Bench runner configuration.
#[derive(Clone, Debug)]
pub struct BenchConfig {
    pub warmup_iters: usize,
    pub max_samples: usize,
    pub max_time: Duration,
}

impl Default for BenchConfig {
    fn default() -> Self {
        // Honors BLAST_BENCH_FAST=1 (via EngineConfig) for CI-speed runs.
        let fast = super::config::EngineConfig::global().bench_fast;
        if fast {
            BenchConfig {
                warmup_iters: 1,
                max_samples: 5,
                max_time: Duration::from_millis(500),
            }
        } else {
            BenchConfig {
                warmup_iters: 3,
                max_samples: 30,
                max_time: Duration::from_secs(3),
            }
        }
    }
}

/// A collection of named benchmarks.
pub struct BenchSuite {
    pub title: String,
    pub config: BenchConfig,
    pub results: Vec<BenchResult>,
}

impl BenchSuite {
    pub fn new(title: &str) -> Self {
        println!("=== bench suite: {title} ===");
        BenchSuite {
            title: title.to_string(),
            config: BenchConfig::default(),
            results: Vec::new(),
        }
    }

    /// Time `f`, discarding its output (use `std::hint::black_box` inside
    /// the closure to defeat DCE on inputs).
    pub fn bench(&mut self, name: &str, mut f: impl FnMut()) -> &BenchResult {
        self.bench_with_work(name, None, &mut f)
    }

    /// Time `f` and report throughput as `work / sec`.
    pub fn bench_throughput(
        &mut self,
        name: &str,
        work: f64,
        unit: &'static str,
        mut f: impl FnMut(),
    ) -> &BenchResult {
        self.bench_with_work(name, Some((work, unit)), &mut f)
    }

    fn bench_with_work(
        &mut self,
        name: &str,
        work: Option<(f64, &'static str)>,
        f: &mut dyn FnMut(),
    ) -> &BenchResult {
        for _ in 0..self.config.warmup_iters {
            f();
        }
        let mut samples = Vec::new();
        let start = Instant::now();
        while samples.len() < self.config.max_samples && start.elapsed() < self.config.max_time {
            let t = Instant::now();
            f();
            samples.push(t.elapsed());
        }
        if samples.is_empty() {
            let t = Instant::now();
            f();
            samples.push(t.elapsed());
        }
        let result = BenchResult {
            name: name.to_string(),
            samples,
            work_per_iter: work,
        };
        println!("{}", result.report_line());
        self.results.push(result);
        self.results.last().unwrap()
    }

    /// Mean runtime of a named result (for cross-case ratio reporting).
    pub fn mean_of(&self, name: &str) -> Option<Duration> {
        self.results.iter().find(|r| r.name == name).map(|r| r.mean())
    }

    /// Print a speedup line `a vs b`.
    pub fn report_speedup(&self, baseline: &str, contender: &str) {
        if let (Some(b), Some(c)) = (self.mean_of(baseline), self.mean_of(contender)) {
            let speedup = b.as_secs_f64() / c.as_secs_f64();
            println!(
                "--> {contender} is {speedup:.2}x vs {baseline} ({:.1}% runtime reduction)",
                (1.0 - 1.0 / speedup.max(1e-12)) * 100.0
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn collects_samples_and_reports() {
        let mut suite = BenchSuite::new("test");
        suite.config = BenchConfig {
            warmup_iters: 1,
            max_samples: 4,
            max_time: Duration::from_secs(1),
        };
        let r = suite.bench("noop", || {
            std::hint::black_box(1 + 1);
        });
        assert!(!r.samples.is_empty());
        assert!(r.mean() < Duration::from_millis(100));
        let line = r.report_line();
        assert!(line.contains("noop"));
    }

    #[test]
    fn percentiles_ordered() {
        let r = BenchResult {
            name: "x".into(),
            samples: (1..=100).map(Duration::from_micros).collect(),
            work_per_iter: None,
        };
        assert!(r.percentile(50.0) <= r.percentile(95.0));
        assert_eq!(r.min(), Duration::from_micros(1));
    }

    #[test]
    fn throughput_line() {
        let r = BenchResult {
            name: "tp".into(),
            samples: vec![Duration::from_millis(10)],
            work_per_iter: Some((1000.0, "tok")),
        };
        assert!(r.report_line().contains("tok/s"));
    }
}
