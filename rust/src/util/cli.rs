//! Tiny CLI argument parser for the `blast` binary.
//!
//! Supports `--flag value`, `--flag=value`, boolean `--flag`, and
//! positional arguments. Each subcommand declares the flags it accepts;
//! unknown flags are an error with a hint.

use anyhow::{anyhow, bail, Result};
use std::collections::BTreeMap;

/// Parsed arguments: positionals in order, flags by name (without `--`).
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    pub flags: BTreeMap<String, String>,
    pub bools: Vec<String>,
}

impl Args {
    /// Parse raw argv entries (after the subcommand).
    pub fn parse(raw: &[String], bool_flags: &[&str]) -> Result<Args> {
        let mut out = Args::default();
        let mut i = 0;
        while i < raw.len() {
            let a = &raw[i];
            if let Some(name) = a.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if bool_flags.contains(&name) {
                    out.bools.push(name.to_string());
                } else {
                    i += 1;
                    let v = raw
                        .get(i)
                        .ok_or_else(|| anyhow!("flag --{name} expects a value"))?;
                    out.flags.insert(name.to_string(), v.clone());
                }
            } else {
                out.positional.push(a.clone());
            }
            i += 1;
        }
        Ok(out)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_usize(&self, name: &str, default: usize) -> Result<usize> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow!("--{name} expects an integer, got `{v}`")),
        }
    }

    pub fn get_f64(&self, name: &str, default: f64) -> Result<f64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow!("--{name} expects a number, got `{v}`")),
        }
    }

    pub fn get_u64(&self, name: &str, default: u64) -> Result<u64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow!("--{name} expects an integer, got `{v}`")),
        }
    }

    pub fn has(&self, name: &str) -> bool {
        self.bools.iter().any(|b| b == name) || self.flags.contains_key(name)
    }

    /// Error if any flag outside `allowed` was passed.
    pub fn reject_unknown(&self, allowed: &[&str]) -> Result<()> {
        for k in self.flags.keys().chain(self.bools.iter()) {
            if !allowed.contains(&k.as_str()) {
                bail!("unknown flag --{k} (allowed: {})", allowed.join(", "));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn raw(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parse_mixed() {
        let a = Args::parse(
            &raw(&["pos1", "--n", "32", "--ratio=0.5", "--verbose", "pos2"]),
            &["verbose"],
        )
        .unwrap();
        assert_eq!(a.positional, vec!["pos1", "pos2"]);
        assert_eq!(a.get_usize("n", 0).unwrap(), 32);
        assert_eq!(a.get_f64("ratio", 0.0).unwrap(), 0.5);
        assert!(a.has("verbose"));
        assert!(!a.has("quiet"));
    }

    #[test]
    fn defaults() {
        let a = Args::parse(&raw(&[]), &[]).unwrap();
        assert_eq!(a.get_usize("n", 7).unwrap(), 7);
        assert_eq!(a.get_or("mode", "fast"), "fast");
    }

    #[test]
    fn missing_value_is_error() {
        assert!(Args::parse(&raw(&["--n"]), &[]).is_err());
    }

    #[test]
    fn bad_number_is_error() {
        let a = Args::parse(&raw(&["--n", "abc"]), &[]).unwrap();
        assert!(a.get_usize("n", 0).is_err());
    }

    #[test]
    fn reject_unknown_flags() {
        let a = Args::parse(&raw(&["--bogus", "1"]), &[]).unwrap();
        assert!(a.reject_unknown(&["n", "ratio"]).is_err());
        assert!(a.reject_unknown(&["bogus"]).is_ok());
    }
}
