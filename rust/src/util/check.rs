//! Seeded random-input property testing (proptest-lite).
//!
//! `property(cases, |gen| { ... })` runs the closure against `cases`
//! independently seeded generators; on failure it reports the seed so the
//! case can be replayed deterministically with `PropGen::replay(seed)`.

use crate::tensor::Rng;

/// Random-input generator handed to property closures.
pub struct PropGen {
    pub rng: Rng,
    pub seed: u64,
}

impl PropGen {
    pub fn replay(seed: u64) -> Self {
        PropGen { rng: Rng::new(seed), seed }
    }

    /// usize in [lo, hi] inclusive.
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        assert!(hi >= lo);
        lo + self.rng.below(hi - lo + 1)
    }

    /// f32 in [lo, hi).
    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        self.rng.uniform_range(lo, hi)
    }

    /// A divisor pair: returns (n, b) with b dividing n, n in [lo, hi].
    pub fn divisible(&mut self, lo: usize, hi: usize, max_b: usize) -> (usize, usize) {
        let b = self.usize_in(1, max_b);
        let k = self.usize_in(lo.div_ceil(b).max(1), (hi / b).max(1));
        (b * k, b)
    }

    /// Random matrix.
    pub fn matrix(&mut self, rows: usize, cols: usize) -> crate::tensor::Matrix {
        self.rng.gaussian_matrix(rows, cols, 1.0)
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_u64() & 1 == 1
    }
}

/// Run `f` against `cases` random generators; panics with the failing
/// seed on the first property violation (any panic inside `f`).
///
/// Captured state is wrapped in `AssertUnwindSafe`: a failing property
/// aborts the test anyway, so observing torn captures is not a concern.
pub fn property(cases: usize, f: impl Fn(&mut PropGen)) {
    let base = super::config::EngineConfig::global().prop_seed.unwrap_or(0xB1A57u64);
    for case in 0..cases {
        let seed = base.wrapping_add(case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let mut gen = PropGen::replay(seed);
            f(&mut gen);
        }));
        if let Err(e) = result {
            let msg = e
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!("property failed on case {case} (replay seed {seed:#x}): {msg}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        property(25, |g| {
            let n = g.usize_in(1, 10);
            assert!((1..=10).contains(&n));
        });
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_reports_seed() {
        property(10, |g| {
            let n = g.usize_in(0, 100);
            assert!(n < 5, "n too big: {n}");
        });
    }

    #[test]
    fn replay_is_deterministic() {
        let mut a = PropGen::replay(42);
        let mut b = PropGen::replay(42);
        for _ in 0..20 {
            assert_eq!(a.usize_in(0, 1000), b.usize_in(0, 1000));
        }
    }

    #[test]
    fn divisible_invariant() {
        property(50, |g| {
            let (n, b) = g.divisible(4, 64, 8);
            assert_eq!(n % b, 0);
            assert!(b <= 8);
        });
    }
}
