//! Typed engine configuration (`EngineConfig`).
//!
//! Every runtime knob the engine reads used to be an ad-hoc
//! `std::env::var("BLAST_*")` parse scattered across the subsystems
//! (threading in `util::par`, SIMD selection in `kernels::micro`, trace
//! mode in `obs::trace`, ...). `EngineConfig` centralizes them: the
//! struct's `Default` holds the code defaults, [`EngineConfig::from_env`]
//! applies the `BLAST_*` environment overrides on top, and
//! [`EngineConfig::global`] resolves that once per process and hands out
//! a `'static` reference. The env variables still win — they are now an
//! *override layer* over one typed struct rather than ten independent
//! parsers — and the serving tier can carry an explicitly constructed
//! `EngineConfig` through `CoordinatorConfig` (tests do this to pin KV
//! block geometry without touching the process environment).
//!
//! `global()` is resolved lazily at first access, so tests that set env
//! vars in `main`/ctor code before touching the engine keep working.
//! The full knob table lives in the README ("Engine configuration").

use std::sync::OnceLock;

/// SIMD path preference (`BLAST_SIMD`). Mapped onto
/// `kernels::micro::SimdMode` by the microkernel at startup.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SimdPref {
    /// Use the widest path the CPU supports (default).
    Auto,
    /// Force the AVX2 path (panics at dispatch if unsupported).
    Avx2,
    /// Force the portable fixed-lane path.
    Portable,
}

/// Request-trace mode (`BLAST_TRACE`). Mapped onto `obs::trace`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TracePref {
    /// No tracing (default).
    Off,
    /// Trace serving requests (enqueue/admit/prefill/step/retire).
    Serve,
    /// Trace everything the subsystems emit.
    All,
}

/// All engine knobs, resolved once. Fields use `Option` where `None`
/// means "subsystem default" so a default-constructed config never has
/// to know constants owned elsewhere (e.g. the pack-cache budget).
#[derive(Clone, Debug, PartialEq)]
pub struct EngineConfig {
    /// Worker threads for data-parallel kernels; `0` = one per core
    /// (`BLAST_NUM_THREADS`).
    pub num_threads: usize,
    /// SIMD path selection (`BLAST_SIMD`).
    pub simd: SimdPref,
    /// Force a specific matmul kernel by name, bypassing the autotuner
    /// (`BLAST_KERNEL`).
    pub kernel_force: Option<String>,
    /// Pack-cache budget in MiB; `None` = built-in default
    /// (`BLAST_PACK_CACHE_MB`).
    pub pack_cache_mb: Option<usize>,
    /// Autotune-table persistence path (`BLAST_AUTOTUNE_CACHE`).
    pub autotune_cache: Option<String>,
    /// Request tracing mode (`BLAST_TRACE`).
    pub trace: TracePref,
    /// Plan-profiler sampling period; `None` = built-in default
    /// (`BLAST_PROF_SAMPLE`).
    pub prof_sample: Option<u64>,
    /// Metrics JSON snapshot path written on bench/CLI exit
    /// (`BLAST_METRICS_OUT`).
    pub metrics_out: Option<String>,
    /// Shrink bench workloads to CI-smoke size (`BLAST_BENCH_FAST=1`).
    pub bench_fast: bool,
    /// Base seed for the property-test harness; `None` = built-in
    /// default (`BLAST_PROP_SEED`).
    pub prop_seed: Option<u64>,
    /// Maximum concurrently active sequences per serving worker
    /// (`BLAST_SLOTS`; the name survives from the slotted-pool era).
    pub max_seqs: usize,
    /// Admission burst width per scheduling step (`BLAST_MAX_BATCH`).
    pub max_batch: usize,
    /// Tokens per KV block in the paged block manager (`BLAST_KV_BLOCK`).
    pub kv_block_size: usize,
    /// Extra KV blocks kept beyond `max_seqs × ceil(max_seq/block)` as
    /// prefix-cache headroom (`BLAST_KV_CACHE_BLOCKS`).
    pub kv_cache_blocks: usize,
    /// Override the **total** KV block arena size, replacing the
    /// `max_seqs × ceil(max_seq/block) + kv_cache_blocks` sizing rule
    /// (`BLAST_KV_BLOCKS_TOTAL`). `None` = derived sizing. An
    /// undersized arena is how KV pressure — and therefore preemption —
    /// is provoked; the derived sizing can never starve admission.
    pub kv_total_blocks: Option<usize>,
    /// Bound on the per-worker pending queue; arrivals beyond it are
    /// shed with `ServeError::Overloaded` (`BLAST_MAX_PENDING`).
    pub max_pending: usize,
    /// Consecutive scheduling steps the queue head may starve on KV
    /// blocks before the youngest active sequence is preempted to make
    /// room; `0` disables preemption (`BLAST_PREEMPT_AFTER`).
    pub preempt_after: usize,
    /// Speculation depth: draft-proposed tokens per verify step
    /// (`BLAST_SPEC_GAMMA`). `0` disables speculative decoding (the
    /// default — it needs a draft model to be worthwhile).
    pub spec_gamma: usize,
    /// Draft model for speculative decoding (`BLAST_SPEC_DRAFT`):
    /// a `.bmx` checkpoint path, or the literal `"self"` to draft with
    /// a clone of the target (useful for tests/benches — acceptance is
    /// ~100% since draft ≡ target). `None` = no draft; speculation
    /// stays off even if `spec_gamma > 0`.
    pub spec_draft: Option<String>,
    /// Failpoint spec, `site=action[prob][count],...`
    /// (`BLAST_FAILPOINTS`); `None` = fault injection disarmed.
    pub failpoints: Option<String>,
    /// Base seed for the deterministic per-site failpoint probability
    /// streams (`BLAST_FAILPOINT_SEED`).
    pub failpoint_seed: u64,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            num_threads: 0,
            simd: SimdPref::Auto,
            kernel_force: None,
            pack_cache_mb: None,
            autotune_cache: None,
            trace: TracePref::Off,
            prof_sample: None,
            metrics_out: None,
            bench_fast: false,
            prop_seed: None,
            max_seqs: 8,
            max_batch: 8,
            kv_block_size: 16,
            kv_cache_blocks: 32,
            kv_total_blocks: None,
            max_pending: 256,
            preempt_after: 4,
            spec_gamma: 0,
            spec_draft: None,
            failpoints: None,
            failpoint_seed: 0xB1A57,
        }
    }
}

fn env_nonempty(name: &str) -> Option<String> {
    std::env::var(name).ok().filter(|s| !s.is_empty())
}

fn env_parse<T: std::str::FromStr>(name: &str) -> Option<T> {
    env_nonempty(name).and_then(|s| s.parse().ok())
}

impl EngineConfig {
    /// Code defaults with `BLAST_*` environment overrides applied.
    /// Unparseable values fall back to the default for that knob (the
    /// same forgiving behaviour the old per-site parsers had).
    pub fn from_env() -> Self {
        let mut cfg = EngineConfig::default();
        if let Some(n) = env_parse::<usize>("BLAST_NUM_THREADS") {
            cfg.num_threads = n;
        }
        if let Some(s) = env_nonempty("BLAST_SIMD") {
            cfg.simd = match s.as_str() {
                "avx2" => SimdPref::Avx2,
                "portable" | "scalar" => SimdPref::Portable,
                _ => SimdPref::Auto,
            };
        }
        cfg.kernel_force = env_nonempty("BLAST_KERNEL");
        cfg.pack_cache_mb = env_parse::<usize>("BLAST_PACK_CACHE_MB");
        cfg.autotune_cache = env_nonempty("BLAST_AUTOTUNE_CACHE");
        if let Some(s) = env_nonempty("BLAST_TRACE") {
            cfg.trace = match s.as_str() {
                "serve" => TracePref::Serve,
                "all" => TracePref::All,
                _ => TracePref::Off,
            };
        }
        cfg.prof_sample = env_parse::<u64>("BLAST_PROF_SAMPLE");
        cfg.metrics_out = env_nonempty("BLAST_METRICS_OUT");
        cfg.bench_fast = env_nonempty("BLAST_BENCH_FAST").as_deref() == Some("1");
        cfg.prop_seed = env_parse::<u64>("BLAST_PROP_SEED");
        if let Some(n) = env_parse::<usize>("BLAST_SLOTS") {
            cfg.max_seqs = n.max(1);
        }
        if let Some(n) = env_parse::<usize>("BLAST_MAX_BATCH") {
            cfg.max_batch = n.max(1);
        }
        if let Some(n) = env_parse::<usize>("BLAST_KV_BLOCK") {
            cfg.kv_block_size = n.max(1);
        }
        if let Some(n) = env_parse::<usize>("BLAST_KV_CACHE_BLOCKS") {
            cfg.kv_cache_blocks = n;
        }
        if let Some(n) = env_parse::<usize>("BLAST_KV_BLOCKS_TOTAL") {
            cfg.kv_total_blocks = Some(n.max(1));
        }
        if let Some(n) = env_parse::<usize>("BLAST_MAX_PENDING") {
            cfg.max_pending = n.max(1);
        }
        if let Some(n) = env_parse::<usize>("BLAST_PREEMPT_AFTER") {
            cfg.preempt_after = n;
        }
        if let Some(n) = env_parse::<usize>("BLAST_SPEC_GAMMA") {
            cfg.spec_gamma = n;
        }
        cfg.spec_draft = env_nonempty("BLAST_SPEC_DRAFT");
        cfg.failpoints = env_nonempty("BLAST_FAILPOINTS");
        if let Some(n) = env_parse::<u64>("BLAST_FAILPOINT_SEED") {
            cfg.failpoint_seed = n;
        }
        cfg
    }

    /// The process-wide config: resolved from the environment on first
    /// access, then immutable. Subsystems that keep their own cached
    /// copies (thread count, SIMD mode, trace mode) read through this,
    /// so env vars must be set before the engine is first touched —
    /// the same contract the old per-site `OnceLock`s enforced.
    pub fn global() -> &'static EngineConfig {
        static G: OnceLock<EngineConfig> = OnceLock::new();
        G.get_or_init(EngineConfig::from_env)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_sane() {
        let cfg = EngineConfig::default();
        assert_eq!(cfg.num_threads, 0);
        assert_eq!(cfg.simd, SimdPref::Auto);
        assert_eq!(cfg.trace, TracePref::Off);
        assert!(cfg.kernel_force.is_none());
        assert!(cfg.pack_cache_mb.is_none());
        assert!(cfg.max_seqs >= 1 && cfg.max_batch >= 1);
        assert!(cfg.kv_block_size >= 1);
        assert!(cfg.kv_total_blocks.is_none());
        assert!(cfg.max_pending >= 1);
        assert_eq!(cfg.spec_gamma, 0, "speculation is opt-in");
        assert!(cfg.spec_draft.is_none());
        assert!(cfg.failpoints.is_none());
    }

    #[test]
    fn explicit_override_is_plain_data() {
        // Tests pin geometry by building the struct directly — no env.
        let cfg = EngineConfig { kv_block_size: 4, max_seqs: 2, ..EngineConfig::default() };
        assert_eq!(cfg.kv_block_size, 4);
        assert_eq!(cfg.max_seqs, 2);
        assert_eq!(cfg.kv_cache_blocks, EngineConfig::default().kv_cache_blocks);
    }

    #[test]
    fn global_is_stable() {
        let a = EngineConfig::global();
        let b = EngineConfig::global();
        assert!(std::ptr::eq(a, b));
        assert_eq!(a, b);
    }
}
