//! Deterministic fault injection (`fail_point!` sites).
//!
//! A failpoint is a named site in engine code — `fail_point!("kv.alloc")`
//! — that is a **no-op unless armed**: the fast path is one relaxed
//! atomic load of a process-global `ARMED` flag, so leaving the sites in
//! release builds costs nothing measurable. Arming happens either from
//! the environment (`BLAST_FAILPOINTS` through
//! [`EngineConfig`](crate::util::config::EngineConfig), parsed lazily on
//! the first site evaluation) or programmatically from tests via
//! [`configure`] / [`clear`].
//!
//! ## Spec grammar
//!
//! ```text
//! BLAST_FAILPOINTS = entry ("," entry)*
//! entry            = site "=" action [ "[" prob "]" [ "[" count "]" ] ]
//! action           = "fail" | "panic" | "sleep:" millis
//! ```
//!
//! * `fail` — the site's `fail_point!` evaluates *triggered*: the caller
//!   runs its failure arm (e.g. `kv.alloc=fail` makes `admit` report
//!   out-of-blocks).
//! * `panic` — panic at the site (exercises the worker's `catch_unwind`
//!   isolation).
//! * `sleep:MS` — block the site for `MS` milliseconds (deadline and
//!   queue-timeout testing), then continue untriggered.
//! * `prob` — fire probability in `[0, 1]` (default `1.0`). The roll is
//!   a per-site xorshift64 stream seeded from `failpoint_seed` mixed
//!   with an FNV-1a hash of the site name, so a given
//!   `(seed, site, call-index)` always rolls the same way — chaos runs
//!   are reproducible.
//! * `count` — maximum number of fires (default unlimited); after that
//!   the site goes quiet.
//!
//! Example: `BLAST_FAILPOINTS=kv.alloc=fail[0.5][20],model.step=panic[0.1]`.
//!
//! Every fire increments the obs counter `failpoint_triggers` and a
//! per-site cumulative count readable via [`triggered`] (which survives
//! [`clear`], so tests can disarm and then assert on what fired).

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Mutex, Once, OnceLock};

/// What an armed site does when its probability roll fires.
#[derive(Clone, Copy, Debug, PartialEq)]
enum Action {
    /// Report "triggered" to the caller (it runs its failure arm).
    Fail,
    /// Panic at the site.
    Panic,
    /// Sleep this many milliseconds, then report "not triggered".
    Sleep(u64),
}

#[derive(Debug)]
struct Site {
    action: Action,
    /// Fire probability in [0, 1].
    prob: f64,
    /// Remaining fires; `None` = unlimited.
    remaining: Option<u64>,
    /// Per-site xorshift64 state (deterministic probability stream).
    rng: u64,
}

/// Fast-path arm flag: one relaxed load when no failpoints are
/// configured (the common case — production and every non-chaos test).
static ARMED: AtomicBool = AtomicBool::new(false);

/// One-time environment arming (`BLAST_FAILPOINTS` via `EngineConfig`).
static ENV_INIT: Once = Once::new();

fn sites() -> &'static Mutex<HashMap<String, Site>> {
    static S: OnceLock<Mutex<HashMap<String, Site>>> = OnceLock::new();
    S.get_or_init(|| Mutex::new(HashMap::new()))
}

/// Cumulative per-site fire counts; survives [`clear`] so tests can
/// disarm first and assert afterwards.
fn fired() -> &'static Mutex<HashMap<String, u64>> {
    static F: OnceLock<Mutex<HashMap<String, u64>>> = OnceLock::new();
    F.get_or_init(|| Mutex::new(HashMap::new()))
}

/// Lock helper tolerant of poisoning: a panic-action site panics *after*
/// releasing the lock, but a chaos test thread dying elsewhere while
/// holding it must not wedge every later evaluation.
fn lock<T>(m: &Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

/// FNV-1a over the site name: mixes the name into the seed so distinct
/// sites get distinct deterministic probability streams.
fn fnv1a(s: &str) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for b in s.as_bytes() {
        h ^= *b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

fn xorshift64(state: &mut u64) -> u64 {
    let mut x = *state;
    x ^= x << 13;
    x ^= x >> 7;
    x ^= x << 17;
    *state = x;
    x
}

/// Parse one `site=action[prob][count]` entry. Returns `None` (entry
/// ignored, matching `EngineConfig`'s forgiving parse policy) on
/// malformed input.
fn parse_entry(entry: &str, seed: u64) -> Option<(String, Site)> {
    let (site, rest) = entry.split_once('=')?;
    let site = site.trim();
    if site.is_empty() {
        return None;
    }
    let mut rest = rest.trim();
    // Peel trailing [..] groups off the action, innermost-last:
    // action[prob][count] | action[prob] | action.
    let mut brackets: Vec<&str> = Vec::new();
    while let Some(open) = rest.rfind('[') {
        if !rest.ends_with(']') {
            return None;
        }
        brackets.push(&rest[open + 1..rest.len() - 1]);
        rest = rest[..open].trim_end();
    }
    brackets.reverse(); // now [prob, count] order
    if brackets.len() > 2 {
        return None;
    }
    let prob = match brackets.first() {
        Some(p) => {
            let p: f64 = p.trim().parse().ok()?;
            if !(0.0..=1.0).contains(&p) {
                return None;
            }
            p
        }
        None => 1.0,
    };
    let remaining = match brackets.get(1) {
        Some(c) => Some(c.trim().parse::<u64>().ok()?),
        None => None,
    };
    let action = if rest == "fail" {
        Action::Fail
    } else if rest == "panic" {
        Action::Panic
    } else if let Some(ms) = rest.strip_prefix("sleep:") {
        Action::Sleep(ms.trim().parse().ok()?)
    } else {
        return None;
    };
    // Seed the per-site stream; xorshift needs nonzero state.
    let rng = (seed ^ fnv1a(site)) | 1;
    Some((site.to_string(), Site { action, prob, remaining, rng }))
}

/// Arm the registry from a spec string (replaces any previous
/// configuration). Sites are seeded from the engine's `failpoint_seed`,
/// so the same spec + seed reproduces the same fire pattern.
pub fn configure(spec: &str) {
    // Make sure a later first-eval doesn't stomp a test's explicit
    // configuration with the (empty) environment one.
    ENV_INIT.call_once(|| {});
    let seed = crate::util::config::EngineConfig::global().failpoint_seed;
    let mut map = HashMap::new();
    for entry in spec.split(',') {
        let entry = entry.trim();
        if entry.is_empty() {
            continue;
        }
        if let Some((name, site)) = parse_entry(entry, seed) {
            map.insert(name, site);
        }
    }
    let armed = !map.is_empty();
    *lock(sites()) = map;
    ARMED.store(armed, Ordering::Relaxed);
}

/// Disarm every site (fire counts are retained — see [`triggered`]).
pub fn clear() {
    ENV_INIT.call_once(|| {});
    lock(sites()).clear();
    ARMED.store(false, Ordering::Relaxed);
}

/// Cumulative fires for a site across the process lifetime (including
/// sites since [`clear`]ed or reconfigured).
pub fn triggered(site: &str) -> u64 {
    lock(fired()).get(site).copied().unwrap_or(0)
}

/// Evaluate a failpoint site. Returns `true` when the site fired with
/// the `fail` action (callers run their failure arm); `panic` fires
/// unwind from inside, and `sleep` delays then returns `false`. Not
/// armed / unknown site / probability roll missed: `false`.
pub fn eval(site: &str) -> bool {
    ENV_INIT.call_once(|| {
        let cfg = crate::util::config::EngineConfig::global();
        if let Some(spec) = &cfg.failpoints {
            configure(spec);
        }
    });
    if !ARMED.load(Ordering::Relaxed) {
        return false;
    }
    eval_slow(site)
}

#[cold]
fn eval_slow(site: &str) -> bool {
    let action = {
        let mut map = lock(sites());
        let Some(s) = map.get_mut(site) else { return false };
        if s.remaining == Some(0) {
            return false;
        }
        if s.prob < 1.0 {
            // 53-bit uniform in [0, 1).
            let roll = (xorshift64(&mut s.rng) >> 11) as f64 / (1u64 << 53) as f64;
            if roll >= s.prob {
                return false;
            }
        }
        if let Some(n) = &mut s.remaining {
            *n -= 1;
        }
        s.action
        // Lock drops here: panic/sleep must not poison or hold it.
    };
    *lock(fired()).entry(site.to_string()).or_insert(0) += 1;
    crate::obs::well_known::failpoint_triggers().inc();
    match action {
        Action::Fail => true,
        Action::Sleep(ms) => {
            std::thread::sleep(std::time::Duration::from_millis(ms));
            false
        }
        Action::Panic => panic!("failpoint '{site}' injected panic"),
    }
}

/// Evaluate a failpoint site by name. Expands to a plain `eval` call —
/// the no-op-unless-armed check is one relaxed atomic load. The
/// two-argument form runs `$on_fail` (e.g. `return None`) when the site
/// fires with the `fail` action.
#[macro_export]
macro_rules! fail_point {
    ($name:expr) => {
        $crate::util::failpoint::eval($name);
    };
    ($name:expr, $on_fail:expr) => {
        if $crate::util::failpoint::eval($name) {
            $on_fail;
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The registry is process-global; serialize these tests against
    /// each other (other suites never arm these site names).
    fn guard() -> std::sync::MutexGuard<'static, ()> {
        static G: OnceLock<Mutex<()>> = OnceLock::new();
        lock(G.get_or_init(|| Mutex::new(())))
    }

    #[test]
    fn unarmed_site_is_noop() {
        let _g = guard();
        clear();
        assert!(!eval("fp.test.unarmed"));
    }

    #[test]
    fn fail_action_fires_and_counts() {
        let _g = guard();
        configure("fp.test.fail=fail");
        let before = triggered("fp.test.fail");
        assert!(eval("fp.test.fail"));
        assert!(eval("fp.test.fail"));
        clear();
        assert_eq!(triggered("fp.test.fail"), before + 2, "counts survive clear");
        assert!(!eval("fp.test.fail"), "disarmed after clear");
    }

    #[test]
    fn count_limit_exhausts() {
        let _g = guard();
        configure("fp.test.count=fail[1][3]");
        let fires = (0..10).filter(|_| eval("fp.test.count")).count();
        clear();
        assert_eq!(fires, 3);
    }

    #[test]
    fn probability_stream_is_deterministic() {
        let _g = guard();
        configure("fp.test.prob=fail[0.5][1000]");
        let a: Vec<bool> = (0..64).map(|_| eval("fp.test.prob")).collect();
        // Re-configuring reseeds the site: the same stream replays.
        configure("fp.test.prob=fail[0.5][1000]");
        let b: Vec<bool> = (0..64).map(|_| eval("fp.test.prob")).collect();
        clear();
        assert_eq!(a, b);
        let fires = a.iter().filter(|x| **x).count();
        assert!(fires > 10 && fires < 54, "p=0.5 over 64 rolls fired {fires}");
    }

    #[test]
    fn sleep_action_delays_without_firing_caller_arm() {
        let _g = guard();
        configure("fp.test.sleep=sleep:5");
        let t0 = std::time::Instant::now();
        assert!(!eval("fp.test.sleep"), "sleep evaluates untriggered");
        clear();
        assert!(t0.elapsed() >= std::time::Duration::from_millis(5));
    }

    #[test]
    fn malformed_entries_are_ignored() {
        let _g = guard();
        configure("nonsense,fp.test.bad=explode,=fail,fp.test.badprob=fail[2.0]");
        assert!(!eval("fp.test.bad"));
        assert!(!eval("fp.test.badprob"));
        // A well-formed entry among garbage still arms.
        configure("garbage,fp.test.ok=fail");
        assert!(eval("fp.test.ok"));
        clear();
    }

    #[test]
    fn parse_spec_shapes() {
        let (name, s) = parse_entry("a.b=sleep:25[0.25][7]", 1).unwrap();
        assert_eq!(name, "a.b");
        assert_eq!(s.action, Action::Sleep(25));
        assert!((s.prob - 0.25).abs() < 1e-12);
        assert_eq!(s.remaining, Some(7));
        assert!(parse_entry("a.b=fail[0.5][x]", 1).is_none());
        assert!(parse_entry("a.b=fail[0.5][1][2]", 1).is_none());
        assert!(parse_entry("a.b", 1).is_none());
    }
}
