//! Synthetic language corpus: a Zipf-weighted first-order Markov grammar
//! mixed with deterministic "skill" segments (arithmetic counting,
//! copying, alternation). The Markov component gives the LM distributional
//! structure to model (so perplexity differences between weight
//! structures are meaningful); the skill segments give the zero-shot
//! tasks something the re-trained models must preserve.

use crate::tensor::Rng;

/// Special tokens at the top of the vocabulary.
pub const BOS: usize = 0;

/// A generated corpus with train/validation splits.
#[derive(Clone, Debug)]
pub struct SyntheticCorpus {
    pub vocab: usize,
    pub train: Vec<usize>,
    pub valid: Vec<usize>,
}

impl SyntheticCorpus {
    /// Generate a corpus with `vocab` tokens, `train_len` training tokens
    /// and `valid_len` validation tokens. Deterministic for a vocab size
    /// (internal fixed seed) so experiments are comparable across runs.
    pub fn generate(vocab: usize, train_len: usize, valid_len: usize) -> Self {
        assert!(vocab >= 16, "vocab too small");
        let mut rng = Rng::new(0xC0FFEE ^ vocab as u64);
        // Build a sparse row-stochastic Markov transition table with
        // Zipf-ish stationary mass.
        let fanout = 4usize;
        let table: Vec<Vec<(usize, f32)>> = (0..vocab)
            .map(|s| {
                let mut row = Vec::with_capacity(fanout);
                for k in 0..fanout {
                    // Deterministic successors with steeply decaying weight
                    // (steep enough that bigram statistics are learnable).
                    let succ = (s * 7 + k * 13 + 1) % vocab;
                    let w = 1.0 / ((k + 1) * (k + 1)) as f32;
                    row.push((succ, w));
                }
                // One random successor for entropy.
                row.push((rng.below(vocab), 0.1));
                row
            })
            .collect();

        let mut gen = |len: usize, rng: &mut Rng| -> Vec<usize> {
            let mut out = Vec::with_capacity(len);
            let mut state = BOS;
            while out.len() < len {
                // Occasionally emit a deterministic skill segment (kept
                // rare so Markov statistics dominate the bigram table).
                if rng.uniform() < 0.06 && out.len() + 8 < len {
                    match rng.below(3) {
                        0 => {
                            // counting: t, t+1, t+2, t+3
                            let start = 1 + rng.below(vocab - 8);
                            for d in 0..4 {
                                out.push((start + d) % vocab);
                            }
                        }
                        1 => {
                            // copy pattern: a b a b
                            let a = 1 + rng.below(vocab - 2);
                            let b = 1 + rng.below(vocab - 2);
                            out.extend_from_slice(&[a, b, a, b]);
                        }
                        _ => {
                            // descent: t, t-1, t-2, t-3
                            let start = 8 + rng.below(vocab - 9);
                            for d in 0..4 {
                                out.push(start - d);
                            }
                        }
                    }
                    state = *out.last().unwrap();
                } else {
                    let row = &table[state];
                    let weights: Vec<f32> = row.iter().map(|(_, w)| *w).collect();
                    let pick = rng.categorical(&weights);
                    state = row[pick].0;
                    out.push(state);
                }
            }
            out.truncate(len);
            out
        };

        let train = gen(train_len, &mut rng);
        let valid = gen(valid_len, &mut rng);
        SyntheticCorpus { vocab, train, valid }
    }

    pub fn train_dataset(&self) -> LmDataset {
        LmDataset { tokens: self.train.clone() }
    }

    pub fn valid_dataset(&self) -> LmDataset {
        LmDataset { tokens: self.valid.clone() }
    }
}

/// A flat token stream with sequence sampling.
#[derive(Clone, Debug)]
pub struct LmDataset {
    pub tokens: Vec<usize>,
}

impl LmDataset {
    pub fn len(&self) -> usize {
        self.tokens.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tokens.is_empty()
    }

    /// Random-crop batcher.
    pub fn batcher(&self, seq_len: usize, seed: u64) -> Batcher<'_> {
        Batcher { data: self, seq_len, rng: Rng::new(seed) }
    }

    /// Deterministic non-overlapping evaluation windows.
    pub fn eval_windows(&self, seq_len: usize) -> Vec<&[usize]> {
        self.tokens.chunks_exact(seq_len).collect()
    }
}

/// Samples random training sequences.
pub struct Batcher<'a> {
    data: &'a LmDataset,
    seq_len: usize,
    rng: Rng,
}

impl Batcher<'_> {
    pub fn next_sequence(&mut self) -> Vec<usize> {
        let max_start = self.data.tokens.len().saturating_sub(self.seq_len + 1);
        let start = if max_start == 0 { 0 } else { self.rng.below(max_start) };
        self.data.tokens[start..(start + self.seq_len).min(self.data.tokens.len())].to_vec()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn corpus_shape_and_range() {
        let c = SyntheticCorpus::generate(64, 5000, 1000);
        assert_eq!(c.train.len(), 5000);
        assert_eq!(c.valid.len(), 1000);
        assert!(c.train.iter().all(|&t| t < 64));
    }

    #[test]
    fn corpus_deterministic() {
        let a = SyntheticCorpus::generate(64, 1000, 100);
        let b = SyntheticCorpus::generate(64, 1000, 100);
        assert_eq!(a.train, b.train);
    }

    #[test]
    fn corpus_nontrivial_entropy() {
        // Unigram distribution should be non-uniform but not degenerate.
        let c = SyntheticCorpus::generate(64, 20_000, 100);
        let mut counts = vec![0usize; 64];
        for &t in &c.train {
            counts[t] += 1;
        }
        let nonzero = counts.iter().filter(|&&c| c > 0).count();
        assert!(nonzero > 40, "only {nonzero} distinct tokens");
        let max = *counts.iter().max().unwrap();
        assert!(max < c.train.len() / 4, "one token dominates");
    }

    #[test]
    fn markov_structure_learnable() {
        // Bigram predictability: the most frequent successor of a state
        // should capture a large share (skill segments + fanout-1 weight).
        let c = SyntheticCorpus::generate(64, 50_000, 100);
        let mut next_counts = vec![vec![0usize; 64]; 64];
        for w in c.train.windows(2) {
            next_counts[w[0]][w[1]] += 1;
        }
        let mut top_share = 0.0f64;
        let mut states = 0usize;
        for s in 0..64 {
            let total: usize = next_counts[s].iter().sum();
            if total < 50 {
                continue;
            }
            let top = *next_counts[s].iter().max().unwrap();
            top_share += top as f64 / total as f64;
            states += 1;
        }
        top_share /= states as f64;
        assert!(top_share > 0.25, "bigram top-share {top_share} too uniform");
    }

    #[test]
    fn batcher_sequences_valid() {
        let c = SyntheticCorpus::generate(32, 1000, 100);
        let d = c.train_dataset();
        let mut b = d.batcher(16, 1);
        for _ in 0..10 {
            let s = b.next_sequence();
            assert_eq!(s.len(), 16);
            assert!(s.iter().all(|&t| t < 32));
        }
    }

    #[test]
    fn eval_windows_cover() {
        let c = SyntheticCorpus::generate(32, 1000, 256);
        let d = c.valid_dataset();
        let w = d.eval_windows(32);
        assert_eq!(w.len(), 8);
        assert!(w.iter().all(|s| s.len() == 32));
    }
}
