//! Synthetic image-classification dataset: 10 procedurally generated
//! texture classes on 16×16 single-channel images. Class identity is
//! carried by spatial frequency / orientation / pattern family, with
//! per-sample phase, amplitude jitter, and additive noise — enough
//! intra-class variance that a linear probe cannot solve it but a small
//! ViT can (standing in for CIFAR-10 in Fig. 4 / Table 1).

use crate::tensor::Rng;

/// One labeled image.
#[derive(Clone, Debug)]
pub struct LabeledImage {
    pub pixels: Vec<f32>,
    pub label: usize,
}

/// Generator for the texture dataset.
#[derive(Clone, Debug)]
pub struct TextureDataset {
    pub img: usize,
    pub n_classes: usize,
}

impl TextureDataset {
    pub fn new(img: usize, n_classes: usize) -> Self {
        assert!(n_classes <= 10, "at most 10 texture families defined");
        TextureDataset { img, n_classes }
    }

    /// Render one sample of `class` with the given RNG.
    pub fn sample(&self, class: usize, rng: &mut Rng) -> LabeledImage {
        let n = self.img;
        // Modest jitter: enough intra-class variance to defeat a nearest-
        // centroid classifier, small enough that class geometry dominates.
        let phase = rng.uniform_range(0.0, 0.6);
        let amp = rng.uniform_range(0.85, 1.15);
        let noise = 0.10f32;
        let mut pixels = vec![0.0f32; n * n];
        for i in 0..n {
            for j in 0..n {
                let x = i as f32 / n as f32 * std::f32::consts::TAU;
                let y = j as f32 / n as f32 * std::f32::consts::TAU;
                let v = match class {
                    0 => (2.0 * x + phase).sin(),                    // horizontal stripes
                    1 => (2.0 * y + phase).sin(),                    // vertical stripes
                    2 => (2.0 * (x + y) + phase).sin(),              // diagonal
                    3 => (2.0 * x + phase).sin() * (2.0 * y).sin(),  // checker
                    4 => (4.0 * x + phase).sin(),                    // high-freq horizontal
                    5 => (4.0 * y + phase).sin(),                    // high-freq vertical
                    6 => ((x - std::f32::consts::PI).powi(2)
                        + (y - std::f32::consts::PI).powi(2))
                    .sqrt()
                    .sin(),                                          // rings
                    7 => ((2.0 * x).sin() + (3.0 * y).sin()) * 0.5,  // plaid
                    8 => (x * y / std::f32::consts::PI + phase).sin(), // hyperbolic
                    _ => ((3.0 * (x - y)) + phase).sin(),            // anti-diagonal
                };
                pixels[i * n + j] = amp * v + noise * rng.gaussian();
            }
        }
        LabeledImage { pixels, label: class }
    }

    /// A balanced batch of labeled samples.
    pub fn batch(&self, per_class: usize, rng: &mut Rng) -> Vec<LabeledImage> {
        let mut out = Vec::with_capacity(per_class * self.n_classes);
        for c in 0..self.n_classes {
            for _ in 0..per_class {
                out.push(self.sample(c, rng));
            }
        }
        rng.shuffle(&mut out);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sample_shape_and_label() {
        let ds = TextureDataset::new(16, 10);
        let mut rng = Rng::new(600);
        let s = ds.sample(3, &mut rng);
        assert_eq!(s.pixels.len(), 256);
        assert_eq!(s.label, 3);
        assert!(s.pixels.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn classes_statistically_distinct() {
        // Mean inter-class L2 distance must exceed intra-class distance.
        let ds = TextureDataset::new(16, 4);
        let mut rng = Rng::new(601);
        let per = 8;
        let samples: Vec<Vec<LabeledImage>> = (0..4)
            .map(|c| (0..per).map(|_| ds.sample(c, &mut rng)).collect())
            .collect();
        let dist = |a: &[f32], b: &[f32]| -> f64 {
            a.iter().zip(b).map(|(x, y)| ((x - y) as f64).powi(2)).sum::<f64>()
        };
        let mut intra = 0.0;
        let mut intra_n = 0;
        let mut inter = 0.0;
        let mut inter_n = 0;
        for c1 in 0..4 {
            for i in 0..per {
                for c2 in 0..4 {
                    for j in 0..per {
                        if c1 == c2 && i < j {
                            intra += dist(&samples[c1][i].pixels, &samples[c2][j].pixels);
                            intra_n += 1;
                        } else if c1 < c2 {
                            inter += dist(&samples[c1][i].pixels, &samples[c2][j].pixels);
                            inter_n += 1;
                        }
                    }
                }
            }
        }
        let intra = intra / intra_n as f64;
        let inter = inter / inter_n as f64;
        assert!(
            inter > intra * 1.2,
            "classes not separable: inter {inter} vs intra {intra}"
        );
    }

    #[test]
    fn batch_balanced_and_shuffled() {
        let ds = TextureDataset::new(16, 5);
        let mut rng = Rng::new(602);
        let b = ds.batch(3, &mut rng);
        assert_eq!(b.len(), 15);
        let mut counts = vec![0usize; 5];
        for s in &b {
            counts[s.label] += 1;
        }
        assert!(counts.iter().all(|&c| c == 3));
        // Shuffled: labels not in sorted blocks.
        let labels: Vec<usize> = b.iter().map(|s| s.label).collect();
        let sorted = {
            let mut l = labels.clone();
            l.sort();
            l
        };
        assert_ne!(labels, sorted);
    }
}
