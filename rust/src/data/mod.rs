//! Synthetic workloads standing in for the paper's datasets (see
//! DESIGN.md §3 for the substitution rationale):
//!
//! * [`corpus`] — Markov-grammar + sequence-task corpus ↔ WikiText /
//!   SlimPajama;
//! * [`images`] — procedurally generated class-conditional textures ↔
//!   CIFAR / ImageNet classification;
//! * [`diffusion`] — class-conditional structured 8×8 images ↔ the
//!   ImageNet-256 generation task of Table 2;
//! * [`zeroshot`] — choice-scoring task suites ↔ PIQA/HellaSwag/etc.

pub mod corpus;
pub mod images;
pub mod diffusion;
pub mod zeroshot;
