//! Zero-shot choice-scoring task suites (standing in for PIQA, HellaSwag,
//! WinoGrande, BoolQ, OBQA, ARC-e, ARC-c in Tables 3/12/13).
//!
//! Each task instance is a prompt plus K continuation choices; the model
//! scores each choice by length-normalized log-likelihood and picks the
//! argmax — the exact scoring protocol of lm-eval-harness. Tasks are
//! built from the same Markov/skill statistics as the training corpus so
//! a well-trained LM materially beats chance, and compression-induced
//! damage shows up as accuracy loss.

use crate::data::corpus::SyntheticCorpus;
use crate::tensor::Rng;

/// One multiple-choice instance.
#[derive(Clone, Debug)]
pub struct ChoiceTask {
    pub prompt: Vec<usize>,
    pub choices: Vec<Vec<usize>>,
    pub answer: usize,
}

/// A named suite of instances.
#[derive(Clone, Debug)]
pub struct TaskSuite {
    pub name: String,
    pub tasks: Vec<ChoiceTask>,
}

/// Build the 7 task suites over a corpus's statistics.
pub fn build_suites(corpus: &SyntheticCorpus, per_suite: usize) -> Vec<TaskSuite> {
    let vocab = corpus.vocab;
    let mut rng = Rng::new(0x5EED);

    // Empirical bigram table from the training split (the "world
    // knowledge" the tasks probe).
    let mut bigram = vec![vec![0u32; vocab]; vocab];
    for w in corpus.train.windows(2) {
        bigram[w[0]][w[1]] += 1;
    }
    let top_next = |s: usize| -> usize {
        let row = &bigram[s];
        let mut best = 0;
        for (i, &c) in row.iter().enumerate() {
            if c > row[best] {
                best = i;
            }
        }
        best
    };

    let mut suites = Vec::new();

    // Suite 1 "continuation" (PIQA-like): pick the likely continuation of
    // a corpus fragment.
    suites.push(TaskSuite {
        name: "continuation".into(),
        tasks: (0..per_suite)
            .map(|_| {
                let start = rng.below(corpus.train.len().saturating_sub(8));
                let prompt = corpus.train[start..start + 4].to_vec();
                let state = prompt[3];
                let good = vec![top_next(state)];
                let mut bad = vec![(top_next(state) + vocab / 2) % vocab];
                if bad == good {
                    bad[0] = (bad[0] + 1) % vocab;
                }
                shuffled_choice(prompt, vec![good, bad], &mut rng)
            })
            .collect(),
    });

    // Suite 2 "counting" (ARC-easy-like): continue an ascending run.
    suites.push(TaskSuite {
        name: "counting".into(),
        tasks: (0..per_suite)
            .map(|_| {
                let start = 1 + rng.below(vocab - 8);
                let prompt = vec![start, start + 1, start + 2];
                let good = vec![start + 3];
                let bad = vec![(start + 5) % vocab];
                shuffled_choice(prompt, vec![good, bad], &mut rng)
            })
            .collect(),
    });

    // Suite 3 "descending" (ARC-challenge-like).
    suites.push(TaskSuite {
        name: "descending".into(),
        tasks: (0..per_suite)
            .map(|_| {
                let start = 9 + rng.below(vocab - 10);
                let prompt = vec![start, start - 1, start - 2];
                let good = vec![start - 3];
                let bad = vec![start.saturating_sub(6).max(1)];
                shuffled_choice(prompt, vec![good, bad], &mut rng)
            })
            .collect(),
    });

    // Suite 4 "copy" (WinoGrande-like pattern completion): a b a -> b.
    suites.push(TaskSuite {
        name: "copy".into(),
        tasks: (0..per_suite)
            .map(|_| {
                let a = 1 + rng.below(vocab - 2);
                let mut b = 1 + rng.below(vocab - 2);
                if b == a {
                    b = (b + 1) % vocab.max(2);
                }
                let prompt = vec![a, b, a];
                let good = vec![b];
                let bad = vec![(b + vocab / 3) % vocab];
                shuffled_choice(prompt, vec![good, bad], &mut rng)
            })
            .collect(),
    });

    // Suite 5 "boolq": 2-way likely-vs-unlikely bigram judgment with a
    // longer continuation (2 tokens).
    suites.push(TaskSuite {
        name: "bigram-judge".into(),
        tasks: (0..per_suite)
            .map(|_| {
                let start = rng.below(corpus.train.len().saturating_sub(10));
                let prompt = corpus.train[start..start + 3].to_vec();
                let s = prompt[2];
                let n1 = top_next(s);
                let good = vec![n1, top_next(n1)];
                let bad = vec![(n1 + vocab / 2) % vocab, rng.below(vocab)];
                shuffled_choice(prompt, vec![good, bad], &mut rng)
            })
            .collect(),
    });

    // Suite 6 "obqa": 4-way continuation.
    suites.push(TaskSuite {
        name: "multi-choice".into(),
        tasks: (0..per_suite)
            .map(|_| {
                let start = rng.below(corpus.train.len().saturating_sub(8));
                let prompt = corpus.train[start..start + 4].to_vec();
                let s = prompt[3];
                let good = vec![top_next(s)];
                let mut choices = vec![good];
                for k in 1..4usize {
                    let mut alt = (top_next(s) + k * vocab / 5 + 1) % vocab;
                    if alt == top_next(s) {
                        alt = (alt + 1) % vocab;
                    }
                    choices.push(vec![alt]);
                }
                shuffled_choice(prompt, choices, &mut rng)
            })
            .collect(),
    });

    // Suite 7 "hellaswag": longer prompt, 3-token continuations.
    suites.push(TaskSuite {
        name: "long-continuation".into(),
        tasks: (0..per_suite)
            .map(|_| {
                let start = rng.below(corpus.train.len().saturating_sub(16));
                let prompt = corpus.train[start..start + 6].to_vec();
                let mut s = prompt[5];
                let mut good = Vec::new();
                for _ in 0..3 {
                    s = top_next(s);
                    good.push(s);
                }
                let bad: Vec<usize> = (0..3).map(|_| rng.below(vocab)).collect();
                shuffled_choice(prompt, vec![good, bad], &mut rng)
            })
            .collect(),
    });

    suites.truncate(7);
    suites
}

fn shuffled_choice(prompt: Vec<usize>, mut choices: Vec<Vec<usize>>, rng: &mut Rng) -> ChoiceTask {
    // choices[0] is the gold answer pre-shuffle.
    let gold = choices[0].clone();
    rng.shuffle(&mut choices);
    let answer = choices.iter().position(|c| *c == gold).unwrap();
    ChoiceTask { prompt, choices, answer }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn seven_suites_built() {
        let c = SyntheticCorpus::generate(64, 10_000, 100);
        let suites = build_suites(&c, 20);
        assert_eq!(suites.len(), 7);
        for s in &suites {
            assert_eq!(s.tasks.len(), 20);
            for t in &s.tasks {
                assert!(t.answer < t.choices.len());
                assert!(t.choices.len() >= 2);
                assert!(!t.prompt.is_empty());
                // Gold choice is unique.
                let gold = &t.choices[t.answer];
                assert_eq!(t.choices.iter().filter(|c| *c == gold).count(), 1);
            }
        }
    }

    #[test]
    fn deterministic() {
        let c = SyntheticCorpus::generate(64, 5_000, 100);
        let a = build_suites(&c, 5);
        let b = build_suites(&c, 5);
        assert_eq!(a[0].tasks[0].prompt, b[0].tasks[0].prompt);
        assert_eq!(a[3].tasks[2].answer, b[3].tasks[2].answer);
    }

    #[test]
    fn answers_not_constant() {
        // Shuffling must distribute the gold index.
        let c = SyntheticCorpus::generate(64, 5_000, 100);
        let suites = build_suites(&c, 30);
        for s in &suites {
            let first = s.tasks[0].answer;
            assert!(
                s.tasks.iter().any(|t| t.answer != first),
                "suite {} has constant answer position",
                s.name
            );
        }
    }
}
