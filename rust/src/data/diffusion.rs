//! Synthetic image distribution for the diffusion (DiT) experiment:
//! 4 classes of structured 8×8 images (standing in for class-conditional
//! ImageNet in Table 2). Each class is a smooth parametric family so the
//! denoiser must learn a genuinely multi-modal, class-dependent
//! distribution — the regime where SVD-compression visibly damages
//! generation quality in the paper.

use crate::tensor::Rng;

/// Class-conditional sample generator.
#[derive(Clone, Copy, Debug)]
pub struct DiffusionDataset {
    pub img: usize,
    pub n_classes: usize,
}

impl DiffusionDataset {
    pub fn new(img: usize, n_classes: usize) -> Self {
        assert!(n_classes <= 4);
        DiffusionDataset { img, n_classes }
    }

    /// Draw one clean image x0 from class `c`.
    pub fn sample(&self, c: usize, rng: &mut Rng) -> Vec<f32> {
        let n = self.img;
        let mut out = vec![0.0f32; n * n];
        // Per-sample latent parameters.
        let cx = rng.uniform_range(0.3, 0.7) * n as f32;
        let cy = rng.uniform_range(0.3, 0.7) * n as f32;
        let s = rng.uniform_range(0.8, 1.4);
        for i in 0..n {
            for j in 0..n {
                let dx = (i as f32 - cx) / (n as f32 / 4.0) * s;
                let dy = (j as f32 - cy) / (n as f32 / 4.0) * s;
                out[i * n + j] = match c {
                    // Gaussian blob.
                    0 => (-(dx * dx + dy * dy)).exp() * 1.6 - 0.8,
                    // Ring.
                    1 => {
                        let r = (dx * dx + dy * dy).sqrt();
                        (-(r - 1.2).powi(2) * 4.0).exp() * 1.6 - 0.8
                    }
                    // Vertical bar.
                    2 => (-(dx * dx) * 2.0).exp() * 1.6 - 0.8,
                    // Cross.
                    _ => {
                        let v = (-(dx * dx) * 3.0).exp().max((-(dy * dy) * 3.0).exp());
                        v * 1.6 - 0.8
                    }
                } + 0.05 * rng.gaussian();
            }
        }
        out
    }

    /// A balanced training batch of (image, class) pairs.
    pub fn batch(&self, per_class: usize, rng: &mut Rng) -> Vec<(Vec<f32>, usize)> {
        let mut out = Vec::new();
        for c in 0..self.n_classes {
            for _ in 0..per_class {
                out.push((self.sample(c, rng), c));
            }
        }
        rng.shuffle(&mut out);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn samples_bounded() {
        let ds = DiffusionDataset::new(8, 4);
        let mut rng = Rng::new(610);
        for c in 0..4 {
            let x = ds.sample(c, &mut rng);
            assert_eq!(x.len(), 64);
            assert!(x.iter().all(|v| v.is_finite() && v.abs() < 2.0));
        }
    }

    #[test]
    fn class_means_differ() {
        let ds = DiffusionDataset::new(8, 4);
        let mut rng = Rng::new(611);
        let mean_img = |c: usize, rng: &mut Rng| -> Vec<f64> {
            let mut acc = vec![0.0f64; 64];
            for _ in 0..50 {
                let x = ds.sample(c, rng);
                for (a, v) in acc.iter_mut().zip(&x) {
                    *a += *v as f64;
                }
            }
            acc.iter().map(|v| v / 50.0).collect()
        };
        let m0 = mean_img(0, &mut rng);
        let m1 = mean_img(1, &mut rng);
        let d: f64 = m0.iter().zip(&m1).map(|(a, b)| (a - b).powi(2)).sum();
        assert!(d > 0.1, "class means indistinct: {d}");
    }

    #[test]
    fn intra_class_variance_nonzero() {
        let ds = DiffusionDataset::new(8, 2);
        let mut rng = Rng::new(612);
        let a = ds.sample(0, &mut rng);
        let b = ds.sample(0, &mut rng);
        let d: f32 = a.iter().zip(&b).map(|(x, y)| (x - y).abs()).sum();
        assert!(d > 0.5, "samples too similar: {d}");
    }
}
