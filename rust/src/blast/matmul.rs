//! Algorithm 1 — the BLAST matrix product.
//!
//! For `y = A x` with the partitioned input `x = [x_1; ...; x_b]`:
//!
//! 1. `z_j = V_j^T x_j`               (batched over j — "right factor")
//! 2. `w_i = Σ_j s_{i,j} ⊙ z_j`       (diagonal coupling + aggregation)
//! 3. `y_i = U_i w_i`                 (batched over i — "left factor")
//!
//! Cost: `(m + n + b²)·r` multiplies per column. The matrix variant
//! processes `X ∈ R^{n×cols}` one block row at a time so the `z_j`
//! intermediates are shared across every output block row — this is the
//! "computed once and shared" property the paper calls out.

use super::matrix::BlastMatrix;
use crate::kernels::{plan_cache, Couplings, Factors, PlanOperands, StructPlan};
use crate::tensor::{matmul, matmul_tn, Matrix};
use std::sync::Arc;

impl BlastMatrix {
    /// This matrix's [`StructPlan`] — the lowered Algorithm-1 stage
    /// program — from the process-wide structural plan cache. Plans
    /// hold no weight values, so the handle stays valid across factor
    /// sweeps (only shape changes would require a different plan, and
    /// shapes are fixed at construction).
    pub fn plan(&self) -> Arc<StructPlan> {
        plan_cache().get(
            crate::kernels::PlanSig {
                kind: crate::kernels::PlanKind::Blast,
                b: self.b as u32,
                r: self.r as u32,
                q: crate::kernels::QuantMode::F32,
            },
            self.m,
            self.n,
        )
    }

    /// Borrowed plan operands over this matrix's factor storage
    /// (allocation-free; group 0 is `V`, group 1 is `U`, couplings are
    /// the nested `s` table).
    pub fn plan_operands(&self) -> PlanOperands<'_> {
        PlanOperands {
            g0: Factors::Mats(&self.v),
            g1: Factors::Mats(&self.u),
            s: Some(Couplings::Nested(&self.s)),
        }
    }
    /// `y = A · x` (Algorithm 1), dispatched through the kernel engine.
    ///
    /// A single vector is a batch-1 activation row (`y = A x` ⟺
    /// `yᵀ = xᵀ Aᵀ`), so this shares the tuned decode-shape plan with
    /// the serving path instead of keeping a separate hand-rolled loop.
    pub fn matvec(&self, x: &[f32]) -> Vec<f32> {
        assert_eq!(x.len(), self.n, "matvec input length mismatch");
        let xm = Matrix::from_vec(1, self.n, x.to_vec());
        crate::kernels::engine().blast_act(&xm, self).data
    }

    /// `Y = A · X` for `X ∈ R^{n×c}` (the matrix/tensor variant of
    /// Appendix A's `blast_matmul`, transposed convention: columns are
    /// independent inputs).
    pub fn matmul_right(&self, x: &Matrix) -> Matrix {
        assert_eq!(x.rows, self.n, "matmul_right shape mismatch");
        let p = self.p();
        let q = self.q();
        let r = self.r;
        let c = x.cols;

        // Stage 1: Z_j = V_j^T X_j  (r×c), computed once per block column.
        let z: Vec<Matrix> = (0..self.b)
            .map(|j| {
                let xj = x.submatrix(j * q, (j + 1) * q, 0, c);
                matmul_tn(&self.v[j], &xj)
            })
            .collect();

        let mut y = Matrix::zeros(self.m, c);
        let mut w = Matrix::zeros(r, c);
        for i in 0..self.b {
            // Stage 2: W = Σ_j diag(s_{i,j}) Z_j.
            w.data.fill(0.0);
            for j in 0..self.b {
                let s = &self.s[i][j];
                let zj = &z[j];
                for k in 0..r {
                    let sk = s[k];
                    if sk == 0.0 {
                        continue;
                    }
                    let zrow = zj.row(k);
                    let wrow = w.row_mut(k);
                    for t in 0..c {
                        wrow[t] += sk * zrow[t];
                    }
                }
            }
            // Stage 3: Y_i = U_i W.
            let yi = matmul(&self.u[i], &w);
            y.set_submatrix(i * p, 0, &yi);
        }
        y
    }

    /// `Y = X · A^T` for row-major activations `X ∈ R^{batch×n}` — the
    /// layout used by the linear layers (`y = W x` per row with `W = A`,
    /// i.e. PyTorch's `x @ W.T`). This is the inference hot path; it
    /// lowers to this matrix's [`StructPlan`] (see [`plan`]) and
    /// dispatches through the kernel engine, which autotunes between
    /// the naive reference and the packed plan executors per (plan
    /// signature, shape, batch-bucket) and caches the choice.
    ///
    /// [`plan`]: BlastMatrix::plan
    pub fn matmul_act(&self, x: &Matrix) -> Matrix {
        assert_eq!(x.cols, self.n, "matmul_act shape mismatch: x cols {} vs n {}", x.cols, self.n);
        crate::kernels::engine().blast_act(x, self)
    }

    /// [`matmul_act`] into a caller-owned output: the allocation-free
    /// variant for steady-state loops (the kernel draws its stage
    /// scratch from thread-local pools and `out`'s buffer is reused
    /// whenever its capacity suffices). Bit-identical to
    /// [`matmul_act`].
    ///
    /// [`matmul_act`]: BlastMatrix::matmul_act
    pub fn matmul_act_into(&self, x: &Matrix, out: &mut Matrix) {
        assert_eq!(x.cols, self.n, "matmul_act shape mismatch: x cols {} vs n {}", x.cols, self.n);
        crate::kernels::engine().blast_act_into(x, self, out);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Rng;

    #[test]
    fn matvec_matches_dense() {
        let mut rng = Rng::new(60);
        for &(m, n, b, r) in &[(4, 4, 1, 2), (8, 8, 2, 3), (12, 6, 3, 2), (16, 16, 4, 5)] {
            let a = BlastMatrix::random_init(m, n, b, r, 1.0, &mut rng);
            let dense = a.to_dense();
            let x: Vec<f32> = (0..n).map(|i| ((i * 7 + 1) as f32).sin()).collect();
            let y = a.matvec(&x);
            let y_ref = crate::tensor::gemv(&dense, &x);
            for (a, b) in y.iter().zip(&y_ref) {
                assert!((a - b).abs() < 1e-3, "{a} vs {b} (m={m},n={n},b2={b},r={r})");
            }
        }
    }

    #[test]
    fn matmul_right_matches_dense() {
        let mut rng = Rng::new(61);
        let a = BlastMatrix::random_init(12, 8, 4, 3, 1.0, &mut rng);
        let dense = a.to_dense();
        let x = rng.gaussian_matrix(8, 5, 1.0);
        let y = a.matmul_right(&x);
        let y_ref = matmul(&dense, &x);
        assert!(y.sub(&y_ref).fro_norm() < 1e-3 * (1.0 + y_ref.fro_norm()));
    }

    #[test]
    fn matmul_act_matches_dense() {
        let mut rng = Rng::new(62);
        let a = BlastMatrix::random_init(10, 15, 5, 4, 1.0, &mut rng);
        let dense = a.to_dense();
        let x = rng.gaussian_matrix(7, 15, 1.0);
        let y = a.matmul_act(&x);
        // Reference: X · A^T.
        let y_ref = crate::tensor::matmul_nt(&x, &dense);
        assert!(y.sub(&y_ref).fro_norm() < 1e-3 * (1.0 + y_ref.fro_norm()));
        assert_eq!(y.shape(), (7, 10));
    }

    #[test]
    fn matmul_act_into_bit_matches_allocating_variant() {
        let mut rng = Rng::new(65);
        let a = BlastMatrix::random_init(12, 8, 4, 3, 1.0, &mut rng);
        let x = rng.gaussian_matrix(5, 8, 1.0);
        let y = a.matmul_act(&x);
        let mut out = Matrix::zeros(5, 12);
        let ptr = out.data.as_ptr();
        a.matmul_act_into(&x, &mut out);
        assert_eq!(out.data, y.data);
        assert_eq!(out.data.as_ptr(), ptr, "adequately-sized buffer must be reused");
    }

    #[test]
    fn b1_is_plain_low_rank_product() {
        // b=1: y = U diag(s) V^T x exactly.
        let mut rng = Rng::new(63);
        let a = BlastMatrix::random_init(6, 6, 1, 3, 1.0, &mut rng);
        let x: Vec<f32> = (0..6).map(|i| i as f32).collect();
        let y = a.matvec(&x);
        let dense = a.to_dense();
        let y_ref = crate::tensor::gemv(&dense, &x);
        for (p, q) in y.iter().zip(&y_ref) {
            assert!((p - q).abs() < 1e-4);
        }
    }

    #[test]
    fn zero_coupling_gives_zero_output() {
        let mut rng = Rng::new(64);
        let mut a = BlastMatrix::random_init(8, 8, 2, 2, 1.0, &mut rng);
        for i in 0..2 {
            for j in 0..2 {
                a.s[i][j].fill(0.0);
            }
        }
        let x = vec![1.0f32; 8];
        assert!(a.matvec(&x).iter().all(|&v| v == 0.0));
    }

    #[test]
    fn matvec_flops_formula_consistent() {
        // Stage 1 (Vᵀx, n·r mults) IS computed once and shared across
        // every row block i — the plan lowers it as a single Gemm over
        // the full input — so the advertised count is exactly
        // (m + n + b²)·r: n·r for stage 1, b²·r for the per-block-pair
        // coupling, m·r for stage 3.
        let a = BlastMatrix::zeros(256, 256, 16, 8);
        let flops = a.matvec_flops();
        assert_eq!(flops, (256 + 256 + 16 * 16) * 8);
        // The plan IR counts the same lowering op by op; the advertised
        // formula and the executed plan must never drift apart.
        assert_eq!(flops, a.plan().flops_per_row());
        // vs dense 65536 mults: ~10.7x fewer.
        assert!(flops < 256 * 256 / 10);
    }
}
