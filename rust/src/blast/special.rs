//! Special-case constructors (paper §2 and Appendix A.1).
//!
//! BLAST encompasses low-rank, block-diagonal, and block-low-rank (BLR /
//! Monarch-style) matrices through particular diagonal couplings:
//! * low-rank: all `s_{i,j} = 1` (the blocks tile `U V^T`);
//! * block-diagonal: `s_{i,j} = 1[i==j]` with `r = p`;
//! * BLR with per-block rank `t`: `r = b·t` and `s_{i,j}` one-hot on the
//!   `j`-th width-`t` band.

use super::matrix::BlastMatrix;
use crate::tensor::Matrix;

impl BlastMatrix {
    /// Embed a global low-rank factorization `A ≈ U V^T`
    /// (`U: m×r`, `V: n×r`) as a BLAST matrix with `b×b` blocks: slice the
    /// factors by block and set every coupling to ones (§2 "Low-Rank
    /// Matrices as Special Cases").
    pub fn from_low_rank(u: &Matrix, v: &Matrix, b: usize) -> Self {
        assert_eq!(u.cols, v.cols, "factor rank mismatch");
        let m = u.rows;
        let n = v.rows;
        let r = u.cols;
        let mut a = Self::zeros(m, n, b, r);
        let p = a.p();
        let q = a.q();
        for i in 0..b {
            a.u[i] = u.submatrix(i * p, (i + 1) * p, 0, r);
        }
        for j in 0..b {
            a.v[j] = v.submatrix(j * q, (j + 1) * q, 0, r);
        }
        for i in 0..b {
            for j in 0..b {
                a.s[i][j] = vec![1.0; r];
            }
        }
        a
    }

    /// Build a BLAST matrix representing a block-diagonal matrix with the
    /// given diagonal blocks (each `p×q`), factored at rank `r ≤ min(p,q)`
    /// via SVD when needed (Appendix A.1 "Block diagonal matrix").
    pub fn from_block_diagonal(blocks: &[Matrix], r: usize) -> Self {
        let b = blocks.len();
        assert!(b > 0);
        let p = blocks[0].rows;
        let q = blocks[0].cols;
        assert!(blocks.iter().all(|blk| blk.shape() == (p, q)));
        let mut a = Self::zeros(p * b, q * b, b, r);
        for (i, blk) in blocks.iter().enumerate() {
            let svd = crate::linalg::truncated_svd(blk, r);
            // U_i = left singular vectors, V_i = right, s_{i,i} = σ.
            let mut u = Matrix::zeros(p, r);
            let mut v = Matrix::zeros(q, r);
            let mut s = vec![0.0f32; r];
            for k in 0..r.min(svd.s.len()) {
                s[k] = svd.s[k];
                for t in 0..p {
                    u.set(t, k, svd.u.at(t, k));
                }
                for t in 0..q {
                    v.set(t, k, svd.v.at(t, k));
                }
            }
            a.u[i] = u;
            a.v[i] = v;
            a.s[i][i] = s;
            // Off-diagonal couplings stay zero.
        }
        a
    }

    /// Build a BLAST matrix representing a block-low-rank (BLR) matrix:
    /// every block `(i,j)` has its own rank-`t` factorization
    /// `A_{i,j} ≈ P_{i,j} Q_{i,j}^T`. Realized with `r = b·t` and one-hot
    /// band couplings (Appendix A.1 "Block low-rank matrix").
    ///
    /// `block_factors[i][j] = (P, Q)` with `P: p×t`, `Q: q×t`.
    pub fn from_blr(block_factors: &[Vec<(Matrix, Matrix)>]) -> Self {
        let b = block_factors.len();
        assert!(b > 0 && block_factors.iter().all(|row| row.len() == b));
        let (p, t) = block_factors[0][0].0.shape();
        let q = block_factors[0][0].1.rows;
        let r = b * t;
        let mut a = Self::zeros(p * b, q * b, b, r);

        // U_i = [P_{i,1} | P_{i,2} | ... | P_{i,b}]  (p × bt)
        // V_j's band j holds Q_{i,j}... but Q depends on i, which shared
        // bases cannot express in general. The BLR → BLAST embedding of
        // Appendix A.1 assumes the *Monarch* convention where the right
        // factor of block (i,j) depends only on j and the left only on i
        // after permutation; concretely we place P_{i,j} in U_i's band j
        // and Q_{i,j} in V_j's band... which again collides across i.
        //
        // The paper's §A.1 BLR example uses rank-1 blocks with bases
        // u_{i,j}, v_{i,j} and sets U_i = [u_{i,1} ... u_{i,b}],
        // V_j = [v_{1,j} ... v_{b,j}]: U_i's band j holds the left basis
        // of block (i,j) and V_j's band *i* holds the right basis of
        // block (i,j). The coupling s_{i,j} must then select column band
        // j from U_i and band i from V_j simultaneously — which works
        // only when the selected bands coincide (band index k belongs to
        // both U_i band j and V_j band i iff the coupling is supported on
        // the intersection). With one-hot s_{i,j} on band j, V_j band j
        // must hold v_{j-th}: this reproduces Monarch, where right bases
        // depend only on their own block column. We implement exactly
        // that (t ≥ 1 generalization of §A.1's rank-1 example with the
        // Monarch sharing pattern): Q must satisfy Q_{i,j} = Q_j.
        for i in 0..b {
            let mut u = Matrix::zeros(p, r);
            for j in 0..b {
                let pij = &block_factors[i][j].0;
                assert_eq!(pij.shape(), (p, t));
                for a_ in 0..p {
                    for k in 0..t {
                        u.set(a_, j * t + k, pij.at(a_, k));
                    }
                }
            }
            a.u[i] = u;
        }
        for j in 0..b {
            let mut v = Matrix::zeros(q, r);
            // Monarch sharing: use block (0, j)'s right factor for the
            // whole block column (caller must pass Q_{i,j} = Q_j).
            let qj = &block_factors[0][j].1;
            assert_eq!(qj.shape(), (q, t));
            for a_ in 0..q {
                for k in 0..t {
                    v.set(a_, j * t + k, qj.at(a_, k));
                }
            }
            a.v[j] = v;
        }
        for i in 0..b {
            for j in 0..b {
                let mut s = vec![0.0f32; r];
                for k in 0..t {
                    s[j * t + k] = 1.0;
                }
                a.s[i][j] = s;
            }
        }
        a
    }

    /// Embed a Monarch-style matrix: block `(i,j) = L_{i,j} R_j` where the
    /// right basis is shared per block column. This is the `from_blr`
    /// sharing pattern; provided as a named constructor for clarity.
    pub fn from_monarch(l: &[Vec<Matrix>], r_bases: &[Matrix]) -> Self {
        let b = l.len();
        assert_eq!(r_bases.len(), b);
        let factors: Vec<Vec<(Matrix, Matrix)>> = (0..b)
            .map(|i| {
                (0..b)
                    .map(|j| (l[i][j].clone(), r_bases[j].transpose()))
                    .collect()
            })
            .collect();
        Self::from_blr(&factors)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::{matmul, matmul_nt, Rng};

    #[test]
    fn low_rank_embedding_exact() {
        let mut rng = Rng::new(70);
        let u = rng.gaussian_matrix(12, 3, 1.0);
        let v = rng.gaussian_matrix(12, 3, 1.0);
        let dense = matmul_nt(&u, &v);
        for b in [1, 2, 3, 4, 6] {
            let a = BlastMatrix::from_low_rank(&u, &v, b);
            let rec = a.to_dense();
            assert!(
                rec.sub(&dense).fro_norm() < 1e-4 * dense.fro_norm(),
                "b={b}"
            );
        }
    }

    #[test]
    fn block_diagonal_embedding_exact_full_rank() {
        let mut rng = Rng::new(71);
        let blocks: Vec<Matrix> = (0..3).map(|_| rng.gaussian_matrix(4, 4, 1.0)).collect();
        let a = BlastMatrix::from_block_diagonal(&blocks, 4);
        let rec = a.to_dense();
        for i in 0..3 {
            for j in 0..3 {
                let blk = rec.block(i, j, 3, 3);
                if i == j {
                    assert!(blk.sub(&blocks[i]).fro_norm() < 1e-2 * blocks[i].fro_norm());
                } else {
                    assert!(blk.fro_norm() < 1e-4);
                }
            }
        }
    }

    #[test]
    fn block_diagonal_low_rank_blocks() {
        // r < p: diagonal blocks are the best rank-r approximations.
        let mut rng = Rng::new(72);
        let blocks: Vec<Matrix> = (0..2).map(|_| rng.gaussian_matrix(6, 6, 1.0)).collect();
        let a = BlastMatrix::from_block_diagonal(&blocks, 2);
        let rec = a.to_dense();
        for i in 0..2 {
            let blk = rec.block(i, i, 2, 2);
            let best = crate::linalg::truncated_svd(&blocks[i], 2).reconstruct(2);
            assert!(blk.sub(&best).fro_norm() < 1e-2 * best.fro_norm());
        }
    }

    #[test]
    fn monarch_embedding_exact() {
        // Monarch block (i,j) = L_{i,j} R_j with t=2 shared right bases.
        let mut rng = Rng::new(73);
        let b = 3;
        let (p, q, t) = (4, 5, 2);
        let l: Vec<Vec<Matrix>> = (0..b)
            .map(|_| (0..b).map(|_| rng.gaussian_matrix(p, t, 1.0)).collect())
            .collect();
        let r_bases: Vec<Matrix> = (0..b).map(|_| rng.gaussian_matrix(t, q, 1.0)).collect();
        let a = BlastMatrix::from_monarch(&l, &r_bases);
        assert_eq!(a.r, b * t);
        let rec = a.to_dense();
        for i in 0..b {
            for j in 0..b {
                let expect = matmul(&l[i][j], &r_bases[j]);
                let got = rec.block(i, j, b, b);
                assert!(
                    got.sub(&expect).fro_norm() < 1e-3 * (1.0 + expect.fro_norm()),
                    "block ({i},{j})"
                );
            }
        }
    }

    #[test]
    fn param_count_overhead_vs_blr() {
        // §A.1: BLAST costs r·b² more than raw BLR at the same expressivity.
        let b = 4;
        let t = 2;
        let (p, q) = (8, 8);
        let r = b * t;
        let a = BlastMatrix::zeros(p * b, q * b, b, r);
        let blr_params = b * b * (p + q) * t;
        assert_eq!(a.num_params(), r * (p * b + q * b) + r * b * b);
        // For the Monarch sharing pattern, BLAST stores (m+n)r + rb² vs
        // Monarch's (m + n·b... ) — just assert the documented delta:
        assert_eq!(
            a.num_params() as i64 - (2 * p * b * r) as i64,
            (r * b * b) as i64
        );
        assert!(blr_params > 0);
    }
}
