//! `BlastMatrix` — storage and basic algebra for the BLAST parameterization.

use crate::tensor::{Matrix, Rng};

/// A BLAST matrix of logical shape `m×n` with `b×b` blocks and rank
/// parameter `r` (paper Eq. 1–2).
///
/// Storage:
/// * `u[i]` — left factor `U_i ∈ R^{p×r}` shared across block row `i`;
/// * `v[j]` — right factor `V_j ∈ R^{q×r}` shared across block column `j`;
/// * `s[i][j]` — diagonal coupling `s_{i,j} ∈ R^r` specific to block
///   `(i, j)` — the source of BLAST's adaptivity.
#[derive(Clone, Debug)]
pub struct BlastMatrix {
    pub m: usize,
    pub n: usize,
    pub b: usize,
    pub r: usize,
    pub u: Vec<Matrix>,      // b entries, each p×r
    pub v: Vec<Matrix>,      // b entries, each q×r
    pub s: Vec<Vec<Vec<f32>>>, // [b][b][r]
}

impl BlastMatrix {
    /// Block height `p = m/b`.
    #[inline]
    pub fn p(&self) -> usize {
        self.m / self.b
    }

    /// Block width `q = n/b`.
    #[inline]
    pub fn q(&self) -> usize {
        self.n / self.b
    }

    /// All-zeros BLAST matrix.
    pub fn zeros(m: usize, n: usize, b: usize, r: usize) -> Self {
        assert!(b > 0 && m % b == 0 && n % b == 0, "b must divide both m={m} and n={n}");
        assert!(r > 0, "rank must be positive");
        let p = m / b;
        let q = n / b;
        BlastMatrix {
            m,
            n,
            b,
            r,
            u: (0..b).map(|_| Matrix::zeros(p, r)).collect(),
            v: (0..b).map(|_| Matrix::zeros(q, r)).collect(),
            s: vec![vec![vec![0.0; r]; b]; b],
        }
    }

    /// Random init for training from scratch (Appendix C.2):
    /// `U, V ~ N(0, std)`, `s ~ Unif(0, 2)`.
    pub fn random_init(m: usize, n: usize, b: usize, r: usize, std: f32, rng: &mut Rng) -> Self {
        let mut a = Self::zeros(m, n, b, r);
        let p = a.p();
        let q = a.q();
        for i in 0..b {
            a.u[i] = rng.gaussian_matrix(p, r, std);
            a.v[i] = rng.gaussian_matrix(q, r, std);
        }
        for i in 0..b {
            for j in 0..b {
                a.s[i][j] = rng.uniform_vec(r, 0.0, 2.0);
            }
        }
        a
    }

    /// Small random init for factorization (Algorithm 2 line 1):
    /// `U, V ~ N(0, eps²)`, `s ~ Unif(0, 1)`.
    pub fn factorization_init(
        m: usize,
        n: usize,
        b: usize,
        r: usize,
        eps: f32,
        rng: &mut Rng,
    ) -> Self {
        let mut a = Self::zeros(m, n, b, r);
        let p = a.p();
        let q = a.q();
        for i in 0..b {
            a.u[i] = rng.gaussian_matrix(p, r, eps);
            a.v[i] = rng.gaussian_matrix(q, r, eps);
        }
        for i in 0..b {
            for j in 0..b {
                a.s[i][j] = rng.uniform_vec(r, 0.0, 1.0);
            }
        }
        a
    }

    /// Number of stored parameters: `r(m+n) + r b²` (paper §2).
    pub fn num_params(&self) -> usize {
        self.r * (self.m + self.n) + self.r * self.b * self.b
    }

    /// Multiplications per matrix-vector product: `(m + n + b²)·r`
    /// (paper §2, Algorithm 1 analysis).
    pub fn matvec_flops(&self) -> usize {
        (self.m + self.n + self.b * self.b) * self.r
    }

    /// Dense parameter count of the equivalent `m×n` matrix.
    pub fn dense_params(&self) -> usize {
        self.m * self.n
    }

    /// Compression ratio = 1 - params(blast)/params(dense).
    pub fn compression_ratio(&self) -> f64 {
        1.0 - self.num_params() as f64 / self.dense_params() as f64
    }

    /// `U_i · diag(s_{i,j})` — the coupling folded into the left factor,
    /// so a block reconstruction becomes one dense `X · Wᵀ` dispatch
    /// (`u_scaled(i,j) · V_jᵀ`); used by the block-parallel loss of
    /// `factorize::loss`.
    pub fn u_scaled(&self, i: usize, j: usize) -> Matrix {
        let mut out = self.u[i].clone();
        let s = &self.s[i][j];
        for a in 0..out.rows {
            for (v, sv) in out.row_mut(a).iter_mut().zip(s) {
                *v *= sv;
            }
        }
        out
    }

    /// Reconstruct block `(i, j)` densely: `U_i diag(s_{i,j}) V_j^T`.
    pub fn block_dense(&self, i: usize, j: usize) -> Matrix {
        let p = self.p();
        let q = self.q();
        let u = &self.u[i];
        let v = &self.v[j];
        let s = &self.s[i][j];
        let mut out = Matrix::zeros(p, q);
        // out[a, c] = sum_k u[a,k] * s[k] * v[c,k]
        for a in 0..p {
            let urow = u.row(a);
            let orow = out.row_mut(a);
            for k in 0..self.r {
                let us = urow[k] * s[k];
                if us == 0.0 {
                    continue;
                }
                let vcol = v.data[..].chunks_exact(self.r);
                // iterate rows of v (each row is length r)
                for (c, vrow) in vcol.enumerate() {
                    orow[c] += us * vrow[k];
                }
            }
        }
        out
    }

    /// Full dense reconstruction (test/debug; O(m·n·r)).
    pub fn to_dense(&self) -> Matrix {
        let p = self.p();
        let q = self.q();
        let mut out = Matrix::zeros(self.m, self.n);
        for i in 0..self.b {
            for j in 0..self.b {
                let blk = self.block_dense(i, j);
                out.set_submatrix(i * p, j * q, &blk);
            }
        }
        out
    }

    /// The concatenated scaled right factor
    /// `V̄_i = [S_{i,1} V_1^T ... S_{i,b} V_b^T]^T ∈ R^{n×r}` used in the
    /// `U_i` update (Eq. 5 / Algorithm 2 line 3).
    pub fn v_bar(&self, i: usize) -> Matrix {
        let q = self.q();
        let mut out = Matrix::zeros(self.n, self.r);
        for j in 0..self.b {
            let s = &self.s[i][j];
            let v = &self.v[j];
            for a in 0..q {
                let vrow = v.row(a);
                let orow = out.row_mut(j * q + a);
                for k in 0..self.r {
                    orow[k] = vrow[k] * s[k];
                }
            }
        }
        out
    }

    /// The concatenated scaled left factor
    /// `Ū_j = [(U_1 S_{1,j})^T ... (U_b S_{b,j})^T]^T ∈ R^{m×r}` used in
    /// the `V_j` update (Eq. 6 / Algorithm 2 line 4).
    pub fn u_bar(&self, j: usize) -> Matrix {
        let p = self.p();
        let mut out = Matrix::zeros(self.m, self.r);
        for i in 0..self.b {
            let s = &self.s[i][j];
            let u = &self.u[i];
            for a in 0..p {
                let urow = u.row(a);
                let orow = out.row_mut(i * p + a);
                for k in 0..self.r {
                    orow[k] = urow[k] * s[k];
                }
            }
        }
        out
    }

    /// Flatten all parameters into a named bundle (for checkpointing).
    pub fn to_bundle(&self, prefix: &str) -> crate::tensor::io::TensorBundle {
        let mut bundle = crate::tensor::io::TensorBundle::new();
        for i in 0..self.b {
            bundle.insert(format!("{prefix}.u.{i}"), self.u[i].clone());
            bundle.insert(format!("{prefix}.v.{i}"), self.v[i].clone());
        }
        // Pack s as a (b*b) × r matrix.
        let mut s = Matrix::zeros(self.b * self.b, self.r);
        for i in 0..self.b {
            for j in 0..self.b {
                s.row_mut(i * self.b + j).copy_from_slice(&self.s[i][j]);
            }
        }
        bundle.insert(format!("{prefix}.s"), s);
        bundle
    }

    /// Inverse of `to_bundle`.
    pub fn from_bundle(
        bundle: &crate::tensor::io::TensorBundle,
        prefix: &str,
        m: usize,
        n: usize,
        b: usize,
        r: usize,
    ) -> anyhow::Result<Self> {
        let mut a = Self::zeros(m, n, b, r);
        for i in 0..b {
            a.u[i] = bundle.get(&format!("{prefix}.u.{i}"))?.clone();
            a.v[i] = bundle.get(&format!("{prefix}.v.{i}"))?.clone();
        }
        let s = bundle.get(&format!("{prefix}.s"))?;
        anyhow::ensure!(s.shape() == (b * b, r), "bad s shape");
        for i in 0..b {
            for j in 0..b {
                a.s[i][j].copy_from_slice(s.row(i * b + j));
            }
        }
        Ok(a)
    }

    /// Frobenius norm of the represented dense matrix (via reconstruction).
    pub fn fro_norm(&self) -> f32 {
        self.to_dense().fro_norm()
    }

    /// True if any factor entry is NaN/inf.
    pub fn has_nonfinite(&self) -> bool {
        self.u.iter().any(|m| m.has_nonfinite())
            || self.v.iter().any(|m| m.has_nonfinite())
            || self
                .s
                .iter()
                .flatten()
                .flatten()
                .any(|x| !x.is_finite())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_and_params() {
        let a = BlastMatrix::zeros(12, 8, 4, 3);
        assert_eq!(a.p(), 3);
        assert_eq!(a.q(), 2);
        assert_eq!(a.u.len(), 4);
        assert_eq!(a.v.len(), 4);
        assert_eq!(a.u[0].shape(), (3, 3));
        assert_eq!(a.v[0].shape(), (2, 3));
        // r(m+n) + r b^2 = 3*20 + 3*16 = 108
        assert_eq!(a.num_params(), 108);
        // (m+n+b^2) r = (12+8+16)*3 = 108
        assert_eq!(a.matvec_flops(), 108);
    }

    #[test]
    #[should_panic]
    fn indivisible_blocks_panic() {
        BlastMatrix::zeros(10, 8, 3, 2);
    }

    #[test]
    fn block_dense_matches_manual() {
        let mut rng = Rng::new(50);
        let a = BlastMatrix::random_init(6, 6, 2, 2, 1.0, &mut rng);
        let blk = a.block_dense(0, 1);
        // Manual: U_0 diag(s_{0,1}) V_1^T
        let u = &a.u[0];
        let v = &a.v[1];
        let s = &a.s[0][1];
        for i in 0..3 {
            for j in 0..3 {
                let mut acc = 0.0f32;
                for k in 0..2 {
                    acc += u.at(i, k) * s[k] * v.at(j, k);
                }
                assert!((blk.at(i, j) - acc).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn to_dense_assembles_blocks() {
        let mut rng = Rng::new(51);
        let a = BlastMatrix::random_init(8, 12, 4, 3, 1.0, &mut rng);
        let dense = a.to_dense();
        assert_eq!(dense.shape(), (8, 12));
        for i in 0..4 {
            for j in 0..4 {
                let blk = dense.block(i, j, 4, 4);
                let expect = a.block_dense(i, j);
                assert!(blk.sub(&expect).fro_norm() < 1e-5);
            }
        }
    }

    #[test]
    fn v_bar_u_bar_shapes_and_values() {
        let mut rng = Rng::new(52);
        let a = BlastMatrix::random_init(4, 6, 2, 3, 1.0, &mut rng);
        let vb = a.v_bar(0);
        assert_eq!(vb.shape(), (6, 3));
        // Row 0 of v_bar(0) is V_0 row 0 scaled by s_{0,0}.
        for k in 0..3 {
            assert!((vb.at(0, k) - a.v[0].at(0, k) * a.s[0][0][k]).abs() < 1e-6);
        }
        // Row q (=3) is V_1 row 0 scaled by s_{0,1}.
        for k in 0..3 {
            assert!((vb.at(3, k) - a.v[1].at(0, k) * a.s[0][1][k]).abs() < 1e-6);
        }
        let ub = a.u_bar(1);
        assert_eq!(ub.shape(), (4, 3));
        for k in 0..3 {
            assert!((ub.at(0, k) - a.u[0].at(0, k) * a.s[0][1][k]).abs() < 1e-6);
            assert!((ub.at(2, k) - a.u[1].at(0, k) * a.s[1][1][k]).abs() < 1e-6);
        }
    }

    #[test]
    fn v_bar_identity_reconstruction() {
        // A_{i,*} = U_i V̄_i^T must equal the dense block row.
        let mut rng = Rng::new(53);
        let a = BlastMatrix::random_init(6, 9, 3, 2, 1.0, &mut rng);
        let dense = a.to_dense();
        for i in 0..3 {
            let row = crate::tensor::matmul_nt(&a.u[i], &a.v_bar(i));
            let expect = dense.block_row(i, 3);
            assert!(row.sub(&expect).fro_norm() < 1e-4);
        }
        // And A_{*,j} = Ū_j V_j^T equals dense block columns.
        for j in 0..3 {
            let col = crate::tensor::matmul_nt(&a.u_bar(j), &a.v[j]);
            let expect = dense.block_col(j, 3);
            assert!(col.sub(&expect).fro_norm() < 1e-4);
        }
    }

    #[test]
    fn u_scaled_reconstructs_block() {
        let mut rng = Rng::new(55);
        let a = BlastMatrix::random_init(6, 6, 2, 3, 1.0, &mut rng);
        let rec = crate::tensor::matmul_nt(&a.u_scaled(0, 1), &a.v[1]);
        assert!(rec.sub(&a.block_dense(0, 1)).fro_norm() < 1e-5);
    }

    #[test]
    fn bundle_round_trip() {
        let mut rng = Rng::new(54);
        let a = BlastMatrix::random_init(8, 8, 2, 4, 0.5, &mut rng);
        let bundle = a.to_bundle("layer0");
        let b = BlastMatrix::from_bundle(&bundle, "layer0", 8, 8, 2, 4).unwrap();
        assert!(a.to_dense().sub(&b.to_dense()).fro_norm() < 1e-6);
    }

    #[test]
    fn compression_ratio_sane() {
        // 256x256, b=16, r=8: params = 8*512 + 8*256 = 6144; dense = 65536.
        let a = BlastMatrix::zeros(256, 256, 16, 8);
        assert_eq!(a.num_params(), 6144);
        assert!((a.compression_ratio() - (1.0 - 6144.0 / 65536.0)).abs() < 1e-12);
    }
}
