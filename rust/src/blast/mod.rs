//! The BLAST matrix (paper §2).
//!
//! An `m×n` matrix is partitioned into `b×b` blocks of size `p×q`
//! (`p = m/b`, `q = n/b`, Eq. 1); each block is parameterized as
//! `A_{i,j} = U_i · diag(s_{i,j}) · V_j^T` (Eq. 2) with the left factor
//! shared across a block row, the right factor shared across a block
//! column, and a per-block diagonal coupling vector. Parameter count:
//! `r·(m+n) + r·b²`.

pub mod matrix;
pub mod matmul;
pub mod special;
pub mod budget;

pub use matrix::BlastMatrix;
pub use budget::{
    blast_achieved_ratio, blast_rank_for_ratio, lowrank_rank_for_ratio, CompressionBudget,
};
