//! Rank-budget solvers: pick the structure hyperparameter (BLAST `r`,
//! low-rank rank, Monarch block rank, …) that hits a target compression
//! ratio, mirroring the paper's "we used the same hyperparameter r for
//! every target weight matrix by setting it to meet the computational
//! budget of the DNN" (§4) and the per-layer tables of Appendix C.

/// A compression target for one `m×n` weight matrix.
#[derive(Clone, Copy, Debug)]
pub struct CompressionBudget {
    pub m: usize,
    pub n: usize,
    /// Target compression ratio in (0, 1): fraction of parameters removed.
    pub ratio: f64,
}

impl CompressionBudget {
    pub fn new(m: usize, n: usize, ratio: f64) -> Self {
        assert!((0.0..1.0).contains(&ratio), "ratio must be in [0,1)");
        CompressionBudget { m, n, ratio }
    }

    /// Parameter budget: `(1 - ratio) * m * n`.
    pub fn param_budget(&self) -> usize {
        ((1.0 - self.ratio) * (self.m as f64) * (self.n as f64)).floor() as usize
    }
}

/// Largest BLAST rank `r` such that `r(m+n) + r b² ≤ budget` and `r ≥ 1`.
/// Returns `None` if even `r = 1` exceeds the budget.
pub fn blast_rank_for_ratio(m: usize, n: usize, b: usize, ratio: f64) -> Option<usize> {
    let budget = CompressionBudget::new(m, n, ratio).param_budget();
    let per_rank = m + n + b * b;
    let r = budget / per_rank;
    if r == 0 {
        None
    } else {
        Some(r)
    }
}

/// Largest low-rank rank such that `r(m+n) ≤ budget`.
pub fn lowrank_rank_for_ratio(m: usize, n: usize, ratio: f64) -> Option<usize> {
    let budget = CompressionBudget::new(m, n, ratio).param_budget();
    let r = budget / (m + n);
    if r == 0 {
        None
    } else {
        Some(r)
    }
}

/// Largest Monarch per-block rank `t` such that block factors fit:
/// params = b² · (p + q) · t = (m + n)·b·t ≤ budget.
pub fn monarch_rank_for_ratio(m: usize, n: usize, b: usize, ratio: f64) -> Option<usize> {
    let budget = CompressionBudget::new(m, n, ratio).param_budget();
    let per_t = (m + n) * b;
    let t = budget / per_t;
    if t == 0 {
        None
    } else {
        Some(t)
    }
}

/// Largest block-diagonal per-block rank `t` such that
/// params = b · (p + q) · t = (m + n)·t ≤ budget, capped at min(p, q)
/// (t = min(p,q) stores the diagonal blocks densely-equivalent).
pub fn blockdiag_rank_for_ratio(m: usize, n: usize, b: usize, ratio: f64) -> Option<usize> {
    let budget = CompressionBudget::new(m, n, ratio).param_budget();
    let per_t = m + n;
    let t = (budget / per_t).min((m / b).min(n / b));
    if t == 0 {
        None
    } else {
        Some(t)
    }
}

/// Achieved ratio for a BLAST configuration.
pub fn blast_achieved_ratio(m: usize, n: usize, b: usize, r: usize) -> f64 {
    1.0 - ((r * (m + n) + r * b * b) as f64) / ((m * n) as f64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn blast_rank_meets_budget() {
        // Llama Q_proj-like: 4096x4096, b=16, 50% CR.
        let r = blast_rank_for_ratio(4096, 4096, 16, 0.5).unwrap();
        let params = r * (4096 + 4096) + r * 256;
        assert!(params <= 4096 * 4096 / 2);
        // One more rank unit would bust the budget.
        let params_next = (r + 1) * (4096 + 4096) + (r + 1) * 256;
        assert!(params_next > 4096 * 4096 / 2);
        // Paper's Table 9 uses r=1024 for attention at 50% *model-wide*
        // CR (their ratio is computed over all parameters including the
        // uncompressed embedding/head); the strict per-matrix solver
        // lands just below: 992.
        assert_eq!(r, 992);
    }

    #[test]
    fn lowrank_rank_meets_budget() {
        let r = lowrank_rank_for_ratio(4096, 4096, 0.5).unwrap();
        assert!(r * 8192 <= 8_388_608);
        assert!((r + 1) * 8192 > 8_388_608);
        assert_eq!(r, 1024);
    }

    #[test]
    fn monarch_and_blockdiag() {
        let t = monarch_rank_for_ratio(256, 256, 4, 0.5).unwrap();
        assert!(t * 512 * 4 <= 32768);
        let t = blockdiag_rank_for_ratio(256, 256, 4, 0.5).unwrap();
        assert!(t <= 64);
    }

    #[test]
    fn infeasible_returns_none() {
        assert!(blast_rank_for_ratio(8, 8, 2, 0.95).is_none());
    }

    #[test]
    fn achieved_ratio_consistent() {
        let r = blast_rank_for_ratio(512, 512, 8, 0.7).unwrap();
        let achieved = blast_achieved_ratio(512, 512, 8, r);
        assert!(achieved >= 0.7 - 0.01, "achieved {achieved}");
    }
}
