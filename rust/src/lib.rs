//! # blast-repro
//!
//! Full-system reproduction of **BLAST: Block-Level Adaptive Structured
//! Matrices for Efficient Deep Neural Network Inference** (Lee, Kwon, Qu,
//! Kim — NeurIPS 2024) as a three-layer Rust + JAX + Pallas stack.
//!
//! * [`tensor`] / [`linalg`] — dense numeric substrate (from scratch).
//! * [`kernels`] — the matmul kernel engine: packed-microkernel dense
//!   kernels plus the structure-plan executor (every weight structure
//!   lowered to microkernel stages) behind one trait, selected per
//!   (plan signature, shape, batch) by an autotuner; every inference
//!   hot path dispatches through it.
//! * [`blast`] — the BLAST matrix type and Algorithm 1 products.
//! * [`factorize`] — Algorithm 2 (preconditioned GD factorization, with
//!   block-parallel sweeps through the kernel engine), the Low-Rank /
//!   Monarch / Block-Diagonal baseline compressors, and the parallel,
//!   resumable whole-model compression pipeline behind `blast compress`.
//! * [`nn`] / [`train`] — structured-linear transformer stack with
//!   Rust-native inference and training (manual backprop).
//! * [`data`] / [`eval`] — synthetic workloads and the paper's metrics.
//! * [`runtime`] — PJRT executor for AOT-compiled JAX/Pallas artifacts.
//! * [`coordinator`] — the L3 serving system: router, streaming
//!   responses, and iteration-level continuous batching over a paged
//!   KV block manager with radix-tree prefix caching.
//! * [`experiments`] — one harness per paper table/figure.
//! * [`obs`] — crate-wide observability: metrics registry, plan-stage
//!   profiler, request tracer, and the snapshot/exposition surfaces.

pub mod util;
pub mod obs;
pub mod tensor;
pub mod linalg;
pub mod kernels;
pub mod blast;
pub mod factorize;
pub mod nn;
pub mod train;
pub mod data;
pub mod eval;
pub mod runtime;
pub mod coordinator;
pub mod experiments;

// Crate-level re-exports. `Executor` and `Manifest` live in
// `runtime::{executor, manifest}` (there are no top-level modules of
// those names); re-export them here so downstream code has a stable
// path that does not depend on the runtime module layout.
pub use runtime::{ArtifactEntry, Executor, Manifest, PjrtEngine};
