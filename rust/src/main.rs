//! `blast` — CLI for the BLAST reproduction.
//!
//! Subcommands:
//!   factorize        factorize a synthetic matrix (GD vs PrecGD demo)
//!   compress         dense `.bmx` checkpoint → compressed checkpoint via
//!                    the parallel, resumable pipeline (or train a small
//!                    dense model first when no --in is given)
//!   train            train a TinyLM from scratch (--save writes a
//!                    `.bmx` checkpoint the pipeline can consume)
//!   serve            start the coordinator and run a request load
//!                    (--model serves a compressed checkpoint)
//!   stats            short self-drive, then pretty-print the metrics
//!                    snapshot (pack cache, plan GFLOP/s, serving)
//!   generate         one-off generation through a trained model
//!   experiment <id>  run a paper table/figure harness (or `all`)
//!   bench-runtime    Table-4 matvec sweep at Llama shapes
//!   info             artifact manifest + environment summary

use anyhow::{bail, Result};
use blast_repro::coordinator::{Coordinator, CoordinatorConfig};
use blast_repro::data::corpus::SyntheticCorpus;
use blast_repro::experiments;
use blast_repro::factorize::{factorize_gd, factorize_precgd, GdOptions, PrecGdOptions};
use blast_repro::nn::attention::StructureKind;
use blast_repro::nn::gpt::{LmConfig, TinyLM};
use blast_repro::tensor::{matmul_nt, Rng};
use blast_repro::train::{train_lm, LmTrainConfig};
use blast_repro::util::cli::Args;

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn usage() -> &'static str {
    "usage: blast <factorize|compress|train|serve|stats|generate|experiment|bench-runtime|info> [flags]\n\
     flags are --name value; examples:\n\
       blast experiment fig3 --scale 1\n\
       blast experiment all --scale 0\n\
       blast train --structure blast --b 4 --r 8 --steps 200 --save dense.bmx\n\
       blast compress --in dense.bmx --out blast.bmx --structure blast --ratio 0.5 \\\n\
                      --ckpt-dir compress_ckpt --jobs 0   # resumes from ckpt-dir\n\
       blast compress --ratio 0.5 --structure auto        # trains a demo model first\n\
       blast compress --in dense.bmx --out q.bmx --quantize int8   # int8 weight panels\n\
       blast serve --model blast.bmx --requests 32 --slots 8\n\
       blast serve --model blast.bmx --spec-gamma 4 --spec-draft self   # speculative decoding\n\
       blast stats --model blast.bmx --requests 12        # metrics snapshot (incl. spec acceptance rate)\n\
       blast generate --model blast.bmx --tokens 20\n\
       blast bench-runtime --reps 5"
}

fn parse_structure(args: &Args) -> Result<StructureKind> {
    let b = args.get_usize("b", 4)?;
    let r = args.get_usize("r", 8)?;
    let t = args.get_usize("t", 4)?;
    Ok(match args.get_or("structure", "dense") {
        "dense" => StructureKind::Dense,
        "lowrank" => StructureKind::LowRank { r },
        "blast" => StructureKind::Blast { b, r },
        "monarch" => StructureKind::Monarch { b, t },
        "blockdiag" => StructureKind::BlockDiag { b, t },
        other => bail!("unknown structure `{other}`"),
    })
}

fn run() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = argv.first() else {
        println!("{}", usage());
        return Ok(());
    };
    let args = Args::parse(&argv[1..], &["verbose", "no-prec", "fresh", "no-retrain"])?;

    match cmd.as_str() {
        "factorize" => cmd_factorize(&args),
        "compress" => cmd_compress(&args),
        "train" => cmd_train(&args),
        "serve" => cmd_serve(&args),
        "stats" => cmd_stats(&args),
        "generate" => cmd_generate(&args),
        "experiment" => {
            let id = args
                .positional
                .first()
                .map(String::as_str)
                .unwrap_or("all");
            let scale = args.get_usize("scale", 1)?;
            if id == "all" {
                experiments::run_all(scale)
            } else {
                experiments::run(id, scale)
            }
        }
        "bench-runtime" => {
            let reps = args.get_usize("reps", 5)?;
            experiments::runtime_exp::print_matvec_sweep(reps);
            Ok(())
        }
        "info" => cmd_info(),
        _ => {
            println!("{}", usage());
            bail!("unknown command `{cmd}`")
        }
    }
}

fn cmd_factorize(args: &Args) -> Result<()> {
    let n = args.get_usize("n", 128)?;
    let b = args.get_usize("b", 8)?;
    let r = args.get_usize("r", 16)?;
    let r_star = args.get_usize("r-star", 8)?;
    let iters = args.get_usize("iters", 100)?;
    let seed = args.get_u64("seed", 0)?;

    let mut rng = Rng::new(seed);
    let u = rng.gaussian_matrix(n, r_star, 1.0);
    let v = rng.gaussian_matrix(n, r_star, 1.0);
    let target = matmul_nt(&u, &v).scale(1.0 / (r_star as f32).sqrt());
    println!("target: {n}x{n} rank-{r_star}; factorizing with b={b}, r={r}, {iters} iters");

    if !args.has("no-prec") {
        let res = factorize_precgd(
            &target,
            &PrecGdOptions { b, r, iters, seed, ..Default::default() },
        );
        println!("PrecGD (Algorithm 2): rel error {:.3e}", res.rel_error);
        for (k, loss) in res.trace.iter().step_by((iters / 10).max(1)) {
            println!("  iter {k:>4}: loss {loss:.3e}");
        }
    }
    let res = factorize_gd(&target, &GdOptions { b, r, iters, seed, ..Default::default() });
    println!("plain GD: rel error {:.3e}", res.rel_error);
    Ok(())
}

/// `blast compress`: dense checkpoint in → compressed checkpoint out,
/// through the parallel, resumable pipeline. Without `--in`, a small
/// dense TinyLM is trained on the synthetic corpus first (demo mode) and
/// perplexity is reported before/after compression and re-training.
fn cmd_compress(args: &Args) -> Result<()> {
    use blast_repro::factorize::{
        CompressionPipeline, Compressor, PipelineOptions, StructurePolicy,
    };
    use std::path::{Path, PathBuf};

    let ratio = args.get_f64("ratio", 0.5)?;
    let b = args.get_usize("b", 4)?;
    let structure_tok = args.get_or("structure", "blast");
    let policy = StructurePolicy::parse(structure_tok, b)
        .ok_or_else(|| anyhow::anyhow!("unknown structure/policy `{structure_tok}`"))?;
    let out = args.get_or("out", "blast_model.bmx").to_string();
    let ckpt_dir = PathBuf::from(args.get_or("ckpt-dir", "compress_ckpt"));
    if args.has("fresh") && ckpt_dir.exists() {
        std::fs::remove_dir_all(&ckpt_dir)?;
        println!("--fresh: cleared {}", ckpt_dir.display());
    }
    let quantize_tok = args.get_or("quantize", "f32");
    let quantize = blast_repro::kernels::QuantMode::parse(quantize_tok)
        .ok_or_else(|| anyhow::anyhow!("unknown --quantize mode `{quantize_tok}` (f32|int8)"))?;
    let compressor = Compressor {
        blast_iters: args.get_usize("iters", 120)?,
        seed: args.get_u64("seed", 0)?,
        ..Default::default()
    };
    let pipeline = CompressionPipeline::new(
        compressor,
        PipelineOptions {
            policy,
            ratio,
            jobs: args.get_usize("jobs", 0)?,
            checkpoint_dir: Some(ckpt_dir.clone()),
            max_layers: None,
            quantize,
        },
    );

    let report = if let Some(input) = args.get("in") {
        println!(
            "compressing {} -> {} (policy {}, ratio {:.0}%, resumable via {})",
            input,
            out,
            pipeline.opts.policy.name(),
            ratio * 100.0,
            ckpt_dir.display()
        );
        let (model, report) = pipeline.compress_checkpoint(Path::new(input), Path::new(&out))?;
        // Serving handoff smoke: the written checkpoint must generate.
        let sample = model.generate(&[1, 2, 3], 8);
        println!("handoff check: compressed model generates {sample:?}");
        report
    } else {
        let steps = args.get_usize("steps", 200)?;
        let retrain_steps = args.get_usize("retrain-steps", 100)?;
        println!("no --in given: training a dense TinyLM ({steps} steps) to compress...");
        let corpus = SyntheticCorpus::generate(64, 20_000, 2048);
        let mut rng = Rng::new(args.get_u64("seed", 0)?);
        let mut lm = TinyLM::new(LmConfig::tiny(StructureKind::Dense), &mut rng);
        train_lm(&mut lm, &corpus.train_dataset(), &LmTrainConfig { steps, ..Default::default() });
        let ppl0 = blast_repro::eval::perplexity(&lm, &corpus.valid_dataset(), 32, 8);
        println!("dense valid perplexity: {ppl0:.2}");

        let report = pipeline.compress_model(&mut lm)?;
        let ppl1 = blast_repro::eval::perplexity(&lm, &corpus.valid_dataset(), 32, 8);
        println!("compressed perplexity: {ppl1:.2}");
        if !args.has("no-retrain") {
            blast_repro::train::retrain_lm(&mut lm, &corpus.train_dataset(), retrain_steps);
            let ppl2 = blast_repro::eval::perplexity(&lm, &corpus.valid_dataset(), 32, 8);
            println!("re-trained perplexity: {ppl2:.2}");
        }
        lm.save(&out)?;
        report
    };

    println!(
        "{:<20} {:<24} {:>10} {:>12} {:>12} {:>8}",
        "layer", "structure", "rel err", "params in", "params out", "resumed"
    );
    for l in &report.layers {
        println!(
            "{:<20} {:<24} {:>10.4} {:>12} {:>12} {:>8}",
            l.name,
            l.structure,
            l.rel_error,
            l.params_before,
            l.params_after,
            if l.resumed { "yes" } else { "" }
        );
    }
    let resumed = report.layers.iter().filter(|l| l.resumed).count();
    println!(
        "total: {} -> {} model params ({:.1}% removed), mean rel err {:.4}, {} of {} layers resumed",
        report.params_before,
        report.params_after,
        report.achieved_ratio() * 100.0,
        report.mean_rel_error(),
        resumed,
        report.layers.len()
    );
    if report.quantize == blast_repro::kernels::QuantMode::I8 {
        println!("weights stamped int8 (weight-only packed panels; activations stay f32)");
    }
    println!(
        "wrote {out}; manifest at {}",
        ckpt_dir.join("manifest.json").display()
    );
    Ok(())
}

fn cmd_train(args: &Args) -> Result<()> {
    let structure = parse_structure(args)?;
    let steps = args.get_usize("steps", 200)?;
    let corpus = SyntheticCorpus::generate(64, 20_000, 2048);
    let mut rng = Rng::new(args.get_u64("seed", 0)?);
    let mut lm = TinyLM::new(LmConfig::tiny(structure), &mut rng);
    println!(
        "training {} ({} params, {} linear-FLOPs/token) for {steps} steps",
        structure.name(),
        lm.num_params(),
        lm.flops_per_token()
    );
    let log = train_lm(
        &mut lm,
        &corpus.train_dataset(),
        &LmTrainConfig { steps, log_every: (steps / 10).max(1), ..Default::default() },
    );
    let ppl = blast_repro::eval::perplexity(&lm, &corpus.valid_dataset(), 32, 8);
    println!("final train loss {:.4}, valid perplexity {ppl:.2}", log.final_loss);
    if let Some(path) = args.get("save") {
        lm.save(path)?;
        println!("checkpoint written to {path} (feed it to `blast compress --in {path}`)");
    }
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let n_requests = args.get_usize("requests", 32)?;
    let max_batch = args.get_usize("batch", 8)?;
    let slots = args.get_usize("slots", 8)?;
    let new_tokens = args.get_usize("tokens", 16)?;
    // With --model, serve a compressed `.bmx` checkpoint (the pipeline
    // handoff); otherwise spin up the dense/BLAST demo pair.
    let models = if let Some(path) = args.get("model") {
        let lm = TinyLM::load(path)?;
        println!(
            "loaded {} ({} params, structure {})",
            path,
            lm.num_params(),
            lm.cfg.structure.name()
        );
        vec![("model".to_string(), lm)]
    } else {
        let mut rng = Rng::new(args.get_u64("seed", 0)?);
        vec![
            ("dense".to_string(), TinyLM::new(LmConfig::tiny(StructureKind::Dense), &mut rng)),
            (
                "blast".to_string(),
                TinyLM::new(LmConfig::tiny(StructureKind::Blast { b: 4, r: 8 }), &mut rng),
            ),
        ]
    };
    let vocab = models[0].1.cfg.vocab;
    let mut cfg = CoordinatorConfig::with_max_seqs(slots);
    cfg.batcher.max_batch = max_batch;
    // Speculative decoding: `--spec-gamma N --spec-draft <path|self>`
    // (CLI wins over the BLAST_SPEC_* env knobs baked into the config).
    cfg.engine.spec_gamma = args.get_usize("spec-gamma", cfg.engine.spec_gamma)?;
    if let Some(d) = args.get("spec-draft") {
        cfg.engine.spec_draft = Some(d.to_string());
    }
    let coord = Coordinator::new(models, cfg)?;
    let variants = coord.variants();
    println!("serving variants: {variants:?}");
    let t0 = std::time::Instant::now();
    let mut handles = Vec::new();
    for i in 0..n_requests {
        let variant = &variants[i % variants.len()];
        let prompt = vec![1 + i % vocab.saturating_sub(2).max(1), 2, 3];
        let (_, rx) = coord.submit(variant, prompt, new_tokens)?;
        handles.push(rx);
    }
    let mut tokens = 0usize;
    for rx in handles {
        let resp = rx.recv()?;
        tokens += resp.generated;
    }
    let dt = t0.elapsed();
    println!(
        "{n_requests} requests, {tokens} tokens in {dt:?} ({:.1} tok/s)",
        tokens as f64 / dt.as_secs_f64()
    );
    println!("metrics: {}", coord.metrics.report());
    // Observability surfaces: the Prometheus-style exposition next to
    // the classic report, and the JSON snapshot to BLAST_METRICS_OUT
    // when the operator set it.
    let snap = blast_repro::obs::MetricsSnapshot::collect()
        .with_serving(coord.metrics.snapshot_json());
    println!("--- metrics exposition ---\n{}", snap.to_prometheus());
    if let Some(path) = snap.write_env_out()? {
        println!("metrics snapshot written to {path}");
    }
    coord.shutdown();
    Ok(())
}

/// `blast stats`: drive a short synthetic load through the coordinator,
/// then pretty-print the full metrics snapshot (pack-cache hit/miss,
/// per-signature plan GFLOP/s, KV occupancy, serving latencies) and the
/// text exposition. Honors `BLAST_METRICS_OUT` like `serve` does.
fn cmd_stats(args: &Args) -> Result<()> {
    let n_requests = args.get_usize("requests", 12)?;
    let slots = args.get_usize("slots", 4)?;
    let new_tokens = args.get_usize("tokens", 8)?;
    let models = if let Some(path) = args.get("model") {
        let lm = TinyLM::load(path)?;
        println!(
            "loaded {} ({} params, structure {})",
            path,
            lm.num_params(),
            lm.cfg.structure.name()
        );
        vec![("model".to_string(), lm)]
    } else {
        let mut rng = Rng::new(args.get_u64("seed", 0)?);
        vec![(
            "blast".to_string(),
            TinyLM::new(LmConfig::tiny(StructureKind::Blast { b: 4, r: 8 }), &mut rng),
        )]
    };
    let vocab = models[0].1.cfg.vocab;
    let mut cfg = CoordinatorConfig::with_max_seqs(slots);
    cfg.engine.spec_gamma = args.get_usize("spec-gamma", cfg.engine.spec_gamma)?;
    if let Some(d) = args.get("spec-draft") {
        cfg.engine.spec_draft = Some(d.to_string());
    }
    let coord = Coordinator::new(models, cfg)?;
    let variants = coord.variants();
    println!("self-drive: {n_requests} requests x {new_tokens} tokens...");
    let mut handles = Vec::new();
    for i in 0..n_requests {
        let variant = &variants[i % variants.len()];
        let prompt = vec![1 + i % vocab.saturating_sub(2).max(1), 2, 3];
        let (_, rx) = coord.submit(variant, prompt, new_tokens)?;
        handles.push(rx);
    }
    for rx in handles {
        let _ = rx.recv()?;
    }
    let snap = blast_repro::obs::MetricsSnapshot::collect()
        .with_serving(coord.metrics.snapshot_json());
    println!("{}", snap.to_pretty());
    println!("--- metrics exposition ---\n{}", snap.to_prometheus());
    if let Some(path) = snap.write_env_out()? {
        println!("metrics snapshot written to {path}");
    }
    coord.shutdown();
    Ok(())
}

fn cmd_generate(args: &Args) -> Result<()> {
    let tokens = args.get_usize("tokens", 20)?;
    let lm = if let Some(path) = args.get("model") {
        let lm = TinyLM::load(path)?;
        println!("loaded {} (structure {})", path, lm.cfg.structure.name());
        lm
    } else {
        let structure = parse_structure(args)?;
        let mut rng = Rng::new(args.get_u64("seed", 0)?);
        TinyLM::new(LmConfig::tiny(structure), &mut rng)
    };
    let out = lm.generate(&[1, 2, 3], tokens);
    println!("{out:?}");
    Ok(())
}

fn cmd_info() -> Result<()> {
    println!("blast-repro — BLAST (NeurIPS 2024) reproduction");
    println!("threads: {}", blast_repro::util::par::num_threads());
    match blast_repro::runtime::Manifest::load("artifacts") {
        Ok(m) => {
            println!("artifacts ({}):", m.dir.display());
            for a in &m.artifacts {
                println!(
                    "  {:<28} args={} outputs={} file={}",
                    a.name,
                    a.arg_shapes.len(),
                    a.num_outputs,
                    a.file.file_name().unwrap_or_default().to_string_lossy()
                );
            }
        }
        Err(e) => println!("artifacts: not built ({e}); run `make artifacts`"),
    }
    match blast_repro::runtime::PjrtEngine::cpu() {
        Ok(engine) => println!("PJRT platform: {}", engine.platform()),
        Err(e) => println!("PJRT unavailable: {e}"),
    }
    let ids: Vec<&str> = experiments::registry().iter().map(|e| e.id).collect();
    println!("experiments: {}", ids.join(", "));
    Ok(())
}
