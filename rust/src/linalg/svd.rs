//! Singular value decomposition via one-sided Jacobi rotations, plus a
//! power-iteration estimator for the largest singular value.
//!
//! * `truncated_svd` backs the Low-Rank baseline compressor (the paper
//!   compresses DiT/Llama weights by SVD for its Low-Rank comparison) and
//!   the per-block SVD inside the Monarch baseline.
//! * `sigma_max` backs Theorem 1's step-size rule `η ≤ 1/σ₁(G)`.

use crate::tensor::Matrix;

/// Result of a (thin) SVD: `A = U · diag(s) · V^T`, singular values sorted
/// descending, `U (m×k)`, `V (n×k)` with `k = min(m, n)`.
#[derive(Clone, Debug)]
pub struct Svd {
    pub u: Matrix,
    pub s: Vec<f32>,
    pub v: Matrix,
}

impl Svd {
    /// Reconstruct `U[:, :r] diag(s[:r]) V[:, :r]^T`.
    pub fn reconstruct(&self, r: usize) -> Matrix {
        let r = r.min(self.s.len());
        let m = self.u.rows;
        let n = self.v.rows;
        let mut out = Matrix::zeros(m, n);
        for k in 0..r {
            let sk = self.s[k];
            if sk == 0.0 {
                continue;
            }
            for i in 0..m {
                let uik = self.u.at(i, k) * sk;
                if uik == 0.0 {
                    continue;
                }
                let row = out.row_mut(i);
                for j in 0..n {
                    row[j] += uik * self.v.at(j, k);
                }
            }
        }
        out
    }

    /// Numerical rank at a relative tolerance.
    pub fn rank(&self, rel_tol: f32) -> usize {
        let s0 = self.s.first().copied().unwrap_or(0.0);
        if s0 == 0.0 {
            return 0;
        }
        self.s.iter().take_while(|&&s| s > rel_tol * s0).count()
    }
}

/// Full (thin) SVD by one-sided Jacobi on the working matrix.
///
/// For `m < n` we factor the transpose and swap U/V — one-sided Jacobi
/// orthogonalizes columns, so it wants the tall orientation.
pub fn svd(a: &Matrix) -> Svd {
    let (m, n) = a.shape();
    if m < n {
        let t = svd(&a.transpose());
        return Svd { u: t.v, s: t.s, v: t.u };
    }
    // Work on W = A (m×n), rotating column pairs until convergence.
    let mut w = a.clone();
    let mut v = Matrix::eye(n);
    let max_sweeps = 60;
    let tol = 1e-12f64;

    for _sweep in 0..max_sweeps {
        let mut off = 0.0f64;
        for p in 0..n {
            for q in (p + 1)..n {
                // Compute the 2x2 Gram entries for columns p and q.
                let (mut app, mut aqq, mut apq) = (0.0f64, 0.0f64, 0.0f64);
                for i in 0..m {
                    let wp = w.at(i, p) as f64;
                    let wq = w.at(i, q) as f64;
                    app += wp * wp;
                    aqq += wq * wq;
                    apq += wp * wq;
                }
                off += apq * apq;
                if apq.abs() <= tol * (app * aqq).sqrt() || apq == 0.0 {
                    continue;
                }
                // Jacobi rotation that zeroes the (p,q) Gram entry.
                let tau = (aqq - app) / (2.0 * apq);
                let t = tau.signum() / (tau.abs() + (1.0 + tau * tau).sqrt());
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = c * t;
                let (cf, sf) = (c as f32, s as f32);
                for i in 0..m {
                    let wp = w.at(i, p);
                    let wq = w.at(i, q);
                    w.set(i, p, cf * wp - sf * wq);
                    w.set(i, q, sf * wp + cf * wq);
                }
                for i in 0..n {
                    let vp = v.at(i, p);
                    let vq = v.at(i, q);
                    v.set(i, p, cf * vp - sf * vq);
                    v.set(i, q, sf * vp + cf * vq);
                }
            }
        }
        let wnorm = w.fro_norm_sq();
        if off <= (tol * wnorm).max(f64::MIN_POSITIVE) {
            break;
        }
    }

    // Singular values = column norms of W; U = W with normalized columns.
    let mut s: Vec<f32> = (0..n)
        .map(|j| {
            let mut acc = 0.0f64;
            for i in 0..m {
                let x = w.at(i, j) as f64;
                acc += x * x;
            }
            acc.sqrt() as f32
        })
        .collect();

    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&i, &j| s[j].partial_cmp(&s[i]).unwrap());

    let mut u = Matrix::zeros(m, n);
    let mut v_sorted = Matrix::zeros(n, n);
    let mut s_sorted = vec![0.0f32; n];
    for (new_j, &old_j) in order.iter().enumerate() {
        let sv = s[old_j];
        s_sorted[new_j] = sv;
        let inv = if sv > 1e-20 { 1.0 / sv } else { 0.0 };
        for i in 0..m {
            u.set(i, new_j, w.at(i, old_j) * inv);
        }
        for i in 0..n {
            v_sorted.set(i, new_j, v.at(i, old_j));
        }
    }
    s = s_sorted;
    Svd { u, s, v: v_sorted }
}

/// Rank-`r` truncated SVD: returns `(U_r·diag(s_r)^{1/2}, V_r·diag(s_r)^{1/2})`
/// style factors? No — returns the plain `(U_r, s_r, V_r)` triple packed in
/// an `Svd` with only `r` columns kept.
pub fn truncated_svd(a: &Matrix, r: usize) -> Svd {
    let full = svd(a);
    let k = r.min(full.s.len());
    let u = full.u.submatrix(0, full.u.rows, 0, k);
    let v = full.v.submatrix(0, full.v.rows, 0, k);
    let s = full.s[..k].to_vec();
    Svd { u, s, v }
}

/// Largest singular value of `A`, estimated by power iteration on `A^T A`.
///
/// Deterministic start vector (ones + small index perturbation) keeps runs
/// reproducible; 100 iterations with a relative tolerance of 1e-6 is far
/// more than enough for a step-size bound.
pub fn sigma_max(a: &Matrix) -> f32 {
    let (m, n) = a.shape();
    if m == 0 || n == 0 {
        return 0.0;
    }
    let mut x: Vec<f32> = (0..n).map(|i| 1.0 + 1e-3 * (i as f32).sin()).collect();
    normalize(&mut x);
    let mut prev = 0.0f64;
    for _ in 0..100 {
        // y = A x ; z = A^T y
        let y = crate::tensor::gemv(a, &x);
        let z = crate::tensor::ops::gemv_t(a, &y);
        let lambda = norm(&z);
        if lambda == 0.0 {
            return 0.0;
        }
        x = z;
        normalize(&mut x);
        let sigma = lambda.sqrt();
        if (sigma - prev).abs() <= 1e-6 * sigma.max(1e-30) {
            return sigma as f32;
        }
        prev = sigma;
    }
    prev as f32
}

/// Largest eigenvalue of a symmetric PSD matrix (for the `σ₁(G^T G)`-style
/// bounds where `G^T G` is already formed).
pub fn lambda_max_psd(g: &Matrix) -> f32 {
    assert_eq!(g.rows, g.cols);
    let n = g.rows;
    if n == 0 {
        return 0.0;
    }
    let mut x: Vec<f32> = (0..n).map(|i| 1.0 + 1e-3 * (i as f32).cos()).collect();
    normalize(&mut x);
    let mut prev = 0.0f64;
    for _ in 0..100 {
        let y = crate::tensor::gemv(g, &x);
        let lambda = norm(&y);
        if lambda == 0.0 {
            return 0.0;
        }
        x = y;
        normalize(&mut x);
        if (lambda - prev).abs() <= 1e-7 * lambda.max(1e-30) {
            return lambda as f32;
        }
        prev = lambda;
    }
    prev as f32
}

fn norm(x: &[f32]) -> f64 {
    x.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>().sqrt()
}

fn normalize(x: &mut [f32]) {
    let n = norm(x);
    if n > 0.0 {
        let inv = (1.0 / n) as f32;
        for v in x.iter_mut() {
            *v *= inv;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::{matmul, matmul_tn, Rng};

    fn assert_close(a: &Matrix, b: &Matrix, tol: f32) {
        assert_eq!(a.shape(), b.shape());
        let scale = 1.0f32.max(b.max_abs());
        for (x, y) in a.data.iter().zip(&b.data) {
            assert!((x - y).abs() <= tol * scale, "{x} vs {y}");
        }
    }

    #[test]
    fn reconstructs_square() {
        let mut rng = Rng::new(20);
        let a = rng.gaussian_matrix(12, 12, 1.0);
        let d = svd(&a);
        assert_close(&d.reconstruct(12), &a, 1e-3);
    }

    #[test]
    fn reconstructs_tall_and_wide() {
        let mut rng = Rng::new(21);
        let tall = rng.gaussian_matrix(20, 7, 1.0);
        let d = svd(&tall);
        assert_close(&d.reconstruct(7), &tall, 1e-3);

        let wide = rng.gaussian_matrix(6, 19, 1.0);
        let d = svd(&wide);
        assert_close(&d.reconstruct(6), &wide, 1e-3);
    }

    #[test]
    fn singular_values_sorted_and_orthonormal() {
        let mut rng = Rng::new(22);
        let a = rng.gaussian_matrix(15, 10, 1.0);
        let d = svd(&a);
        for w in d.s.windows(2) {
            assert!(w[0] >= w[1] - 1e-5);
        }
        let utu = matmul_tn(&d.u, &d.u);
        let vtv = matmul_tn(&d.v, &d.v);
        assert_close(&utu, &Matrix::eye(10), 1e-3);
        assert_close(&vtv, &Matrix::eye(10), 1e-3);
    }

    #[test]
    fn exact_rank_detected() {
        // Build a rank-3 matrix.
        let mut rng = Rng::new(23);
        let u = rng.gaussian_matrix(16, 3, 1.0);
        let v = rng.gaussian_matrix(12, 3, 1.0);
        let a = matmul(&u, &v.transpose());
        let d = svd(&a);
        assert_eq!(d.rank(1e-4), 3);
        // Truncation at r=3 is (numerically) exact.
        let rec = truncated_svd(&a, 3).reconstruct(3);
        assert!(rec.sub(&a).fro_norm() / a.fro_norm() < 1e-3);
    }

    #[test]
    fn truncation_error_decreases() {
        let mut rng = Rng::new(24);
        let a = rng.gaussian_matrix(20, 20, 1.0);
        let mut prev = f32::INFINITY;
        for r in [1, 3, 6, 12, 20] {
            let err = truncated_svd(&a, r).reconstruct(r).sub(&a).fro_norm();
            assert!(err <= prev + 1e-4, "r={r}: {err} > {prev}");
            prev = err;
        }
        assert!(prev < 1e-2 * a.fro_norm());
    }

    #[test]
    fn eckart_young_optimality_vs_random() {
        // SVD truncation must beat a random projection of the same rank.
        let mut rng = Rng::new(25);
        let a = rng.gaussian_matrix(18, 18, 1.0);
        let svd_err = truncated_svd(&a, 4).reconstruct(4).sub(&a).fro_norm();
        let q = rng.gaussian_matrix(18, 4, 1.0);
        let (qq, _) = crate::linalg::qr_decompose(&q);
        let proj = matmul(&qq, &matmul_tn(&qq, &a));
        let rand_err = proj.sub(&a).fro_norm();
        assert!(svd_err <= rand_err + 1e-5);
    }

    #[test]
    fn sigma_max_matches_svd() {
        let mut rng = Rng::new(26);
        for &(m, n) in &[(10, 10), (24, 8), (7, 30)] {
            let a = rng.gaussian_matrix(m, n, 1.0);
            let d = svd(&a);
            let est = sigma_max(&a);
            assert!(
                (est - d.s[0]).abs() < 1e-2 * d.s[0],
                "sigma_max {est} vs svd {}",
                d.s[0]
            );
        }
    }

    #[test]
    fn lambda_max_psd_matches() {
        let mut rng = Rng::new(27);
        let a = rng.gaussian_matrix(14, 9, 1.0);
        let g = matmul_tn(&a, &a); // A^T A is PSD
        let lam = lambda_max_psd(&g);
        let sig = sigma_max(&a);
        assert!((lam - sig * sig).abs() < 2e-2 * (sig * sig));
    }

    #[test]
    fn zero_matrix() {
        let a = Matrix::zeros(5, 5);
        let d = svd(&a);
        assert!(d.s.iter().all(|&s| s == 0.0));
        assert_eq!(sigma_max(&a), 0.0);
        assert_eq!(d.rank(1e-6), 0);
    }
}
