//! Cholesky factorization and SPD solves.
//!
//! The PrecGD preconditioners of Eqs. 8–9 are inverses of regularized Gram
//! matrices `G + δI` (always symmetric positive definite for δ > 0), so a
//! Cholesky solve is the right tool — O(r³/3) and unconditionally stable
//! here.

use crate::tensor::Matrix;
use anyhow::{bail, Result};

/// Lower-triangular Cholesky factor `L` with `A = L · L^T`.
/// Errors if `A` is not (numerically) positive definite.
pub fn cholesky(a: &Matrix) -> Result<Matrix> {
    assert_eq!(a.rows, a.cols, "cholesky needs a square matrix");
    let n = a.rows;
    let mut l = Matrix::zeros(n, n);
    for i in 0..n {
        for j in 0..=i {
            let mut sum = a.at(i, j) as f64;
            for k in 0..j {
                sum -= (l.at(i, k) as f64) * (l.at(j, k) as f64);
            }
            if i == j {
                if sum <= 0.0 {
                    bail!("matrix not positive definite at pivot {i} (value {sum})");
                }
                l.set(i, j, sum.sqrt() as f32);
            } else {
                l.set(i, j, (sum / l.at(j, j) as f64) as f32);
            }
        }
    }
    Ok(l)
}

/// Solve `A X = B` for SPD `A` via Cholesky (`X` returned, `B` is n×k).
pub fn spd_solve_matrix(a: &Matrix, b: &Matrix) -> Result<Matrix> {
    let l = cholesky(a)?;
    let n = a.rows;
    assert_eq!(b.rows, n);
    let k = b.cols;
    // Forward substitution: L Y = B.
    let mut y = Matrix::zeros(n, k);
    for i in 0..n {
        for c in 0..k {
            let mut sum = b.at(i, c) as f64;
            for t in 0..i {
                sum -= (l.at(i, t) as f64) * (y.at(t, c) as f64);
            }
            y.set(i, c, (sum / l.at(i, i) as f64) as f32);
        }
    }
    // Back substitution: L^T X = Y.
    let mut x = Matrix::zeros(n, k);
    for i in (0..n).rev() {
        for c in 0..k {
            let mut sum = y.at(i, c) as f64;
            for t in (i + 1)..n {
                sum -= (l.at(t, i) as f64) * (x.at(t, c) as f64);
            }
            x.set(i, c, (sum / l.at(i, i) as f64) as f32);
        }
    }
    Ok(x)
}

/// Inverse of an SPD matrix (used for the preconditioners `P = (G+δI)^{-1}`
/// of Eqs. 8–9 when we want the explicit matrix; prefer `spd_solve_matrix`
/// when only the product is needed).
pub fn spd_inverse(a: &Matrix) -> Result<Matrix> {
    spd_solve_matrix(a, &Matrix::eye(a.rows))
}

/// Solve `x A = b` from the right for SPD `A`, i.e. returns `B · A^{-1}`
/// where `B` is k×n. Equivalent to solving `A X^T = B^T`.
pub fn spd_solve_right(b: &Matrix, a: &Matrix) -> Result<Matrix> {
    let xt = spd_solve_matrix(a, &b.transpose())?;
    Ok(xt.transpose())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::{matmul, matmul_nt, matmul_tn, Rng};

    fn spd(n: usize, seed: u64) -> Matrix {
        let mut rng = Rng::new(seed);
        let g = rng.gaussian_matrix(n + 4, n, 1.0);
        let mut a = matmul_tn(&g, &g);
        for i in 0..n {
            *a.at_mut(i, i) += 0.5; // keep well-conditioned
        }
        a
    }

    fn assert_close(a: &Matrix, b: &Matrix, tol: f32) {
        assert_eq!(a.shape(), b.shape());
        let scale = 1.0f32.max(b.max_abs());
        for (x, y) in a.data.iter().zip(&b.data) {
            assert!((x - y).abs() <= tol * scale, "{x} vs {y}");
        }
    }

    #[test]
    fn cholesky_reconstructs() {
        let a = spd(8, 40);
        let l = cholesky(&a).unwrap();
        assert_close(&matmul_nt(&l, &l), &a, 1e-4);
        // Strictly upper part must be zero.
        for i in 0..8 {
            for j in (i + 1)..8 {
                assert_eq!(l.at(i, j), 0.0);
            }
        }
    }

    #[test]
    fn solve_matches_direct() {
        let a = spd(10, 41);
        let mut rng = Rng::new(42);
        let b = rng.gaussian_matrix(10, 3, 1.0);
        let x = spd_solve_matrix(&a, &b).unwrap();
        assert_close(&matmul(&a, &x), &b, 1e-3);
    }

    #[test]
    fn inverse_is_inverse() {
        let a = spd(6, 43);
        let inv = spd_inverse(&a).unwrap();
        assert_close(&matmul(&a, &inv), &Matrix::eye(6), 1e-3);
        assert_close(&matmul(&inv, &a), &Matrix::eye(6), 1e-3);
    }

    #[test]
    fn right_solve() {
        let a = spd(7, 44);
        let mut rng = Rng::new(45);
        let b = rng.gaussian_matrix(4, 7, 1.0);
        let x = spd_solve_right(&b, &a).unwrap();
        assert_close(&matmul(&x, &a), &b, 1e-3);
    }

    #[test]
    fn non_pd_rejected() {
        let mut a = Matrix::eye(3);
        a.set(2, 2, -1.0);
        assert!(cholesky(&a).is_err());
    }

    #[test]
    fn regularization_rescues_singular() {
        // Rank-1 Gram matrix is singular; + δI makes it solvable — exactly
        // the Eq. 8/9 situation.
        let v = Matrix::from_vec(3, 1, vec![1.0, 2.0, 3.0]);
        let mut g = matmul_nt(&v, &v);
        assert!(cholesky(&g).is_err() || g.at(0, 0) > 0.0); // singular case
        for i in 0..3 {
            *g.at_mut(i, i) += 0.1;
        }
        assert!(cholesky(&g).is_ok());
    }
}
