//! Householder QR decomposition.
//!
//! Used for orthonormalizing bases (sanity checks on SVD output) and by
//! the Monarch baseline's per-block factorization.

use crate::tensor::Matrix;

/// Thin QR: for `A (m×n)` with `m >= n`, returns `(Q, R)` with
/// `Q (m×n)` having orthonormal columns and `R (n×n)` upper triangular,
/// so `A = Q · R`.
pub fn qr_decompose(a: &Matrix) -> (Matrix, Matrix) {
    let (m, n) = a.shape();
    assert!(m >= n, "thin QR requires rows >= cols, got {m}x{n}");
    // Work on a column-major copy of A stored as R (m×n), accumulating
    // Householder reflectors.
    let mut r = a.clone();
    // Store reflector vectors; v[k] has length m-k.
    let mut reflectors: Vec<Vec<f32>> = Vec::with_capacity(n);

    for k in 0..n {
        // Build the Householder vector for column k below the diagonal.
        let mut norm_sq = 0.0f64;
        for i in k..m {
            let x = r.at(i, k) as f64;
            norm_sq += x * x;
        }
        let norm = norm_sq.sqrt() as f32;
        let x0 = r.at(k, k);
        let alpha = if x0 >= 0.0 { -norm } else { norm };
        let mut v: Vec<f32> = (k..m).map(|i| r.at(i, k)).collect();
        v[0] -= alpha;
        let vnorm_sq: f64 = v.iter().map(|&x| (x as f64) * (x as f64)).sum();
        if vnorm_sq > 1e-30 {
            // Apply H = I - 2 v v^T / (v^T v) to R[k.., k..].
            for j in k..n {
                let mut dot = 0.0f64;
                for (vi, i) in v.iter().zip(k..m) {
                    dot += (*vi as f64) * (r.at(i, j) as f64);
                }
                let scale = (2.0 * dot / vnorm_sq) as f32;
                for (vi, i) in v.iter().zip(k..m) {
                    *r.at_mut(i, j) -= scale * vi;
                }
            }
        }
        reflectors.push(v);
    }

    // Q = H_0 H_1 ... H_{n-1} applied to the first n columns of I.
    let mut q = Matrix::zeros(m, n);
    for j in 0..n {
        q.set(j, j, 1.0);
    }
    for k in (0..n).rev() {
        let v = &reflectors[k];
        let vnorm_sq: f64 = v.iter().map(|&x| (x as f64) * (x as f64)).sum();
        if vnorm_sq <= 1e-30 {
            continue;
        }
        for j in 0..n {
            let mut dot = 0.0f64;
            for (vi, i) in v.iter().zip(k..m) {
                dot += (*vi as f64) * (q.at(i, j) as f64);
            }
            let scale = (2.0 * dot / vnorm_sq) as f32;
            for (vi, i) in v.iter().zip(k..m) {
                *q.at_mut(i, j) -= scale * vi;
            }
        }
    }

    // Zero the strictly-lower part of R and truncate to n×n.
    let mut r_out = Matrix::zeros(n, n);
    for i in 0..n {
        for j in i..n {
            r_out.set(i, j, r.at(i, j));
        }
    }
    (q, r_out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::{matmul, matmul_tn, Rng};

    fn assert_close(a: &Matrix, b: &Matrix, tol: f32) {
        assert_eq!(a.shape(), b.shape());
        let scale = 1.0f32.max(b.max_abs());
        for (x, y) in a.data.iter().zip(&b.data) {
            assert!((x - y).abs() <= tol * scale, "{x} vs {y}");
        }
    }

    #[test]
    fn reconstructs() {
        let mut rng = Rng::new(10);
        for &(m, n) in &[(4, 4), (10, 6), (33, 17), (64, 64)] {
            let a = rng.gaussian_matrix(m, n, 1.0);
            let (q, r) = qr_decompose(&a);
            assert_close(&matmul(&q, &r), &a, 1e-4);
        }
    }

    #[test]
    fn q_orthonormal() {
        let mut rng = Rng::new(11);
        let a = rng.gaussian_matrix(20, 12, 1.0);
        let (q, _) = qr_decompose(&a);
        let qtq = matmul_tn(&q, &q);
        assert_close(&qtq, &Matrix::eye(12), 1e-4);
    }

    #[test]
    fn r_upper_triangular() {
        let mut rng = Rng::new(12);
        let a = rng.gaussian_matrix(9, 9, 1.0);
        let (_, r) = qr_decompose(&a);
        for i in 0..9 {
            for j in 0..i {
                assert_eq!(r.at(i, j), 0.0);
            }
        }
    }

    #[test]
    fn rank_deficient_ok() {
        // Column 1 = 2 * column 0: QR must still reconstruct.
        let a = Matrix::from_fn(6, 3, |i, j| match j {
            0 => (i + 1) as f32,
            1 => 2.0 * (i + 1) as f32,
            _ => (i as f32).sin(),
        });
        let (q, r) = qr_decompose(&a);
        assert_close(&matmul(&q, &r), &a, 1e-4);
    }
}
