//! Numerical linear algebra substrate.
//!
//! The factorization algorithms (Algorithm 2, baseline compressors) need:
//! * truncated SVD — the low-rank / Monarch baselines compress via SVD;
//! * SPD solves — the preconditioners of Eqs. 8–9 are
//!   `(G^T G + δI)^{-1}`, computed via Cholesky;
//! * largest-singular-value estimates — Theorem 1's step-size rule is
//!   `η ≤ 1/σ₁(·)`, estimated by power iteration.
//!
//! Everything is implemented from scratch (no BLAS/LAPACK dependency).

pub mod qr;
pub mod svd;
pub mod solve;

pub use qr::qr_decompose;
pub use solve::{cholesky, spd_inverse, spd_solve_matrix};
pub use svd::{sigma_max, svd, truncated_svd, Svd};
