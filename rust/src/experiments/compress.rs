//! Compression + re-training experiments on vision models:
//! Fig. 6 (ViT accuracy–FLOPs after compress+retrain), Table 2 (DiT
//! FID/sFID/IS at 50 % CR), Fig. 1 (qualitative shared-noise samples).

use crate::data::diffusion::DiffusionDataset;
use crate::data::images::TextureDataset;
use crate::factorize::{Compressor, Structure};
use crate::nn::attention::StructureKind;
use crate::nn::dit::{Ddpm, DitConfig, TinyDiT};
use crate::nn::param::AdamW;
use crate::nn::vit::{TinyViT, VitConfig};
use crate::tensor::{Matrix, Rng};
use crate::train::vit_trainer::{eval_vit_accuracy, train_vit, VitTrainConfig};
use anyhow::Result;

fn vit_cfg() -> VitConfig {
    VitConfig { n_classes: 4, ..VitConfig::tiny(StructureKind::Dense) }
}

/// Compress every transformer linear of a ViT in place through the
/// parallel layer queue; returns the per-layer reconstruction errors of
/// the layers that met the budget.
fn compress_vit(vit: &mut TinyViT, s: Structure, ratio: f64, comp: &Compressor) -> Vec<f64> {
    let mut named = Vec::new();
    for (i, blk) in vit.blocks.iter_mut().enumerate() {
        named.push((format!("block{i}.attn.wqkv"), &mut blk.attn.wqkv));
        named.push((format!("block{i}.attn.wo"), &mut blk.attn.wo));
        named.push((format!("block{i}.fc1"), &mut blk.fc1));
        named.push((format!("block{i}.fc2"), &mut blk.fc2));
    }
    crate::factorize::compress_linears_parallel(named, comp, s, ratio)
        .into_iter()
        .flatten()
        .collect()
}

/// Fig. 6 — ViT compress + retrain accuracy–FLOPs curves.
pub fn fig6(scale: usize) -> Result<()> {
    let (train_steps, retrain_steps, blast_iters, eval_n) = match scale {
        0 => (60, 25, 15, 5),
        1 => (300, 120, 80, 25),
        _ => (800, 300, 200, 50),
    };
    let data = TextureDataset::new(16, 4);
    // Train the dense reference once.
    let mut rng = Rng::new(1400);
    let mut dense = TinyViT::new(vit_cfg(), &mut rng);
    train_vit(&mut dense, &data, &VitTrainConfig { steps: train_steps, ..Default::default() });
    let dense_acc = eval_vit_accuracy(&dense, &data, eval_n, 3);
    println!("dense reference accuracy: {dense_acc:.1}%");
    println!(
        "{:<24} {:>6} {:>14} {:>14} {:>14}",
        "structure", "CR(%)", "acc compressed", "acc retrained", "mean rel err"
    );

    let comp = Compressor { blast_iters, ..Default::default() };
    for ratio in [0.3, 0.5] {
        for s in [
            Structure::LowRank,
            Structure::Monarch { b: 4 },
            Structure::BlockDiag { b: 4 },
            Structure::Blast { b: 4 },
        ] {
            let mut m = dense.clone();
            let errs = compress_vit(&mut m, s, ratio, &comp);
            let mean_err: f64 = errs.iter().sum::<f64>() / errs.len().max(1) as f64;
            let acc_comp = eval_vit_accuracy(&m, &data, eval_n, 3);
            train_vit(
                &mut m,
                &data,
                &VitTrainConfig { steps: retrain_steps, lr: 5e-4, ..Default::default() },
            );
            let acc_re = eval_vit_accuracy(&m, &data, eval_n, 3);
            println!(
                "{:<24} {:>6.0} {:>14.1} {:>14.1} {:>14.4}",
                s.name(),
                ratio * 100.0,
                acc_comp,
                acc_re,
                mean_err
            );
        }
    }
    Ok(())
}

// ---------------------------------------------------------------------
// DiT experiments
// ---------------------------------------------------------------------

/// Train a dense TinyDiT on the synthetic distribution.
fn train_dit(steps: usize, seed: u64) -> (TinyDiT, Ddpm, DiffusionDataset) {
    let mut rng = Rng::new(seed);
    let mut dit = TinyDiT::new(DitConfig::tiny(StructureKind::Dense), &mut rng);
    let ddpm = Ddpm::new(dit.cfg.n_timesteps);
    let ds = DiffusionDataset::new(dit.cfg.img, dit.cfg.n_classes);
    let mut opt = AdamW::new(2e-3, 0.0);
    let mut data_rng = Rng::new(seed ^ 0xD1F);
    for step in 0..steps {
        dit.zero_grads();
        let mut loss = 0.0;
        for _ in 0..4 {
            let c = data_rng.below(ds.n_classes);
            let x0 = ds.sample(c, &mut data_rng);
            loss += dit.train_example(&ddpm, &x0, c, &mut data_rng);
        }
        for p in dit.params_mut() {
            p.g.scale_inplace(0.25);
        }
        let lr = 2e-3 * (1.0 - step as f32 / steps as f32).max(0.05);
        opt.step(&mut dit.params_mut(), lr);
        let _ = loss;
    }
    (dit, ddpm, ds)
}

fn compress_dit(dit: &mut TinyDiT, s: Structure, ratio: f64, comp: &Compressor) {
    // Paper Table 7: compress QKV, FC1, adaLN projections — through the
    // parallel layer queue.
    let mut named = Vec::new();
    for (i, blk) in dit.blocks.iter_mut().enumerate() {
        named.push((format!("block{i}.attn.wqkv"), &mut blk.attn.wqkv));
        named.push((format!("block{i}.fc1"), &mut blk.fc1));
    }
    named.push(("adaln_proj".to_string(), &mut dit.adaln_proj));
    crate::factorize::compress_linears_parallel(named, comp, s, ratio);
}

fn retrain_dit(dit: &mut TinyDiT, ddpm: &Ddpm, ds: &DiffusionDataset, steps: usize, seed: u64) {
    let mut opt = AdamW::new(5e-4, 0.0);
    let mut rng = Rng::new(seed);
    for _ in 0..steps {
        dit.zero_grads();
        for _ in 0..4 {
            let c = rng.below(ds.n_classes);
            let x0 = ds.sample(c, &mut rng);
            dit.train_example(ddpm, &x0, c, &mut rng);
        }
        for p in dit.params_mut() {
            p.g.scale_inplace(0.25);
        }
        opt.step(&mut dit.params_mut(), 5e-4);
    }
}

/// Sample a pool of images (rows = samples) class-balanced from a model.
fn sample_pool(dit: &TinyDiT, ddpm: &Ddpm, n: usize, seed: u64) -> (Matrix, Vec<usize>) {
    let mut rng = Rng::new(seed);
    let dim = dit.cfg.img * dit.cfg.img;
    let mut pool = Matrix::zeros(n, dim);
    let mut classes = Vec::with_capacity(n);
    for i in 0..n {
        let c = i % dit.cfg.n_classes;
        let noise: Vec<f32> = (0..dim).map(|_| rng.gaussian()).collect();
        let img = dit.sample(ddpm, &noise, c);
        pool.row_mut(i).copy_from_slice(&img);
        classes.push(c);
    }
    (pool, classes)
}

/// Reference pool straight from the data distribution.
fn reference_pool(ds: &DiffusionDataset, n: usize, seed: u64) -> Matrix {
    let mut rng = Rng::new(seed);
    let dim = ds.img * ds.img;
    let mut pool = Matrix::zeros(n, dim);
    for i in 0..n {
        let c = i % ds.n_classes;
        let x = ds.sample(c, &mut rng);
        pool.row_mut(i).copy_from_slice(&x);
    }
    pool
}

/// IS analogue via a nearest-class-mean probe on the data distribution.
fn is_analogue(pool: &Matrix, ds: &DiffusionDataset, seed: u64) -> f64 {
    // Class means from the true distribution.
    let mut rng = Rng::new(seed);
    let dim = ds.img * ds.img;
    let mut means = vec![vec![0.0f64; dim]; ds.n_classes];
    for (c, mean) in means.iter_mut().enumerate() {
        for _ in 0..40 {
            let x = ds.sample(c, &mut rng);
            for (m, v) in mean.iter_mut().zip(&x) {
                *m += *v as f64 / 40.0;
            }
        }
    }
    // Soft class probabilities ∝ exp(-distance).
    let mut probs = Matrix::zeros(pool.rows, ds.n_classes);
    for i in 0..pool.rows {
        let row = pool.row(i);
        let mut logits = vec![0.0f64; ds.n_classes];
        for (c, mean) in means.iter().enumerate() {
            let d: f64 = row
                .iter()
                .zip(mean)
                .map(|(a, b)| (*a as f64 - b).powi(2))
                .sum();
            logits[c] = -d / 2.0;
        }
        let max = logits.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        let z: f64 = logits.iter().map(|l| (l - max).exp()).sum();
        for c in 0..ds.n_classes {
            probs.set(i, c, ((logits[c] - max).exp() / z) as f32);
        }
    }
    crate::eval::inception_score_analogue(&probs)
}

/// Table 2 — DiT compression at 50 % CR: Original vs Low-Rank vs BLAST.
pub fn table2(scale: usize) -> Result<()> {
    let (train_steps, retrain_steps, blast_iters, pool_n) = match scale {
        0 => (120, 40, 15, 24),
        1 => (600, 200, 80, 64),
        _ => (2000, 600, 200, 200),
    };
    let (dit, ddpm, ds) = train_dit(train_steps, 1500);
    let reference = reference_pool(&ds, pool_n, 77);
    let comp = Compressor { blast_iters, ..Default::default() };

    println!(
        "{:<4} {:<12} {:>10} {:>10} {:>8}",
        "CR", "Method", "FID(↓)", "sFID(↓)", "IS(↑)"
    );
    let mut report = |label: &str, cr: f64, model: &TinyDiT| {
        let (pool, _) = sample_pool(model, &ddpm, pool_n, 99);
        let fid = crate::eval::fid::fid_between(&pool, &reference);
        let sfid = crate::eval::sfid_analogue(&pool, &reference, ds.img);
        let is = is_analogue(&pool, &ds, 31);
        println!(
            "{:<4.0} {:<12} {:>10.3} {:>10.3} {:>8.3}",
            cr * 100.0,
            label,
            fid,
            sfid,
            is
        );
        fid
    };

    let fid_orig = report("Original", 0.0, &dit);

    let mut lowrank = dit.clone();
    compress_dit(&mut lowrank, Structure::LowRank, 0.5, &comp);
    retrain_dit(&mut lowrank, &ddpm, &ds, retrain_steps, 2000);
    let fid_lr = report("Low-Rank", 0.5, &lowrank);

    let mut blast = dit.clone();
    compress_dit(&mut blast, Structure::Blast { b: 4 }, 0.5, &comp);
    retrain_dit(&mut blast, &ddpm, &ds, retrain_steps, 2000);
    let fid_blast = report("BLAST4", 0.5, &blast);

    println!(
        "shape check — paper: FID(BLAST) ≈ FID(orig) << FID(low-rank); got {:.3} / {:.3} / {:.3}",
        fid_blast, fid_orig, fid_lr
    );
    Ok(())
}

/// Fig. 1 — qualitative: shared-noise samples from original / low-rank /
/// BLAST models written as PGM images + per-sample MSE vs the original.
pub fn fig1(scale: usize) -> Result<()> {
    let (train_steps, retrain_steps, blast_iters) = match scale {
        0 => (120, 40, 15),
        1 => (600, 200, 80),
        _ => (2000, 600, 200),
    };
    let (dit, ddpm, ds) = train_dit(train_steps, 1500);
    let comp = Compressor { blast_iters, ..Default::default() };
    let mut lowrank = dit.clone();
    compress_dit(&mut lowrank, Structure::LowRank, 0.5, &comp);
    retrain_dit(&mut lowrank, &ddpm, &ds, retrain_steps, 2000);
    let mut blast = dit.clone();
    compress_dit(&mut blast, Structure::Blast { b: 4 }, 0.5, &comp);
    retrain_dit(&mut blast, &ddpm, &ds, retrain_steps, 2000);

    let out_dir = std::path::Path::new("experiments_out/fig1");
    std::fs::create_dir_all(out_dir)?;
    let mut rng = Rng::new(123);
    let dim = dit.cfg.img * dit.cfg.img;
    println!("{:<8} {:>14} {:>14}", "class", "MSE low-rank", "MSE BLAST");
    let mut mse_lr_total = 0.0;
    let mut mse_bl_total = 0.0;
    for c in 0..ds.n_classes {
        let noise: Vec<f32> = (0..dim).map(|_| rng.gaussian()).collect();
        let img_orig = dit.sample(&ddpm, &noise, c);
        let img_lr = lowrank.sample(&ddpm, &noise, c);
        let img_bl = blast.sample(&ddpm, &noise, c);
        write_pgm(&out_dir.join(format!("class{c}_original.pgm")), &img_orig, dit.cfg.img)?;
        write_pgm(&out_dir.join(format!("class{c}_lowrank.pgm")), &img_lr, dit.cfg.img)?;
        write_pgm(&out_dir.join(format!("class{c}_blast.pgm")), &img_bl, dit.cfg.img)?;
        let mse = |a: &[f32], b: &[f32]| -> f64 {
            a.iter().zip(b).map(|(x, y)| ((x - y) as f64).powi(2)).sum::<f64>() / a.len() as f64
        };
        let m_lr = mse(&img_orig, &img_lr);
        let m_bl = mse(&img_orig, &img_bl);
        mse_lr_total += m_lr;
        mse_bl_total += m_bl;
        println!("{:<8} {:>14.4} {:>14.4}", c, m_lr, m_bl);
    }
    println!(
        "totals: low-rank {:.4} vs BLAST {:.4} (paper: BLAST preserves instance-wise resemblance)",
        mse_lr_total, mse_bl_total
    );
    println!("PGM sample grids written to {}", out_dir.display());
    Ok(())
}

fn write_pgm(path: &std::path::Path, img: &[f32], side: usize) -> Result<()> {
    let mut out = format!("P2\n{side} {side}\n255\n");
    let (lo, hi) = img
        .iter()
        .fold((f32::INFINITY, f32::NEG_INFINITY), |(l, h), &v| (l.min(v), h.max(v)));
    let span = (hi - lo).max(1e-6);
    for i in 0..side {
        for j in 0..side {
            let v = ((img[i * side + j] - lo) / span * 255.0) as u8;
            out.push_str(&format!("{v} "));
        }
        out.push('\n');
    }
    std::fs::write(path, out)?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dit_pipeline_smoke() {
        // Train briefly, compress both ways, verify the models sample
        // finite images and BLAST's layer error is lower than low-rank's
        // at the same budget (the Table 2 mechanism).
        let (dit, ddpm, ds) = train_dit(40, 1500);
        let comp = Compressor { blast_iters: 15, ..Default::default() };

        let dense_w = dit.blocks[0].attn.wqkv.dense_weight();
        let lr = comp.compress(&dense_w, Structure::LowRank, 0.5).unwrap();
        let bl = comp.compress(&dense_w, Structure::Blast { b: 4 }, 0.5).unwrap();
        assert!(bl.rel_error(&dense_w) <= lr.rel_error(&dense_w) * 1.5);

        let mut m = dit.clone();
        compress_dit(&mut m, Structure::Blast { b: 4 }, 0.5, &comp);
        let noise: Vec<f32> = (0..64).map(|i| ((i * 7) as f32).sin()).collect();
        let img = m.sample(&ddpm, &noise, 0);
        assert!(img.iter().all(|v| v.is_finite()));
        let _ = ds;
    }
}
