//! Table 4 — decode runtime vs compression ratio and block count.
//!
//! The paper measures Llama-7B text-generation wall-clock at L ∈ {10,
//! 100, 1000} for CR ∈ {0, 20, 50} and b ∈ {2, 16} on an A100 and
//! attributes the speedup to reduced memory traffic. We reproduce the
//! *shape* two ways:
//!
//! 1. `table4` — end-to-end decode through the serving coordinator with
//!    Rust-native models at TinyLM scale (CPU is also bandwidth-bound on
//!    the weight streams);
//! 2. `matvec_sweep` — raw matvec at the paper's real Llama shapes
//!    (4096×4096 / 11008×4096, b ∈ {2, 16}), where the parameter-count
//!    reduction maps directly to runtime.

use crate::blast::{blast_rank_for_ratio, BlastMatrix};
use crate::nn::attention::StructureKind;
use crate::nn::gpt::{LmConfig, TinyLM};
use crate::tensor::{Matrix, Rng};
use anyhow::Result;
use std::time::Instant;

/// Time one full generation of `l` tokens (mean of `reps` runs).
fn time_generate(model: &TinyLM, l: usize, reps: usize) -> f64 {
    let prompt = [1usize, 2, 3];
    // Warmup.
    let _ = model.generate(&prompt, 2);
    let mut total = 0.0;
    for _ in 0..reps {
        let t0 = Instant::now();
        let out = model.generate(&prompt, l);
        total += t0.elapsed().as_secs_f64();
        std::hint::black_box(out);
    }
    total / reps as f64
}

/// Table 4 (end-to-end decode at model scale).
pub fn table4(scale: usize) -> Result<()> {
    let (lengths, reps): (&[usize], usize) = match scale {
        0 => (&[10, 30], 2),
        1 => (&[10, 50, 100], 5),
        _ => (&[10, 100, 500], 10),
    };
    let cfg_for = |s: StructureKind| {
        let mut c = LmConfig::tiny(s);
        c.max_seq = lengths.iter().max().unwrap() + 8;
        c
    };
    // Rows: dense; CR 20% b∈{2,4}; CR 50% b=4 (b=16 needs d≥... our
    // d_model=64 supports b up to 16 on fc layers; use b=2 and b=4 as
    // the small/large block counts).
    let rows: Vec<(String, StructureKind)> = vec![
        ("CR 0% (dense)".into(), StructureKind::Dense),
        ("CR 20% b=2".into(), blast_cfg(0.2, 2)),
        ("CR 20% b=4".into(), blast_cfg(0.2, 4)),
        ("CR 50% b=4".into(), blast_cfg(0.5, 4)),
    ];
    print!("{:<18} {:>10}", "config", "params");
    for l in lengths {
        print!(" {:>12}", format!("L={l} (ms)"));
    }
    println!(" {:>10}", "vs dense");
    let mut dense_times = Vec::new();
    for (label, s) in rows {
        let mut rng = Rng::new(1700);
        let model = TinyLM::new(cfg_for(s), &mut rng);
        print!("{:<18} {:>10}", label, model.num_params());
        let mut times = Vec::new();
        for &l in lengths {
            let t = time_generate(&model, l, reps);
            times.push(t);
            print!(" {:>12.3}", t * 1e3);
        }
        if dense_times.is_empty() {
            dense_times = times.clone();
            println!(" {:>10}", "1.00x");
        } else {
            let speedup = dense_times.last().unwrap() / times.last().unwrap();
            println!(" {:>9.2}x", speedup);
        }
    }
    Ok(())
}

fn blast_cfg(ratio: f64, b: usize) -> StructureKind {
    // Budget solved on the largest layer (d_ff x d_model = 128x64).
    let r = blast_rank_for_ratio(128, 64, b, ratio).unwrap_or(1);
    StructureKind::Blast { b, r }
}

/// Raw matvec sweep at the paper's Llama-7B shapes — the Table 4
/// mechanism isolated. Returns (label, mean seconds) rows.
pub fn matvec_sweep(reps: usize) -> Vec<(String, f64)> {
    let mut out = Vec::new();
    let shapes = [(4096usize, 4096usize), (11008, 4096)];
    for (m, n) in shapes {
        let mut rng = Rng::new(1800);
        let dense = rng.gaussian_matrix(m, n, 0.02);
        let x: Vec<f32> = (0..n).map(|i| (i as f32 * 0.1).sin()).collect();
        // Warmup + time dense.
        let _ = crate::tensor::gemv(&dense, &x);
        let t0 = Instant::now();
        for _ in 0..reps {
            std::hint::black_box(crate::tensor::gemv(&dense, &x));
        }
        let dense_t = t0.elapsed().as_secs_f64() / reps as f64;
        out.push((format!("dense {m}x{n}"), dense_t));

        for (ratio, b) in [(0.2, 2usize), (0.2, 16), (0.5, 16)] {
            let Some(r) = blast_rank_for_ratio(m, n, b, ratio) else {
                continue;
            };
            let blast = BlastMatrix::random_init(m, n, b, r, 0.02, &mut rng);
            let _ = blast.matvec(&x);
            let t0 = Instant::now();
            for _ in 0..reps {
                std::hint::black_box(blast.matvec(&x));
            }
            let t = t0.elapsed().as_secs_f64() / reps as f64;
            out.push((
                format!("blast {m}x{n} CR{:.0}% b={b} r={r}", ratio * 100.0),
                t,
            ));
        }
    }
    out
}

/// Pretty-print the matvec sweep (used by `blast bench-runtime`).
pub fn print_matvec_sweep(reps: usize) {
    println!("{:<40} {:>12} {:>10}", "config", "mean (µs)", "vs dense");
    let rows = matvec_sweep(reps);
    let mut dense_t = 0.0;
    for (label, t) in rows {
        if label.starts_with("dense") {
            dense_t = t;
            println!("{:<40} {:>12.1} {:>10}", label, t * 1e6, "1.00x");
        } else {
            println!("{:<40} {:>12.1} {:>9.2}x", label, t * 1e6, dense_t / t);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn blast_decode_faster_than_dense_at_llama_shapes() {
        // The Table 4 mechanism: at 4096x4096 with 50% CR, the BLAST
        // matvec must beat the dense matvec (both single-threaded-ish,
        // both bandwidth-bound).
        let m = 1024; // scaled-down llama shape for test runtime
        let n = 1024;
        let mut rng = Rng::new(1900);
        let dense = rng.gaussian_matrix(m, n, 0.02);
        let x: Vec<f32> = (0..n).map(|i| (i as f32 * 0.1).cos()).collect();
        let r = blast_rank_for_ratio(m, n, 16, 0.5).unwrap();
        let blast = BlastMatrix::random_init(m, n, 16, r, 0.02, &mut rng);
        // Correct first.
        let y_d = crate::tensor::gemv(&dense, &x);
        assert_eq!(y_d.len(), blast.matvec(&x).len());
        // Time both.
        let time = |f: &dyn Fn() -> Vec<f32>| {
            let _ = f();
            let t0 = Instant::now();
            for _ in 0..20 {
                std::hint::black_box(f());
            }
            t0.elapsed().as_secs_f64()
        };
        let t_dense = time(&|| crate::tensor::gemv(&dense, &x));
        let t_blast = time(&|| blast.matvec(&x));
        assert!(
            t_blast < t_dense,
            "blast matvec {t_blast:.6}s should beat dense {t_dense:.6}s"
        );
    }

    #[test]
    fn matvec_flops_vs_params_ratio() {
        // 50% params -> roughly 50% work in the bandwidth-bound regime.
        let r = blast_rank_for_ratio(4096, 4096, 16, 0.5).unwrap();
        let b = BlastMatrix::zeros(4096, 4096, 16, r);
        let param_ratio = b.num_params() as f64 / (4096.0 * 4096.0);
        assert!((param_ratio - 0.5).abs() < 0.02, "param ratio {param_ratio}");
    }
}

/// Re-export Matrix so the bench target can build inputs without extra
/// imports.
pub use crate::tensor::Matrix as BenchMatrix;
#[allow(unused)]
fn _keep(m: Matrix) {}
