//! Mirror of the paper's hyperparameter tables (Tables 5–11), scaled to
//! the synthetic substrates. Documented constants so every experiment
//! reads its settings from one place.

use crate::nn::attention::StructureKind;

/// Paper Table 5 (training from scratch) — scaled.
pub mod from_scratch {
    /// Epoch-equivalent optimizer steps at scale 1.
    pub const LM_STEPS: [usize; 3] = [60, 300, 900];
    pub const VIT_STEPS: [usize; 3] = [40, 250, 800];
    pub const LM_LR: f32 = 3e-3;
    pub const VIT_LR: f32 = 2e-3;
    pub const WEIGHT_DECAY: f32 = 0.01; // paper: 0.05 at ViT scale
}

/// Paper Table 6 (re-training) — scaled.
pub mod retrain {
    pub const LM_STEPS: [usize; 3] = [40, 200, 600];
    pub const LM_LR: f32 = 1e-3; // paper: 2e-4 at Llama scale
}

/// Paper Table 9-analogue: the BLAST settings used for the LLM
/// compression rows (b fixed, r solved from the budget).
pub mod llm_compress {
    /// Paper fixes b = 16 for Llama-7B; our TinyLM uses b = 4 (d=64).
    pub const BLAST_B: usize = 4;
    pub const PRECGD_ITERS: [usize; 3] = [30, 120, 300];
    pub const DELTA0: f32 = 0.1;
}

/// Structure grids per experiment (matched-budget sweeps).
///
/// The paper uses BLAST₃ for ViT; our d_model=64 models require b to
/// divide every layer dimension, so we use b = 4 throughout (the paper's
/// own Fig. 6 shows b = 3 vs b = 12 barely differ).
pub fn scratch_structures(budget: f64) -> Vec<StructureKind> {
    let b = 4usize;
    let r_lr = ((budget * 64.0 * 64.0) / (64.0 + 64.0)) as usize;
    let r_blast = ((budget * 64.0 * 64.0) / (64.0 + 64.0 + (b * b) as f64)) as usize;
    let t_mon = ((budget * 64.0 * 64.0) / ((64.0 + 64.0) * b as f64)) as usize;
    let t_bd = (budget * 64.0) as usize;
    vec![
        StructureKind::LowRank { r: r_lr.max(1) },
        StructureKind::Monarch { b, t: t_mon.max(1) },
        StructureKind::BlockDiag { b, t: t_bd.max(1) },
        StructureKind::Blast { b, r: r_blast.max(1) },
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scratch_structures_budgeted() {
        for budget in [0.3, 0.5, 0.7] {
            let list = scratch_structures(budget);
            assert_eq!(list.len(), 4);
            // BLAST config exists and divides d_model=63? b=3 divides 63?
            // d_model is 64 in LmConfig::tiny — b=3 does not divide 64;
            // fig4/table1 use d_model that b divides (checked there).
            for s in &list {
                match s {
                    StructureKind::LowRank { r } => assert!(*r >= 1),
                    StructureKind::Blast { r, .. } => assert!(*r >= 1),
                    StructureKind::Monarch { t, .. } => assert!(*t >= 1),
                    StructureKind::BlockDiag { t, .. } => assert!(*t >= 1),
                    _ => {}
                }
            }
        }
    }
}
