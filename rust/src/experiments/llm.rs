//! LLM compression experiments: Table 3 (perplexity + avg 0-shot,
//! ±re-training), Tables 12/13 (per-task accuracy), Fig. 7 (accuracy vs
//! compression ratio before/after re-training).

use super::configs;
use crate::data::corpus::SyntheticCorpus;
use crate::data::zeroshot::build_suites;
use crate::eval::{eval_suites, perplexity};
use crate::factorize::{Compressor, Structure};
use crate::nn::attention::StructureKind;
use crate::nn::gpt::{LmConfig, TinyLM};
use crate::tensor::Rng;
use crate::train::{compress_lm, retrain_lm, train_lm, LmTrainConfig};
use anyhow::Result;

struct LlmBench {
    corpus: SyntheticCorpus,
    suites: Vec<crate::data::zeroshot::TaskSuite>,
    dense: TinyLM,
    ppl_windows: usize,
}

fn setup(scale: usize) -> LlmBench {
    let (corpus_len, train_steps, per_suite, ppl_windows) = match scale {
        0 => (10_000, 120, 12, 4),
        1 => (30_000, 500, 40, 12),
        _ => (80_000, 1500, 100, 24),
    };
    let corpus = SyntheticCorpus::generate(64, corpus_len, 2048);
    let suites = build_suites(&corpus, per_suite);
    let mut rng = Rng::new(1600);
    let mut dense = TinyLM::new(LmConfig::tiny(StructureKind::Dense), &mut rng);
    train_lm(
        &mut dense,
        &corpus.train_dataset(),
        &LmTrainConfig { steps: train_steps, ..Default::default() },
    );
    LlmBench { corpus, suites, dense, ppl_windows }
}

fn eval_row(b: &LlmBench, model: &TinyLM) -> (f64, f64) {
    let ppl = perplexity(model, &b.corpus.valid_dataset(), 32, b.ppl_windows);
    let (_, avg) = eval_suites(model, &b.suites);
    (ppl, avg)
}

/// Table 3 — the headline LLM compression table.
pub fn table3(scale: usize) -> Result<()> {
    let b = setup(scale);
    let blast_iters = configs::llm_compress::PRECGD_ITERS[scale.min(2)];
    let retrain_steps = configs::retrain::LM_STEPS[scale.min(2)];
    let comp = Compressor { blast_iters, ..Default::default() };
    let bb = configs::llm_compress::BLAST_B;

    let (ppl0, acc0) = eval_row(&b, &b.dense);
    println!(
        "{:<6} {:<26} {:>10} {:>10} {:>12} {:>16}",
        "CR", "Method", "#Params", "Retrained?", "ppl (↓)", "avg 0-shot (↑)"
    );
    println!(
        "{:<6} {:<26} {:>10} {:>10} {:>12.2} {:>16.2}",
        "0%", "Original", b.dense.num_params(), "N/A", ppl0, acc0
    );

    // 20% compression-only rows (paper's upper Table 3 block).
    for s in [Structure::LowRank, Structure::Monarch { b: bb }, Structure::Blast { b: bb }] {
        let mut m = b.dense.clone();
        let rep = compress_lm(&mut m, s, 0.2, &comp);
        let (ppl, acc) = eval_row(&b, &m);
        println!(
            "{:<6} {:<26} {:>10} {:>10} {:>12.2} {:>16.2}",
            "20%",
            s.name(),
            rep.params_after,
            "No",
            ppl,
            acc
        );
    }

    // 50% compression + retraining rows.
    for s in [
        Structure::LowRank,
        Structure::Monarch { b: bb },
        Structure::BlockDiag { b: bb },
        Structure::Blast { b: bb },
    ] {
        let mut m = b.dense.clone();
        let rep = compress_lm(&mut m, s, 0.5, &comp);
        retrain_lm(&mut m, &b.corpus.train_dataset(), retrain_steps);
        let (ppl, acc) = eval_row(&b, &m);
        println!(
            "{:<6} {:<26} {:>10} {:>10} {:>12.2} {:>16.2}",
            "50%",
            s.name(),
            rep.params_after,
            "Yes",
            ppl,
            acc
        );
    }
    Ok(())
}

fn per_task_table(b: &LlmBench, rows: Vec<(String, TinyLM)>) {
    print!("{:<26}", "Method");
    for s in &b.suites {
        print!(" {:>16}", s.name);
    }
    println!(" {:>9}", "Average");
    for (label, model) in rows {
        let (results, avg) = eval_suites(&model, &b.suites);
        print!("{label:<26}");
        for r in &results {
            print!(" {:>16.2}", r.accuracy);
        }
        println!(" {avg:>9.2}");
    }
}

/// Table 12 — per-task 0-shot accuracy, compression only (10 %, 20 %).
pub fn table12(scale: usize) -> Result<()> {
    let b = setup(scale);
    let comp = Compressor {
        blast_iters: configs::llm_compress::PRECGD_ITERS[scale.min(2)],
        ..Default::default()
    };
    let bb = configs::llm_compress::BLAST_B;
    let mut rows = vec![("Original".to_string(), b.dense.clone())];
    for ratio in [0.1, 0.2] {
        for s in [
            Structure::LowRank,
            Structure::Monarch { b: bb },
            Structure::Blast { b: 2 },
            Structure::Blast { b: bb },
        ] {
            let mut m = b.dense.clone();
            compress_lm(&mut m, s, ratio, &comp);
            rows.push((format!("{} @{:.0}%", s.name(), ratio * 100.0), m));
        }
    }
    per_task_table(&b, rows);
    Ok(())
}

/// Table 13 — per-task 0-shot after re-training (20 %, 50 %).
pub fn table13(scale: usize) -> Result<()> {
    let b = setup(scale);
    let comp = Compressor {
        blast_iters: configs::llm_compress::PRECGD_ITERS[scale.min(2)],
        ..Default::default()
    };
    let retrain_steps = configs::retrain::LM_STEPS[scale.min(2)];
    let bb = configs::llm_compress::BLAST_B;
    let mut rows = vec![("Original".to_string(), b.dense.clone())];
    for (ratio, structures) in [
        (0.2, vec![Structure::Blast { b: bb }]),
        (
            0.5,
            vec![
                Structure::LowRank,
                Structure::Monarch { b: bb },
                Structure::BlockDiag { b: bb },
                Structure::Blast { b: bb },
            ],
        ),
    ] {
        for s in structures {
            let mut m = b.dense.clone();
            compress_lm(&mut m, s, ratio, &comp);
            retrain_lm(&mut m, &b.corpus.train_dataset(), retrain_steps);
            rows.push((format!("{} @{:.0}%", s.name(), ratio * 100.0), m));
        }
    }
    per_task_table(&b, rows);
    Ok(())
}

/// Fig. 7 — avg 0-shot accuracy vs CR, before and after re-training.
pub fn fig7(scale: usize) -> Result<()> {
    let b = setup(scale);
    let comp = Compressor {
        blast_iters: configs::llm_compress::PRECGD_ITERS[scale.min(2)],
        ..Default::default()
    };
    let retrain_steps = configs::retrain::LM_STEPS[scale.min(2)];
    let bb = configs::llm_compress::BLAST_B;
    let (_, acc0) = eval_row(&b, &b.dense);
    println!("original avg 0-shot: {acc0:.2}%");
    println!("{:>6} {:>18} {:>18}", "CR(%)", "before retrain", "after retrain");
    for ratio in [0.1, 0.2, 0.3, 0.5, 0.7] {
        let mut m = b.dense.clone();
        if compress_lm(&mut m, Structure::Blast { b: bb }, ratio, &comp).layers_compressed == 0 {
            println!("{:>6.0} {:>18} {:>18}", ratio * 100.0, "infeasible", "-");
            continue;
        }
        let (_, acc_before) = eval_row(&b, &m);
        retrain_lm(&mut m, &b.corpus.train_dataset(), retrain_steps);
        let (_, acc_after) = eval_row(&b, &m);
        println!("{:>6.0} {:>18.2} {:>18.2}", ratio * 100.0, acc_before, acc_after);
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn blast_beats_blockdiag_after_compression() {
        // The Table 3 ordering at smoke scale: BLAST's post-compression
        // perplexity is below Block-Diagonal's at the same CR.
        let b = setup(0);
        let comp = Compressor { blast_iters: 30, ..Default::default() };
        let mut m_blast = b.dense.clone();
        compress_lm(&mut m_blast, Structure::Blast { b: 4 }, 0.5, &comp);
        let mut m_bd = b.dense.clone();
        compress_lm(&mut m_bd, Structure::BlockDiag { b: 4 }, 0.5, &comp);
        let (ppl_blast, _) = eval_row(&b, &m_blast);
        let (ppl_bd, _) = eval_row(&b, &m_bd);
        assert!(
            ppl_blast < ppl_bd,
            "BLAST ppl {ppl_blast} should beat Block-Diagonal {ppl_bd}"
        );
    }
}
