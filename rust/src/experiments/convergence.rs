//! Fig. 3 / Fig. 9 — factorization convergence, GD vs PrecGD.
//!
//! Paper setup: 256×256 target, b = 16, r* = 8, r ∈ {r*, 32}; the claim
//! is (i) exact-rank GD converges on a low-rank target, (ii) over-
//! parameterized GD stalls, (iii) PrecGD recovers low error in both
//! regimes, (iv) on a *BLAST* target plain GD fails even at exact rank.

use crate::blast::BlastMatrix;
use crate::factorize::{factorize_gd, factorize_precgd, GdOptions, PrecGdOptions};
use crate::tensor::{matmul_nt, Matrix, Rng};
use anyhow::Result;

fn low_rank_target(n: usize, r_star: usize, seed: u64) -> Matrix {
    let mut rng = Rng::new(seed);
    let u = rng.gaussian_matrix(n, r_star, 1.0);
    let v = rng.gaussian_matrix(n, r_star, 1.0);
    matmul_nt(&u, &v).scale(1.0 / (r_star as f32).sqrt())
}

fn blast_target(n: usize, b: usize, r_star: usize, seed: u64) -> Matrix {
    let mut rng = Rng::new(seed);
    BlastMatrix::random_init(n, n, b, r_star, 0.3, &mut rng).to_dense()
}

fn dims(scale: usize) -> (usize, usize, usize, usize) {
    // (n, b, iters, r_over)
    match scale {
        0 => (64, 4, 30, 16),
        1 => (128, 8, 60, 32),
        _ => (256, 16, 100, 32),
    }
}

fn run_pair(target: &Matrix, b: usize, r: usize, iters: usize, label: &str) -> (f64, f64) {
    let gd = factorize_gd(
        target,
        &GdOptions { b, r, iters, seed: 0, trace_every: 0, ..Default::default() },
    );
    let pgd = factorize_precgd(
        target,
        &PrecGdOptions { b, r, iters, seed: 0, trace_every: 0, ..Default::default() },
    );
    println!(
        "  {label:<28} GD rel-err {:>10.3e}   PrecGD rel-err {:>10.3e}   (PrecGD {:>6.1}x lower)",
        gd.rel_error,
        pgd.rel_error,
        gd.rel_error / pgd.rel_error.max(1e-12)
    );
    (gd.rel_error, pgd.rel_error)
}

/// Fig. 3: low-rank target.
pub fn fig3(scale: usize) -> Result<()> {
    let (n, b, iters, r_over) = dims(scale);
    let r_star = 8.min(n / 8);
    let target = low_rank_target(n, r_star, 42);
    println!("low-rank target {n}x{n}, r*={r_star}, b={b}, {iters} iters");
    let (gd_exact, pgd_exact) = run_pair(&target, b, r_star, iters, &format!("exact r={r_star}"));
    let (gd_over, pgd_over) = run_pair(&target, b, r_over, iters, &format!("overparam r={r_over}"));
    // Paper claims: exact-rank converges either way; overparam GD stalls
    // but PrecGD recovers.
    println!(
        "  shape check: overparam PrecGD beats GD: {}",
        pgd_over < gd_over
    );
    let _ = (gd_exact, pgd_exact);
    Ok(())
}

/// Fig. 9: BLAST target (GD fails even at exact rank; PrecGD recovers).
pub fn fig9(scale: usize) -> Result<()> {
    let (n, b, iters, r_over) = dims(scale);
    let r_star = 8.min(n / 8);
    let target = blast_target(n, b, r_star, 43);
    println!("BLAST target {n}x{n}, b={b}, r*={r_star}, {iters} iters");
    run_pair(&target, b, r_star, iters, &format!("exact r={r_star}"));
    run_pair(&target, b, r_over, iters, &format!("overparam r={r_over}"));
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig3_shape_holds_at_smoke_scale() {
        // The quantitative claim behind Fig. 3-right.
        let (n, b, iters, r_over) = dims(0);
        let target = low_rank_target(n, 8, 42);
        let gd = factorize_gd(
            &target,
            &GdOptions { b, r: r_over, iters, seed: 0, trace_every: 0, ..Default::default() },
        );
        let pgd = factorize_precgd(
            &target,
            &PrecGdOptions { b, r: r_over, iters, seed: 0, trace_every: 0, ..Default::default() },
        );
        assert!(pgd.rel_error < 0.5 * gd.rel_error);
    }
}
