//! Experiment harnesses — one per paper table/figure (see DESIGN.md §5).
//!
//! Each harness prints the same rows/series the paper reports; absolute
//! numbers come from the synthetic substrates (DESIGN.md §3), the *shape*
//! (who wins, by roughly what factor, where crossovers fall) is the
//! reproduction target. `scale` trades runtime for fidelity: 0 = smoke,
//! 1 = default, 2 = thorough.

pub mod configs;
pub mod convergence;
pub mod scratch;
pub mod compress;
pub mod llm;
pub mod runtime_exp;

use anyhow::{bail, Result};

/// Experiment registry entry.
pub struct Experiment {
    pub id: &'static str,
    pub paper_ref: &'static str,
    pub run: fn(scale: usize) -> Result<()>,
}

/// All registered experiments.
pub fn registry() -> Vec<Experiment> {
    vec![
        Experiment {
            id: "fig3",
            paper_ref: "Fig. 3 — GD vs PrecGD on a low-rank target",
            run: convergence::fig3,
        },
        Experiment {
            id: "fig9",
            paper_ref: "Fig. 9 — GD vs PrecGD on a BLAST target",
            run: convergence::fig9,
        },
        Experiment {
            id: "fig4",
            paper_ref: "Fig. 4 — ViT-S from scratch, accuracy vs FLOPs",
            run: scratch::fig4,
        },
        Experiment {
            id: "table1",
            paper_ref: "Table 1 — ViT-B from scratch, accuracy + relative FLOPs",
            run: scratch::table1,
        },
        Experiment {
            id: "fig5",
            paper_ref: "Fig. 5 — GPT-2 perplexity–FLOPs trade-off",
            run: scratch::fig5,
        },
        Experiment {
            id: "fig6",
            paper_ref: "Fig. 6 — ViT compress+retrain accuracy–FLOPs",
            run: compress::fig6,
        },
        Experiment {
            id: "table2",
            paper_ref: "Table 2 — DiT 50% compression FID/sFID/IS",
            run: compress::table2,
        },
        Experiment {
            id: "fig1",
            paper_ref: "Fig. 1 — DiT qualitative samples from shared noise",
            run: compress::fig1,
        },
        Experiment {
            id: "table3",
            paper_ref: "Table 3 — LLM compression ± re-training",
            run: llm::table3,
        },
        Experiment {
            id: "table12",
            paper_ref: "Table 12 — per-task 0-shot, compression only",
            run: llm::table12,
        },
        Experiment {
            id: "table13",
            paper_ref: "Table 13 — per-task 0-shot after re-training",
            run: llm::table13,
        },
        Experiment {
            id: "fig7",
            paper_ref: "Fig. 7 — accuracy vs CR, before/after re-training",
            run: llm::fig7,
        },
        Experiment {
            id: "table4",
            paper_ref: "Table 4 — decode runtime vs CR and b",
            run: runtime_exp::table4,
        },
    ]
}

/// Run one experiment by id.
pub fn run(id: &str, scale: usize) -> Result<()> {
    for e in registry() {
        if e.id == id {
            println!("=== {} ({}) ===", e.id, e.paper_ref);
            return (e.run)(scale);
        }
    }
    bail!(
        "unknown experiment `{id}` (have: {})",
        registry().iter().map(|e| e.id).collect::<Vec<_>>().join(", ")
    )
}

/// Run everything.
pub fn run_all(scale: usize) -> Result<()> {
    for e in registry() {
        println!("\n=== {} ({}) ===", e.id, e.paper_ref);
        (e.run)(scale)?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_ids_unique_and_complete() {
        let ids: Vec<&str> = registry().iter().map(|e| e.id).collect();
        let mut dedup = ids.clone();
        dedup.sort();
        dedup.dedup();
        assert_eq!(dedup.len(), ids.len(), "duplicate experiment ids");
        for required in [
            "fig3", "fig9", "fig4", "table1", "fig5", "fig6", "table2",
            "fig1", "table3", "table12", "table13", "fig7", "table4",
        ] {
            assert!(ids.contains(&required), "missing {required}");
        }
    }

    #[test]
    fn unknown_id_is_error() {
        assert!(run("bogus", 0).is_err());
    }

    #[test]
    fn smoke_fig3() {
        run("fig3", 0).unwrap();
    }

    #[test]
    fn smoke_table4() {
        run("table4", 0).unwrap();
    }
}
