//! From-scratch training experiments: Fig. 4 (ViT-S accuracy vs FLOPs),
//! Table 1 (ViT-B accuracy + relative FLOPs), Fig. 5 (GPT-2 perplexity
//! vs FLOPs) — all on the synthetic substrates with matched FLOP budgets.

use super::configs;
use crate::data::corpus::SyntheticCorpus;
use crate::data::images::TextureDataset;
use crate::eval::perplexity;
use crate::nn::attention::StructureKind;
use crate::nn::gpt::{LmConfig, TinyLM};
use crate::nn::vit::{TinyViT, VitConfig};
use crate::tensor::Rng;
use crate::train::vit_trainer::{eval_vit_accuracy, train_vit, VitTrainConfig};
use crate::train::{train_lm, LmTrainConfig};
use anyhow::Result;

/// Fig. 4 — ViT from scratch: accuracy vs relative FLOPs per structure.
pub fn fig4(scale: usize) -> Result<()> {
    let steps = configs::from_scratch::VIT_STEPS[scale.min(2)];
    let data = TextureDataset::new(16, 4);
    println!("{:<28} {:>10} {:>14} {:>12}", "structure", "acc (%)", "rel FLOPs (%)", "params");

    // Dense reference.
    let dense_flops = {
        let mut rng = Rng::new(1000);
        let vit = TinyViT::new(vit_cfg(StructureKind::Dense), &mut rng);
        vit.flops_per_token() as f64
    };

    let mut results = Vec::new();
    let mut structures = vec![StructureKind::Dense];
    for budget in [0.3, 0.5] {
        structures.extend(configs::scratch_structures(budget));
    }
    for s in structures {
        let mut rng = Rng::new(1000);
        let mut vit = TinyViT::new(vit_cfg(s), &mut rng);
        train_vit(
            &mut vit,
            &data,
            &VitTrainConfig {
                steps,
                lr: configs::from_scratch::VIT_LR,
                weight_decay: configs::from_scratch::WEIGHT_DECAY,
                ..Default::default()
            },
        );
        let eval_n = if scale == 0 { 5 } else { 25 };
        let acc = eval_vit_accuracy(&vit, &data, eval_n, 7);
        let rel = 100.0 * vit.flops_per_token() as f64 / dense_flops;
        println!("{:<28} {:>10.1} {:>14.1} {:>12}", s.name(), acc, rel, vit.num_params());
        results.push((s, acc, rel));
    }
    Ok(())
}

fn vit_cfg(s: StructureKind) -> VitConfig {
    VitConfig { n_classes: 4, ..VitConfig::tiny(s) }
}

/// Table 1 — larger-config ViT from scratch (one row per structure at the
/// ~30 % FLOPs point, like the paper's table).
pub fn table1(scale: usize) -> Result<()> {
    let steps = configs::from_scratch::VIT_STEPS[scale.min(2)] * 2;
    let data = TextureDataset::new(16, 4);
    let dense_flops = {
        let mut rng = Rng::new(1100);
        TinyViT::new(vit_cfg(StructureKind::Dense), &mut rng).flops_per_token() as f64
    };
    println!("{:<28} {:>12} {:>18}", "Model", "Accuracy (%)", "Relative FLOPs (%)");
    let mut rows = vec![StructureKind::Dense];
    rows.extend(configs::scratch_structures(0.3));
    for s in rows {
        let mut rng = Rng::new(1100);
        let mut vit = TinyViT::new(vit_cfg(s), &mut rng);
        train_vit(
            &mut vit,
            &data,
            &VitTrainConfig { steps, lr: configs::from_scratch::VIT_LR, ..Default::default() },
        );
        let eval_n = if scale == 0 { 5 } else { 25 };
        let acc = eval_vit_accuracy(&vit, &data, eval_n, 11);
        let rel = 100.0 * vit.flops_per_token() as f64 / dense_flops;
        println!("{:<28} {:>12.1} {:>18.1}", s.name(), acc, rel);
    }
    Ok(())
}

/// Fig. 5 — LM from scratch: perplexity vs FLOPs trade-off curves.
pub fn fig5(scale: usize) -> Result<()> {
    let steps = configs::from_scratch::LM_STEPS[scale.min(2)];
    let corpus = SyntheticCorpus::generate(64, 20_000, 2_048);
    let train_data = corpus.train_dataset();
    let valid = corpus.valid_dataset();
    let dense_flops = {
        let mut rng = Rng::new(1200);
        TinyLM::new(LmConfig::tiny(StructureKind::Dense), &mut rng).flops_per_token() as f64
    };
    println!("{:<28} {:>12} {:>14}", "structure", "valid ppl", "rel FLOPs (%)");
    let mut structures = vec![StructureKind::Dense];
    for budget in [0.25, 0.5] {
        structures.extend(configs::scratch_structures(budget));
    }
    for s in structures {
        let mut rng = Rng::new(1200);
        let mut lm = TinyLM::new(LmConfig::tiny(s), &mut rng);
        train_lm(
            &mut lm,
            &train_data,
            &LmTrainConfig {
                steps,
                lr: configs::from_scratch::LM_LR,
                weight_decay: configs::from_scratch::WEIGHT_DECAY,
                ..Default::default()
            },
        );
        let windows = if scale == 0 { 4 } else { 16 };
        let ppl = perplexity(&lm, &valid, 32, windows);
        let rel = 100.0 * lm.flops_per_token() as f64 / dense_flops;
        println!("{:<28} {:>12.2} {:>14.1}", s.name(), ppl, rel);
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn blast_trains_competitively_at_matched_flops() {
        // The Fig. 4/5 shape at smoke scale: at ~0.5 budget, BLAST's
        // trained perplexity is finite and below the random baseline, and
        // its FLOPs undercut dense.
        let corpus = SyntheticCorpus::generate(64, 8_000, 640);
        let mut rng = Rng::new(1300);
        let s = StructureKind::Blast { b: 4, r: 14 };
        let mut lm = TinyLM::new(LmConfig::tiny(s), &mut rng);
        let flops_dense =
            TinyLM::new(LmConfig::tiny(StructureKind::Dense), &mut rng).flops_per_token();
        assert!(lm.flops_per_token() < flops_dense / 2);
        let before = perplexity(&lm, &corpus.valid_dataset(), 32, 4);
        train_lm(
            &mut lm,
            &corpus.train_dataset(),
            &LmTrainConfig { steps: 60, ..Default::default() },
        );
        let after = perplexity(&lm, &corpus.valid_dataset(), 32, 4);
        assert!(after < before * 0.7, "{before} -> {after}");
    }
}
