//! PJRT executor: compile HLO-text artifacts once, execute many times.
//!
//! Wraps the `xla` crate (`PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `client.compile` → `execute`) behind
//! a value-level `TensorValue` interface so the coordinator and the
//! end-to-end training example can feed plain `f32`/`i32` buffers in
//! manifest order without touching XLA types.
//!
//! The `xla` crate is not available in the offline build, so everything
//! that touches XLA types is gated behind the `pjrt` cargo feature. With
//! the feature off (the default) a stub [`PjrtEngine`]/[`Executor`] pair
//! with identical signatures is compiled whose constructor returns an
//! error — callers (`blast info`, the PJRT integration tests, benches)
//! already handle "PJRT unavailable" gracefully, so the whole crate
//! builds and tests without XLA.

use super::manifest::ArtifactEntry;
use anyhow::{bail, Context, Result};
#[cfg(feature = "pjrt")]
use std::collections::HashMap;

/// A host-side tensor value (what crosses the executor boundary).
#[derive(Clone, Debug)]
pub enum TensorValue {
    F32 { shape: Vec<usize>, data: Vec<f32> },
    I32 { shape: Vec<usize>, data: Vec<i32> },
}

impl TensorValue {
    pub fn shape(&self) -> &[usize] {
        match self {
            TensorValue::F32 { shape, .. } | TensorValue::I32 { shape, .. } => shape,
        }
    }

    pub fn scalar_f32(v: f32) -> Self {
        TensorValue::F32 { shape: vec![], data: vec![v] }
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            TensorValue::F32 { data, .. } => Ok(data),
            _ => bail!("tensor is not f32"),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match self {
            TensorValue::I32 { data, .. } => Ok(data),
            _ => bail!("tensor is not i32"),
        }
    }

    pub fn from_matrix(m: &crate::tensor::Matrix) -> Self {
        TensorValue::F32 { shape: vec![m.rows, m.cols], data: m.data.clone() }
    }

    #[cfg(feature = "pjrt")]
    fn to_literal(&self) -> Result<xla::Literal> {
        match self {
            TensorValue::F32 { shape, data } => {
                let bytes: Vec<u8> = data.iter().flat_map(|v| v.to_le_bytes()).collect();
                xla::Literal::create_from_shape_and_untyped_data(
                    xla::ElementType::F32,
                    shape,
                    &bytes,
                )
                .map_err(|e| anyhow::anyhow!("literal create failed: {e:?}"))
            }
            TensorValue::I32 { shape, data } => {
                let bytes: Vec<u8> = data.iter().flat_map(|v| v.to_le_bytes()).collect();
                xla::Literal::create_from_shape_and_untyped_data(
                    xla::ElementType::S32,
                    shape,
                    &bytes,
                )
                .map_err(|e| anyhow::anyhow!("literal create failed: {e:?}"))
            }
        }
    }

    #[cfg(feature = "pjrt")]
    fn from_literal(lit: &xla::Literal) -> Result<Self> {
        let shape = lit
            .array_shape()
            .map_err(|e| anyhow::anyhow!("shape query failed: {e:?}"))?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        match shape.ty() {
            xla::ElementType::F32 => Ok(TensorValue::F32 {
                shape: dims,
                data: lit
                    .to_vec::<f32>()
                    .map_err(|e| anyhow::anyhow!("to_vec failed: {e:?}"))?,
            }),
            xla::ElementType::S32 => Ok(TensorValue::I32 {
                shape: dims,
                data: lit
                    .to_vec::<i32>()
                    .map_err(|e| anyhow::anyhow!("to_vec failed: {e:?}"))?,
            }),
            other => bail!("unsupported output element type {other:?}"),
        }
    }
}

/// Shared PJRT client + executable cache.
#[cfg(feature = "pjrt")]
pub struct PjrtEngine {
    client: xla::PjRtClient,
    cache: HashMap<String, xla::PjRtLoadedExecutable>,
}

#[cfg(feature = "pjrt")]
impl PjrtEngine {
    pub fn cpu() -> Result<Self> {
        let client =
            xla::PjRtClient::cpu().map_err(|e| anyhow::anyhow!("PJRT cpu client: {e:?}"))?;
        Ok(PjrtEngine { client, cache: HashMap::new() })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (or fetch from cache) an artifact's executable.
    pub fn load(&mut self, entry: &ArtifactEntry) -> Result<Executor<'_>> {
        if !self.cache.contains_key(&entry.name) {
            let proto = xla::HloModuleProto::from_text_file(&entry.file)
                .map_err(|e| anyhow::anyhow!("parsing {}: {e:?}", entry.file.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| anyhow::anyhow!("compiling {}: {e:?}", entry.name))?;
            self.cache.insert(entry.name.clone(), exe);
        }
        Ok(Executor {
            exe: &self.cache[&entry.name],
            entry: entry.clone(),
        })
    }
}

/// A compiled artifact ready to run.
#[cfg(feature = "pjrt")]
pub struct Executor<'a> {
    exe: &'a xla::PjRtLoadedExecutable,
    pub entry: ArtifactEntry,
}

#[cfg(feature = "pjrt")]
impl Executor<'_> {
    /// Execute with arguments in manifest order; returns the flattened
    /// tuple outputs.
    pub fn run(&self, args: &[TensorValue]) -> Result<Vec<TensorValue>> {
        if args.len() != self.entry.arg_shapes.len() {
            bail!(
                "artifact {} expects {} args, got {}",
                self.entry.name,
                self.entry.arg_shapes.len(),
                args.len()
            );
        }
        // Shape-check against the manifest (scalars may be [] vs [1]).
        for (i, (arg, want)) in args.iter().zip(&self.entry.arg_shapes).enumerate() {
            let got = arg.shape();
            if got != want.as_slice() && !(got.is_empty() && want.is_empty()) {
                bail!(
                    "artifact {} arg {i}: shape {:?} != manifest {:?}",
                    self.entry.name,
                    got,
                    want
                );
            }
        }
        let literals: Vec<xla::Literal> =
            args.iter().map(|a| a.to_literal()).collect::<Result<_>>()?;
        let result = self
            .exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow::anyhow!("execute {}: {e:?}", self.entry.name))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("to_literal_sync: {e:?}"))?;
        // aot.py lowers with return_tuple=True.
        let parts = lit
            .to_tuple()
            .map_err(|e| anyhow::anyhow!("to_tuple: {e:?}"))?;
        parts.iter().map(TensorValue::from_literal).collect()
    }
}

/// Stub engine compiled when the `pjrt` feature is off: identical public
/// surface, but the constructor reports that PJRT support is unavailable.
#[cfg(not(feature = "pjrt"))]
pub struct PjrtEngine {
    _private: (),
}

#[cfg(not(feature = "pjrt"))]
impl PjrtEngine {
    pub fn cpu() -> Result<Self> {
        bail!(
            "PJRT support is not compiled in; rebuild with `--features pjrt` \
             (requires the `xla` crate)"
        )
    }

    pub fn platform(&self) -> String {
        "unavailable".to_string()
    }

    pub fn load(&mut self, _entry: &ArtifactEntry) -> Result<Executor<'_>> {
        bail!("PJRT support is not compiled in")
    }
}

/// Stub executor matching the real `Executor<'a>` surface.
#[cfg(not(feature = "pjrt"))]
pub struct Executor<'a> {
    pub entry: ArtifactEntry,
    _engine: std::marker::PhantomData<&'a ()>,
}

#[cfg(not(feature = "pjrt"))]
impl Executor<'_> {
    pub fn run(&self, _args: &[TensorValue]) -> Result<Vec<TensorValue>> {
        bail!("PJRT support is not compiled in")
    }
}

/// Load a params `.bmx` bundle into manifest-ordered TensorValues, using
/// the artifact's `param_names` and `arg_shapes` (the bundle stores 2-D
/// views; reshape to the manifest's true shapes).
pub fn load_params_ordered(entry: &ArtifactEntry) -> Result<Vec<TensorValue>> {
    let bundle = crate::tensor::io::TensorBundle::load(&entry.params_file)?;
    let mut out = Vec::with_capacity(entry.param_names.len());
    for (i, name) in entry.param_names.iter().enumerate() {
        let m = bundle.get(name).with_context(|| format!("param {name}"))?;
        let want = &entry.arg_shapes[i];
        let numel: usize = want.iter().product::<usize>().max(1);
        if m.len() != numel {
            bail!(
                "param {name}: bundle has {} elements, manifest wants {:?}",
                m.len(),
                want
            );
        }
        out.push(TensorValue::F32 { shape: want.clone(), data: m.data.clone() });
    }
    Ok(out)
}

#[cfg(all(test, feature = "pjrt"))]
mod tests {
    use super::*;

    #[test]
    fn tensor_value_round_trip() {
        let v = TensorValue::F32 { shape: vec![2, 2], data: vec![1., 2., 3., 4.] };
        let lit = v.to_literal().unwrap();
        let back = TensorValue::from_literal(&lit).unwrap();
        assert_eq!(back.shape(), &[2, 2]);
        assert_eq!(back.as_f32().unwrap(), &[1., 2., 3., 4.]);

        let vi = TensorValue::I32 { shape: vec![3], data: vec![7, -1, 0] };
        let lit = vi.to_literal().unwrap();
        let back = TensorValue::from_literal(&lit).unwrap();
        assert_eq!(back.as_i32().unwrap(), &[7, -1, 0]);
    }

    #[test]
    fn scalar_value() {
        let v = TensorValue::scalar_f32(2.5);
        let lit = v.to_literal().unwrap();
        let back = TensorValue::from_literal(&lit).unwrap();
        assert_eq!(back.as_f32().unwrap(), &[2.5]);
    }
}

#[cfg(all(test, not(feature = "pjrt")))]
mod stub_tests {
    use super::*;

    #[test]
    fn stub_engine_reports_unavailable() {
        let err = PjrtEngine::cpu().err().expect("stub must not construct");
        assert!(format!("{err}").contains("PJRT support"));
    }

    #[test]
    fn tensor_value_accessors_work_without_xla() {
        let v = TensorValue::F32 { shape: vec![2, 2], data: vec![1., 2., 3., 4.] };
        assert_eq!(v.shape(), &[2, 2]);
        assert_eq!(v.as_f32().unwrap(), &[1., 2., 3., 4.]);
        assert!(v.as_i32().is_err());
        let s = TensorValue::scalar_f32(2.5);
        assert!(s.shape().is_empty());
    }
}
