pub mod manifest;
pub mod executor;

pub use executor::{Executor, PjrtEngine};
pub use manifest::{ArtifactEntry, Manifest};
