//! Artifact manifest parsing (`artifacts/manifest.json`, written by
//! `python/compile/aot.py`): per-artifact entrypoint names, file paths,
//! argument shapes/dtypes, and flattened-parameter order.

use crate::util::json::Json;
use anyhow::{Context, Result};
use std::path::{Path, PathBuf};

/// One AOT artifact.
#[derive(Clone, Debug)]
pub struct ArtifactEntry {
    pub name: String,
    pub variant: String,
    pub entrypoint: String,
    pub file: PathBuf,
    pub params_file: PathBuf,
    pub param_names: Vec<String>,
    pub arg_shapes: Vec<Vec<usize>>,
    pub arg_dtypes: Vec<String>,
    pub num_outputs: usize,
}

/// The parsed manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub artifacts: Vec<ArtifactEntry>,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        let root = Json::parse(&text).context("parsing manifest.json")?;
        let mut artifacts = Vec::new();
        for a in root.get("artifacts")?.as_arr().context("artifacts not array")? {
            let shapes = a
                .get("arg_shapes")?
                .as_arr()
                .context("arg_shapes")?
                .iter()
                .map(|s| {
                    s.as_arr()
                        .unwrap_or(&[])
                        .iter()
                        .filter_map(|d| d.as_usize())
                        .collect()
                })
                .collect();
            let dtypes = a
                .get("arg_dtypes")?
                .as_arr()
                .context("arg_dtypes")?
                .iter()
                .filter_map(|d| d.as_str().map(str::to_string))
                .collect();
            let param_names = a
                .get("param_names")?
                .as_arr()
                .context("param_names")?
                .iter()
                .filter_map(|d| d.as_str().map(str::to_string))
                .collect();
            artifacts.push(ArtifactEntry {
                name: a.get("name")?.as_str().context("name")?.to_string(),
                variant: a.get("variant")?.as_str().context("variant")?.to_string(),
                entrypoint: a.get("entrypoint")?.as_str().context("entrypoint")?.to_string(),
                file: dir.join(a.get("file")?.as_str().context("file")?),
                params_file: dir.join(a.get("params_file")?.as_str().context("params_file")?),
                param_names,
                arg_shapes: shapes,
                arg_dtypes: dtypes,
                num_outputs: a.get("num_outputs")?.as_usize().context("num_outputs")?,
            });
        }
        Ok(Manifest { dir, artifacts })
    }

    /// Look up an artifact by `variant.entrypoint` name.
    pub fn find(&self, name: &str) -> Result<&ArtifactEntry> {
        self.artifacts
            .iter()
            .find(|a| a.name == name)
            .with_context(|| {
                format!(
                    "artifact `{name}` not in manifest (have: {:?})",
                    self.artifacts.iter().map(|a| &a.name).collect::<Vec<_>>()
                )
            })
    }

    pub fn variants(&self) -> Vec<&str> {
        let mut v: Vec<&str> = self.artifacts.iter().map(|a| a.variant.as_str()).collect();
        v.sort();
        v.dedup();
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_available() -> bool {
        std::path::Path::new("artifacts/manifest.json").exists()
    }

    #[test]
    fn parses_real_manifest_when_present() {
        if !artifacts_available() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let m = Manifest::load("artifacts").unwrap();
        assert!(!m.artifacts.is_empty());
        let fwd = m.find("tinylm_dense.forward").unwrap();
        assert_eq!(fwd.entrypoint, "forward");
        assert!(fwd.file.exists(), "hlo file missing");
        assert!(fwd.params_file.exists(), "params file missing");
        assert!(!fwd.param_names.is_empty());
        // Last arg of forward is the token vector.
        assert_eq!(fwd.arg_shapes.last().unwrap().len(), 1);
    }

    #[test]
    fn missing_artifact_error_lists_names() {
        if !artifacts_available() {
            return;
        }
        let m = Manifest::load("artifacts").unwrap();
        let err = m.find("nope.forward").unwrap_err().to_string();
        assert!(err.contains("nope.forward"));
    }

    #[test]
    fn parses_synthetic_manifest() {
        let dir = std::env::temp_dir().join("blast_manifest_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"format":1,"artifacts":[{"name":"m.fwd","variant":"m",
                "entrypoint":"fwd","file":"m.fwd.hlo.txt",
                "params_file":"m.params.bmx","param_names":["a","b"],
                "arg_shapes":[[2,3],[4]],"arg_dtypes":["float32","int32"],
                "num_outputs":1,"config":{}}]}"#,
        )
        .unwrap();
        let m = Manifest::load(&dir).unwrap();
        let e = m.find("m.fwd").unwrap();
        assert_eq!(e.arg_shapes, vec![vec![2, 3], vec![4]]);
        assert_eq!(e.arg_dtypes, vec!["float32", "int32"]);
        assert_eq!(m.variants(), vec!["m"]);
        std::fs::remove_dir_all(&dir).ok();
    }
}
