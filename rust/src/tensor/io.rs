//! Binary tensor I/O shared between the Rust runtime and the Python build
//! path.
//!
//! Format (`.bmx`, little-endian): magic `b"BMX1"`, then `u32 count`, then
//! per entry: `u32 name_len`, name bytes (utf-8), `u32 rows`, `u32 cols`,
//! `rows*cols` f32 values. Simple enough that `python/compile/aot.py`
//! reads/writes the same files with `struct` + `numpy`.

use super::matrix::Matrix;
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::path::Path;

const MAGIC: &[u8; 4] = b"BMX1";

/// An ordered, named collection of matrices (a checkpoint shard).
#[derive(Clone, Debug, Default)]
pub struct TensorBundle {
    pub entries: BTreeMap<String, Matrix>,
}

impl TensorBundle {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn insert(&mut self, name: impl Into<String>, m: Matrix) {
        self.entries.insert(name.into(), m);
    }

    pub fn get(&self, name: &str) -> Result<&Matrix> {
        self.entries
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("tensor `{name}` not in bundle (have: {:?})",
                self.entries.keys().collect::<Vec<_>>()))
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Total parameter count across all entries.
    pub fn num_params(&self) -> usize {
        self.entries.values().map(|m| m.len()).sum()
    }

    /// Serialize to a writer.
    pub fn write_to(&self, w: &mut impl Write) -> Result<()> {
        w.write_all(MAGIC)?;
        w.write_all(&(self.entries.len() as u32).to_le_bytes())?;
        for (name, m) in &self.entries {
            let nb = name.as_bytes();
            w.write_all(&(nb.len() as u32).to_le_bytes())?;
            w.write_all(nb)?;
            w.write_all(&(m.rows as u32).to_le_bytes())?;
            w.write_all(&(m.cols as u32).to_le_bytes())?;
            // Bulk-write the f32 payload.
            let bytes: Vec<u8> = m.data.iter().flat_map(|x| x.to_le_bytes()).collect();
            w.write_all(&bytes)?;
        }
        Ok(())
    }

    /// Deserialize from a reader.
    pub fn read_from(r: &mut impl Read) -> Result<Self> {
        let mut magic = [0u8; 4];
        r.read_exact(&mut magic)?;
        if &magic != MAGIC {
            bail!("bad magic: expected BMX1, got {:?}", magic);
        }
        let count = read_u32(r)? as usize;
        let mut entries = BTreeMap::new();
        for _ in 0..count {
            let name_len = read_u32(r)? as usize;
            if name_len > 1 << 20 {
                bail!("unreasonable name length {name_len}");
            }
            let mut nb = vec![0u8; name_len];
            r.read_exact(&mut nb)?;
            let name = String::from_utf8(nb).context("tensor name not utf-8")?;
            let rows = read_u32(r)? as usize;
            let cols = read_u32(r)? as usize;
            if rows.checked_mul(cols).map_or(true, |n| n > 1 << 31) {
                bail!("unreasonable tensor shape {rows}x{cols}");
            }
            let mut bytes = vec![0u8; rows * cols * 4];
            r.read_exact(&mut bytes)?;
            let data: Vec<f32> = bytes
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect();
            entries.insert(name, Matrix::from_vec(rows, cols, data));
        }
        Ok(TensorBundle { entries })
    }

    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        let mut f = std::io::BufWriter::new(
            std::fs::File::create(path.as_ref())
                .with_context(|| format!("creating {}", path.as_ref().display()))?,
        );
        self.write_to(&mut f)
    }

    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let mut f = std::io::BufReader::new(
            std::fs::File::open(path.as_ref())
                .with_context(|| format!("opening {}", path.as_ref().display()))?,
        );
        Self::read_from(&mut f)
    }
}

fn read_u32(r: &mut impl Read) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::rng::Rng;

    #[test]
    fn round_trip_in_memory() {
        let mut rng = Rng::new(1);
        let mut b = TensorBundle::new();
        b.insert("w1", rng.gaussian_matrix(3, 5, 1.0));
        b.insert("w2", rng.gaussian_matrix(7, 2, 0.5));
        b.insert("empty", Matrix::zeros(0, 4));
        let mut buf = Vec::new();
        b.write_to(&mut buf).unwrap();
        let b2 = TensorBundle::read_from(&mut &buf[..]).unwrap();
        assert_eq!(b2.len(), 3);
        assert_eq!(b2.get("w1").unwrap(), b.get("w1").unwrap());
        assert_eq!(b2.get("w2").unwrap(), b.get("w2").unwrap());
        assert_eq!(b2.get("empty").unwrap().shape(), (0, 4));
    }

    #[test]
    fn round_trip_file() {
        let dir = std::env::temp_dir().join("blast_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bundle.bmx");
        let mut rng = Rng::new(2);
        let mut b = TensorBundle::new();
        b.insert("layer.0.weight", rng.gaussian_matrix(16, 16, 1.0));
        b.save(&path).unwrap();
        let b2 = TensorBundle::load(&path).unwrap();
        assert_eq!(b2.get("layer.0.weight").unwrap(), b.get("layer.0.weight").unwrap());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn bad_magic_rejected() {
        let buf = b"NOPE\x00\x00\x00\x00".to_vec();
        assert!(TensorBundle::read_from(&mut &buf[..]).is_err());
    }

    #[test]
    fn missing_tensor_error_lists_names() {
        let mut b = TensorBundle::new();
        b.insert("a", Matrix::zeros(1, 1));
        let err = b.get("b").unwrap_err().to_string();
        assert!(err.contains("`b`") && err.contains("a"));
    }

    #[test]
    fn num_params() {
        let mut b = TensorBundle::new();
        b.insert("x", Matrix::zeros(3, 4));
        b.insert("y", Matrix::zeros(2, 2));
        assert_eq!(b.num_params(), 16);
    }
}
