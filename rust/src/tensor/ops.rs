//! Matrix-multiply kernels: cache-blocked, thread-parallel GEMM plus the
//! transposed variants the factorization algorithms need (`A^T B`, `A B^T`).
//!
//! These kernels are the Rust-native hot path for the experiments that do
//! not go through PJRT (Algorithm 2 factorization, Rust-native training,
//! and the Table 4 decode-runtime benchmark). Parallelism comes from the
//! in-repo scoped-thread pool (`util::par`); the inner loops are written
//! over contiguous rows so they auto-vectorize.

use super::matrix::Matrix;
use crate::util::par;

/// Panel width along the shared (k) dimension. Chosen so one panel of the
/// B operand stays L1-resident.
const KC: usize = 256;
/// Minimum work (rows*cols*k) before we bother spawning threads.
const PAR_THRESHOLD: usize = 64 * 64 * 64;

/// `C = A · B`.
pub fn matmul(a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(a.cols, b.rows, "matmul shape mismatch: {:?} x {:?}", a.shape(), b.shape());
    let mut c = Matrix::zeros(a.rows, b.cols);
    gemm(1.0, a, b, 0.0, &mut c);
    c
}

/// `C = A^T · B` without materializing the transpose.
pub fn matmul_tn(a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(a.rows, b.rows, "matmul_tn shape mismatch");
    let (k, m) = (a.rows, a.cols);
    let n = b.cols;
    let mut c = Matrix::zeros(m, n);
    if m * n * k >= PAR_THRESHOLD && m >= 8 {
        // Parallelize over output row chunks: each chunk re-scans A/B rows
        // but owns a disjoint slice of C.
        let threads = par::num_threads();
        let chunk_rows = m.div_ceil(threads).max(1);
        par::par_chunks_mut(&mut c.data, chunk_rows * n, |ci, c_chunk| {
            let i0 = ci * chunk_rows;
            let rows_here = c_chunk.len() / n;
            for t in 0..k {
                let arow = a.row(t);
                let brow = b.row(t);
                for ii in 0..rows_here {
                    let aval = arow[i0 + ii];
                    if aval == 0.0 {
                        continue;
                    }
                    let crow = &mut c_chunk[ii * n..(ii + 1) * n];
                    for j in 0..n {
                        crow[j] += aval * brow[j];
                    }
                }
            }
        });
    } else {
        for t in 0..k {
            let arow = a.row(t);
            let brow = b.row(t);
            for i in 0..m {
                let aval = arow[i];
                if aval == 0.0 {
                    continue;
                }
                let crow = &mut c.data[i * n..(i + 1) * n];
                for j in 0..n {
                    crow[j] += aval * brow[j];
                }
            }
        }
    }
    c
}

/// `C = A · B^T` without materializing the transpose.
pub fn matmul_nt(a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(a.cols, b.cols, "matmul_nt shape mismatch");
    let (m, k) = (a.rows, a.cols);
    let n = b.rows;
    let mut c = Matrix::zeros(m, n);
    let body = |i: usize, crow: &mut [f32]| {
        let arow = a.row(i);
        for j in 0..n {
            let brow = b.row(j);
            let mut acc = 0.0f32;
            // Dot product of two contiguous rows — auto-vectorizes well.
            for t in 0..k {
                acc += arow[t] * brow[t];
            }
            crow[j] = acc;
        }
    };
    if m * n * k >= PAR_THRESHOLD && m >= 4 {
        par::par_chunks_mut(&mut c.data, n, |i, crow| body(i, crow));
    } else {
        for i in 0..m {
            let crow = &mut c.data[i * n..(i + 1) * n];
            body(i, crow);
        }
    }
    c
}

/// General `C = alpha * A · B + beta * C`.
pub fn gemm(alpha: f32, a: &Matrix, b: &Matrix, beta: f32, c: &mut Matrix) {
    assert_eq!(a.cols, b.rows, "gemm inner dim mismatch");
    assert_eq!(c.rows, a.rows, "gemm output rows mismatch");
    assert_eq!(c.cols, b.cols, "gemm output cols mismatch");
    let (m, k) = (a.rows, a.cols);
    let n = b.cols;

    if beta != 1.0 {
        if beta == 0.0 {
            c.data.fill(0.0);
        } else {
            c.scale_inplace(beta);
        }
    }

    let row_kernel = |i: usize, crow: &mut [f32]| {
        let arow = a.row(i);
        for k0 in (0..k).step_by(KC) {
            let k1 = (k0 + KC).min(k);
            for t in k0..k1 {
                let aval = alpha * arow[t];
                if aval == 0.0 {
                    continue;
                }
                let brow = b.row(t);
                // axpy: crow += aval * brow — contiguous, vectorizes.
                for j in 0..n {
                    crow[j] += aval * brow[j];
                }
            }
        }
    };

    if m * n * k >= PAR_THRESHOLD && m >= 4 {
        par::par_chunks_mut(&mut c.data, n, |i, crow| row_kernel(i, crow));
    } else {
        for i in 0..m {
            let crow = &mut c.data[i * n..(i + 1) * n];
            row_kernel(i, crow);
        }
    }
}

/// `C = A · B + bias` broadcast over rows (the linear-layer primitive).
pub fn gemm_bias(a: &Matrix, b: &Matrix, bias: Option<&[f32]>) -> Matrix {
    let mut c = matmul(a, b);
    if let Some(bias) = bias {
        assert_eq!(bias.len(), c.cols);
        for i in 0..c.rows {
            let row = c.row_mut(i);
            for (x, bv) in row.iter_mut().zip(bias) {
                *x += bv;
            }
        }
    }
    c
}

/// `y = A · x` for a single vector.
pub fn gemv(a: &Matrix, x: &[f32]) -> Vec<f32> {
    assert_eq!(a.cols, x.len(), "gemv shape mismatch");
    let mut y = vec![0.0f32; a.rows];
    let body = |i: usize, yi: &mut f32| {
        let row = a.row(i);
        let mut acc = 0.0f32;
        for t in 0..a.cols {
            acc += row[t] * x[t];
        }
        *yi = acc;
    };
    if a.rows * a.cols >= PAR_THRESHOLD {
        // Thread-sized row chunks: one dispatch per worker, not per row.
        let chunk = a.rows.div_ceil(par::num_threads()).max(1);
        par::par_chunks_mut(&mut y, chunk, |ci, ychunk| {
            let i0 = ci * chunk;
            for (ii, yi) in ychunk.iter_mut().enumerate() {
                body(i0 + ii, yi);
            }
        });
    } else {
        for (i, yi) in y.iter_mut().enumerate() {
            body(i, yi);
        }
    }
    y
}

/// `y = A^T · x` for a single vector, without materializing the transpose.
pub fn gemv_t(a: &Matrix, x: &[f32]) -> Vec<f32> {
    assert_eq!(a.rows, x.len(), "gemv_t shape mismatch");
    let mut y = vec![0.0f32; a.cols];
    for t in 0..a.rows {
        let xv = x[t];
        if xv == 0.0 {
            continue;
        }
        let row = a.row(t);
        for j in 0..a.cols {
            y[j] += xv * row[j];
        }
    }
    y
}

/// Dot product with f64 accumulation.
pub fn dot(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| (*x as f64) * (*y as f64)).sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::rng::Rng;

    fn naive_matmul(a: &Matrix, b: &Matrix) -> Matrix {
        let mut c = Matrix::zeros(a.rows, b.cols);
        for i in 0..a.rows {
            for j in 0..b.cols {
                let mut acc = 0.0f64;
                for t in 0..a.cols {
                    acc += (a.at(i, t) as f64) * (b.at(t, j) as f64);
                }
                c.set(i, j, acc as f32);
            }
        }
        c
    }

    fn assert_close(a: &Matrix, b: &Matrix, tol: f32) {
        assert_eq!(a.shape(), b.shape());
        let scale = 1.0f32.max(b.max_abs());
        for (x, y) in a.data.iter().zip(&b.data) {
            assert!(
                (x - y).abs() <= tol * scale,
                "mismatch: {x} vs {y} (tol {tol}, scale {scale})"
            );
        }
    }

    #[test]
    fn matmul_matches_naive() {
        let mut rng = Rng::new(42);
        for &(m, k, n) in &[(1, 1, 1), (3, 4, 5), (17, 31, 13), (64, 128, 32), (100, 77, 201)] {
            let a = rng.gaussian_matrix(m, k, 1.0);
            let b = rng.gaussian_matrix(k, n, 1.0);
            assert_close(&matmul(&a, &b), &naive_matmul(&a, &b), 1e-4);
        }
    }

    #[test]
    fn matmul_tn_matches_transpose() {
        let mut rng = Rng::new(7);
        for &(m, k, n) in &[(5, 9, 4), (32, 64, 16), (130, 70, 90)] {
            let a = rng.gaussian_matrix(k, m, 1.0);
            let b = rng.gaussian_matrix(k, n, 1.0);
            assert_close(&matmul_tn(&a, &b), &matmul(&a.transpose(), &b), 1e-4);
        }
    }

    #[test]
    fn matmul_nt_matches_transpose() {
        let mut rng = Rng::new(8);
        for &(m, k, n) in &[(5, 9, 4), (32, 64, 16), (130, 70, 90)] {
            let a = rng.gaussian_matrix(m, k, 1.0);
            let b = rng.gaussian_matrix(n, k, 1.0);
            assert_close(&matmul_nt(&a, &b), &matmul(&a, &b.transpose()), 1e-4);
        }
    }

    #[test]
    fn parallel_paths_match_naive() {
        // Shapes above PAR_THRESHOLD exercise the threaded kernels.
        let mut rng = Rng::new(9);
        let a = rng.gaussian_matrix(96, 96, 1.0);
        let b = rng.gaussian_matrix(96, 96, 1.0);
        assert_close(&matmul(&a, &b), &naive_matmul(&a, &b), 1e-3);
        assert_close(&matmul_tn(&a, &b), &naive_matmul(&a.transpose(), &b), 1e-3);
        assert_close(&matmul_nt(&a, &b), &naive_matmul(&a, &b.transpose()), 1e-3);
    }

    #[test]
    fn gemm_alpha_beta() {
        let mut rng = Rng::new(3);
        let a = rng.gaussian_matrix(6, 7, 1.0);
        let b = rng.gaussian_matrix(7, 5, 1.0);
        let c0 = rng.gaussian_matrix(6, 5, 1.0);
        let mut c = c0.clone();
        gemm(2.0, &a, &b, 0.5, &mut c);
        let expected = naive_matmul(&a, &b).scale(2.0).add(&c0.scale(0.5));
        assert_close(&c, &expected, 1e-4);
    }

    #[test]
    fn gemv_matches_matmul() {
        let mut rng = Rng::new(11);
        let a = rng.gaussian_matrix(13, 29, 1.0);
        let x: Vec<f32> = (0..29).map(|i| (i as f32).sin()).collect();
        let xm = Matrix::from_vec(29, 1, x.clone());
        let y = gemv(&a, &x);
        let ym = matmul(&a, &xm);
        for i in 0..13 {
            assert!((y[i] - ym.at(i, 0)).abs() < 1e-4);
        }
        let yt = gemv_t(&a, &gemv(&a, &x));
        let ytm = matmul(&a.transpose(), &ym);
        for j in 0..29 {
            assert!((yt[j] - ytm.at(j, 0)).abs() < 1e-3);
        }
    }

    #[test]
    fn gemm_bias_broadcast() {
        let a = Matrix::ones(2, 3);
        let b = Matrix::eye(3);
        let out = gemm_bias(&a, &b, Some(&[1.0, 2.0, 3.0]));
        assert_eq!(out.row(0), &[2.0, 3.0, 4.0]);
        assert_eq!(out.row(1), &[2.0, 3.0, 4.0]);
    }

    #[test]
    fn identity_is_noop() {
        let mut rng = Rng::new(5);
        let a = rng.gaussian_matrix(10, 10, 1.0);
        assert_close(&matmul(&a, &Matrix::eye(10)), &a, 1e-6);
        assert_close(&matmul(&Matrix::eye(10), &a), &a, 1e-6);
    }
}
