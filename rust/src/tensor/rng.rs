//! Deterministic RNG (xoshiro256**) with gaussian / uniform sampling.
//!
//! Every experiment in the repo is seeded, so runs are reproducible bit-
//! for-bit. The paper initializes BLAST factors as
//! `U, V ~ N(0, eps^2 I)`, `s ~ Unif(0, 1)` (Algorithm 2 line 1) and
//! from-scratch training uses `N(0, 0.02)`-style init (Appendix C.2) —
//! both are provided here.

use super::matrix::Matrix;

/// xoshiro256** PRNG. Fast, high-quality, and trivially seedable.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second gaussian from Box–Muller.
    spare: Option<f32>,
}

impl Rng {
    /// Seeded constructor (splitmix64 expansion of the seed).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()], spare: None }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f32 {
        // 24 high bits -> f32 mantissa.
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn uniform_range(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        (self.next_u64() % n as u64) as usize
    }

    /// Standard normal via Box–Muller (with spare caching).
    pub fn gaussian(&mut self) -> f32 {
        if let Some(v) = self.spare.take() {
            return v;
        }
        loop {
            let u1 = self.uniform();
            if u1 <= f32::MIN_POSITIVE {
                continue;
            }
            let u2 = self.uniform();
            let r = (-2.0 * u1.ln()).sqrt();
            let theta = 2.0 * std::f32::consts::PI * u2;
            self.spare = Some(r * theta.sin());
            return r * theta.cos();
        }
    }

    /// Matrix with i.i.d. N(0, std^2) entries.
    pub fn gaussian_matrix(&mut self, rows: usize, cols: usize, std: f32) -> Matrix {
        let mut m = Matrix::zeros(rows, cols);
        for x in &mut m.data {
            *x = self.gaussian() * std;
        }
        m
    }

    /// Matrix with i.i.d. Unif[lo, hi) entries.
    pub fn uniform_matrix(&mut self, rows: usize, cols: usize, lo: f32, hi: f32) -> Matrix {
        let mut m = Matrix::zeros(rows, cols);
        for x in &mut m.data {
            *x = self.uniform_range(lo, hi);
        }
        m
    }

    /// Vector with i.i.d. Unif[lo, hi) entries (the `s_{i,j}` init of
    /// Algorithm 2 line 1 uses Unif(0,1)).
    pub fn uniform_vec(&mut self, n: usize, lo: f32, hi: f32) -> Vec<f32> {
        (0..n).map(|_| self.uniform_range(lo, hi)).collect()
    }

    /// Vector with i.i.d. N(0, std^2) entries.
    pub fn gaussian_vec(&mut self, n: usize, std: f32) -> Vec<f32> {
        (0..n).map(|_| self.gaussian() * std).collect()
    }

    /// Sample an index from unnormalized non-negative weights.
    pub fn categorical(&mut self, weights: &[f32]) -> usize {
        let total: f32 = weights.iter().sum();
        let mut t = self.uniform() * total;
        for (i, &w) in weights.iter().enumerate() {
            t -= w;
            if t <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(123);
        let mut b = Rng::new(123);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn uniform_bounds() {
        let mut rng = Rng::new(9);
        for _ in 0..10_000 {
            let u = rng.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn gaussian_moments() {
        let mut rng = Rng::new(77);
        let n = 100_000;
        let xs: Vec<f32> = (0..n).map(|_| rng.gaussian()).collect();
        let mean: f64 = xs.iter().map(|&x| x as f64).sum::<f64>() / n as f64;
        let var: f64 =
            xs.iter().map(|&x| (x as f64 - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn categorical_respects_weights() {
        let mut rng = Rng::new(31);
        let mut counts = [0usize; 3];
        for _ in 0..30_000 {
            counts[rng.categorical(&[1.0, 2.0, 7.0])] += 1;
        }
        assert!(counts[2] > counts[1] && counts[1] > counts[0]);
        let frac2 = counts[2] as f64 / 30_000.0;
        assert!((frac2 - 0.7).abs() < 0.03);
    }

    #[test]
    fn below_in_range() {
        let mut rng = Rng::new(5);
        for _ in 0..1000 {
            assert!(rng.below(17) < 17);
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Rng::new(4);
        let mut xs: Vec<usize> = (0..50).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
