//! Row-major `f32` matrix with block-view support.

use std::fmt;

/// A dense row-major `f32` matrix.
///
/// This is the workhorse container for the whole Rust layer: weights,
/// activations, factor matrices (`U_i`, `V_j`), and gradients all live in
/// `Matrix`. Storage is a flat `Vec<f32>` of length `rows * cols`.
#[derive(Clone, PartialEq)]
pub struct Matrix {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Matrix {
    /// All-zeros matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Matrix { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// All-ones matrix.
    pub fn ones(rows: usize, cols: usize) -> Self {
        Matrix { rows, cols, data: vec![1.0; rows * cols] }
    }

    /// Identity matrix of size `n`.
    pub fn eye(n: usize) -> Self {
        let mut m = Matrix::zeros(n, n);
        for i in 0..n {
            m.data[i * n + i] = 1.0;
        }
        m
    }

    /// Build from an existing flat row-major buffer.
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols, "buffer length mismatch");
        Matrix { rows, cols, data }
    }

    /// Build from a function of (row, col).
    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f32) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for i in 0..rows {
            for j in 0..cols {
                data.push(f(i, j));
            }
        }
        Matrix { rows, cols, data }
    }

    /// Diagonal matrix from a vector.
    pub fn diag(v: &[f32]) -> Self {
        let n = v.len();
        let mut m = Matrix::zeros(n, n);
        for i in 0..n {
            m.data[i * n + i] = v[i];
        }
        m
    }

    #[inline]
    pub fn at(&self, i: usize, j: usize) -> f32 {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[i * self.cols + j]
    }

    #[inline]
    pub fn at_mut(&mut self, i: usize, j: usize) -> &mut f32 {
        debug_assert!(i < self.rows && j < self.cols);
        &mut self.data[i * self.cols + j]
    }

    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f32) {
        self.data[i * self.cols + j] = v;
    }

    /// Row `i` as a slice.
    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Column `j` copied out into a Vec.
    pub fn col(&self, j: usize) -> Vec<f32> {
        (0..self.rows).map(|i| self.at(i, j)).collect()
    }

    /// Shape as a tuple.
    #[inline]
    pub fn shape(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }

    /// Number of elements.
    #[inline]
    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Reshape in place to `rows × cols`, **reusing the existing buffer**
    /// when its capacity suffices — the primitive the kernel `run_into`
    /// paths use to keep the steady-state decode step allocation-free.
    ///
    /// Contents after the call are unspecified: a same-size buffer keeps
    /// its old values (no redundant memset on the hot path — every
    /// `run_into` kernel overwrites all `rows·cols` elements), while a
    /// size change zero-fills. Callers that need a zeroed buffer to
    /// accumulate into should use `ScratchArena::take_matrix` or
    /// [`Matrix::zeros`].
    pub fn reset(&mut self, rows: usize, cols: usize) {
        let len = rows * cols;
        self.rows = rows;
        self.cols = cols;
        if self.data.len() != len {
            self.data.clear();
            self.data.resize(len, 0.0);
        }
    }

    /// Transposed copy.
    pub fn transpose(&self) -> Matrix {
        let mut t = Matrix::zeros(self.cols, self.rows);
        // Blocked transpose for cache friendliness on large matrices.
        const B: usize = 32;
        for i0 in (0..self.rows).step_by(B) {
            for j0 in (0..self.cols).step_by(B) {
                for i in i0..(i0 + B).min(self.rows) {
                    for j in j0..(j0 + B).min(self.cols) {
                        t.data[j * self.rows + i] = self.data[i * self.cols + j];
                    }
                }
            }
        }
        t
    }

    /// Copy of the sub-matrix `rows r0..r1`, `cols c0..c1` (exclusive ends).
    pub fn submatrix(&self, r0: usize, r1: usize, c0: usize, c1: usize) -> Matrix {
        assert!(r1 <= self.rows && c1 <= self.cols && r0 <= r1 && c0 <= c1);
        let mut out = Matrix::zeros(r1 - r0, c1 - c0);
        for (oi, i) in (r0..r1).enumerate() {
            let src = &self.data[i * self.cols + c0..i * self.cols + c1];
            out.row_mut(oi).copy_from_slice(src);
        }
        out
    }

    /// Write `block` into the sub-matrix starting at `(r0, c0)`.
    pub fn set_submatrix(&mut self, r0: usize, c0: usize, block: &Matrix) {
        assert!(r0 + block.rows <= self.rows && c0 + block.cols <= self.cols);
        for i in 0..block.rows {
            let dst_start = (r0 + i) * self.cols + c0;
            self.data[dst_start..dst_start + block.cols].copy_from_slice(block.row(i));
        }
    }

    /// The `(i, j)` block of an equally partitioned matrix (Eq. 1): the
    /// matrix is split into `b_rows × b_cols` blocks; block sizes must
    /// divide evenly.
    pub fn block(&self, i: usize, j: usize, b_rows: usize, b_cols: usize) -> Matrix {
        assert_eq!(self.rows % b_rows, 0, "rows must divide into b_rows blocks");
        assert_eq!(self.cols % b_cols, 0, "cols must divide into b_cols blocks");
        let p = self.rows / b_rows;
        let q = self.cols / b_cols;
        self.submatrix(i * p, (i + 1) * p, j * q, (j + 1) * q)
    }

    /// Block row `i` (all columns): an `p × n` slice of the partition.
    pub fn block_row(&self, i: usize, b_rows: usize) -> Matrix {
        assert_eq!(self.rows % b_rows, 0);
        let p = self.rows / b_rows;
        self.submatrix(i * p, (i + 1) * p, 0, self.cols)
    }

    /// Block column `j` (all rows): an `m × q` slice of the partition.
    pub fn block_col(&self, j: usize, b_cols: usize) -> Matrix {
        assert_eq!(self.cols % b_cols, 0);
        let q = self.cols / b_cols;
        self.submatrix(0, self.rows, j * q, (j + 1) * q)
    }

    /// Frobenius norm.
    pub fn fro_norm(&self) -> f32 {
        self.data.iter().map(|x| (*x as f64) * (*x as f64)).sum::<f64>().sqrt() as f32
    }

    /// Squared Frobenius norm (f64 accumulation).
    pub fn fro_norm_sq(&self) -> f64 {
        self.data.iter().map(|x| (*x as f64) * (*x as f64)).sum::<f64>()
    }

    /// Max absolute entry.
    pub fn max_abs(&self) -> f32 {
        self.data.iter().fold(0.0f32, |a, &x| a.max(x.abs()))
    }

    /// Elementwise map into a new matrix.
    pub fn map(&self, f: impl Fn(f32) -> f32) -> Matrix {
        Matrix {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().map(|&x| f(x)).collect(),
        }
    }

    /// In-place elementwise map.
    pub fn map_inplace(&mut self, f: impl Fn(f32) -> f32) {
        for x in &mut self.data {
            *x = f(*x);
        }
    }

    /// `self + other`.
    pub fn add(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.shape(), other.shape());
        let data = self.data.iter().zip(&other.data).map(|(a, b)| a + b).collect();
        Matrix { rows: self.rows, cols: self.cols, data }
    }

    /// `self - other`.
    pub fn sub(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.shape(), other.shape());
        let data = self.data.iter().zip(&other.data).map(|(a, b)| a - b).collect();
        Matrix { rows: self.rows, cols: self.cols, data }
    }

    /// In-place `self += alpha * other` (axpy).
    pub fn axpy(&mut self, alpha: f32, other: &Matrix) {
        assert_eq!(self.shape(), other.shape());
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += alpha * b;
        }
    }

    /// `alpha * self`.
    pub fn scale(&self, alpha: f32) -> Matrix {
        self.map(|x| alpha * x)
    }

    /// In-place scaling.
    pub fn scale_inplace(&mut self, alpha: f32) {
        for x in &mut self.data {
            *x *= alpha;
        }
    }

    /// Hadamard (elementwise) product, the `⊙` of Eq. 9.
    pub fn hadamard(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.shape(), other.shape());
        let data = self.data.iter().zip(&other.data).map(|(a, b)| a * b).collect();
        Matrix { rows: self.rows, cols: self.cols, data }
    }

    /// Extract the main diagonal.
    pub fn diagonal(&self) -> Vec<f32> {
        let n = self.rows.min(self.cols);
        (0..n).map(|i| self.at(i, i)).collect()
    }

    /// Sum of all entries.
    pub fn sum(&self) -> f64 {
        self.data.iter().map(|&x| x as f64).sum()
    }

    /// Mean of all entries.
    pub fn mean(&self) -> f64 {
        self.sum() / self.len() as f64
    }

    /// True if any entry is NaN or infinite.
    pub fn has_nonfinite(&self) -> bool {
        self.data.iter().any(|x| !x.is_finite())
    }

    /// Vertically stack matrices (all must share `cols`).
    pub fn vstack(mats: &[&Matrix]) -> Matrix {
        assert!(!mats.is_empty());
        let cols = mats[0].cols;
        assert!(mats.iter().all(|m| m.cols == cols));
        let rows = mats.iter().map(|m| m.rows).sum();
        let mut data = Vec::with_capacity(rows * cols);
        for m in mats {
            data.extend_from_slice(&m.data);
        }
        Matrix { rows, cols, data }
    }

    /// Horizontally stack matrices (all must share `rows`).
    pub fn hstack(mats: &[&Matrix]) -> Matrix {
        assert!(!mats.is_empty());
        let rows = mats[0].rows;
        assert!(mats.iter().all(|m| m.rows == rows));
        let cols = mats.iter().map(|m| m.cols).sum();
        let mut out = Matrix::zeros(rows, cols);
        let mut c0 = 0;
        for m in mats {
            out.set_submatrix(0, c0, m);
            c0 += m.cols;
        }
        out
    }
}

impl fmt::Debug for Matrix {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Matrix({}x{})", self.rows, self.cols)?;
        if self.rows <= 8 && self.cols <= 8 {
            writeln!(f)?;
            for i in 0..self.rows {
                write!(f, "  [")?;
                for j in 0..self.cols {
                    write!(f, "{:>9.4}", self.at(i, j))?;
                    if j + 1 < self.cols {
                        write!(f, ", ")?;
                    }
                }
                writeln!(f, "]")?;
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zeros_ones_eye() {
        let z = Matrix::zeros(2, 3);
        assert_eq!(z.shape(), (2, 3));
        assert!(z.data.iter().all(|&x| x == 0.0));
        let o = Matrix::ones(3, 2);
        assert!(o.data.iter().all(|&x| x == 1.0));
        let e = Matrix::eye(3);
        assert_eq!(e.at(0, 0), 1.0);
        assert_eq!(e.at(0, 1), 0.0);
        assert_eq!(e.at(2, 2), 1.0);
    }

    #[test]
    fn indexing_and_rows() {
        let m = Matrix::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(m.at(0, 2), 3.0);
        assert_eq!(m.at(1, 0), 4.0);
        assert_eq!(m.row(1), &[4., 5., 6.]);
        assert_eq!(m.col(1), vec![2., 5.]);
    }

    #[test]
    fn reset_reuses_buffer_and_zero_fills_on_size_change() {
        let mut m = Matrix::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        let ptr = m.data.as_ptr();
        // Same total size: buffer (and contents) retained, shape changes.
        m.reset(3, 2);
        assert_eq!(m.shape(), (3, 2));
        assert_eq!(m.data.as_ptr(), ptr);
        // Shrink within capacity: zero-filled, no reallocation.
        m.reset(1, 2);
        assert_eq!(m.data, vec![0.0, 0.0]);
        assert_eq!(m.data.as_ptr(), ptr);
        // Grow back within capacity: zero-filled, no reallocation.
        m.reset(2, 3);
        assert!(m.data.iter().all(|&v| v == 0.0));
        assert_eq!(m.data.as_ptr(), ptr);
    }

    #[test]
    fn transpose_round_trip() {
        let m = Matrix::from_fn(37, 53, |i, j| (i * 53 + j) as f32);
        let t = m.transpose();
        assert_eq!(t.shape(), (53, 37));
        assert_eq!(t.at(5, 7), m.at(7, 5));
        assert_eq!(t.transpose(), m);
    }

    #[test]
    fn submatrix_and_blocks() {
        let m = Matrix::from_fn(6, 6, |i, j| (i * 10 + j) as f32);
        let s = m.submatrix(1, 3, 2, 5);
        assert_eq!(s.shape(), (2, 3));
        assert_eq!(s.at(0, 0), 12.0);
        assert_eq!(s.at(1, 2), 24.0);

        // 3x3 partition of a 6x6 matrix: blocks are 2x2.
        let b = m.block(1, 2, 3, 3);
        assert_eq!(b.shape(), (2, 2));
        assert_eq!(b.at(0, 0), m.at(2, 4));

        let br = m.block_row(2, 3);
        assert_eq!(br.shape(), (2, 6));
        assert_eq!(br.at(0, 0), m.at(4, 0));

        let bc = m.block_col(0, 3);
        assert_eq!(bc.shape(), (6, 2));
        assert_eq!(bc.at(5, 1), m.at(5, 1));
    }

    #[test]
    fn set_submatrix_round_trip() {
        let mut m = Matrix::zeros(4, 4);
        let b = Matrix::from_fn(2, 2, |i, j| (i + j) as f32 + 1.0);
        m.set_submatrix(1, 2, &b);
        assert_eq!(m.block(0, 1, 2, 2).submatrix(1, 2, 0, 2), b.submatrix(0, 1, 0, 2));
        assert_eq!(m.at(1, 2), 1.0);
        assert_eq!(m.at(2, 3), 3.0);
        assert_eq!(m.at(0, 0), 0.0);
    }

    #[test]
    fn arithmetic() {
        let a = Matrix::from_vec(2, 2, vec![1., 2., 3., 4.]);
        let b = Matrix::from_vec(2, 2, vec![5., 6., 7., 8.]);
        assert_eq!(a.add(&b).data, vec![6., 8., 10., 12.]);
        assert_eq!(b.sub(&a).data, vec![4., 4., 4., 4.]);
        assert_eq!(a.hadamard(&b).data, vec![5., 12., 21., 32.]);
        assert_eq!(a.scale(2.0).data, vec![2., 4., 6., 8.]);
        let mut c = a.clone();
        c.axpy(0.5, &b);
        assert_eq!(c.data, vec![3.5, 5., 6.5, 8.]);
    }

    #[test]
    fn norms() {
        let a = Matrix::from_vec(1, 2, vec![3., 4.]);
        assert!((a.fro_norm() - 5.0).abs() < 1e-6);
        assert!((a.fro_norm_sq() - 25.0).abs() < 1e-9);
        assert_eq!(a.max_abs(), 4.0);
    }

    #[test]
    fn stack() {
        let a = Matrix::ones(1, 2);
        let b = Matrix::zeros(2, 2);
        let v = Matrix::vstack(&[&a, &b]);
        assert_eq!(v.shape(), (3, 2));
        assert_eq!(v.at(0, 0), 1.0);
        assert_eq!(v.at(1, 0), 0.0);

        let c = Matrix::ones(2, 1);
        let h = Matrix::hstack(&[&b, &c]);
        assert_eq!(h.shape(), (2, 3));
        assert_eq!(h.at(0, 2), 1.0);
        assert_eq!(h.at(1, 0), 0.0);
    }

    #[test]
    fn diag_and_diagonal() {
        let d = Matrix::diag(&[1., 2., 3.]);
        assert_eq!(d.at(1, 1), 2.0);
        assert_eq!(d.at(0, 1), 0.0);
        assert_eq!(d.diagonal(), vec![1., 2., 3.]);
    }

    #[test]
    fn nonfinite_detection() {
        let mut m = Matrix::zeros(2, 2);
        assert!(!m.has_nonfinite());
        m.set(0, 1, f32::NAN);
        assert!(m.has_nonfinite());
    }
}
