//! Dense tensor substrate.
//!
//! The paper's algorithms (Algorithm 1 matmul, Algorithm 2 factorization,
//! transformer inference/training) are expressed over row-major `f32`
//! matrices. This module provides the `Matrix` type, cache-blocked and
//! rayon-parallel GEMM/GEMV kernels, elementwise operations, block views
//! (the `b×b` partitioning of Eq. 1), a deterministic RNG, and a binary
//! serialization format shared with the Python build path.

pub mod matrix;
pub mod ops;
pub mod rng;
pub mod io;

pub use matrix::Matrix;
pub use ops::{gemm, gemm_bias, gemv, matmul, matmul_tn, matmul_nt};
pub use rng::Rng;
