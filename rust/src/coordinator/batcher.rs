//! Dynamic batcher: greedily form decode batches up to `max_batch`
//! requests, waiting at most `max_wait` for stragglers — the standard
//! continuous-batching admission policy (vLLM-style, simplified to
//! request granularity).

use super::request::GenerateRequest;
use std::sync::mpsc::Receiver;
use std::time::{Duration, Instant};

/// Batching policy knobs.
#[derive(Clone, Copy, Debug)]
pub struct BatcherConfig {
    pub max_batch: usize,
    pub max_wait: Duration,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig { max_batch: 8, max_wait: Duration::from_millis(2) }
    }
}

/// Pulls requests off a channel and forms batches.
pub struct DynamicBatcher {
    pub cfg: BatcherConfig,
    rx: Receiver<GenerateRequest>,
    /// Request pulled while closing out the previous batch.
    pending: Option<GenerateRequest>,
}

impl DynamicBatcher {
    pub fn new(rx: Receiver<GenerateRequest>, cfg: BatcherConfig) -> Self {
        DynamicBatcher { cfg, rx, pending: None }
    }

    /// Block for the next batch. Returns `None` when the channel is
    /// closed and drained.
    pub fn next_batch(&mut self) -> Option<Vec<GenerateRequest>> {
        let mut batch = Vec::with_capacity(self.cfg.max_batch);
        if let Some(p) = self.pending.take() {
            batch.push(p);
        }
        if batch.is_empty() {
            // Block for the first request.
            match self.rx.recv() {
                Ok(req) => batch.push(req),
                Err(_) => return None,
            }
        }
        // Fill up to max_batch within the deadline.
        let deadline = Instant::now() + self.cfg.max_wait;
        while batch.len() < self.cfg.max_batch {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match self.rx.recv_timeout(deadline - now) {
                Ok(req) => batch.push(req),
                Err(std::sync::mpsc::RecvTimeoutError::Timeout) => break,
                Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => break,
            }
        }
        Some(batch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::channel;

    fn req(id: u64, tx: &std::sync::mpsc::Sender<super::super::request::GenerateResponse>) -> GenerateRequest {
        GenerateRequest {
            id,
            variant: "v".into(),
            prompt: vec![1],
            max_new_tokens: 1,
            respond_to: tx.clone(),
            enqueued_at: Instant::now(),
        }
    }

    #[test]
    fn batches_respect_max_batch() {
        let (tx, rx) = channel();
        let (rtx, _rrx) = channel();
        for i in 0..10 {
            tx.send(req(i, &rtx)).unwrap();
        }
        drop(tx);
        let mut b = DynamicBatcher::new(
            rx,
            BatcherConfig { max_batch: 4, max_wait: Duration::from_millis(5) },
        );
        let b1 = b.next_batch().unwrap();
        assert_eq!(b1.len(), 4);
        let b2 = b.next_batch().unwrap();
        assert_eq!(b2.len(), 4);
        let b3 = b.next_batch().unwrap();
        assert_eq!(b3.len(), 2);
        assert!(b.next_batch().is_none());
    }

    #[test]
    fn preserves_fifo_order() {
        let (tx, rx) = channel();
        let (rtx, _rrx) = channel();
        for i in 0..6 {
            tx.send(req(i, &rtx)).unwrap();
        }
        drop(tx);
        let mut b = DynamicBatcher::new(
            rx,
            BatcherConfig { max_batch: 8, max_wait: Duration::from_millis(1) },
        );
        let batch = b.next_batch().unwrap();
        let ids: Vec<u64> = batch.iter().map(|r| r.id).collect();
        assert_eq!(ids, vec![0, 1, 2, 3, 4, 5]);
    }

    #[test]
    fn deadline_bounds_wait() {
        let (tx, rx) = channel();
        let (rtx, _rrx) = channel();
        tx.send(req(0, &rtx)).unwrap();
        let mut b = DynamicBatcher::new(
            rx,
            BatcherConfig { max_batch: 8, max_wait: Duration::from_millis(10) },
        );
        let t0 = Instant::now();
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.len(), 1);
        assert!(t0.elapsed() < Duration::from_millis(500), "waited too long");
        drop(tx);
        assert!(b.next_batch().is_none());
    }
}
