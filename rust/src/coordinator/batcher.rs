//! Admission policy for the continuous-batching worker.
//!
//! The worker's step loop calls [`DynamicBatcher::try_admit`] between
//! decode iterations: a non-blocking drain of up to `free_slots` queued
//! requests, so new arrivals join the running batch without ever
//! stalling live sequences. [`DynamicBatcher::recv_one`] parks an idle
//! worker until work arrives. The legacy blocking
//! [`DynamicBatcher::next_batch`] (greedy batch formation up to
//! `max_batch` within `max_wait`) is kept for request-granularity
//! callers and tests.

use super::request::WorkItem;
use std::sync::mpsc::{Receiver, TryRecvError};
use std::time::{Duration, Instant};

/// Batching policy knobs. Under continuous batching `max_batch` caps
/// the admissions (prefills) per decode iteration; `max_wait` only
/// affects the legacy `next_batch` path.
#[derive(Clone, Copy, Debug)]
pub struct BatcherConfig {
    pub max_batch: usize,
    pub max_wait: Duration,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        let max_batch = crate::util::config::EngineConfig::global().max_batch;
        BatcherConfig { max_batch, max_wait: Duration::from_millis(2) }
    }
}

/// Pulls requests off a channel and forms batches. (Purely a view over
/// the channel: the channel itself is the only queue, so the blocking
/// and non-blocking paths can be mixed freely without losing FIFO
/// order.)
pub struct DynamicBatcher {
    pub cfg: BatcherConfig,
    rx: Receiver<WorkItem>,
}

impl DynamicBatcher {
    pub fn new(rx: Receiver<WorkItem>, cfg: BatcherConfig) -> Self {
        DynamicBatcher { cfg, rx }
    }

    /// Non-blocking admission: drain up to `limit` queued requests in
    /// FIFO order, returning immediately with whatever is available
    /// (possibly nothing). The continuous-batching step loop calls this
    /// with the number of free KV-pool slots between decode iterations.
    pub fn try_admit(&mut self, limit: usize) -> Vec<WorkItem> {
        let mut out = Vec::new();
        while out.len() < limit {
            match self.rx.try_recv() {
                Ok(req) => out.push(req),
                Err(TryRecvError::Empty) | Err(TryRecvError::Disconnected) => break,
            }
        }
        out
    }

    /// Block for a single request — used to park an idle worker.
    /// Returns `None` when the channel is closed and drained.
    pub fn recv_one(&mut self) -> Option<WorkItem> {
        self.rx.recv().ok()
    }

    /// Block for the next batch. Returns `None` when the channel is
    /// closed and drained.
    pub fn next_batch(&mut self) -> Option<Vec<WorkItem>> {
        let mut batch = Vec::with_capacity(self.cfg.max_batch);
        // Block for the first request.
        match self.rx.recv() {
            Ok(req) => batch.push(req),
            Err(_) => return None,
        }
        // Fill up to max_batch within the deadline.
        let deadline = Instant::now() + self.cfg.max_wait;
        while batch.len() < self.cfg.max_batch {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match self.rx.recv_timeout(deadline - now) {
                Ok(req) => batch.push(req),
                Err(std::sync::mpsc::RecvTimeoutError::Timeout) => break,
                Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => break,
            }
        }
        Some(batch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::channel;

    fn req(
        id: u64,
        tx: &std::sync::mpsc::Sender<super::super::request::ResponseEvent>,
    ) -> WorkItem {
        WorkItem {
            id,
            req: super::super::request::GenerateRequest::new(vec![1], 1),
            respond_to: tx.clone(),
            enqueued_at: Instant::now(),
            resume: None,
        }
    }

    #[test]
    fn try_admit_is_nonblocking_fifo_and_capped() {
        let (tx, rx) = channel();
        let (rtx, _rrx) = channel();
        for i in 0..5 {
            tx.send(req(i, &rtx)).unwrap();
        }
        let mut b = DynamicBatcher::new(rx, BatcherConfig::default());
        // Cap 0: nothing, even with work queued.
        assert!(b.try_admit(0).is_empty());
        // Cap 3: exactly the first three, in order.
        let ids: Vec<u64> = b.try_admit(3).iter().map(|r| r.id).collect();
        assert_eq!(ids, vec![0, 1, 2]);
        // Remaining two, cap larger than the queue: returns what's there.
        let ids: Vec<u64> = b.try_admit(8).iter().map(|r| r.id).collect();
        assert_eq!(ids, vec![3, 4]);
        // Empty queue: immediate empty result, channel still open.
        let t0 = Instant::now();
        assert!(b.try_admit(8).is_empty());
        assert!(t0.elapsed() < Duration::from_millis(100), "try_admit blocked");
        drop(tx);
        assert!(b.try_admit(8).is_empty());
    }

    #[test]
    fn recv_one_blocks_then_yields_and_detects_close() {
        let (tx, rx) = channel();
        let (rtx, _rrx) = channel();
        tx.send(req(9, &rtx)).unwrap();
        let mut b = DynamicBatcher::new(rx, BatcherConfig::default());
        assert_eq!(b.recv_one().unwrap().id, 9);
        drop(tx);
        assert!(b.recv_one().is_none());
    }

    #[test]
    fn blocking_and_nonblocking_paths_share_fifo_order() {
        // Mixing the legacy blocking path with try_admit must keep the
        // channel's FIFO order intact (there is no side-buffer).
        let (tx, rx) = channel();
        let (rtx, _rrx) = channel();
        for i in 0..4 {
            tx.send(req(i, &rtx)).unwrap();
        }
        let mut b = DynamicBatcher::new(
            rx,
            BatcherConfig { max_batch: 2, max_wait: Duration::from_millis(5) },
        );
        // next_batch consumes 0 and 1; try_admit picks up from 2;
        // recv_one yields 3.
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.len(), 2);
        let ids: Vec<u64> = b.try_admit(1).iter().map(|r| r.id).collect();
        assert_eq!(ids, vec![2]);
        assert_eq!(b.recv_one().unwrap().id, 3);
        drop(tx);
    }

    #[test]
    fn batches_respect_max_batch() {
        let (tx, rx) = channel();
        let (rtx, _rrx) = channel();
        for i in 0..10 {
            tx.send(req(i, &rtx)).unwrap();
        }
        drop(tx);
        let mut b = DynamicBatcher::new(
            rx,
            BatcherConfig { max_batch: 4, max_wait: Duration::from_millis(5) },
        );
        let b1 = b.next_batch().unwrap();
        assert_eq!(b1.len(), 4);
        let b2 = b.next_batch().unwrap();
        assert_eq!(b2.len(), 4);
        let b3 = b.next_batch().unwrap();
        assert_eq!(b3.len(), 2);
        assert!(b.next_batch().is_none());
    }

    #[test]
    fn preserves_fifo_order() {
        let (tx, rx) = channel();
        let (rtx, _rrx) = channel();
        for i in 0..6 {
            tx.send(req(i, &rtx)).unwrap();
        }
        drop(tx);
        let mut b = DynamicBatcher::new(
            rx,
            BatcherConfig { max_batch: 8, max_wait: Duration::from_millis(1) },
        );
        let batch = b.next_batch().unwrap();
        let ids: Vec<u64> = batch.iter().map(|r| r.id).collect();
        assert_eq!(ids, vec![0, 1, 2, 3, 4, 5]);
    }

    #[test]
    fn deadline_bounds_wait() {
        let (tx, rx) = channel();
        let (rtx, _rrx) = channel();
        tx.send(req(0, &rtx)).unwrap();
        let mut b = DynamicBatcher::new(
            rx,
            BatcherConfig { max_batch: 8, max_wait: Duration::from_millis(10) },
        );
        let t0 = Instant::now();
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.len(), 1);
        assert!(t0.elapsed() < Duration::from_millis(500), "waited too long");
        drop(tx);
        assert!(b.next_batch().is_none());
    }
}
