//! Serving metrics: counters + latency histogram, lock-protected and
//! cheap to clone snapshots out of.

use std::sync::Mutex;
use std::time::Duration;

/// Exponential-bucket latency histogram (microseconds).
#[derive(Clone, Debug, Default)]
pub struct Histogram {
    /// Bucket i counts samples in [2^i, 2^{i+1}) µs; 40 buckets ≈ 12 days.
    buckets: Vec<u64>,
    count: u64,
    sum_us: u64,
    max_us: u64,
}

impl Histogram {
    pub fn record(&mut self, d: Duration) {
        let us = d.as_micros().min(u128::from(u64::MAX)) as u64;
        let idx = (64 - us.max(1).leading_zeros()) as usize;
        if self.buckets.len() <= idx {
            self.buckets.resize(idx + 1, 0);
        }
        self.buckets[idx] += 1;
        self.count += 1;
        self.sum_us += us;
        self.max_us = self.max_us.max(us);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn mean(&self) -> Duration {
        if self.count == 0 {
            return Duration::ZERO;
        }
        Duration::from_micros(self.sum_us / self.count)
    }

    pub fn max(&self) -> Duration {
        Duration::from_micros(self.max_us)
    }

    /// Upper bound of the bucket containing the p-th percentile.
    pub fn percentile(&self, p: f64) -> Duration {
        if self.count == 0 {
            return Duration::ZERO;
        }
        let target = ((self.count as f64) * p / 100.0).ceil() as u64;
        let mut seen = 0u64;
        for (i, &c) in self.buckets.iter().enumerate() {
            seen += c;
            if seen >= target {
                return Duration::from_micros(1u64 << i);
            }
        }
        self.max()
    }
}

/// Aggregate serving metrics.
#[derive(Debug, Default)]
pub struct Metrics {
    inner: Mutex<MetricsInner>,
}

#[derive(Clone, Debug, Default)]
pub struct MetricsInner {
    pub requests: u64,
    pub tokens_generated: u64,
    pub batches: u64,
    pub batch_size_sum: u64,
    pub queue_latency: Histogram,
    pub e2e_latency: Histogram,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record_batch(&self, batch_size: usize) {
        let mut m = self.inner.lock().unwrap();
        m.batches += 1;
        m.batch_size_sum += batch_size as u64;
    }

    pub fn record_request(&self, tokens: usize, queue: Duration, e2e: Duration) {
        let mut m = self.inner.lock().unwrap();
        m.requests += 1;
        m.tokens_generated += tokens as u64;
        m.queue_latency.record(queue);
        m.e2e_latency.record(e2e);
    }

    pub fn snapshot(&self) -> MetricsInner {
        self.inner.lock().unwrap().clone()
    }

    pub fn report(&self) -> String {
        let m = self.snapshot();
        let mean_batch = if m.batches > 0 {
            m.batch_size_sum as f64 / m.batches as f64
        } else {
            0.0
        };
        format!(
            "requests={} tokens={} batches={} mean_batch={:.2} \
             queue(mean={:?} p95={:?}) e2e(mean={:?} p95={:?} max={:?})",
            m.requests,
            m.tokens_generated,
            m.batches,
            mean_batch,
            m.queue_latency.mean(),
            m.queue_latency.percentile(95.0),
            m.e2e_latency.mean(),
            m.e2e_latency.percentile(95.0),
            m.e2e_latency.max(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_percentiles_ordered() {
        let mut h = Histogram::default();
        for us in [10u64, 20, 40, 80, 160, 320, 640, 1280, 2560, 100_000] {
            h.record(Duration::from_micros(us));
        }
        assert_eq!(h.count(), 10);
        assert!(h.percentile(50.0) <= h.percentile(95.0));
        assert!(h.percentile(95.0) <= h.max() * 2);
        assert!(h.mean() > Duration::from_micros(100));
    }

    #[test]
    fn metrics_aggregate() {
        let m = Metrics::new();
        m.record_batch(4);
        m.record_batch(2);
        for _ in 0..6 {
            m.record_request(5, Duration::from_micros(50), Duration::from_millis(1));
        }
        let s = m.snapshot();
        assert_eq!(s.requests, 6);
        assert_eq!(s.tokens_generated, 30);
        assert_eq!(s.batches, 2);
        assert!(m.report().contains("requests=6"));
    }

    #[test]
    fn empty_metrics_safe() {
        let m = Metrics::new();
        let s = m.snapshot();
        assert_eq!(s.requests, 0);
        assert_eq!(s.e2e_latency.mean(), Duration::ZERO);
        assert!(!m.report().is_empty());
    }
}
