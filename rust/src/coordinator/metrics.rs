//! Serving metrics: counters, queue-depth gauge, and fixed-bucket
//! latency histograms (p50/p95/p99), built on the crate-wide [`obs`]
//! primitives — every field is a relaxed atomic, so recording from the
//! worker fleet takes no lock and performs no allocation.
//!
//! Tracked per worker fleet:
//!
//! * request counters — requests served, tokens generated, decode
//!   iterations (`batches`) and their summed width;
//! * queue depth — submitted-but-not-yet-admitted requests (current and
//!   peak), maintained by `record_enqueued`/`record_admitted`;
//! * latency histograms — queue wait, end-to-end, **TTFT** (enqueue →
//!   first generated token) and **TPOT** (mean inter-token latency per
//!   request), as the shared log-linear [`Histogram`] (which lives in
//!   [`obs`] since the observability PR; re-exported here so
//!   `coordinator::Histogram` keeps working) with p50/p95/p99 in the
//!   report.
//!
//! [`obs`]: crate::obs

pub use crate::obs::Histogram;

use crate::obs::{Counter, Gauge};
use crate::util::json::{obj, Json};
use std::time::Duration;

/// Aggregate serving metrics. All-atomic: recorders on the hot step
/// loop and snapshot readers never contend on a lock.
#[derive(Debug, Default)]
pub struct Metrics {
    requests: Counter,
    cancelled: Counter,
    shed: Counter,
    expired: Counter,
    poisoned: Counter,
    preempted: Counter,
    tokens_generated: Counter,
    batches: Counter,
    batch_size_sum: Counter,
    queue_depth: Gauge,
    queue_depth_peak: Gauge,
    queue_latency: Histogram,
    e2e_latency: Histogram,
    ttft: Histogram,
    tpot: Histogram,
}

/// A point-in-time copy of [`Metrics`] (plain values, cheap to move
/// around and assert on).
#[derive(Clone, Debug, Default)]
pub struct MetricsInner {
    pub requests: u64,
    /// Of `requests`, how many were cancelled (client dropped the
    /// stream before completion). Cancelled requests keep their token
    /// counts but are excluded from the e2e/tpot histograms (queue
    /// wait is recorded at admission, before cancellation can be
    /// known); the ttft histogram records a request iff its first
    /// token was actually delivered, so a mid-stream cancel keeps its
    /// TTFT.
    pub cancelled: u64,
    /// Requests rejected without serving: pending queue at its bound
    /// (`Overloaded`) or a KV budget exceeding the whole arena
    /// (`TooLarge`). Not counted in `requests` and excluded from every
    /// latency histogram.
    pub shed: u64,
    /// Requests terminated by a deadline/queue-timeout expiry (queued
    /// or mid-decode). Not counted in `requests`, excluded from the
    /// latency histograms.
    pub expired: u64,
    /// Requests quarantined after a worker panic touched them (blocks
    /// freed, `ServeError::Poisoned` delivered). Not counted in
    /// `requests`.
    pub poisoned: u64,
    /// KV-pressure preemptions (sequence evicted mid-decode and
    /// re-enqueued for recompute-resume). A per-event counter, not a
    /// request outcome: a preempted request that later finishes still
    /// lands in `requests`.
    pub preempted: u64,
    pub tokens_generated: u64,
    /// Decode iterations of the continuous-batching step loop.
    pub batches: u64,
    /// Summed step width (active sequences per iteration).
    pub batch_size_sum: u64,
    /// Requests submitted but not yet admitted to a KV slot.
    pub queue_depth: u64,
    pub queue_depth_peak: u64,
    pub queue_latency: Histogram,
    pub e2e_latency: Histogram,
    /// Enqueue → first generated token.
    pub ttft: Histogram,
    /// Mean inter-token latency, one sample per request with ≥ 2 tokens.
    pub tpot: Histogram,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    /// A request entered a variant queue.
    pub fn record_enqueued(&self) {
        self.queue_depth.add(1);
        // Peak maintenance races concurrent admissions by design — the
        // gauge pair is advisory, and `set_max` keeps it monotone.
        self.queue_depth_peak.set_max(self.queue_depth.get());
    }

    /// A request failed to enqueue after `record_enqueued` (the worker
    /// shut down): undo the gauge.
    pub fn record_enqueue_aborted(&self) {
        self.queue_depth.sub(1);
    }

    /// A request left the queue for a KV slot after waiting `queue`.
    pub fn record_admitted(&self, queue: Duration) {
        // Saturating: enqueue accounting races admission by design (the
        // gauge is advisory), so never underflow.
        self.queue_depth.sub(1);
        self.queue_latency.record(queue);
    }

    /// A queued request was shed (`Overloaded` at the pending bound, or
    /// `TooLarge` for the arena): it leaves the queue without serving.
    pub fn record_shed(&self) {
        self.shed.inc();
        self.queue_depth.sub(1);
    }

    /// A *queued* request's deadline/queue-timeout expired: it leaves
    /// the queue without serving.
    pub fn record_expired_queued(&self) {
        self.expired.inc();
        self.queue_depth.sub(1);
    }

    /// An *active* request's deadline expired mid-decode (it already
    /// left the queue at admission — no gauge movement).
    pub fn record_expired_active(&self) {
        self.expired.inc();
    }

    /// An active request was quarantined after a worker panic.
    pub fn record_poisoned(&self) {
        self.poisoned.inc();
    }

    /// An active sequence was preempted for KV pressure and re-entered
    /// the pending queue (so the depth gauge goes back up by one).
    pub fn record_preempted(&self) {
        self.preempted.inc();
        self.queue_depth.add(1);
        self.queue_depth_peak.set_max(self.queue_depth.get());
    }

    /// A preempted sequence was re-admitted. Gauge-only: its queue wait
    /// was already recorded at first admission, and a second histogram
    /// sample would double-count the request.
    pub fn record_readmitted(&self) {
        self.queue_depth.sub(1);
    }

    /// One decode iteration advanced `batch_size` sequences.
    pub fn record_batch(&self, batch_size: usize) {
        self.batches.inc();
        self.batch_size_sum.add(batch_size as u64);
    }

    /// A request produced its first token `d` after being enqueued.
    pub fn record_ttft(&self, d: Duration) {
        self.ttft.record(d);
    }

    /// A request retired: `tokens` generated, end-to-end latency `e2e`,
    /// and (when it generated ≥ 2 tokens) its mean inter-token latency.
    /// Cancelled requests (client gone before completion) are counted
    /// but kept out of the latency histograms — a truncated request's
    /// "latency" would make the served percentiles look artificially
    /// good.
    pub fn record_request(
        &self,
        tokens: usize,
        e2e: Duration,
        tpot: Option<Duration>,
        cancelled: bool,
    ) {
        self.requests.inc();
        self.tokens_generated.add(tokens as u64);
        if cancelled {
            self.cancelled.inc();
            return;
        }
        self.e2e_latency.record(e2e);
        if let Some(t) = tpot {
            self.tpot.record(t);
        }
    }

    pub fn snapshot(&self) -> MetricsInner {
        MetricsInner {
            requests: self.requests.get(),
            cancelled: self.cancelled.get(),
            shed: self.shed.get(),
            expired: self.expired.get(),
            poisoned: self.poisoned.get(),
            preempted: self.preempted.get(),
            tokens_generated: self.tokens_generated.get(),
            batches: self.batches.get(),
            batch_size_sum: self.batch_size_sum.get(),
            queue_depth: self.queue_depth.get(),
            queue_depth_peak: self.queue_depth_peak.get(),
            queue_latency: self.queue_latency.clone(),
            e2e_latency: self.e2e_latency.clone(),
            ttft: self.ttft.clone(),
            tpot: self.tpot.clone(),
        }
    }

    /// The serving section of the metrics snapshot
    /// (`obs::MetricsSnapshot::with_serving`).
    pub fn snapshot_json(&self) -> Json {
        let m = self.snapshot();
        let mean_batch = if m.batches > 0 {
            m.batch_size_sum as f64 / m.batches as f64
        } else {
            0.0
        };
        obj(vec![
            ("requests", Json::from(m.requests as usize)),
            ("cancelled", Json::from(m.cancelled as usize)),
            ("shed", Json::from(m.shed as usize)),
            ("expired", Json::from(m.expired as usize)),
            ("poisoned", Json::from(m.poisoned as usize)),
            ("preempted", Json::from(m.preempted as usize)),
            ("tokens_generated", Json::from(m.tokens_generated as usize)),
            ("steps", Json::from(m.batches as usize)),
            ("mean_step_width", Json::from(mean_batch)),
            ("queue_depth", Json::from(m.queue_depth as usize)),
            ("queue_depth_peak", Json::from(m.queue_depth_peak as usize)),
            ("queue_latency", m.queue_latency.to_json()),
            ("e2e_latency", m.e2e_latency.to_json()),
            ("ttft", m.ttft.to_json()),
            ("tpot", m.tpot.to_json()),
        ])
    }

    pub fn report(&self) -> String {
        let m = self.snapshot();
        let mean_batch = if m.batches > 0 {
            m.batch_size_sum as f64 / m.batches as f64
        } else {
            0.0
        };
        let (q50, q95, q99) = m.queue_latency.percentiles();
        let (e50, e95, e99) = m.e2e_latency.percentiles();
        let (t50, t95, t99) = m.ttft.percentiles();
        let (p50, p95, p99) = m.tpot.percentiles();
        format!(
            "requests={} (cancelled {}) shed={} expired={} poisoned={} preempted={} \
             tokens={} steps={} mean_step_width={:.2} \
             queue_depth={} (peak {}) \
             queue(p50={q50:?} p95={q95:?} p99={q99:?}) \
             e2e(p50={e50:?} p95={e95:?} p99={e99:?} max={:?}) \
             ttft(p50={t50:?} p95={t95:?} p99={t99:?}) \
             tpot(p50={p50:?} p95={p95:?} p99={p99:?})",
            m.requests,
            m.cancelled,
            m.shed,
            m.expired,
            m.poisoned,
            m.preempted,
            m.tokens_generated,
            m.batches,
            mean_batch,
            m.queue_depth,
            m.queue_depth_peak,
            m.e2e_latency.max(),
        )
    }
}

#[cfg(test)]
mod tests {
    // The Histogram unit tests (percentile ordering, log-linear error
    // bound, bucket-index/upper consistency) moved with the type to
    // `obs::tests`; what stays here covers the serving aggregation.
    use super::*;

    #[test]
    fn metrics_aggregate() {
        let m = Metrics::new();
        m.record_batch(4);
        m.record_batch(2);
        for _ in 0..6 {
            m.record_enqueued();
        }
        for _ in 0..6 {
            m.record_admitted(Duration::from_micros(50));
            m.record_ttft(Duration::from_micros(300));
            m.record_request(
                5,
                Duration::from_millis(1),
                Some(Duration::from_micros(120)),
                false,
            );
        }
        let s = m.snapshot();
        assert_eq!(s.requests, 6);
        assert_eq!(s.cancelled, 0);
        assert_eq!(s.tokens_generated, 30);
        assert_eq!(s.batches, 2);
        assert_eq!(s.queue_depth, 0);
        assert_eq!(s.queue_depth_peak, 6);
        assert_eq!(s.queue_latency.count(), 6);
        assert_eq!(s.ttft.count(), 6);
        assert_eq!(s.tpot.count(), 6);
        assert!(m.report().contains("requests=6"));
        assert!(m.report().contains("ttft"));
    }

    #[test]
    fn cancelled_requests_counted_but_kept_out_of_latency() {
        let m = Metrics::new();
        m.record_request(3, Duration::from_millis(5), None, true);
        m.record_request(4, Duration::from_millis(1), None, false);
        let s = m.snapshot();
        assert_eq!(s.requests, 2);
        assert_eq!(s.cancelled, 1);
        assert_eq!(s.tokens_generated, 7);
        assert_eq!(s.e2e_latency.count(), 1, "cancelled excluded from e2e");
        assert!(m.report().contains("cancelled 1"));
    }

    #[test]
    fn every_queue_exit_path_balances_the_depth_gauge() {
        let m = Metrics::new();
        // Five enqueues leave by five different paths; the gauge must
        // return to zero (the chaos suite's leak invariant).
        for _ in 0..5 {
            m.record_enqueued();
        }
        m.record_admitted(Duration::from_micros(10)); // served
        m.record_shed(); // overloaded
        m.record_expired_queued(); // deadline in queue
        m.record_enqueue_aborted(); // worker gone at submit
        m.record_admitted(Duration::from_micros(10)); // will be preempted
        assert_eq!(m.snapshot().queue_depth, 0);
        // Preemption re-enters the queue; re-admission drains it again
        // without a second queue-latency sample.
        m.record_preempted();
        assert_eq!(m.snapshot().queue_depth, 1);
        m.record_readmitted();
        let s = m.snapshot();
        assert_eq!(s.queue_depth, 0);
        assert_eq!(s.queue_latency.count(), 2, "readmission adds no histogram sample");
        assert_eq!((s.shed, s.expired, s.preempted), (1, 1, 1));
    }

    #[test]
    fn failure_outcomes_stay_out_of_request_counters() {
        let m = Metrics::new();
        m.record_poisoned();
        m.record_expired_active();
        m.record_shed();
        let s = m.snapshot();
        assert_eq!(s.requests, 0, "failed requests are not 'served'");
        assert_eq!((s.poisoned, s.expired, s.shed), (1, 1, 1));
        assert_eq!(s.e2e_latency.count(), 0);
        let j = m.snapshot_json();
        assert_eq!(j.get("poisoned").unwrap().as_usize(), Some(1));
        assert_eq!(j.get("expired").unwrap().as_usize(), Some(1));
        assert_eq!(j.get("shed").unwrap().as_usize(), Some(1));
        assert_eq!(j.get("preempted").unwrap().as_usize(), Some(0));
        assert!(m.report().contains("poisoned=1"));
    }

    #[test]
    fn queue_depth_never_underflows() {
        let m = Metrics::new();
        m.record_admitted(Duration::ZERO);
        assert_eq!(m.snapshot().queue_depth, 0);
    }

    #[test]
    fn empty_metrics_safe() {
        let m = Metrics::new();
        let s = m.snapshot();
        assert_eq!(s.requests, 0);
        assert_eq!(s.e2e_latency.mean(), Duration::ZERO);
        assert_eq!(s.ttft.percentile(99.0), Duration::ZERO);
        assert!(!m.report().is_empty());
    }

    #[test]
    fn snapshot_json_mirrors_report() {
        let m = Metrics::new();
        m.record_batch(3);
        m.record_enqueued();
        m.record_admitted(Duration::from_micros(40));
        m.record_ttft(Duration::from_micros(200));
        m.record_request(5, Duration::from_millis(2), Some(Duration::from_micros(90)), false);
        let j = m.snapshot_json();
        assert_eq!(j.get("requests").unwrap().as_usize(), Some(1));
        assert_eq!(j.get("tokens_generated").unwrap().as_usize(), Some(5));
        assert_eq!(j.get("steps").unwrap().as_usize(), Some(1));
        assert_eq!(
            j.get("ttft").unwrap().get("count").unwrap().as_usize(),
            Some(1)
        );
        assert!(j.get("e2e_latency").unwrap().get("p99_us").unwrap().as_usize().unwrap() > 0);
    }
}
