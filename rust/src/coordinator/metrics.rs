//! Serving metrics: counters, queue-depth gauge, and fixed-bucket
//! latency histograms (p50/p95/p99), lock-protected and cheap to clone
//! snapshots out of.
//!
//! Tracked per worker fleet:
//!
//! * request counters — requests served, tokens generated, decode
//!   iterations (`batches`) and their summed width;
//! * queue depth — submitted-but-not-yet-admitted requests (current and
//!   peak), maintained by `record_enqueued`/`record_admitted`;
//! * latency histograms — queue wait, end-to-end, **TTFT** (enqueue →
//!   first generated token) and **TPOT** (mean inter-token latency per
//!   request), all as fixed log-linear bucket tables with no
//!   dependencies and p50/p95/p99 in the report.

use std::sync::Mutex;
use std::time::Duration;

/// Log-linear latency histogram (microseconds): each power-of-two
/// octave splits into [`SUB_BUCKETS`] linear sub-buckets, so percentile
/// reads are bounded to ~25 % relative error (vs. ~100 % for plain
/// power-of-two buckets) while the table stays a fixed, tiny `Vec<u64>`
/// — no samples retained, no dependencies.
#[derive(Clone, Debug, Default)]
pub struct Histogram {
    buckets: Vec<u64>,
    count: u64,
    sum_us: u64,
    max_us: u64,
}

/// Linear sub-buckets per power-of-two octave.
const SUB_BUCKETS: u64 = 4;

/// Bucket index for a microsecond value.
fn bucket_index(us: u64) -> usize {
    // Clamp so the sub-bucket arithmetic cannot overflow (2^60 µs is
    // ~36 000 years; nothing real lands there).
    let us = us.clamp(1, 1 << 60);
    let oct = 63 - u64::from(us.leading_zeros());
    let base = 1u64 << oct;
    let sub = ((us - base) * SUB_BUCKETS) >> oct;
    (oct * SUB_BUCKETS + sub) as usize
}

/// Inclusive upper bound (µs) of bucket `idx`.
fn bucket_upper_us(idx: usize) -> u64 {
    let oct = idx as u64 / SUB_BUCKETS;
    let sub = idx as u64 % SUB_BUCKETS;
    let base = 1u64 << oct;
    base + ((sub + 1) * base) / SUB_BUCKETS
}

impl Histogram {
    pub fn record(&mut self, d: Duration) {
        let us = d.as_micros().min(u128::from(u64::MAX)) as u64;
        let idx = bucket_index(us);
        if self.buckets.len() <= idx {
            self.buckets.resize(idx + 1, 0);
        }
        self.buckets[idx] += 1;
        self.count += 1;
        self.sum_us += us;
        self.max_us = self.max_us.max(us);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn mean(&self) -> Duration {
        if self.count == 0 {
            return Duration::ZERO;
        }
        Duration::from_micros(self.sum_us / self.count)
    }

    pub fn max(&self) -> Duration {
        Duration::from_micros(self.max_us)
    }

    /// Upper bound of the bucket containing the p-th percentile
    /// (capped at the observed max).
    pub fn percentile(&self, p: f64) -> Duration {
        if self.count == 0 {
            return Duration::ZERO;
        }
        let target = (((self.count as f64) * p / 100.0).ceil() as u64).max(1);
        let mut seen = 0u64;
        for (i, &c) in self.buckets.iter().enumerate() {
            seen += c;
            if seen >= target {
                return Duration::from_micros(bucket_upper_us(i).min(self.max_us));
            }
        }
        self.max()
    }

    /// The (p50, p95, p99) triple every snapshot consumer wants.
    pub fn percentiles(&self) -> (Duration, Duration, Duration) {
        (self.percentile(50.0), self.percentile(95.0), self.percentile(99.0))
    }
}

/// Aggregate serving metrics.
#[derive(Debug, Default)]
pub struct Metrics {
    inner: Mutex<MetricsInner>,
}

#[derive(Clone, Debug, Default)]
pub struct MetricsInner {
    pub requests: u64,
    /// Of `requests`, how many were cancelled (client dropped the
    /// stream before completion). Cancelled requests keep their token
    /// counts but are excluded from the e2e/tpot histograms (queue
    /// wait is recorded at admission, before cancellation can be
    /// known); the ttft histogram records a request iff its first
    /// token was actually delivered, so a mid-stream cancel keeps its
    /// TTFT.
    pub cancelled: u64,
    pub tokens_generated: u64,
    /// Decode iterations of the continuous-batching step loop.
    pub batches: u64,
    /// Summed step width (active sequences per iteration).
    pub batch_size_sum: u64,
    /// Requests submitted but not yet admitted to a KV slot.
    pub queue_depth: u64,
    pub queue_depth_peak: u64,
    pub queue_latency: Histogram,
    pub e2e_latency: Histogram,
    /// Enqueue → first generated token.
    pub ttft: Histogram,
    /// Mean inter-token latency, one sample per request with ≥ 2 tokens.
    pub tpot: Histogram,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    /// A request entered a variant queue.
    pub fn record_enqueued(&self) {
        let mut m = self.inner.lock().unwrap();
        m.queue_depth += 1;
        m.queue_depth_peak = m.queue_depth_peak.max(m.queue_depth);
    }

    /// A request failed to enqueue after `record_enqueued` (the worker
    /// shut down): undo the gauge.
    pub fn record_enqueue_aborted(&self) {
        let mut m = self.inner.lock().unwrap();
        m.queue_depth = m.queue_depth.saturating_sub(1);
    }

    /// A request left the queue for a KV slot after waiting `queue`.
    pub fn record_admitted(&self, queue: Duration) {
        let mut m = self.inner.lock().unwrap();
        // Saturating: enqueue accounting races admission by design (the
        // gauge is advisory), so never underflow.
        m.queue_depth = m.queue_depth.saturating_sub(1);
        m.queue_latency.record(queue);
    }

    /// One decode iteration advanced `batch_size` sequences.
    pub fn record_batch(&self, batch_size: usize) {
        let mut m = self.inner.lock().unwrap();
        m.batches += 1;
        m.batch_size_sum += batch_size as u64;
    }

    /// A request produced its first token `d` after being enqueued.
    pub fn record_ttft(&self, d: Duration) {
        self.inner.lock().unwrap().ttft.record(d);
    }

    /// A request retired: `tokens` generated, end-to-end latency `e2e`,
    /// and (when it generated ≥ 2 tokens) its mean inter-token latency.
    /// Cancelled requests (client gone before completion) are counted
    /// but kept out of the latency histograms — a truncated request's
    /// "latency" would make the served percentiles look artificially
    /// good.
    pub fn record_request(
        &self,
        tokens: usize,
        e2e: Duration,
        tpot: Option<Duration>,
        cancelled: bool,
    ) {
        let mut m = self.inner.lock().unwrap();
        m.requests += 1;
        m.tokens_generated += tokens as u64;
        if cancelled {
            m.cancelled += 1;
            return;
        }
        m.e2e_latency.record(e2e);
        if let Some(t) = tpot {
            m.tpot.record(t);
        }
    }

    pub fn snapshot(&self) -> MetricsInner {
        self.inner.lock().unwrap().clone()
    }

    pub fn report(&self) -> String {
        let m = self.snapshot();
        let mean_batch = if m.batches > 0 {
            m.batch_size_sum as f64 / m.batches as f64
        } else {
            0.0
        };
        let (q50, q95, q99) = m.queue_latency.percentiles();
        let (e50, e95, e99) = m.e2e_latency.percentiles();
        let (t50, t95, t99) = m.ttft.percentiles();
        let (p50, p95, p99) = m.tpot.percentiles();
        format!(
            "requests={} (cancelled {}) tokens={} steps={} mean_step_width={:.2} \
             queue_depth={} (peak {}) \
             queue(p50={q50:?} p95={q95:?} p99={q99:?}) \
             e2e(p50={e50:?} p95={e95:?} p99={e99:?} max={:?}) \
             ttft(p50={t50:?} p95={t95:?} p99={t99:?}) \
             tpot(p50={p50:?} p95={p95:?} p99={p99:?})",
            m.requests,
            m.cancelled,
            m.tokens_generated,
            m.batches,
            mean_batch,
            m.queue_depth,
            m.queue_depth_peak,
            m.e2e_latency.max(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_percentiles_ordered() {
        let mut h = Histogram::default();
        for us in [10u64, 20, 40, 80, 160, 320, 640, 1280, 2560, 100_000] {
            h.record(Duration::from_micros(us));
        }
        assert_eq!(h.count(), 10);
        assert!(h.percentile(50.0) <= h.percentile(95.0));
        assert!(h.percentile(95.0) <= h.percentile(99.0));
        assert!(h.percentile(99.0) <= h.max());
        assert!(h.mean() > Duration::from_micros(100));
    }

    #[test]
    fn log_linear_buckets_bound_percentile_error() {
        // Uniform 1..=1000 µs: the sub-bucketed table must place p50
        // within 25 % of the true median (plain pow-2 buckets give
        // 512→1024, i.e. up to ~100 % off).
        let mut h = Histogram::default();
        for us in 1..=1000u64 {
            h.record(Duration::from_micros(us));
        }
        let p50 = h.percentile(50.0).as_micros() as f64;
        assert!(
            (400.0..=640.0).contains(&p50),
            "p50 {p50}µs too far from true median 500µs"
        );
        let p99 = h.percentile(99.0).as_micros() as f64;
        assert!((940.0..=1000.0).contains(&p99), "p99 {p99}µs off");
    }

    #[test]
    fn bucket_index_and_upper_are_consistent() {
        for us in [1u64, 2, 3, 5, 9, 100, 1023, 1024, 1025, 1 << 20, u64::MAX] {
            let idx = bucket_index(us);
            assert!(
                bucket_upper_us(idx) >= us.clamp(1, 1 << 60),
                "upper({idx}) < {us}"
            );
            if idx > 0 {
                assert!(bucket_upper_us(idx - 1) <= bucket_upper_us(idx));
            }
        }
        // Monotone: larger values never land in earlier buckets.
        let mut prev = 0usize;
        for us in 1..4096u64 {
            let idx = bucket_index(us);
            assert!(idx >= prev, "bucket order broke at {us}µs");
            prev = idx;
        }
    }

    #[test]
    fn metrics_aggregate() {
        let m = Metrics::new();
        m.record_batch(4);
        m.record_batch(2);
        for _ in 0..6 {
            m.record_enqueued();
        }
        for _ in 0..6 {
            m.record_admitted(Duration::from_micros(50));
            m.record_ttft(Duration::from_micros(300));
            m.record_request(
                5,
                Duration::from_millis(1),
                Some(Duration::from_micros(120)),
                false,
            );
        }
        let s = m.snapshot();
        assert_eq!(s.requests, 6);
        assert_eq!(s.cancelled, 0);
        assert_eq!(s.tokens_generated, 30);
        assert_eq!(s.batches, 2);
        assert_eq!(s.queue_depth, 0);
        assert_eq!(s.queue_depth_peak, 6);
        assert_eq!(s.queue_latency.count(), 6);
        assert_eq!(s.ttft.count(), 6);
        assert_eq!(s.tpot.count(), 6);
        assert!(m.report().contains("requests=6"));
        assert!(m.report().contains("ttft"));
    }

    #[test]
    fn cancelled_requests_counted_but_kept_out_of_latency() {
        let m = Metrics::new();
        m.record_request(3, Duration::from_millis(5), None, true);
        m.record_request(4, Duration::from_millis(1), None, false);
        let s = m.snapshot();
        assert_eq!(s.requests, 2);
        assert_eq!(s.cancelled, 1);
        assert_eq!(s.tokens_generated, 7);
        assert_eq!(s.e2e_latency.count(), 1, "cancelled excluded from e2e");
        assert!(m.report().contains("cancelled 1"));
    }

    #[test]
    fn queue_depth_never_underflows() {
        let m = Metrics::new();
        m.record_admitted(Duration::ZERO);
        assert_eq!(m.snapshot().queue_depth, 0);
    }

    #[test]
    fn empty_metrics_safe() {
        let m = Metrics::new();
        let s = m.snapshot();
        assert_eq!(s.requests, 0);
        assert_eq!(s.e2e_latency.mean(), Duration::ZERO);
        assert_eq!(s.ttft.percentile(99.0), Duration::ZERO);
        assert!(!m.report().is_empty());
    }
}
