//! The coordinator proper: router + per-variant continuous-batching
//! workers.
//!
//! Each registered model variant gets its own request queue, admission
//! policy ([`DynamicBatcher`]), and worker thread running an
//! **iteration-level continuous-batching** step loop over a paged
//! [`KvBlockManager`]:
//!
//! ```text
//!        ┌──────────────────────── step loop ────────────────────────┐
//!        │ 1. admit: drain arrivals (shedding past the pending       │
//!        │    bound), expire dead requests, admit from the head      │
//!        │    while KV blocks allow; preempt the youngest active     │
//!        │    sequence when the head starves too long                │
//!        │ 2. sample: one token per active sequence, streamed to the │
//!        │    client immediately; finished sequences retire, free    │
//!        │    their private blocks, and leave their prompt's prefix  │
//!        │    blocks cached for future requests                      │
//!        │ 3. decode: ONE batched decode step advances every live    │
//!        │    sequence (batch = active sequences through the kernels)│
//!        └───────────────────────────────────────────────────────────┘
//! ```
//!
//! Sequences never wait for each other: a request admitted mid-flight
//! joins the next iteration, and a finished sequence's blocks are
//! reusable one iteration later. Decode math is bit-identical to
//! per-request `TinyLM::generate` for every accepted prompt (see
//! `tests/serving_parity.rs`) — including prefix-cache hits, which read
//! the exact K/V rows the original prefill wrote — so continuous
//! batching and prefix caching are purely throughput/latency changes.
//! The submit boundary rejects out-of-vocab tokens and prompts longer
//! than the context window (both would hurt the whole variant, not just
//! the offending request); empty prompts are accepted but generate zero
//! tokens rather than reproducing `generate`'s quirk of sampling from a
//! zeroed logits row.
//!
//! ## Self-speculative decoding
//!
//! With a draft model configured ([`EngineConfig::spec_draft`] — a
//! second `.bmx` checkpoint or the literal `"self"`) and a speculation
//! depth ([`EngineConfig::spec_gamma`] > 0), step 3 becomes a
//! *speculative* step: per sequence the draft proposes γ tokens ahead
//! of the pending one (through its own private paged KV arena), then
//! ONE batched multi-token [`TinyLM::verify_step`] scores every
//! appended position of every live sequence, the longest draft prefix
//! matching the target's own greedy argmax is accepted, and the
//! rejected tail is rewound with
//! [`KvBlockManager::rollback_append`]. Output is **bit-identical** to
//! non-speculative decoding — the accepted tokens *are* the target's
//! argmaxes, the bonus logits row after the last accepted position is
//! exactly what a plain decode step would have produced, and a draft
//! that proposes garbage costs only wasted verify rows. Requests can
//! opt out per call with `SamplingParams::speculative = false`; such
//! sequences ride the same verify batch with a count of one, which
//! degenerates to the plain batched decode step row for row.
//!
//! ## Failure semantics
//!
//! **Every submitted request terminates** with exactly one of `Done` or
//! `Error` — no [`ResponseHandle`] ever hangs:
//!
//! * The pending queue is bounded ([`EngineConfig::max_pending`]);
//!   arrivals past the bound are shed with [`ServeError::Overloaded`],
//!   and a request whose KV budget exceeds the whole arena is shed with
//!   [`ServeError::TooLarge`] instead of starving the FIFO head forever.
//! * [`SamplingParams::deadline`] / `queue_timeout` expiries terminate
//!   with [`ServeError::DeadlineExceeded`] / [`ServeError::QueueTimeout`],
//!   checked both in the queue and between decode steps.
//! * A panic inside per-request model work (prefill, or the batched
//!   decode step) is caught with `catch_unwind`; the poisoned sequence
//!   is quarantined — blocks freed via its generation-tagged handle,
//!   [`ServeError::Poisoned`] delivered — while every other sequence
//!   replays the step in isolation and continues bit-identically
//!   (`prepare_append` is idempotent until `commit_append`, and KV row
//!   writes land in place, so a replay cannot double-append).
//! * Worker death or queue close sends a terminal event to everything
//!   still queued or in flight, and a closed channel surfaces as
//!   [`ServeError::WorkerGone`] from [`ResponseHandle::recv`].
//!
//! **KV-pressure preemption**: when the queue head starves on blocks
//! for [`EngineConfig::preempt_after`] consecutive steps, the
//! youngest-by-admission active sequence is preempted — blocks freed,
//! prompt + generated tokens re-enqueued as a resume item. On
//! re-admission it re-prefills (usually mostly from the prefix cache)
//! and continues decoding **bit-identically** to an uninterrupted run,
//! because prefill ≡ decode by the serving parity contract. With the
//! default derived arena sizing the head can never starve; preemption
//! becomes reachable when [`EngineConfig::kv_total_blocks`] undersizes
//! the arena.
//!
//! [`KvBlockManager`]: crate::nn::kvcache::KvBlockManager
//! [`EngineConfig::max_pending`]: crate::util::config::EngineConfig::max_pending
//! [`EngineConfig::preempt_after`]: crate::util::config::EngineConfig::preempt_after
//! [`EngineConfig::kv_total_blocks`]: crate::util::config::EngineConfig::kv_total_blocks
//! [`SamplingParams::deadline`]: super::request::SamplingParams::deadline

use super::batcher::{BatcherConfig, DynamicBatcher};
use super::metrics::Metrics;
use super::request::{
    GenerateRequest, GenerateResponse, RequestId, ResponseEvent, ResponseHandle, ResumeState,
    ServeError, WorkItem,
};
use crate::nn::gpt::{argmax, TinyLM};
use crate::nn::kvcache::KvBlockManager;
use crate::obs::trace;
use crate::tensor::Matrix;
use crate::util::arena::ScratchArena;
use crate::util::config::EngineConfig;
use anyhow::{bail, Result};
use std::collections::{HashMap, VecDeque};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Arrivals are drained from the channel in chunks of this size so a
/// burst is shed incrementally against the pending bound instead of
/// being materialized into one unbounded `Vec` first.
const ARRIVAL_CHUNK: usize = 32;

/// Coordinator configuration: batching policy plus the engine-level
/// knobs each worker sizes its KV block manager from
/// ([`EngineConfig::max_seqs`] concurrent sequences,
/// [`EngineConfig::kv_block_size`] positions per block,
/// [`EngineConfig::kv_cache_blocks`] of prefix-cache headroom — or a
/// hard [`EngineConfig::kv_total_blocks`] arena override) and runs its
/// robustness policy from ([`EngineConfig::max_pending`] queue bound,
/// [`EngineConfig::preempt_after`] starvation threshold).
#[derive(Clone, Debug)]
pub struct CoordinatorConfig {
    pub batcher: BatcherConfig,
    pub engine: EngineConfig,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig {
            batcher: BatcherConfig::default(),
            engine: EngineConfig::global().clone(),
        }
    }
}

impl CoordinatorConfig {
    /// Default config with `n` concurrent sequences per worker.
    pub fn with_max_seqs(n: usize) -> Self {
        let mut cfg = Self::default();
        cfg.engine.max_seqs = n;
        cfg
    }
}

/// One variant's route: its request queue plus the model bounds used to
/// validate prompts at the submission boundary — the model asserts on
/// out-of-vocab tokens (a worker panic would kill the variant), and an
/// unbounded prompt would stall every live sequence behind an O(n²)
/// prefill while blowing past the sequence's admitted block budget.
struct Route {
    queue: Sender<WorkItem>,
    vocab: usize,
    max_seq: usize,
}

/// A running coordinator.
pub struct Coordinator {
    routes: HashMap<String, Route>,
    workers: Vec<std::thread::JoinHandle<()>>,
    pub metrics: Arc<Metrics>,
    next_id: AtomicU64,
}

impl Coordinator {
    /// Build a coordinator serving the given (name, model) variants.
    /// A worker-thread spawn failure tears down the workers already
    /// started and reports the error instead of panicking.
    pub fn new(models: Vec<(String, TinyLM)>, cfg: CoordinatorConfig) -> Result<Self> {
        let metrics = Arc::new(Metrics::new());
        let mut routes: HashMap<String, Route> = HashMap::new();
        let mut workers = Vec::new();
        for (name, model) in models {
            let (tx, rx) = channel::<WorkItem>();
            let (vocab, max_seq) = (model.cfg.vocab, model.cfg.max_seq);
            let draft = match load_draft(&cfg.engine, &model) {
                Ok(d) => d,
                Err(e) => {
                    drop(tx);
                    routes.clear();
                    for w in workers {
                        let _ = w.join();
                    }
                    bail!("draft model for variant `{name}`: {e}");
                }
            };
            let m = Arc::clone(&metrics);
            let wcfg = cfg.clone();
            let spawned = std::thread::Builder::new()
                .name(format!("worker-{name}"))
                .spawn(move || worker_loop(model, draft, rx, wcfg, m));
            match spawned {
                Ok(handle) => {
                    workers.push(handle);
                    routes.insert(name.clone(), Route { queue: tx, vocab, max_seq });
                }
                Err(e) => {
                    // Unwind: dropping the routes (and this variant's
                    // `tx`) closes every queue; the spawned workers
                    // drain and exit before we report.
                    drop(tx);
                    routes.clear();
                    for w in workers {
                        let _ = w.join();
                    }
                    bail!("failed to spawn worker thread for variant `{name}`: {e}");
                }
            }
        }
        Ok(Coordinator { routes, workers, metrics, next_id: AtomicU64::new(1) })
    }

    /// Submit a [`GenerateRequest`]; returns the id and a streaming
    /// [`ResponseHandle`] (per-token `Token` events, then `Done` — or a
    /// terminal `Error`).
    pub fn submit_request(
        &self,
        variant: &str,
        req: GenerateRequest,
    ) -> Result<(RequestId, ResponseHandle)> {
        let Some(route) = self.routes.get(variant) else {
            bail!(
                "unknown variant `{variant}` (have: {:?})",
                self.routes.keys().collect::<Vec<_>>()
            );
        };
        // Validate untrusted input here: an out-of-vocab token would
        // panic the variant's worker (caught there, but pointlessly),
        // and a prompt longer than the context window would stall live
        // sequences behind an O(n²) prefill. Capping at max_seq also
        // means a sequence never outgrows the block budget it was
        // admitted with.
        if req.prompt.len() > route.max_seq {
            bail!(
                "prompt of {} tokens exceeds variant `{variant}`'s context window ({})",
                req.prompt.len(),
                route.max_seq
            );
        }
        if let Some(&bad) = req.prompt.iter().find(|&&t| t >= route.vocab) {
            bail!(
                "prompt token {bad} out of vocab (variant `{variant}` has vocab {})",
                route.vocab
            );
        }
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let (tx, rx) = channel();
        trace::serve_point("enqueue", id);
        // Count the enqueue before sending: the worker may admit (and
        // decrement the gauge) the instant the request lands.
        self.metrics.record_enqueued();
        let sent = route.queue.send(WorkItem {
            id,
            req,
            respond_to: tx,
            enqueued_at: Instant::now(),
            resume: None,
        });
        if sent.is_err() {
            self.metrics.record_enqueue_aborted();
            bail!("variant `{variant}` worker has shut down");
        }
        Ok((id, ResponseHandle::new(rx)))
    }

    /// Thin wrapper over [`submit_request`] keeping the original
    /// `(prompt, max_new_tokens)` call shape.
    ///
    /// [`submit_request`]: Coordinator::submit_request
    pub fn submit(
        &self,
        variant: &str,
        prompt: Vec<usize>,
        max_new_tokens: usize,
    ) -> Result<(RequestId, ResponseHandle)> {
        self.submit_request(variant, GenerateRequest::new(prompt, max_new_tokens))
    }

    /// Submit a [`GenerateRequest`] and block for the final summary.
    /// Serving failures surface as the typed [`ServeError`] through the
    /// anyhow chain.
    pub fn generate_request(
        &self,
        variant: &str,
        req: GenerateRequest,
    ) -> Result<GenerateResponse> {
        let (_, handle) = self.submit_request(variant, req)?;
        Ok(handle.recv()?)
    }

    /// Convenience: submit and block for the final summary.
    pub fn generate(
        &self,
        variant: &str,
        prompt: Vec<usize>,
        max_new_tokens: usize,
    ) -> Result<GenerateResponse> {
        self.generate_request(variant, GenerateRequest::new(prompt, max_new_tokens))
    }

    pub fn variants(&self) -> Vec<String> {
        let mut v: Vec<String> = self.routes.keys().cloned().collect();
        v.sort();
        v
    }

    /// Close all queues and join workers.
    pub fn shutdown(mut self) {
        self.routes.clear(); // drop senders → workers drain and exit
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.routes.clear();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// One in-flight sequence: its work item, KV sequence handle, token
/// state, and the pending logits its next token will be sampled from.
struct ActiveSeq {
    item: WorkItem,
    handle: crate::nn::kvcache::SeqHandle,
    /// Prompt + generated tokens.
    tokens: Vec<usize>,
    generated: usize,
    /// Logits (1×vocab) the next token samples from when the sequence
    /// has no row in the shared step matrix: the last prefill position
    /// after (re-)admission, or an isolation-replay result after a
    /// batched-step panic. `None` when the prompt was empty (nothing to
    /// sample from). Consumed by the next sampling step — steady-state
    /// sequences sample straight from the shared step-logits matrix
    /// (one row per live sequence), so the hot loop never copies
    /// logits around.
    logits: Option<Matrix>,
    /// Queue wait (first admission wait + any post-preemption requeue
    /// wait).
    queue_time: Duration,
    /// Most recent admission.
    admitted_at: Instant,
    /// Active compute accumulated in earlier admissions (non-zero only
    /// after a preemption).
    compute_before: Duration,
    /// Set when the first token is sampled (drives TPOT at retire).
    first_token_at: Option<Instant>,
    /// Enqueue → first token, computed once at sampling time; the
    /// `Done` summary reuses exactly the value the ttft histogram saw.
    ttft: Option<Duration>,
    /// Client dropped its receiver: stop decoding, skip `Done`.
    cancelled: bool,
    /// Draft-model KV state for speculative decoding. `None` until the
    /// first speculative round (and reset to `None` by preemption and
    /// panic recovery — the next round re-syncs from the token list).
    draft: Option<DraftSeq>,
}

/// A sequence's private draft-model KV state: its handle into the
/// draft arena plus how many of the sequence's tokens the draft has
/// committed (the draft always trails the pending token).
struct DraftSeq {
    handle: crate::nn::kvcache::SeqHandle,
    len: usize,
}

/// Per-worker speculative-decoding state: the draft model, its private
/// paged KV arena (derived sizing like the target's, so a draft
/// reservation can never starve while active sequences ≤ `max_seqs`),
/// and the reusable per-step buffers that keep the speculative hot
/// path allocation-free in steady state.
struct SpecCtx {
    model: TinyLM,
    dmgr: KvBlockManager,
    gamma: usize,
    /// Flattened verify tokens: per sequence, its pending token
    /// followed by its draft proposals.
    verify_toks: Vec<usize>,
    verify_counts: Vec<usize>,
    verify_handles: Vec<crate::nn::kvcache::SeqHandle>,
    /// Per-sequence `(first verify row, proposed, accepted)`.
    spans: Vec<(usize, usize, usize)>,
    /// 1×vocab scratch for the draft's one-at-a-time proposal decodes.
    draft_logits: Matrix,
}

/// Resolve the speculative draft for a variant: `None` when speculation
/// is off (`spec_gamma == 0` or no `spec_draft`), a clone of the target
/// for `"self"`, otherwise a `.bmx` checkpoint — which must share the
/// target's vocab (acceptance compares token ids) and cover its context
/// window (the draft embeds the same positions).
fn load_draft(engine: &EngineConfig, target: &TinyLM) -> Result<Option<TinyLM>> {
    if engine.spec_gamma == 0 {
        return Ok(None);
    }
    let draft = match engine.spec_draft.as_deref() {
        None => return Ok(None),
        Some("self") => target.clone(),
        Some(path) => TinyLM::load(path)?,
    };
    if draft.cfg.vocab != target.cfg.vocab {
        bail!(
            "draft vocab {} != target vocab {} (speculative verification compares token ids)",
            draft.cfg.vocab,
            target.cfg.vocab
        );
    }
    if draft.cfg.max_seq < target.cfg.max_seq {
        bail!(
            "draft context window {} is shorter than the target's {}",
            draft.cfg.max_seq,
            target.cfg.max_seq
        );
    }
    Ok(Some(draft))
}

/// What one admission attempt did.
enum AdmitOutcome {
    /// Budget reserved, prompt (re-)prefilled: the sequence is live.
    Admitted(ActiveSeq),
    /// The arena cannot reserve the budget *right now*: the item goes
    /// back to the queue head (FIFO) and retries after retirements —
    /// or triggers preemption if it starves too long.
    Retry(WorkItem),
    /// The item terminated here (`TooLarge` shed, or a prefill panic
    /// poisoned it): its terminal event and metrics are already
    /// recorded.
    Rejected,
}

/// Best-effort panic-payload extraction for [`ServeError::Poisoned`].
fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

/// Deliver a terminal [`ResponseEvent::Error`] (ignoring a gone client
/// — the *engine*-side obligation is met either way).
fn fail_item(item: &WorkItem, error: ServeError) {
    let _ = item.respond_to.send(ResponseEvent::Error { id: item.id, error });
}

/// Try to admit one work item: reserve a block budget for the whole
/// (remaining) generation, then prefill the part of the prompt the
/// prefix cache does not already hold. Resume items restore their
/// carried progress instead of starting over.
fn admit(
    model: &TinyLM,
    mgr: &mut KvBlockManager,
    metrics: &Metrics,
    mut item: WorkItem,
) -> AdmitOutcome {
    // Reserve capacity for prompt + the remaining generation up front
    // (clamped to the context window, past which decode stops anyway)
    // so the decode path can never run out of blocks mid-sequence. For
    // a resume item the prompt already contains the generated tokens,
    // so the reservation equals the uninterrupted request's.
    let pre_gen = item.resume.map_or(0, |r| r.generated);
    let remaining = item.req.params.max_new_tokens.saturating_sub(pre_gen);
    let max_total = if item.req.prompt.is_empty() {
        0
    } else {
        (item.req.prompt.len() + remaining).min(model.cfg.max_seq)
    };
    let budget = max_total.div_ceil(mgr.block_size().max(1));
    if budget > mgr.num_blocks() {
        // Can never fit, not even into an idle arena: shed immediately
        // rather than blocking the FIFO head on impossible capacity.
        trace::serve_point("shed", item.id);
        fail_item(
            &item,
            ServeError::TooLarge { budget_blocks: budget, arena_blocks: mgr.num_blocks() },
        );
        metrics.record_shed();
        return AdmitOutcome::Rejected;
    }
    let Some(adm) = mgr.admit(&item.req.prompt, max_total) else {
        return AdmitOutcome::Retry(item);
    };
    let (queue_time, compute_before, first_token_at, ttft) = match item.resume {
        Some(r) => {
            // The requeue wait after the preemption is queue time too;
            // the queue-latency histogram already sampled this request
            // at first admission, so only the gauge moves.
            metrics.record_readmitted();
            (
                r.queue_time + r.preempted_at.elapsed(),
                r.compute_before,
                r.first_token_at,
                r.ttft,
            )
        }
        None => {
            let q = item.enqueued_at.elapsed();
            metrics.record_admitted(q);
            (q, Duration::ZERO, None, None)
        }
    };
    trace::serve_point("admit", item.id);
    let admitted_at = Instant::now();
    // Prefill ONLY the suffix the prefix cache does not cover; the
    // cached span's K/V rows are shared with the request that wrote
    // them, so the math (and every token out) is bit-identical to a
    // cold prefill of the whole prompt. A resume item's "prompt" is
    // prompt + generated: re-prefilling it reconstructs exactly the
    // logits an uninterrupted decode would be holding (prefill ≡
    // decode), which is what makes preemption bit-exact.
    //
    // catch_unwind: a panic in model code must poison only this
    // request, not the variant's worker thread.
    let prefill = catch_unwind(AssertUnwindSafe(|| {
        model.prefill_seq(&item.req.prompt[adm.cached_tokens..], mgr, adm.handle)
    }));
    let logits = match prefill {
        Ok(logits) => logits,
        Err(payload) => {
            // Quarantine: the sequence's blocks hold partially written
            // rows; the generation-tagged free returns them safely.
            mgr.free(adm.handle);
            trace::serve_point("poisoned", item.id);
            fail_item(&item, ServeError::Poisoned(panic_message(&*payload)));
            metrics.record_poisoned();
            return AdmitOutcome::Rejected;
        }
    };
    trace::serve_point("prefill", item.id);
    // The prompt buffer becomes the sequence's token list (nothing
    // reads item.req.prompt after prefill) — no second copy per seq.
    let tokens = std::mem::take(&mut item.req.prompt);
    // Publish the prompt's full blocks into the prefix cache so the
    // NEXT request sharing this prompt prefix skips prefill over it.
    mgr.cache_prefix(adm.handle, &tokens);
    item.resume = None;
    AdmitOutcome::Admitted(ActiveSeq {
        item,
        handle: adm.handle,
        tokens,
        generated: pre_gen,
        logits,
        queue_time,
        admitted_at,
        compute_before,
        first_token_at,
        ttft,
        cancelled: false,
        draft: None,
    })
}

/// Free a sequence's draft-model KV state, if any. A no-op when
/// speculation is off or the sequence never speculated. Must run before
/// every path that retires, preempts, or quarantines a sequence — the
/// draft arena's leak invariant mirrors the target's.
fn release_draft(seq: &mut ActiveSeq, spec: Option<&mut SpecCtx>) {
    if let (Some(ds), Some(sp)) = (seq.draft.take(), spec) {
        sp.dmgr.free(ds.handle);
    }
}

/// Preempt an active sequence for KV pressure: free its blocks, carry
/// its progress into a [`ResumeState`], and re-enqueue it at the back
/// of the pending queue (the starving head admits first on the freed
/// blocks; re-queueing at the front would just thrash).
fn preempt(
    seq: ActiveSeq,
    mgr: &mut KvBlockManager,
    metrics: &Metrics,
    pending: &mut VecDeque<WorkItem>,
) {
    let ActiveSeq {
        mut item,
        handle,
        tokens,
        generated,
        queue_time,
        admitted_at,
        compute_before,
        first_token_at,
        ttft,
        ..
    } = seq;
    mgr.free(handle);
    trace::serve_point("preempt", item.id);
    let now = Instant::now();
    // The re-prefill prompt is everything generated so far; streamed
    // token indices continue from `generated` after resume.
    item.req.prompt = tokens;
    item.resume = Some(ResumeState {
        generated,
        queue_time,
        compute_before: compute_before + now.duration_since(admitted_at),
        first_token_at,
        ttft,
        preempted_at: now,
    });
    metrics.record_preempted();
    pending.push_back(item);
}

/// Retire a sequence: release its handle (private blocks return to the
/// free list; prompt-prefix blocks stay cached for future hits), record
/// metrics, send `Done`; under `BLAST_TRACE=serve` also dump the
/// request's lifecycle timeline.
fn retire(seq: ActiveSeq, mgr: &mut KvBlockManager, metrics: &Metrics) {
    let id = seq.item.id;
    mgr.free(seq.handle);
    trace::serve_point("retire", id);
    let compute_time = seq.compute_before + seq.admitted_at.elapsed();
    let ttft = seq.ttft;
    let tpot = seq.first_token_at.and_then(|t| {
        (seq.generated >= 2).then(|| t.elapsed() / (seq.generated as u32 - 1))
    });
    metrics.record_request(
        seq.generated,
        seq.queue_time + compute_time,
        tpot,
        seq.cancelled,
    );
    if !seq.cancelled {
        let ActiveSeq { item, tokens, generated, queue_time, .. } = seq;
        let _ = item.respond_to.send(ResponseEvent::Done(GenerateResponse {
            id: item.id,
            tokens,
            generated,
            queue_time,
            compute_time,
            ttft,
        }));
        // `item` (and its sender) drops here, closing the client stream.
    }
    // Timeline dump on Done: the format/println cost only exists when
    // the operator asked for it.
    if trace::enabled(trace::TraceMode::Serve) {
        if let Some(line) = trace::format_timeline(id) {
            println!("{line}");
        }
    }
}

/// Worker: the iteration-level continuous-batching step loop described
/// in the module docs. Greedy sampling per sequence matches the
/// per-request decode loop token for token: prefill yields the logits
/// of the last prompt position, each iteration samples one token from
/// the pending logits, and the batched decode step (bit-identical to
/// `decode_step` per row) produces the next logits.
fn worker_loop(
    model: TinyLM,
    draft: Option<TinyLM>,
    rx: Receiver<WorkItem>,
    cfg: CoordinatorConfig,
    metrics: Arc<Metrics>,
) {
    let max_seqs = cfg.engine.max_seqs.max(1);
    let max_pending = cfg.engine.max_pending.max(1);
    let gamma = cfg.engine.spec_gamma;
    // Warm the execution caches before taking traffic: pretune builds
    // every layer's StructPlan (cached on the layer — Monarch/BlockDiag/
    // LowRank models serve through the same plan path as Dense/BLAST),
    // then tunes decode at batch 1 and at full concurrency plus the
    // longest prefill this model accepts, so plan builds, tuning probes,
    // and factor-panel packing all run at model-load time rather than
    // inside the first request. Speculative workers also pretune the
    // verify width (up to γ+1 rows per sequence in one batch).
    if draft.is_some() && gamma > 0 {
        model.pretune(&[1, max_seqs, max_seqs * (gamma + 1), model.cfg.max_seq - 1]);
    } else {
        model.pretune(&[1, max_seqs, model.cfg.max_seq - 1]);
    }
    // Speculation state: the draft keeps its OWN paged arena (derived
    // sizing — never starves) so target preemption/rollback arithmetic
    // stays independent of draft bookkeeping.
    let mut spec: Option<SpecCtx> = match draft {
        Some(dm) if gamma > 0 => {
            dm.pretune(&[1, dm.cfg.max_seq - 1]);
            let dmgr = dm.new_kv_manager_with(
                max_seqs,
                cfg.engine.kv_block_size,
                cfg.engine.kv_cache_blocks,
            );
            let draft_logits = Matrix::zeros(0, dm.cfg.vocab);
            Some(SpecCtx {
                model: dm,
                dmgr,
                gamma,
                verify_toks: Vec::with_capacity(max_seqs * (gamma + 1)),
                verify_counts: Vec::with_capacity(max_seqs),
                verify_handles: Vec::with_capacity(max_seqs),
                spans: Vec::with_capacity(max_seqs),
                draft_logits,
            })
        }
        _ => None,
    };
    // Arena sizing: derived (worst case per sequence + cache headroom,
    // under which admission can always eventually reserve) unless
    // `kv_total_blocks` pins an explicit — possibly undersized, KV
    // pressure is a real deployment state — total.
    let mut mgr = match cfg.engine.kv_total_blocks {
        Some(n) => KvBlockManager::new(
            model.cfg.n_layers,
            n.max(1),
            cfg.engine.kv_block_size.max(1),
            model.cfg.d_model,
        ),
        None => model.new_kv_manager_with(
            max_seqs,
            cfg.engine.kv_block_size,
            cfg.engine.kv_cache_blocks,
        ),
    };
    let mut batcher = DynamicBatcher::new(rx, cfg.batcher);
    let mut active: Vec<ActiveSeq> = Vec::new();
    // Requests pulled off the queue but not yet admitted (waiting for
    // KV blocks). FIFO: the head blocks everything behind it, so a big
    // request cannot be starved by small ones slipping past. Bounded:
    // arrivals past `max_pending` shed at the drain below.
    let mut pending: VecDeque<WorkItem> = VecDeque::new();
    // Steady-state decode scratch: one arena per worker plus reusable
    // step buffers, so an iteration with no admissions or retirements
    // performs zero heap allocations (the prefill on admission is the
    // one allowed allocator — it is not steady state).
    let mut arena = ScratchArena::new();
    let mut step_toks: Vec<usize> = Vec::with_capacity(max_seqs);
    let mut step_handles: Vec<crate::nn::kvcache::SeqHandle> =
        Vec::with_capacity(max_seqs);
    let mut next_active: Vec<ActiveSeq> = Vec::with_capacity(max_seqs);
    // Logits of the previous decode/verify step (valid when
    // `have_logits`): `active[i]` samples from row `step_rows[i]`
    // (retired sequences were filtered out of `active` before the step
    // ran, and admissions only append, so the prefix-index
    // correspondence is stable across iterations). A plain decode step
    // writes one row per sequence — `step_rows` is the identity — while
    // a speculative verify step writes γᵢ+1 rows per sequence and each
    // survivor points at the row after its last accepted position.
    let mut step_logits = Matrix::zeros(0, model.cfg.vocab);
    let mut step_rows: Vec<usize> = Vec::with_capacity(max_seqs);
    let mut have_logits = false;
    // Consecutive iterations the queue head failed to reserve blocks
    // while sequences were active (feeds the preemption trigger).
    let mut starved_steps = 0usize;
    loop {
        // ---- 1a. Arrivals: drain the channel against the bound. ----
        if active.is_empty() && pending.is_empty() {
            // Idle: park until work arrives (None = queue closed).
            let Some(item) = batcher.recv_one() else { break };
            pending.push_back(item);
        }
        // Chunked drain: a burst beyond `max_pending` is shed with
        // `Overloaded` as it is pulled, so neither the pending queue
        // nor any transient batch buffer grows without bound.
        loop {
            let batch = batcher.try_admit(ARRIVAL_CHUNK);
            if batch.is_empty() {
                break;
            }
            for item in batch {
                if pending.len() < max_pending {
                    pending.push_back(item);
                } else {
                    trace::serve_point("shed", item.id);
                    fail_item(&item, ServeError::Overloaded { limit: max_pending });
                    metrics.record_shed();
                }
            }
        }

        // ---- 1b. Expire dead requests while they are still queued. ----
        pending.retain(|item| {
            let waited = item.enqueued_at.elapsed();
            let params = item.req.params;
            let expired = if params.deadline.is_some_and(|d| waited > d) {
                Some(ServeError::DeadlineExceeded)
            } else if item.resume.is_none()
                && params.queue_timeout.is_some_and(|t| waited > t)
            {
                // queue_timeout guards time-to-first-admission only: a
                // preempted sequence already started, so it is exempt
                // (its end-to-end deadline still applies).
                Some(ServeError::QueueTimeout)
            } else {
                None
            };
            match expired {
                Some(error) => {
                    trace::serve_point("expire", item.id);
                    fail_item(item, error);
                    metrics.record_expired_queued();
                    false
                }
                None => true,
            }
        });

        // ---- 1c. Admission: head-of-line FIFO while blocks last. ----
        // `max_batch` caps prefills per iteration; the manager's block
        // budget caps concurrency. When the front item cannot reserve
        // its blocks it waits for retirements rather than letting later
        // requests jump the queue.
        let mut admitted = 0usize;
        let mut head_blocked = false;
        while admitted < cfg.batcher.max_batch.max(1) && active.len() < max_seqs {
            let Some(item) = pending.pop_front() else { break };
            match admit(&model, &mut mgr, &metrics, item) {
                AdmitOutcome::Admitted(seq) => {
                    active.push(seq);
                    admitted += 1;
                }
                AdmitOutcome::Rejected => {}
                AdmitOutcome::Retry(item) => {
                    pending.push_front(item);
                    head_blocked = true;
                    break;
                }
            }
        }

        // ---- 1d. KV-pressure preemption. ----
        // The head starving `preempt_after` consecutive steps while
        // sequences hold blocks means retirements alone are not freeing
        // capacity fast enough: preempt the youngest admission (least
        // compute to redo, and FIFO fairness favors the oldest). The
        // victim is only *marked* here — the sample loop below preempts
        // it in place of sampling, which keeps every other sequence's
        // row index into the shared step-logits matrix intact.
        let mut preempt_idx: Option<usize> = None;
        if head_blocked && !active.is_empty() && cfg.engine.preempt_after > 0 {
            starved_steps += 1;
            if starved_steps >= cfg.engine.preempt_after {
                preempt_idx = active
                    .iter()
                    .enumerate()
                    .max_by_key(|(_, s)| s.admitted_at)
                    .map(|(i, _)| i);
                starved_steps = 0;
            }
        } else {
            starved_steps = 0;
        }

        // ---- 2. Sample one token per sequence; stream + retire. ----
        let prev_live = if have_logits { step_rows.len() } else { 0 };
        step_toks.clear();
        step_handles.clear();
        for (idx, mut seq) in active.drain(..).enumerate() {
            if preempt_idx == Some(idx) {
                release_draft(&mut seq, spec.as_mut());
                preempt(seq, &mut mgr, &metrics, &mut pending);
                continue;
            }
            let params = seq.item.req.params;
            // Between-steps deadline check: a sequence past its
            // deadline stops consuming decode capacity immediately.
            if params.deadline.is_some_and(|d| seq.item.enqueued_at.elapsed() > d) {
                trace::serve_point("expire", seq.item.id);
                release_draft(&mut seq, spec.as_mut());
                mgr.free(seq.handle);
                fail_item(&seq.item, ServeError::DeadlineExceeded);
                metrics.record_expired_active();
                continue;
            }
            let sampled = if seq.generated >= params.max_new_tokens {
                None // max_new_tokens exhausted (or zero).
            } else if idx < prev_live {
                // Continuing sequence: its row of the last decode step.
                Some(argmax(step_logits.row(step_rows[idx])))
            } else {
                // Freshly (re-)admitted or isolation-replayed: its
                // private logits (None = empty prompt, nothing to
                // sample from).
                seq.logits.as_ref().map(|l| argmax(l.row(0)))
            };
            let Some(next) = sampled else {
                release_draft(&mut seq, spec.as_mut());
                retire(seq, &mut mgr, &metrics);
                continue;
            };
            seq.tokens.push(next);
            seq.generated += 1;
            let first = seq.first_token_at.is_none();
            if first {
                let now = Instant::now();
                seq.first_token_at = Some(now);
                seq.ttft = Some(
                    seq.queue_time
                        + seq.compute_before
                        + now.duration_since(seq.admitted_at),
                );
            }
            let event = ResponseEvent::Token {
                id: seq.item.id,
                token: next,
                index: seq.generated - 1,
            };
            // Chaos site: a dropped/failed delivery must look exactly
            // like a vanished client (cancellation path).
            let delivered = !crate::util::failpoint::eval("resp.send")
                && seq.item.respond_to.send(event).is_ok();
            if !delivered {
                // Client went away: free the blocks instead of decoding on.
                seq.cancelled = true;
            } else if first {
                // Record TTFT only once the first token actually
                // reached the client — a request cancelled before
                // delivery must not contribute a latency sample.
                metrics.record_ttft(seq.ttft.expect("set above"));
                trace::serve_point("first_token", seq.item.id);
            }
            let pos = seq.tokens.len() - 1;
            let done = seq.cancelled
                || seq.generated >= params.max_new_tokens
                || pos + 1 >= model.cfg.max_seq
                || params.stop_token == Some(next);
            if done {
                release_draft(&mut seq, spec.as_mut());
                retire(seq, &mut mgr, &metrics);
            } else {
                // The private logits (if any) are spent; from here on
                // the sequence samples from the shared step matrix.
                seq.logits = None;
                step_toks.push(next);
                step_handles.push(seq.handle);
                next_active.push(seq);
            }
        }
        std::mem::swap(&mut active, &mut next_active); // next_active is now empty

        // ---- 3. One batched decode/verify step over every live
        // sequence. ---- Plain workers run ONE batched decode step (one
        // row per sequence); speculative workers run draft proposals
        // plus ONE batched multi-token verify (γᵢ+1 rows per sequence),
        // then stream the accepted tokens. Both paths go through the
        // arena-backed zero-allocation machinery (KV rows land in
        // blocks reserved at admission — never the heap). A panic
        // anywhere in a step is caught and isolated per sequence.
        if step_toks.is_empty() {
            have_logits = false;
        } else if let Some(sp) = spec.as_mut() {
            metrics.record_batch(step_toks.len());
            let step = catch_unwind(AssertUnwindSafe(|| {
                crate::fail_point!("worker.step");
                spec_step(
                    &model,
                    sp,
                    &mut mgr,
                    &mut active,
                    &step_toks,
                    &mut arena,
                    &mut step_logits,
                );
            }));
            match step {
                Ok(()) => {
                    // Stream accepted tokens; survivors' next-sample
                    // rows land in `step_rows`. This runs OUTSIDE the
                    // unwind guard: all model/manager work is done, so
                    // nothing here can panic and partially-streamed
                    // state never needs recovery.
                    spec_emit(&model, sp, &mut mgr, &metrics, &mut active, &mut step_rows);
                    have_logits = true;
                }
                Err(_) => {
                    have_logits = false;
                    recover_step_panic(
                        &model,
                        &mut mgr,
                        &metrics,
                        &mut active,
                        &mut pending,
                        &step_toks,
                        &mut arena,
                        Some(sp),
                    );
                }
            }
        } else {
            metrics.record_batch(step_toks.len());
            let step = catch_unwind(AssertUnwindSafe(|| {
                crate::fail_point!("worker.step");
                model.decode_step_batch_into(
                    &step_toks,
                    &mut mgr,
                    &step_handles,
                    &mut arena,
                    &mut step_logits,
                );
            }));
            match step {
                Ok(()) => {
                    have_logits = true;
                    step_rows.clear();
                    step_rows.extend(0..step_toks.len());
                }
                Err(_) => {
                    have_logits = false;
                    recover_step_panic(
                        &model,
                        &mut mgr,
                        &metrics,
                        &mut active,
                        &mut pending,
                        &step_toks,
                        &mut arena,
                        None,
                    );
                }
            }
        }
    }
    // Queue closed and drained — both sets are normally empty here, but
    // the no-hang guarantee does not rely on "normally": anything still
    // queued or live gets its terminal event before the thread exits.
    for item in pending.drain(..) {
        fail_item(&item, ServeError::WorkerGone);
        metrics.record_enqueue_aborted(); // gauge −1, no outcome counter
    }
    for mut seq in active.drain(..) {
        release_draft(&mut seq, spec.as_mut());
        mgr.free(seq.handle);
        fail_item(&seq.item, ServeError::WorkerGone);
    }
}

/// Bring a sequence's draft KV up to date with everything except its
/// pending token (`tokens[..len-1]`). Fresh sequences — and ones whose
/// draft state was reset by preemption or panic recovery — admit into
/// the draft arena and prefill; steady-state sequences catch up by at
/// most one decode step (the bonus token of a fully-accepted round).
fn ensure_draft(sp: &mut SpecCtx, seq: &mut ActiveSeq, arena: &mut ScratchArena) {
    let want = seq.tokens.len() - 1;
    if seq.draft.is_none() {
        // Reserve the same worst-case budget the target reserved at
        // admission (prompt + remaining generation, clamped to the
        // draft's window): the draft transiently runs up to γ positions
        // past the committed history, which that budget already covers
        // because γ is capped at the window/remaining-token room.
        let prompt_len = seq.tokens.len() - seq.generated;
        let max_total =
            (prompt_len + seq.item.req.params.max_new_tokens).min(sp.model.cfg.max_seq);
        let adm = sp
            .dmgr
            .admit(&seq.tokens[..want], max_total)
            .expect("draft arena uses derived sizing; reservation cannot starve");
        seq.draft = Some(DraftSeq { handle: adm.handle, len: adm.cached_tokens });
        if want > adm.cached_tokens {
            let _ = sp.model.prefill_seq(&seq.tokens[adm.cached_tokens..want], &mut sp.dmgr, adm.handle);
        }
        seq.draft.as_mut().expect("just set").len = want;
        return;
    }
    let ds = seq.draft.as_mut().expect("checked above");
    while ds.len < want {
        let tok = seq.tokens[ds.len];
        sp.model.decode_step_batch_into(
            &[tok],
            &mut sp.dmgr,
            &[ds.handle],
            arena,
            &mut sp.draft_logits,
        );
        ds.len += 1;
    }
}

/// One speculative step: per-sequence draft proposals, ONE batched
/// multi-token verify over every live sequence, greedy acceptance of
/// the longest draft prefix matching the target's own argmax, and
/// rollback of the rejected tails (target via
/// [`KvBlockManager::rollback_append`], draft likewise in its own
/// arena). Fills `sp.spans` with each sequence's
/// `(first row, proposed, accepted)` into `step_logits` for
/// [`spec_emit`]. Deliberately does NOT touch token lists or the client
/// stream: a panic anywhere in here leaves every sequence in a state
/// [`recover_step_panic`] can replay or recompute bit-identically.
fn spec_step(
    model: &TinyLM,
    sp: &mut SpecCtx,
    mgr: &mut KvBlockManager,
    active: &mut [ActiveSeq],
    step_toks: &[usize],
    arena: &mut ScratchArena,
    step_logits: &mut Matrix,
) {
    sp.verify_toks.clear();
    sp.verify_counts.clear();
    sp.verify_handles.clear();
    sp.spans.clear();
    for (i, seq) in active.iter_mut().enumerate() {
        let t = step_toks[i];
        let params = seq.item.req.params;
        let len = seq.tokens.len(); // tokens[len-1] == t, not yet in KV
        let remaining = params.max_new_tokens.saturating_sub(seq.generated);
        // Cap γ so even full acceptance cannot overrun the request's
        // remaining tokens (the bonus token needs one slot too) or the
        // context window (the verify appends γ+1 positions at len-1..),
        // keeping the transient KV length inside the admission budget.
        let g = if params.speculative {
            sp.gamma
                .min(remaining.saturating_sub(1))
                .min(model.cfg.max_seq.saturating_sub(len))
        } else {
            0
        };
        let row0 = sp.verify_toks.len();
        sp.verify_toks.push(t);
        if g > 0 {
            ensure_draft(sp, seq, arena);
            let dh = seq.draft.as_ref().expect("ensure_draft sets it").handle;
            let mut cur = t;
            for _ in 0..g {
                sp.model.decode_step_batch_into(
                    &[cur],
                    &mut sp.dmgr,
                    &[dh],
                    arena,
                    &mut sp.draft_logits,
                );
                seq.draft.as_mut().expect("set above").len += 1;
                cur = argmax(sp.draft_logits.row(0));
                sp.verify_toks.push(cur);
            }
            crate::obs::well_known::spec_tokens_proposed().add(g as u64);
        }
        sp.verify_counts.push(g + 1);
        sp.verify_handles.push(seq.handle);
        sp.spans.push((row0, g, 0));
    }
    // THE verify: one batched multi-token step — logits for every
    // appended position of every sequence, each row bit-identical to
    // what sequential single-token decode would produce there
    // (`tests/spec_decode.rs` holds this to the bit).
    model.verify_step(
        &sp.verify_toks,
        mgr,
        &sp.verify_handles,
        &sp.verify_counts,
        arena,
        step_logits,
    );
    // Greedy acceptance + rewind. The accepted tokens ARE the target's
    // argmaxes, so emitting them is bit-identical to plain decoding.
    for (i, seq) in active.iter_mut().enumerate() {
        let (row0, g, _) = sp.spans[i];
        let mut a = 0usize;
        while a < g && argmax(step_logits.row(row0 + a)) == sp.verify_toks[row0 + a + 1] {
            a += 1;
        }
        mgr.rollback_append(seq.handle, g - a);
        if g > 0 {
            trace::serve_point("spec_verify", seq.item.id);
            crate::obs::well_known::spec_tokens_accepted().add(a as u64);
            // The draft holds history + [t, d₁..d_{γ−1}]; rewind it to
            // the target's new committed length (history + t + d₁..dₐ).
            // On full acceptance it is one token SHORT instead — the
            // next round's catch-up decode supplies dᵧ.
            let keep = seq.tokens.len() + a;
            let ds = seq.draft.as_mut().expect("speculated above");
            if ds.len > keep {
                sp.dmgr.rollback_append(ds.handle, ds.len - keep);
                ds.len = keep;
            }
        }
        sp.spans[i].2 = a;
    }
    let proposed = crate::obs::well_known::spec_tokens_proposed().get();
    if proposed > 0 {
        let accepted = crate::obs::well_known::spec_tokens_accepted().get();
        crate::obs::well_known::spec_acceptance_rate().set(accepted as f64 / proposed as f64);
    }
}

/// Stream the tokens a speculative step accepted. Every accepted draft
/// token goes through exactly the per-token protocol of the sampling
/// phase — stream, cancel-on-failed-send, stop-token / max-new-tokens /
/// context-window termination — so a client cannot observe whether a
/// token came from speculation or plain decode. Survivors record the
/// verify row after their last accepted position in `step_rows` (the
/// target's "bonus" distribution, exactly what a plain decode step
/// would have produced) for the next sampling phase.
fn spec_emit(
    model: &TinyLM,
    sp: &mut SpecCtx,
    mgr: &mut KvBlockManager,
    metrics: &Metrics,
    active: &mut Vec<ActiveSeq>,
    step_rows: &mut Vec<usize>,
) {
    step_rows.clear();
    let drained: Vec<ActiveSeq> = std::mem::take(active);
    for (i, mut seq) in drained.into_iter().enumerate() {
        let (row0, _, a) = sp.spans[i];
        let params = seq.item.req.params;
        let mut dead = false;
        for j in 0..a {
            let tok = sp.verify_toks[row0 + 1 + j];
            seq.tokens.push(tok);
            seq.generated += 1;
            let event = ResponseEvent::Token {
                id: seq.item.id,
                token: tok,
                index: seq.generated - 1,
            };
            // Same chaos site as the sampling phase: a failed delivery
            // is indistinguishable from a vanished client.
            let delivered = !crate::util::failpoint::eval("resp.send")
                && seq.item.respond_to.send(event).is_ok();
            if !delivered {
                seq.cancelled = true;
            }
            let pos = seq.tokens.len() - 1;
            let done = seq.cancelled
                || seq.generated >= params.max_new_tokens
                || pos + 1 >= model.cfg.max_seq
                || params.stop_token == Some(tok);
            if done {
                dead = true;
                break;
            }
        }
        if dead {
            if let Some(ds) = seq.draft.take() {
                sp.dmgr.free(ds.handle);
            }
            retire(seq, mgr, metrics);
        } else {
            step_rows.push(row0 + a);
            active.push(seq);
        }
    }
}

/// The batched step aborted part-way: replay each sequence alone to
/// find the poisoned one(s). The replay is bit-identical because
/// `prepare_append` only tops blocks up to the same need and KV row
/// writes overwrite in place — nothing the aborted batch did can
/// double-apply. Survivors keep their logits privately (like a fresh
/// prefill row); a sequence whose multi-token append already committed
/// is recompute-resumed through the preemption path instead (its KV
/// state is ahead of its token list and its logits are lost — the
/// re-prefill is bit-exact). Draft state is unknown after a panic
/// anywhere in a speculative step, so it is reset wholesale; the next
/// speculative round re-syncs from the token list.
#[allow(clippy::too_many_arguments)]
fn recover_step_panic(
    model: &TinyLM,
    mgr: &mut KvBlockManager,
    metrics: &Metrics,
    active: &mut Vec<ActiveSeq>,
    pending: &mut VecDeque<WorkItem>,
    step_toks: &[usize],
    arena: &mut ScratchArena,
    mut spec: Option<&mut SpecCtx>,
) {
    let failed: Vec<ActiveSeq> = std::mem::take(active);
    for (i, mut seq) in failed.into_iter().enumerate() {
        release_draft(&mut seq, spec.as_deref_mut());
        if mgr.seq_len(seq.handle) >= seq.tokens.len() {
            // This sequence's append already committed in the aborted
            // batch (single-token, or a verify that never rolled back):
            // a replay would append twice.
            preempt(seq, mgr, metrics, pending);
            continue;
        }
        let tok = step_toks[i];
        let h = seq.handle;
        let mut single = Matrix::zeros(0, model.cfg.vocab);
        let replay = catch_unwind(AssertUnwindSafe(|| {
            model.decode_step_batch_into(&[tok], mgr, &[h], arena, &mut single);
        }));
        match replay {
            Ok(()) => {
                seq.logits = Some(single);
                active.push(seq);
            }
            Err(payload) => {
                // Reproducibly poisoned: quarantine.
                trace::serve_point("poisoned", seq.item.id);
                mgr.free(seq.handle);
                fail_item(&seq.item, ServeError::Poisoned(panic_message(&*payload)));
                metrics.record_poisoned();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::attention::StructureKind;
    use crate::nn::gpt::LmConfig;
    use crate::tensor::Rng;

    fn tiny_model(seed: u64, s: StructureKind) -> TinyLM {
        let mut rng = Rng::new(seed);
        TinyLM::new(LmConfig::tiny(s), &mut rng)
    }

    /// Deterministic test config: fixed geometry regardless of BLAST_*
    /// env in the test environment.
    fn test_cfg(max_seqs: usize) -> CoordinatorConfig {
        let engine = EngineConfig { max_seqs, ..EngineConfig::default() };
        CoordinatorConfig { batcher: BatcherConfig::default(), engine }
    }

    #[test]
    fn serves_requests_and_matches_direct_generation() {
        let model = tiny_model(900, StructureKind::Blast { b: 2, r: 4 });
        let direct = model.generate(&[1, 2, 3], 5);
        let coord = Coordinator::new(
            vec![("blast".into(), model)],
            CoordinatorConfig::default(),
        )
        .unwrap();
        let resp = coord.generate("blast", vec![1, 2, 3], 5).unwrap();
        assert_eq!(resp.tokens, direct);
        assert_eq!(resp.generated, 5);
        assert!(resp.ttft.is_some());
        coord.shutdown();
    }

    #[test]
    fn routes_by_variant() {
        let m1 = tiny_model(901, StructureKind::Dense);
        let m2 = tiny_model(902, StructureKind::Blast { b: 2, r: 4 });
        let out1 = m1.generate(&[5, 6], 4);
        let out2 = m2.generate(&[5, 6], 4);
        let coord = Coordinator::new(
            vec![("dense".into(), m1), ("blast".into(), m2)],
            CoordinatorConfig::default(),
        )
        .unwrap();
        assert_eq!(coord.generate("dense", vec![5, 6], 4).unwrap().tokens, out1);
        assert_eq!(coord.generate("blast", vec![5, 6], 4).unwrap().tokens, out2);
        assert!(coord.generate("nope", vec![1], 1).is_err());
        coord.shutdown();
    }

    #[test]
    fn concurrent_requests_all_answered() {
        let model = tiny_model(903, StructureKind::Dense);
        let coord = Arc::new(
            Coordinator::new(vec![("m".into(), model)], CoordinatorConfig::default())
                .unwrap(),
        );
        let mut handles = Vec::new();
        for i in 0..16usize {
            let c = Arc::clone(&coord);
            handles.push(std::thread::spawn(move || {
                let resp = c.generate("m", vec![i % 8, (i * 3) % 8], 3).unwrap();
                (i, resp)
            }));
        }
        let mut seen = std::collections::HashSet::new();
        for h in handles {
            let (i, resp) = h.join().unwrap();
            assert_eq!(resp.tokens.len(), 2 + resp.generated);
            assert!(seen.insert(i));
        }
        assert_eq!(seen.len(), 16);
        let snap = coord.metrics.snapshot();
        assert_eq!(snap.requests, 16);
        assert!(snap.batches >= 1);
        assert_eq!(snap.queue_depth, 0, "all admitted");
    }

    #[test]
    fn seq_churn_more_requests_than_capacity() {
        // 2 concurrent sequences, 10 concurrent requests: admission
        // must recycle KV blocks mid-flight without corrupting any
        // sequence (prefix-cache hits between the shared prompts are
        // exercised too — same prompts recur across requests).
        let model = tiny_model(905, StructureKind::Blast { b: 2, r: 4 });
        let expectations: Vec<(Vec<usize>, Vec<usize>)> = (0..10usize)
            .map(|i| {
                let prompt: Vec<usize> = vec![1 + i % 8, (2 * i) % 8 + 1];
                (prompt.clone(), model.generate(&prompt, 4 + i % 5))
            })
            .collect();
        let coord = Arc::new(
            Coordinator::new(vec![("m".into(), model)], test_cfg(2)).unwrap(),
        );
        let mut joins = Vec::new();
        for (i, (prompt, expected)) in expectations.into_iter().enumerate() {
            let c = Arc::clone(&coord);
            joins.push(std::thread::spawn(move || {
                let resp = c.generate("m", prompt, 4 + i % 5).unwrap();
                assert_eq!(resp.tokens, expected, "request {i}");
            }));
        }
        for j in joins {
            j.join().unwrap();
        }
        let snap = coord.metrics.snapshot();
        assert_eq!(snap.requests, 10);
        // With only 2 sequences, steps can never be wider than 2.
        assert!(snap.batch_size_sum <= snap.batches * 2);
    }

    #[test]
    fn stop_token_ends_generation_early_inclusive() {
        let model = tiny_model(910, StructureKind::Dense);
        let prompt = vec![2usize, 5];
        let direct = model.generate(&prompt, 8);
        // Pick the third generated token as the stop token; the served
        // stream must stop at its FIRST occurrence, still emitting it.
        let stop = direct[prompt.len() + 2];
        let first_hit = direct[prompt.len()..]
            .iter()
            .position(|&t| t == stop)
            .expect("stop token is generated");
        let expected: Vec<usize> = direct[..prompt.len() + first_hit + 1].to_vec();
        let coord =
            Coordinator::new(vec![("m".into(), model)], CoordinatorConfig::default())
                .unwrap();
        let req = GenerateRequest::builder(prompt)
            .max_tokens(8)
            .stop_token(stop)
            .build();
        let resp = coord.generate_request("m", req).unwrap();
        assert_eq!(resp.tokens, expected);
        assert_eq!(resp.generated, first_hit + 1);
        coord.shutdown();
    }

    #[test]
    fn streaming_tokens_precede_done() {
        let model = tiny_model(906, StructureKind::Dense);
        let direct = model.generate(&[2, 4], 6);
        let coord =
            Coordinator::new(vec![("m".into(), model)], CoordinatorConfig::default())
                .unwrap();
        let (id, handle) = coord.submit("m", vec![2, 4], 6).unwrap();
        let mut streamed = Vec::new();
        let mut summary = None;
        for ev in handle.events() {
            match ev {
                ResponseEvent::Token { id: eid, token, index } => {
                    assert_eq!(eid, id);
                    assert_eq!(index, streamed.len());
                    streamed.push(token);
                }
                ResponseEvent::Done(resp) => summary = Some(resp),
                ResponseEvent::Error { error, .. } => {
                    panic!("healthy request must not error: {error}")
                }
            }
        }
        let summary = summary.expect("stream must end with Done");
        assert_eq!(summary.tokens, direct);
        assert_eq!(&summary.tokens[2..], &streamed[..]);
        coord.shutdown();
    }

    #[test]
    fn dropped_client_does_not_wedge_the_worker() {
        let model = tiny_model(907, StructureKind::Dense);
        let coord =
            Coordinator::new(vec![("m".into(), model)], CoordinatorConfig::default())
                .unwrap();
        {
            let (_, handle) = coord.submit("m", vec![1, 2, 3], 50).unwrap();
            drop(handle); // client gives up immediately
        }
        // The worker must cancel the orphan and keep serving.
        let resp = coord.generate("m", vec![3, 2], 3).unwrap();
        assert_eq!(resp.generated, 3);
        coord.shutdown();
    }

    #[test]
    fn metrics_populated() {
        let model = tiny_model(904, StructureKind::Dense);
        let coord =
            Coordinator::new(vec![("m".into(), model)], CoordinatorConfig::default())
                .unwrap();
        coord.generate("m", vec![1, 2], 3).unwrap();
        let snap = coord.metrics.snapshot();
        assert_eq!(snap.requests, 1);
        assert_eq!(snap.tokens_generated, 3);
        assert_eq!(snap.e2e_latency.count(), 1);
        assert_eq!(snap.ttft.count(), 1);
        assert_eq!(snap.tpot.count(), 1, "3 tokens → one TPOT sample");
        assert_eq!(snap.queue_latency.count(), 1);
        coord.shutdown();
    }

    #[test]
    fn out_of_vocab_prompt_rejected_at_submit() {
        let model = tiny_model(909, StructureKind::Dense);
        let vocab = model.cfg.vocab;
        let coord =
            Coordinator::new(vec![("m".into(), model)], CoordinatorConfig::default())
                .unwrap();
        // Rejected at the boundary, not panicking the worker…
        let err = coord.generate("m", vec![1, vocab, 2], 3).unwrap_err();
        assert!(format!("{err}").contains("out of vocab"), "{err}");
        // …which therefore keeps serving valid requests.
        let resp = coord.generate("m", vec![1, 2], 3).unwrap();
        assert_eq!(resp.generated, 3);
        coord.shutdown();
    }

    #[test]
    fn zero_new_tokens_and_empty_prompt() {
        let model = tiny_model(908, StructureKind::Dense);
        let coord =
            Coordinator::new(vec![("m".into(), model)], CoordinatorConfig::default())
                .unwrap();
        let resp = coord.generate("m", vec![4, 5, 6], 0).unwrap();
        assert_eq!(resp.tokens, vec![4, 5, 6]);
        assert_eq!(resp.generated, 0);
        assert!(resp.ttft.is_none());
        // Empty prompts generate nothing (deliberate divergence from
        // `TinyLM::generate`, which samples from a zeroed logits row).
        let resp = coord.generate("m", vec![], 5).unwrap();
        assert!(resp.tokens.is_empty());
        assert_eq!(resp.generated, 0);
        coord.shutdown();
    }

    #[test]
    fn oversized_request_is_shed_not_head_of_line_blocking() {
        let model = tiny_model(912, StructureKind::Dense);
        let mut cfg = test_cfg(2);
        cfg.engine.kv_block_size = 4;
        cfg.engine.kv_total_blocks = Some(3); // 12 positions, ever
        let coord = Coordinator::new(vec![("m".into(), model)], cfg).unwrap();
        // Budget ceil((3 + 20 capped at max_seq)/4) > 3 blocks: can
        // never fit — must shed with TooLarge, not block the queue.
        let (_, h) = coord.submit("m", vec![1, 2, 3], 20).unwrap();
        match h.recv() {
            Err(ServeError::TooLarge { budget_blocks, arena_blocks }) => {
                assert!(budget_blocks > arena_blocks);
                assert_eq!(arena_blocks, 3);
            }
            other => panic!("expected TooLarge, got {other:?}"),
        }
        // A request that fits the small arena still serves, proving the
        // oversized one neither wedged nor leaked anything.
        let resp = coord.generate("m", vec![1, 2, 3], 2).unwrap();
        assert_eq!(resp.generated, 2);
        let snap = coord.metrics.snapshot();
        assert_eq!(snap.shed, 1);
        assert_eq!(snap.queue_depth, 0);
        coord.shutdown();
    }

    /// Speculative config helper: draft with a clone of the target.
    fn spec_cfg(max_seqs: usize, gamma: usize) -> CoordinatorConfig {
        let mut cfg = test_cfg(max_seqs);
        cfg.engine.spec_gamma = gamma;
        cfg.engine.spec_draft = Some("self".into());
        cfg
    }

    #[test]
    fn speculative_self_draft_matches_direct_generation() {
        let model = tiny_model(920, StructureKind::Blast { b: 2, r: 4 });
        let direct = model.generate(&[1, 2, 3], 7);
        let coord = Coordinator::new(vec![("m".into(), model)], spec_cfg(4, 3)).unwrap();
        let resp = coord.generate("m", vec![1, 2, 3], 7).unwrap();
        assert_eq!(resp.tokens, direct, "speculative output must be bit-identical");
        assert_eq!(resp.generated, 7);
        coord.shutdown();
    }

    #[test]
    fn speculative_stop_token_and_opt_out_requests_mix() {
        let model = tiny_model(921, StructureKind::Dense);
        let prompt = vec![2usize, 5];
        let direct = model.generate(&prompt, 8);
        let stop = direct[prompt.len() + 2];
        let first_hit = direct[prompt.len()..]
            .iter()
            .position(|&t| t == stop)
            .expect("stop token is generated");
        let expected: Vec<usize> = direct[..prompt.len() + first_hit + 1].to_vec();
        let coord = Coordinator::new(vec![("m".into(), model)], spec_cfg(4, 4)).unwrap();
        // Stop token honored at its FIRST occurrence even when it
        // arrives inside a burst of accepted draft tokens.
        let req = GenerateRequest::builder(prompt.clone())
            .max_tokens(8)
            .stop_token(stop)
            .build();
        let resp = coord.generate_request("m", req).unwrap();
        assert_eq!(resp.tokens, expected);
        assert_eq!(resp.generated, first_hit + 1);
        // A request that opts out of speculation on the same worker
        // (count-1 verify rows) still matches exactly.
        let req = GenerateRequest::builder(prompt).max_tokens(8).speculative(false).build();
        let resp = coord.generate_request("m", req).unwrap();
        assert_eq!(resp.tokens, direct);
        coord.shutdown();
    }

    #[test]
    fn gamma_without_draft_is_plain_decoding() {
        let model = tiny_model(922, StructureKind::Dense);
        let direct = model.generate(&[4, 1], 5);
        let mut cfg = test_cfg(2);
        cfg.engine.spec_gamma = 3; // no spec_draft → speculation off
        let coord = Coordinator::new(vec![("m".into(), model)], cfg).unwrap();
        assert_eq!(coord.generate("m", vec![4, 1], 5).unwrap().tokens, direct);
        coord.shutdown();
    }

    #[test]
    fn zero_deadline_and_zero_queue_timeout_expire_before_admission() {
        let model = tiny_model(913, StructureKind::Dense);
        let coord = Coordinator::new(vec![("m".into(), model)], test_cfg(2)).unwrap();
        let req = GenerateRequest::builder(vec![1, 2])
            .max_tokens(4)
            .deadline(Duration::ZERO)
            .build();
        let (_, h) = coord.submit_request("m", req).unwrap();
        assert!(matches!(h.recv(), Err(ServeError::DeadlineExceeded)));
        let req = GenerateRequest::builder(vec![1, 2])
            .max_tokens(4)
            .queue_timeout(Duration::ZERO)
            .build();
        let (_, h) = coord.submit_request("m", req).unwrap();
        assert!(matches!(h.recv(), Err(ServeError::QueueTimeout)));
        // The engine stays healthy and the gauge is balanced.
        let resp = coord.generate("m", vec![1, 2], 3).unwrap();
        assert_eq!(resp.generated, 3);
        let snap = coord.metrics.snapshot();
        assert_eq!(snap.expired, 2);
        assert_eq!(snap.requests, 1, "expired requests are not 'served'");
        assert_eq!(snap.queue_depth, 0);
        coord.shutdown();
    }
}
