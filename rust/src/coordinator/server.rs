//! The coordinator proper: router + per-variant worker threads.
//!
//! Each registered model variant gets its own request queue, dynamic
//! batcher, and worker thread running the decode loop over the Rust
//! native `TinyLM` (KV-cached, one cache slot per in-flight request).
//! The router dispatches by variant name and returns a handle clients
//! block on.

use super::batcher::{BatcherConfig, DynamicBatcher};
use super::metrics::Metrics;
use super::request::{GenerateRequest, GenerateResponse, RequestId};
use crate::nn::gpt::{argmax, TinyLM};
use anyhow::{bail, Result};
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::time::Instant;

/// Coordinator configuration.
#[derive(Clone, Debug, Default)]
pub struct CoordinatorConfig {
    pub batcher: BatcherConfig,
}

/// A running coordinator.
pub struct Coordinator {
    routes: HashMap<String, Sender<GenerateRequest>>,
    workers: Vec<std::thread::JoinHandle<()>>,
    pub metrics: Arc<Metrics>,
    next_id: AtomicU64,
}

impl Coordinator {
    /// Build a coordinator serving the given (name, model) variants.
    pub fn new(models: Vec<(String, TinyLM)>, cfg: CoordinatorConfig) -> Self {
        let metrics = Arc::new(Metrics::new());
        let mut routes = HashMap::new();
        let mut workers = Vec::new();
        for (name, model) in models {
            let (tx, rx) = channel::<GenerateRequest>();
            routes.insert(name.clone(), tx);
            let m = Arc::clone(&metrics);
            let batcher_cfg = cfg.batcher;
            workers.push(
                std::thread::Builder::new()
                    .name(format!("worker-{name}"))
                    .spawn(move || worker_loop(model, rx, batcher_cfg, m))
                    .expect("spawn worker"),
            );
        }
        Coordinator { routes, workers, metrics, next_id: AtomicU64::new(1) }
    }

    /// Submit a generation request; returns (id, receiver).
    pub fn submit(
        &self,
        variant: &str,
        prompt: Vec<usize>,
        max_new_tokens: usize,
    ) -> Result<(RequestId, Receiver<GenerateResponse>)> {
        let Some(route) = self.routes.get(variant) else {
            bail!(
                "unknown variant `{variant}` (have: {:?})",
                self.routes.keys().collect::<Vec<_>>()
            );
        };
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let (tx, rx) = channel();
        route
            .send(GenerateRequest {
                id,
                variant: variant.to_string(),
                prompt,
                max_new_tokens,
                respond_to: tx,
                enqueued_at: Instant::now(),
            })
            .map_err(|_| anyhow::anyhow!("variant `{variant}` worker has shut down"))?;
        Ok((id, rx))
    }

    /// Convenience: submit and block for the response.
    pub fn generate(
        &self,
        variant: &str,
        prompt: Vec<usize>,
        max_new_tokens: usize,
    ) -> Result<GenerateResponse> {
        let (_, rx) = self.submit(variant, prompt, max_new_tokens)?;
        rx.recv().map_err(|_| anyhow::anyhow!("worker dropped the response"))
    }

    pub fn variants(&self) -> Vec<String> {
        let mut v: Vec<String> = self.routes.keys().cloned().collect();
        v.sort();
        v
    }

    /// Close all queues and join workers.
    pub fn shutdown(mut self) {
        self.routes.clear(); // drop senders → workers drain and exit
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.routes.clear();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Worker: batch requests, run the decode loop per request with its own
/// KV slot, respond on each request's channel.
fn worker_loop(
    model: TinyLM,
    rx: Receiver<GenerateRequest>,
    batcher_cfg: BatcherConfig,
    metrics: Arc<Metrics>,
) {
    // Warm the kernel autotuner before taking traffic, so tuning probes
    // run at model-load time rather than inside the first request. The
    // prefill batch dimension is the *prompt length*, so cover the
    // decode shape (batch 1), the micro-batch bucket, and the longest
    // prompt this model accepts (which warms the large-batch buckets).
    model.pretune(&[1, batcher_cfg.max_batch.max(2), model.cfg.max_seq - 1]);
    let mut batcher = DynamicBatcher::new(rx, batcher_cfg);
    while let Some(batch) = batcher.next_batch() {
        metrics.record_batch(batch.len());
        // Serve each request in the batch: one batched prefill over the
        // prompt (Algorithm-1 products batched across positions through
        // the kernel engine), then the token-by-token decode loop. KV
        // slots are independent; the batch amortizes queue/dispatch
        // overhead (the structured matmuls inside the model are the
        // Table-4 object of study).
        for req in batch {
            let queue_time = req.enqueued_at.elapsed();
            let t0 = Instant::now();
            let mut kv = model.new_kv_cache();
            let mut tokens = req.prompt.clone();
            // Prefill positions 0..max_seq-1 of the prompt in one pass
            // (the same positions the per-token loop used to ingest).
            let prefill_len = req.prompt.len().min(model.cfg.max_seq - 1);
            let mut logits = model.prefill(&req.prompt[..prefill_len], &mut kv);
            let mut generated = 0usize;
            for _ in 0..req.max_new_tokens {
                let Some(l) = &logits else { break };
                let next = argmax(l.row(0));
                tokens.push(next);
                generated += 1;
                let pos = tokens.len() - 1;
                if pos + 1 >= model.cfg.max_seq {
                    break;
                }
                logits = Some(model.decode_step(next, pos, &mut kv));
            }
            let compute_time = t0.elapsed();
            metrics.record_request(generated, queue_time, queue_time + compute_time);
            let _ = req.respond_to.send(GenerateResponse {
                id: req.id,
                tokens,
                generated,
                queue_time,
                compute_time,
            });
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::attention::StructureKind;
    use crate::nn::gpt::LmConfig;
    use crate::tensor::Rng;

    fn tiny_model(seed: u64, s: StructureKind) -> TinyLM {
        let mut rng = Rng::new(seed);
        TinyLM::new(LmConfig::tiny(s), &mut rng)
    }

    #[test]
    fn serves_requests_and_matches_direct_generation() {
        let model = tiny_model(900, StructureKind::Blast { b: 2, r: 4 });
        let direct = model.generate(&[1, 2, 3], 5);
        let coord = Coordinator::new(
            vec![("blast".into(), model)],
            CoordinatorConfig::default(),
        );
        let resp = coord.generate("blast", vec![1, 2, 3], 5).unwrap();
        assert_eq!(resp.tokens, direct);
        assert_eq!(resp.generated, 5);
        coord.shutdown();
    }

    #[test]
    fn routes_by_variant() {
        let m1 = tiny_model(901, StructureKind::Dense);
        let m2 = tiny_model(902, StructureKind::Blast { b: 2, r: 4 });
        let out1 = m1.generate(&[5, 6], 4);
        let out2 = m2.generate(&[5, 6], 4);
        let coord = Coordinator::new(
            vec![("dense".into(), m1), ("blast".into(), m2)],
            CoordinatorConfig::default(),
        );
        assert_eq!(coord.generate("dense", vec![5, 6], 4).unwrap().tokens, out1);
        assert_eq!(coord.generate("blast", vec![5, 6], 4).unwrap().tokens, out2);
        assert!(coord.generate("nope", vec![1], 1).is_err());
        coord.shutdown();
    }

    #[test]
    fn concurrent_requests_all_answered() {
        let model = tiny_model(903, StructureKind::Dense);
        let coord = Arc::new(Coordinator::new(
            vec![("m".into(), model)],
            CoordinatorConfig::default(),
        ));
        let mut handles = Vec::new();
        for i in 0..16usize {
            let c = Arc::clone(&coord);
            handles.push(std::thread::spawn(move || {
                let resp = c.generate("m", vec![i % 8, (i * 3) % 8], 3).unwrap();
                (i, resp)
            }));
        }
        let mut seen = std::collections::HashSet::new();
        for h in handles {
            let (i, resp) = h.join().unwrap();
            assert_eq!(resp.tokens.len(), 2 + resp.generated);
            assert!(seen.insert(i));
        }
        assert_eq!(seen.len(), 16);
        let snap = coord.metrics.snapshot();
        assert_eq!(snap.requests, 16);
        assert!(snap.batches >= 1);
    }

    #[test]
    fn metrics_populated() {
        let model = tiny_model(904, StructureKind::Dense);
        let coord = Coordinator::new(vec![("m".into(), model)], CoordinatorConfig::default());
        coord.generate("m", vec![1, 2], 3).unwrap();
        let snap = coord.metrics.snapshot();
        assert_eq!(snap.requests, 1);
        assert_eq!(snap.tokens_generated, 3);
        assert!(snap.e2e_latency.count() == 1);
        coord.shutdown();
    }
}
