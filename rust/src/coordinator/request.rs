//! Request/response types crossing the coordinator boundary.
//!
//! A [`GenerateRequest`] is plain data: the prompt plus its
//! [`SamplingParams`]. Build one directly, via [`GenerateRequest::new`],
//! or through the [`builder`](GenerateRequest::builder); the coordinator
//! wraps it into an internal [`WorkItem`] (id, response channel, queue
//! timestamp) at submission time, so request *content* and transport
//! *bookkeeping* stay separate types.
//!
//! Responses stream: the worker emits one [`ResponseEvent::Token`] per
//! generated token as soon as it is sampled (continuous batching
//! produces tokens incrementally, so clients can render them live) and
//! a final [`ResponseEvent::Done`] summary. [`ResponseHandle`] wraps the
//! event channel; its [`recv`](ResponseHandle::recv) drains to the
//! summary, so blocking callers keep the pre-streaming call shape.
//!
//! **Every stream terminates.** A request that cannot complete ends
//! with [`ResponseEvent::Error`] carrying a [`ServeError`] (shed,
//! expired, poisoned by a panic, ...), and a worker dying outright
//! closes the channel, which [`recv`](ResponseHandle::recv) maps to
//! [`ServeError::WorkerGone`] — `recv` can never block forever on a
//! request the engine has abandoned.

use std::sync::mpsc::{Receiver, RecvError, RecvTimeoutError, Sender};
use std::time::{Duration, Instant};

/// Monotonically increasing request identifier.
pub type RequestId = u64;

/// Why a request terminated without a completed generation. Carried by
/// [`ResponseEvent::Error`]; also what [`ResponseHandle::recv`] returns
/// when the worker vanished without sending a terminal event.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ServeError {
    /// A panic inside this request's model work (prefill or its slice
    /// of a decode step) was caught; the sequence was quarantined and
    /// its blocks freed. Carries the panic message.
    Poisoned(String),
    /// The request was shed at admission: the pending queue was at its
    /// `max_pending` bound.
    Overloaded {
        /// The bound that was hit.
        limit: usize,
    },
    /// `SamplingParams::queue_timeout` elapsed before first admission.
    QueueTimeout,
    /// `SamplingParams::deadline` elapsed (queued or mid-decode).
    DeadlineExceeded,
    /// The request can never fit: its KV budget exceeds the whole
    /// block arena.
    TooLarge {
        /// Blocks the request would need reserved.
        budget_blocks: usize,
        /// Blocks the arena has in total.
        arena_blocks: usize,
    },
    /// The worker's event channel disconnected with no terminal event.
    WorkerGone,
    /// Client-side receive deadline elapsed
    /// ([`ResponseHandle::recv_deadline`]); the request may still be
    /// running.
    RecvTimeout,
}

impl std::fmt::Display for ServeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServeError::Poisoned(msg) => {
                write!(f, "request poisoned by a worker panic: {msg}")
            }
            ServeError::Overloaded { limit } => {
                write!(f, "shed at admission: pending queue at its bound ({limit})")
            }
            ServeError::QueueTimeout => write!(f, "queue timeout elapsed before admission"),
            ServeError::DeadlineExceeded => write!(f, "request deadline exceeded"),
            ServeError::TooLarge { budget_blocks, arena_blocks } => write!(
                f,
                "request KV budget ({budget_blocks} blocks) exceeds the arena ({arena_blocks})"
            ),
            ServeError::WorkerGone => write!(f, "serving worker gone (channel closed)"),
            ServeError::RecvTimeout => write!(f, "client receive deadline elapsed"),
        }
    }
}

impl std::error::Error for ServeError {}

/// Per-request sampling/termination knobs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SamplingParams {
    /// Generation stops after this many new tokens (0 = prompt only).
    pub max_new_tokens: usize,
    /// Generation stops early once this token is sampled. The stop
    /// token itself is still emitted (and counted), matching what a
    /// client scanning the stream for it would observe.
    pub stop_token: Option<usize>,
    /// End-to-end deadline measured from enqueue. Checked at admission
    /// and between decode steps; on expiry the request terminates with
    /// [`ServeError::DeadlineExceeded`]. `None` = no deadline.
    pub deadline: Option<Duration>,
    /// Maximum time the request may wait in the pending queue before
    /// its *first* admission (a preempted-then-requeued sequence is
    /// exempt — it already started). On expiry:
    /// [`ServeError::QueueTimeout`]. `None` = wait indefinitely.
    pub queue_timeout: Option<Duration>,
    /// Allow speculative decoding for this request (default `true`).
    /// Only meaningful when the serving worker has a draft model and
    /// `spec_gamma > 0`; output is bit-identical either way (the target
    /// verifies every token), so this is a latency/throughput knob —
    /// e.g. for isolating a request from draft-induced step jitter.
    pub speculative: bool,
}

impl Default for SamplingParams {
    fn default() -> Self {
        SamplingParams {
            max_new_tokens: 16,
            stop_token: None,
            deadline: None,
            queue_timeout: None,
            speculative: true,
        }
    }
}

/// A text-generation request: prompt + sampling parameters.
#[derive(Clone, Debug)]
pub struct GenerateRequest {
    pub prompt: Vec<usize>,
    pub params: SamplingParams,
}

impl GenerateRequest {
    /// Request with default params except `max_new_tokens`.
    pub fn new(prompt: Vec<usize>, max_new_tokens: usize) -> Self {
        GenerateRequest {
            prompt,
            params: SamplingParams { max_new_tokens, ..SamplingParams::default() },
        }
    }

    /// Fluent construction: `GenerateRequest::builder(prompt)
    /// .max_tokens(32).stop_token(0).build()`.
    pub fn builder(prompt: Vec<usize>) -> GenerateRequestBuilder {
        GenerateRequestBuilder { prompt, params: SamplingParams::default() }
    }
}

/// Builder returned by [`GenerateRequest::builder`].
#[derive(Clone, Debug)]
pub struct GenerateRequestBuilder {
    prompt: Vec<usize>,
    params: SamplingParams,
}

impl GenerateRequestBuilder {
    pub fn max_tokens(mut self, n: usize) -> Self {
        self.params.max_new_tokens = n;
        self
    }

    pub fn stop_token(mut self, tok: usize) -> Self {
        self.params.stop_token = Some(tok);
        self
    }

    /// End-to-end deadline from enqueue (admission + decode).
    pub fn deadline(mut self, d: Duration) -> Self {
        self.params.deadline = Some(d);
        self
    }

    /// Maximum queue wait before first admission.
    pub fn queue_timeout(mut self, d: Duration) -> Self {
        self.params.queue_timeout = Some(d);
        self
    }

    /// Opt this request out of (or back into) speculative decoding.
    pub fn speculative(mut self, on: bool) -> Self {
        self.params.speculative = on;
        self
    }

    pub fn build(self) -> GenerateRequest {
        GenerateRequest { prompt: self.prompt, params: self.params }
    }
}

/// One queued unit of work inside the coordinator: the client's request
/// plus the transport bookkeeping the worker needs (assigned id, event
/// channel, enqueue timestamp). Constructed at the submit boundary —
/// client code never builds one.
#[derive(Debug)]
pub struct WorkItem {
    pub id: RequestId,
    pub req: GenerateRequest,
    /// Channel the worker streams events on.
    pub respond_to: Sender<ResponseEvent>,
    /// Enqueue timestamp (for latency accounting).
    pub enqueued_at: std::time::Instant,
    /// Present iff this item is a preempted sequence re-enqueued for
    /// recompute-resume; `None` for fresh client submissions.
    pub resume: Option<ResumeState>,
}

/// Progress carried across a KV-pressure preemption. The worker freed
/// the sequence's blocks but kept its tokens: on re-admission the
/// prompt + generated tokens are re-prefilled (usually mostly from the
/// prefix cache) and decoding continues at `generated` — bit-identical
/// to never having been preempted, since prefill ≡ decode by the parity
/// contract.
#[derive(Clone, Copy, Debug)]
pub struct ResumeState {
    /// Tokens already generated (streamed token indices continue here).
    pub generated: usize,
    /// Queue wait accumulated before the preemption.
    pub queue_time: Duration,
    /// Active compute time accumulated before the preemption.
    pub compute_before: Duration,
    /// First-token timestamp, if the first token was already delivered.
    pub first_token_at: Option<Instant>,
    /// TTFT, if already measured (it does not change on resume).
    pub ttft: Option<Duration>,
    /// When the preemption happened (requeue wait accounts as queue
    /// time from here).
    pub preempted_at: Instant,
}

/// One streamed serving event.
#[derive(Clone, Debug)]
pub enum ResponseEvent {
    /// The `index`-th generated token (0-based) of request `id`,
    /// emitted the moment it is sampled.
    Token { id: RequestId, token: usize, index: usize },
    /// Final summary; always the last event of a request's stream.
    Done(GenerateResponse),
    /// Terminal failure; always the last event of a failed request's
    /// stream (no `Done` follows).
    Error { id: RequestId, error: ServeError },
}

/// The completed generation.
#[derive(Clone, Debug)]
pub struct GenerateResponse {
    pub id: RequestId,
    pub tokens: Vec<usize>,
    /// Tokens actually generated (≤ max_new_tokens).
    pub generated: usize,
    pub queue_time: Duration,
    pub compute_time: Duration,
    /// Enqueue → first generated token (`None` when nothing was
    /// generated, e.g. an empty prompt or `max_new_tokens == 0`).
    pub ttft: Option<Duration>,
}

/// Client-side view of one request's event stream.
pub struct ResponseHandle {
    rx: Receiver<ResponseEvent>,
}

impl ResponseHandle {
    pub fn new(rx: Receiver<ResponseEvent>) -> Self {
        ResponseHandle { rx }
    }

    /// Next streamed event (blocking). Errors once the stream is closed
    /// — after `Done`, or on worker shutdown.
    pub fn recv_event(&self) -> Result<ResponseEvent, RecvError> {
        self.rx.recv()
    }

    /// Blocking convenience: drain the stream to the final summary.
    /// Terminal outcomes map to `Err`: a streamed
    /// [`ResponseEvent::Error`] yields its [`ServeError`], and a channel
    /// that closes with no terminal event yields
    /// [`ServeError::WorkerGone`] — this can never block forever on an
    /// abandoned request.
    pub fn recv(&self) -> Result<GenerateResponse, ServeError> {
        loop {
            match self.rx.recv().map_err(|_| ServeError::WorkerGone)? {
                ResponseEvent::Done(resp) => return Ok(resp),
                ResponseEvent::Error { error, .. } => return Err(error),
                ResponseEvent::Token { .. } => {}
            }
        }
    }

    /// Like [`recv`](Self::recv) with a client-side wall-clock bound:
    /// past `deadline` it returns [`ServeError::RecvTimeout`] without a
    /// terminal event having arrived (the request may still complete —
    /// this is the caller giving up, not the engine). The chaos suite
    /// uses this to assert "no handle ever hangs" with a finite budget.
    pub fn recv_deadline(&self, deadline: Instant) -> Result<GenerateResponse, ServeError> {
        loop {
            let remaining = deadline.saturating_duration_since(Instant::now());
            match self.rx.recv_timeout(remaining) {
                Ok(ResponseEvent::Done(resp)) => return Ok(resp),
                Ok(ResponseEvent::Error { error, .. }) => return Err(error),
                Ok(ResponseEvent::Token { .. }) => {}
                Err(RecvTimeoutError::Timeout) => return Err(ServeError::RecvTimeout),
                Err(RecvTimeoutError::Disconnected) => return Err(ServeError::WorkerGone),
            }
        }
    }

    /// Iterate events until the stream closes (the worker drops its
    /// sender right after `Done`).
    pub fn events(&self) -> impl Iterator<Item = ResponseEvent> + '_ {
        std::iter::from_fn(move || self.rx.recv().ok())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::channel;

    fn done(id: RequestId, tokens: Vec<usize>, generated: usize) -> ResponseEvent {
        ResponseEvent::Done(GenerateResponse {
            id,
            tokens,
            generated,
            queue_time: Default::default(),
            compute_time: Default::default(),
            ttft: None,
        })
    }

    #[test]
    fn builder_and_new_agree_on_defaults() {
        let a = GenerateRequest::new(vec![1, 2], 9);
        let b = GenerateRequest::builder(vec![1, 2]).max_tokens(9).build();
        assert_eq!(a.prompt, b.prompt);
        assert_eq!(a.params, b.params);
        assert_eq!(a.params.stop_token, None);
        let c = GenerateRequest::builder(vec![3]).stop_token(0).build();
        assert_eq!(c.params.max_new_tokens, SamplingParams::default().max_new_tokens);
        assert_eq!(c.params.stop_token, Some(0));
    }

    #[test]
    fn work_item_response_round_trip() {
        let (tx, rx) = channel();
        let item = WorkItem {
            id: 7,
            req: GenerateRequest::new(vec![1, 2, 3], 4),
            respond_to: tx,
            enqueued_at: std::time::Instant::now(),
            resume: None,
        };
        item.respond_to.send(done(item.id, vec![1, 2, 3, 9], 1)).unwrap();
        drop(item);
        let handle = ResponseHandle::new(rx);
        let resp = handle.recv().unwrap();
        assert_eq!(resp.id, 7);
        assert_eq!(resp.tokens.len(), 4);
        assert!(handle.recv_event().is_err(), "stream closed after Done");
    }

    #[test]
    fn recv_skips_token_events_and_events_iterates_all() {
        let (tx, rx) = channel();
        tx.send(ResponseEvent::Token { id: 1, token: 42, index: 0 }).unwrap();
        tx.send(ResponseEvent::Token { id: 1, token: 7, index: 1 }).unwrap();
        tx.send(done(1, vec![42, 7], 2)).unwrap();
        drop(tx);
        let handle = ResponseHandle::new(rx);
        let events: Vec<ResponseEvent> = handle.events().collect();
        assert_eq!(events.len(), 3);
        match &events[0] {
            ResponseEvent::Token { token, index, .. } => {
                assert_eq!((*token, *index), (42, 0));
            }
            other => panic!("expected Token, got {other:?}"),
        }
        assert!(matches!(events[2], ResponseEvent::Done(_)));

        // recv() on a fresh stream jumps straight to the summary.
        let (tx, rx) = channel();
        tx.send(ResponseEvent::Token { id: 2, token: 3, index: 0 }).unwrap();
        tx.send(done(2, vec![3], 1)).unwrap();
        let handle = ResponseHandle::new(rx);
        assert_eq!(handle.recv().unwrap().generated, 1);
    }

    #[test]
    fn recv_maps_terminal_error_event() {
        let (tx, rx) = channel();
        tx.send(ResponseEvent::Token { id: 3, token: 5, index: 0 }).unwrap();
        tx.send(ResponseEvent::Error { id: 3, error: ServeError::DeadlineExceeded })
            .unwrap();
        drop(tx);
        let handle = ResponseHandle::new(rx);
        assert_eq!(handle.recv(), Err(ServeError::DeadlineExceeded));
    }

    #[test]
    fn recv_maps_disconnect_to_worker_gone() {
        let (tx, rx) = channel::<ResponseEvent>();
        drop(tx);
        let handle = ResponseHandle::new(rx);
        assert_eq!(handle.recv(), Err(ServeError::WorkerGone));
    }

    #[test]
    fn recv_deadline_times_out_then_still_delivers() {
        let (tx, rx) = channel();
        let handle = ResponseHandle::new(rx);
        let soon = Instant::now() + Duration::from_millis(5);
        assert_eq!(handle.recv_deadline(soon), Err(ServeError::RecvTimeout));
        // The request wasn't abandoned — a late Done is still readable.
        tx.send(done(4, vec![9], 1)).unwrap();
        let far = Instant::now() + Duration::from_secs(5);
        assert_eq!(handle.recv_deadline(far).unwrap().id, 4);
    }

    #[test]
    fn builder_sets_deadline_knobs() {
        let r = GenerateRequest::builder(vec![1])
            .deadline(Duration::from_millis(50))
            .queue_timeout(Duration::from_millis(10))
            .build();
        assert_eq!(r.params.deadline, Some(Duration::from_millis(50)));
        assert_eq!(r.params.queue_timeout, Some(Duration::from_millis(10)));
        // Defaults stay unbounded.
        assert_eq!(SamplingParams::default().deadline, None);
        assert_eq!(SamplingParams::default().queue_timeout, None);
    }

    #[test]
    fn speculative_defaults_on_and_builder_opts_out() {
        assert!(SamplingParams::default().speculative);
        let r = GenerateRequest::builder(vec![1]).speculative(false).build();
        assert!(!r.params.speculative);
        let r = GenerateRequest::builder(vec![1]).speculative(true).build();
        assert!(r.params.speculative);
    }

    #[test]
    fn serve_error_displays_are_distinct() {
        let errs = [
            ServeError::Poisoned("boom".into()),
            ServeError::Overloaded { limit: 8 },
            ServeError::QueueTimeout,
            ServeError::DeadlineExceeded,
            ServeError::TooLarge { budget_blocks: 9, arena_blocks: 4 },
            ServeError::WorkerGone,
            ServeError::RecvTimeout,
        ];
        let texts: Vec<String> = errs.iter().map(|e| e.to_string()).collect();
        for (i, a) in texts.iter().enumerate() {
            assert!(!a.is_empty());
            for b in texts.iter().skip(i + 1) {
                assert_ne!(a, b);
            }
        }
    }
}
