//! Request/response types crossing the coordinator boundary.
//!
//! Responses stream: the worker emits one [`ResponseEvent::Token`] per
//! generated token as soon as it is sampled (continuous batching
//! produces tokens incrementally, so clients can render them live) and
//! a final [`ResponseEvent::Done`] summary. [`ResponseHandle`] wraps the
//! event channel; its [`recv`](ResponseHandle::recv) drains to the
//! summary, so blocking callers keep the pre-streaming call shape.

use std::sync::mpsc::{Receiver, RecvError, Sender};
use std::time::Duration;

/// Monotonically increasing request identifier.
pub type RequestId = u64;

/// A text-generation request.
#[derive(Debug)]
pub struct GenerateRequest {
    pub id: RequestId,
    /// Which model variant serves this request ("dense", "blast_50", …).
    pub variant: String,
    pub prompt: Vec<usize>,
    pub max_new_tokens: usize,
    /// Channel the worker streams events on.
    pub respond_to: Sender<ResponseEvent>,
    /// Enqueue timestamp (for latency accounting).
    pub enqueued_at: std::time::Instant,
}

/// One streamed serving event.
#[derive(Clone, Debug)]
pub enum ResponseEvent {
    /// The `index`-th generated token (0-based) of request `id`,
    /// emitted the moment it is sampled.
    Token { id: RequestId, token: usize, index: usize },
    /// Final summary; always the last event of a request's stream.
    Done(GenerateResponse),
}

/// The completed generation.
#[derive(Clone, Debug)]
pub struct GenerateResponse {
    pub id: RequestId,
    pub tokens: Vec<usize>,
    /// Tokens actually generated (≤ max_new_tokens).
    pub generated: usize,
    pub queue_time: Duration,
    pub compute_time: Duration,
    /// Enqueue → first generated token (`None` when nothing was
    /// generated, e.g. an empty prompt or `max_new_tokens == 0`).
    pub ttft: Option<Duration>,
}

/// Client-side view of one request's event stream.
pub struct ResponseHandle {
    rx: Receiver<ResponseEvent>,
}

impl ResponseHandle {
    pub fn new(rx: Receiver<ResponseEvent>) -> Self {
        ResponseHandle { rx }
    }

    /// Next streamed event (blocking). Errors once the stream is closed
    /// — after `Done`, or on worker shutdown.
    pub fn recv_event(&self) -> Result<ResponseEvent, RecvError> {
        self.rx.recv()
    }

    /// Blocking convenience: drain the stream to the final summary.
    /// Call-compatible with the pre-streaming
    /// `Receiver<GenerateResponse>::recv`.
    pub fn recv(&self) -> Result<GenerateResponse, RecvError> {
        loop {
            if let ResponseEvent::Done(resp) = self.rx.recv()? {
                return Ok(resp);
            }
        }
    }

    /// Iterate events until the stream closes (the worker drops its
    /// sender right after `Done`).
    pub fn events(&self) -> impl Iterator<Item = ResponseEvent> + '_ {
        std::iter::from_fn(move || self.rx.recv().ok())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::channel;

    fn done(id: RequestId, tokens: Vec<usize>, generated: usize) -> ResponseEvent {
        ResponseEvent::Done(GenerateResponse {
            id,
            tokens,
            generated,
            queue_time: Default::default(),
            compute_time: Default::default(),
            ttft: None,
        })
    }

    #[test]
    fn request_response_round_trip() {
        let (tx, rx) = channel();
        let req = GenerateRequest {
            id: 7,
            variant: "blast".into(),
            prompt: vec![1, 2, 3],
            max_new_tokens: 4,
            respond_to: tx,
            enqueued_at: std::time::Instant::now(),
        };
        req.respond_to.send(done(req.id, vec![1, 2, 3, 9], 1)).unwrap();
        drop(req);
        let handle = ResponseHandle::new(rx);
        let resp = handle.recv().unwrap();
        assert_eq!(resp.id, 7);
        assert_eq!(resp.tokens.len(), 4);
        assert!(handle.recv_event().is_err(), "stream closed after Done");
    }

    #[test]
    fn recv_skips_token_events_and_events_iterates_all() {
        let (tx, rx) = channel();
        tx.send(ResponseEvent::Token { id: 1, token: 42, index: 0 }).unwrap();
        tx.send(ResponseEvent::Token { id: 1, token: 7, index: 1 }).unwrap();
        tx.send(done(1, vec![42, 7], 2)).unwrap();
        drop(tx);
        let handle = ResponseHandle::new(rx);
        let events: Vec<ResponseEvent> = handle.events().collect();
        assert_eq!(events.len(), 3);
        match &events[0] {
            ResponseEvent::Token { token, index, .. } => {
                assert_eq!((*token, *index), (42, 0));
            }
            other => panic!("expected Token, got {other:?}"),
        }
        assert!(matches!(events[2], ResponseEvent::Done(_)));

        // recv() on a fresh stream jumps straight to the summary.
        let (tx, rx) = channel();
        tx.send(ResponseEvent::Token { id: 2, token: 3, index: 0 }).unwrap();
        tx.send(done(2, vec![3], 1)).unwrap();
        let handle = ResponseHandle::new(rx);
        assert_eq!(handle.recv().unwrap().generated, 1);
    }
}
