//! Request/response types crossing the coordinator boundary.

use std::sync::mpsc::Sender;

/// Monotonically increasing request identifier.
pub type RequestId = u64;

/// A text-generation request.
#[derive(Debug)]
pub struct GenerateRequest {
    pub id: RequestId,
    /// Which model variant serves this request ("dense", "blast_50", …).
    pub variant: String,
    pub prompt: Vec<usize>,
    pub max_new_tokens: usize,
    /// Channel the worker answers on.
    pub respond_to: Sender<GenerateResponse>,
    /// Enqueue timestamp (for latency accounting).
    pub enqueued_at: std::time::Instant,
}

/// The completed generation.
#[derive(Clone, Debug)]
pub struct GenerateResponse {
    pub id: RequestId,
    pub tokens: Vec<usize>,
    /// Tokens actually generated (≤ max_new_tokens).
    pub generated: usize,
    pub queue_time: std::time::Duration,
    pub compute_time: std::time::Duration,
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::channel;

    #[test]
    fn request_response_round_trip() {
        let (tx, rx) = channel();
        let req = GenerateRequest {
            id: 7,
            variant: "blast".into(),
            prompt: vec![1, 2, 3],
            max_new_tokens: 4,
            respond_to: tx,
            enqueued_at: std::time::Instant::now(),
        };
        req.respond_to
            .send(GenerateResponse {
                id: req.id,
                tokens: vec![1, 2, 3, 9],
                generated: 1,
                queue_time: Default::default(),
                compute_time: Default::default(),
            })
            .unwrap();
        let resp = rx.recv().unwrap();
        assert_eq!(resp.id, 7);
        assert_eq!(resp.tokens.len(), 4);
    }
}
