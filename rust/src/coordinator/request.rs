//! Request/response types crossing the coordinator boundary.
//!
//! A [`GenerateRequest`] is plain data: the prompt plus its
//! [`SamplingParams`]. Build one directly, via [`GenerateRequest::new`],
//! or through the [`builder`](GenerateRequest::builder); the coordinator
//! wraps it into an internal [`WorkItem`] (id, response channel, queue
//! timestamp) at submission time, so request *content* and transport
//! *bookkeeping* stay separate types.
//!
//! Responses stream: the worker emits one [`ResponseEvent::Token`] per
//! generated token as soon as it is sampled (continuous batching
//! produces tokens incrementally, so clients can render them live) and
//! a final [`ResponseEvent::Done`] summary. [`ResponseHandle`] wraps the
//! event channel; its [`recv`](ResponseHandle::recv) drains to the
//! summary, so blocking callers keep the pre-streaming call shape.

use std::sync::mpsc::{Receiver, RecvError, Sender};
use std::time::Duration;

/// Monotonically increasing request identifier.
pub type RequestId = u64;

/// Per-request sampling/termination knobs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SamplingParams {
    /// Generation stops after this many new tokens (0 = prompt only).
    pub max_new_tokens: usize,
    /// Generation stops early once this token is sampled. The stop
    /// token itself is still emitted (and counted), matching what a
    /// client scanning the stream for it would observe.
    pub stop_token: Option<usize>,
}

impl Default for SamplingParams {
    fn default() -> Self {
        SamplingParams { max_new_tokens: 16, stop_token: None }
    }
}

/// A text-generation request: prompt + sampling parameters.
#[derive(Clone, Debug)]
pub struct GenerateRequest {
    pub prompt: Vec<usize>,
    pub params: SamplingParams,
}

impl GenerateRequest {
    /// Request with default params except `max_new_tokens`.
    pub fn new(prompt: Vec<usize>, max_new_tokens: usize) -> Self {
        GenerateRequest {
            prompt,
            params: SamplingParams { max_new_tokens, ..SamplingParams::default() },
        }
    }

    /// Fluent construction: `GenerateRequest::builder(prompt)
    /// .max_tokens(32).stop_token(0).build()`.
    pub fn builder(prompt: Vec<usize>) -> GenerateRequestBuilder {
        GenerateRequestBuilder { prompt, params: SamplingParams::default() }
    }
}

/// Builder returned by [`GenerateRequest::builder`].
#[derive(Clone, Debug)]
pub struct GenerateRequestBuilder {
    prompt: Vec<usize>,
    params: SamplingParams,
}

impl GenerateRequestBuilder {
    pub fn max_tokens(mut self, n: usize) -> Self {
        self.params.max_new_tokens = n;
        self
    }

    pub fn stop_token(mut self, tok: usize) -> Self {
        self.params.stop_token = Some(tok);
        self
    }

    pub fn build(self) -> GenerateRequest {
        GenerateRequest { prompt: self.prompt, params: self.params }
    }
}

/// One queued unit of work inside the coordinator: the client's request
/// plus the transport bookkeeping the worker needs (assigned id, event
/// channel, enqueue timestamp). Constructed at the submit boundary —
/// client code never builds one.
#[derive(Debug)]
pub struct WorkItem {
    pub id: RequestId,
    pub req: GenerateRequest,
    /// Channel the worker streams events on.
    pub respond_to: Sender<ResponseEvent>,
    /// Enqueue timestamp (for latency accounting).
    pub enqueued_at: std::time::Instant,
}

/// One streamed serving event.
#[derive(Clone, Debug)]
pub enum ResponseEvent {
    /// The `index`-th generated token (0-based) of request `id`,
    /// emitted the moment it is sampled.
    Token { id: RequestId, token: usize, index: usize },
    /// Final summary; always the last event of a request's stream.
    Done(GenerateResponse),
}

/// The completed generation.
#[derive(Clone, Debug)]
pub struct GenerateResponse {
    pub id: RequestId,
    pub tokens: Vec<usize>,
    /// Tokens actually generated (≤ max_new_tokens).
    pub generated: usize,
    pub queue_time: Duration,
    pub compute_time: Duration,
    /// Enqueue → first generated token (`None` when nothing was
    /// generated, e.g. an empty prompt or `max_new_tokens == 0`).
    pub ttft: Option<Duration>,
}

/// Client-side view of one request's event stream.
pub struct ResponseHandle {
    rx: Receiver<ResponseEvent>,
}

impl ResponseHandle {
    pub fn new(rx: Receiver<ResponseEvent>) -> Self {
        ResponseHandle { rx }
    }

    /// Next streamed event (blocking). Errors once the stream is closed
    /// — after `Done`, or on worker shutdown.
    pub fn recv_event(&self) -> Result<ResponseEvent, RecvError> {
        self.rx.recv()
    }

    /// Blocking convenience: drain the stream to the final summary.
    /// Call-compatible with the pre-streaming
    /// `Receiver<GenerateResponse>::recv`.
    pub fn recv(&self) -> Result<GenerateResponse, RecvError> {
        loop {
            if let ResponseEvent::Done(resp) = self.rx.recv()? {
                return Ok(resp);
            }
        }
    }

    /// Iterate events until the stream closes (the worker drops its
    /// sender right after `Done`).
    pub fn events(&self) -> impl Iterator<Item = ResponseEvent> + '_ {
        std::iter::from_fn(move || self.rx.recv().ok())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::channel;

    fn done(id: RequestId, tokens: Vec<usize>, generated: usize) -> ResponseEvent {
        ResponseEvent::Done(GenerateResponse {
            id,
            tokens,
            generated,
            queue_time: Default::default(),
            compute_time: Default::default(),
            ttft: None,
        })
    }

    #[test]
    fn builder_and_new_agree_on_defaults() {
        let a = GenerateRequest::new(vec![1, 2], 9);
        let b = GenerateRequest::builder(vec![1, 2]).max_tokens(9).build();
        assert_eq!(a.prompt, b.prompt);
        assert_eq!(a.params, b.params);
        assert_eq!(a.params.stop_token, None);
        let c = GenerateRequest::builder(vec![3]).stop_token(0).build();
        assert_eq!(c.params.max_new_tokens, SamplingParams::default().max_new_tokens);
        assert_eq!(c.params.stop_token, Some(0));
    }

    #[test]
    fn work_item_response_round_trip() {
        let (tx, rx) = channel();
        let item = WorkItem {
            id: 7,
            req: GenerateRequest::new(vec![1, 2, 3], 4),
            respond_to: tx,
            enqueued_at: std::time::Instant::now(),
        };
        item.respond_to.send(done(item.id, vec![1, 2, 3, 9], 1)).unwrap();
        drop(item);
        let handle = ResponseHandle::new(rx);
        let resp = handle.recv().unwrap();
        assert_eq!(resp.id, 7);
        assert_eq!(resp.tokens.len(), 4);
        assert!(handle.recv_event().is_err(), "stream closed after Done");
    }

    #[test]
    fn recv_skips_token_events_and_events_iterates_all() {
        let (tx, rx) = channel();
        tx.send(ResponseEvent::Token { id: 1, token: 42, index: 0 }).unwrap();
        tx.send(ResponseEvent::Token { id: 1, token: 7, index: 1 }).unwrap();
        tx.send(done(1, vec![42, 7], 2)).unwrap();
        drop(tx);
        let handle = ResponseHandle::new(rx);
        let events: Vec<ResponseEvent> = handle.events().collect();
        assert_eq!(events.len(), 3);
        match &events[0] {
            ResponseEvent::Token { token, index, .. } => {
                assert_eq!((*token, *index), (42, 0));
            }
            other => panic!("expected Token, got {other:?}"),
        }
        assert!(matches!(events[2], ResponseEvent::Done(_)));

        // recv() on a fresh stream jumps straight to the summary.
        let (tx, rx) = channel();
        tx.send(ResponseEvent::Token { id: 2, token: 3, index: 0 }).unwrap();
        tx.send(done(2, vec![3], 1)).unwrap();
        let handle = ResponseHandle::new(rx);
        assert_eq!(handle.recv().unwrap().generated, 1);
    }
}
