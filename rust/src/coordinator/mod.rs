//! L3 coordinator: the serving system around the compressed models.
//!
//! Architecture (vllm-router-like; std::thread + mpsc — the build is
//! offline so no tokio, and the request path is synchronous channel
//! passing):
//!
//! ```text
//!   clients ──> Router ──> per-variant queue ──> DynamicBatcher
//!                                                    │ (max batch / deadline)
//!                                                    v
//!                                              Worker thread
//!                                         (prefill + decode loop,
//!                                          KV-cache slots, metrics)
//!                                                    │
//!   clients <── response channels <──────────────────┘
//! ```
//!
//! The paper's contribution lives in the *weights* (L1/L2); the
//! coordinator is the production harness that turns the compressed model
//! into a service and measures the Table-4 runtime story end to end.

pub mod request;
pub mod batcher;
pub mod metrics;
pub mod server;

pub use batcher::{BatcherConfig, DynamicBatcher};
pub use metrics::Metrics;
pub use request::{GenerateRequest, GenerateResponse, RequestId};
pub use server::{Coordinator, CoordinatorConfig};
