//! L3 coordinator: the serving system around the compressed models.
//!
//! Architecture (vLLM-style iteration-level continuous batching with
//! paged KV + prefix caching; std::thread + mpsc — the build is offline
//! so no tokio, and the request path is synchronous channel passing):
//!
//! ```text
//!   clients ──> Router ──> per-variant queue ──> DynamicBatcher
//!                                                    │ try_admit → pending FIFO
//!                                                    v
//!                                            Worker step loop
//!                                     ┌─ admit → reserve KV blocks, prefill
//!                                     │  only past the cached prefix
//!                                     ├─ sample 1 token/sequence, stream it
//!                                     ├─ retire finished → free private
//!                                     │  blocks, keep prefix blocks cached
//!                                     └─ ONE batched decode step (batch =
//!                                        active sequences through the kernels)
//!                                                    │
//!   clients <── Token / Done event streams <─────────┘
//! ```
//!
//! Requests are admitted *between decode iterations* once the
//! [`nn::kvcache::KvBlockManager`](crate::nn::kvcache::KvBlockManager)
//! can reserve their block budget, so new arrivals never stall live
//! sequences and a finished sequence's blocks are reusable one
//! iteration later. Prompt prefixes are content-addressed: a request
//! whose prompt shares a full-block prefix with an earlier one reuses
//! the cached K/V rows and skips prefill over the shared span. Tokens
//! stream to clients as [`ResponseEvent::Token`] the moment they are
//! sampled; [`Coordinator::generate`] stays as the blocking convenience
//! wrapper over [`GenerateRequest`] + [`SamplingParams`]. The paper's
//! contribution lives in the *weights* (L1/L2); the coordinator is the
//! production harness that turns the compressed model into a service
//! and measures the Table-4 runtime story end to end — batched decode
//! is what lets BLAST's Algorithm-1 products amortize across concurrent
//! users.
//!
//! Failure semantics (see [`server`] module docs for the full story):
//! every submitted request terminates with `Done` or a typed
//! [`ResponseEvent::Error`] carrying a [`ServeError`] — the pending
//! queue is bounded with load shedding, deadlines are enforced in the
//! queue and between decode steps, panics in model code poison only the
//! offending sequence, and KV pressure preempts the youngest active
//! sequence for a bit-identical recompute-resume.

pub mod request;
pub mod batcher;
pub mod metrics;
pub mod server;

pub use batcher::{BatcherConfig, DynamicBatcher};
pub use metrics::{Histogram, Metrics};
pub use request::{
    GenerateRequest, GenerateRequestBuilder, GenerateResponse, RequestId, ResponseEvent,
    ResponseHandle, ResumeState, SamplingParams, ServeError, WorkItem,
};
pub use server::{Coordinator, CoordinatorConfig};

pub use crate::util::config::EngineConfig;
