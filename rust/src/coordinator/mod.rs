//! L3 coordinator: the serving system around the compressed models.
//!
//! Architecture (vLLM-style iteration-level continuous batching;
//! std::thread + mpsc — the build is offline so no tokio, and the
//! request path is synchronous channel passing):
//!
//! ```text
//!   clients ──> Router ──> per-variant queue ──> DynamicBatcher
//!                                                    │ try_admit(free slots)
//!                                                    v
//!                                            Worker step loop
//!                                     ┌─ admit → prefill into KvPool slot
//!                                     ├─ sample 1 token/sequence, stream it
//!                                     ├─ retire finished → free slot
//!                                     └─ ONE batched decode step (batch =
//!                                        active slots through the kernels)
//!                                                    │
//!   clients <── Token / Done event streams <─────────┘
//! ```
//!
//! Requests are admitted *between decode iterations* into free slots of
//! a fixed [`nn::kvcache::KvPool`](crate::nn::kvcache::KvPool), so new
//! arrivals never stall live sequences and a finished sequence's slot
//! is reused one iteration later. Tokens stream to clients as
//! [`ResponseEvent::Token`] the moment they are sampled;
//! [`Coordinator::generate`] stays as the blocking convenience wrapper.
//! The paper's contribution lives in the *weights* (L1/L2); the
//! coordinator is the production harness that turns the compressed
//! model into a service and measures the Table-4 runtime story end to
//! end — batched decode is what lets BLAST's Algorithm-1 products
//! amortize across concurrent users.

pub mod request;
pub mod batcher;
pub mod metrics;
pub mod server;

pub use batcher::{BatcherConfig, DynamicBatcher};
pub use metrics::{Histogram, Metrics};
pub use request::{
    GenerateRequest, GenerateResponse, RequestId, ResponseEvent, ResponseHandle,
};
pub use server::{Coordinator, CoordinatorConfig};
