//! Baseline compressors the paper compares against (§4):
//! * **Low-Rank** — truncated SVD of the whole matrix;
//! * **Monarch / BLR** — per-block low-rank with bases shared per block
//!   column (the batched-bmm-friendly BLR variant of Dao et al.);
//! * **Block-Diagonal** — keep only the diagonal blocks.
//!
//! Each returns both the compressed weight representation and its dense
//! reconstruction so the nn layer and the experiments can use either.

use crate::blast::BlastMatrix;
use crate::linalg::{truncated_svd, Svd};
use crate::tensor::{matmul, Matrix};

/// Low-rank compression: `A ≈ U_r diag(s_r) V_r^T` with
/// `r` chosen for the target parameter budget. Stores the scaled factors
/// `(P, Q)` with `P = U diag(s)`, `Q = V`.
#[derive(Clone, Debug)]
pub struct LowRankWeight {
    /// m×r (left factor, singular values folded in).
    pub p: Matrix,
    /// n×r (right factor).
    pub q: Matrix,
}

impl LowRankWeight {
    pub fn compress(a: &Matrix, r: usize) -> Self {
        let Svd { u, s, v } = truncated_svd(a, r);
        let mut p = u;
        for i in 0..p.rows {
            let row = p.row_mut(i);
            for (k, sv) in s.iter().enumerate() {
                row[k] *= sv;
            }
        }
        LowRankWeight { p, q: v }
    }

    pub fn to_dense(&self) -> Matrix {
        crate::tensor::matmul_nt(&self.p, &self.q)
    }

    pub fn num_params(&self) -> usize {
        self.p.len() + self.q.len()
    }

    /// `y = X A^T` for activations (linear-layer convention).
    pub fn matmul_act(&self, x: &Matrix) -> Matrix {
        // X (batch×n) · Q (n×r) -> batch×r, then · P^T -> batch×m.
        let z = matmul(x, &self.q);
        crate::tensor::matmul_nt(&z, &self.p)
    }
}

/// Monarch-style block-low-rank compression: the matrix is partitioned
/// into `b×b` blocks; the right basis `R_j (t×q)` is shared by block
/// column `j` (computed from the SVD of the stacked block column), and
/// each block keeps its own left coupling `L_{i,j} (p×t)`.
///
/// Params: `b·t·q` (bases) + `b²·p·t` (couplings) = `n·t + m·b·t`.
#[derive(Clone, Debug)]
pub struct MonarchWeight {
    pub b: usize,
    pub t: usize,
    /// Per block column: t×q shared right basis.
    pub r_bases: Vec<Matrix>,
    /// l[i][j]: p×t left coupling of block (i, j).
    pub l: Vec<Vec<Matrix>>,
}

impl MonarchWeight {
    pub fn compress(a: &Matrix, b: usize, t: usize) -> Self {
        assert!(a.rows % b == 0 && a.cols % b == 0);
        let p = a.rows / b;
        let q = a.cols / b;
        let t = t.min(q).min(p * b);
        let mut r_bases = Vec::with_capacity(b);
        let mut l: Vec<Vec<Matrix>> = vec![Vec::with_capacity(b); b];

        for j in 0..b {
            // Stack the block column (m×q) and take its top-t right
            // singular subspace as the shared basis.
            let col = a.block_col(j, b);
            let svd = truncated_svd(&col, t);
            // R_j = V_t^T (t×q).
            let r_j = svd.v.transpose();
            // L_{i,j} = A_{i,j} R_j^T (p×t) — least-squares coupling onto
            // the orthonormal basis rows.
            for i in 0..b {
                let blk = a.block(i, j, b, b);
                let lij = crate::tensor::matmul_nt(&blk, &r_j);
                l[i].push(lij);
            }
            r_bases.push(r_j);
            let _ = p;
        }
        MonarchWeight { b, t, r_bases, l }
    }

    pub fn to_dense(&self) -> Matrix {
        let p = self.l[0][0].rows;
        let q = self.r_bases[0].cols;
        let b = self.b;
        let mut out = Matrix::zeros(p * b, q * b);
        for i in 0..b {
            for j in 0..b {
                let blk = matmul(&self.l[i][j], &self.r_bases[j]);
                out.set_submatrix(i * p, j * q, &blk);
            }
        }
        out
    }

    pub fn num_params(&self) -> usize {
        let base: usize = self.r_bases.iter().map(|m| m.len()).sum();
        let coup: usize = self.l.iter().flatten().map(|m| m.len()).sum();
        base + coup
    }

    /// `y = X A^T`.
    pub fn matmul_act(&self, x: &Matrix) -> Matrix {
        let b = self.b;
        let p = self.l[0][0].rows;
        let q = self.r_bases[0].cols;
        let batch = x.rows;
        let mut y = Matrix::zeros(batch, p * b);
        for j in 0..b {
            let xj = x.submatrix(0, batch, j * q, (j + 1) * q);
            // z_j = X_j R_j^T (batch×t), shared across output rows.
            let zj = crate::tensor::matmul_nt(&xj, &self.r_bases[j]);
            for i in 0..b {
                // y_i += z_j L_{i,j}^T
                let contrib = crate::tensor::matmul_nt(&zj, &self.l[i][j]);
                for t in 0..batch {
                    let yrow = &mut y.row_mut(t)[i * p..(i + 1) * p];
                    for (yv, cv) in yrow.iter_mut().zip(contrib.row(t)) {
                        *yv += cv;
                    }
                }
            }
        }
        y
    }
}

/// Block-diagonal compression: keep (a low-rank approximation of) the
/// diagonal blocks, zero everything else.
#[derive(Clone, Debug)]
pub struct BlockDiagWeight {
    pub b: usize,
    /// Per-diagonal-block (P_i, Q_i) rank-t factors: block = P_i Q_i^T.
    pub blocks: Vec<(Matrix, Matrix)>,
}

impl BlockDiagWeight {
    pub fn compress(a: &Matrix, b: usize, t: usize) -> Self {
        assert!(a.rows % b == 0 && a.cols % b == 0);
        let blocks = (0..b)
            .map(|i| {
                let blk = a.block(i, i, b, b);
                let t = t.min(blk.rows.min(blk.cols));
                let Svd { u, s, v } = truncated_svd(&blk, t);
                let mut p = u;
                for row in 0..p.rows {
                    let r = p.row_mut(row);
                    for (k, sv) in s.iter().enumerate() {
                        r[k] *= sv;
                    }
                }
                (p, v)
            })
            .collect();
        BlockDiagWeight { b, blocks }
    }

    pub fn to_dense(&self) -> Matrix {
        let p = self.blocks[0].0.rows;
        let q = self.blocks[0].1.rows;
        let b = self.b;
        let mut out = Matrix::zeros(p * b, q * b);
        for (i, (pm, qm)) in self.blocks.iter().enumerate() {
            let blk = crate::tensor::matmul_nt(pm, qm);
            out.set_submatrix(i * p, i * q, &blk);
        }
        out
    }

    pub fn num_params(&self) -> usize {
        self.blocks.iter().map(|(p, q)| p.len() + q.len()).sum()
    }

    /// `y = X A^T`.
    pub fn matmul_act(&self, x: &Matrix) -> Matrix {
        let b = self.b;
        let p = self.blocks[0].0.rows;
        let q = self.blocks[0].1.rows;
        let batch = x.rows;
        let mut y = Matrix::zeros(batch, p * b);
        for i in 0..b {
            let xi = x.submatrix(0, batch, i * q, (i + 1) * q);
            let z = matmul(&xi, &self.blocks[i].1); // batch×t
            let yi = crate::tensor::matmul_nt(&z, &self.blocks[i].0); // batch×p
            for t in 0..batch {
                y.row_mut(t)[i * p..(i + 1) * p].copy_from_slice(yi.row(t));
            }
        }
        y
    }
}

/// Convert a compressed low-rank weight into the equivalent BLAST matrix
/// (the §2 embedding) — used by tests to validate the expressivity claims.
pub fn lowrank_to_blast(w: &LowRankWeight, b: usize) -> BlastMatrix {
    BlastMatrix::from_low_rank(&w.p, &w.q, b)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Rng;

    #[test]
    fn lowrank_exact_on_lowrank_target() {
        let mut rng = Rng::new(110);
        let u = rng.gaussian_matrix(20, 3, 1.0);
        let v = rng.gaussian_matrix(16, 3, 1.0);
        let a = crate::tensor::matmul_nt(&u, &v);
        let w = LowRankWeight::compress(&a, 3);
        assert!(w.to_dense().sub(&a).fro_norm() < 1e-2 * a.fro_norm());
        assert_eq!(w.num_params(), 20 * 3 + 16 * 3);
    }

    #[test]
    fn lowrank_matmul_act() {
        let mut rng = Rng::new(111);
        let a = rng.gaussian_matrix(12, 10, 1.0);
        let w = LowRankWeight::compress(&a, 10); // full rank -> exact
        let x = rng.gaussian_matrix(4, 10, 1.0);
        let y = w.matmul_act(&x);
        let y_ref = crate::tensor::matmul_nt(&x, &a);
        assert!(y.sub(&y_ref).fro_norm() < 1e-2 * (1.0 + y_ref.fro_norm()));
    }

    #[test]
    fn monarch_reconstruction_quality() {
        let mut rng = Rng::new(112);
        let a = rng.gaussian_matrix(16, 16, 1.0);
        // Full block rank -> near exact.
        let w = MonarchWeight::compress(&a, 4, 4);
        assert!(w.to_dense().sub(&a).fro_norm() < 5e-2 * a.fro_norm());
        // Lower rank -> worse but bounded.
        let w2 = MonarchWeight::compress(&a, 4, 2);
        let e2 = w2.to_dense().sub(&a).fro_norm();
        assert!(e2 > 0.0 && e2 < a.fro_norm());
    }

    #[test]
    fn monarch_matmul_act_matches_dense() {
        let mut rng = Rng::new(113);
        let a = rng.gaussian_matrix(12, 8, 1.0);
        let w = MonarchWeight::compress(&a, 4, 2);
        let dense = w.to_dense();
        let x = rng.gaussian_matrix(5, 8, 1.0);
        let y = w.matmul_act(&x);
        let y_ref = crate::tensor::matmul_nt(&x, &dense);
        assert!(y.sub(&y_ref).fro_norm() < 1e-3 * (1.0 + y_ref.fro_norm()));
    }

    #[test]
    fn blockdiag_zeroes_offdiagonal() {
        let mut rng = Rng::new(114);
        let a = rng.gaussian_matrix(12, 12, 1.0);
        let w = BlockDiagWeight::compress(&a, 3, 4); // full-rank diag blocks
        let d = w.to_dense();
        for i in 0..3 {
            for j in 0..3 {
                let blk = d.block(i, j, 3, 3);
                if i == j {
                    let orig = a.block(i, i, 3, 3);
                    assert!(blk.sub(&orig).fro_norm() < 5e-2 * orig.fro_norm());
                } else {
                    assert!(blk.fro_norm() < 1e-5);
                }
            }
        }
    }

    #[test]
    fn blockdiag_matmul_act_matches_dense() {
        let mut rng = Rng::new(115);
        let a = rng.gaussian_matrix(9, 6, 1.0);
        let w = BlockDiagWeight::compress(&a, 3, 2);
        let dense = w.to_dense();
        let x = rng.gaussian_matrix(4, 6, 1.0);
        let y = w.matmul_act(&x);
        let y_ref = crate::tensor::matmul_nt(&x, &dense);
        assert!(y.sub(&y_ref).fro_norm() < 1e-3 * (1.0 + y_ref.fro_norm()));
    }

    #[test]
    fn lowrank_embeds_into_blast() {
        let mut rng = Rng::new(116);
        let a = rng.gaussian_matrix(12, 12, 1.0);
        let w = LowRankWeight::compress(&a, 4);
        let blast = lowrank_to_blast(&w, 3);
        assert!(blast.to_dense().sub(&w.to_dense()).fro_norm() < 1e-3);
    }

    #[test]
    fn blast_beats_baselines_on_blast_target() {
        // The paper's central flexibility claim: when the true structure
        // is BLAST (heterogeneous block ranks), BLAST factorization
        // reconstructs better than low-rank or block-diagonal at matched
        // parameter budget.
        let mut rng = Rng::new(117);
        let truth = BlastMatrix::random_init(32, 32, 4, 3, 0.4, &mut rng);
        let a = truth.to_dense();
        let blast_params = truth.num_params();

        // Low-rank at the same budget.
        let r_lr = blast_params / (32 + 32);
        let lr = LowRankWeight::compress(&a, r_lr);
        // Block-diag at the same budget: t = budget / (m+n).
        let bd = BlockDiagWeight::compress(&a, 4, blast_params / 64);

        let fit = crate::factorize::precgd::factorize_precgd(
            &a,
            &crate::factorize::PrecGdOptions {
                b: 4,
                r: 3,
                iters: 80,
                seed: 13,
                ..Default::default()
            },
        );
        let e_blast = fit.rel_error;
        let e_lr = lr.to_dense().sub(&a).fro_norm() as f64 / a.fro_norm() as f64;
        let e_bd = bd.to_dense().sub(&a).fro_norm() as f64 / a.fro_norm() as f64;
        assert!(
            e_blast < e_lr && e_blast < e_bd,
            "blast {e_blast} vs lowrank {e_lr} vs blockdiag {e_bd}"
        );
    }
}
