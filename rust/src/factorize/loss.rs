//! The factorization objective (Eq. 4) and its gradients (Appendix A.2.1).
//!
//! `ℓ(U, V, s) = Σ_{i,j} ½ ‖A_{i,j} − U_i diag(s_{i,j}) V_j^T‖_F²`
//!
//! Gradients (concatenated-factor form):
//! * `∇U_i = (U_i V̄_i^T − A_{i,*}) V̄_i`                        (Eq. 10)
//! * `∇V_j = (Ū_j V_j^T − A_{*,j})^T Ū_j`                       (Eq. 11)
//! * `∇s_{i,j} = ((U_i^T U_i) ⊙ (V_j^T V_j)) s_{i,j}
//!               − diag(U_i^T A_{i,j} V_j)`                     (Eq. 15)
//!
//! Every O(p·n·r)-class product here is **plan construction + engine
//! dispatch**: the `A·Bᵀ` reconstructions build a cached
//! [`StructPlan::dense`] and the `A·B` forms a
//! [`StructPlan::dense_t`] (columns gathered once per call into the
//! executor's reused thread-local scratch — the pre-plan code
//! allocated a fresh transpose on every call), both executed through
//! [`KernelEngine::plan_act_serial`] — the same structure-plan layer
//! the serving path runs, on its serial reference executor. Serial
//! matters twice here: it never spawns worker threads (the `b×b`
//! factor grid — and the pipeline's layer queue above it — own all
//! thread-level parallelism, so a nested parallel dispatch would
//! oversubscribe the machine), and it never touches the pack cache
//! (these factors mutate every sweep iteration; packing them would
//! churn panels that can never be reused). By the engine's fixed-lane
//! contract this routing is bit-identical to every other dispatch
//! path.
//!
//! [`StructPlan::dense`]: crate::kernels::StructPlan::dense
//! [`StructPlan::dense_t`]: crate::kernels::StructPlan::dense_t
//! [`KernelEngine::plan_act_serial`]: crate::kernels::KernelEngine::plan_act_serial

use crate::blast::BlastMatrix;
use crate::kernels::{engine, plan_cache, PlanOperands};
use crate::tensor::{matmul_tn, Matrix};
use crate::util::par;

/// `A · Bᵀ` as a dense structure plan on the serial executor — the
/// factorization-side entry to the engine's plan path.
fn nt_planned(a: &Matrix, b: &Matrix) -> Matrix {
    let plan = plan_cache().dense(b.rows, b.cols);
    engine().plan_act_serial(a, &plan, &PlanOperands::single(b))
}

/// `A · B` as a col-gathered dense-transpose plan on the serial
/// executor (bit-identical to `nt_planned(a, &b.transpose())`, without
/// the transpose).
fn mm_planned(a: &Matrix, b: &Matrix) -> Matrix {
    let plan = plan_cache().dense_t(b.cols, b.rows);
    engine().plan_act_serial(a, &plan, &PlanOperands::single(b))
}

/// Eq. 4 evaluated over the full matrix: `½ ‖A − BLAST‖_F²`.
///
/// Computed block-by-block (the natural decomposition of Eq. 4), summed
/// in block order with f64 accumulation.
pub fn blast_loss(target: &Matrix, x: &BlastMatrix) -> f64 {
    blast_loss_with(target, x, false)
}

/// [`blast_loss`] with the per-block terms optionally evaluated across
/// the thread pool. The per-block arithmetic and the final (sequential,
/// block-ordered) sum are identical in both modes, so parallel and
/// single-thread evaluation return bit-identical values.
pub fn blast_loss_with(target: &Matrix, x: &BlastMatrix, parallel: bool) -> f64 {
    assert_eq!(target.shape(), (x.m, x.n));
    let b = x.b;
    let terms = par::par_map_if(parallel, b * b, |idx| {
        block_loss_term(target, x, idx / b, idx % b)
    });
    terms.iter().sum()
}

/// `½ ‖A_{i,j} − U_i diag(s_{i,j}) V_j^T‖_F²` — one block's share of Eq. 4.
fn block_loss_term(target: &Matrix, x: &BlastMatrix, i: usize, j: usize) -> f64 {
    let rec = nt_planned(&x.u_scaled(i, j), &x.v[j]); // p×q
    let a = target.block(i, j, x.b, x.b);
    0.5 * a.sub(&rec).fro_norm_sq()
}

/// Gradient of Eq. 4 w.r.t. `U_i` (Eq. 10): `(U_i V̄_i^T − A_{i,*}) V̄_i`.
pub fn grad_u(target: &Matrix, x: &BlastMatrix, i: usize) -> Matrix {
    let v_bar = x.v_bar(i); // n×r
    let a_row = target.block_row(i, x.b); // p×n
    let resid = nt_planned(&x.u[i], &v_bar).sub(&a_row); // p×n
    mm_planned(&resid, &v_bar) // p×r
}

/// Gradient w.r.t. `V_j` (Eq. 11): `(Ū_j V_j^T − A_{*,j})^T Ū_j`.
pub fn grad_v(target: &Matrix, x: &BlastMatrix, j: usize) -> Matrix {
    let u_bar = x.u_bar(j); // m×r
    let a_col = target.block_col(j, x.b); // m×q
    let resid = nt_planned(&u_bar, &x.v[j]).sub(&a_col); // m×q
    mm_planned(&resid.transpose(), &u_bar) // q×r
}

/// Gradient w.r.t. `s_{i,j}` (Eq. 15):
/// `W_{i,j} s_{i,j} − diag(U_i^T A_{i,j} V_j)` with
/// `W_{i,j} = (U_i^T U_i) ⊙ (V_j^T V_j)`.
pub fn grad_s(target: &Matrix, x: &BlastMatrix, i: usize, j: usize) -> Vec<f32> {
    let w = gram_hadamard(&x.u[i], &x.v[j]); // r×r
    let rhs = diag_utav(&x.u[i], &target.block(i, j, x.b, x.b), &x.v[j]); // r
    let ws = crate::tensor::gemv(&w, &x.s[i][j]);
    ws.iter().zip(&rhs).map(|(a, b)| a - b).collect()
}

/// `W_{i,j} = (U^T U) ⊙ (V^T V)` — the Gram-Hadamard matrix of Eq. 9,
/// also the Lipschitz operator for the `s` update in Theorem 1.
pub fn gram_hadamard(u: &Matrix, v: &Matrix) -> Matrix {
    let gu = matmul_tn(u, u);
    let gv = matmul_tn(v, v);
    gu.hadamard(&gv)
}

/// `diag(U^T A V)` computed without forming the full r×r product:
/// entry `k` is `u_k^T A v_k`.
pub fn diag_utav(u: &Matrix, a: &Matrix, v: &Matrix) -> Vec<f32> {
    let av = mm_planned(a, v); // p×r
    let r = u.cols;
    let mut out = vec![0.0f32; r];
    for k in 0..r {
        let mut acc = 0.0f64;
        for t in 0..u.rows {
            acc += (u.at(t, k) as f64) * (av.at(t, k) as f64);
        }
        out[k] = acc as f32;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Rng;

    /// Numerical gradient check via central differences.
    fn numeric_grad(
        target: &Matrix,
        x: &BlastMatrix,
        perturb: impl Fn(&mut BlastMatrix, f32),
    ) -> f64 {
        let h = 1e-3f32;
        let mut xp = x.clone();
        perturb(&mut xp, h);
        let mut xm = x.clone();
        perturb(&mut xm, -h);
        (blast_loss(target, &xp) - blast_loss(target, &xm)) / (2.0 * h as f64)
    }

    #[test]
    fn grad_u_matches_numeric() {
        let mut rng = Rng::new(80);
        let x = BlastMatrix::random_init(6, 6, 2, 2, 0.5, &mut rng);
        let target = rng.gaussian_matrix(6, 6, 1.0);
        let g = grad_u(&target, &x, 0);
        for (a, c) in [(0, 0), (1, 1), (2, 0)] {
            let num = numeric_grad(&target, &x, |xx, h| {
                *xx.u[0].at_mut(a, c) += h;
            });
            let ana = g.at(a, c) as f64;
            assert!(
                (num - ana).abs() < 2e-2 * (1.0 + ana.abs()),
                "U grad ({a},{c}): numeric {num} vs analytic {ana}"
            );
        }
    }

    #[test]
    fn grad_v_matches_numeric() {
        let mut rng = Rng::new(81);
        let x = BlastMatrix::random_init(6, 6, 2, 2, 0.5, &mut rng);
        let target = rng.gaussian_matrix(6, 6, 1.0);
        let g = grad_v(&target, &x, 1);
        for (a, c) in [(0, 0), (1, 1), (2, 0)] {
            let num = numeric_grad(&target, &x, |xx, h| {
                *xx.v[1].at_mut(a, c) += h;
            });
            let ana = g.at(a, c) as f64;
            assert!(
                (num - ana).abs() < 2e-2 * (1.0 + ana.abs()),
                "V grad ({a},{c}): numeric {num} vs analytic {ana}"
            );
        }
    }

    #[test]
    fn grad_s_matches_numeric() {
        let mut rng = Rng::new(82);
        let x = BlastMatrix::random_init(6, 6, 2, 3, 0.5, &mut rng);
        let target = rng.gaussian_matrix(6, 6, 1.0);
        let g = grad_s(&target, &x, 1, 0);
        for k in 0..3 {
            let num = numeric_grad(&target, &x, |xx, h| {
                xx.s[1][0][k] += h;
            });
            let ana = g[k] as f64;
            assert!(
                (num - ana).abs() < 2e-2 * (1.0 + ana.abs()),
                "s grad [{k}]: numeric {num} vs analytic {ana}"
            );
        }
    }

    #[test]
    fn loss_zero_at_exact_representation() {
        let mut rng = Rng::new(83);
        let x = BlastMatrix::random_init(8, 8, 2, 2, 1.0, &mut rng);
        let target = x.to_dense();
        assert!(blast_loss(&target, &x) < 1e-8);
        // Gradients vanish at the optimum.
        assert!(grad_u(&target, &x, 0).fro_norm() < 1e-3);
        assert!(grad_v(&target, &x, 1).fro_norm() < 1e-3);
        assert!(grad_s(&target, &x, 0, 1).iter().all(|g| g.abs() < 1e-3));
    }

    #[test]
    fn gram_hadamard_psd() {
        let mut rng = Rng::new(84);
        let u = rng.gaussian_matrix(10, 4, 1.0);
        let v = rng.gaussian_matrix(10, 4, 1.0);
        let w = gram_hadamard(&u, &v);
        // Schur product theorem: W is PSD. Check x^T W x >= 0 for random x.
        for _ in 0..20 {
            let x = rng.gaussian_vec(4, 1.0);
            let wx = crate::tensor::gemv(&w, &x);
            let quad: f64 = x.iter().zip(&wx).map(|(a, b)| (*a as f64) * (*b as f64)).sum();
            assert!(quad >= -1e-4, "quad form {quad}");
        }
    }

    #[test]
    fn diag_utav_matches_full_product() {
        let mut rng = Rng::new(85);
        let u = rng.gaussian_matrix(7, 3, 1.0);
        let a = rng.gaussian_matrix(7, 5, 1.0);
        let v = rng.gaussian_matrix(5, 3, 1.0);
        let d = diag_utav(&u, &a, &v);
        let full = crate::tensor::matmul(&matmul_tn(&u, &a), &v);
        for k in 0..3 {
            assert!((d[k] - full.at(k, k)).abs() < 1e-4);
        }
    }
}
