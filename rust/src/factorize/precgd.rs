//! Algorithm 2 — preconditioned BLAST factorization.
//!
//! The preconditioners (Eqs. 8–9) are regularized Gram inverses applied on
//! the factor side of each gradient:
//!
//! * `U_i ← U_i − η (U_i V̄_i^T − A_{i,*}) V̄_i · (V̄_i^T V̄_i + δI)^{-1}`
//! * `V_j ← V_j − η (Ū_j V_j^T − A_{*,j})^T Ū_j · (Ū_j^T Ū_j + δI)^{-1}`
//! * `s_{i,j} ← s_{i,j} − η (W_{i,j} + δI)^{-1}(W_{i,j} s − diag(U^T A V))`
//!
//! with `W_{i,j} = (U_i^T U_i) ⊙ (V_j^T V_j)` and
//! `δ = δ₀ · sqrt(ℓ(U, V, s))` (Eq. 19) — the regularizer shrinks with the
//! residual, recovering the ideal Newton-like preconditioner near the
//! optimum. This fixes the slow convergence of over-parameterized (`r >
//! r*`) factorization (paper Fig. 3/9).
//!
//! ## Block-parallel execution
//!
//! Within each of the three sweeps the per-factor updates are mutually
//! independent given the shared stage-2 coupling: a `U_i` update reads
//! only `V`/`s` (fixed during the U sweep), a `V_j` update only the
//! already-updated `U` and `s`, and an `s_{i,j}` update only `U_i`,
//! `V_j`, and its own coupling vector. With [`PrecGdOptions::parallel`]
//! each sweep therefore fans its `b` (or `b²`) updates across the
//! scoped-thread pool with a barrier between sweeps, preserving the
//! Algorithm-2 ordering. Because every update's arithmetic is unchanged
//! and results are written back in index order, the parallel schedule is
//! **bit-identical** to the sequential one (asserted by
//! `tests/factorize_parity.rs`). The per-iteration loss (needed for the
//! Eq. 19 regularizer) is likewise evaluated block-parallel, and each
//! iteration's post-sweep loss is reused as the next iteration's `ℓ` so
//! the objective is computed once, not twice, per iteration.

use super::gd::FactorizeResult;
use super::loss::{blast_loss_with, diag_utav, grad_s, grad_u, grad_v, gram_hadamard};
use crate::blast::BlastMatrix;
use crate::linalg::solve::{spd_solve_matrix, spd_solve_right};
use crate::tensor::{matmul_tn, Matrix, Rng};
use crate::util::par::par_map_if;

/// Options for Algorithm 2.
#[derive(Clone, Debug)]
pub struct PrecGdOptions {
    pub b: usize,
    pub r: usize,
    /// Iterations K.
    pub iters: usize,
    /// Init scale ε (Algorithm 2 line 1).
    pub init_eps: f32,
    /// δ₀ of Eq. 19 (paper uses 0.1).
    pub delta0: f32,
    /// Step size schedule: linearly decaying 1 → 0 like the paper, or
    /// constant 1.
    pub lr_decay: bool,
    pub seed: u64,
    pub trace_every: usize,
    /// Fan each sweep's independent per-factor updates (and the
    /// per-block loss terms) across the scoped-thread pool —
    /// bit-identical to the sequential schedule (see module docs).
    pub parallel: bool,
}

impl Default for PrecGdOptions {
    fn default() -> Self {
        PrecGdOptions {
            b: 4,
            r: 8,
            iters: 100,
            init_eps: 1e-2,
            delta0: 0.1,
            lr_decay: true,
            seed: 0,
            trace_every: 1,
            parallel: true,
        }
    }
}

/// Run Algorithm 2 on a dense target.
pub fn factorize_precgd(target: &Matrix, opts: &PrecGdOptions) -> FactorizeResult {
    let mut rng = Rng::new(opts.seed);
    let mut x = BlastMatrix::factorization_init(
        target.rows,
        target.cols,
        opts.b,
        opts.r,
        opts.init_eps,
        &mut rng,
    );
    let mut trace = Vec::new();
    let target_norm = target.fro_norm() as f64;
    let par = opts.parallel;

    // ℓ at the current iterate; refreshed after each iteration's sweeps
    // so Eq. 19's δ and the trace share one evaluation per iteration.
    let mut cur_loss = blast_loss_with(target, &x, par);

    for k in 0..opts.iters {
        let eta = if opts.lr_decay {
            1.0 - k as f32 / opts.iters as f32
        } else {
            1.0
        };
        // δ = δ₀ √ℓ (Eq. 19), from the pre-sweep loss.
        let delta = (opts.delta0 as f64 * cur_loss.sqrt()).max(1e-10) as f32;

        // --- U updates (Algorithm 2 line 3). ---
        let new_u = par_map_if(par, x.b, |i| {
            let v_bar = x.v_bar(i); // n×r
            let mut gram = matmul_tn(&v_bar, &v_bar); // r×r
            for t in 0..x.r {
                *gram.at_mut(t, t) += delta;
            }
            let g = grad_u(target, &x, i); // p×r
            let mut u = x.u[i].clone();
            // U -= η · g · (gram)^{-1}  (right preconditioning)
            match spd_solve_right(&g, &gram) {
                Ok(pg) => u.axpy(-eta, &pg),
                Err(_) => u.axpy(-eta / (gram.max_abs().max(1e-12)), &g),
            }
            u
        });
        x.u = new_u;

        // --- V updates (line 4), using updated U. ---
        let new_v = par_map_if(par, x.b, |j| {
            let u_bar = x.u_bar(j); // m×r
            let mut gram = matmul_tn(&u_bar, &u_bar);
            for t in 0..x.r {
                *gram.at_mut(t, t) += delta;
            }
            let g = grad_v(target, &x, j); // q×r
            let mut v = x.v[j].clone();
            match spd_solve_right(&g, &gram) {
                Ok(pg) => v.axpy(-eta, &pg),
                Err(_) => v.axpy(-eta / (gram.max_abs().max(1e-12)), &g),
            }
            v
        });
        x.v = new_v;

        // --- s updates (line 5), using updated U, V. ---
        let new_s = par_map_if(par, x.b * x.b, |idx| {
            let (i, j) = (idx / x.b, idx % x.b);
            let mut w = gram_hadamard(&x.u[i], &x.v[j]);
            let g = {
                // W s − diag(U^T A V) with the *updated* factors.
                let ws = crate::tensor::gemv(&w, &x.s[i][j]);
                let rhs = diag_utav(&x.u[i], &target.block(i, j, x.b, x.b), &x.v[j]);
                ws.iter().zip(&rhs).map(|(a, b)| a - b).collect::<Vec<f32>>()
            };
            for t in 0..x.r {
                *w.at_mut(t, t) += delta;
            }
            let gm = Matrix::from_vec(x.r, 1, g);
            let mut s = x.s[i][j].clone();
            match spd_solve_matrix(&w, &gm) {
                Ok(pg) => {
                    for t in 0..x.r {
                        s[t] -= eta * pg.at(t, 0);
                    }
                }
                Err(_) => {
                    let lip = w.max_abs().max(1e-12);
                    for t in 0..x.r {
                        s[t] -= eta / lip * gm.at(t, 0);
                    }
                }
            }
            s
        });
        for (idx, s) in new_s.into_iter().enumerate() {
            x.s[idx / x.b][idx % x.b] = s;
        }

        cur_loss = blast_loss_with(target, &x, par);
        if opts.trace_every > 0 && (k % opts.trace_every == 0 || k + 1 == opts.iters) {
            trace.push((k, cur_loss));
        }
    }

    let final_loss = cur_loss;
    let rel_error = (2.0 * final_loss).sqrt() / target_norm.max(1e-30);
    FactorizeResult { blast: x, trace, rel_error }
}

/// Sanity helper shared by tests and experiments: make sure the gradient
/// at the returned point is small relative to the target scale (we stopped
/// at a near-stationary point, not because of exhausted iterations with a
/// huge step).
pub fn stationarity_residual(target: &Matrix, x: &BlastMatrix) -> f64 {
    let mut acc = 0.0f64;
    for i in 0..x.b {
        acc += grad_u(target, x, i).fro_norm_sq();
    }
    for j in 0..x.b {
        acc += grad_v(target, x, j).fro_norm_sq();
    }
    for i in 0..x.b {
        for j in 0..x.b {
            acc += grad_s(target, x, i, j)
                .iter()
                .map(|g| (*g as f64) * (*g as f64))
                .sum::<f64>();
        }
    }
    acc.sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::factorize::gd::{factorize_gd, GdOptions};
    use crate::tensor::matmul_nt;

    fn low_rank_target(n: usize, r_star: usize, seed: u64) -> Matrix {
        let mut rng = Rng::new(seed);
        let u = rng.gaussian_matrix(n, r_star, 1.0);
        let v = rng.gaussian_matrix(n, r_star, 1.0);
        matmul_nt(&u, &v).scale(1.0 / (r_star as f32).sqrt())
    }

    fn blast_target(n: usize, b: usize, r_star: usize, seed: u64) -> Matrix {
        let mut rng = Rng::new(seed);
        BlastMatrix::random_init(n, n, b, r_star, 0.3, &mut rng).to_dense()
    }

    #[test]
    fn precgd_beats_gd_when_overparameterized() {
        // The paper's headline factorization claim (Fig. 3-right): with
        // r = 4·r*, PrecGD reaches much lower error than plain GD.
        let target = low_rank_target(64, 4, 100);
        let gd = factorize_gd(
            &target,
            &GdOptions { b: 4, r: 16, iters: 60, seed: 7, ..Default::default() },
        );
        let prec = factorize_precgd(
            &target,
            &PrecGdOptions { b: 4, r: 16, iters: 60, seed: 7, ..Default::default() },
        );
        assert!(
            prec.rel_error < 0.5 * gd.rel_error,
            "PrecGD {} should be well below GD {}",
            prec.rel_error,
            gd.rel_error
        );
        assert!(prec.rel_error < 0.05, "PrecGD rel error {}", prec.rel_error);
    }

    #[test]
    fn precgd_exact_rank_converges_fast() {
        let target = low_rank_target(64, 4, 101);
        let prec = factorize_precgd(
            &target,
            &PrecGdOptions { b: 4, r: 4, iters: 40, seed: 8, ..Default::default() },
        );
        assert!(prec.rel_error < 0.02, "rel error {}", prec.rel_error);
    }

    #[test]
    fn precgd_on_blast_target() {
        // Fig. 9 setup: the target itself is a BLAST matrix.
        let target = blast_target(64, 4, 4, 102);
        let prec = factorize_precgd(
            &target,
            &PrecGdOptions { b: 4, r: 8, iters: 60, seed: 9, ..Default::default() },
        );
        assert!(prec.rel_error < 0.1, "rel error {}", prec.rel_error);
    }

    #[test]
    fn loss_decreases_overall() {
        let target = low_rank_target(48, 4, 103);
        let prec = factorize_precgd(
            &target,
            &PrecGdOptions { b: 4, r: 8, iters: 40, seed: 10, ..Default::default() },
        );
        let first = prec.trace.first().unwrap().1;
        let last = prec.trace.last().unwrap().1;
        assert!(last < 1e-2 * first, "loss {first} -> {last}");
    }

    #[test]
    fn factors_stay_finite() {
        let target = blast_target(32, 2, 6, 104);
        let prec = factorize_precgd(
            &target,
            &PrecGdOptions { b: 2, r: 12, iters: 50, seed: 11, ..Default::default() },
        );
        assert!(!prec.blast.has_nonfinite());
    }

    #[test]
    fn rectangular_target() {
        // m != n exercises the p != q paths.
        let mut rng = Rng::new(105);
        let u = rng.gaussian_matrix(48, 4, 1.0);
        let v = rng.gaussian_matrix(32, 4, 1.0);
        let target = matmul_nt(&u, &v);
        let prec = factorize_precgd(
            &target,
            &PrecGdOptions { b: 4, r: 8, iters: 60, seed: 12, ..Default::default() },
        );
        assert!(prec.rel_error < 0.05, "rel error {}", prec.rel_error);
    }
}
