//! Parallel, resumable whole-model compression pipeline (paper §3.2 at
//! production scale).
//!
//! The paper's headline result comes from factorizing *existing* dense
//! weights layer by layer (Algorithm 2). Layers are embarrassingly
//! parallel, so this driver runs them through a work queue of scoped
//! worker threads, with three production affordances the single-call
//! [`Compressor`] lacks:
//!
//! * **Layer work queue** — `jobs` workers pull layer tasks off an
//!   atomic cursor. Each task gets a deterministic per-layer seed
//!   (`base_seed + layer_index`), so results are independent of worker
//!   scheduling. The inner PrecGD factor-grid parallelism stays on
//!   while the layer queue leaves at least half the thread pool idle
//!   (`2·jobs ≤ threads` — e.g. a resumed run with two layers left on a
//!   16-core box still uses the cores) and is switched off once the
//!   queue itself can keep the machine busy; the heuristic bounds, but
//!   does not eliminate, transient thread oversubscription (scoped
//!   threads are short-lived per sweep). Both knobs route into the same
//!   kernel-engine-backed solver and never change numerics.
//! * **Per-layer structure/budget selection** — a [`StructurePolicy`]:
//!   either one fixed [`Structure`] for every layer, or `Auto`, which
//!   tries every structured family at the target ratio and keeps the one
//!   with the lowest reconstruction error (the registry sweep of §4 as a
//!   policy).
//! * **Resume** — with a checkpoint directory, every finished layer
//!   appends one JSON line to `progress.jsonl` and writes its factors to
//!   `layers/layer<i>.bmx`. A killed run restarted with the same
//!   directory skips finished layers, reloads their factors, and
//!   produces the **same manifest** as an uninterrupted run (asserted in
//!   `tests/factorize_parity.rs`). The final `manifest.json` records
//!   per-layer structure, reconstruction error, and achieved compression.
//!
//! The CLI entry point is `blast compress` (see `main.rs`); the output
//! checkpoint loads directly into [`TinyLM`] and therefore into the
//! serving coordinator.

use super::compressor::{CompressedWeight, Compressor, Structure};
use crate::kernels::QuantMode;
use crate::nn::gpt::TinyLM;
use crate::nn::linear::{Linear, LinearWeight};
use crate::tensor::io::TensorBundle;
use crate::tensor::Matrix;
use crate::util::json::{obj, Json};
use crate::util::par;
use anyhow::{Context, Result};
use std::io::Write as _;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::Instant;

/// How the pipeline picks a structure for each layer.
#[derive(Clone, Debug)]
pub enum StructurePolicy {
    /// One structure for every layer.
    Fixed(Structure),
    /// Try Low-Rank / Block-Diagonal / Monarch / BLAST at the target
    /// ratio and keep the lowest-reconstruction-error representation.
    Auto { b: usize },
}

impl StructurePolicy {
    /// Parse a CLI token: a structure name or `auto`.
    pub fn parse(token: &str, b: usize) -> Option<StructurePolicy> {
        if token == "auto" {
            return Some(StructurePolicy::Auto { b });
        }
        Structure::parse(token, b).map(StructurePolicy::Fixed)
    }

    fn candidates(&self) -> Vec<Structure> {
        match *self {
            StructurePolicy::Fixed(s) => vec![s],
            StructurePolicy::Auto { b } => vec![
                Structure::LowRank,
                Structure::BlockDiag { b },
                Structure::Monarch { b },
                Structure::Blast { b },
            ],
        }
    }

    pub fn name(&self) -> String {
        match self {
            StructurePolicy::Fixed(s) => s.name(),
            StructurePolicy::Auto { b } => format!("Auto(b={b})"),
        }
    }
}

/// Pipeline configuration.
#[derive(Clone, Debug)]
pub struct PipelineOptions {
    pub policy: StructurePolicy,
    /// Target compression ratio (fraction of parameters removed).
    pub ratio: f64,
    /// Layer-queue worker threads; 0 = one per hardware thread.
    pub jobs: usize,
    /// Progress/factors/manifest directory; `None` disables
    /// checkpointing (pure in-memory run).
    pub checkpoint_dir: Option<PathBuf>,
    /// Test knob: stop after this many *newly compressed* layers, as if
    /// the process had been killed mid-run. `None` in production.
    pub max_layers: Option<usize>,
    /// Inference weight precision stamped on every processed layer at
    /// apply time (`--quantize int8`). Factor values are computed and
    /// checkpointed in f32 either way — quantization happens at pack
    /// time in the kernel engine, so the mode is pure metadata here.
    pub quantize: QuantMode,
}

impl Default for PipelineOptions {
    fn default() -> Self {
        PipelineOptions {
            policy: StructurePolicy::Fixed(Structure::Blast { b: 4 }),
            ratio: 0.5,
            jobs: 0,
            checkpoint_dir: None,
            max_layers: None,
            quantize: QuantMode::F32,
        }
    }
}

/// Outcome for one layer.
#[derive(Clone, Debug)]
pub struct LayerReport {
    pub name: String,
    /// Chosen structure name (`Dense(kept)` when no candidate met the
    /// budget and the dense weight was left in place).
    pub structure: String,
    pub rel_error: f64,
    pub params_before: usize,
    pub params_after: usize,
    pub seconds: f64,
    /// Whether the layer's weight was replaced.
    pub compressed: bool,
    /// Loaded from a checkpoint instead of recomputed.
    pub resumed: bool,
}

/// Whole-run summary (the manifest's in-memory form).
#[derive(Clone, Debug)]
pub struct PipelineReport {
    pub layers: Vec<LayerReport>,
    pub params_before: usize,
    pub params_after: usize,
    /// False only under the `max_layers` test knob.
    pub completed: bool,
    /// Weight precision stamped on the output model's layers.
    pub quantize: QuantMode,
}

impl PipelineReport {
    pub fn achieved_ratio(&self) -> f64 {
        1.0 - self.params_after as f64 / self.params_before.max(1) as f64
    }

    pub fn mean_rel_error(&self) -> f64 {
        let compressed: Vec<&LayerReport> =
            self.layers.iter().filter(|l| l.compressed).collect();
        if compressed.is_empty() {
            return 0.0;
        }
        compressed.iter().map(|l| l.rel_error).sum::<f64>() / compressed.len() as f64
    }

    /// Machine-readable manifest (written as `manifest.json`).
    pub fn manifest_json(&self) -> Json {
        let layers: Vec<Json> = self
            .layers
            .iter()
            .map(|l| {
                obj(vec![
                    ("layer", Json::from(l.name.clone())),
                    ("structure", Json::from(l.structure.clone())),
                    ("rel_error", Json::from(l.rel_error)),
                    ("params_before", Json::from(l.params_before)),
                    ("params_after", Json::from(l.params_after)),
                    ("compressed", Json::from(l.compressed)),
                    ("seconds", Json::from(l.seconds)),
                ])
            })
            .collect();
        obj(vec![
            ("layers", Json::Arr(layers)),
            ("params_before", Json::from(self.params_before)),
            ("params_after", Json::from(self.params_after)),
            ("achieved_ratio", Json::from(self.achieved_ratio())),
            ("mean_rel_error", Json::from(self.mean_rel_error())),
            ("completed", Json::from(self.completed)),
            ("quantize", Json::from(self.quantize.name().to_string())),
        ])
    }
}

struct LayerTask {
    index: usize,
    name: String,
    /// Weight shape (`out×inp`), known without materializing the weight.
    out: usize,
    inp: usize,
    params_before: usize,
    /// FNV-1a content fingerprint of the source weight (every stored
    /// tensor's bits, plus a structure tag) — part of the
    /// checkpoint-directory fingerprint that stops a resume against
    /// factors computed from a *different* model.
    content_hash: u64,
}

struct LayerOutcome {
    structure: String,
    /// `None` = keep the dense weight (budget infeasible).
    weight: Option<LinearWeight>,
    rel_error: f64,
    params_after: usize,
    seconds: f64,
    resumed: bool,
}

/// The pipeline driver.
pub struct CompressionPipeline {
    pub compressor: Compressor,
    pub opts: PipelineOptions,
}

impl CompressionPipeline {
    pub fn new(compressor: Compressor, opts: PipelineOptions) -> Self {
        CompressionPipeline { compressor, opts }
    }

    /// Compress every transformer linear of `model` in place (embeddings
    /// and head stay dense, as in the paper) and return the run report.
    pub fn compress_model(&self, model: &mut TinyLM) -> Result<PipelineReport> {
        let params_before_model = model.num_params();
        // Shared read-only views are the single layer enumeration; task
        // metadata derives from them, and each worker materializes its
        // current layer's dense weight on demand (peak extra memory is
        // one dense matrix per live worker, not per layer).
        let views = layer_views(model);
        let tasks = layer_tasks(&views, self.opts.checkpoint_dir.is_some());
        let total = tasks.len();

        // ---- Resume state -------------------------------------------
        let ckpt = match &self.opts.checkpoint_dir {
            Some(dir) => Some(CheckpointCtx::open(dir, &self.fingerprint(&tasks))?),
            None => None,
        };
        let mut outcomes: Vec<Option<LayerOutcome>> = (0..total).map(|_| None).collect();
        let mut pending: Vec<usize> = Vec::new();
        for task in &tasks {
            match ckpt.as_ref().and_then(|c| c.try_resume(task)) {
                Some(outcome) => outcomes[task.index] = Some(outcome),
                None => pending.push(task.index),
            }
        }
        if let Some(cap) = self.opts.max_layers {
            pending.truncate(cap);
        }

        // ---- Work queue ---------------------------------------------
        let jobs = if self.opts.jobs == 0 {
            par::num_threads()
        } else {
            self.opts.jobs
        }
        .min(pending.len())
        .max(1);
        let grid_parallel = grid_parallel_for(jobs, &self.compressor);

        let slots: Vec<Mutex<Option<LayerOutcome>>> =
            (0..total).map(|_| Mutex::new(None)).collect();
        let cursor = AtomicUsize::new(0);
        let done = AtomicUsize::new(outcomes.iter().filter(|o| o.is_some()).count());
        let worker_err: Mutex<Option<anyhow::Error>> = Mutex::new(None);
        std::thread::scope(|scope| {
            for _ in 0..jobs {
                scope.spawn(|| loop {
                    let at = cursor.fetch_add(1, Ordering::Relaxed);
                    let Some(&task_idx) = pending.get(at) else { break };
                    let task = &tasks[task_idx];
                    let dense = views[task_idx].1.dense_weight();
                    let outcome = self.compress_one(task, &dense, grid_parallel);
                    drop(dense);
                    // Per-layer observability: duration histogram,
                    // last-layer Eq.-4 loss, and the progress heartbeat
                    // (stderr, so piped report output stays clean).
                    let reg = crate::obs::registry();
                    reg.counter("factorize_layers_done").inc();
                    reg.gauge_f64("factorize_last_rel_error").set(outcome.rel_error);
                    reg.histogram("factorize_layer_seconds")
                        .record_us((outcome.seconds * 1e6) as u64);
                    let k = done.fetch_add(1, Ordering::Relaxed) + 1;
                    eprintln!(
                        "[compress] layer {k}/{total} {} -> {} rel_err={:.4} in {:.1}s",
                        task.name, outcome.structure, outcome.rel_error, outcome.seconds
                    );
                    if let Some(c) = &ckpt {
                        if let Err(e) = c.record(task, &outcome) {
                            *worker_err.lock().unwrap() = Some(e);
                            break;
                        }
                    }
                    *slots[task_idx].lock().unwrap() = Some(outcome);
                });
            }
        });
        drop(views);
        if let Some(e) = worker_err.into_inner().unwrap() {
            return Err(e).context("checkpointing a finished layer");
        }
        for (idx, slot) in slots.into_iter().enumerate() {
            if let Some(outcome) = slot.into_inner().unwrap() {
                outcomes[idx] = Some(outcome);
            }
        }

        // ---- Apply + report -----------------------------------------
        let mut layers = Vec::with_capacity(total);
        let mut completed = true;
        for ((name, layer), (task, outcome)) in
            layer_refs(model).into_iter().zip(tasks.iter().zip(&mut outcomes))
        {
            // Hard check (release builds included): the mutable
            // traversal must pair with the task list exactly, or a
            // compressed weight would silently land on the wrong
            // same-shaped layer.
            assert_eq!(name, task.name, "layer enumeration drift");
            let Some(outcome) = outcome.take() else {
                completed = false;
                continue;
            };
            let compressed = outcome.weight.is_some();
            if let Some(w) = outcome.weight {
                layer.weight = w; // bias is preserved
                // The weight structure changed in place: drop the
                // layer's cached execution plan so the next dispatch
                // lowers the new structure.
                layer.plan = Default::default();
            }
            // Stamp the run's weight precision (dense-kept layers too:
            // int8 panels apply to any structure, dense included).
            layer.set_quant(self.opts.quantize);
            layers.push(LayerReport {
                name: task.name.clone(),
                structure: outcome.structure,
                rel_error: outcome.rel_error,
                params_before: task.params_before,
                params_after: outcome.params_after,
                seconds: outcome.seconds,
                compressed,
                resumed: outcome.resumed,
            });
        }
        let report = PipelineReport {
            layers,
            params_before: params_before_model,
            params_after: model.num_params(),
            completed,
            quantize: self.opts.quantize,
        };
        // Final Eq.-4 loss over the whole run, as a gauge the snapshot
        // surfaces next to the per-layer histogram.
        if !report.layers.is_empty() {
            let mean = report.layers.iter().map(|l| l.rel_error).sum::<f64>()
                / report.layers.len() as f64;
            crate::obs::registry().gauge_f64("factorize_mean_rel_error").set(mean);
        }
        if completed {
            if let Some(c) = &ckpt {
                std::fs::write(
                    c.dir.join("manifest.json"),
                    report.manifest_json().to_string_pretty(),
                )
                .with_context(|| format!("writing manifest in {}", c.dir.display()))?;
            }
        }
        Ok(report)
    }

    /// File-to-file entry point: dense `.bmx` checkpoint in, compressed
    /// `.bmx` checkpoint out (ready for `TinyLM::load` / the serving
    /// coordinator). Returns the compressed model and the report.
    pub fn compress_checkpoint(
        &self,
        input: &Path,
        output: &Path,
    ) -> Result<(TinyLM, PipelineReport)> {
        let mut model = TinyLM::load(input)
            .with_context(|| format!("loading dense checkpoint {}", input.display()))?;
        let report = self.compress_model(&mut model)?;
        anyhow::ensure!(report.completed, "pipeline run did not complete");
        model
            .save(output)
            .with_context(|| format!("writing compressed checkpoint {}", output.display()))?;
        Ok((model, report))
    }

    /// Checkpoint-directory fingerprint: the compression configuration
    /// plus every source layer's shape and content hash. A directory
    /// created under a different config or from a different model must
    /// not be silently resumed (factors would be stale); see
    /// [`CheckpointCtx::open`].
    fn fingerprint(&self, tasks: &[LayerTask]) -> String {
        let layers: Vec<Json> = tasks
            .iter()
            .map(|t| {
                obj(vec![
                    ("name", Json::from(t.name.clone())),
                    ("out", Json::from(t.out)),
                    ("inp", Json::from(t.inp)),
                    ("hash", Json::from(format!("{:016x}", t.content_hash))),
                ])
            })
            .collect();
        obj(vec![
            ("policy", Json::from(self.opts.policy.name())),
            ("ratio", Json::from(self.opts.ratio)),
            ("quantize", Json::from(self.opts.quantize.name().to_string())),
            ("blast_iters", Json::from(self.compressor.blast_iters)),
            ("delta0", Json::from(self.compressor.delta0 as f64)),
            ("seed", Json::from(self.compressor.seed as usize)),
            ("layers", Json::Arr(layers)),
        ])
        .to_string()
    }

    /// Compress one layer: try the policy's candidates, keep the best.
    fn compress_one(&self, task: &LayerTask, dense: &Matrix, grid_parallel: bool) -> LayerOutcome {
        let t0 = Instant::now();
        let comp = Compressor {
            // Deterministic per-layer seed: results do not depend on
            // which worker picks the task up.
            seed: self.compressor.seed.wrapping_add(task.index as u64),
            parallel: grid_parallel,
            ..self.compressor.clone()
        };
        let mut best: Option<(Structure, CompressedWeight, f64)> = None;
        for s in self.opts.policy.candidates() {
            // One sweep = one candidate factorization attempt (Auto
            // tries four per layer, Fixed one).
            crate::obs::registry().counter("factorize_sweeps").inc();
            if let Some(w) = comp.compress(dense, s, self.opts.ratio) {
                let err = w.rel_error(dense);
                let better = match &best {
                    Some((_, _, e)) => err < *e,
                    None => true,
                };
                if better {
                    best = Some((s, w, err));
                }
            }
        }
        match best {
            Some((s, w, err)) => {
                let weight =
                    crate::train::linear_weight_from_compressed(w, task.out, task.inp);
                LayerOutcome {
                    structure: s.name(),
                    params_after: weight_params(&weight),
                    weight: Some(weight),
                    rel_error: err,
                    seconds: t0.elapsed().as_secs_f64(),
                    resumed: false,
                }
            }
            None => LayerOutcome {
                structure: "Dense(kept)".into(),
                weight: None,
                rel_error: 0.0,
                params_after: task.params_before,
                seconds: t0.elapsed().as_secs_f64(),
                resumed: false,
            },
        }
    }
}

/// Parameter count of a bare weight (no bias), matching
/// `Linear::num_params` minus the bias term.
fn weight_params(w: &LinearWeight) -> usize {
    Linear {
        weight: w.clone(),
        bias: None,
        out_features: 0,
        in_features: 0,
        quant: QuantMode::F32,
        plan: Default::default(),
    }
    .num_params()
}

/// FNV-1a over a stream of `u32` words.
fn fnv1a_u32s(words: impl Iterator<Item = u32>) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for w in words {
        for b in w.to_le_bytes() {
            h = (h ^ b as u64).wrapping_mul(0x100000001b3);
        }
    }
    h
}

/// Source-weight fingerprint: a structure tag, the layer shape, and the
/// bits of every stored tensor — read in place, never materializing a
/// dense reconstruction. Two layers hash equal only if their stored
/// representation is identical, so a checkpoint directory can never be
/// resumed against a model with different weights (dense *or*
/// structured sources).
fn weight_content_hash(layer: &Linear) -> u64 {
    let (tag, tensors): (u32, Vec<&Matrix>) = match &layer.weight {
        LinearWeight::Dense { w } => (1, vec![&w.v]),
        LinearWeight::LowRank { p, q } => (2, vec![&p.v, &q.v]),
        LinearWeight::Blast { u, v, s, .. } => {
            let mut t: Vec<&Matrix> = u.iter().map(|x| &x.v).collect();
            t.extend(v.iter().map(|x| &x.v));
            t.push(&s.v);
            (3, t)
        }
        LinearWeight::Monarch { rb, l, .. } => {
            let mut t: Vec<&Matrix> = rb.iter().map(|x| &x.v).collect();
            t.extend(l.iter().map(|x| &x.v));
            (4, t)
        }
        LinearWeight::BlockDiag { pd, qd, .. } => {
            let mut t: Vec<&Matrix> = pd.iter().map(|x| &x.v).collect();
            t.extend(qd.iter().map(|x| &x.v));
            (5, t)
        }
    };
    let header = [tag, layer.out_features as u32, layer.in_features as u32];
    fnv1a_u32s(
        header
            .into_iter()
            .chain(tensors.into_iter().flat_map(|m| m.data.iter().map(|x| x.to_bits()))),
    )
}

/// Whether PrecGD's inner factor-grid parallelism should run under
/// `jobs` layer-queue workers: the scoped-thread pool is shared and the
/// grid schedule is bit-identical regardless, so the grid stays on
/// whenever the layer queue alone cannot keep the machine busy
/// (`2·jobs ≤ threads`); only a near-saturating layer queue turns it
/// off to avoid multiplying live threads.
fn grid_parallel_for(jobs: usize, compressor: &Compressor) -> bool {
    compressor.parallel && (jobs == 1 || 2 * jobs <= par::num_threads())
}

/// Shared views over the compressible linears of a TinyLM, in
/// deterministic order (embeddings and head stay dense, as in the
/// paper). This is the single source of truth for the layer list —
/// [`layer_tasks`] derives from it, and the mutable [`layer_refs`]
/// traversal is checked against it by name at apply time (a hard
/// `assert`, active in release builds: same-shaped layers exist in
/// every block, so a silent mispairing would corrupt the model without
/// any shape error).
fn layer_views(model: &TinyLM) -> Vec<(String, &Linear)> {
    let mut out: Vec<(String, &Linear)> = Vec::new();
    for (i, blk) in model.blocks.iter().enumerate() {
        out.push((format!("block{i}.attn.wqkv"), &blk.attn.wqkv));
        out.push((format!("block{i}.attn.wo"), &blk.attn.wo));
        out.push((format!("block{i}.fc1"), &blk.fc1));
        out.push((format!("block{i}.fc2"), &blk.fc2));
    }
    out
}

/// Task metadata for [`layer_views`]' layers. Dense weights are
/// materialized per task by the workers; `with_hash` controls whether
/// the content fingerprint is computed (only the checkpointed path
/// reads it — the hash walks every weight byte, so skip it otherwise).
fn layer_tasks(views: &[(String, &Linear)], with_hash: bool) -> Vec<LayerTask> {
    views
        .iter()
        .enumerate()
        .map(|(index, (name, layer))| LayerTask {
            index,
            name: name.clone(),
            out: layer.out_features,
            inp: layer.in_features,
            params_before: weight_params(&layer.weight),
            content_hash: if with_hash { weight_content_hash(layer) } else { 0 },
        })
        .collect()
}

/// Mutable twins of [`layer_tasks`], for the apply phase.
fn layer_refs(model: &mut TinyLM) -> Vec<(String, &mut Linear)> {
    let mut out = Vec::new();
    for (i, blk) in model.blocks.iter_mut().enumerate() {
        out.push((format!("block{i}.attn.wqkv"), &mut blk.attn.wqkv));
        out.push((format!("block{i}.attn.wo"), &mut blk.attn.wo));
        out.push((format!("block{i}.fc1"), &mut blk.fc1));
        out.push((format!("block{i}.fc2"), &mut blk.fc2));
    }
    out
}

// ---------------------------------------------------------------------
// Checkpointing
// ---------------------------------------------------------------------

struct CheckpointCtx {
    dir: PathBuf,
    /// Finished-layer records from `progress.jsonl` (layer name → record).
    done: std::collections::BTreeMap<String, DoneRecord>,
    /// Append-mode progress writer, shared by the workers.
    writer: Mutex<std::io::BufWriter<std::fs::File>>,
}

#[derive(Clone, Debug)]
struct DoneRecord {
    index: usize,
    structure: String,
    rel_error: f64,
    params_after: usize,
    seconds: f64,
}

impl CheckpointCtx {
    /// Open (or create) a checkpoint directory. `fingerprint` is the
    /// run's full configuration + source-model signature: a directory
    /// whose stored `config.json` differs is from a *different* run and
    /// resuming it would silently apply stale factors, so that is a
    /// hard error (start over with `--fresh` or another directory).
    fn open(dir: &Path, fingerprint: &str) -> Result<CheckpointCtx> {
        std::fs::create_dir_all(dir.join("layers"))
            .with_context(|| format!("creating checkpoint dir {}", dir.display()))?;
        let config_path = dir.join("config.json");
        match std::fs::read_to_string(&config_path) {
            Ok(stored) if stored.trim() != fingerprint.trim() => {
                anyhow::bail!(
                    "checkpoint dir {} was created by a different compression run \
                     (config/model fingerprint mismatch); pass --fresh to discard it \
                     or use another --ckpt-dir",
                    dir.display()
                );
            }
            Ok(_) => {}
            Err(_) => {
                std::fs::write(&config_path, fingerprint)
                    .with_context(|| format!("writing {}", config_path.display()))?;
            }
        }
        let progress_path = dir.join("progress.jsonl");
        let mut done = std::collections::BTreeMap::new();
        if let Ok(text) = std::fs::read_to_string(&progress_path) {
            for line in text.lines() {
                // Tolerate a torn final line from a killed run.
                let Ok(v) = Json::parse(line) else { continue };
                let get_num = |k: &str| v.get(k).ok().and_then(Json::as_f64);
                let (Ok(layer), Ok(structure)) = (v.get("layer"), v.get("structure")) else {
                    continue;
                };
                let (Some(layer), Some(structure)) = (layer.as_str(), structure.as_str())
                else {
                    continue;
                };
                let (Some(index), Some(rel_error), Some(params_after)) = (
                    get_num("index"),
                    get_num("rel_error"),
                    get_num("params_after"),
                ) else {
                    continue;
                };
                done.insert(
                    layer.to_string(),
                    DoneRecord {
                        index: index as usize,
                        structure: structure.to_string(),
                        rel_error,
                        params_after: params_after as usize,
                        seconds: get_num("seconds").unwrap_or(0.0),
                    },
                );
            }
        }
        let file = std::fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(&progress_path)
            .with_context(|| format!("opening {}", progress_path.display()))?;
        Ok(CheckpointCtx {
            dir: dir.to_path_buf(),
            done,
            writer: Mutex::new(std::io::BufWriter::new(file)),
        })
    }

    fn factors_path(&self, index: usize) -> PathBuf {
        self.dir.join("layers").join(format!("layer{index}.bmx"))
    }

    /// Rebuild a finished layer's outcome from disk, or `None` if it
    /// must be (re)computed.
    fn try_resume(&self, task: &LayerTask) -> Option<LayerOutcome> {
        let rec = self.done.get(&task.name)?;
        if rec.index != task.index {
            return None; // stale checkpoint from a different model
        }
        if rec.structure == "Dense(kept)" {
            return Some(LayerOutcome {
                structure: rec.structure.clone(),
                weight: None,
                rel_error: rec.rel_error,
                params_after: rec.params_after,
                seconds: rec.seconds,
                resumed: true,
            });
        }
        let bundle = TensorBundle::load(self.factors_path(task.index)).ok()?;
        let layer = Linear::read_from(&bundle, "layer").ok()?;
        Some(LayerOutcome {
            structure: rec.structure.clone(),
            weight: Some(layer.weight),
            rel_error: rec.rel_error,
            params_after: rec.params_after,
            seconds: rec.seconds,
            resumed: true,
        })
    }

    /// Persist one finished layer: factors file first, then the progress
    /// line — a crash between the two leaves a recomputable layer, never
    /// a progress record pointing at missing factors.
    fn record(&self, task: &LayerTask, outcome: &LayerOutcome) -> Result<()> {
        if let Some(w) = &outcome.weight {
            // Factors checkpoint in f32 regardless of the run's quant
            // mode; the mode is re-stamped at apply time, so a resumed
            // run and a fresh run produce the same model.
            let carrier = Linear {
                weight: w.clone(),
                bias: None,
                out_features: task.out,
                in_features: task.inp,
                quant: QuantMode::F32,
                plan: Default::default(),
            };
            let mut bundle = TensorBundle::new();
            carrier.write_into(&mut bundle, "layer");
            bundle.save(self.factors_path(task.index))?;
        }
        let line = obj(vec![
            ("layer", Json::from(task.name.clone())),
            ("index", Json::from(task.index)),
            ("structure", Json::from(outcome.structure.clone())),
            ("rel_error", Json::from(outcome.rel_error)),
            ("params_before", Json::from(task.params_before)),
            ("params_after", Json::from(outcome.params_after)),
            ("seconds", Json::from(outcome.seconds)),
        ]);
        let text = line.to_string();
        let mut w = self.writer.lock().unwrap();
        writeln!(w, "{text}")?;
        w.flush()?;
        Ok(())
    }
}

/// Compress an arbitrary set of named linears through the layer work
/// queue (no checkpointing) — the parallel backend for `compress_lm` and
/// the ViT/DiT experiment harnesses. Returns each layer's relative
/// reconstruction error (`None` = budget infeasible, dense kept).
pub fn compress_linears_parallel(
    layers: Vec<(String, &mut Linear)>,
    compressor: &Compressor,
    structure: Structure,
    ratio: f64,
) -> Vec<Option<f64>> {
    let tasks: Vec<LayerTask> = layers
        .iter()
        .enumerate()
        .map(|(index, (name, layer))| LayerTask {
            index,
            name: name.clone(),
            out: layer.out_features,
            inp: layer.in_features,
            params_before: weight_params(&layer.weight),
            // No checkpointing on this path, so nothing reads the hash.
            content_hash: 0,
        })
        .collect();
    let pipe = CompressionPipeline::new(
        compressor.clone(),
        PipelineOptions {
            policy: StructurePolicy::Fixed(structure),
            ratio,
            ..Default::default()
        },
    );
    let jobs = par::num_threads().min(tasks.len()).max(1);
    let grid_parallel = grid_parallel_for(jobs, compressor);
    let outcomes = par::par_map_if(jobs > 1, tasks.len(), |i| {
        let dense = layers[i].1.dense_weight();
        pipe.compress_one(&tasks[i], &dense, grid_parallel)
    });
    let mut errs = Vec::with_capacity(tasks.len());
    for ((_, layer), outcome) in layers.into_iter().zip(outcomes) {
        match outcome.weight {
            Some(w) => {
                layer.weight = w;
                // New structure, stale plan: reset the layer's cell.
                layer.plan = Default::default();
                errs.push(Some(outcome.rel_error));
            }
            None => errs.push(None),
        }
    }
    errs
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::attention::StructureKind;
    use crate::nn::gpt::LmConfig;
    use crate::tensor::Rng;

    fn small_dense_lm(seed: u64) -> TinyLM {
        let mut rng = Rng::new(seed);
        TinyLM::new(LmConfig::tiny(StructureKind::Dense), &mut rng)
    }

    fn quick_pipeline(policy: StructurePolicy, dir: Option<PathBuf>) -> CompressionPipeline {
        CompressionPipeline::new(
            Compressor { blast_iters: 10, ..Default::default() },
            PipelineOptions { policy, ratio: 0.5, checkpoint_dir: dir, ..Default::default() },
        )
    }

    #[test]
    fn in_memory_run_compresses_every_layer() {
        let mut lm = small_dense_lm(900);
        let pipe = quick_pipeline(StructurePolicy::Fixed(Structure::Blast { b: 4 }), None);
        let report = pipe.compress_model(&mut lm).unwrap();
        assert!(report.completed);
        assert_eq!(report.layers.len(), lm.cfg.n_layers * 4);
        assert!(report.layers.iter().all(|l| l.compressed));
        assert!(report.params_after < report.params_before);
        assert!(report.achieved_ratio() > 0.0);
        // The compressed model still runs.
        let out = lm.generate(&[1, 2, 3], 4);
        assert_eq!(out.len(), 7);
    }

    #[test]
    fn auto_policy_never_worse_than_blockdiag() {
        let mut lm_auto = small_dense_lm(901);
        let mut lm_bd = small_dense_lm(901);
        let auto = quick_pipeline(StructurePolicy::Auto { b: 4 }, None)
            .compress_model(&mut lm_auto)
            .unwrap();
        let bd = quick_pipeline(StructurePolicy::Fixed(Structure::BlockDiag { b: 4 }), None)
            .compress_model(&mut lm_bd)
            .unwrap();
        assert!(auto.mean_rel_error() <= bd.mean_rel_error() + 1e-9);
    }

    #[test]
    fn deterministic_across_runs() {
        let mut a = small_dense_lm(902);
        let mut b = small_dense_lm(902);
        let ra = quick_pipeline(StructurePolicy::Fixed(Structure::Blast { b: 4 }), None)
            .compress_model(&mut a)
            .unwrap();
        let rb = quick_pipeline(StructurePolicy::Fixed(Structure::Blast { b: 4 }), None)
            .compress_model(&mut b)
            .unwrap();
        for (la, lb) in ra.layers.iter().zip(&rb.layers) {
            assert_eq!(la.rel_error, lb.rel_error, "{}", la.name);
        }
        let tokens = vec![5usize, 6, 7];
        assert_eq!(a.forward(&tokens).data, b.forward(&tokens).data);
    }

    #[test]
    fn quantized_run_stamps_layers_and_manifest() {
        let mut lm = small_dense_lm(903);
        let mut pipe = quick_pipeline(StructurePolicy::Fixed(Structure::Blast { b: 4 }), None);
        pipe.opts.quantize = QuantMode::I8;
        let report = pipe.compress_model(&mut lm).unwrap();
        assert!(report.completed);
        let m = report.manifest_json();
        assert_eq!(m.get("quantize").unwrap().as_str(), Some("int8"));
        for (_, layer) in layer_views(&lm) {
            assert_eq!(layer.quant, QuantMode::I8);
            assert_eq!(layer.plan_sig().q, QuantMode::I8);
        }
        // The quantized model still generates.
        let out = lm.generate(&[1, 2, 3], 4);
        assert_eq!(out.len(), 7);
    }

    #[test]
    fn policy_parse() {
        assert!(matches!(
            StructurePolicy::parse("auto", 4),
            Some(StructurePolicy::Auto { b: 4 })
        ));
        assert!(matches!(
            StructurePolicy::parse("blast", 8),
            Some(StructurePolicy::Fixed(Structure::Blast { b: 8 }))
        ));
        assert!(StructurePolicy::parse("bogus", 4).is_none());
    }
}
