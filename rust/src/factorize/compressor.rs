//! Uniform compression interface over all structures.
//!
//! The experiment harnesses sweep `(structure, compression ratio)` pairs;
//! this module hides the per-structure rank solving and factorization
//! behind one call so the sweeps stay declarative.

use super::baselines::{BlockDiagWeight, LowRankWeight, MonarchWeight};
use super::precgd::{factorize_precgd, PrecGdOptions};
use crate::blast::{budget, BlastMatrix};
use crate::tensor::Matrix;

/// The structured-matrix families evaluated in the paper (§4).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Structure {
    Dense,
    LowRank,
    Monarch { b: usize },
    BlockDiag { b: usize },
    Blast { b: usize },
}

impl Structure {
    /// Parse a CLI/config token (`dense`, `lowrank`, `monarch`,
    /// `blockdiag`, `blast`); block-structured families take `b` from the
    /// caller. `None` on unknown tokens.
    pub fn parse(token: &str, b: usize) -> Option<Structure> {
        match token {
            "dense" => Some(Structure::Dense),
            "lowrank" => Some(Structure::LowRank),
            "monarch" => Some(Structure::Monarch { b }),
            "blockdiag" => Some(Structure::BlockDiag { b }),
            "blast" => Some(Structure::Blast { b }),
            _ => None,
        }
    }

    pub fn name(&self) -> String {
        match self {
            Structure::Dense => "Dense".into(),
            Structure::LowRank => "Low-Rank".into(),
            Structure::Monarch { b } => format!("Monarch(b={b})"),
            Structure::BlockDiag { b } => format!("Block-Diagonal(b={b})"),
            Structure::Blast { b } => format!("BLAST{b}"),
        }
    }
}

/// A compressed weight of any structure, plus bookkeeping.
#[derive(Clone, Debug)]
pub enum CompressedWeight {
    Dense(Matrix),
    LowRank(LowRankWeight),
    Monarch(MonarchWeight),
    BlockDiag(BlockDiagWeight),
    Blast(BlastMatrix),
}

impl CompressedWeight {
    pub fn to_dense(&self) -> Matrix {
        match self {
            CompressedWeight::Dense(m) => m.clone(),
            CompressedWeight::LowRank(w) => w.to_dense(),
            CompressedWeight::Monarch(w) => w.to_dense(),
            CompressedWeight::BlockDiag(w) => w.to_dense(),
            CompressedWeight::Blast(w) => w.to_dense(),
        }
    }

    pub fn num_params(&self) -> usize {
        match self {
            CompressedWeight::Dense(m) => m.len(),
            CompressedWeight::LowRank(w) => w.num_params(),
            CompressedWeight::Monarch(w) => w.num_params(),
            CompressedWeight::BlockDiag(w) => w.num_params(),
            CompressedWeight::Blast(w) => w.num_params(),
        }
    }

    /// `y = X A^T` — the linear-layer forward for any structure.
    pub fn matmul_act(&self, x: &Matrix) -> Matrix {
        match self {
            CompressedWeight::Dense(m) => crate::tensor::matmul_nt(x, m),
            CompressedWeight::LowRank(w) => w.matmul_act(x),
            CompressedWeight::Monarch(w) => w.matmul_act(x),
            CompressedWeight::BlockDiag(w) => w.matmul_act(x),
            CompressedWeight::Blast(w) => w.matmul_act(x),
        }
    }

    /// Relative reconstruction error vs. the original weight.
    pub fn rel_error(&self, original: &Matrix) -> f64 {
        let d = self.to_dense();
        d.sub(original).fro_norm() as f64 / (original.fro_norm() as f64).max(1e-30)
    }
}

/// Compression engine: given a dense weight and a target ratio, produce
/// the compressed representation for a structure.
#[derive(Clone, Debug)]
pub struct Compressor {
    /// PrecGD iterations for BLAST factorization (paper: K=300 for Llama,
    /// K=500 for DiT; tests use fewer).
    pub blast_iters: usize,
    pub delta0: f32,
    pub seed: u64,
    /// Run PrecGD's `b×b` factor-grid updates across the thread pool
    /// (bit-identical to the sequential sweep). The pipeline driver
    /// turns this off when it already parallelizes across layers, so the
    /// machine is not oversubscribed.
    pub parallel: bool,
}

impl Default for Compressor {
    fn default() -> Self {
        Compressor { blast_iters: 150, delta0: 0.1, seed: 0, parallel: true }
    }
}

impl Compressor {
    /// Compress `a` with `structure` at compression `ratio` (fraction of
    /// parameters removed). Returns `None` if the structure cannot meet
    /// the budget (e.g. rank would be 0).
    pub fn compress(
        &self,
        a: &Matrix,
        structure: Structure,
        ratio: f64,
    ) -> Option<CompressedWeight> {
        let (m, n) = a.shape();
        match structure {
            Structure::Dense => Some(CompressedWeight::Dense(a.clone())),
            Structure::LowRank => {
                let r = budget::lowrank_rank_for_ratio(m, n, ratio)?;
                Some(CompressedWeight::LowRank(LowRankWeight::compress(a, r)))
            }
            Structure::Monarch { b } => {
                let t = budget::monarch_rank_for_ratio(m, n, b, ratio)?;
                Some(CompressedWeight::Monarch(MonarchWeight::compress(a, b, t)))
            }
            Structure::BlockDiag { b } => {
                let t = budget::blockdiag_rank_for_ratio(m, n, b, ratio)?;
                Some(CompressedWeight::BlockDiag(BlockDiagWeight::compress(a, b, t)))
            }
            Structure::Blast { b } => {
                let r = budget::blast_rank_for_ratio(m, n, b, ratio)?;
                let res = factorize_precgd(
                    a,
                    &PrecGdOptions {
                        b,
                        r,
                        iters: self.blast_iters,
                        delta0: self.delta0,
                        seed: self.seed,
                        parallel: self.parallel,
                        ..Default::default()
                    },
                );
                Some(CompressedWeight::Blast(res.blast))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Rng;

    #[test]
    fn all_structures_meet_budget() {
        let mut rng = Rng::new(120);
        let a = rng.gaussian_matrix(32, 32, 1.0);
        let c = Compressor { blast_iters: 30, ..Default::default() };
        let dense_params = 32 * 32;
        for s in [
            Structure::LowRank,
            Structure::Monarch { b: 4 },
            Structure::BlockDiag { b: 4 },
            Structure::Blast { b: 4 },
        ] {
            let w = c.compress(&a, s, 0.5).unwrap_or_else(|| panic!("{s:?} failed"));
            assert!(
                w.num_params() <= dense_params / 2 + 64,
                "{s:?}: {} params",
                w.num_params()
            );
        }
    }

    #[test]
    fn matmul_act_consistent_with_dense_reconstruction() {
        let mut rng = Rng::new(121);
        let a = rng.gaussian_matrix(16, 16, 1.0);
        let x = rng.gaussian_matrix(3, 16, 1.0);
        let c = Compressor { blast_iters: 20, ..Default::default() };
        for s in [
            Structure::Dense,
            Structure::LowRank,
            Structure::Monarch { b: 2 },
            Structure::BlockDiag { b: 2 },
            Structure::Blast { b: 2 },
        ] {
            let w = c.compress(&a, s, 0.4).unwrap();
            let y = w.matmul_act(&x);
            let y_ref = crate::tensor::matmul_nt(&x, &w.to_dense());
            assert!(
                y.sub(&y_ref).fro_norm() < 1e-3 * (1.0 + y_ref.fro_norm()),
                "{s:?}"
            );
        }
    }

    #[test]
    fn infeasible_budget_returns_none() {
        let mut rng = Rng::new(122);
        let a = rng.gaussian_matrix(8, 8, 1.0);
        let c = Compressor::default();
        assert!(c.compress(&a, Structure::LowRank, 0.99).is_none());
    }

    #[test]
    fn dense_passthrough_exact() {
        let mut rng = Rng::new(123);
        let a = rng.gaussian_matrix(8, 8, 1.0);
        let c = Compressor::default();
        let w = c.compress(&a, Structure::Dense, 0.0).unwrap();
        assert!(w.rel_error(&a) < 1e-9);
    }
}
