//! Alternating gradient descent for BLAST factorization (Eqs. 5–7) with
//! the Theorem-1 step-size rule.
//!
//! Each iteration performs three sweeps — all `U_i`, then all `V_j`
//! (using the *updated* U), then all `s_{i,j}` (using updated U and V) —
//! exactly the ordering of Eqs. 5–7 that Theorem 1's monotone-descent
//! proof requires. *Within* a sweep the per-factor updates are mutually
//! independent (each `U_i` reads only `V`/`s`, each `V_j` only the
//! already-updated `U`/`s`, each `s_{i,j}` only `U_i`/`V_j`), so with
//! [`GdOptions::parallel`] the sweep fans the `b` (or `b²`) updates
//! across the scoped-thread pool with a barrier between sweeps —
//! bit-identical to the sequential order because each update's
//! arithmetic is unchanged and results are written back in index order.
//! Step sizes are either a user-supplied schedule scaled into the
//! Lipschitz bound, or the bound itself:
//! `η_U ≤ 1/σ₁(V̄^T V̄)`, `η_V ≤ 1/σ₁(Ū^T Ū)`,
//! `η_s ≤ 1/σ₁((U^T U)⊙(V^T V))`.

use super::loss::{blast_loss_with, grad_s, grad_u, grad_v, gram_hadamard};
use crate::blast::BlastMatrix;
use crate::linalg::svd::lambda_max_psd;
use crate::tensor::{matmul_tn, Matrix, Rng};
use crate::util::par::par_map_if;

/// Options for plain (non-preconditioned) GD factorization.
#[derive(Clone, Debug)]
pub struct GdOptions {
    /// Number of blocks per side.
    pub b: usize,
    /// BLAST rank.
    pub r: usize,
    /// Iterations.
    pub iters: usize,
    /// Init scale ε for `U, V ~ N(0, ε²)`.
    pub init_eps: f32,
    /// RNG seed.
    pub seed: u64,
    /// Step-size schedule multiplier at iteration k in [0, 1]; the
    /// effective step is `schedule(k) / L` with `L` the per-factor
    /// Lipschitz constant. The paper uses a linearly decaying schedule.
    pub lr_decay: bool,
    /// Record the loss every `trace_every` iterations (0 = never).
    pub trace_every: usize,
    /// Fan each sweep's independent per-factor updates across the
    /// scoped-thread pool (bit-identical to the sequential sweep; see
    /// module docs).
    pub parallel: bool,
}

impl Default for GdOptions {
    fn default() -> Self {
        GdOptions {
            b: 4,
            r: 8,
            iters: 100,
            init_eps: 1e-2,
            seed: 0,
            lr_decay: true,
            trace_every: 1,
            parallel: true,
        }
    }
}

/// Result of a factorization run.
#[derive(Clone, Debug)]
pub struct FactorizeResult {
    pub blast: BlastMatrix,
    /// `(iteration, loss)` trace of Eq. 4.
    pub trace: Vec<(usize, f64)>,
    /// Final relative reconstruction error `‖A − Â‖_F / ‖A‖_F`.
    pub rel_error: f64,
}

/// Plain alternating GD (Eqs. 5–7).
pub fn factorize_gd(target: &Matrix, opts: &GdOptions) -> FactorizeResult {
    let mut rng = Rng::new(opts.seed);
    let mut x = BlastMatrix::factorization_init(
        target.rows,
        target.cols,
        opts.b,
        opts.r,
        opts.init_eps,
        &mut rng,
    );
    let mut trace = Vec::new();
    let target_norm = target.fro_norm() as f64;

    let par = opts.parallel;
    for k in 0..opts.iters {
        let sched = if opts.lr_decay {
            1.0 - k as f32 / opts.iters as f32
        } else {
            1.0
        };

        // --- U sweep (Eq. 5), step 1/σ₁(V̄_i^T V̄_i). ---
        let new_u = par_map_if(par, x.b, |i| {
            let v_bar = x.v_bar(i);
            let lip = lambda_max_psd(&matmul_tn(&v_bar, &v_bar)).max(1e-12);
            let g = grad_u(target, &x, i);
            let mut u = x.u[i].clone();
            u.axpy(-sched / lip, &g);
            u
        });
        x.u = new_u;

        // --- V sweep (Eq. 6) with updated U. ---
        let new_v = par_map_if(par, x.b, |j| {
            let u_bar = x.u_bar(j);
            let lip = lambda_max_psd(&matmul_tn(&u_bar, &u_bar)).max(1e-12);
            let g = grad_v(target, &x, j);
            let mut v = x.v[j].clone();
            v.axpy(-sched / lip, &g);
            v
        });
        x.v = new_v;

        // --- s sweep (Eq. 7) with updated U, V. ---
        let new_s = par_map_if(par, x.b * x.b, |idx| {
            let (i, j) = (idx / x.b, idx % x.b);
            let w = gram_hadamard(&x.u[i], &x.v[j]);
            let lip = lambda_max_psd(&w).max(1e-12);
            let g = grad_s(target, &x, i, j);
            let eta = sched / lip;
            x.s[i][j]
                .iter()
                .zip(&g)
                .map(|(sk, gk)| sk - eta * gk)
                .collect::<Vec<f32>>()
        });
        for (idx, s) in new_s.into_iter().enumerate() {
            x.s[idx / x.b][idx % x.b] = s;
        }

        if opts.trace_every > 0 && (k % opts.trace_every == 0 || k + 1 == opts.iters) {
            trace.push((k, blast_loss_with(target, &x, par)));
        }
    }

    let final_loss = blast_loss_with(target, &x, par);
    let rel_error = (2.0 * final_loss).sqrt() / target_norm.max(1e-30);
    FactorizeResult { blast: x, trace, rel_error }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::matmul_nt;

    /// Synthetic low-rank target of exact rank r*.
    pub(crate) fn low_rank_target(n: usize, r_star: usize, seed: u64) -> Matrix {
        let mut rng = Rng::new(seed);
        let u = rng.gaussian_matrix(n, r_star, 1.0);
        let v = rng.gaussian_matrix(n, r_star, 1.0);
        matmul_nt(&u, &v).scale(1.0 / (r_star as f32).sqrt())
    }

    #[test]
    fn exact_rank_converges() {
        // Paper Fig. 3-left setup (scaled down for test time): rank-4
        // target, r = r* = 4, b = 4 — GD finds a low-error solution.
        let target = low_rank_target(64, 4, 90);
        let opts = GdOptions { b: 4, r: 4, iters: 80, seed: 1, ..Default::default() };
        let res = factorize_gd(&target, &opts);
        assert!(res.rel_error < 0.05, "rel error {}", res.rel_error);
    }

    #[test]
    fn loss_monotone_nonincreasing() {
        // Theorem 1: with the 1/L step sizes (no decay, so the pure bound)
        // the loss never increases.
        let target = low_rank_target(48, 6, 91);
        let opts = GdOptions {
            b: 4,
            r: 8,
            iters: 40,
            lr_decay: false,
            seed: 2,
            trace_every: 1,
            ..Default::default()
        };
        let res = factorize_gd(&target, &opts);
        for w in res.trace.windows(2) {
            assert!(
                w[1].1 <= w[0].1 * (1.0 + 1e-6) + 1e-9,
                "loss increased: {} -> {} at iter {}",
                w[0].1,
                w[1].1,
                w[1].0
            );
        }
    }

    #[test]
    fn overparameterized_converges_slower() {
        // Fig. 3-right: r = 4·r* converges more slowly than exact r at the
        // same iteration count.
        let target = low_rank_target(64, 4, 92);
        let exact = factorize_gd(
            &target,
            &GdOptions { b: 4, r: 4, iters: 60, seed: 3, ..Default::default() },
        );
        let over = factorize_gd(
            &target,
            &GdOptions { b: 4, r: 16, iters: 60, seed: 3, ..Default::default() },
        );
        assert!(
            over.rel_error > exact.rel_error,
            "overparam {} should exceed exact {}",
            over.rel_error,
            exact.rel_error
        );
    }

    #[test]
    fn trace_recorded() {
        let target = low_rank_target(32, 2, 93);
        let res = factorize_gd(
            &target,
            &GdOptions { b: 2, r: 2, iters: 10, trace_every: 2, seed: 4, ..Default::default() },
        );
        assert!(res.trace.len() >= 5);
        assert_eq!(res.trace[0].0, 0);
    }

    #[test]
    fn parallel_sweep_bit_identical_to_sequential() {
        let target = low_rank_target(48, 4, 95);
        let seq = factorize_gd(
            &target,
            &GdOptions { b: 4, r: 8, iters: 15, seed: 6, parallel: false, ..Default::default() },
        );
        let par = factorize_gd(
            &target,
            &GdOptions { b: 4, r: 8, iters: 15, seed: 6, parallel: true, ..Default::default() },
        );
        assert_eq!(seq.rel_error, par.rel_error);
        assert_eq!(seq.trace, par.trace);
        for (a, b) in seq.blast.u.iter().zip(&par.blast.u) {
            assert_eq!(a.data, b.data);
        }
    }

    #[test]
    fn no_nonfinite_factors() {
        let target = low_rank_target(32, 4, 94);
        let res = factorize_gd(
            &target,
            &GdOptions { b: 4, r: 8, iters: 50, seed: 5, ..Default::default() },
        );
        assert!(!res.blast.has_nonfinite());
    }
}
