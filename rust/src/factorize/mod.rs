//! Weight compression via factorization (paper §3.2).
//!
//! * [`loss`] — the block-wise Frobenius objective of Eq. 4 and its
//!   gradients (Appendix A.2.1).
//! * [`gd`] — plain alternating gradient descent (Eqs. 5–7) with the
//!   Theorem-1 step-size rule.
//! * [`precgd`] — Algorithm 2: preconditioned GD with the regularized
//!   Gram-inverse preconditioners of Eqs. 8–9.
//! * [`baselines`] — Low-Rank (truncated SVD), Monarch (per-block-column
//!   shared-basis SVD), and Block-Diagonal compressors the paper compares
//!   against.
//! * [`compressor`] — a uniform `Compressor` interface + registry used by
//!   the experiment harnesses.
//! * [`pipeline`] — the parallel, resumable whole-model compression
//!   driver (layer work queue, JSONL progress checkpointing, manifest);
//!   backs the `blast compress` CLI.

pub mod loss;
pub mod gd;
pub mod precgd;
pub mod baselines;
pub mod compressor;
pub mod pipeline;

pub use compressor::{CompressedWeight, Compressor, Structure};
pub use gd::{factorize_gd, GdOptions};
pub use pipeline::{
    compress_linears_parallel, CompressionPipeline, PipelineOptions, PipelineReport,
    StructurePolicy,
};
pub use precgd::{factorize_precgd, PrecGdOptions};
pub use loss::{blast_loss, blast_loss_with};
