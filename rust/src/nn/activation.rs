//! Activations and the cross-entropy head, with backward passes.

use crate::tensor::Matrix;

/// GELU (tanh approximation, as used by GPT-2/ViT).
pub fn gelu(x: &Matrix) -> Matrix {
    x.map(gelu_scalar)
}

#[inline]
pub fn gelu_scalar(x: f32) -> f32 {
    const C: f32 = 0.7978845608; // sqrt(2/pi)
    0.5 * x * (1.0 + (C * (x + 0.044715 * x * x * x)).tanh())
}

/// In-place GELU (the decode hot path's allocation-free variant;
/// bit-identical to [`gelu`]).
pub fn gelu_inplace(x: &mut Matrix) {
    x.map_inplace(gelu_scalar);
}

/// d/dx gelu(x).
#[inline]
pub fn gelu_grad_scalar(x: f32) -> f32 {
    const C: f32 = 0.7978845608;
    let x3 = 0.044715 * x * x * x;
    let t = (C * (x + x3)).tanh();
    let sech2 = 1.0 - t * t;
    0.5 * (1.0 + t) + 0.5 * x * sech2 * C * (1.0 + 3.0 * 0.044715 * x * x)
}

/// Backward: `dx = dy ⊙ gelu'(x)`.
pub fn gelu_backward(x: &Matrix, dy: &Matrix) -> Matrix {
    assert_eq!(x.shape(), dy.shape());
    let data = x
        .data
        .iter()
        .zip(&dy.data)
        .map(|(xv, dv)| gelu_grad_scalar(*xv) * dv)
        .collect();
    Matrix { rows: x.rows, cols: x.cols, data }
}

/// SiLU (used by Llama-style MLPs).
pub fn silu(x: &Matrix) -> Matrix {
    x.map(|v| v / (1.0 + (-v).exp()))
}

/// Row-wise softmax (numerically stable).
pub fn softmax_rows(x: &Matrix) -> Matrix {
    let mut out = x.clone();
    for i in 0..out.rows {
        let row = out.row_mut(i);
        let max = row.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
        let mut sum = 0.0f32;
        for v in row.iter_mut() {
            *v = (*v - max).exp();
            sum += *v;
        }
        let inv = 1.0 / sum.max(1e-30);
        for v in row.iter_mut() {
            *v *= inv;
        }
    }
    out
}

/// Softmax backward given the softmax output `p` and upstream `dy`:
/// `dx_i = p_i (dy_i − Σ_j p_j dy_j)` per row.
pub fn softmax_backward(p: &Matrix, dy: &Matrix) -> Matrix {
    assert_eq!(p.shape(), dy.shape());
    let mut dx = Matrix::zeros(p.rows, p.cols);
    for i in 0..p.rows {
        let prow = p.row(i);
        let drow = dy.row(i);
        let dot: f32 = prow.iter().zip(drow).map(|(a, b)| a * b).sum();
        let out = dx.row_mut(i);
        for j in 0..prow.len() {
            out[j] = prow[j] * (drow[j] - dot);
        }
    }
    dx
}

/// Mean cross-entropy over rows of `logits` against integer `targets`.
/// Returns `(loss, dlogits)` with `dlogits` already scaled by `1/rows`
/// (the gradient of the mean loss). Rows with `target == ignore` are
/// skipped (padding).
pub fn cross_entropy(logits: &Matrix, targets: &[usize], ignore: usize) -> (f64, Matrix) {
    assert_eq!(logits.rows, targets.len());
    let p = softmax_rows(logits);
    let mut dlogits = p.clone();
    let mut loss = 0.0f64;
    let mut count = 0usize;
    for (i, &t) in targets.iter().enumerate() {
        if t == ignore {
            dlogits.row_mut(i).fill(0.0);
            continue;
        }
        assert!(t < logits.cols, "target {t} out of vocab {}", logits.cols);
        loss -= (p.at(i, t).max(1e-30) as f64).ln();
        *dlogits.at_mut(i, t) -= 1.0;
        count += 1;
    }
    let count = count.max(1);
    loss /= count as f64;
    dlogits.scale_inplace(1.0 / count as f32);
    (loss, dlogits)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Rng;

    #[test]
    fn gelu_fixed_points() {
        assert_eq!(gelu_scalar(0.0), 0.0);
        assert!((gelu_scalar(10.0) - 10.0).abs() < 1e-3);
        assert!(gelu_scalar(-10.0).abs() < 1e-3);
        // gelu(1) ≈ 0.8412
        assert!((gelu_scalar(1.0) - 0.8412).abs() < 1e-3);
    }

    #[test]
    fn gelu_grad_matches_fd() {
        for &x in &[-2.0f32, -0.5, 0.0, 0.3, 1.7] {
            let h = 1e-3;
            let num = (gelu_scalar(x + h) - gelu_scalar(x - h)) / (2.0 * h);
            let ana = gelu_grad_scalar(x);
            assert!((num - ana).abs() < 1e-3, "x={x}: {num} vs {ana}");
        }
    }

    #[test]
    fn softmax_rows_normalized() {
        let mut rng = Rng::new(320);
        let x = rng.gaussian_matrix(5, 7, 3.0);
        let p = softmax_rows(&x);
        for i in 0..5 {
            let s: f32 = p.row(i).iter().sum();
            assert!((s - 1.0).abs() < 1e-5);
            assert!(p.row(i).iter().all(|&v| v >= 0.0));
        }
    }

    #[test]
    fn softmax_stable_at_large_logits() {
        let x = Matrix::from_vec(1, 3, vec![1000.0, 999.0, -1000.0]);
        let p = softmax_rows(&x);
        assert!(p.data.iter().all(|v| v.is_finite()));
        assert!(p.at(0, 0) > p.at(0, 1));
    }

    #[test]
    fn softmax_backward_matches_fd() {
        let mut rng = Rng::new(321);
        let x = rng.gaussian_matrix(2, 4, 1.0);
        let dy = rng.gaussian_matrix(2, 4, 1.0);
        let p = softmax_rows(&x);
        let dx = softmax_backward(&p, &dy);
        let h = 1e-3f32;
        for (i, j) in [(0, 0), (1, 2)] {
            let mut xp = x.clone();
            *xp.at_mut(i, j) += h;
            let mut xm = x.clone();
            *xm.at_mut(i, j) -= h;
            let f = |m: &Matrix| -> f32 {
                softmax_rows(m)
                    .data
                    .iter()
                    .zip(&dy.data)
                    .map(|(a, b)| a * b)
                    .sum()
            };
            let num = (f(&xp) - f(&xm)) / (2.0 * h);
            assert!((num - dx.at(i, j)).abs() < 1e-2, "({i},{j})");
        }
    }

    #[test]
    fn cross_entropy_perfect_prediction() {
        // Huge logit on the right class -> near-zero loss.
        let mut logits = Matrix::zeros(2, 3);
        logits.set(0, 1, 100.0);
        logits.set(1, 2, 100.0);
        let (loss, dl) = cross_entropy(&logits, &[1, 2], usize::MAX);
        assert!(loss < 1e-6);
        assert!(dl.max_abs() < 1e-3);
    }

    #[test]
    fn cross_entropy_uniform_is_log_vocab() {
        let logits = Matrix::zeros(4, 10);
        let (loss, _) = cross_entropy(&logits, &[0, 3, 5, 9], usize::MAX);
        assert!((loss - (10.0f64).ln()).abs() < 1e-5);
    }

    #[test]
    fn cross_entropy_grad_matches_fd() {
        let mut rng = Rng::new(322);
        let logits = rng.gaussian_matrix(3, 5, 1.0);
        let targets = [2usize, 0, 4];
        let (_, dl) = cross_entropy(&logits, &targets, usize::MAX);
        let h = 1e-2f32;
        for (i, j) in [(0, 2), (1, 1), (2, 4)] {
            let mut lp = logits.clone();
            *lp.at_mut(i, j) += h;
            let mut lm = logits.clone();
            *lm.at_mut(i, j) -= h;
            let (fp, _) = cross_entropy(&lp, &targets, usize::MAX);
            let (fm, _) = cross_entropy(&lm, &targets, usize::MAX);
            let num = ((fp - fm) / (2.0 * h as f64)) as f32;
            assert!((num - dl.at(i, j)).abs() < 1e-3, "({i},{j})");
        }
    }

    #[test]
    fn cross_entropy_ignores_padding() {
        let mut logits = Matrix::zeros(2, 3);
        logits.set(0, 0, 5.0);
        let (loss_with_pad, dl) = cross_entropy(&logits, &[0, usize::MAX], usize::MAX);
        let (loss_single, _) = cross_entropy(&logits.submatrix(0, 1, 0, 3), &[0], usize::MAX);
        assert!((loss_with_pad - loss_single).abs() < 1e-9);
        assert!(dl.row(1).iter().all(|&v| v == 0.0));
    }
}
